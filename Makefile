# Convenience targets for the local-mapper workspace.
#
#   make check      fmt --check + clippy -D warnings + tier-1 build/tests + examples
#   make test       tier-1 only (what the CI gate runs)
#   make examples   build every cargo example (the public-API canary)
#   make api-json   compile-all → compile_all.json (the api_v1 document CI validates)
#   make bench      all nine paper/ablation reports
#   make bench-json perf harness (smoke) → BENCH_eval.json at the repo root
#   make doc        rustdoc, warnings are errors
#   make artifacts  AOT-compile the JAX/Pallas conv artifacts (needs jax)

.PHONY: check fmt clippy test examples api-json bench bench-json doc artifacts

check: fmt clippy test examples

examples:
	cargo build --examples

api-json:
	cargo run --release --bin local-mapper -- compile-all --threads 4 --format json > compile_all.json

fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

test:
	cargo build --release && cargo test -q

bench:
	for b in ablation_latency_sim fig3_random fig7_energy mapper_quality \
	         motivation_mapspace noc_validation perf_analyzer \
	         table2_workloads table3_mapping_time; do \
	    cargo bench --bench $$b || exit 1; \
	done

bench-json:
	cargo run --release --bin local-mapper -- perf --smoke --out BENCH_eval.json

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

artifacts:
	python3 python/compile/aot.py --out artifacts
