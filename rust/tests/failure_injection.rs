//! Failure-injection tests: the framework must fail loudly and precisely
//! on impossible hardware, malformed configs, corrupt manifests and
//! unsatisfiable mappings — a compiler component cannot silently mis-map.

use local_mapper::arch::{config, presets, Accelerator, Noc, PeArray, StorageLevel, Style};
use local_mapper::coordinator::MappingService;
use local_mapper::fault::{self, FaultKind};
use local_mapper::mappers::{LocalMapper, MapStatus, Mapper};
use local_mapper::mapping::{Mapping, MappingError};
use local_mapper::model::evaluate;
use local_mapper::runtime::read_manifest;
use local_mapper::workload::{zoo, ConvLayer};
use std::sync::atomic::Ordering;
use std::sync::Mutex;
use std::time::Duration;

/// The fault plan and the submission-ordinal counter are process globals,
/// and this binary's tests run concurrently: every test that arms a fault
/// *or* drives a [`MappingService`] (whose submit path consults those
/// globals) serializes on this lock. Poisoning is tolerated so one failed
/// assertion doesn't cascade.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn tiny_rf_acc(rf_depth: u64) -> Accelerator {
    Accelerator {
        name: "broken".into(),
        style: Style::EyerissLike,
        datawidth_bits: 16,
        levels: vec![
            StorageLevel::register_file("RF", rf_depth, 16),
            StorageLevel::buffer("GLB", 1024, 64),
            StorageLevel::dram(64),
        ],
        pe: PeArray::new(2, 2),
        noc: Noc::default(),
        mac_energy_pj: 1.0,
        clock_mhz: 200.0,
    }
}

#[test]
fn local_survives_degenerate_rf() {
    // A 3-element RF can hold exactly the all-1 tile (W+I+O): LOCAL must
    // still produce a valid (if poor) mapping.
    let acc = tiny_rf_acc(3);
    let layer = zoo::alexnet()[2].clone();
    let m = LocalMapper::new().map(&layer, &acc).unwrap();
    m.validate(&layer, &acc).unwrap();
}

#[test]
fn validate_rejects_impossible_rf() {
    // 2 elements cannot hold W+I+O of even a 1×1×…×1 tile.
    let acc = tiny_rf_acc(2);
    let layer = zoo::alexnet()[2].clone();
    let m = Mapping::trivial(&layer, acc.n_levels());
    let err = m.validate(&layer, &acc).unwrap_err();
    assert!(matches!(err, MappingError::Bounding { level: 0, .. }), "{err}");
}

#[test]
fn evaluate_refuses_cross_arch_mapping() {
    // Mapping built for a 3-level machine must be rejected on a 2-level one.
    let eyeriss = presets::eyeriss();
    let layer = zoo::vgg16()[0].clone();
    let m = LocalMapper::new().map(&layer, &eyeriss).unwrap();
    let two_level = Accelerator {
        levels: vec![StorageLevel::register_file("RF", 16, 16), StorageLevel::dram(64)],
        ..eyeriss
    };
    let err = evaluate(&layer, &two_level, &m).unwrap_err();
    assert!(matches!(err, MappingError::LevelMismatch { found: 3, expected: 2 }));
}

#[test]
fn config_rejects_garbage() {
    for src in [
        "accelerator: [not, a, map]",
        "accelerator:\n  name: x\n  pe_array: [0, 4]\n  levels:\n    - name: DRAM\n      width: 64\n      unbounded: true\n",
        "accelerator:\n  name: x\n  pe_array: [4]\n  levels:\n    - name: DRAM\n      width: 64\n      unbounded: true\n",
        ": no key",
    ] {
        assert!(config::accelerator_from_str(src).is_err(), "accepted: {src}");
    }
}

#[test]
fn config_missing_file_is_io_error() {
    let e = config::accelerator_from_file("/nonexistent/acc.yaml").unwrap_err();
    assert!(format!("{e}").contains("io"), "{e}");
}

#[test]
fn manifest_corruption_detected() {
    let dir = std::env::temp_dir().join("lm_fail_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    // Tab-indented YAML.
    std::fs::write(dir.join("manifest.yaml"), "artifacts:\n\t- name: x\n").unwrap();
    assert!(read_manifest(&dir.join("manifest.yaml")).is_err());
    // Bad shape element.
    std::fs::write(
        dir.join("manifest.yaml"),
        "artifacts:\n  - name: k\n    file: k.hlo.txt\n    inputs:\n      - [1, banana]\n    output: [1]\n",
    )
    .unwrap();
    assert!(read_manifest(&dir.join("manifest.yaml")).is_err());
}

#[test]
fn zero_dim_layers_rejected_by_construction() {
    // ConvLayer::bound==0 would break factorization; trivial mapping on a
    // malformed layer must fail coverage validation, not panic.
    let mut layer = ConvLayer::new("bad", 4, 4, 1, 1, 4, 4);
    layer.m = 0;
    let acc = presets::eyeriss();
    let m = Mapping::trivial(&ConvLayer::new("ok", 4, 4, 1, 1, 4, 4), acc.n_levels());
    assert!(m.validate(&layer, &acc).is_err());
}

#[test]
fn service_falls_back_to_local_when_the_mapper_fails() {
    // A mapper that always fails no longer takes the request down with it:
    // the worker retries with the O(1) LOCAL mapper and flags the reply as
    // FellBack, so metrics count fallbacks instead of errors.
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    #[derive(Clone)]
    struct FailingMapper;
    impl Mapper for FailingMapper {
        fn name(&self) -> String {
            "failing".into()
        }
        fn map(
            &self,
            _layer: &ConvLayer,
            _acc: &Accelerator,
        ) -> Result<local_mapper::mapping::Mapping, local_mapper::mappers::MapError> {
            Err(local_mapper::mappers::MapError::NoValidMapping("injected".into()))
        }
    }
    let acc = presets::eyeriss();
    let layers = zoo::alexnet();
    let svc = MappingService::start(acc.clone(), FailingMapper, 2);
    let replies = svc.map_all(&layers);
    assert_eq!(replies.len(), layers.len());
    for (reply, layer) in replies.iter().zip(&layers) {
        let reply = reply.as_ref().expect("fallback must serve the request");
        match &reply.outcome.status {
            MapStatus::FellBack { reason } => assert!(reason.contains("injected"), "{reason}"),
            other => panic!("expected FellBack, got {other}"),
        }
        reply.outcome.mapping.validate(layer, &acc).unwrap();
    }
    let n = layers.len() as u64;
    assert_eq!(svc.metrics.fallbacks.load(Ordering::Relaxed), n);
    assert_eq!(svc.metrics.errors.load(Ordering::Relaxed), 0);
    assert_eq!(svc.metrics.panics.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

#[test]
fn injected_panic_is_contained_and_served_by_local() {
    // Arm a one-shot panic on the third submission: the worker must catch
    // the unwind, count it, and still answer the request with a valid LOCAL
    // mapping flagged FellBack. Every other reply is untouched.
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let acc = presets::eyeriss();
    let layers = zoo::alexnet();
    let fault = fault::arm_guard(FaultKind::Panic { layer_idx: 2 });
    let svc = MappingService::start(acc.clone(), LocalMapper::new(), 1);
    let replies = svc.map_all(&layers);
    drop(fault);
    assert_eq!(replies.len(), layers.len());
    for (i, (reply, layer)) in replies.iter().zip(&layers).enumerate() {
        let reply = reply.as_ref().expect("panic must not lose the request");
        reply.outcome.mapping.validate(layer, &acc).unwrap();
        if i == 2 {
            match &reply.outcome.status {
                MapStatus::FellBack { reason } => assert!(reason.contains("panic"), "{reason}"),
                other => panic!("expected FellBack on the injected layer, got {other}"),
            }
        } else {
            assert!(reply.outcome.status.is_ok(), "layer {i}: {}", reply.outcome.status);
        }
    }
    // Exactly one panic and exactly one fallback, nothing else.
    assert_eq!(svc.metrics.panics.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.fallbacks.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.errors.load(Ordering::Relaxed), 0);
    assert_eq!(svc.metrics.respawns.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

#[test]
fn injected_oom_sim_degrades_every_layer_to_local() {
    // oom-sim fails every search attempt, so every reply rides the LOCAL
    // fallback; no request is lost and no error escapes.
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let acc = presets::eyeriss();
    let layers = zoo::alexnet();
    let fault = fault::arm_guard(FaultKind::OomSim);
    let svc = MappingService::start(acc.clone(), LocalMapper::new(), 2);
    let replies = svc.map_all(&layers);
    drop(fault);
    for (reply, layer) in replies.iter().zip(&layers) {
        let reply = reply.as_ref().expect("oom-sim must degrade, not fail");
        match &reply.outcome.status {
            MapStatus::FellBack { reason } => assert!(reason.contains("oom-sim"), "{reason}"),
            other => panic!("expected FellBack, got {other}"),
        }
        reply.outcome.mapping.validate(layer, &acc).unwrap();
    }
    assert_eq!(svc.metrics.fallbacks.load(Ordering::Relaxed), layers.len() as u64);
    assert_eq!(svc.metrics.panics.load(Ordering::Relaxed), 0);
    assert_eq!(svc.metrics.errors.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

#[test]
fn dead_worker_is_respawned_on_the_next_submission() {
    // worker-death panics outside the containment region, so the in-flight
    // request is lost (the reply channel drops) — but the supervisor must
    // reap the corpse and respawn a replacement on a later submit.
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let acc = presets::eyeriss();
    let layers = zoo::alexnet();
    let fault = fault::arm_guard(FaultKind::WorkerDeath { layer_idx: 0 });
    let svc = MappingService::start(acc.clone(), LocalMapper::new(), 1);
    let lost = svc.submit(layers[0].clone()).wait().unwrap_err();
    assert!(format!("{lost}").contains("service dropped request"), "{lost}");
    // The thread needs a moment to finish unwinding before the supervisor
    // can observe the death; nudge submit() until the respawn lands (the
    // replacement worker then drains everything queued meanwhile).
    let mut respawned = false;
    for _ in 0..200 {
        drop(svc.submit(layers[1].clone()));
        if svc.metrics.respawns.load(Ordering::Relaxed) == 1 {
            respawned = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(respawned, "supervisor never respawned the dead worker");
    let reply = svc.submit(layers[2].clone()).wait().unwrap();
    assert!(reply.outcome.status.is_ok());
    reply.outcome.mapping.validate(&layers[2], &acc).unwrap();
    drop(fault);
    assert_eq!(svc.metrics.respawns.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.panics.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

/// One framed request/reply round trip against a serve-daemon socket.
fn daemon_request(socket: &str, payload: &str) -> String {
    use std::io::{Read, Write};
    let mut s = std::os::unix::net::UnixStream::connect(socket).expect("daemon socket accepts");
    s.write_all(&(payload.len() as u32).to_be_bytes()).unwrap();
    s.write_all(payload.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut header = [0u8; 4];
    s.read_exact(&mut header).unwrap();
    let mut buf = vec![0u8; u32::from_be_bytes(header) as usize];
    s.read_exact(&mut buf).unwrap();
    String::from_utf8(buf).unwrap()
}

#[test]
fn stalled_daemon_sheds_load_with_busy_then_recovers() {
    // Arm `stall:400` and hold the daemon's single admission slot with a
    // slow request: a concurrent request must be shed with a typed E_BUSY
    // document (not queued, not dropped), the stalled request itself must
    // still complete, and after disarming the daemon serves normally.
    use local_mapper::api::json::{parse, Json};
    use local_mapper::api::serve::{spawn, ServeConfig};
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let dir = std::env::temp_dir().join(format!("lm_fail_stall_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let socket = dir.join("daemon.sock").to_str().unwrap().to_string();
    let handle =
        spawn(ServeConfig { socket: socket.clone(), queue_limit: 1, ..ServeConfig::default() })
            .expect("daemon binds");
    let fault = fault::arm_guard(FaultKind::Stall { ms: 400 });
    let compile = "{\"verb\": \"compile\", \"layer\": \"alexnet:1\", \"threads\": 1}";
    let slow = {
        let socket = socket.clone();
        std::thread::spawn(move || daemon_request(&socket, compile))
    };
    // Let the slow request claim the slot; the daemon stalls well past
    // this window, so the shed below cannot race the slot release.
    std::thread::sleep(Duration::from_millis(100));
    let shed = parse(&daemon_request(&socket, compile)).expect("busy doc parses");
    assert_eq!(shed.get("kind").and_then(Json::as_str), Some("error"));
    assert_eq!(shed.get("code").and_then(Json::as_str), Some("E_BUSY"));
    let doc = parse(&slow.join().expect("stalled request thread")).unwrap();
    assert_eq!(
        doc.get("kind").and_then(Json::as_str),
        Some("compile"),
        "a stall delays, it must not fail the admitted request"
    );
    drop(fault);
    let doc = parse(&daemon_request(&socket, compile)).unwrap();
    assert_eq!(doc.get("kind").and_then(Json::as_str), Some("compile"), "post-stall recovery");
    handle.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn constrained_search_reports_exhaustion() {
    // With budget 1 on a heavily constrained space the search may fail to
    // find any valid candidate; it must return NoValidMapping, not panic.
    use local_mapper::mappers::ConstrainedSearch;
    use local_mapper::mapspace::Dataflow;
    let acc = tiny_rf_acc(3);
    let layer = zoo::vgg16()[8].clone();
    let s = ConstrainedSearch::new(Dataflow::WeightStationary, 1, 0);
    match s.run(&layer, &acc) {
        Ok(out) => out.mapping.validate(&layer, &acc).unwrap(),
        Err(e) => assert!(format!("{e}").contains("no valid mapping")),
    }
}
