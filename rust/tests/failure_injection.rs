//! Failure-injection tests: the framework must fail loudly and precisely
//! on impossible hardware, malformed configs, corrupt manifests and
//! unsatisfiable mappings — a compiler component cannot silently mis-map.

use local_mapper::arch::{config, presets, Accelerator, Noc, PeArray, StorageLevel, Style};
use local_mapper::mappers::{LocalMapper, Mapper};
use local_mapper::mapping::{Mapping, MappingError};
use local_mapper::model::evaluate;
use local_mapper::runtime::read_manifest;
use local_mapper::workload::{zoo, ConvLayer};

fn tiny_rf_acc(rf_depth: u64) -> Accelerator {
    Accelerator {
        name: "broken".into(),
        style: Style::EyerissLike,
        datawidth_bits: 16,
        levels: vec![
            StorageLevel::register_file("RF", rf_depth, 16),
            StorageLevel::buffer("GLB", 1024, 64),
            StorageLevel::dram(64),
        ],
        pe: PeArray::new(2, 2),
        noc: Noc::default(),
        mac_energy_pj: 1.0,
        clock_mhz: 200.0,
    }
}

#[test]
fn local_survives_degenerate_rf() {
    // A 3-element RF can hold exactly the all-1 tile (W+I+O): LOCAL must
    // still produce a valid (if poor) mapping.
    let acc = tiny_rf_acc(3);
    let layer = zoo::alexnet()[2].clone();
    let m = LocalMapper::new().map(&layer, &acc).unwrap();
    m.validate(&layer, &acc).unwrap();
}

#[test]
fn validate_rejects_impossible_rf() {
    // 2 elements cannot hold W+I+O of even a 1×1×…×1 tile.
    let acc = tiny_rf_acc(2);
    let layer = zoo::alexnet()[2].clone();
    let m = Mapping::trivial(&layer, acc.n_levels());
    let err = m.validate(&layer, &acc).unwrap_err();
    assert!(matches!(err, MappingError::Bounding { level: 0, .. }), "{err}");
}

#[test]
fn evaluate_refuses_cross_arch_mapping() {
    // Mapping built for a 3-level machine must be rejected on a 2-level one.
    let eyeriss = presets::eyeriss();
    let layer = zoo::vgg16()[0].clone();
    let m = LocalMapper::new().map(&layer, &eyeriss).unwrap();
    let two_level = Accelerator {
        levels: vec![StorageLevel::register_file("RF", 16, 16), StorageLevel::dram(64)],
        ..eyeriss
    };
    let err = evaluate(&layer, &two_level, &m).unwrap_err();
    assert!(matches!(err, MappingError::LevelMismatch { found: 3, expected: 2 }));
}

#[test]
fn config_rejects_garbage() {
    for src in [
        "accelerator: [not, a, map]",
        "accelerator:\n  name: x\n  pe_array: [0, 4]\n  levels:\n    - name: DRAM\n      width: 64\n      unbounded: true\n",
        "accelerator:\n  name: x\n  pe_array: [4]\n  levels:\n    - name: DRAM\n      width: 64\n      unbounded: true\n",
        ": no key",
    ] {
        assert!(config::accelerator_from_str(src).is_err(), "accepted: {src}");
    }
}

#[test]
fn config_missing_file_is_io_error() {
    let e = config::accelerator_from_file("/nonexistent/acc.yaml").unwrap_err();
    assert!(format!("{e}").contains("io"), "{e}");
}

#[test]
fn manifest_corruption_detected() {
    let dir = std::env::temp_dir().join("lm_fail_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    // Tab-indented YAML.
    std::fs::write(dir.join("manifest.yaml"), "artifacts:\n\t- name: x\n").unwrap();
    assert!(read_manifest(&dir.join("manifest.yaml")).is_err());
    // Bad shape element.
    std::fs::write(
        dir.join("manifest.yaml"),
        "artifacts:\n  - name: k\n    file: k.hlo.txt\n    inputs:\n      - [1, banana]\n    output: [1]\n",
    )
    .unwrap();
    assert!(read_manifest(&dir.join("manifest.yaml")).is_err());
}

#[test]
fn zero_dim_layers_rejected_by_construction() {
    // ConvLayer::bound==0 would break factorization; trivial mapping on a
    // malformed layer must fail coverage validation, not panic.
    let mut layer = ConvLayer::new("bad", 4, 4, 1, 1, 4, 4);
    layer.m = 0;
    let acc = presets::eyeriss();
    let m = Mapping::trivial(&ConvLayer::new("ok", 4, 4, 1, 1, 4, 4), acc.n_levels());
    assert!(m.validate(&layer, &acc).is_err());
}

#[test]
fn service_reports_errors_in_metrics() {
    // A mapper that always fails must surface through metrics and replies,
    // not crash workers.
    #[derive(Clone)]
    struct FailingMapper;
    impl Mapper for FailingMapper {
        fn name(&self) -> String {
            "failing".into()
        }
        fn map(
            &self,
            _layer: &ConvLayer,
            _acc: &Accelerator,
        ) -> Result<local_mapper::mapping::Mapping, local_mapper::mappers::MapError> {
            Err(local_mapper::mappers::MapError::NoValidMapping("injected".into()))
        }
    }
    let svc = local_mapper::coordinator::MappingService::start(presets::eyeriss(), FailingMapper, 2);
    let replies = svc.map_all(&zoo::alexnet());
    assert!(replies.iter().all(|r| r.is_err()));
    assert_eq!(svc.metrics.errors.load(std::sync::atomic::Ordering::Relaxed), 5);
    svc.shutdown();
}

#[test]
fn constrained_search_reports_exhaustion() {
    // With budget 1 on a heavily constrained space the search may fail to
    // find any valid candidate; it must return NoValidMapping, not panic.
    use local_mapper::mappers::ConstrainedSearch;
    use local_mapper::mapspace::Dataflow;
    let acc = tiny_rf_acc(3);
    let layer = zoo::vgg16()[8].clone();
    let s = ConstrainedSearch::new(Dataflow::WeightStationary, 1, 0);
    match s.run(&layer, &acc) {
        Ok(out) => out.mapping.validate(&layer, &acc).unwrap(),
        Err(e) => assert!(format!("{e}").contains("no valid mapping")),
    }
}
