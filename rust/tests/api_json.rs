//! Property tests for the API's JSON layer: every [`CompileReport`] the
//! session can produce must serialize to parseable `"api_v1"` JSON whose
//! totals equal the typed struct exactly — energy, latency, MACs and
//! cache hits. Floats are emitted in shortest round-trip form, so the
//! comparisons are `==`, not tolerances.

use local_mapper::api::json::{self, parse, Json};
use local_mapper::api::{CompileRequest, Session};
use local_mapper::mappers::Objective;

/// A small but diverse request grid: operator-diverse networks × mappers ×
/// objectives × arch presets (kept light — the search mappers run at tiny
/// budgets).
fn request_grid() -> Vec<CompileRequest> {
    let mut out = Vec::new();
    for (net, mapper, budget) in [
        ("alexnet", "local", 300),
        ("vgg02", "local", 300),
        ("bert", "local", 300),
        ("alexnet", "random", 300),
        ("alexnet", "rs", 300),
    ] {
        for objective in [Objective::Energy, Objective::Delay] {
            out.push(
                CompileRequest::new()
                    .network(net)
                    .mapper(mapper)
                    .budget(budget)
                    .objective(objective)
                    .threads(2),
            );
        }
    }
    // One non-default arch and one single-layer request.
    out.push(CompileRequest::new().network("squeezenet").arch_preset("nvdla").threads(2));
    out.push(CompileRequest::new().layer_spec("vgg16:9"));
    out
}

/// Parse helper: a named member that must exist.
fn field<'a>(v: &'a Json, key: &str) -> &'a Json {
    v.get(key).unwrap_or_else(|| panic!("missing key '{key}'"))
}

#[test]
fn prop_every_compile_report_serializes_to_matching_json() {
    let session = Session::new();
    for (i, req) in request_grid().iter().enumerate() {
        let report = session.compile(req).unwrap_or_else(|e| panic!("request {i}: {e}"));
        let doc = json::compile_report(&report);
        let v = parse(&doc).unwrap_or_else(|e| panic!("request {i}: {e}\n{doc}"));

        // Version tag and discriminator.
        assert_eq!(field(&v, "schema").as_str(), Some(json::SCHEMA), "request {i}");
        assert_eq!(field(&v, "kind").as_str(), Some("compile"));
        assert_eq!(field(&v, "objective").as_str(), Some(report.objective.name()));

        // Totals equal the typed struct exactly.
        let totals = field(&v, "totals");
        assert_eq!(
            field(totals, "layers").as_u64(),
            Some(report.total_layers() as u64),
            "request {i}"
        );
        assert_eq!(field(totals, "macs").as_u64(), Some(report.total_macs()));
        assert_eq!(
            field(totals, "energy_uj").as_f64(),
            Some(report.total_energy_uj()),
            "request {i}: energy must round-trip exactly"
        );
        assert_eq!(
            field(totals, "latency_cycles").as_u64(),
            Some(report.total_latency_cycles())
        );
        assert_eq!(
            field(totals, "mean_utilization").as_f64(),
            Some(report.mean_utilization())
        );

        // Cache section equals the typed counters.
        let cache = field(&v, "cache");
        assert_eq!(field(cache, "requests").as_u64(), Some(report.requests));
        assert_eq!(field(cache, "hits").as_u64(), Some(report.cache_hits));
        assert_eq!(field(cache, "hit_rate").as_f64(), Some(report.hit_rate()));

        // Per-network and per-layer values are self-consistent with the
        // document's own totals.
        let nets = field(&v, "networks").as_arr().unwrap();
        assert_eq!(nets.len(), report.networks.len());
        let mut layer_energy_sum = 0.0;
        let mut layer_latency_sum = 0u64;
        let mut cached_count = 0u64;
        for (net, typed) in nets.iter().zip(&report.networks) {
            assert_eq!(field(net, "name").as_str(), Some(typed.name.as_str()));
            let layers = field(net, "layers").as_arr().unwrap();
            assert_eq!(layers.len(), typed.layers.len());
            for (l, tl) in layers.iter().zip(&typed.layers) {
                assert_eq!(field(l, "name").as_str(), Some(tl.layer.name.as_str()));
                assert_eq!(field(l, "op").as_str(), Some(tl.layer.op.name()));
                assert_eq!(field(l, "macs").as_u64(), Some(tl.macs()));
                assert_eq!(field(l, "energy_uj").as_f64(), Some(tl.energy_uj()));
                assert_eq!(field(l, "latency_cycles").as_u64(), Some(tl.latency_cycles()));
                assert_eq!(field(l, "cached").as_bool(), Some(tl.cached));
                assert_eq!(field(l, "score").as_f64(), Some(tl.outcome.score));
                // The mapping block covers every storage level.
                let mapping = field(l, "mapping");
                let temporal = field(mapping, "temporal").as_arr().unwrap();
                assert_eq!(temporal.len(), report.acc.n_levels());
                let perms = field(mapping, "permutation").as_arr().unwrap();
                assert_eq!(perms.len(), report.acc.n_levels());
                for p in perms {
                    assert_eq!(p.as_str().unwrap().len(), 7, "permutation lists all dims");
                }
                layer_energy_sum += field(l, "energy_uj").as_f64().unwrap();
                layer_latency_sum += field(l, "latency_cycles").as_u64().unwrap();
                if tl.cached {
                    cached_count += 1;
                }
            }
        }
        // Layer sums reproduce the totals (float sum re-done in the same
        // order the report computes it, so equality is exact for latency
        // and tight for energy).
        assert_eq!(layer_latency_sum, report.total_latency_cycles(), "request {i}");
        assert!(
            (layer_energy_sum - report.total_energy_uj()).abs()
                <= 1e-9 * report.total_energy_uj().abs(),
            "request {i}: layer energies {layer_energy_sum} vs total {}",
            report.total_energy_uj()
        );
        assert_eq!(cached_count, report.cache_hits, "request {i}");
    }
}

#[test]
fn prop_streaming_iter_matches_blocking_compile() {
    // The streaming surface must agree with the blocking one: same layers,
    // same mappings, same scores — streaming is a delivery mode, not a
    // different compiler.
    let session = Session::new();
    let req = CompileRequest::new().network("squeezenet").threads(4);
    let blocking = session.compile(&req).unwrap();
    let streamed: Vec<_> = session
        .compile_iter(&req)
        .unwrap()
        .map(|r| r.unwrap())
        .collect();
    let flat: Vec<_> = blocking.networks.iter().flat_map(|n| n.layers.iter()).collect();
    assert_eq!(streamed.len(), flat.len());
    for (s, b) in streamed.iter().zip(flat) {
        assert_eq!(s.layer.name, b.layer.name);
        assert_eq!(s.outcome.mapping, b.outcome.mapping);
        assert_eq!(s.outcome.score, b.outcome.score);
        // The blocking compile ran first, so the stream is fully cached.
        assert!(s.cached, "{}", s.layer.name);
    }
}

#[test]
fn prop_json_documents_are_byte_stable_modulo_timing() {
    // Two serializations of the same report are byte-identical; two
    // compiles of the same request differ only in measured wall-clock
    // numbers (key/string sequence identical).
    let session = Session::new();
    let req = CompileRequest::new().network("alexnet").threads(1);
    let a = session.compile(&req).unwrap();
    assert_eq!(json::compile_report(&a), json::compile_report(&a));
    let b = session.compile(&req).unwrap();
    let strings = |doc: &str| -> Vec<String> {
        doc.split('"').skip(1).step_by(2).map(str::to_string).collect()
    };
    let (sa, sb) = (strings(&json::compile_report(&a)), strings(&json::compile_report(&b)));
    // The cached re-compile flips only "cached" values, which are unquoted
    // booleans — every quoted token (keys, names, permutations) matches.
    assert_eq!(sa, sb);
}
