//! CLI integration tests: drive the `local-mapper` binary end to end and
//! check output shape and exit codes for every subcommand (reduced budgets
//! so the suite stays fast).
//!
//! Exit codes follow the `api::Error` classes: 0 ok, 2 usage, 3 invalid
//! input, 4 mapping/execution failure; stderr carries the stable error
//! code as `error[E_*]: ...`. The `--format json` tests pin the `"api_v1"`
//! schema and its byte-stable key order.

use local_mapper::api::json::{parse, Json};
use std::process::Command;

fn run(args: &[&str]) -> (String, String, i32) {
    let out = Command::new(env!("CARGO_BIN_EXE_local-mapper"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.code().unwrap_or(-1),
    )
}

#[test]
fn help_lists_subcommands() {
    let (stdout, _, code) = run(&["help"]);
    assert_eq!(code, 0);
    for sub in [
        "map", "compile", "compile-all", "table3", "fig3", "fig7", "mapspace", "arch", "run",
        "simulate", "explore", "serve", "cache-stats", "cache-compact", "perf",
    ] {
        assert!(stdout.contains(sub), "help missing {sub}");
    }
    // The search-engine, robustness and output flags are documented.
    for flag in [
        "--objective",
        "--search-threads",
        "--no-prune",
        "--certify",
        "--format",
        "--deadline-ms",
        "--fail-fast",
        "--inject-fault",
        "--seed-policy",
        "--recompile-from",
        "--cache-dir",
        "--queue-limit",
        "--graph-mode",
        "--no-fuse",
    ] {
        assert!(stdout.contains(flag), "help missing {flag}");
    }
}

#[test]
fn unknown_subcommand_exits_2() {
    let (_, stderr, code) = run(&["frobnicate"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn map_prints_loop_nest_and_energy() {
    let (stdout, _, code) = run(&["map", "--layer", "vgg02:5", "--arch", "eyeriss"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("parallel_for"));
    assert!(stdout.contains("energy="));
    assert!(stdout.contains("DRAM"));
}

#[test]
fn map_with_explicit_dims() {
    let (stdout, _, code) = run(&["map", "--layer", "16x8x3x3x14x14", "--arch", "nvdla"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("custom"));
}

#[test]
fn map_rejects_bad_layer_spec() {
    let (_, stderr, code) = run(&["map", "--layer", "not-a-layer"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("error[E_REQUEST]"), "{stderr}");
}

#[test]
fn map_rejects_unknown_arch() {
    let (_, stderr, code) = run(&["map", "--arch", "tpu"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("unknown arch"));
    assert!(stderr.contains("error[E_REQUEST]"), "{stderr}");
}

#[test]
fn unknown_format_is_a_usage_error() {
    for sub in ["map", "compile", "compile-all", "simulate", "explore"] {
        let (_, stderr, code) = run(&[sub, "--format", "frob"]);
        assert_eq!(code, 2, "{sub}: {stderr}");
        assert!(stderr.contains("unknown format"), "{sub}: {stderr}");
    }
}

#[test]
fn map_with_search_mappers() {
    // One resolver exposes all seven mapping mechanisms.
    for mapper in ["rs", "ws", "os", "random", "ga", "annealing", "refine", "exhaustive"] {
        let (stdout, stderr, code) =
            run(&["map", "--layer", "alexnet:3", "--mapper", mapper, "--budget", "40"]);
        assert_eq!(code, 0, "{mapper}: {stderr}");
        assert!(stdout.contains("energy="), "{mapper}");
    }
}

#[test]
fn map_matmul_and_pooling_layers_from_zoo() {
    // Operator-diverse layers are addressable through the same CLI.
    let (stdout, stderr, code) = run(&["map", "--layer", "bert:1", "--arch", "nvdla"]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("BERT_b1_q"), "{stdout}");
    let (stdout, _, code) = run(&["map", "--layer", "vgg16pool:3", "--arch", "eyeriss"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("VGG16_pool1"), "{stdout}");
}

#[test]
fn objective_flag_works_end_to_end() {
    // map: the chosen objective is echoed and scored.
    let (stdout, stderr, code) = run(&[
        "map", "--layer", "alexnet:3", "--objective", "delay", "--mapper", "refine",
        "--budget", "40",
    ]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("objective=delay"), "{stdout}");
    let (_, stderr, code) = run(&["map", "--objective", "frob"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown objective"), "{stderr}");
    // compile: whole-network compile under a non-default objective.
    let (stdout, stderr, code) =
        run(&["compile", "--network", "alexnet", "--objective", "edp"]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("total:"), "{stdout}");
    // compile-all: the batch pipeline accepts it too (LOCAL is µs/layer).
    let (stdout, stderr, code) =
        run(&["compile-all", "--objective", "delay", "--threads", "4"]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("cache:"), "{stdout}");
    // explore: the co-design sweep accepts it.
    let (stdout, stderr, code) =
        run(&["explore", "--network", "alexnet", "--objective", "edp"]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("Pareto front"), "{stdout}");
}

#[test]
fn engine_flags_are_accepted() {
    // --search-threads and --no-prune parse and keep results valid.
    let (stdout, stderr, code) = run(&[
        "map", "--layer", "alexnet:3", "--mapper", "rs", "--budget", "200",
        "--search-threads", "4",
    ]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("energy="), "{stdout}");
    let (stdout, stderr, code) = run(&[
        "map", "--layer", "alexnet:3", "--mapper", "exhaustive", "--budget", "200", "--no-prune",
    ]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("energy="), "{stdout}");
}

#[test]
fn compile_with_mapper_flag() {
    let (stdout, stderr, code) = run(&[
        "compile", "--network", "alexnet", "--mapper", "refine", "--budget", "60",
    ]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("mapper=LOCAL+refine"), "{stdout}");
    let (_, stderr, code) = run(&["compile", "--network", "alexnet", "--mapper", "frob"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown mapper"));
    assert!(stderr.contains("error[E_REQUEST]"), "{stderr}");
}

#[test]
fn compile_network_summary() {
    let (stdout, _, code) = run(&["compile", "--network", "alexnet", "--arch", "shidiannao"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("AlexNet_conv5"));
    assert!(stdout.contains("total:"));
}

#[test]
fn compile_from_network_file() {
    let path = std::env::temp_dir().join("lm_cli_net.yaml");
    std::fs::write(
        &path,
        "layers:\n  - name: a\n    m: 16\n    c: 8\n    r: 3\n    s: 3\n    p: 14\n    q: 14\n",
    )
    .unwrap();
    let (stdout, _, code) = run(&["compile", "--network-file", path.to_str().unwrap()]);
    assert_eq!(code, 0);
    assert!(stdout.contains("layers=1"));
    // Malformed file → clean invalid-input error (exit 3, E_WORKLOAD).
    std::fs::write(&path, "layers:\n  - m: 16\n").unwrap();
    let (_, stderr, code) = run(&["compile", "--network-file", path.to_str().unwrap()]);
    assert_eq!(code, 3, "{stderr}");
    assert!(stderr.contains("error[E_WORKLOAD]"), "{stderr}");
}

#[test]
fn compile_all_prints_batch_summary_and_metrics() {
    let (stdout, stderr, code) = run(&["compile-all", "--arch", "eyeriss", "--threads", "4"]);
    assert_eq!(code, 0, "{stderr}");
    for net in [
        "vgg16",
        "resnet50",
        "mobilenetv2",
        "squeezenet",
        "alexnet",
        "bert",
        "vgg16pool",
        "mobilenetv2res",
    ] {
        assert!(stdout.contains(net), "summary missing {net}");
    }
    assert!(stdout.contains("cache:"), "missing cache hit-rate line");
    assert!(stdout.contains("p50="), "missing p50 service time");
    assert!(stdout.contains("p99="), "missing p99 service time");
    assert!(stdout.contains("energy (µJ)"), "missing energy column");
    assert!(stdout.contains("latency (cyc)"), "missing latency column");
}

#[test]
fn compile_all_rejects_unknown_mapper() {
    let (_, stderr, code) = run(&["compile-all", "--mapper", "frob"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown mapper"));
}

#[test]
fn table2_exact() {
    let (stdout, _, code) = run(&["table2"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("51380224"));
    assert!(stdout.contains("1849688064"));
}

#[test]
fn table3_small_budget_and_csv() {
    let (stdout, _, code) = run(&["table3", "--budget", "40", "--seed", "1"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("Speedup"));
    let (csv, _, code) = run(&["table3", "--budget", "40", "--seed", "1", "--csv"]);
    assert_eq!(code, 0);
    assert_eq!(csv.lines().count(), 28); // header + 27 cells
}

#[test]
fn fig3_small() {
    let (stdout, _, code) = run(&["fig3", "--n", "50", "--seed", "3"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("random_max"));
    assert!(stdout.contains("spread"));
}

#[test]
fn fig7_small() {
    let (stdout, _, code) = run(&["fig7", "--budget", "30", "--seed", "3"]);
    assert_eq!(code, 0);
    assert_eq!(stdout.matches("== ").count(), 9, "nine panels");
    assert!(stdout.contains("LOCAL"));
}

#[test]
fn mapspace_sizes() {
    let (stdout, _, code) = run(&["mapspace"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("3.732e8"));
}

#[test]
fn arch_dump_roundtrips_through_file() {
    let (yaml, _, code) = run(&["arch", "--name", "nvdla", "--dump"]);
    assert_eq!(code, 0);
    let path = std::env::temp_dir().join("lm_cli_arch.yaml");
    std::fs::write(&path, &yaml).unwrap();
    let (stdout, _, code) = run(&["arch", "--file", path.to_str().unwrap()]);
    assert_eq!(code, 0);
    assert!(stdout.contains("NVDLA"));
}

#[test]
fn simulate_reports_bottleneck() {
    let (stdout, _, code) = run(&["simulate", "--layer", "vgg16:9", "--arch", "eyeriss"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("bottleneck level"));
    assert!(stdout.contains("tile-pipeline sim"));
    let (single, _, _) = run(&["simulate", "--layer", "vgg16:9", "--arch", "eyeriss", "--single-buffer"]);
    assert!(single.contains("single-buffered"));
}

#[test]
fn explore_prints_pareto() {
    let (stdout, _, code) = run(&["explore", "--network", "alexnet", "--arch", "eyeriss"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("Pareto front"));
}

/// The exact top-level key order of an `"api_v1"` compile document. Key
/// order is part of the output contract (byte-stable across runs); any
/// reordering is a schema change and must bump the tag.
const COMPILE_KEYS: [&str; 13] = [
    "schema",
    "kind",
    "workload",
    "arch",
    "mapper",
    "objective",
    "networks",
    "totals",
    "cache",
    "warm",
    "graph",
    "failures",
    "compile_time_ms",
];

const LAYER_KEYS: [&str; 14] = [
    "name",
    "op",
    "macs",
    "energy_uj",
    "pj_per_mac",
    "latency_cycles",
    "utilization",
    "evaluations",
    "map_time_ms",
    "score",
    "cached",
    "certified",
    "status",
    "mapping",
];

fn assert_compile_skeleton(doc: &Json) {
    assert_eq!(doc.get("schema").unwrap().as_str(), Some("api_v1"));
    assert_eq!(doc.get("kind").unwrap().as_str(), Some("compile"));
    assert_eq!(doc.keys(), COMPILE_KEYS.to_vec());
    assert_eq!(
        doc.get("warm").unwrap().keys(),
        vec!["policy", "seeded", "seed_quality", "incremental_reused"]
    );
    assert_eq!(
        doc.get("graph").unwrap().keys(),
        vec!["mode", "groups", "fused_layers", "cross_layer_dram_bytes", "dram_bytes_saved"]
    );
    for net in doc.get("networks").unwrap().as_arr().unwrap() {
        assert_eq!(net.keys(), vec!["name", "layers", "totals", "compile_time_ms"]);
        for layer in net.get("layers").unwrap().as_arr().unwrap() {
            assert_eq!(layer.keys(), LAYER_KEYS.to_vec());
            // Both status keys are always present; the kind is one of the
            // three stable discriminators.
            let status = layer.get("status").unwrap();
            assert_eq!(status.keys(), vec!["kind", "reason"]);
            let kind = status.get("kind").unwrap().as_str().unwrap();
            assert!(
                matches!(kind, "ok" | "degraded" | "fell_back"),
                "unknown status kind {kind}"
            );
            assert_eq!(
                layer.get("mapping").unwrap().keys(),
                vec!["temporal", "permutation", "spatial_x", "spatial_y"]
            );
        }
    }
}

#[test]
fn map_format_json_golden() {
    let (stdout, stderr, code) =
        run(&["map", "--layer", "vgg02:5", "--arch", "eyeriss", "--format", "json"]);
    assert_eq!(code, 0, "{stderr}");
    // The document opens with the schema tag, byte for byte.
    assert!(
        stdout.starts_with("{\n  \"schema\": \"api_v1\",\n  \"kind\": \"compile\",\n"),
        "{stdout}"
    );
    let doc = parse(&stdout).expect("map JSON parses");
    assert_compile_skeleton(&doc);
    assert_eq!(doc.get("workload").unwrap().as_str(), Some("VGG02_conv5"));
    assert_eq!(doc.get("arch").unwrap().as_str(), Some("Eyeriss"));
    assert_eq!(doc.get("mapper").unwrap().as_str(), Some("LOCAL"));
    assert_eq!(doc.get("objective").unwrap().as_str(), Some("energy"));
    let layers = doc.get("networks").unwrap().as_arr().unwrap()[0]
        .get("layers")
        .unwrap()
        .as_arr()
        .unwrap();
    assert_eq!(layers.len(), 1);
    assert_eq!(layers[0].get("name").unwrap().as_str(), Some("VGG02_conv5"));
    assert_eq!(layers[0].get("op").unwrap().as_str(), Some("conv"));
    // Table-1 layer: M=256, C=128, R=S=3, P=Q=56.
    assert_eq!(
        layers[0].get("macs").unwrap().as_u64(),
        Some(256 * 128 * 9 * 56 * 56)
    );
    assert!(layers[0].get("energy_uj").unwrap().as_f64().unwrap() > 0.0);
    // Key order is byte-stable: a second run emits the identical key
    // sequence (only measured wall-clock values may differ).
    let (second, _, _) =
        run(&["map", "--layer", "vgg02:5", "--arch", "eyeriss", "--format", "json"]);
    let keys = |s: &str| -> Vec<String> {
        s.lines()
            .flat_map(|l| {
                l.split('"')
                    .skip(1)
                    .step_by(2)
                    .map(str::to_string)
                    .collect::<Vec<_>>()
            })
            .collect()
    };
    assert_eq!(keys(&stdout), keys(&second), "key/string sequence diverged across runs");
}

#[test]
fn compile_all_format_json_golden() {
    let (stdout, stderr, code) = run(&["compile-all", "--threads", "4", "--format", "json"]);
    assert_eq!(code, 0, "{stderr}");
    let doc = parse(&stdout).expect("compile-all JSON parses");
    assert_compile_skeleton(&doc);
    assert_eq!(doc.get("workload").unwrap().as_str(), Some("zoo(8)"));
    // The batch zoo, in submission order, with its exact layer counts.
    let nets = doc.get("networks").unwrap().as_arr().unwrap();
    let expect: [(&str, u64); 8] = [
        ("vgg16", 13),
        ("resnet50", 53),
        ("mobilenetv2", 52),
        ("squeezenet", 26),
        ("alexnet", 5),
        ("bert", 96),
        ("vgg16pool", 18),
        ("mobilenetv2res", 62),
    ];
    assert_eq!(nets.len(), 8);
    for (net, (name, layers)) in nets.iter().zip(expect) {
        assert_eq!(net.get("name").unwrap().as_str(), Some(name));
        assert_eq!(
            net.get("layers").unwrap().as_arr().unwrap().len() as u64,
            layers,
            "{name}"
        );
        assert_eq!(
            net.get("totals").unwrap().get("layers").unwrap().as_u64(),
            Some(layers),
            "{name}"
        );
    }
    let totals = doc.get("totals").unwrap();
    assert_eq!(totals.get("layers").unwrap().as_u64(), Some(325));
    assert!(totals.get("energy_uj").unwrap().as_f64().unwrap() > 0.0);
    let cache = doc.get("cache").unwrap();
    assert_eq!(cache.get("requests").unwrap().as_u64(), Some(325));
    assert!(cache.get("hits").unwrap().as_u64().unwrap() > 0);
}

#[test]
fn compile_simulate_explore_emit_api_v1_json() {
    let (stdout, stderr, code) =
        run(&["compile", "--network", "alexnet", "--format", "json"]);
    assert_eq!(code, 0, "{stderr}");
    let doc = parse(&stdout).expect("compile JSON parses");
    assert_compile_skeleton(&doc);
    assert_eq!(doc.get("workload").unwrap().as_str(), Some("alexnet"));

    let (stdout, stderr, code) =
        run(&["simulate", "--layer", "vgg16:9", "--format", "json"]);
    assert_eq!(code, 0, "{stderr}");
    let doc = parse(&stdout).expect("simulate JSON parses");
    assert_eq!(doc.get("schema").unwrap().as_str(), Some("api_v1"));
    assert_eq!(doc.get("kind").unwrap().as_str(), Some("simulate"));
    let sim = doc.get("sim").unwrap();
    assert!(sim.get("total_cycles").unwrap().as_u64().unwrap() > 0);
    assert!(!sim.get("levels").unwrap().as_arr().unwrap().is_empty());

    let (stdout, stderr, code) =
        run(&["explore", "--network", "alexnet", "--format", "json"]);
    assert_eq!(code, 0, "{stderr}");
    let doc = parse(&stdout).expect("explore JSON parses");
    assert_eq!(doc.get("schema").unwrap().as_str(), Some("api_v1"));
    assert_eq!(doc.get("kind").unwrap().as_str(), Some("explore"));
    assert_eq!(doc.get("results").unwrap().as_arr().unwrap().len(), 28);
    assert!(!doc.get("pareto").unwrap().as_arr().unwrap().is_empty());
}

#[test]
fn certify_map_json_golden() {
    // A custom layer small enough for the budget to cover the whole
    // lattice: 4x2x1x1x4x2 on Eyeriss (3 levels → 5 factorization slots)
    // has 15·5·15·5 = 5625 tilings × 7 rotations = 39375 candidates, so a
    // 40k budget certifies the optimum. `--certify` defaults the mapper
    // to exhaustive; the layer report must carry `"certified": true` in
    // the pinned key position (after `cached`, before `mapping`).
    let (stdout, stderr, code) = run(&[
        "map", "--layer", "4x2x1x1x4x2", "--arch", "eyeriss", "--format", "json",
        "--budget", "40000", "--certify",
    ]);
    assert_eq!(code, 0, "{stderr}");
    let doc = parse(&stdout).expect("certify map JSON parses");
    assert_compile_skeleton(&doc);
    let layer = &doc.get("networks").unwrap().as_arr().unwrap()[0]
        .get("layers")
        .unwrap()
        .as_arr()
        .unwrap()[0];
    assert_eq!(layer.get("certified").unwrap().as_bool(), Some(true), "{stdout}");
    assert_eq!(layer.get("cached").unwrap().as_bool(), Some(false));
    assert!(layer.get("evaluations").unwrap().as_u64().unwrap() > 0);

    // Without --certify the flag is false for every mapper (including
    // budget-truncated exhaustive search).
    let (stdout, stderr, code) =
        run(&["map", "--layer", "4x2x1x1x4x2", "--arch", "eyeriss", "--format", "json"]);
    assert_eq!(code, 0, "{stderr}");
    let doc = parse(&stdout).expect("plain map JSON parses");
    let layer = &doc.get("networks").unwrap().as_arr().unwrap()[0]
        .get("layers")
        .unwrap()
        .as_arr()
        .unwrap()[0];
    assert_eq!(layer.get("certified").unwrap().as_bool(), Some(false));
}

#[test]
fn perf_smoke_writes_valid_bench_json() {
    let path = std::env::temp_dir().join("lm_cli_bench_eval.json");
    let (stdout, stderr, code) =
        run(&["perf", "--smoke", "--out", path.to_str().unwrap()]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("evals/s"), "{stdout}");
    assert!(stdout.contains("exhaustive"), "{stdout}");
    let json = std::fs::read_to_string(&path).unwrap();
    for key in [
        "\"schema\": 7",
        "\"graph\"",
        "\"fused_dram_bytes\"",
        "\"evaluator\"",
        "\"per_op\"",
        "\"exhaustive\"",
        "\"search\"",
        "\"pruning\"",
        "\"scaling\"",
        "\"bound_search\"",
        "\"evals_bnb\"",
        "\"certified\": true",
        "\"warm_start\"",
        "\"warm_seeded\"",
        "\"zoo_batch\"",
        "\"service\"",
        "\"warm_evaluations\": 0",
        "\"smoke\": true",
    ] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
    // A rate of exactly 0 means the harness measured nothing — the same
    // condition the CI validation step rejects.
    assert!(!json.contains("\"legacy_evals_per_sec\": 0.000"), "{json}");
    assert!(!json.contains("\"context_evals_per_sec\": 0.000"), "{json}");
}

#[test]
fn cache_dir_warm_restart_is_fully_cached_and_bit_identical() {
    // The tentpole contract end to end: two *separate processes* compile
    // the same network with the same --cache-dir; the second must serve
    // every layer from the disk log ("cached": true across the board)
    // with bit-identical mappings and scores.
    let dir = std::env::temp_dir().join(format!("lm_cli_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let d = dir.to_str().unwrap();
    let args = [
        "compile", "--network", "alexnet", "--threads", "1", "--format", "json",
        "--cache-dir", d,
    ];
    let (cold, stderr, code) = run(&args);
    assert_eq!(code, 0, "{stderr}");
    let (warm, stderr, code) = run(&args);
    assert_eq!(code, 0, "{stderr}");
    let cold_layers = first_network_layers(&parse(&cold).expect("cold JSON parses"));
    let warm_layers = first_network_layers(&parse(&warm).expect("warm JSON parses"));
    assert_eq!(warm_layers.len(), 5);
    for l in &warm_layers {
        assert_eq!(l.get("cached").and_then(Json::as_bool), Some(true), "{warm}");
    }
    for (a, b) in cold_layers.iter().zip(&warm_layers) {
        assert_eq!(layer_identity(a), layer_identity(b), "restart perturbed a layer");
    }

    // cache-stats over the same directory: one record per unique layer,
    // lifetime totals spanning both processes, full alexnet coverage.
    let (stats, stderr, code) = run(&["cache-stats", "--cache-dir", d]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stats.contains("records: 5"), "{stats}");
    assert!(stats.contains("lifetime: 10 requests, 5 cache hits"), "{stats}");
    assert!(stats.contains("alexnet"), "{stats}");
    assert!(stats.contains("5/5"), "{stats}");

    // Without a directory, cache-stats is a usage error pointing at the
    // flag and the environment variable.
    let (_, stderr, code) = run(&["cache-stats"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("--cache-dir"), "{stderr}");
    assert!(stderr.contains("LOCAL_MAPPER_CACHE_DIR"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_errors_cleanly_without_artifacts() {
    let (_, stderr, code) = run(&["run", "--artifacts", "/nonexistent/dir"]);
    assert_eq!(code, 4, "{stderr}");
    assert!(stderr.contains("error[E_RUNTIME]"), "{stderr}");
}

/// The layers of a compile document's first network.
fn first_network_layers(doc: &Json) -> Vec<Json> {
    doc.get("networks").unwrap().as_arr().unwrap()[0]
        .get("layers")
        .unwrap()
        .as_arr()
        .unwrap()
        .to_vec()
}

/// A layer object minus the members that legitimately vary across runs
/// (measured wall-clock, cache state), for bit-identity comparisons.
fn layer_identity(layer: &Json) -> Vec<(String, Json)> {
    match layer {
        Json::Obj(members) => members
            .iter()
            .filter(|(k, _)| k != "map_time_ms" && k != "cached")
            .cloned()
            .collect(),
        _ => panic!("layer is not an object"),
    }
}

#[test]
fn injected_panic_is_contained_and_other_layers_are_bit_identical() {
    // The acceptance property: `--inject-fault panic:<i>` must exit 0,
    // report layer i as fell_back with a valid LOCAL mapping, and leave
    // every other layer bit-identical (mapping, scores, tie-breaks) to
    // the fault-free run — only wall-clock values may differ.
    let base = ["compile", "--network", "alexnet", "--threads", "2", "--format", "json"];
    let (clean, stderr, code) = run(&base);
    assert_eq!(code, 0, "{stderr}");
    let clean_layers = first_network_layers(&parse(&clean).expect("clean JSON parses"));
    assert_eq!(clean_layers.len(), 5);
    for i in [0usize, 2, 4] {
        let spec = format!("panic:{i}");
        let mut args: Vec<&str> = base.to_vec();
        args.extend(["--inject-fault", &spec]);
        let (out, stderr, code) = run(&args);
        assert_eq!(code, 0, "panic:{i}: {stderr}");
        let doc = parse(&out).expect("faulted JSON parses");
        assert_compile_skeleton(&doc);
        // A contained panic is a degradation, not a hard failure.
        assert!(doc.get("failures").unwrap().as_arr().unwrap().is_empty(), "panic:{i}");
        let layers = first_network_layers(&doc);
        assert_eq!(layers.len(), clean_layers.len());
        for (j, (got, want)) in layers.iter().zip(&clean_layers).enumerate() {
            let status = got.get("status").unwrap();
            if j == i {
                assert_eq!(
                    status.get("kind").unwrap().as_str(),
                    Some("fell_back"),
                    "panic:{i}: {out}"
                );
                assert!(
                    status.get("reason").unwrap().as_str().unwrap().contains("panic"),
                    "panic:{i}: {out}"
                );
                // The LOCAL fallback still produced a full mapping.
                let mapping = got.get("mapping").unwrap();
                assert!(!mapping.get("temporal").unwrap().as_arr().unwrap().is_empty());
                assert!(got.get("energy_uj").unwrap().as_f64().unwrap() > 0.0);
            } else {
                assert_eq!(
                    layer_identity(got),
                    layer_identity(want),
                    "panic:{i} perturbed layer {j}"
                );
            }
        }
    }
}

#[test]
fn deadline_zero_falls_back_to_local_on_every_layer() {
    // An already-expired deadline means no search mapper can even start:
    // every layer must degrade to the O(1) LOCAL fallback — valid
    // mappings, fell_back status, exit 0, no hard failures.
    let (out, stderr, code) = run(&[
        "compile", "--network", "alexnet", "--mapper", "rs", "--budget", "50",
        "--deadline-ms", "0", "--format", "json",
    ]);
    assert_eq!(code, 0, "{stderr}");
    let doc = parse(&out).expect("deadline JSON parses");
    assert_compile_skeleton(&doc);
    assert!(doc.get("failures").unwrap().as_arr().unwrap().is_empty());
    let layers = first_network_layers(&doc);
    assert_eq!(layers.len(), 5);
    for l in &layers {
        assert_eq!(
            l.get("status").unwrap().get("kind").unwrap().as_str(),
            Some("fell_back"),
            "{out}"
        );
        assert!(!l.get("mapping").unwrap().get("temporal").unwrap().as_arr().unwrap().is_empty());
        assert!(l.get("energy_uj").unwrap().as_f64().unwrap() > 0.0);
    }
    // A malformed deadline is a usage error.
    let (_, stderr, code) = run(&["map", "--deadline-ms", "soon"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("deadline-ms"), "{stderr}");
}

#[test]
fn seed_policy_flag_parses_and_rejects_junk() {
    // Every policy name is accepted end to end; with the O(1) LOCAL
    // mapper no seeding happens, so all three produce valid reports.
    for policy in ["off", "adapt", "exact"] {
        let (stdout, stderr, code) =
            run(&["compile", "--network", "alexnet", "--seed-policy", policy]);
        assert_eq!(code, 0, "{policy}: {stderr}");
        assert!(stdout.contains("total:"), "{policy}: {stdout}");
    }
    let (_, stderr, code) = run(&["compile", "--seed-policy", "frob"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("error[E_REQUEST]"), "{stderr}");
    assert!(stderr.contains("off|adapt|exact"), "{stderr}");
}

#[test]
fn recompile_from_reuses_a_prior_report() {
    // Write a donor report, then recompile the same request against it:
    // every layer must be reused verbatim without hitting the service.
    let path = std::env::temp_dir().join("lm_cli_recompile_donor.json");
    let base = ["compile", "--network", "alexnet", "--format", "json"];
    let (donor, stderr, code) = run(&base);
    assert_eq!(code, 0, "{stderr}");
    std::fs::write(&path, &donor).unwrap();
    let mut args: Vec<&str> = base.to_vec();
    args.extend(["--recompile-from", path.to_str().unwrap()]);
    let (out, stderr, code) = run(&args);
    assert_eq!(code, 0, "{stderr}");
    let doc = parse(&out).expect("recompile JSON parses");
    assert_compile_skeleton(&doc);
    let warm = doc.get("warm").unwrap();
    assert_eq!(warm.get("incremental_reused").unwrap().as_u64(), Some(5), "{out}");
    assert_eq!(
        doc.get("cache").unwrap().get("requests").unwrap().as_u64(),
        Some(0),
        "reused layers must not hit the service: {out}"
    );
    // Reused layers carry the donor's mappings bit for bit.
    let donor_layers = first_network_layers(&parse(&donor).unwrap());
    for (got, want) in first_network_layers(&doc).iter().zip(&donor_layers) {
        assert_eq!(got.get("mapping"), want.get("mapping"));
        assert_eq!(got.get("score"), want.get("score"));
        assert_eq!(got.get("cached").unwrap().as_bool(), Some(true));
    }

    // A missing donor is an I/O error; a malformed one a JSON error.
    let (_, stderr, code) =
        run(&["compile", "--network", "alexnet", "--recompile-from", "/nonexistent.json"]);
    assert_eq!(code, 3, "{stderr}");
    assert!(stderr.contains("error[E_IO]"), "{stderr}");
    std::fs::write(&path, "{not json").unwrap();
    let mut args: Vec<&str> = base.to_vec();
    args.extend(["--recompile-from", path.to_str().unwrap()]);
    let (_, stderr, code) = run(&args);
    assert_eq!(code, 3, "{stderr}");
    assert!(stderr.contains("error[E_JSON]"), "{stderr}");
}

#[test]
fn bad_inject_fault_spec_is_a_usage_error() {
    let (_, stderr, code) =
        run(&["compile", "--network", "alexnet", "--inject-fault", "melt:1"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("error[E_REQUEST]"), "{stderr}");
    assert!(stderr.contains("melt"), "{stderr}");
}

#[test]
fn graph_mode_fuse_saves_dram_and_leaves_mappings_bit_identical() {
    // The PR's acceptance criterion end to end: on mobilenetv2res,
    // --graph-mode fuse must form at least one multi-node fused group and
    // report strictly lower estimated cross-layer DRAM traffic than off,
    // while every per-layer mapping stays bit-identical (the analysis
    // never touches the mapping pipeline).
    let base = ["compile", "--network", "mobilenetv2res", "--format", "json"];
    let (off, stderr, code) = run(&base);
    assert_eq!(code, 0, "{stderr}");
    let mut args: Vec<&str> = base.to_vec();
    args.extend(["--graph-mode", "fuse"]);
    let (fuse, stderr, code) = run(&args);
    assert_eq!(code, 0, "{stderr}");
    let off_doc = parse(&off).expect("off JSON parses");
    let fuse_doc = parse(&fuse).expect("fuse JSON parses");
    assert_compile_skeleton(&off_doc);
    assert_compile_skeleton(&fuse_doc);

    let off_graph = off_doc.get("graph").unwrap();
    assert_eq!(off_graph.get("mode").unwrap().as_str(), Some("off"));
    assert_eq!(off_graph.get("groups").unwrap().as_u64(), Some(0));
    assert_eq!(off_graph.get("dram_bytes_saved").unwrap().as_u64(), Some(0));
    let off_cross = off_graph.get("cross_layer_dram_bytes").unwrap().as_u64().unwrap();
    assert!(off_cross > 0);

    let fuse_graph = fuse_doc.get("graph").unwrap();
    assert_eq!(fuse_graph.get("mode").unwrap().as_str(), Some("fuse"));
    assert!(fuse_graph.get("groups").unwrap().as_u64().unwrap() >= 1, "{fuse}");
    assert!(fuse_graph.get("fused_layers").unwrap().as_u64().unwrap() >= 2);
    let fuse_cross = fuse_graph.get("cross_layer_dram_bytes").unwrap().as_u64().unwrap();
    let saved = fuse_graph.get("dram_bytes_saved").unwrap().as_u64().unwrap();
    assert!(fuse_cross < off_cross, "fusion must strictly reduce cross-layer DRAM");
    assert_eq!(fuse_cross + saved, off_cross, "savings must account against the off baseline");

    // Same layers, same mappings, same scores — graph analysis is a
    // reporting layer, not a different compiler.
    let off_layers = first_network_layers(&off_doc);
    let fuse_layers = first_network_layers(&fuse_doc);
    assert_eq!(off_layers.len(), 62);
    for (a, b) in off_layers.iter().zip(&fuse_layers) {
        assert_eq!(layer_identity(a), layer_identity(b), "graph mode perturbed a layer");
    }

    // --no-fuse forces off even when --graph-mode asks for fusion.
    let mut args: Vec<&str> = base.to_vec();
    args.extend(["--graph-mode", "fuse", "--no-fuse"]);
    let (forced, stderr, code) = run(&args);
    assert_eq!(code, 0, "{stderr}");
    let forced_doc = parse(&forced).expect("no-fuse JSON parses");
    assert_eq!(forced_doc.get("graph").unwrap().get("mode").unwrap().as_str(), Some("off"));

    // Junk modes are usage errors that list the accepted spellings.
    let (_, stderr, code) = run(&["compile", "--graph-mode", "frob"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("off|fuse|co_select"), "{stderr}");
}

#[test]
fn cache_compact_rewrites_the_log_and_reports_counts() {
    let dir = std::env::temp_dir().join(format!("lm_cli_compact_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let d = dir.to_str().unwrap();
    let (_, stderr, code) =
        run(&["compile", "--network", "alexnet", "--cache-dir", d]);
    assert_eq!(code, 0, "{stderr}");
    // Duplicate the first record by hand: the log is append-only, so a
    // re-solved layer would land exactly like this.
    let log = dir.join("mappings.log");
    let text = std::fs::read_to_string(&log).unwrap();
    let first = text.lines().next().unwrap().to_string();
    std::fs::write(&log, format!("{text}{first}\n")).unwrap();
    let (out, stderr, code) = run(&["cache-compact", "--cache-dir", d]);
    assert_eq!(code, 0, "{stderr}");
    assert!(out.contains("records: 6 -> 5"), "{out}");
    assert!(out.contains("1 duplicate"), "{out}");
    // The compacted log still serves a fully-warm restart.
    let (stats, stderr, code) = run(&["cache-stats", "--cache-dir", d]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stats.contains("records: 5"), "{stats}");
    // Without a directory, same usage error surface as cache-stats.
    let (_, stderr, code) = run(&["cache-compact"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("--cache-dir"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lifetime_totals_survive_an_error_exit() {
    // The exit-path audit's pinned property: `main` drops the Session
    // before `process::exit` on *every* exit class, so the lifetime
    // totals flushed by `MappingService::Drop` are never lost or torn —
    // even when a later run with the same --cache-dir exits 3.
    let dir = std::env::temp_dir().join(format!("lm_cli_exit3_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let d = dir.to_str().unwrap();
    let (_, stderr, code) = run(&["compile", "--network", "alexnet", "--cache-dir", d]);
    assert_eq!(code, 0, "{stderr}");
    // A malformed network file: invalid input, exit 3, after the session
    // (and its cache wiring) already exists.
    let bad = dir.join("bad_net.yaml");
    std::fs::write(&bad, "layers:\n  - m: 16\n").unwrap();
    let (_, stderr, code) = run(&[
        "compile", "--network-file", bad.to_str().unwrap(), "--cache-dir", d,
    ]);
    assert_eq!(code, 3, "{stderr}");
    // The totals from the successful run are intact and readable.
    let (stats, stderr, code) = run(&["cache-stats", "--cache-dir", d]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stats.contains("records: 5"), "{stats}");
    assert!(stats.contains("5 requests"), "{stats}");
    let _ = std::fs::remove_dir_all(&dir);
}
