//! CLI integration tests: drive the `local-mapper` binary end to end and
//! check output shape and exit codes for every subcommand (reduced budgets
//! so the suite stays fast).

use std::process::Command;

fn run(args: &[&str]) -> (String, String, i32) {
    let out = Command::new(env!("CARGO_BIN_EXE_local-mapper"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.code().unwrap_or(-1),
    )
}

#[test]
fn help_lists_subcommands() {
    let (stdout, _, code) = run(&["help"]);
    assert_eq!(code, 0);
    for sub in [
        "map", "compile", "compile-all", "table3", "fig3", "fig7", "mapspace", "arch", "run",
        "simulate", "explore", "perf",
    ] {
        assert!(stdout.contains(sub), "help missing {sub}");
    }
    // The search-engine flags are documented.
    for flag in ["--objective", "--search-threads", "--no-prune"] {
        assert!(stdout.contains(flag), "help missing {flag}");
    }
}

#[test]
fn unknown_subcommand_exits_2() {
    let (_, stderr, code) = run(&["frobnicate"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn map_prints_loop_nest_and_energy() {
    let (stdout, _, code) = run(&["map", "--layer", "vgg02:5", "--arch", "eyeriss"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("parallel_for"));
    assert!(stdout.contains("energy="));
    assert!(stdout.contains("DRAM"));
}

#[test]
fn map_with_explicit_dims() {
    let (stdout, _, code) = run(&["map", "--layer", "16x8x3x3x14x14", "--arch", "nvdla"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("custom"));
}

#[test]
fn map_rejects_bad_layer_spec() {
    let (_, stderr, code) = run(&["map", "--layer", "not-a-layer"]);
    assert_eq!(code, 1);
    assert!(stderr.contains("error"));
}

#[test]
fn map_rejects_unknown_arch() {
    let (_, stderr, code) = run(&["map", "--arch", "tpu"]);
    assert_eq!(code, 1);
    assert!(stderr.contains("unknown arch"));
}

#[test]
fn map_with_search_mappers() {
    // One resolver exposes all seven mapping mechanisms.
    for mapper in ["rs", "ws", "os", "random", "ga", "annealing", "refine", "exhaustive"] {
        let (stdout, stderr, code) =
            run(&["map", "--layer", "alexnet:3", "--mapper", mapper, "--budget", "40"]);
        assert_eq!(code, 0, "{mapper}: {stderr}");
        assert!(stdout.contains("energy="), "{mapper}");
    }
}

#[test]
fn map_matmul_and_pooling_layers_from_zoo() {
    // Operator-diverse layers are addressable through the same CLI.
    let (stdout, stderr, code) = run(&["map", "--layer", "bert:1", "--arch", "nvdla"]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("BERT_b1_q"), "{stdout}");
    let (stdout, _, code) = run(&["map", "--layer", "vgg16pool:3", "--arch", "eyeriss"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("VGG16_pool1"), "{stdout}");
}

#[test]
fn objective_flag_works_end_to_end() {
    // map: the chosen objective is echoed and scored.
    let (stdout, stderr, code) = run(&[
        "map", "--layer", "alexnet:3", "--objective", "delay", "--mapper", "refine",
        "--budget", "40",
    ]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("objective=delay"), "{stdout}");
    let (_, stderr, code) = run(&["map", "--objective", "frob"]);
    assert_eq!(code, 1);
    assert!(stderr.contains("unknown objective"), "{stderr}");
    // compile: whole-network compile under a non-default objective.
    let (stdout, stderr, code) =
        run(&["compile", "--network", "alexnet", "--objective", "edp"]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("total:"), "{stdout}");
    // compile-all: the batch pipeline accepts it too (LOCAL is µs/layer).
    let (stdout, stderr, code) =
        run(&["compile-all", "--objective", "delay", "--threads", "4"]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("cache:"), "{stdout}");
    // explore: the co-design sweep accepts it.
    let (stdout, stderr, code) =
        run(&["explore", "--network", "alexnet", "--objective", "edp"]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("Pareto front"), "{stdout}");
}

#[test]
fn engine_flags_are_accepted() {
    // --search-threads and --no-prune parse and keep results valid.
    let (stdout, stderr, code) = run(&[
        "map", "--layer", "alexnet:3", "--mapper", "rs", "--budget", "200",
        "--search-threads", "4",
    ]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("energy="), "{stdout}");
    let (stdout, stderr, code) = run(&[
        "map", "--layer", "alexnet:3", "--mapper", "exhaustive", "--budget", "200", "--no-prune",
    ]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("energy="), "{stdout}");
}

#[test]
fn compile_with_mapper_flag() {
    let (stdout, stderr, code) = run(&[
        "compile", "--network", "alexnet", "--mapper", "refine", "--budget", "60",
    ]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("mapper=LOCAL+refine"), "{stdout}");
    let (_, stderr, code) = run(&["compile", "--network", "alexnet", "--mapper", "frob"]);
    assert_eq!(code, 1);
    assert!(stderr.contains("unknown mapper"));
}

#[test]
fn compile_network_summary() {
    let (stdout, _, code) = run(&["compile", "--network", "alexnet", "--arch", "shidiannao"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("AlexNet_conv5"));
    assert!(stdout.contains("total:"));
}

#[test]
fn compile_from_network_file() {
    let path = std::env::temp_dir().join("lm_cli_net.yaml");
    std::fs::write(
        &path,
        "layers:\n  - name: a\n    m: 16\n    c: 8\n    r: 3\n    s: 3\n    p: 14\n    q: 14\n",
    )
    .unwrap();
    let (stdout, _, code) = run(&["compile", "--network-file", path.to_str().unwrap()]);
    assert_eq!(code, 0);
    assert!(stdout.contains("layers=1"));
    // Malformed file → clean error.
    std::fs::write(&path, "layers:\n  - m: 16\n").unwrap();
    let (_, stderr, code) = run(&["compile", "--network-file", path.to_str().unwrap()]);
    assert_eq!(code, 1);
    assert!(stderr.contains("error"));
}

#[test]
fn compile_all_prints_batch_summary_and_metrics() {
    let (stdout, stderr, code) = run(&["compile-all", "--arch", "eyeriss", "--threads", "4"]);
    assert_eq!(code, 0, "{stderr}");
    for net in [
        "vgg16",
        "resnet50",
        "mobilenetv2",
        "squeezenet",
        "alexnet",
        "bert",
        "vgg16pool",
        "mobilenetv2res",
    ] {
        assert!(stdout.contains(net), "summary missing {net}");
    }
    assert!(stdout.contains("cache:"), "missing cache hit-rate line");
    assert!(stdout.contains("p50="), "missing p50 service time");
    assert!(stdout.contains("p99="), "missing p99 service time");
    assert!(stdout.contains("energy (µJ)"), "missing energy column");
    assert!(stdout.contains("latency (cyc)"), "missing latency column");
}

#[test]
fn compile_all_rejects_unknown_mapper() {
    let (_, stderr, code) = run(&["compile-all", "--mapper", "frob"]);
    assert_eq!(code, 1);
    assert!(stderr.contains("unknown mapper"));
}

#[test]
fn table2_exact() {
    let (stdout, _, code) = run(&["table2"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("51380224"));
    assert!(stdout.contains("1849688064"));
}

#[test]
fn table3_small_budget_and_csv() {
    let (stdout, _, code) = run(&["table3", "--budget", "40", "--seed", "1"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("Speedup"));
    let (csv, _, code) = run(&["table3", "--budget", "40", "--seed", "1", "--csv"]);
    assert_eq!(code, 0);
    assert_eq!(csv.lines().count(), 28); // header + 27 cells
}

#[test]
fn fig3_small() {
    let (stdout, _, code) = run(&["fig3", "--n", "50", "--seed", "3"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("random_max"));
    assert!(stdout.contains("spread"));
}

#[test]
fn fig7_small() {
    let (stdout, _, code) = run(&["fig7", "--budget", "30", "--seed", "3"]);
    assert_eq!(code, 0);
    assert_eq!(stdout.matches("== ").count(), 9, "nine panels");
    assert!(stdout.contains("LOCAL"));
}

#[test]
fn mapspace_sizes() {
    let (stdout, _, code) = run(&["mapspace"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("3.732e8"));
}

#[test]
fn arch_dump_roundtrips_through_file() {
    let (yaml, _, code) = run(&["arch", "--name", "nvdla", "--dump"]);
    assert_eq!(code, 0);
    let path = std::env::temp_dir().join("lm_cli_arch.yaml");
    std::fs::write(&path, &yaml).unwrap();
    let (stdout, _, code) = run(&["arch", "--file", path.to_str().unwrap()]);
    assert_eq!(code, 0);
    assert!(stdout.contains("NVDLA"));
}

#[test]
fn simulate_reports_bottleneck() {
    let (stdout, _, code) = run(&["simulate", "--layer", "vgg16:9", "--arch", "eyeriss"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("bottleneck level"));
    assert!(stdout.contains("tile-pipeline sim"));
    let (single, _, _) = run(&["simulate", "--layer", "vgg16:9", "--arch", "eyeriss", "--single-buffer"]);
    assert!(single.contains("single-buffered"));
}

#[test]
fn explore_prints_pareto() {
    let (stdout, _, code) = run(&["explore", "--network", "alexnet", "--arch", "eyeriss"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("Pareto front"));
}

#[test]
fn perf_smoke_writes_valid_bench_json() {
    let path = std::env::temp_dir().join("lm_cli_bench_eval.json");
    let (stdout, stderr, code) =
        run(&["perf", "--smoke", "--out", path.to_str().unwrap()]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("evals/s"), "{stdout}");
    assert!(stdout.contains("exhaustive"), "{stdout}");
    let json = std::fs::read_to_string(&path).unwrap();
    for key in [
        "\"schema\": 3",
        "\"evaluator\"",
        "\"per_op\"",
        "\"exhaustive\"",
        "\"search\"",
        "\"pruning\"",
        "\"scaling\"",
        "\"zoo_batch\"",
        "\"smoke\": true",
    ] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
    // A rate of exactly 0 means the harness measured nothing — the same
    // condition the CI validation step rejects.
    assert!(!json.contains("\"legacy_evals_per_sec\": 0.000"), "{json}");
    assert!(!json.contains("\"context_evals_per_sec\": 0.000"), "{json}");
}

#[test]
fn run_errors_cleanly_without_artifacts() {
    let (_, stderr, code) = run(&["run", "--artifacts", "/nonexistent/dir"]);
    assert_eq!(code, 1);
    assert!(stderr.contains("error"));
}
