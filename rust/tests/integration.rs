//! Integration tests: full pipelines across modules (workload → mapper →
//! model → energy → report → coordinator), all presets, all experiments at
//! reduced budgets.

use local_mapper::arch::{config, presets};
use local_mapper::coordinator::{compile_network, MappingService};
use local_mapper::mappers::genetic::GeneticMapper;
use local_mapper::mappers::{ConstrainedSearch, LocalMapper, Mapper, RandomMapper};
use local_mapper::mapspace::Dataflow;
use local_mapper::model::evaluate;
use local_mapper::report;
use local_mapper::workload::zoo;

#[test]
fn every_mapper_maps_every_preset_and_category() {
    let mappers: Vec<Box<dyn Mapper>> = vec![
        Box::new(LocalMapper::new()),
        Box::new(RandomMapper::new(16, 1)),
        Box::new(ConstrainedSearch::new(Dataflow::RowStationary, 40, 1)),
        Box::new(ConstrainedSearch::new(Dataflow::WeightStationary, 40, 1)),
        Box::new(ConstrainedSearch::new(Dataflow::OutputStationary, 40, 1)),
        Box::new(GeneticMapper::new(8, 3, 1)),
    ];
    for acc in presets::all() {
        for row in zoo::table2_workloads() {
            for m in &mappers {
                let out = m
                    .run(&row.layer, &acc)
                    .unwrap_or_else(|e| panic!("{} on {}×{}: {e}", m.name(), row.layer.name, acc.name));
                assert!(out.evaluation.energy.total_pj() > 0.0);
                assert!(out.evaluation.utilization > 0.0 && out.evaluation.utilization <= 1.0);
            }
        }
    }
}

#[test]
fn whole_zoo_compiles_on_every_preset() {
    for net in zoo::NETWORKS {
        let layers = zoo::network(net).unwrap();
        for acc in presets::all() {
            let plan = compile_network(&layers, &acc, &LocalMapper::new(), 4)
                .unwrap_or_else(|e| panic!("{net} on {}: {e}", acc.name));
            assert_eq!(plan.layers.len(), layers.len());
            assert_eq!(plan.total_macs(), layers.iter().map(|l| l.macs()).sum::<u64>());
        }
    }
}

#[test]
fn energy_totals_consistent_between_breakdown_and_total() {
    let acc = presets::eyeriss();
    for layer in zoo::vgg16() {
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        let e = evaluate(&layer, &acc, &m).unwrap();
        let component_sum: f64 =
            e.energy.components(&acc).iter().map(|(_, pj)| pj).sum();
        assert!((component_sum - e.energy.total_pj()).abs() < 1e-6 * e.energy.total_pj());
    }
}

#[test]
fn table3_experiment_shape_holds_at_small_budget() {
    let cells = report::table3(120, 7);
    assert_eq!(cells.len(), 27);
    // LOCAL faster on ≥ 24/27; energy within 2× on most cells.
    let faster = cells.iter().filter(|c| c.speedup > 1.0).count();
    assert!(faster >= 24, "{faster}/27");
    let close = cells.iter().filter(|c| c.local_energy_uj <= 2.0 * c.baseline_energy_uj).count();
    assert!(close >= 18, "LOCAL energy within 2x on only {close}/27");
}

#[test]
fn fig7_dram_dominance() {
    let panels = report::fig7(60, 11);
    let mut dominant = 0;
    let mut cells = 0;
    for p in &panels {
        for (_, base, _) in &p.entries {
            cells += 1;
            let on_chip_max = base.energy.level_pj[..base.energy.level_pj.len() - 1]
                .iter()
                .cloned()
                .fold(0.0f64, f64::max);
            if base.energy.dram_pj() >= on_chip_max * 0.5 {
                dominant += 1;
            }
        }
    }
    // DRAM is a (near-)dominant component on the large majority of cells.
    assert!(dominant * 10 >= cells * 7, "{dominant}/{cells}");
}

#[test]
fn service_survives_mixed_workload_burst() {
    let svc = MappingService::start(presets::nvdla(), LocalMapper::new(), 4);
    let mut layers = Vec::new();
    layers.extend(zoo::vgg16());
    layers.extend(zoo::squeezenet());
    layers.extend(zoo::alexnet());
    let replies = svc.map_all(&layers);
    assert_eq!(replies.len(), layers.len());
    assert!(replies.iter().all(|r| r.is_ok()));
    let m = &svc.metrics;
    assert_eq!(m.requests.load(std::sync::atomic::Ordering::Relaxed), layers.len() as u64);
    assert_eq!(m.errors.load(std::sync::atomic::Ordering::Relaxed), 0);
    svc.shutdown();
}

#[test]
fn arch_yaml_roundtrip_preserves_evaluation() {
    // A mapping evaluated on a preset must evaluate identically on the
    // YAML round-tripped copy of that preset.
    let layer = zoo::vgg16()[0].clone();
    for acc in presets::all() {
        let acc2 = config::accelerator_from_str(&config::accelerator_to_yaml(&acc)).unwrap();
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        let e1 = evaluate(&layer, &acc, &m).unwrap();
        let e2 = evaluate(&layer, &acc2, &m).unwrap();
        assert_eq!(e1, e2, "{}", acc.name);
    }
}

#[test]
fn depthwise_network_end_to_end() {
    let layers = zoo::mobilenet_v2();
    let acc = presets::eyeriss();
    let plan = compile_network(&layers, &acc, &LocalMapper::new(), 4).unwrap();
    // Depthwise layers must carry less weight traffic than their dense
    // shape would imply; at minimum, the plan is complete and consistent.
    assert_eq!(plan.layers.len(), 52);
    for lp in &plan.layers {
        assert!(lp.outcome.evaluation.energy.total_pj() > 0.0, "{}", lp.layer.name);
    }
}

#[test]
fn warm_starts_seed_bert_and_never_worsen_scores() {
    use local_mapper::coordinator::{compile_batch_with_policy, SeedPolicy};
    // BERT's matmul family (q/k/v/attn_out, ffn1, ffn2) gives the
    // similarity index same-op neighbors: with one worker the two later
    // matmul shapes are cache misses with a seedable neighbor, so the
    // adapt policy must seed exactly those two. Seeding merges into the
    // search result, so every per-layer score is equal or better than the
    // unseeded run of the identical mapper.
    let acc = presets::eyeriss();
    let networks = vec![("bert".to_string(), zoo::bert_base())];
    let mapper = RandomMapper::new(400, 9);
    let cold =
        compile_batch_with_policy(&networks, &acc, &mapper, 1, SeedPolicy::Off).unwrap();
    let warm =
        compile_batch_with_policy(&networks, &acc, &mapper, 1, SeedPolicy::Adapt).unwrap();
    assert_eq!(cold.warm_seeded, 0, "policy off must never seed");
    assert_eq!(warm.warm_seeded, 2, "both later matmul misses seed from the first");
    assert!(
        warm.seed_quality > 0.0 && warm.seed_quality <= 1.0 + 1e-9,
        "seed quality is a final/seed score ratio: {}",
        warm.seed_quality
    );
    for ((_, cp), (_, wp)) in cold.networks.iter().zip(&warm.networks) {
        assert_eq!(cp.layers.len(), wp.layers.len());
        for (c, w) in cp.layers.iter().zip(&wp.layers) {
            assert_eq!(c.layer, w.layer, "layer order diverged");
            assert!(
                w.outcome.score <= c.outcome.score,
                "{}: seeded {} > unseeded {}",
                w.layer.name,
                w.outcome.score,
                c.outcome.score
            );
        }
    }
}

#[test]
fn operator_diverse_networks_end_to_end() {
    use local_mapper::model::TensorIdx;
    use local_mapper::workload::{OpKind, Tensor};
    // The full pipeline (zoo → LOCAL → EvalContext → coordinator) must
    // handle matmul, pooling and elementwise layers on every preset.
    for (net, expect_layers) in [("bert", 96), ("vgg16pool", 18), ("mobilenetv2res", 62)] {
        let layers = zoo::network(net).unwrap();
        for acc in presets::all() {
            let plan = compile_network(&layers, &acc, &LocalMapper::new(), 4)
                .unwrap_or_else(|e| panic!("{net} on {}: {e}", acc.name));
            assert_eq!(plan.layers.len(), expect_layers);
            for lp in &plan.layers {
                let e = &lp.outcome.evaluation;
                assert!(e.energy.total_pj() > 0.0, "{net}/{}", lp.layer.name);
                // Weight-less ops carry zero weight traffic end to end.
                if !lp.layer.op.uses_weights() {
                    let w: u64 =
                        e.access.iter().map(|row| row[Tensor::Weight.t_idx()].total()).sum();
                    assert_eq!(w, 0, "{net}/{}", lp.layer.name);
                }
                if lp.layer.op == OpKind::Elementwise {
                    // Both operands read per add at the datapath.
                    assert_eq!(
                        e.access[0][Tensor::Input.t_idx()].reads,
                        2 * e.macs,
                        "{net}/{}",
                        lp.layer.name
                    );
                }
            }
        }
    }
}
