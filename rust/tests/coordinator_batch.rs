//! Batch-pipeline integration tests: `compile_batch` must agree with
//! sequential `compile_network` layer-for-layer, exploit the cross-network
//! mapping cache on repeated networks, and keep `ServiceMetrics` monotone
//! across successive batches on one service.

use local_mapper::arch::presets;
use local_mapper::coordinator::{compile_batch, compile_network, MappingService};
use local_mapper::mappers::LocalMapper;
use local_mapper::workload::zoo;
use std::sync::atomic::Ordering;
use std::time::Duration;

#[test]
fn batch_equals_sequential_compile_layer_for_layer() {
    let acc = presets::eyeriss();
    let networks = vec![
        ("vgg16".to_string(), zoo::vgg16()),
        ("alexnet".to_string(), zoo::alexnet()),
        ("squeezenet".to_string(), zoo::squeezenet()),
    ];
    let batch = compile_batch(&networks, &acc, &LocalMapper::new(), 4).unwrap();
    assert_eq!(batch.networks.len(), 3);
    for (name, plan) in &batch.networks {
        let layers = zoo::network(name).unwrap();
        let seq = compile_network(&layers, &acc, &LocalMapper::new(), 1).unwrap();
        assert_eq!(plan.layers.len(), seq.layers.len(), "{name}");
        for (a, b) in plan.layers.iter().zip(&seq.layers) {
            assert_eq!(a.layer, b.layer, "{name}: layer order diverged");
            assert_eq!(a.outcome.mapping, b.outcome.mapping, "{name}/{}", a.layer.name);
            assert_eq!(a.outcome.evaluation, b.outcome.evaluation, "{name}/{}", a.layer.name);
        }
        assert_eq!(plan.total_macs(), seq.total_macs(), "{name}");
    }
}

#[test]
fn repeated_networks_hit_the_cross_network_cache() {
    let acc = presets::nvdla();
    // Two copies of the same network on one worker: the worker processes
    // requests in submission order, so every layer of the second copy is a
    // guaranteed cache hit (plus any within-network shape repeats).
    let networks = vec![
        ("vgg16-a".to_string(), zoo::vgg16()),
        ("vgg16-b".to_string(), zoo::vgg16()),
    ];
    let batch = compile_batch(&networks, &acc, &LocalMapper::new(), 1).unwrap();
    assert_eq!(batch.requests, 26);
    assert!(batch.hit_rate() > 0.0);
    assert!(
        batch.cache_hits >= 13,
        "whole second copy should hit: {} hits",
        batch.cache_hits
    );
    // Per-layer flags agree with the aggregate.
    let flagged: usize = batch
        .networks
        .iter()
        .flat_map(|(_, p)| &p.layers)
        .filter(|lp| lp.cached)
        .count();
    assert_eq!(flagged as u64, batch.cache_hits);
    // The second copy is entirely cached.
    assert!(batch.networks[1].1.layers.iter().all(|lp| lp.cached));
}

#[test]
fn batch_reports_service_percentiles() {
    let acc = presets::shidiannao();
    let batch = compile_batch(
        &[("mobilenetv2".to_string(), zoo::mobilenet_v2())],
        &acc,
        &LocalMapper::new(),
        4,
    )
    .unwrap();
    assert_eq!(batch.requests, 52);
    assert!(batch.p50_service > Duration::ZERO);
    assert!(batch.p50_service <= batch.p99_service);
    assert!(batch.batch_time >= batch.p99_service);
}

#[test]
fn service_metrics_are_monotone_across_batches() {
    let svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 2);
    let mut last_requests = 0u64;
    let mut last_hits = 0u64;
    let mut last_ns = 0u64;
    for round in 0..3 {
        let replies = svc.map_all(&zoo::alexnet());
        assert!(replies.iter().all(|r| r.is_ok()));
        let requests = svc.metrics.requests.load(Ordering::Relaxed);
        let hits = svc.metrics.cache_hits.load(Ordering::Relaxed);
        let ns = svc.metrics.service_ns.load(Ordering::Relaxed);
        assert_eq!(requests, last_requests + 5, "round {round}");
        assert!(hits >= last_hits, "round {round}");
        assert!(ns >= last_ns, "round {round}");
        last_requests = requests;
        last_hits = hits;
        last_ns = ns;
    }
    // After the first round every AlexNet shape is cached: rounds 2 and 3
    // are all hits.
    assert!(last_hits >= 10, "hits: {last_hits}");
    assert!(svc.metrics.p50_service_time() <= svc.metrics.p99_service_time());
    assert!(svc.metrics.hit_rate() > 0.0);
    svc.shutdown();
}

#[test]
fn whole_batch_zoo_compiles_on_every_preset() {
    for acc in presets::all() {
        let batch = compile_batch(&zoo::batch_zoo(), &acc, &LocalMapper::new(), 4)
            .unwrap_or_else(|e| panic!("batch on {}: {e}", acc.name));
        assert_eq!(batch.networks.len(), 8);
        assert_eq!(batch.total_layers(), 13 + 53 + 52 + 26 + 5 + 96 + 18 + 62);
        assert_eq!(batch.requests, batch.total_layers() as u64);
        // The zoo repeats shapes heavily (ResNet bottlenecks, VGG pairs,
        // BERT's identical encoder blocks): the shared cache must see hits
        // even under racy workers.
        assert!(batch.hit_rate() > 0.0, "{}: no cache hits", acc.name);
        for (name, plan) in &batch.networks {
            assert!(plan.total_energy_uj() > 0.0, "{name}");
            assert!(plan.total_latency_cycles() > 0, "{name}");
        }
    }
}

#[test]
fn operator_diverse_networks_ride_the_shared_cache() {
    // The acceptance scenario: matmul/pooling/elementwise networks flow
    // through the same shared-cache service as the conv zoo. BERT's 12
    // identical encoder blocks make most of its 96 layers cache hits.
    let acc = presets::eyeriss();
    let networks = vec![
        ("bert".to_string(), zoo::bert_base()),
        ("vgg16pool".to_string(), zoo::vgg16_pooled()),
        ("mobilenetv2res".to_string(), zoo::mobilenet_v2_residual()),
    ];
    let batch = compile_batch(&networks, &acc, &LocalMapper::new(), 1).unwrap();
    assert_eq!(batch.total_layers(), 96 + 18 + 62);
    // One worker → deterministic order: BERT has only 4 unique shapes
    // (q/k/v/attn_out share one matmul shape, plus ffn1, ffn2 and the
    // add), so 92 of its 96 requests hit the cache.
    assert!(batch.cache_hits >= 90, "hits: {}", batch.cache_hits);
    for (name, plan) in &batch.networks {
        assert!(plan.total_energy_uj() > 0.0, "{name}");
    }
}
