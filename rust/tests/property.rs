//! Property tests (hand-rolled SplitMix64 driver — proptest is not in the
//! offline crate set). Each property sweeps randomized layers, machines
//! and mappings and asserts an invariant of the system.

use local_mapper::arch::{presets, Accelerator, Noc, PeArray, StorageLevel, Style};
use local_mapper::coordinator::layer_key;
use local_mapper::mappers::engine::{BoundedLattice, OdometerSource, SearchDriver};
use local_mapper::mappers::{
    ConstrainedSearch, ExhaustiveMapper, LocalMapper, Mapper, Objective, RandomMapper,
};
use local_mapper::mapspace::{lattice_order, lattice_subtree_blocks, repair, sample_random, Dataflow};
use local_mapper::model::{evaluate, evaluate_unchecked, EvalContext, TensorIdx};
use local_mapper::util::rng::SplitMix64;
use local_mapper::workload::{zoo, ConvLayer, Dim, OpKind, Tensor};

/// Random plausible conv layer (dims drawn from real-network ranges).
fn random_layer(rng: &mut SplitMix64) -> ConvLayer {
    let pick = |rng: &mut SplitMix64, xs: &[u64]| xs[rng.index(xs.len())];
    let k = pick(rng, &[1, 3, 5, 7]);
    let pq = pick(rng, &[7, 13, 14, 27, 28, 56]);
    ConvLayer::new(
        "prop",
        pick(rng, &[8, 16, 64, 96, 128, 256]),
        pick(rng, &[3, 8, 16, 64, 128, 512]),
        k,
        k,
        pq,
        pq,
    )
}

/// Random plausible layer of a given operator kind (dims drawn from
/// real-network ranges of that op's live subset).
fn random_op_layer(op: OpKind, rng: &mut SplitMix64) -> ConvLayer {
    let pick = |rng: &mut SplitMix64, xs: &[u64]| xs[rng.index(xs.len())];
    let ch = pick(rng, &[8, 16, 64, 96, 128, 256]);
    let pq = pick(rng, &[7, 13, 14, 27, 28, 56]);
    match op {
        OpKind::Conv => random_layer(rng),
        OpKind::DepthwiseConv => {
            ConvLayer::new("prop-dw", ch, ch, 3, 3, pq, pq).depthwise()
        }
        OpKind::MatMul => {
            let c = pick(rng, &[8, 64, 256, 768]);
            let rows = pick(rng, &[8, 64, 128]);
            ConvLayer::matmul("prop-mm", ch, c, rows)
        }
        OpKind::Pooling => {
            ConvLayer::pooling("prop-pool", ch, pick(rng, &[2, 3]), pq, pq).with_stride(2)
        }
        OpKind::Elementwise => ConvLayer::elementwise("prop-add", ch, pq, pq),
    }
}

/// Random accelerator: style, PE dims, buffer geometry.
fn random_acc(rng: &mut SplitMix64) -> Accelerator {
    let styles = [Style::EyerissLike, Style::NvdlaLike, Style::ShiDianNaoLike];
    let pick = |rng: &mut SplitMix64, xs: &[u64]| xs[rng.index(xs.len())];
    let acc = Accelerator {
        name: "prop".into(),
        style: styles[rng.index(3)],
        datawidth_bits: 16,
        levels: vec![
            StorageLevel::register_file("RF", pick(rng, &[16, 32, 64]), 16),
            StorageLevel::buffer("GLB", pick(rng, &[4096, 16384, 65536]), 64),
            StorageLevel::dram(64),
        ],
        pe: PeArray::new(pick(rng, &[4, 8, 12, 16]), pick(rng, &[4, 8, 14, 16])),
        noc: Noc::default(),
        mac_energy_pj: 1.0,
        clock_mhz: 200.0,
    };
    acc.validate().unwrap();
    acc
}

#[test]
fn prop_eval_context_bit_identical_to_legacy() {
    // The zero-allocation EvalContext path must produce *bit-identical*
    // Evaluations to the legacy allocating evaluator: same integers, same
    // floats (same operations in the same order), across random valid
    // mappings × the full five-network zoo × all three presets.
    let mut rng = SplitMix64::new(0x2026);
    for acc in presets::all() {
        for (net, layers) in zoo::batch_zoo() {
            for layer in &layers {
                let mut ctx = EvalContext::new(layer, &acc);
                for _ in 0..3 {
                    let m = sample_random(layer, &acc, &mut rng);
                    let legacy = evaluate_unchecked(layer, &acc, &m);
                    let fast = ctx.evaluate_into(&m);
                    assert_eq!(
                        &legacy, fast,
                        "context/legacy diverged on {net}/{} × {}",
                        layer.name, acc.name
                    );
                }
            }
        }
    }
}

#[test]
fn prop_eval_context_bit_identical_on_random_scenes() {
    // Same bit-identity over randomized layers and machines (covers
    // depthwise-free shapes the zoo sweep may miss and random PE/buffer
    // geometries).
    let mut rng = SplitMix64::new(0x1DEA);
    for _ in 0..100 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let mut ctx = EvalContext::new(&layer, &acc);
        let m = sample_random(&layer, &acc, &mut rng);
        assert_eq!(&evaluate_unchecked(&layer, &acc, &m), ctx.evaluate_into(&m));
    }
}

#[test]
fn prop_parallel_exhaustive_matches_single_thread() {
    // Sharded parallel enumeration must return the identical best mapping,
    // best-energy bits and evaluation count as the single-threaded oracle
    // at every thread count (deterministic best-of-shards merge).
    let acc = Accelerator {
        name: "prop-ex".into(),
        style: Style::NvdlaLike,
        datawidth_bits: 16,
        levels: vec![
            StorageLevel::register_file("RF", 64, 16),
            StorageLevel::buffer("GLB", 1024, 64),
            StorageLevel::dram(64),
        ],
        pe: PeArray::new(4, 4),
        noc: Noc::default(),
        mac_energy_pj: 1.0,
        clock_mhz: 200.0,
    };
    let layer = ConvLayer::new("prop-tiny", 4, 2, 1, 1, 4, 4);
    let size = ExhaustiveMapper::space_size(&layer, &acc);
    assert!(size < 2_000_000, "space too big for the determinism sweep: {size}");
    let base = ExhaustiveMapper::new(size).with_permutations().run(&layer, &acc).unwrap();
    for threads in [2usize, 3, 4, 8] {
        let par = ExhaustiveMapper::new(size)
            .with_permutations()
            .with_threads(threads)
            .run(&layer, &acc)
            .unwrap();
        assert_eq!(par.mapping, base.mapping, "threads={threads}");
        assert_eq!(
            par.evaluation.energy.total_pj().to_bits(),
            base.evaluation.energy.total_pj().to_bits(),
            "threads={threads}"
        );
        assert_eq!(par.evaluations, base.evaluations, "threads={threads}");
    }
}

#[test]
fn prop_objective_bound_is_a_true_lower_bound() {
    // The pruner's contract: `EvalContext::objective_bound` of a tiling
    // never exceeds the real (energy, latency) of ANY per-level
    // permutation of that tiling — across random ops, machines and
    // mappings. A violated bound could prune the argmin.
    let mut rng = SplitMix64::new(0xB0_07D);
    for trial in 0..150 {
        let op = OpKind::ALL[trial % OpKind::ALL.len()];
        let layer = random_op_layer(op, &mut rng);
        let acc = random_acc(&mut rng);
        let mut ctx = EvalContext::new(&layer, &acc);
        let base = sample_random(&layer, &acc, &mut rng);
        let (e_lb, l_lb) = ctx.objective_bound(&base);
        // The mapping itself plus shuffled/rotated permutation variants
        // all share the tiling, so all must respect the bound.
        let mut m = base.clone();
        for variant in 0..8 {
            if variant > 0 {
                for l in 0..m.n_levels() {
                    rng.shuffle(&mut m.permutation[l]);
                }
            }
            let e = ctx.evaluate_into(&m);
            assert!(
                e_lb <= e.energy.total_pj(),
                "energy bound {e_lb} > actual {} for {layer} on {acc}",
                e.energy.total_pj()
            );
            assert!(
                l_lb <= e.latency_cycles,
                "latency bound {l_lb} > actual {} for {layer} on {acc}",
                e.latency_cycles
            );
        }
    }
}

#[test]
fn prop_pruned_exhaustive_is_bit_identical_and_cuts_2x() {
    // Bound-based pruning must return the bit-identical best mapping and
    // evaluation as the unpruned enumeration on every (preset, zoo layer,
    // budget) — while evaluating strictly fewer candidates, at least 2x
    // fewer somewhere on every preset.
    for acc in presets::all() {
        let mut best_cut = 1.0f64;
        let mut pruned_any = false;
        let cases: [(ConvLayer, u64); 3] = [
            (zoo::vgg02()[4].clone(), 3_000),
            (zoo::vgg02()[4].clone(), 10_000),
            (zoo::vgg16()[8].clone(), 20_000),
        ];
        for (layer, budget) in cases {
            let full = ExhaustiveMapper::new(budget).with_permutations().without_pruning();
            let base = full.run(&layer, &acc).unwrap();
            let fast = ExhaustiveMapper::new(budget).with_permutations();
            let out = fast.run(&layer, &acc).unwrap();
            assert_eq!(out.mapping, base.mapping, "{} × {} b{budget}", layer.name, acc.name);
            assert_eq!(
                out.evaluation.energy.total_pj().to_bits(),
                base.evaluation.energy.total_pj().to_bits(),
                "{} × {} b{budget}",
                layer.name,
                acc.name
            );
            assert!(out.evaluations <= base.evaluations);
            // Every in-budget candidate is either examined or pruned.
            assert_eq!(out.evaluations + fast.pruned(), base.evaluations);
            pruned_any |= fast.pruned() > 0;
            best_cut = best_cut.max(base.evaluations as f64 / out.evaluations.max(1) as f64);
        }
        assert!(pruned_any, "{}: pruner never engaged", acc.name);
        assert!(best_cut >= 2.0, "{}: best pruning cut only {best_cut:.2}x", acc.name);
    }
}

#[test]
fn prop_pruned_search_preserves_the_tiebreak_index() {
    // At the driver level the whole triple (mapping, score bits, global
    // tie-break index) must survive pruning, threads or both.
    let acc = presets::eyeriss();
    let layer = zoo::vgg02()[4].clone();
    let source = OdometerSource::new(&layer, &acc, true);
    let seed = LocalMapper::new().map(&layer, &acc).unwrap();
    let serial = SearchDriver {
        objective: Objective::Energy,
        budget: 5_000,
        threads: 1,
        prune: false,
        deadline: None,
    };
    let base = serial.search(&layer, &acc, &source, std::slice::from_ref(&seed)).unwrap();
    for (threads, prune) in [(1, true), (4, false), (4, true)] {
        let out = SearchDriver {
            objective: Objective::Energy,
            budget: 5_000,
            threads,
            prune,
            deadline: None,
        }
        .search(&layer, &acc, &source, std::slice::from_ref(&seed))
        .unwrap();
        assert_eq!(out.mapping, base.mapping, "threads={threads} prune={prune}");
        assert_eq!(out.score.to_bits(), base.score.to_bits());
        assert_eq!(out.index, base.index, "threads={threads} prune={prune}");
        assert_eq!(out.examined + out.pruned, base.examined);
    }
}

#[test]
fn prop_pruned_constrained_search_is_bit_identical() {
    for acc in presets::all() {
        for df in [Dataflow::RowStationary, Dataflow::WeightStationary] {
            let layer = zoo::vgg16()[8].clone();
            let full = ConstrainedSearch::new(df, 500, 13).without_pruning();
            let base = full.run(&layer, &acc).unwrap();
            let fast = ConstrainedSearch::new(df, 500, 13);
            let out = fast.run(&layer, &acc).unwrap();
            assert_eq!(out.mapping, base.mapping, "{} × {}", df.name(), acc.name);
            assert_eq!(
                out.evaluation.energy.total_pj().to_bits(),
                base.evaluation.energy.total_pj().to_bits()
            );
            assert_eq!(out.evaluations + fast.pruned(), base.evaluations);
        }
    }
}

#[test]
fn prop_parallel_stochastic_searches_are_thread_invariant() {
    // The newly parallel mappers — best-of-N random and the constrained
    // RS/WS/OS searches — return identical outcomes (mapping, evaluation
    // bits, evaluation count) at 1/2/4/8 threads for a fixed seed.
    for acc in presets::all() {
        let layer = zoo::vgg02()[4].clone();
        let rnd_base = RandomMapper::new(300, 21).run(&layer, &acc).unwrap();
        let rs_base = ConstrainedSearch::new(Dataflow::RowStationary, 300, 21)
            .run(&layer, &acc)
            .unwrap();
        for threads in [2usize, 4, 8] {
            let rnd = RandomMapper::new(300, 21).with_threads(threads).run(&layer, &acc).unwrap();
            assert_eq!(rnd.mapping, rnd_base.mapping, "random t={threads} on {}", acc.name);
            assert_eq!(
                rnd.evaluation.energy.total_pj().to_bits(),
                rnd_base.evaluation.energy.total_pj().to_bits()
            );
            assert_eq!(rnd.evaluations, rnd_base.evaluations);
            let rs = ConstrainedSearch::new(Dataflow::RowStationary, 300, 21)
                .with_threads(threads)
                .run(&layer, &acc)
                .unwrap();
            assert_eq!(rs.mapping, rs_base.mapping, "rs t={threads} on {}", acc.name);
            assert_eq!(
                rs.evaluation.energy.total_pj().to_bits(),
                rs_base.evaluation.energy.total_pj().to_bits()
            );
            assert_eq!(rs.evaluations, rs_base.evaluations);
        }
    }
}

#[test]
fn conv_relevance_tables_match_legacy() {
    // The conv-path bit-identity guarantee starts here: the op-generic
    // relevance tables must reproduce the pre-refactor hand-coded sets
    // exactly for dense conv (the old `Tensor::relevant`) and depthwise
    // (the old special case adding M to Input's relevance).
    let dense: [(Tensor, &[Dim]); 3] = [
        (Tensor::Weight, &[Dim::M, Dim::C, Dim::R, Dim::S]),
        (Tensor::Input, &[Dim::N, Dim::C, Dim::P, Dim::R, Dim::Q, Dim::S]),
        (Tensor::Output, &[Dim::N, Dim::M, Dim::P, Dim::Q]),
    ];
    for (t, legacy) in dense {
        for d in Dim::ALL {
            assert_eq!(OpKind::Conv.relevant(t, d), legacy.contains(&d), "conv {t} {d}");
            // Depthwise = dense + (Input, M), exactly as the old
            // `relevant_for` special case computed it.
            let legacy_dw = legacy.contains(&d) || (t == Tensor::Input && d == Dim::M);
            assert_eq!(OpKind::DepthwiseConv.relevant(t, d), legacy_dw, "dw {t} {d}");
        }
    }
}

#[test]
fn prop_local_valid_for_every_op_kind_on_every_preset() {
    // LOCAL must construct a valid mapping for every OpKind × arch preset
    // across randomized layer shapes of each op's live dimension subset.
    let mut rng = SplitMix64::new(0x0123);
    for op in OpKind::ALL {
        for acc in presets::all() {
            for _ in 0..20 {
                let layer = random_op_layer(op, &mut rng);
                let m = LocalMapper::new().map(&layer, &acc).unwrap_or_else(|e| {
                    panic!("LOCAL failed on {op} {layer} × {}: {e}", acc.name)
                });
                m.validate(&layer, &acc).unwrap_or_else(|e| {
                    panic!("invalid LOCAL mapping on {op} {layer} × {}: {e}", acc.name)
                });
            }
        }
    }
}

#[test]
fn prop_eval_context_bit_identical_across_op_kinds() {
    // The op-aware masks and weight gating of the zero-allocation path
    // must agree bit-for-bit with the legacy evaluator on every operator
    // projection and random machines, not just conv.
    let mut rng = SplitMix64::new(0x0FF1CE);
    for op in OpKind::ALL {
        for _ in 0..30 {
            let layer = random_op_layer(op, &mut rng);
            let acc = random_acc(&mut rng);
            let mut ctx = EvalContext::new(&layer, &acc);
            let m = sample_random(&layer, &acc, &mut rng);
            assert_eq!(
                &evaluate_unchecked(&layer, &acc, &m),
                ctx.evaluate_into(&m),
                "context/legacy diverged on {op} {layer} × random acc"
            );
        }
    }
}

#[test]
fn prop_layer_keys_distinct_across_ops() {
    // Distinct op kinds with identical dimension bounds must never share
    // a cache key or a shard fingerprint (cross-op cache collisions would
    // serve a matmul a pooling mapping).
    let mut rng = SplitMix64::new(0xD15C0);
    let acc = presets::eyeriss();
    for _ in 0..100 {
        let pick = |rng: &mut SplitMix64, xs: &[u64]| xs[rng.index(xs.len())];
        let ch = pick(&mut rng, &[8, 64, 256]);
        let pq = pick(&mut rng, &[7, 14, 28]);
        // Three ops sharing the exact same seven bounds.
        let conv = ConvLayer::new("k", ch, 1, 1, 1, pq, pq);
        let pool = ConvLayer::pooling("k", ch, 1, pq, pq);
        let add = ConvLayer::elementwise("k", ch, pq, pq);
        assert_eq!(conv.bounds(), pool.bounds());
        assert_eq!(conv.bounds(), add.bounds());
        let keys = [layer_key(&conv, &acc), layer_key(&pool, &acc), layer_key(&add, &acc)];
        for i in 0..3 {
            for j in i + 1..3 {
                assert_ne!(keys[i], keys[j], "op keys collided at ch={ch} pq={pq}");
                assert_ne!(keys[i].fnv1a(), keys[j].fnv1a(), "fingerprints collided");
            }
        }
    }
}

#[test]
fn prop_weightless_ops_have_zero_weight_traffic_everywhere() {
    let mut rng = SplitMix64::new(0xADD);
    for op in [OpKind::Pooling, OpKind::Elementwise] {
        for _ in 0..40 {
            let layer = random_op_layer(op, &mut rng);
            let acc = random_acc(&mut rng);
            let e = evaluate_unchecked(&layer, &acc, &sample_random(&layer, &acc, &mut rng));
            for l in 0..acc.n_levels() {
                assert_eq!(e.access[l][Tensor::Weight.t_idx()].total(), 0, "{op} level {l}");
            }
            assert!(e.energy.total_pj() > 0.0 && e.energy.total_pj().is_finite());
        }
    }
}

#[test]
fn prop_local_always_yields_valid_mapping() {
    let mut rng = SplitMix64::new(0xA11CE);
    for _ in 0..300 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let m = LocalMapper::new()
            .map(&layer, &acc)
            .unwrap_or_else(|e| panic!("LOCAL failed: {layer} on {acc}: {e}"));
        m.validate(&layer, &acc).unwrap();
    }
}

#[test]
fn prop_random_samples_always_valid() {
    let mut rng = SplitMix64::new(0xBEEF);
    for _ in 0..300 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let m = sample_random(&layer, &acc, &mut rng);
        m.validate(&layer, &acc).unwrap();
    }
}

#[test]
fn prop_mac_energy_is_mapping_invariant() {
    // The MAC component of energy depends only on the layer, never on the
    // mapping (conservation of compute).
    let mut rng = SplitMix64::new(0xC0FFEE);
    for _ in 0..100 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let a = evaluate_unchecked(&layer, &acc, &sample_random(&layer, &acc, &mut rng));
        let b = evaluate_unchecked(&layer, &acc, &sample_random(&layer, &acc, &mut rng));
        assert_eq!(a.macs, layer.macs());
        assert_eq!(a.macs, b.macs);
        assert_eq!(a.energy.mac_pj, b.energy.mac_pj);
    }
}

#[test]
fn prop_rf_datapath_reads_equal_macs() {
    // Every MAC reads W and I from the RF exactly once in our model,
    // regardless of mapping.
    let mut rng = SplitMix64::new(0xDADA);
    for _ in 0..100 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let e = evaluate_unchecked(&layer, &acc, &sample_random(&layer, &acc, &mut rng));
        assert_eq!(e.access[0][Tensor::Weight.t_idx()].reads, e.macs);
        assert_eq!(e.access[0][Tensor::Input.t_idx()].reads, e.macs);
    }
}

#[test]
fn prop_dram_reads_bounded_below_by_tensor_volume() {
    // DRAM must serve at least one full read of W and I (no compression,
    // no bypass), and at least one full write of O.
    let mut rng = SplitMix64::new(0xFEED);
    for _ in 0..100 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let e = evaluate_unchecked(&layer, &acc, &sample_random(&layer, &acc, &mut rng));
        let top = acc.n_levels() - 1;
        assert!(e.access[top][Tensor::Weight.t_idx()].reads >= layer.tensor_volume(Tensor::Weight));
        assert!(e.access[top][Tensor::Output.t_idx()].writes >= layer.tensor_volume(Tensor::Output));
    }
}

#[test]
fn prop_energy_positive_and_finite() {
    let mut rng = SplitMix64::new(0x5EED);
    for _ in 0..200 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let e = evaluate_unchecked(&layer, &acc, &sample_random(&layer, &acc, &mut rng));
        let pj = e.energy.total_pj();
        assert!(pj.is_finite() && pj > 0.0);
        assert!(e.latency_cycles > 0);
        assert!(e.utilization > 0.0 && e.utilization <= 1.0);
    }
}

#[test]
fn prop_repair_is_idempotent() {
    let mut rng = SplitMix64::new(0x1D3A);
    for _ in 0..200 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let m = sample_random(&layer, &acc, &mut rng);
        let mut m2 = m.clone();
        repair(&layer, &acc, &mut m2);
        assert_eq!(m, m2);
    }
}

#[test]
fn prop_more_parallelism_never_decreases_utilization_metric() {
    // Utilization equals spatial fan-out / PE count by construction.
    let mut rng = SplitMix64::new(0xFACE);
    for _ in 0..100 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let m = sample_random(&layer, &acc, &mut rng);
        let e = evaluate_unchecked(&layer, &acc, &m);
        let expect = (m.spatial_x_used() * m.spatial_y_used()) as f64 / acc.pe.count() as f64;
        assert!((e.utilization - expect).abs() < 1e-12);
    }
}

#[test]
fn prop_local_energy_at_most_random_median() {
    // LOCAL must consistently land in the good half of the random
    // distribution (Fig. 3 vs §5): check across random scenes.
    let mut rng = SplitMix64::new(0xB0B);
    let mut wins = 0;
    let mut total = 0;
    for _ in 0..40 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let local = LocalMapper::new().run(&layer, &acc).unwrap();
        let mut energies: Vec<f64> = (0..31)
            .map(|_| {
                evaluate_unchecked(&layer, &acc, &sample_random(&layer, &acc, &mut rng))
                    .energy
                    .total_pj()
            })
            .collect();
        energies.sort_by(f64::total_cmp);
        let median = energies[energies.len() / 2];
        total += 1;
        if local.evaluation.energy.total_pj() <= median {
            wins += 1;
        }
    }
    assert!(wins * 10 >= total * 9, "LOCAL beat the random median on only {wins}/{total} scenes");
}

#[test]
fn prop_trivial_mapping_is_energy_upper_bound_class() {
    // The all-at-DRAM mapping is never better than LOCAL.
    let mut rng = SplitMix64::new(0xE0F);
    for _ in 0..50 {
        let layer = random_layer(&mut rng);
        let acc = random_acc(&mut rng);
        let trivial = local_mapper::mapping::Mapping::trivial(&layer, acc.n_levels());
        let e_triv = evaluate(&layer, &acc, &trivial).unwrap();
        let e_local = LocalMapper::new().run(&layer, &acc).unwrap().evaluation;
        assert!(
            e_local.energy.total_pj() <= e_triv.energy.total_pj() * 1.001,
            "{layer} on {acc}: LOCAL {} > trivial {}",
            e_local.energy.total_pj(),
            e_triv.energy.total_pj()
        );
    }
}

#[test]
fn prop_permutation_only_changes_energy_not_macs_or_footprint() {
    let mut rng = SplitMix64::new(0xAB);
    for _ in 0..100 {
        let layer = random_layer(&mut rng);
        let acc = presets::eyeriss();
        let mut m = sample_random(&layer, &acc, &mut rng);
        let e1 = evaluate_unchecked(&layer, &acc, &m);
        for l in 0..m.n_levels() {
            rng.shuffle(&mut m.permutation[l]);
        }
        let e2 = evaluate_unchecked(&layer, &acc, &m);
        assert_eq!(e1.macs, e2.macs);
        assert_eq!(e1.utilization, e2.utilization);
        // Footprints (tile sizes) unchanged → validity unchanged.
        m.validate(&layer, &acc).unwrap();
    }
}

#[test]
fn prop_partial_bound_is_a_true_lower_bound_of_completions() {
    // Branch-and-bound's contract: `EvalContext::partial_bound` of a
    // prefix assignment never exceeds the real (energy, latency) — hence
    // never the composed objective — of any **rotation-block member** of
    // any completion of that prefix (rotations are exactly what the
    // lattice source emits; the tight bound is deliberately unsound for
    // arbitrary shuffled permutations). Every sampled valid mapping's
    // tiling is a completion of each of its own prefixes along the DFS
    // order, so we check all 8 prefix depths against each of its 7
    // rotation members across sampled zoo layers × the three presets ×
    // the three objectives.
    let order = lattice_order();
    let mut rng = SplitMix64::new(0xB0B0);
    for acc in presets::all() {
        for (net, layers) in zoo::batch_zoo() {
            for (li, layer) in layers.iter().enumerate() {
                if li % 9 != 0 {
                    continue; // sample the zoo, don't sweep all 325 layers
                }
                let mut ctx = EvalContext::new(layer, &acc);
                let m = sample_random(layer, &acc, &mut rng);
                let mut variant = m.clone();
                for rot in 0..7usize {
                    let mut p = Dim::ALL;
                    p.rotate_left(rot);
                    for l in 0..variant.n_levels() {
                        variant.permutation[l] = p;
                    }
                    let e = ctx.evaluate_into(&variant).clone();
                    for depth in 0..=7usize {
                        // The prefix: dims past `depth` in DFS order reset
                        // to 1 everywhere (not yet assigned).
                        let mut prefix = m.clone();
                        let mut assigned = [true; 7];
                        for &d in &order[depth..] {
                            assigned[d.idx()] = false;
                            for l in 0..prefix.n_levels() {
                                prefix.temporal[l][d.idx()] = 1;
                            }
                            prefix.spatial_x[d.idx()] = 1;
                            prefix.spatial_y[d.idx()] = 1;
                        }
                        let (e_lb, l_lb) = ctx.partial_bound(&prefix, &assigned);
                        assert!(
                            e_lb <= e.energy.total_pj(),
                            "energy bound {e_lb} > actual {} at depth {depth} on {net}/{} × {}",
                            e.energy.total_pj(),
                            layer.name,
                            acc.name
                        );
                        assert!(
                            l_lb <= e.latency_cycles,
                            "latency bound {l_lb} > actual {} at depth {depth} on {net}/{} × {}",
                            e.latency_cycles,
                            layer.name,
                            acc.name
                        );
                        for objective in Objective::ALL {
                            assert!(
                                objective.compose(e_lb, l_lb) <= objective.score(&e),
                                "{objective} bound inverted at depth {depth} on {net}/{}",
                                layer.name
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_branch_and_bound_bit_identical_to_unpruned_exhaustive() {
    // Branch-and-bound over the factorization lattice must return the
    // identical triple (mapping, score bits, tie-break index) as the
    // unpruned flat enumeration over the same budgeted range — for every
    // objective, at 1/2/4/8 worker threads, with every in-budget
    // candidate accounted examined-or-pruned.
    let acc = presets::eyeriss();
    let layer = zoo::vgg02()[4].clone();
    let budget = 3_000u64;
    let odometer = OdometerSource::new(&layer, &acc, true);
    let lattice = BoundedLattice::new(&layer, &acc, true);
    for objective in Objective::ALL {
        let base = SearchDriver { objective, budget, threads: 1, prune: false, deadline: None }
            .search(&layer, &acc, &odometer, &[])
            .unwrap();
        for threads in [1usize, 2, 4, 8] {
            let driver = SearchDriver { objective, budget, threads, prune: true, deadline: None };
            let (bnb, certified) = driver.branch_and_bound(&layer, &acc, &lattice, &[]);
            let bnb = bnb.unwrap();
            assert!(!certified, "a 3k budget cannot cover conv5's space");
            assert_eq!(bnb.mapping, base.mapping, "{objective} t={threads}");
            assert_eq!(bnb.score.to_bits(), base.score.to_bits(), "{objective} t={threads}");
            assert_eq!(bnb.index, base.index, "{objective} t={threads}");
            assert_eq!(
                bnb.examined + bnb.pruned,
                base.examined,
                "{objective} t={threads}: candidates leaked"
            );
            assert!(bnb.pruned > 0, "{objective} t={threads}: B&B pruned nothing");
        }
    }
}

#[test]
fn prop_certified_bnb_examines_at_most_a_tenth_of_exhaustive() {
    // The headline acceptance property: on VGG-16 conv9 under every
    // preset, branch-and-bound warm-started with the unpruned run's own
    // argmin (the oracle-incumbent protocol — seeding with the eventual
    // winner provably cannot change the argmin, since an exact tie
    // resolves to the enumerated copy) examines at most 10% of the
    // candidates the unpruned exhaustive search does, while returning the
    // identical mapping and score.
    let layer = zoo::vgg16()[8].clone();
    let budget = 20_000u64;
    for acc in presets::all() {
        let odometer = OdometerSource::new(&layer, &acc, true);
        let base = SearchDriver {
            objective: Objective::Energy,
            budget,
            threads: 1,
            prune: false,
            deadline: None,
        }
        .search(&layer, &acc, &odometer, &[])
        .unwrap();
        let lattice = BoundedLattice::new(&layer, &acc, true);
        let driver = SearchDriver {
            objective: Objective::Energy,
            budget,
            threads: 1,
            prune: true,
            deadline: None,
        };
        let (bnb, _certified) =
            driver.branch_and_bound(&layer, &acc, &lattice, std::slice::from_ref(&base.mapping));
        let bnb = bnb.unwrap();
        assert_eq!(bnb.mapping, base.mapping, "{}", acc.name);
        assert_eq!(bnb.score.to_bits(), base.score.to_bits(), "{}", acc.name);
        assert_eq!(bnb.index, base.index, "{}", acc.name);
        // Oracle seed adds exactly one examined candidate on top of the
        // examined-or-pruned partition of the in-budget range.
        assert_eq!(bnb.examined + bnb.pruned, base.examined + 1, "{}", acc.name);
        assert!(
            bnb.examined * 10 <= base.examined,
            "{}: B&B examined {} of {} (> 10%)",
            acc.name,
            bnb.examined,
            base.examined
        );
    }
}

#[test]
fn prop_certified_bnb_is_provably_optimal_on_a_covered_space() {
    // When the budget covers the whole lattice, branch-and-bound reports
    // `certified` and its argmin equals the full unpruned enumeration's —
    // at every thread count.
    let acc = Accelerator {
        name: "prop-bnb".into(),
        style: Style::NvdlaLike,
        datawidth_bits: 16,
        levels: vec![
            StorageLevel::register_file("RF", 64, 16),
            StorageLevel::buffer("GLB", 1024, 64),
            StorageLevel::dram(64),
        ],
        pe: PeArray::new(4, 4),
        noc: Noc::default(),
        mac_energy_pj: 1.0,
        clock_mhz: 200.0,
    };
    let layer = ConvLayer::new("prop-bnb-tiny", 4, 2, 1, 1, 4, 2);
    let space = lattice_subtree_blocks(&layer, &acc, 0) * 7;
    let odometer = OdometerSource::new(&layer, &acc, true);
    let base = SearchDriver {
        objective: Objective::Energy,
        budget: space,
        threads: 1,
        prune: false,
        deadline: None,
    }
    .search(&layer, &acc, &odometer, &[])
    .unwrap();
    assert_eq!(base.examined, space, "baseline must enumerate the whole space");
    let lattice = BoundedLattice::new(&layer, &acc, true);
    for threads in [1usize, 2, 4, 8] {
        let driver = SearchDriver {
            objective: Objective::Energy,
            budget: space,
            threads,
            prune: true,
            deadline: None,
        };
        let (bnb, certified) = driver.branch_and_bound(&layer, &acc, &lattice, &[]);
        let bnb = bnb.unwrap();
        assert!(certified, "t={threads}: full-space budget must certify");
        assert_eq!(bnb.mapping, base.mapping, "t={threads}");
        assert_eq!(bnb.score.to_bits(), base.score.to_bits(), "t={threads}");
        assert_eq!(bnb.index, base.index, "t={threads}");
        assert_eq!(bnb.examined + bnb.pruned, space, "t={threads}");
        assert!(bnb.pruned > 0, "t={threads}");
    }
}

#[test]
fn prop_adapted_seeds_are_always_valid() {
    // The warm-start adapter's contract (DESIGN.md §15): adapting a valid
    // neighbor mapping onto any same-op layer yields a mapping that
    // validates on the target, or None — never an invalid seed. Swept
    // across every operator kind, random same-op (source, target) pairs,
    // and both LOCAL and random source mappings.
    use local_mapper::coordinator::adapt_mapping;
    use local_mapper::mapspace::sample_random as sample;
    let mut rng = SplitMix64::new(0x5EED5);
    let acc = presets::eyeriss();
    for op in OpKind::ALL {
        let mut adapted_some = 0;
        for trial in 0..25 {
            let src = random_op_layer(op, &mut rng);
            let dst = random_op_layer(op, &mut rng);
            let neighbor = if trial % 2 == 0 {
                LocalMapper::new().map(&src, &acc).unwrap()
            } else {
                sample(&src, &acc, &mut rng)
            };
            if let Some(seed) = adapt_mapping(&neighbor, &dst, &acc) {
                adapted_some += 1;
                seed.validate(&dst, &acc).unwrap_or_else(|e| {
                    panic!("invalid adapted seed on {op}: {src} -> {dst}: {e}")
                });
            }
        }
        assert!(adapted_some > 0, "{op}: adaptation never succeeded — the sweep is vacuous");
    }
}

#[test]
fn prop_exhaustive_seeding_never_changes_the_mapping() {
    // Seeds are bound-only for exhaustive search: for any valid seed — the
    // eventual argmin, a LOCAL mapping, or a random one — the seeded run
    // returns the bit-identical (mapping, score) as unseeded and never
    // examines more candidates.
    let mut rng = SplitMix64::new(0x1DE17);
    let acc = presets::eyeriss();
    for layer in [zoo::vgg02()[4].clone(), zoo::bert_base()[0].clone()] {
        let ex = ExhaustiveMapper::new(3_000).with_permutations();
        let base = ex.run(&layer, &acc).unwrap();
        let seeds = [
            base.mapping.clone(),
            LocalMapper::new().map(&layer, &acc).unwrap(),
            sample_random(&layer, &acc, &mut rng),
        ];
        for (i, seed) in seeds.iter().enumerate() {
            let out = ex.run_seeded(&layer, &acc, std::slice::from_ref(seed)).unwrap();
            assert_eq!(out.mapping, base.mapping, "{} seed {i}", layer.name);
            assert_eq!(out.score.to_bits(), base.score.to_bits(), "{} seed {i}", layer.name);
            assert!(
                out.evaluations <= base.evaluations,
                "{} seed {i}: seeded examined {} > unseeded {}",
                layer.name,
                out.evaluations,
                base.evaluations
            );
        }
        // All three seeds at once behave the same as the tightest alone.
        let out = ex.run_seeded(&layer, &acc, &seeds).unwrap();
        assert_eq!(out.mapping, base.mapping, "{} all seeds", layer.name);
        assert_eq!(out.score.to_bits(), base.score.to_bits(), "{} all seeds", layer.name);
    }
}

#[test]
fn prop_heuristic_seeding_never_worsens_the_score() {
    // Heuristic mappers merge seeds into the *result only*: for every
    // seeding-capable stochastic mapper, the seeded score is never worse
    // than the unseeded score on the same (layer, budget, rng seed) — and
    // when the seed itself beats the search, the seed wins outright.
    use local_mapper::mappers::{AnnealingMapper, GeneticMapper, LocalRefined};
    let mut rng = SplitMix64::new(0xC0DE5);
    let acc = presets::eyeriss();
    for layer in [zoo::vgg02()[4].clone(), zoo::vgg16()[8].clone()] {
        let seeds =
            [LocalMapper::new().map(&layer, &acc).unwrap(), sample_random(&layer, &acc, &mut rng)];
        let mappers: Vec<(&str, Box<dyn Mapper>)> = vec![
            ("random", Box::new(RandomMapper::new(200, 7))),
            ("rs-search", Box::new(ConstrainedSearch::new(Dataflow::RowStationary, 200, 7))),
            ("annealing", Box::new(AnnealingMapper::new(200, 7))),
            ("ga", Box::new(GeneticMapper::new(16, 5, 7))),
            ("refine", Box::new(LocalRefined::new(200, 7))),
        ];
        for (name, mapper) in &mappers {
            assert!(mapper.accepts_seeds(), "{name} should accept seeds");
            let base = mapper.run(&layer, &acc).unwrap();
            let out = mapper.run_seeded(&layer, &acc, &seeds).unwrap();
            assert!(
                out.score <= base.score,
                "{name} on {}: seeded {} > unseeded {}",
                layer.name,
                out.score,
                base.score
            );
        }
    }
}

#[test]
fn prop_persist_roundtrip_is_bit_identical_over_the_zoo() {
    // The disk log's contract (DESIGN.md §16): every clean outcome
    // appended for the full zoo × all three presets survives a reopen
    // bit-identically — same mapping, same score bits, same evaluation
    // count — and a load replays only records of its own accelerator
    // fingerprint and namespace.
    use local_mapper::coordinator::PersistentCache;
    use local_mapper::mappers::MapOutcome;
    use std::collections::HashMap;
    let dir = std::env::temp_dir().join(format!("lm_prop_persist_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let log = PersistentCache::open(&dir).unwrap().with_namespace("prop|LOCAL");
    let mut expected: HashMap<String, HashMap<_, MapOutcome>> = HashMap::new();
    let mut n_layers = 0usize;
    for acc in presets::all() {
        n_layers = 0;
        let per_acc = expected.entry(acc.name.clone()).or_default();
        for (_, layers) in zoo::batch_zoo() {
            for layer in &layers {
                n_layers += 1;
                let out = LocalMapper::new().run(layer, &acc).unwrap();
                log.append(layer, &out, &acc).unwrap();
                let key = layer_key(layer, &acc).for_objective(out.objective);
                // First record wins on reload; LOCAL is deterministic so
                // duplicates carry the same mapping anyway.
                per_acc.entry(key).or_insert(out);
            }
        }
    }
    for acc in presets::all() {
        // A fresh handle — a process restart — replays exactly the
        // per-accelerator subset, bit for bit.
        let reopened = PersistentCache::open(&dir).unwrap().with_namespace("prop|LOCAL");
        let report = reopened.load(&acc);
        let per_acc = &expected[&acc.name];
        assert_eq!(report.truncated_bytes, 0, "{}: clean log must not truncate", acc.name);
        assert_eq!(report.records, n_layers, "{}: every record must replay", acc.name);
        assert_eq!(report.skipped, 2 * n_layers, "{}: other presets' records skip", acc.name);
        assert_eq!(report.entries.len(), per_acc.len(), "{}: unique keys", acc.name);
        for (key, out) in &report.entries {
            let want = per_acc.get(key).unwrap_or_else(|| panic!("{}: alien key", acc.name));
            assert_eq!(out.mapping, want.mapping, "{}: mapping drifted", acc.name);
            assert_eq!(out.score.to_bits(), want.score.to_bits(), "{}: score bits", acc.name);
            assert_eq!(out.evaluations, want.evaluations, "{}: evaluation count", acc.name);
            assert_eq!(out.certified, want.certified, "{}: certified flag", acc.name);
            assert_eq!(
                out.evaluation.energy.total_pj().to_bits(),
                want.evaluation.energy.total_pj().to_bits(),
                "{}: energy bits",
                acc.name
            );
        }
    }
    // A different producer namespace sees none of it.
    let stranger = PersistentCache::open(&dir).unwrap().with_namespace("prop|other");
    let report = stranger.load(&presets::eyeriss());
    assert_eq!(report.entries.len(), 0, "namespaces must not bleed");
    assert_eq!(report.records, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_graph_modes_never_perturb_the_compiled_zoo() {
    // Graph analysis is a reporting layer (DESIGN.md §17): for every zoo
    // network, `--graph-mode off|fuse|co_select` must produce identical
    // per-layer mappings and score bits — `off` IS the flat pipeline and
    // the other modes only annotate it. Savings, when any, must account
    // exactly against the off baseline.
    use local_mapper::api::{CompileRequest, GraphMode, Session};
    let session = Session::new();
    for (net, _) in zoo::batch_zoo() {
        let base = session
            .compile(&CompileRequest::new().network(&net).graph_mode(GraphMode::Off))
            .unwrap();
        assert_eq!(base.graph.groups, 0, "{net}: off must not form groups");
        assert_eq!(base.graph.dram_bytes_saved, 0, "{net}: off must not claim savings");
        for mode in [GraphMode::Fuse, GraphMode::CoSelect] {
            let out = session
                .compile(&CompileRequest::new().network(&net).graph_mode(mode))
                .unwrap();
            let a = &base.networks[0].layers;
            let b = &out.networks[0].layers;
            assert_eq!(a.len(), b.len(), "{net} {mode:?}");
            for (x, y) in a.iter().zip(b) {
                assert_eq!(
                    x.outcome.mapping, y.outcome.mapping,
                    "{net}/{} perturbed under {mode:?}",
                    x.layer.name
                );
                assert_eq!(
                    x.outcome.score.to_bits(),
                    y.outcome.score.to_bits(),
                    "{net}/{} score bits drifted under {mode:?}",
                    x.layer.name
                );
            }
            assert_eq!(
                out.graph.cross_layer_dram_bytes + out.graph.dram_bytes_saved,
                base.graph.cross_layer_dram_bytes,
                "{net} {mode:?}: savings must account against the off baseline"
            );
        }
    }
}

#[test]
fn prop_fused_group_mappings_validate_on_every_member() {
    // Fusion correctness: every group the pass forms over the zoo keeps
    // per-member mappings that still validate against the member layers
    // (coverage, capacity and the per-op relevance projections of PR 3),
    // and every consecutive producer→consumer pair independently passes
    // the full `fusable` legality check.
    use local_mapper::graph::{fusable, fuse_network, WorkloadGraph};
    let mut formed = 0usize;
    for acc in presets::all() {
        for (net, _) in zoo::batch_zoo() {
            let g = WorkloadGraph::zoo(&net).unwrap();
            for grp in fuse_network(&g, &acc) {
                formed += 1;
                assert!(grp.members.len() >= 2, "{net} on {}: degenerate group", acc.name);
                for pair in grp.members.windows(2) {
                    assert!(
                        fusable(&g.nodes[pair[0]], &g.nodes[pair[1]], &acc),
                        "{net} on {}: illegal edge inside a formed group",
                        acc.name
                    );
                }
                for layer in grp.layers(&g) {
                    let out = LocalMapper::new().run(layer, &acc).unwrap_or_else(|e| {
                        panic!("{net}/{} on {}: member unmappable: {e}", layer.name, acc.name)
                    });
                    out.mapping.validate(layer, &acc).unwrap_or_else(|e| {
                        panic!("{net}/{} on {}: member mapping invalid: {e}", layer.name, acc.name)
                    });
                }
            }
        }
    }
    assert!(formed > 0, "the sweep never formed a group — fusion is vacuous");
}

#[test]
fn prop_dim_coverage_under_mutation_stress() {
    // Hammer the mapping with random factor migrations + repairs; coverage
    // (Π factors == bound) must never break.
    let mut rng = SplitMix64::new(0xCE11);
    let layer = random_layer(&mut rng);
    let acc = random_acc(&mut rng);
    let mut m = sample_random(&layer, &acc, &mut rng);
    for _ in 0..500 {
        // Random legal migration: top-level temporal → L0.
        let d = rng.index(7);
        let top = m.n_levels() - 1;
        if m.temporal[top][d] % 2 == 0 {
            m.temporal[top][d] /= 2;
            m.temporal[0][d] *= 2;
        }
        repair(&layer, &acc, &mut m);
        for dim in Dim::ALL {
            assert_eq!(m.extent(dim), layer.bound(dim), "dim {dim} broke");
        }
    }
}
