//! Runtime end-to-end tests: load the AOT artifacts and execute them via
//! PJRT, verifying numerics against the host conv oracle.
//!
//! These tests need `make artifacts` to have run; they self-skip (with a
//! loud message) when the artifacts directory is absent so `cargo test`
//! stays green on a fresh checkout.

use local_mapper::runtime::{read_manifest, reference_conv, reference_depthwise, Runtime};
use local_mapper::util::rng::SplitMix64;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var_os("LOCAL_MAPPER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.yaml").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP runtime_e2e: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

fn random_input(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| (rng.next_f64() as f32) - 0.5).collect()
}

#[test]
fn manifest_lists_all_expected_kernels() {
    let Some(dir) = artifacts_dir() else { return };
    let entries = read_manifest(&dir.join("manifest.yaml")).unwrap();
    let names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
    for expect in ["conv_quickstart", "conv_high_c", "conv_high_m", "conv_high_pq", "conv_batched"] {
        assert!(names.contains(&expect), "missing {expect} in {names:?}");
    }
}

#[test]
fn all_artifacts_execute_and_match_host_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    let names = rt.load_manifest_dir(&dir).unwrap();
    for name in names {
        let k = rt.kernel(&name).unwrap();
        let inputs: Vec<Vec<f32>> = k
            .input_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| random_input(s.iter().product::<i64>() as usize, 10 + i as u64))
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = k.execute_f32(&refs).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(out.len(), k.output_len(), "{name}: output length");

        let (si, sw) = (&k.input_shapes[0], &k.input_shapes[1]);
        let expect = if sw.len() == 3 {
            // Depthwise artifact: weights are (C, R, S).
            reference_depthwise(
                &inputs[0],
                &inputs[1],
                si[0] as usize,
                si[1] as usize,
                si[2] as usize,
                si[3] as usize,
                sw[1] as usize,
                sw[2] as usize,
                1,
            )
        } else {
            reference_conv(
                &inputs[0],
                &inputs[1],
                si[0] as usize,
                si[1] as usize,
                si[2] as usize,
                si[3] as usize,
                sw[0] as usize,
                sw[2] as usize,
                sw[3] as usize,
                1,
            )
        };
        let max_err = out.iter().zip(&expect).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_err < 1e-3, "{name}: max err {max_err}");
    }
}

#[test]
fn execution_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    rt.load_manifest_dir(&dir).unwrap();
    let k = rt.kernel("conv_quickstart").unwrap();
    let inputs: Vec<Vec<f32>> = k
        .input_shapes
        .iter()
        .map(|s| random_input(s.iter().product::<i64>() as usize, 77))
        .collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let a = k.execute_f32(&refs).unwrap();
    let b = k.execute_f32(&refs).unwrap();
    assert_eq!(a, b);
}

#[test]
fn wrong_arity_and_shape_are_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    rt.load_manifest_dir(&dir).unwrap();
    let k = rt.kernel("conv_quickstart").unwrap();
    // Wrong arity.
    let one = vec![0f32; 8];
    assert!(k.execute_f32(&[&one]).is_err());
    // Wrong element count.
    let bad = vec![0f32; 17];
    let w = vec![0f32; k.input_shapes[1].iter().product::<i64>() as usize];
    assert!(k.execute_f32(&[&bad, &w]).is_err());
}

#[test]
fn unknown_kernel_is_an_error() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    rt.load_manifest_dir(&dir).unwrap();
    assert!(rt.kernel("nope").is_err());
}
