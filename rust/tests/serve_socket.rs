//! End-to-end tests for the `serve` daemon: a real Unix socket, real
//! length-prefixed frames, real client connections (DESIGN.md §16).
//!
//! The tests cover the three service-layer contracts:
//! * one shared session across connections — the second compile of a
//!   network is 100% cached no matter which connection sends it;
//! * warm restart — a *new* daemon over the same `--cache-dir` serves
//!   every layer from the disk log (`disk_hits` == layers);
//! * backpressure — past the admission high-water mark a request gets a
//!   typed `E_BUSY` error document instead of queueing.

use local_mapper::api::json::{parse, Json};
use local_mapper::api::serve::{spawn, ServeConfig};
use std::io::{Read, Write};
use std::os::unix::net::UnixStream;

/// Unique per-test scratch paths (the tests run concurrently in one
/// process, so the socket and cache dir carry the test tag and the pid).
fn scratch(tag: &str) -> (String, String) {
    let base = std::env::temp_dir().join(format!("lm_serve_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).unwrap();
    (
        base.join("daemon.sock").to_str().unwrap().to_string(),
        base.join("cache").to_str().unwrap().to_string(),
    )
}

/// One request/reply round trip on a fresh connection.
fn request(socket: &str, payload: &str) -> String {
    let mut s = UnixStream::connect(socket).expect("daemon socket accepts");
    s.write_all(&(payload.len() as u32).to_be_bytes()).unwrap();
    s.write_all(payload.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut header = [0u8; 4];
    s.read_exact(&mut header).unwrap();
    let mut buf = vec![0u8; u32::from_be_bytes(header) as usize];
    s.read_exact(&mut buf).unwrap();
    String::from_utf8(buf).unwrap()
}

/// The `cached` flags of a compile document's first network.
fn cached_flags(doc: &Json) -> Vec<bool> {
    doc.get("networks").unwrap().as_arr().unwrap()[0]
        .get("layers")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|l| l.get("cached").unwrap().as_bool().unwrap())
        .collect()
}

/// The value of one `local_mapper_<name> <value>` metrics line.
fn metric(text: &str, name: &str) -> f64 {
    let prefix = format!("local_mapper_{name} ");
    text.lines()
        .find_map(|l| l.strip_prefix(&prefix))
        .unwrap_or_else(|| panic!("metrics missing {name}:\n{text}"))
        .trim()
        .parse()
        .unwrap()
}

const COMPILE: &str = "{\"verb\": \"compile\", \"network\": \"alexnet\", \"threads\": 1}";

#[test]
fn daemon_shares_one_cache_across_connections_and_restarts_warm() {
    let (socket, cache) = scratch("warm");

    // Daemon A, cold: the first compile searches, the second — on a brand
    // new connection — is 100% cached from the shared session.
    let a = spawn(ServeConfig {
        socket: socket.clone(),
        cache_dir: Some(cache.clone()),
        ..ServeConfig::default()
    })
    .expect("daemon A binds");
    let cold = parse(&request(&socket, COMPILE)).expect("cold compile doc parses");
    assert_eq!(cold.get("kind").and_then(Json::as_str), Some("compile"));
    assert!(cached_flags(&cold).iter().all(|&c| !c), "cold run must search");
    let second = parse(&request(&socket, COMPILE)).expect("second compile doc parses");
    assert!(cached_flags(&second).iter().all(|&c| c), "cross-connection cache miss");
    let m = request(&socket, "{\"verb\": \"metrics\"}");
    assert_eq!(metric(&m, "requests_total"), 10.0, "{m}");
    assert_eq!(metric(&m, "cache_hits_total"), 5.0, "{m}");
    assert_eq!(metric(&m, "disk_hits_total"), 0.0, "nothing was on disk yet: {m}");
    assert_eq!(metric(&m, "queue_depth"), 0.0, "{m}");
    a.stop();

    // Daemon B over the same cache dir: a *process restart*. Every layer
    // is served from the preloaded disk log — zero evaluations re-spent —
    // and the lifetime totals span both daemons.
    let b = spawn(ServeConfig {
        socket: socket.clone(),
        cache_dir: Some(cache),
        ..ServeConfig::default()
    })
    .expect("daemon B binds");
    let warm = parse(&request(&socket, COMPILE)).expect("warm compile doc parses");
    assert!(cached_flags(&warm).iter().all(|&c| c), "warm restart re-searched");
    let m = request(&socket, "{\"verb\": \"metrics\"}");
    assert_eq!(metric(&m, "disk_hits_total"), 5.0, "{m}");
    assert_eq!(metric(&m, "lifetime_requests_total"), 10.0, "daemon A's totals: {m}");
    b.stop();
}

#[test]
fn zero_queue_limit_rejects_with_typed_busy() {
    let (socket, _) = scratch("busy");
    let h = spawn(ServeConfig { socket: socket.clone(), queue_limit: 0, ..ServeConfig::default() })
        .expect("daemon binds");
    let doc = parse(&request(&socket, COMPILE)).expect("busy doc parses");
    assert_eq!(doc.get("kind").and_then(Json::as_str), Some("error"));
    assert_eq!(doc.get("code").and_then(Json::as_str), Some("E_BUSY"));
    assert!(doc.get("queue_depth").and_then(Json::as_u64).is_some());
    // Metrics still answer — backpressure applies to compiles only.
    let m = request(&socket, "{\"verb\": \"metrics\"}");
    assert_eq!(metric(&m, "requests_total"), 0.0, "{m}");
    h.stop();
}

#[test]
fn malformed_frames_get_typed_error_documents() {
    let (socket, _) = scratch("err");
    let h = spawn(ServeConfig { socket: socket.clone(), ..ServeConfig::default() })
        .expect("daemon binds");
    let doc = parse(&request(&socket, "{\"verb\": \"frobnicate\"}")).unwrap();
    assert_eq!(doc.get("code").and_then(Json::as_str), Some("E_REQUEST"));
    let doc = parse(&request(&socket, "not json")).unwrap();
    assert_eq!(doc.get("code").and_then(Json::as_str), Some("E_JSON"));
    let doc = parse(&request(&socket, "{\"network\": \"hal9000\"}")).unwrap();
    assert_eq!(doc.get("kind").and_then(Json::as_str), Some("error"));
    assert_eq!(doc.get("code").and_then(Json::as_str), Some("E_REQUEST"));
    h.stop();
}
