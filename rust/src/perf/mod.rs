//! The performance harness behind `BENCH_eval.json`.
//!
//! The paper's entire argument is compile-time speed, so the repo tracks
//! its own "evaluations/second" denominator as a machine-readable
//! artifact. [`run`] measures five things:
//!
//! 1. **Evaluator throughput** — the legacy allocating
//!    [`crate::model::evaluate_unchecked`] vs the zero-allocation
//!    [`EvalContext::evaluate_into`] hot path, over the same pre-sampled
//!    candidate pool (VGG-16 conv9 × Eyeriss).
//! 2. **Per-operator throughput** — context-path evaluations/second for a
//!    representative layer of each [`crate::workload::OpKind`] (conv vs
//!    matmul vs pooling vs elementwise), so operator-IR regressions show
//!    up per projection, not just on conv.
//! 3. **Exhaustive scaling** — sharded parallel enumeration throughput at
//!    1/2/4/8 threads on a small fixed layer (pruning and warm-start off,
//!    so every thread count does identical work).
//! 4. **Search engine** (schema 3) — the [`crate::mappers::engine`]
//!    numbers: pruned-vs-unpruned evaluations and wall time for the
//!    mappers with pruning on by default (exhaustive, RS-search), plus
//!    thread scaling for the newly parallel random and constrained
//!    searches.
//! 5. **Branch-and-bound** (schema 4) — certified lattice search
//!    ([`crate::mappers::engine::BoundedLattice`]) against the unpruned
//!    odometer baseline: one VGG-16 conv9 case per preset under the
//!    oracle-incumbent protocol (the baseline's argmin seeds the B&B, so
//!    the numbers isolate the pruning power of the partial bound), plus
//!    one small space the budget fully covers (`certified: true`).
//! 6. **Warm starts** (schema 5) — the same corpus compiled through a
//!    single-worker shared-cache service with seeding off, then with the
//!    similarity-driven adapt policy (DESIGN.md §15). The exhaustive arm
//!    pins the bit-identity contract (the seed is bound-only, so the
//!    argmin cannot move) while cutting evaluations; the random arm shows
//!    the heuristic side (final score never worse than unseeded).
//! 7. **Zoo batch wall time** — [`crate::coordinator::compile_batch`] over
//!    the operator-diverse zoo through the shared-cache service.
//! 8. **Service restart** (schema 6) — the zoo compiled cold into an empty
//!    persistent cache directory, then again through a *fresh* service
//!    over the same directory (a simulated process restart,
//!    DESIGN.md §16): the warm run must spend zero mapper evaluations,
//!    serving every layer from the preloaded disk log.
//! 9. **Graph fusion** (schema 7) — [`crate::graph::analyze`] in off vs
//!    fuse mode on the two multi-predecessor zoo networks
//!    (mobilenetv2res, bert): fused cross-layer DRAM bytes must come in
//!    strictly below unfused (DESIGN.md §17).
//!
//! [`PerfReport::to_json`] renders the result as the `BENCH_eval.json`
//! schema (see the README "Performance" section); the `perf` CLI
//! subcommand and the `perf_analyzer` bench both write it so every PR can
//! track the trajectory. Smoke mode (`PerfConfig::smoke`) bounds the
//! iteration counts for CI.

use crate::arch::{presets, Accelerator, Noc, PeArray, StorageLevel, Style};
use crate::coordinator::{
    compile_batch, compile_batch_persistent, compile_batch_with_policy, BatchPlan,
    PersistentCache, SeedPolicy,
};
use crate::mappers::engine::{BoundedLattice, OdometerSource, SearchDriver};
use crate::mappers::{
    ConstrainedSearch, ExhaustiveMapper, LocalMapper, Mapper, Objective, RandomMapper,
};
use crate::mapping::Mapping;
use crate::mapspace::{sample_random, Dataflow};
use crate::model::{evaluate_unchecked, EvalContext};
use crate::util::bench::median_time;
use crate::util::rng::SplitMix64;
use crate::workload::{zoo, Layer};
use std::time::Instant;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct PerfConfig {
    /// Bound every measurement for CI smoke runs (seconds, not minutes).
    pub smoke: bool,
}

impl PerfConfig {
    /// Full-fidelity run (the `perf_analyzer` bench default).
    pub fn full() -> Self {
        Self { smoke: false }
    }

    /// Bounded-iteration run (the CI `bench-json` target).
    pub fn smoke() -> Self {
        Self { smoke: true }
    }
}

/// Old-vs-new evaluator throughput.
#[derive(Debug, Clone)]
pub struct EvalThroughput {
    /// Legacy allocating `evaluate_unchecked`, evaluations per second.
    pub legacy_evals_per_sec: f64,
    /// `EvalContext::evaluate_into`, evaluations per second.
    pub context_evals_per_sec: f64,
}

impl EvalThroughput {
    /// Context-path speedup over the legacy path.
    pub fn speedup(&self) -> f64 {
        self.context_evals_per_sec / self.legacy_evals_per_sec.max(f64::MIN_POSITIVE)
    }
}

/// Context-path throughput for one representative layer of an operator
/// kind.
#[derive(Debug, Clone)]
pub struct OpThroughput {
    /// Operator-kind name (`conv` / `matmul` / `pool` / `add`).
    pub op: &'static str,
    /// `EvalContext::evaluate_into` evaluations per second on the
    /// representative layer.
    pub evals_per_sec: f64,
}

/// One exhaustive-scaling data point.
#[derive(Debug, Clone)]
pub struct ExhaustivePoint {
    /// Worker threads the enumeration was sharded across.
    pub threads: usize,
    /// Wall-clock of the whole enumeration, ms.
    pub wall_ms: f64,
    /// Candidate evaluations per second (including invalid candidates,
    /// matching the mapper's own accounting).
    pub evals_per_sec: f64,
}

/// Pruned-vs-unpruned cost of one mapper whose pruning is on by default.
#[derive(Debug, Clone)]
pub struct PruneStat {
    /// Mapper name (`exhaustive` / `rs-search`).
    pub mapper: &'static str,
    /// Candidate evaluations without pruning (the full budgeted set).
    pub evals_unpruned: u64,
    /// Candidate evaluations with pruning (bound-skipped blocks excluded).
    pub evals_pruned: u64,
    /// Wall-clock of the unpruned search, ms.
    pub wall_ms_unpruned: f64,
    /// Wall-clock of the pruned search, ms.
    pub wall_ms_pruned: f64,
}

impl PruneStat {
    /// Evaluation-count cut factor (unpruned / pruned).
    pub fn cut(&self) -> f64 {
        self.evals_unpruned as f64 / self.evals_pruned.max(1) as f64
    }
}

/// Thread-scaling point for one newly parallel search mapper.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    /// Mapper name (`random` / `rs-search`).
    pub mapper: &'static str,
    /// Worker threads the indexed stream was sharded across.
    pub threads: usize,
    /// Wall-clock of the whole search, ms.
    pub wall_ms: f64,
}

/// One branch-and-bound case of the schema-4 `bound_search` section:
/// certified lattice search vs the unpruned odometer baseline over the
/// identical budgeted candidate range.
#[derive(Debug, Clone)]
pub struct BoundCase {
    /// Layer name.
    pub layer: String,
    /// Accelerator preset the case ran on.
    pub arch: &'static str,
    /// Evaluation budget both searches were capped at.
    pub budget: u64,
    /// Candidates the unpruned exhaustive baseline examined.
    pub evals_unpruned: u64,
    /// Candidates the branch-and-bound search examined (including its
    /// warm-start seed, when one was given).
    pub evals_bnb: u64,
    /// Candidates branch-and-bound pruned without materializing.
    pub pruned: u64,
    /// Whether the budget provably covered the whole candidate space, so
    /// the argmin is the certified optimum.
    pub certified: bool,
    /// Wall-clock of the unpruned baseline, ms.
    pub wall_ms_unpruned: f64,
    /// Wall-clock of the branch-and-bound search, ms.
    pub wall_ms_bnb: f64,
}

impl BoundCase {
    /// Evaluation-count cut factor (unpruned / branch-and-bound).
    pub fn cut(&self) -> f64 {
        self.evals_unpruned as f64 / self.evals_bnb.max(1) as f64
    }
}

/// The schema-3 `search` section: engine pruning and thread scaling.
#[derive(Debug, Clone)]
pub struct SearchSection {
    /// Pruned-vs-unpruned evaluations/wall per default-pruned mapper.
    pub pruning: Vec<PruneStat>,
    /// Thread scaling for the newly parallel mappers (fixed work:
    /// pruning off).
    pub scaling: Vec<ScalePoint>,
}

/// One seeded-vs-unseeded case of the schema-5 `warm_start` section: the
/// same network compiled through a single-worker shared-cache service with
/// [`SeedPolicy::Off`], then [`SeedPolicy::Adapt`].
#[derive(Debug, Clone)]
pub struct WarmCase {
    /// Mapper name (`exhaustive` / `random`).
    pub mapper: &'static str,
    /// Corpus network.
    pub network: &'static str,
    /// Layers in the corpus.
    pub layers: usize,
    /// Cache misses the adapt run seeded from a similar shape.
    pub warm_seeded: u64,
    /// Mean seed-hit quality of the adapt run (final score / seed score).
    pub seed_quality: f64,
    /// Candidate evaluations over all cache misses, seeding off.
    pub evals_unseeded: u64,
    /// Candidate evaluations over all cache misses, adapt seeding on.
    pub evals_seeded: u64,
    /// Wall-clock of the unseeded batch, ms.
    pub wall_ms_unseeded: f64,
    /// Wall-clock of the seeded batch, ms.
    pub wall_ms_seeded: f64,
    /// Corpus energy with seeding off, µJ.
    pub energy_unseeded_uj: f64,
    /// Corpus energy with adapt seeding on, µJ (never worse than
    /// unseeded — seeds only tighten bounds or join the result merge).
    pub energy_seeded_uj: f64,
    /// Whether every final mapping is bit-identical seeded vs unseeded
    /// (the exhaustive contract; heuristics may legitimately improve).
    pub identical: bool,
}

impl WarmCase {
    /// Evaluation-count cut factor (unseeded / seeded).
    pub fn cut(&self) -> f64 {
        self.evals_unseeded as f64 / self.evals_seeded.max(1) as f64
    }
}

/// Batch-pipeline measurement over the five-network zoo.
#[derive(Debug, Clone)]
pub struct ZooBatch {
    /// Networks compiled.
    pub networks: usize,
    /// Layers compiled across all networks.
    pub layers: usize,
    /// Wall-clock of the whole batch, ms.
    pub wall_ms: f64,
    /// Cross-network cache hit rate in `[0, 1]`.
    pub cache_hit_rate: f64,
}

/// The schema-6 `service` section: the zoo through the persistent disk
/// cache, cold (empty directory) vs warm restart (fresh service, same
/// directory) — the amortized-cold-start numbers (DESIGN.md §16).
#[derive(Debug, Clone)]
pub struct ServiceSection {
    /// Layers compiled in each run (the full zoo).
    pub layers: usize,
    /// Wall-clock of the cold run into the empty cache dir, ms.
    pub cold_wall_ms: f64,
    /// Wall-clock of the warm-restart run (fresh service, same dir), ms.
    pub warm_wall_ms: f64,
    /// Mapper evaluations spent on cache misses in the cold run.
    pub cold_evaluations: u64,
    /// Mapper evaluations spent on cache misses in the warm run — the
    /// warm-restart contract pins this to 0.
    pub warm_evaluations: u64,
    /// Warm-run cache hits served from entries preloaded off disk.
    pub disk_hits: u64,
    /// Requests that coalesced onto an identical in-flight search, summed
    /// over both runs.
    pub coalesced: u64,
}

/// One network's fused-vs-unfused cross-layer DRAM numbers: the schema-7
/// `graph` section (DESIGN.md §17), measured by running the graph
/// analysis in `off` and `fuse` modes over the same zoo network.
#[derive(Debug, Clone)]
pub struct GraphPerf {
    /// Network name (`mobilenetv2res` / `bert`).
    pub network: &'static str,
    /// Fused groups the pass formed.
    pub groups: usize,
    /// Layers captured in a fused group.
    pub fused_layers: usize,
    /// Cross-layer DRAM bytes with graph compilation off (the unfused
    /// baseline: every inter-layer tensor round-trips through DRAM).
    pub unfused_dram_bytes: u64,
    /// Cross-layer DRAM bytes under fusion (strictly lower whenever a
    /// group forms — CI validates this on `mobilenetv2res`).
    pub fused_dram_bytes: u64,
    /// Wall-clock of both analyses (graph build + fusion + accounting,
    /// twice), ms.
    pub wall_ms: f64,
}

/// Everything `BENCH_eval.json` carries.
#[derive(Debug, Clone)]
pub struct PerfReport {
    /// Schema version of the JSON layout.
    pub schema: u32,
    /// Whether this was a bounded smoke run.
    pub smoke: bool,
    /// Old-vs-new evaluator throughput.
    pub evaluator: EvalThroughput,
    /// Context-path throughput per operator kind.
    pub per_op: Vec<OpThroughput>,
    /// Exhaustive scaling at 1/2/4/8 threads.
    pub exhaustive: Vec<ExhaustivePoint>,
    /// Engine pruning + thread-scaling numbers (schema 3).
    pub search: SearchSection,
    /// Certified branch-and-bound vs unpruned exhaustive (schema 4).
    pub bound_search: Vec<BoundCase>,
    /// Similarity-driven warm starts, seeded vs unseeded (schema 5).
    pub warm_start: Vec<WarmCase>,
    /// Zoo batch-pipeline wall time.
    pub zoo_batch: ZooBatch,
    /// Persistent-cache cold vs warm-restart timings (schema 6).
    pub service: ServiceSection,
    /// Fused vs unfused cross-layer DRAM traffic per graph-capable zoo
    /// network (schema 7).
    pub graph: Vec<GraphPerf>,
}

/// Render a finite float for JSON (JSON has no NaN/Inf; rates here are
/// always finite, but belt and braces for a machine-parsed artifact).
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "0".to_string()
    }
}

impl PerfReport {
    /// The machine-readable `BENCH_eval.json` body (stable key set; CI
    /// fails the build if it does not parse or a rate reads as zero).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": {},\n", self.schema));
        s.push_str(&format!("  \"smoke\": {},\n", self.smoke));
        s.push_str(&format!(
            "  \"evaluator\": {{\"legacy_evals_per_sec\": {}, \"context_evals_per_sec\": {}, \"speedup\": {}}},\n",
            jnum(self.evaluator.legacy_evals_per_sec),
            jnum(self.evaluator.context_evals_per_sec),
            jnum(self.evaluator.speedup())
        ));
        s.push_str("  \"per_op\": [\n");
        for (i, p) in self.per_op.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"op\": \"{}\", \"evals_per_sec\": {}}}{}\n",
                p.op,
                jnum(p.evals_per_sec),
                if i + 1 < self.per_op.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"exhaustive\": [\n");
        for (i, p) in self.exhaustive.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"threads\": {}, \"wall_ms\": {}, \"evals_per_sec\": {}}}{}\n",
                p.threads,
                jnum(p.wall_ms),
                jnum(p.evals_per_sec),
                if i + 1 < self.exhaustive.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"search\": {\n");
        s.push_str("    \"pruning\": [\n");
        for (i, p) in self.search.pruning.iter().enumerate() {
            s.push_str(&format!(
                "      {{\"mapper\": \"{}\", \"evals_unpruned\": {}, \"evals_pruned\": {}, \"cut\": {}, \"wall_ms_unpruned\": {}, \"wall_ms_pruned\": {}}}{}\n",
                p.mapper,
                p.evals_unpruned,
                p.evals_pruned,
                jnum(p.cut()),
                jnum(p.wall_ms_unpruned),
                jnum(p.wall_ms_pruned),
                if i + 1 < self.search.pruning.len() { "," } else { "" }
            ));
        }
        s.push_str("    ],\n");
        s.push_str("    \"scaling\": [\n");
        for (i, p) in self.search.scaling.iter().enumerate() {
            s.push_str(&format!(
                "      {{\"mapper\": \"{}\", \"threads\": {}, \"wall_ms\": {}}}{}\n",
                p.mapper,
                p.threads,
                jnum(p.wall_ms),
                if i + 1 < self.search.scaling.len() { "," } else { "" }
            ));
        }
        s.push_str("    ]\n");
        s.push_str("  },\n");
        s.push_str("  \"bound_search\": [\n");
        for (i, c) in self.bound_search.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"layer\": \"{}\", \"arch\": \"{}\", \"budget\": {}, \"evals_unpruned\": {}, \"evals_bnb\": {}, \"pruned\": {}, \"cut\": {}, \"certified\": {}, \"wall_ms_unpruned\": {}, \"wall_ms_bnb\": {}}}{}\n",
                c.layer,
                c.arch,
                c.budget,
                c.evals_unpruned,
                c.evals_bnb,
                c.pruned,
                jnum(c.cut()),
                c.certified,
                jnum(c.wall_ms_unpruned),
                jnum(c.wall_ms_bnb),
                if i + 1 < self.bound_search.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"warm_start\": [\n");
        for (i, w) in self.warm_start.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"mapper\": \"{}\", \"network\": \"{}\", \"layers\": {}, \"warm_seeded\": {}, \"seed_quality\": {}, \"evals_unseeded\": {}, \"evals_seeded\": {}, \"cut\": {}, \"wall_ms_unseeded\": {}, \"wall_ms_seeded\": {}, \"energy_unseeded_uj\": {}, \"energy_seeded_uj\": {}, \"identical\": {}}}{}\n",
                w.mapper,
                w.network,
                w.layers,
                w.warm_seeded,
                jnum(w.seed_quality),
                w.evals_unseeded,
                w.evals_seeded,
                jnum(w.cut()),
                jnum(w.wall_ms_unseeded),
                jnum(w.wall_ms_seeded),
                jnum(w.energy_unseeded_uj),
                jnum(w.energy_seeded_uj),
                w.identical,
                if i + 1 < self.warm_start.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!(
            "  \"zoo_batch\": {{\"networks\": {}, \"layers\": {}, \"wall_ms\": {}, \"cache_hit_rate\": {}}},\n",
            self.zoo_batch.networks,
            self.zoo_batch.layers,
            jnum(self.zoo_batch.wall_ms),
            jnum(self.zoo_batch.cache_hit_rate)
        ));
        s.push_str(&format!(
            "  \"service\": {{\"layers\": {}, \"cold_wall_ms\": {}, \"warm_wall_ms\": {}, \"cold_evaluations\": {}, \"warm_evaluations\": {}, \"disk_hits\": {}, \"coalesced\": {}}},\n",
            self.service.layers,
            jnum(self.service.cold_wall_ms),
            jnum(self.service.warm_wall_ms),
            self.service.cold_evaluations,
            self.service.warm_evaluations,
            self.service.disk_hits,
            self.service.coalesced
        ));
        s.push_str("  \"graph\": [\n");
        for (i, g) in self.graph.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"network\": \"{}\", \"groups\": {}, \"fused_layers\": {}, \"unfused_dram_bytes\": {}, \"fused_dram_bytes\": {}, \"wall_ms\": {}}}{}\n",
                g.network,
                g.groups,
                g.fused_layers,
                g.unfused_dram_bytes,
                g.fused_dram_bytes,
                jnum(g.wall_ms),
                if i + 1 < self.graph.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n");
        s.push_str("}\n");
        s
    }

    /// Human-readable one-screen summary (what the CLI and bench print).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "evaluator: legacy {:.0} evals/s → context {:.0} evals/s ({:.2}x)\n",
            self.evaluator.legacy_evals_per_sec,
            self.evaluator.context_evals_per_sec,
            self.evaluator.speedup()
        ));
        for p in &self.per_op {
            s.push_str(&format!("per-op {}: {:.0} evals/s\n", p.op, p.evals_per_sec));
        }
        for p in &self.exhaustive {
            s.push_str(&format!(
                "exhaustive {}T: {:.1} ms wall, {:.0} evals/s\n",
                p.threads, p.wall_ms, p.evals_per_sec
            ));
        }
        for p in &self.search.pruning {
            s.push_str(&format!(
                "prune {}: {} → {} evals ({:.2}x cut), {:.1} → {:.1} ms\n",
                p.mapper,
                p.evals_unpruned,
                p.evals_pruned,
                p.cut(),
                p.wall_ms_unpruned,
                p.wall_ms_pruned
            ));
        }
        for p in &self.search.scaling {
            s.push_str(&format!(
                "scale {} {}T: {:.1} ms wall\n",
                p.mapper, p.threads, p.wall_ms
            ));
        }
        for c in &self.bound_search {
            s.push_str(&format!(
                "bound {}@{}: {} → {} evals ({:.2}x cut{}), {:.1} → {:.1} ms\n",
                c.layer,
                c.arch,
                c.evals_unpruned,
                c.evals_bnb,
                c.cut(),
                if c.certified { ", certified" } else { "" },
                c.wall_ms_unpruned,
                c.wall_ms_bnb
            ));
        }
        for w in &self.warm_start {
            s.push_str(&format!(
                "warm {}@{}: seeded {} misses (quality {:.3}), {} → {} evals ({:.2}x cut{})\n",
                w.mapper,
                w.network,
                w.warm_seeded,
                w.seed_quality,
                w.evals_unseeded,
                w.evals_seeded,
                w.cut(),
                if w.identical { ", identical" } else { "" }
            ));
        }
        s.push_str(&format!(
            "zoo batch: {} networks, {} layers, {:.1} ms wall, {:.0}% cache hits\n",
            self.zoo_batch.networks,
            self.zoo_batch.layers,
            self.zoo_batch.wall_ms,
            self.zoo_batch.cache_hit_rate * 100.0
        ));
        s.push_str(&format!(
            "service restart: cold {:.1} ms ({} evals) → warm {:.1} ms ({} evals, {} disk hits)",
            self.service.cold_wall_ms,
            self.service.cold_evaluations,
            self.service.warm_wall_ms,
            self.service.warm_evaluations,
            self.service.disk_hits
        ));
        for g in &self.graph {
            s.push_str(&format!(
                "\ngraph {}: {} groups ({} layers), {} → {} cross-layer DRAM bytes",
                g.network, g.groups, g.fused_layers, g.unfused_dram_bytes, g.fused_dram_bytes
            ));
        }
        s
    }
}

/// Small 3-level machine for the exhaustive-scaling measurement (the
/// full-size presets' spaces are too large to enumerate meaningfully).
fn scaling_acc() -> Accelerator {
    Accelerator {
        name: "perf-small".into(),
        style: Style::NvdlaLike,
        datawidth_bits: 16,
        levels: vec![
            StorageLevel::register_file("RF", 64, 16),
            StorageLevel::buffer("GLB", 1024, 64),
            StorageLevel::dram(64),
        ],
        pe: PeArray::new(4, 4),
        noc: Noc::default(),
        mac_energy_pj: 1.0,
        clock_mhz: 200.0,
    }
}

/// Measure one `bound_search` case: the unpruned odometer baseline, then
/// branch-and-bound over the same budgeted range. With `oracle_seed` the
/// baseline's argmin warm-starts the B&B incumbent, so the cut factor
/// isolates the pruning power of the partial bound (seeding with the
/// eventual winner cannot change the argmin — an exact tie resolves to the
/// enumerated copy).
fn bound_case(
    arch: &'static str,
    layer: &Layer,
    acc: &Accelerator,
    budget: u64,
    oracle_seed: bool,
) -> BoundCase {
    let full = SearchDriver {
        objective: Objective::Energy,
        budget,
        threads: 1,
        prune: false,
        deadline: None,
    };
    let odometer = OdometerSource::new(layer, acc, true);
    let t0 = Instant::now();
    let base = full.search(layer, acc, &odometer, &[]).expect("unpruned search maps the layer");
    let wall_ms_unpruned = t0.elapsed().as_secs_f64() * 1e3;

    let lattice = BoundedLattice::new(layer, acc, true);
    let seeds = if oracle_seed { vec![base.mapping.clone()] } else { Vec::new() };
    let bnb_driver = SearchDriver { prune: true, ..full };
    let t0 = Instant::now();
    let (bnb, certified) = bnb_driver.branch_and_bound(layer, acc, &lattice, &seeds);
    let wall_ms_bnb = t0.elapsed().as_secs_f64() * 1e3;
    let bnb = bnb.expect("branch-and-bound maps the layer");
    assert_eq!(bnb.mapping, base.mapping, "B&B diverged from the unpruned argmin");
    assert_eq!(bnb.score.to_bits(), base.score.to_bits());
    BoundCase {
        layer: layer.name.clone(),
        arch,
        budget,
        evals_unpruned: base.examined,
        evals_bnb: bnb.examined,
        pruned: bnb.pruned,
        certified,
        wall_ms_unpruned,
        wall_ms_bnb,
    }
}

/// Measure one `warm_start` case: the same corpus compiled twice through a
/// single-worker shared-cache service — seeding off, then the adapt
/// policy. One worker keeps the miss order deterministic, so both runs map
/// the identical miss set and the comparison isolates seeding.
fn warm_case<M>(
    name: &'static str,
    network: &'static str,
    layers: &[Layer],
    acc: &Accelerator,
    mapper: &M,
) -> WarmCase
where
    M: Mapper + Clone + Send + 'static,
{
    let corpus = vec![(network.to_string(), layers.to_vec())];
    let t0 = Instant::now();
    let off = compile_batch_with_policy(&corpus, acc, mapper, 1, SeedPolicy::Off)
        .expect("unseeded warm-start corpus compiles");
    let wall_ms_unseeded = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    let adapt = compile_batch_with_policy(&corpus, acc, mapper, 1, SeedPolicy::Adapt)
        .expect("seeded warm-start corpus compiles");
    let wall_ms_seeded = t0.elapsed().as_secs_f64() * 1e3;
    // Only cache misses pay search cost; hits replay the cached outcome.
    let evals = |b: &BatchPlan| -> u64 {
        b.networks
            .iter()
            .flat_map(|(_, p)| &p.layers)
            .filter(|l| !l.cached)
            .map(|l| l.outcome.evaluations)
            .sum()
    };
    let identical = off.networks.iter().zip(&adapt.networks).all(|((_, a), (_, b))| {
        a.layers.len() == b.layers.len()
            && a.layers
                .iter()
                .zip(&b.layers)
                .all(|(x, y)| x.outcome.mapping == y.outcome.mapping)
    });
    WarmCase {
        mapper: name,
        network,
        layers: layers.len(),
        warm_seeded: adapt.warm_seeded,
        seed_quality: adapt.seed_quality,
        evals_unseeded: evals(&off),
        evals_seeded: evals(&adapt),
        wall_ms_unseeded,
        wall_ms_seeded,
        energy_unseeded_uj: off.total_energy_uj(),
        energy_seeded_uj: adapt.total_energy_uj(),
        identical,
    }
}

/// Time one mapper run, returning (evaluations, wall ms).
fn timed_map<M: Mapper>(mapper: &M, layer: &Layer, acc: &Accelerator) -> (u64, f64) {
    let t0 = Instant::now();
    let out = mapper.run(layer, acc).expect("perf mapper maps the layer");
    (out.evaluations, t0.elapsed().as_secs_f64() * 1e3)
}

/// Run the whole harness and return the report.
pub fn run(cfg: &PerfConfig) -> PerfReport {
    let acc = presets::eyeriss();
    let layer = zoo::vgg16()[8].clone();
    let (warmup, iters) = if cfg.smoke { (8, 64) } else { (64, 512) };

    // Shared candidate pool so both paths evaluate identical mappings.
    let mut rng = SplitMix64::new(7);
    let pool: Vec<Mapping> = (0..128).map(|_| sample_random(&layer, &acc, &mut rng)).collect();

    let mut i = 0usize;
    let t_legacy = median_time(warmup, iters, || {
        let e = evaluate_unchecked(&layer, &acc, &pool[i % pool.len()]);
        i += 1;
        e.latency_cycles
    });
    let mut ctx = EvalContext::new(&layer, &acc);
    let mut j = 0usize;
    let t_ctx = median_time(warmup, iters, || {
        let lat = ctx.evaluate_into(&pool[j % pool.len()]).latency_cycles;
        j += 1;
        lat
    });
    let evaluator = EvalThroughput {
        legacy_evals_per_sec: 1e9 / t_legacy.median_ns().max(1.0),
        context_evals_per_sec: 1e9 / t_ctx.median_ns().max(1.0),
    };

    // Per-operator-kind throughput: one representative layer per op, same
    // pre-sampled-pool methodology as the evaluator section.
    let op_layers: [(&'static str, Layer); 4] = [
        ("conv", zoo::vgg16()[8].clone()),
        ("matmul", Layer::matmul("perf-mm", 768, 768, 128)),
        ("pool", Layer::pooling("perf-pool", 64, 2, 112, 112).with_stride(2)),
        ("add", Layer::elementwise("perf-add", 768, 128, 1)),
    ];
    let mut per_op = Vec::with_capacity(op_layers.len());
    for (op, l) in op_layers {
        let mut rng = SplitMix64::new(17);
        let pool: Vec<Mapping> = (0..64).map(|_| sample_random(&l, &acc, &mut rng)).collect();
        let mut ctx = EvalContext::new(&l, &acc);
        let mut k = 0usize;
        let t = median_time(warmup, iters, || {
            let lat = ctx.evaluate_into(&pool[k % pool.len()]).latency_cycles;
            k += 1;
            lat
        });
        per_op.push(OpThroughput { op, evals_per_sec: 1e9 / t.median_ns().max(1.0) });
    }

    // Exhaustive scaling on a small fixed space (pruning and warm-start
    // off: every thread count enumerates the identical candidate set, so
    // wall-time differences are pure sharding).
    let ex_layer = Layer::new("perf-ex", 8, 4, 3, 3, 8, 8);
    let ex_acc = scaling_acc();
    let budget = if cfg.smoke { 2_000 } else { 50_000 };
    let mut exhaustive = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        let ex = ExhaustiveMapper::new(budget)
            .with_permutations()
            .without_pruning()
            .without_warm_start()
            .with_threads(threads);
        let t0 = Instant::now();
        let out = ex.run(&ex_layer, &ex_acc).expect("exhaustive maps the perf layer");
        let wall = t0.elapsed();
        exhaustive.push(ExhaustivePoint {
            threads,
            wall_ms: wall.as_secs_f64() * 1e3,
            evals_per_sec: out.evaluations as f64 / wall.as_secs_f64().max(1e-9),
        });
    }

    // Search-engine section: pruned-vs-unpruned for the default-pruned
    // mappers, then thread scaling for the newly parallel streams
    // (pruning off so the work is fixed).
    let search_layer = zoo::vgg02()[4].clone();
    let search_budget: u64 = if cfg.smoke { 3_000 } else { 10_000 };
    let mut pruning = Vec::new();
    {
        let full = ExhaustiveMapper::new(search_budget).with_permutations().without_pruning();
        let (ev_full, ms_full) = timed_map(&full, &search_layer, &acc);
        let fast = ExhaustiveMapper::new(search_budget).with_permutations();
        let (ev_fast, ms_fast) = timed_map(&fast, &search_layer, &acc);
        pruning.push(PruneStat {
            mapper: "exhaustive",
            evals_unpruned: ev_full,
            evals_pruned: ev_fast,
            wall_ms_unpruned: ms_full,
            wall_ms_pruned: ms_fast,
        });
        let cs_budget = search_budget / 10;
        let full = ConstrainedSearch::new(Dataflow::RowStationary, cs_budget, 42).without_pruning();
        let (ev_full, ms_full) = timed_map(&full, &search_layer, &acc);
        let fast = ConstrainedSearch::new(Dataflow::RowStationary, cs_budget, 42);
        let (ev_fast, ms_fast) = timed_map(&fast, &search_layer, &acc);
        pruning.push(PruneStat {
            mapper: "rs-search",
            evals_unpruned: ev_full,
            evals_pruned: ev_fast,
            wall_ms_unpruned: ms_full,
            wall_ms_pruned: ms_fast,
        });
    }
    let mut scaling = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        let rnd = RandomMapper::new(search_budget, 42).with_threads(threads);
        let (_, ms) = timed_map(&rnd, &search_layer, &acc);
        scaling.push(ScalePoint { mapper: "random", threads, wall_ms: ms });
        let rs = ConstrainedSearch::new(Dataflow::RowStationary, search_budget, 42)
            .without_pruning()
            .with_threads(threads);
        let (_, ms) = timed_map(&rs, &search_layer, &acc);
        scaling.push(ScalePoint { mapper: "rs-search", threads, wall_ms: ms });
    }
    let search = SearchSection { pruning, scaling };

    // Branch-and-bound section (schema 4): one VGG-16 conv9 case per
    // preset under the oracle-incumbent protocol, then one small space the
    // budget fully covers so the `certified` flag is exercised for real.
    let bnb_budget: u64 = if cfg.smoke { 6_000 } else { 20_000 };
    let mut bound_search = vec![
        bound_case("eyeriss", &layer, &presets::eyeriss(), bnb_budget, true),
        bound_case("nvdla", &layer, &presets::nvdla(), bnb_budget, true),
        bound_case("shidiannao", &layer, &presets::shidiannao(), bnb_budget, true),
    ];
    let tiny = Layer::new("perf-bnb", 4, 2, 1, 1, 4, 2);
    let tiny_space =
        crate::mapspace::lattice_subtree_blocks(&tiny, &ex_acc, 0).saturating_mul(7);
    bound_search.push(bound_case("perf-small", &tiny, &ex_acc, tiny_space, false));

    // Warm-start section (schema 5): bert's 4 unique shapes give two
    // seedable matmul misses. Exhaustive pins the bit-identity contract
    // with an evaluation cut; random shows the never-worse-score side.
    let warm_budget: u64 = if cfg.smoke { 1_500 } else { 6_000 };
    let bert = zoo::network("bert").expect("bert is in the zoo");
    let warm_start = vec![
        warm_case(
            "exhaustive",
            "bert",
            &bert,
            &acc,
            &ExhaustiveMapper::new(warm_budget).with_permutations(),
        ),
        warm_case("random", "bert", &bert, &acc, &RandomMapper::new(warm_budget, 42)),
    ];

    // Zoo batch pipeline (LOCAL is µs/layer, so this is cheap even full).
    let networks = zoo::batch_zoo();
    let t0 = Instant::now();
    let batch =
        compile_batch(&networks, &acc, &LocalMapper::new(), 4).expect("zoo batch compiles");
    let wall = t0.elapsed();
    let zoo_batch = ZooBatch {
        networks: batch.networks.len(),
        layers: batch.total_layers(),
        wall_ms: wall.as_secs_f64() * 1e3,
        cache_hit_rate: batch.hit_rate(),
    };

    // Service section (schema 6): the zoo compiled cold into an empty
    // cache directory, then through a *fresh* service over the same
    // directory — a simulated process restart. The warm run's mapper
    // evaluations are pinned to zero by `smoke_run_produces_sane_report`.
    let service_dir =
        std::env::temp_dir().join(format!("local-mapper-perf-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&service_dir);
    let open_log = || {
        std::sync::Arc::new(
            PersistentCache::open(&service_dir)
                .expect("perf cache dir opens")
                .with_namespace("perf|LOCAL"),
        )
    };
    let miss_evals = |b: &BatchPlan| -> u64 {
        b.networks
            .iter()
            .flat_map(|(_, p)| &p.layers)
            .filter(|l| !l.cached)
            .map(|l| l.outcome.evaluations)
            .sum()
    };
    let t0 = Instant::now();
    let cold = compile_batch_persistent(
        &networks,
        &acc,
        &LocalMapper::new(),
        4,
        SeedPolicy::Off,
        Some(open_log()),
    )
    .expect("cold service zoo compiles");
    let cold_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    let warm = compile_batch_persistent(
        &networks,
        &acc,
        &LocalMapper::new(),
        4,
        SeedPolicy::Off,
        Some(open_log()),
    )
    .expect("warm-restart service zoo compiles");
    let warm_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let _ = std::fs::remove_dir_all(&service_dir);
    let service = ServiceSection {
        layers: cold.total_layers(),
        cold_wall_ms,
        warm_wall_ms,
        cold_evaluations: miss_evals(&cold),
        warm_evaluations: miss_evals(&warm),
        disk_hits: warm.disk_hits,
        coalesced: cold.coalesced + warm.coalesced,
    };

    // Graph section (schema 7): fused vs unfused cross-layer DRAM traffic
    // on the two zoo networks with real multi-predecessor structure
    // (DESIGN.md §17). The analysis is pure accounting — cheap enough to
    // run at full fidelity even in smoke mode.
    let mut graph = Vec::new();
    for network in ["mobilenetv2res", "bert"] {
        let nets = vec![(network.to_string(), zoo::network(network).expect("zoo network"))];
        let empty = crate::graph::MappingIndex::new();
        let t0 = Instant::now();
        let off =
            crate::graph::analyze(&nets, &acc, crate::graph::GraphMode::Off, Objective::Energy, &empty);
        let fuse = crate::graph::analyze(
            &nets,
            &acc,
            crate::graph::GraphMode::Fuse,
            Objective::Energy,
            &empty,
        );
        graph.push(GraphPerf {
            network,
            groups: fuse.groups,
            fused_layers: fuse.fused_layers,
            unfused_dram_bytes: off.cross_layer_dram_bytes,
            fused_dram_bytes: fuse.cross_layer_dram_bytes,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
    }

    PerfReport {
        schema: 7,
        smoke: cfg.smoke,
        evaluator,
        per_op,
        exhaustive,
        search,
        bound_search,
        warm_start,
        zoo_batch,
        service,
        graph,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_run_produces_sane_report() {
        let r = run(&PerfConfig::smoke());
        assert!(r.smoke);
        assert_eq!(r.schema, 7);
        assert!(r.evaluator.legacy_evals_per_sec > 0.0);
        assert!(r.evaluator.context_evals_per_sec > 0.0);
        assert_eq!(
            r.per_op.iter().map(|p| p.op).collect::<Vec<_>>(),
            vec!["conv", "matmul", "pool", "add"]
        );
        assert!(r.per_op.iter().all(|p| p.evals_per_sec > 0.0));
        assert_eq!(r.exhaustive.len(), 4);
        assert_eq!(r.exhaustive.iter().map(|p| p.threads).collect::<Vec<_>>(), vec![1, 2, 4, 8]);
        assert!(r.exhaustive.iter().all(|p| p.evals_per_sec > 0.0));
        // Schema-3 search section: both default-pruned mappers report, and
        // pruning never examines more than the unpruned run.
        assert_eq!(
            r.search.pruning.iter().map(|p| p.mapper).collect::<Vec<_>>(),
            vec!["exhaustive", "rs-search"]
        );
        for p in &r.search.pruning {
            assert!(p.evals_pruned > 0, "{}", p.mapper);
            assert!(p.evals_pruned <= p.evals_unpruned, "{}", p.mapper);
        }
        assert_eq!(r.search.scaling.len(), 8);
        assert!(r.search.scaling.iter().all(|p| p.wall_ms > 0.0));
        // Schema-4 bound_search: one VGG-16 conv9 case per preset (oracle-
        // incumbent protocol, so B&B covers the same in-budget candidate
        // set as the baseline plus its one seed), then the small certified
        // space (no seed, budget == space).
        assert_eq!(
            r.bound_search.iter().map(|c| c.arch).collect::<Vec<_>>(),
            vec!["eyeriss", "nvdla", "shidiannao", "perf-small"]
        );
        for c in &r.bound_search[..3] {
            assert_eq!(c.layer, "VGG16_conv9");
            assert!(!c.certified, "{}: a 6k budget cannot cover conv9's space", c.arch);
            assert!(c.pruned > 0, "{}: B&B pruned nothing", c.arch);
            assert_eq!(c.evals_bnb + c.pruned, c.evals_unpruned + 1, "{}", c.arch);
        }
        let tiny = &r.bound_search[3];
        assert!(tiny.certified, "budget == space must certify");
        assert_eq!(tiny.evals_bnb + tiny.pruned, tiny.evals_unpruned);
        // Schema-5 warm_start: both arms seed bert's two seedable matmul
        // misses. The exhaustive arm's seed is bound-only, so the final
        // mappings are bit-identical and the seeded run never examines
        // more; the random arm merely never ends worse than unseeded.
        assert_eq!(
            r.warm_start.iter().map(|w| w.mapper).collect::<Vec<_>>(),
            vec!["exhaustive", "random"]
        );
        for w in &r.warm_start {
            assert_eq!(w.network, "bert");
            assert_eq!(w.layers, 96);
            assert_eq!(w.warm_seeded, 2, "{}", w.mapper);
            assert!(w.seed_quality > 0.0 && w.seed_quality <= 1.0 + 1e-9, "{}", w.mapper);
            assert!(w.evals_unseeded > 0 && w.evals_seeded > 0, "{}", w.mapper);
            assert!(
                w.energy_seeded_uj <= w.energy_unseeded_uj * (1.0 + 1e-12),
                "{}: seeding worsened the corpus energy",
                w.mapper
            );
        }
        let ex = &r.warm_start[0];
        assert!(ex.identical, "exhaustive seeding moved the argmin");
        assert!(ex.evals_seeded <= ex.evals_unseeded, "seeding examined more");
        assert_eq!(r.zoo_batch.networks, 8);
        assert!(r.zoo_batch.layers > 300);
        assert!(r.zoo_batch.wall_ms > 0.0);
        // Schema-6 service section: the warm-restart contract — a fresh
        // service over the same cache dir spends zero mapper evaluations
        // and serves every layer from the preloaded disk log.
        assert_eq!(r.service.layers, r.zoo_batch.layers);
        assert!(r.service.cold_evaluations > 0, "cold run must search");
        assert_eq!(r.service.warm_evaluations, 0, "warm restart re-searched");
        assert_eq!(
            r.service.disk_hits, r.service.layers as u64,
            "every warm-run layer must be a disk hit"
        );
        assert!(r.service.cold_wall_ms > 0.0 && r.service.warm_wall_ms > 0.0);
        // Schema-7 graph section: both multi-predecessor networks fuse,
        // and fusion strictly reduces cross-layer DRAM traffic.
        assert_eq!(
            r.graph.iter().map(|g| g.network).collect::<Vec<_>>(),
            vec!["mobilenetv2res", "bert"]
        );
        for g in &r.graph {
            assert!(g.groups > 0, "{}: no fused groups", g.network);
            assert!(g.fused_layers >= 2 * g.groups, "{}", g.network);
            assert!(
                g.fused_dram_bytes < g.unfused_dram_bytes,
                "{}: fusion must strictly reduce cross-layer DRAM",
                g.network
            );
        }
    }

    #[test]
    fn json_has_the_stable_key_set() {
        let r = PerfReport {
            schema: 7,
            smoke: true,
            evaluator: EvalThroughput {
                legacy_evals_per_sec: 100.0,
                context_evals_per_sec: 400.0,
            },
            per_op: vec![
                OpThroughput { op: "conv", evals_per_sec: 300.0 },
                OpThroughput { op: "matmul", evals_per_sec: 500.0 },
            ],
            exhaustive: vec![ExhaustivePoint { threads: 1, wall_ms: 2.0, evals_per_sec: 50.0 }],
            search: SearchSection {
                pruning: vec![PruneStat {
                    mapper: "exhaustive",
                    evals_unpruned: 3001,
                    evals_pruned: 1000,
                    wall_ms_unpruned: 8.0,
                    wall_ms_pruned: 3.0,
                }],
                scaling: vec![ScalePoint { mapper: "random", threads: 2, wall_ms: 4.0 }],
            },
            bound_search: vec![BoundCase {
                layer: "VGG16_conv9".into(),
                arch: "eyeriss",
                budget: 20_000,
                evals_unpruned: 20_000,
                evals_bnb: 1_000,
                pruned: 19_001,
                certified: false,
                wall_ms_unpruned: 40.0,
                wall_ms_bnb: 3.0,
            }],
            warm_start: vec![WarmCase {
                mapper: "exhaustive",
                network: "bert",
                layers: 96,
                warm_seeded: 2,
                seed_quality: 0.95,
                evals_unseeded: 6000,
                evals_seeded: 3000,
                wall_ms_unseeded: 12.0,
                wall_ms_seeded: 6.0,
                energy_unseeded_uj: 100.0,
                energy_seeded_uj: 100.0,
                identical: true,
            }],
            zoo_batch: ZooBatch { networks: 8, layers: 325, wall_ms: 10.0, cache_hit_rate: 0.4 },
            service: ServiceSection {
                layers: 325,
                cold_wall_ms: 50.0,
                warm_wall_ms: 5.0,
                cold_evaluations: 325,
                warm_evaluations: 0,
                disk_hits: 325,
                coalesced: 3,
            },
            graph: vec![GraphPerf {
                network: "mobilenetv2res",
                groups: 10,
                fused_layers: 20,
                unfused_dram_bytes: 1_000_000,
                fused_dram_bytes: 800_000,
                wall_ms: 1.5,
            }],
        };
        let json = r.to_json();
        for key in [
            "\"schema\": 7",
            "\"smoke\"",
            "\"evaluator\"",
            "\"legacy_evals_per_sec\"",
            "\"context_evals_per_sec\"",
            "\"speedup\"",
            "\"per_op\"",
            "\"op\": \"conv\"",
            "\"op\": \"matmul\"",
            "\"exhaustive\"",
            "\"threads\"",
            "\"wall_ms\"",
            "\"evals_per_sec\"",
            "\"search\"",
            "\"pruning\"",
            "\"evals_unpruned\": 3001",
            "\"evals_pruned\": 1000",
            "\"cut\": 3.001",
            "\"scaling\"",
            "\"mapper\": \"random\"",
            "\"bound_search\"",
            "\"evals_bnb\": 1000",
            "\"cut\": 20.000",
            "\"certified\": false",
            "\"warm_start\"",
            "\"warm_seeded\": 2",
            "\"seed_quality\": 0.950",
            "\"evals_unseeded\": 6000",
            "\"evals_seeded\": 3000",
            "\"cut\": 2.000",
            "\"identical\": true",
            "\"zoo_batch\"",
            "\"cache_hit_rate\"",
            "\"service\"",
            "\"cold_wall_ms\": 50.000",
            "\"warm_wall_ms\": 5.000",
            "\"cold_evaluations\": 325",
            "\"warm_evaluations\": 0",
            "\"disk_hits\": 325",
            "\"coalesced\": 3",
            "\"graph\"",
            "\"network\": \"mobilenetv2res\"",
            "\"groups\": 10",
            "\"fused_layers\": 20",
            "\"unfused_dram_bytes\": 1000000",
            "\"fused_dram_bytes\": 800000",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(json.contains("\"speedup\": 4.000"));
        assert!(r.summary().contains("4.00x"));
        assert!(r.summary().contains("per-op matmul"));
        assert!(r.summary().contains("prune exhaustive"));
        assert!(r.summary().contains("scale random 2T"));
        assert!(r.summary().contains("bound VGG16_conv9@eyeriss"));
        assert!(r.summary().contains("warm exhaustive@bert"));
        assert!(r.summary().contains("service restart"));
        assert!(r.summary().contains("graph mobilenetv2res: 10 groups"));
    }

    #[test]
    fn jnum_never_emits_non_finite() {
        assert_eq!(jnum(f64::NAN), "0");
        assert_eq!(jnum(f64::INFINITY), "0");
        assert_eq!(jnum(1.5), "1.500");
    }
}
