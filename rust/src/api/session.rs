//! The [`Session`] facade — the embeddable face of the compiler.
//!
//! A session owns the [`MappingService`] instances that serve its
//! requests. Services are keyed by (accelerator, mapper spec, search
//! params, worker count) and live for the whole session, so the mapping
//! cache and [`ServiceMetrics`] behind a key are **shared across
//! requests**: compiling the same network twice through one session is a
//! 100% cache hit the second time, and a long-lived embedder (a compiler
//! daemon, a serving tier) keeps its warm caches between callers.
//!
//! [`Session::compile`] returns a typed [`CompileReport`];
//! [`Session::compile_iter`] streams [`LayerReport`]s as the worker pool
//! finishes them, so batch callers can render progress without waiting for
//! the last shard. [`Session::simulate`] and [`Session::explore`] wrap the
//! tile-pipeline simulator and the co-design sweep behind the same
//! request/report surface.

use super::json::{self, Json};
use super::request::{CompileRequest, ResolvedRequest};
use super::Error;
use crate::arch::Accelerator;
use crate::coordinator::{JobHandle, MappingService, PersistentCache, SeedPolicy, ServiceMetrics};
use crate::explore::{self, DesignResult, SweepGrid};
use crate::mapping::Mapping;
use crate::mappers::{MapError, MapOutcome, MapStatus, Mapper, Objective};
use crate::model::EvalContext;
use crate::noc::{self, MeshTraffic};
use crate::sim::{self, SimOptions, SimResult};
use crate::workload::Layer;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Everything that distinguishes one mapping service from another: two
/// requests with equal keys share a service (hence cache and metrics).
/// The accelerator contributes its name **and** a fingerprint of its full
/// YAML serialization, so two in-memory configs that happen to share a
/// name never share a service (the per-service mapping cache keys by name
/// only — [`crate::coordinator::LayerKey`] — so a collision there would
/// silently serve results computed for the wrong hardware).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ServiceKey {
    arch: String,
    arch_fp: u64,
    mapper: String,
    budget: u64,
    seed: u64,
    objective: Objective,
    search_threads: usize,
    prune: bool,
    certify: bool,
    deadline_ms: Option<u64>,
    workers: usize,
    seed_policy: SeedPolicy,
    cache_dir: Option<String>,
}

/// FNV-1a over a byte string (stable fingerprint for [`ServiceKey`]).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl ServiceKey {
    fn of(req: &CompileRequest, resolved: &ResolvedRequest) -> Self {
        Self {
            arch: resolved.acc.name.clone(),
            arch_fp: fnv1a(crate::arch::config::accelerator_to_yaml(&resolved.acc).as_bytes()),
            mapper: req.mapper.to_ascii_lowercase(),
            budget: req.search.budget.max(1),
            seed: req.search.seed,
            objective: req.search.objective,
            search_threads: req.search.threads.max(1),
            prune: req.search.prune,
            certify: req.search.certify,
            deadline_ms: req.search.deadline_ms,
            workers: resolved.threads,
            seed_policy: req.seed_policy,
            cache_dir: req.cache_dir.clone(),
        }
    }
}

/// One mapped layer, as reported to API callers.
#[derive(Debug, Clone)]
pub struct LayerReport {
    /// The network the layer belongs to (workload label for single-layer
    /// requests).
    pub network: String,
    /// The layer that was mapped.
    pub layer: Layer,
    /// The mapping result: mapping, evaluation, objective, score, search
    /// cost.
    pub outcome: MapOutcome,
    /// Served from the session's mapping cache (shape already mapped under
    /// the same objective).
    pub cached: bool,
}

impl LayerReport {
    /// Layer energy, µJ.
    pub fn energy_uj(&self) -> f64 {
        self.outcome.evaluation.energy.total_uj()
    }

    /// Layer energy per MAC, pJ.
    pub fn pj_per_mac(&self) -> f64 {
        self.outcome.evaluation.energy.pj_per_mac(self.outcome.evaluation.macs)
    }

    /// Roofline latency, cycles.
    pub fn latency_cycles(&self) -> u64 {
        self.outcome.evaluation.latency_cycles
    }

    /// MAC operations in the layer.
    pub fn macs(&self) -> u64 {
        self.outcome.evaluation.macs
    }

    /// PE utilization in `[0, 1]`.
    pub fn utilization(&self) -> f64 {
        self.outcome.evaluation.utilization
    }
}

/// All layers of one network within a [`CompileReport`].
#[derive(Debug, Clone)]
pub struct NetworkReport {
    /// Network name (workload label).
    pub name: String,
    /// Per-layer reports in network order.
    pub layers: Vec<LayerReport>,
    /// Reply-collection wall-clock for this network within the request.
    pub compile_time: Duration,
}

impl NetworkReport {
    /// Total MACs over the network.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(LayerReport::macs).sum()
    }

    /// Total energy over the network, µJ.
    pub fn total_energy_uj(&self) -> f64 {
        self.layers.iter().map(LayerReport::energy_uj).sum()
    }

    /// Total roofline latency (sequential execution), cycles.
    pub fn total_latency_cycles(&self) -> u64 {
        self.layers.iter().map(LayerReport::latency_cycles).sum()
    }

    /// Network-wide energy per MAC, pJ.
    pub fn pj_per_mac(&self) -> f64 {
        self.total_energy_uj() * 1e6 / self.total_macs().max(1) as f64
    }

    /// MAC-weighted mean PE utilization.
    pub fn mean_utilization(&self) -> f64 {
        let total = self.total_macs() as f64;
        self.layers.iter().map(|l| l.utilization() * l.macs() as f64).sum::<f64>()
            / total.max(1.0)
    }

    /// Layers served from the session cache.
    pub fn cache_hits(&self) -> usize {
        self.layers.iter().filter(|l| l.cached).count()
    }
}

/// One layer that failed to map, recorded in [`CompileReport::failures`]
/// instead of aborting the batch (unless the request set
/// [`CompileRequest::fail_fast`]). Failures here are *hard* failures —
/// even the LOCAL fallback could not produce a valid mapping; degraded or
/// fell-back layers still appear as ordinary [`LayerReport`]s with a
/// non-`Ok` [`crate::mappers::MapStatus`].
#[derive(Debug, Clone)]
pub struct LayerFailure {
    /// The network the failed layer belongs to.
    pub network: String,
    /// The failed layer's name.
    pub layer: String,
    /// Rendered error message (already carries network/layer context).
    pub error: String,
    /// Stable [`Error::code`] of the failure (e.g. `E_SEARCH`, `E_PANIC`).
    pub code: &'static str,
}

/// The typed result of [`Session::compile`]: per-network, per-layer
/// reports plus request-wide cache statistics.
#[derive(Debug, Clone)]
pub struct CompileReport {
    /// Workload label (network name, file path, layer name or `zoo(n)`).
    pub workload: String,
    /// The accelerator the request targeted.
    pub acc: Accelerator,
    /// Mapper display name.
    pub mapper: String,
    /// The objective the mapper minimized.
    pub objective: Objective,
    /// Per-network reports in submission order.
    pub networks: Vec<NetworkReport>,
    /// Layers that failed to map (fallback included), in submission order.
    /// Empty on a fully-successful compile; see [`LayerFailure`].
    pub failures: Vec<LayerFailure>,
    /// Wall-clock of the whole request (submit → last reply).
    pub compile_time: Duration,
    /// Layer-mapping requests this compile submitted.
    pub requests: u64,
    /// Requests served from the session cache (within this request).
    pub cache_hits: u64,
    /// Median service time over the backing service's sample window. The
    /// window is session-scoped, so on a warm session it includes earlier
    /// requests against the same (arch, mapper, params) key.
    pub p50_service: Duration,
    /// 99th-percentile service time over the same window.
    pub p99_service: Duration,
    /// The cross-layer warm-start policy the request ran under.
    pub seed_policy: SeedPolicy,
    /// Cache misses in this request whose mapper run was warm-seeded from
    /// a similar shape's adapted mapping (DESIGN.md §15).
    pub warm_seeded: u64,
    /// Mean seed-hit quality over this request's warm-seeded layers (final
    /// score as a fraction of the seed's; 0 when nothing was seeded).
    pub seed_quality: f64,
    /// Layers reused verbatim from a previous report by
    /// [`Session::recompile`] (always 0 on ordinary compiles).
    pub incremental_reused: u64,
    /// Graph-level compilation summary (DESIGN.md §17): fused groups,
    /// fused layer count and estimated cross-layer DRAM traffic. Present
    /// in every mode — under `off` it carries the unfused baseline with
    /// zero groups, so `fuse`/`co_select` runs are directly comparable.
    pub graph: crate::graph::GraphReport,
}

impl CompileReport {
    /// Layers compiled across all networks.
    pub fn total_layers(&self) -> usize {
        self.networks.iter().map(|n| n.layers.len()).sum()
    }

    /// Total MACs across all networks.
    pub fn total_macs(&self) -> u64 {
        self.networks.iter().map(NetworkReport::total_macs).sum()
    }

    /// Total energy across all networks, µJ.
    pub fn total_energy_uj(&self) -> f64 {
        self.networks.iter().map(NetworkReport::total_energy_uj).sum()
    }

    /// Total roofline latency across all networks, cycles.
    pub fn total_latency_cycles(&self) -> u64 {
        self.networks.iter().map(NetworkReport::total_latency_cycles).sum()
    }

    /// MAC-weighted mean PE utilization across all networks.
    pub fn mean_utilization(&self) -> f64 {
        let total = self.total_macs() as f64;
        self.networks
            .iter()
            .flat_map(|n| n.layers.iter())
            .map(|l| l.utilization() * l.macs() as f64)
            .sum::<f64>()
            / total.max(1.0)
    }

    /// Request-level cache hit rate in `[0, 1]`.
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / self.requests as f64
    }
}

/// The typed result of [`Session::simulate`]: the mapping outcome plus the
/// tile-pipeline and mesh-NoC refinements of its analytical evaluation.
#[derive(Debug, Clone)]
pub struct SimulateReport {
    /// The simulated layer.
    pub layer: Layer,
    /// The accelerator simulated on.
    pub acc: Accelerator,
    /// Mapper display name.
    pub mapper: String,
    /// The mapping outcome (analytical evaluation inside).
    pub outcome: MapOutcome,
    /// Buffering/lockstep options the simulator ran with.
    pub options: SimOptions,
    /// Tile-pipeline simulation result.
    pub sim: SimResult,
    /// Exact mesh-NoC traffic for the same mapping.
    pub mesh: MeshTraffic,
}

impl SimulateReport {
    /// Exact mesh-NoC energy, µJ.
    pub fn mesh_energy_uj(&self) -> f64 {
        self.mesh.energy_pj(self.acc.noc.hop_energy_pj) / 1e6
    }

    /// The analytical model's NoC energy, µJ (comparison point).
    pub fn analytical_noc_uj(&self) -> f64 {
        self.outcome.evaluation.energy.noc_pj / 1e6
    }
}

/// The typed result of [`Session::explore`]: one aggregate per design
/// point plus the (energy, latency) Pareto front.
#[derive(Debug, Clone)]
pub struct ExploreReport {
    /// The workload the sweep mapped on every design.
    pub network: String,
    /// The base accelerator the grid varied.
    pub acc: Accelerator,
    /// Mapper display name.
    pub mapper: String,
    /// Per-design aggregates in grid order.
    pub results: Vec<DesignResult>,
    /// Pareto-optimal subset, energy ascending.
    pub front: Vec<DesignResult>,
}

/// Aggregate counters over every service a session has started.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionMetrics {
    /// Distinct (arch, mapper, params, workers) services started.
    pub services: usize,
    /// Layer-mapping requests answered across all services.
    pub requests: u64,
    /// Requests served from a mapping cache.
    pub cache_hits: u64,
    /// Cache hits whose entry was preloaded from a persistent on-disk
    /// cache rather than computed this process lifetime (DESIGN.md §16).
    pub disk_hits: u64,
    /// Requests that piggybacked on an identical in-flight search instead
    /// of starting their own (DESIGN.md §16).
    pub coalesced: u64,
    /// Requests answered with a mapper error (fallback included — these
    /// layers produced no mapping at all).
    pub errors: u64,
    /// Mapper panics caught by the workers' containment region.
    pub panics: u64,
    /// Requests answered by the O(1) LOCAL fallback after the configured
    /// mapper failed or panicked.
    pub fallbacks: u64,
    /// Dead worker threads respawned by the service supervisors.
    pub respawns: u64,
    /// Cache misses whose mapper run was warm-seeded from a similar
    /// shape's adapted mapping (DESIGN.md §15).
    pub warm_seeded: u64,
    /// Layers reused verbatim across [`Session::recompile`] calls.
    pub incremental_reused: u64,
}

impl SessionMetrics {
    /// Session-wide cache hit rate in `[0, 1]`.
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / self.requests as f64
    }
}

/// Streaming view of a batch compile: yields one [`LayerReport`] per
/// submitted layer, in submission order, blocking only until *that*
/// layer's shard finishes — early layers are consumable while late ones
/// are still mapping. Obtained from [`Session::compile_iter`]; the
/// backing services outlive the stream (they belong to the session).
pub struct LayerStream<'a> {
    items: std::vec::IntoIter<(String, Layer, JobHandle)>,
    _session: std::marker::PhantomData<&'a Session>,
}

impl Iterator for LayerStream<'_> {
    type Item = Result<LayerReport, Error>;

    fn next(&mut self) -> Option<Self::Item> {
        let (network, layer, handle) = self.items.next()?;
        Some(match handle.wait() {
            Ok(reply) => Ok(LayerReport {
                network,
                layer,
                outcome: reply.outcome,
                cached: reply.cached,
            }),
            Err(e) => Err(layer_error(&network, &layer.name, e)),
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.items.size_hint()
    }
}

impl ExactSizeIterator for LayerStream<'_> {}

impl std::fmt::Debug for LayerStream<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LayerStream").field("remaining", &self.items.len()).finish()
    }
}

/// Handles for one submitted network: `(layer, reply handle)` per layer.
type NetworkHandles = Vec<(Layer, JobHandle)>;

/// Warm-start counters attributable to one request: the delta between the
/// service's live counters (final once every submitted reply has been
/// collected) and the pre-submission snapshot.
fn warm_delta(metrics: &ServiceMetrics, warm0: (u64, u64)) -> (u64, f64) {
    let seeded = metrics.warm_seeded.load(Ordering::Relaxed).saturating_sub(warm0.0);
    if seeded == 0 {
        return (0, 0.0);
    }
    let quality_milli =
        metrics.seed_quality_milli.load(Ordering::Relaxed).saturating_sub(warm0.1);
    (seeded, quality_milli as f64 / (seeded as f64 * 1000.0))
}

/// Run the graph-level analysis for one finished compile (DESIGN.md §17).
/// Strictly additive reporting: the per-layer mapping work above is
/// identical in every [`crate::graph::GraphMode`], so `off` stays bit-identical to the
/// flat pipeline. Under `CoSelect` the finished layers' mappings feed the
/// cross-layer DRAM scoring; under `off`/`fuse` the index stays empty
/// (static volume accounting).
fn graph_report(
    mode: crate::graph::GraphMode,
    resolved: &ResolvedRequest,
    objective: Objective,
    networks: &[NetworkReport],
) -> crate::graph::GraphReport {
    let mut mappings = crate::graph::MappingIndex::new();
    if mode == crate::graph::GraphMode::CoSelect {
        for nr in networks {
            for lr in &nr.layers {
                mappings
                    .insert((nr.name.clone(), lr.layer.name.clone()), lr.outcome.mapping.clone());
            }
        }
    }
    crate::graph::analyze(&resolved.networks, &resolved.acc, mode, objective, &mappings)
}

/// Attach network/layer context to a service-side mapping failure.
fn layer_error(network: &str, layer: &str, e: MapError) -> Error {
    Error::Map(match e {
        MapError::NoValidMapping(msg) => {
            MapError::NoValidMapping(format!("{network}/{layer}: {msg}"))
        }
        MapError::Panicked(msg) => MapError::Panicked(format!("{network}/{layer}: {msg}")),
        other => other,
    })
}

/// The session facade: owns the mapping services, shares their caches and
/// metrics across requests, and turns [`CompileRequest`]s into typed
/// reports. See the [module docs](self) for the lifecycle.
pub struct Session {
    services: Mutex<HashMap<ServiceKey, Arc<MappingService>>>,
    /// Layers reused verbatim by [`Session::recompile`] over the session's
    /// lifetime (aggregated into [`SessionMetrics`]).
    incremental_reused: AtomicU64,
}

impl Default for Session {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Session {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.services.lock().map(|g| g.len()).unwrap_or(0);
        f.debug_struct("Session").field("services", &n).finish()
    }
}

impl Session {
    /// An empty session; services start lazily on the first request that
    /// needs them.
    pub fn new() -> Self {
        Self { services: Mutex::new(HashMap::new()), incremental_reused: AtomicU64::new(0) }
    }

    /// The service behind a request's [`ServiceKey`], started on first
    /// use. The session lock is held only for the map lookup/insert (plus,
    /// on a cold key with a cache dir, the disk-cache open — a one-time
    /// cost per key that keeps concurrent first requests from racing two
    /// services onto one log file).
    ///
    /// When the request carries a [`CompileRequest::cache_dir`], the
    /// service is backed by a [`PersistentCache`] namespaced to the
    /// producer identity (mapper name, search seed, seed policy), so a
    /// random-mapper log can never warm an exhaustive service and
    /// different seeds never cross-contaminate (DESIGN.md §16). Opening
    /// the directory can fail; that surfaces as a typed [`Error::Io`].
    fn service_for(
        &self,
        req: &CompileRequest,
        resolved: &ResolvedRequest,
    ) -> Result<Arc<MappingService>, Error> {
        let key = ServiceKey::of(req, resolved);
        // Poison-tolerant like the cache shards: a caller thread that
        // panicked between lookup and insert leaves the map consistent
        // (get/insert never partially apply), so keep serving.
        let mut guard = self.services.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(svc) = guard.get(&key) {
            return Ok(Arc::clone(svc));
        }
        let persist = match &req.cache_dir {
            Some(dir) => {
                let ns = format!(
                    "{}|seed{}|{}",
                    resolved.mapper.name(),
                    req.search.seed,
                    req.seed_policy.name()
                );
                let log = PersistentCache::open(dir)
                    .map_err(|e| Error::io(dir.clone(), e))?
                    .with_namespace(ns);
                Some(Arc::new(log))
            }
            None => None,
        };
        let svc = Arc::new(MappingService::start_with_persist(
            resolved.acc.clone(),
            resolved.mapper.clone(),
            resolved.threads,
            req.seed_policy,
            persist,
        ));
        guard.insert(key, Arc::clone(&svc));
        Ok(svc)
    }

    /// Submit every layer of the resolved request to its service, starting
    /// the service if this is the first request under its key. Returns the
    /// per-network handles, the service's live metrics, and a pre-submission
    /// snapshot of the warm-start counters (so the report can attribute
    /// warm-seeded misses to *this* request on a session-lived service).
    /// Submission happens on a cloned `Arc`, so concurrent compiles against
    /// *different* services never serialize on each other.
    fn submit_all(
        &self,
        req: &CompileRequest,
        resolved: &ResolvedRequest,
    ) -> Result<(Vec<(String, NetworkHandles)>, Arc<ServiceMetrics>, (u64, u64)), Error> {
        let svc = self.service_for(req, resolved)?;
        let warm0 = (
            svc.metrics.warm_seeded.load(Ordering::Relaxed),
            svc.metrics.seed_quality_milli.load(Ordering::Relaxed),
        );
        let submitted = resolved
            .networks
            .iter()
            .map(|(name, layers)| {
                let handles =
                    layers.iter().map(|l| (l.clone(), svc.submit(l.clone()))).collect();
                (name.clone(), handles)
            })
            .collect();
        Ok((submitted, Arc::clone(&svc.metrics), warm0))
    }

    /// Compile a request to a typed [`CompileReport`]. All layers of all
    /// networks are submitted up front (the service shards them across its
    /// worker pool); replies are collected in network order. A layer whose
    /// mapping fails outright (even through the LOCAL fallback) is
    /// recorded in [`CompileReport::failures`] and the rest of the batch
    /// still compiles; set [`CompileRequest::fail_fast`] to instead abort
    /// with the first error (remaining replies are drained either way —
    /// the queue already holds them).
    pub fn compile(&self, req: &CompileRequest) -> Result<CompileReport, Error> {
        self.compile_resolved(req, req.resolve()?)
    }

    /// [`Session::compile`] on an already-resolved request (resolution
    /// touches the filesystem for file-based specs, so callers that have
    /// to inspect the resolution — e.g. [`Session::simulate`] — resolve
    /// exactly once).
    fn compile_resolved(
        &self,
        req: &CompileRequest,
        resolved: ResolvedRequest,
    ) -> Result<CompileReport, Error> {
        let workload = resolved.workload_label();
        let mapper = resolved.mapper.name();
        let objective = resolved.mapper.objective();
        let t0 = Instant::now();
        let (submitted, metrics, warm0) = self.submit_all(req, &resolved)?;

        let mut networks = Vec::with_capacity(submitted.len());
        let mut failures: Vec<LayerFailure> = Vec::new();
        let mut first_error: Option<Error> = None;
        let mut requests = 0u64;
        let mut cache_hits = 0u64;
        for (name, handles) in submitted {
            let n0 = Instant::now();
            let mut layers = Vec::with_capacity(handles.len());
            for (layer, handle) in handles {
                requests += 1;
                match handle.wait() {
                    Ok(reply) => {
                        if reply.cached {
                            cache_hits += 1;
                        }
                        layers.push(LayerReport {
                            network: name.clone(),
                            layer,
                            outcome: reply.outcome,
                            cached: reply.cached,
                        });
                    }
                    Err(e) => {
                        let err = layer_error(&name, &layer.name, e);
                        failures.push(LayerFailure {
                            network: name.clone(),
                            layer: layer.name.clone(),
                            error: err.to_string(),
                            code: err.code(),
                        });
                        if first_error.is_none() {
                            first_error = Some(err);
                        }
                    }
                }
            }
            networks.push(NetworkReport { name, layers, compile_time: n0.elapsed() });
        }
        // Per-layer isolation: failures ride in the report unless the
        // caller opted back into the abort-on-first-error contract.
        if req.fail_fast {
            if let Some(e) = first_error {
                return Err(e);
            }
        }

        let percentiles = metrics.service_time_percentiles(&[0.50, 0.99]);
        let (warm_seeded, seed_quality) = warm_delta(&metrics, warm0);
        let graph = graph_report(req.graph_mode, &resolved, objective, &networks);
        Ok(CompileReport {
            workload,
            acc: resolved.acc,
            mapper,
            objective,
            networks,
            failures,
            compile_time: t0.elapsed(),
            requests,
            cache_hits,
            p50_service: percentiles[0],
            p99_service: percentiles[1],
            seed_policy: req.seed_policy,
            warm_seeded,
            seed_quality,
            incremental_reused: 0,
            graph,
        })
    }

    /// Compile a request as a stream: every layer is submitted up front,
    /// and the returned iterator yields each [`LayerReport`] as soon as
    /// its shard finishes (submission order), so callers can consume a
    /// 300-layer batch incrementally instead of waiting on the slowest
    /// network.
    pub fn compile_iter(&self, req: &CompileRequest) -> Result<LayerStream<'_>, Error> {
        let resolved = req.resolve()?;
        let (submitted, _, _) = self.submit_all(req, &resolved)?;
        let items: Vec<(String, Layer, JobHandle)> = submitted
            .into_iter()
            .flat_map(|(name, handles)| {
                handles.into_iter().map(move |(layer, handle)| (name.clone(), layer, handle))
            })
            .collect();
        Ok(LayerStream { items: items.into_iter(), _session: std::marker::PhantomData })
    }

    /// Incrementally recompile against a previous compile document
    /// (parsed api_v1 JSON, e.g. from [`super::json::parse`]): layers whose
    /// `(network, layer, op)` appear in `prev` with a mapping that still
    /// validates on the request's accelerator are **reused verbatim** —
    /// re-evaluated through the analytical model (one evaluation, status
    /// `ok`, `cached = true`) without ever touching the search — and only
    /// the changed layers go through the mapping service. The donor
    /// document must match the request's schema, kind, arch and objective;
    /// otherwise everything remaps and the call degrades to an ordinary
    /// compile. [`CompileReport::incremental_reused`] counts the reused
    /// layers (DESIGN.md §15).
    pub fn recompile(
        &self,
        prev: &Json,
        req: &CompileRequest,
    ) -> Result<CompileReport, Error> {
        let resolved = req.resolve()?;
        let workload = resolved.workload_label();
        let mapper_name = resolved.mapper.name();
        let objective = resolved.mapper.objective();
        let t0 = Instant::now();

        // Harvest donor mappings. A donor is only trustworthy for the same
        // arch and objective (a delay-optimal mapping must never be reused
        // for an energy request); each candidate is re-validated against
        // the *new* layer below, so a renamed-but-reshaped layer remaps.
        let donor_ok = prev.get("schema").and_then(Json::as_str) == Some(json::SCHEMA)
            && prev.get("kind").and_then(Json::as_str) == Some("compile")
            && prev.get("arch").and_then(Json::as_str) == Some(resolved.acc.name.as_str())
            && prev.get("objective").and_then(Json::as_str) == Some(objective.name());
        let mut donors: HashMap<(String, String, String), Mapping> = HashMap::new();
        if donor_ok {
            for net in prev.get("networks").and_then(Json::as_arr).unwrap_or(&[]) {
                let Some(net_name) = net.get("name").and_then(Json::as_str) else { continue };
                for l in net.get("layers").and_then(Json::as_arr).unwrap_or(&[]) {
                    if let (Some(name), Some(op), Some(m)) = (
                        l.get("name").and_then(Json::as_str),
                        l.get("op").and_then(Json::as_str),
                        l.get("mapping").and_then(json::parse_mapping),
                    ) {
                        donors.insert(
                            (net_name.to_string(), name.to_string(), op.to_string()),
                            m,
                        );
                    }
                }
            }
        }

        enum Slot {
            Reused(Box<LayerReport>),
            Pending(Layer, JobHandle),
        }

        let svc = self.service_for(req, &resolved)?;
        let warm0 = (
            svc.metrics.warm_seeded.load(Ordering::Relaxed),
            svc.metrics.seed_quality_milli.load(Ordering::Relaxed),
        );
        // First pass: reuse or submit, submitting every changed layer up
        // front so the pool shards them.
        let mut reused = 0u64;
        let mut all: Vec<(String, Vec<Slot>)> = Vec::with_capacity(resolved.networks.len());
        for (name, layers) in &resolved.networks {
            let mut slots = Vec::with_capacity(layers.len());
            for layer in layers {
                let donor = donors
                    .get(&(name.clone(), layer.name.clone(), layer.op.name().to_string()))
                    .filter(|m| m.validate(layer, &resolved.acc).is_ok());
                match donor {
                    Some(m) => {
                        let e0 = Instant::now();
                        let mut ctx = EvalContext::new(layer, &resolved.acc);
                        let evaluation = ctx.evaluate_into(m).clone();
                        let score = objective.score(&evaluation);
                        reused += 1;
                        slots.push(Slot::Reused(Box::new(LayerReport {
                            network: name.clone(),
                            layer: layer.clone(),
                            outcome: MapOutcome {
                                mapping: m.clone(),
                                evaluation,
                                evaluations: 1,
                                elapsed: e0.elapsed(),
                                objective,
                                score,
                                certified: false,
                                status: MapStatus::Ok,
                            },
                            cached: true,
                        })));
                    }
                    None => slots.push(Slot::Pending(layer.clone(), svc.submit(layer.clone()))),
                }
            }
            all.push((name.clone(), slots));
        }

        // Second pass: collect in order, exactly like an ordinary compile.
        let mut networks = Vec::with_capacity(all.len());
        let mut failures: Vec<LayerFailure> = Vec::new();
        let mut first_error: Option<Error> = None;
        let mut requests = 0u64;
        let mut cache_hits = 0u64;
        for (name, slots) in all {
            let n0 = Instant::now();
            let mut layers = Vec::with_capacity(slots.len());
            for slot in slots {
                match slot {
                    Slot::Reused(report) => layers.push(*report),
                    Slot::Pending(layer, handle) => {
                        requests += 1;
                        match handle.wait() {
                            Ok(reply) => {
                                if reply.cached {
                                    cache_hits += 1;
                                }
                                layers.push(LayerReport {
                                    network: name.clone(),
                                    layer,
                                    outcome: reply.outcome,
                                    cached: reply.cached,
                                });
                            }
                            Err(e) => {
                                let err = layer_error(&name, &layer.name, e);
                                failures.push(LayerFailure {
                                    network: name.clone(),
                                    layer: layer.name.clone(),
                                    error: err.to_string(),
                                    code: err.code(),
                                });
                                if first_error.is_none() {
                                    first_error = Some(err);
                                }
                            }
                        }
                    }
                }
            }
            networks.push(NetworkReport { name, layers, compile_time: n0.elapsed() });
        }
        if req.fail_fast {
            if let Some(e) = first_error {
                return Err(e);
            }
        }

        self.incremental_reused.fetch_add(reused, Ordering::Relaxed);
        let percentiles = svc.metrics.service_time_percentiles(&[0.50, 0.99]);
        let (warm_seeded, seed_quality) = warm_delta(&svc.metrics, warm0);
        let graph = graph_report(req.graph_mode, &resolved, objective, &networks);
        Ok(CompileReport {
            workload,
            acc: resolved.acc,
            mapper: mapper_name,
            objective,
            networks,
            failures,
            compile_time: t0.elapsed(),
            requests,
            cache_hits,
            p50_service: percentiles[0],
            p99_service: percentiles[1],
            seed_policy: req.seed_policy,
            warm_seeded,
            seed_quality,
            incremental_reused: reused,
            graph,
        })
    }

    /// Map a single-layer request through the session (warm-cache
    /// included) and refine its evaluation with the tile-pipeline
    /// simulator and the exact mesh-NoC model.
    pub fn simulate(
        &self,
        req: &CompileRequest,
        options: SimOptions,
    ) -> Result<SimulateReport, Error> {
        let resolved = req.resolve()?;
        let total: usize = resolved.networks.iter().map(|(_, l)| l.len()).sum();
        if total != 1 {
            return Err(Error::request(format!(
                "simulate needs a single-layer workload (got {total} layers)"
            )));
        }
        // Force fail-fast: a failed single layer must surface as its typed
        // error here, not as an empty report with a `failures` entry.
        let strict = req.clone().fail_fast(true);
        let report = self.compile_resolved(&strict, resolved)?;
        let layer = report
            .networks
            .first()
            .and_then(|n| n.layers.first())
            .cloned()
            .ok_or_else(|| Error::request("simulate: the layer produced no report"))?;
        let sim = sim::simulate(&layer.layer, &report.acc, &layer.outcome.mapping, options);
        let mesh = noc::simulate_mesh(&layer.layer, &report.acc, &layer.outcome.mapping);
        Ok(SimulateReport {
            layer: layer.layer,
            acc: report.acc,
            mapper: report.mapper,
            outcome: layer.outcome,
            options,
            sim,
            mesh,
        })
    }

    /// Sweep the hardware/mapping co-design grid for the request's
    /// workload: map every layer on every design point with the request's
    /// mapper and aggregate per design, returning the grid results and
    /// the (energy, latency) Pareto front.
    pub fn explore(
        &self,
        req: &CompileRequest,
        grid: &SweepGrid,
    ) -> Result<ExploreReport, Error> {
        let resolved = req.resolve()?;
        let name = resolved.workload_label();
        let layers: Vec<Layer> =
            resolved.networks.iter().flat_map(|(_, l)| l.iter().cloned()).collect();
        let points = grid.points(&resolved.acc);
        let results = explore::sweep(&points, &layers, &resolved.mapper)?;
        let front = explore::pareto(&results);
        Ok(ExploreReport {
            network: name,
            acc: resolved.acc,
            mapper: resolved.mapper.name(),
            results,
            front,
        })
    }

    /// Aggregate counters over every service this session has started.
    pub fn metrics(&self) -> SessionMetrics {
        // Metrics are read-only over atomics; a poisoned map is still safe
        // to aggregate from.
        let guard = self.services.lock().unwrap_or_else(|p| p.into_inner());
        let mut m = SessionMetrics {
            services: guard.len(),
            requests: 0,
            cache_hits: 0,
            disk_hits: 0,
            coalesced: 0,
            errors: 0,
            panics: 0,
            fallbacks: 0,
            respawns: 0,
            warm_seeded: 0,
            incremental_reused: self.incremental_reused.load(Ordering::Relaxed),
        };
        for svc in guard.values() {
            m.requests += svc.metrics.requests.load(Ordering::Relaxed);
            m.cache_hits += svc.metrics.cache_hits.load(Ordering::Relaxed);
            m.disk_hits += svc.metrics.disk_hits.load(Ordering::Relaxed);
            m.coalesced += svc.metrics.coalesced.load(Ordering::Relaxed);
            m.errors += svc.metrics.errors.load(Ordering::Relaxed);
            m.panics += svc.metrics.panics.load(Ordering::Relaxed);
            m.fallbacks += svc.metrics.fallbacks.load(Ordering::Relaxed);
            m.respawns += svc.metrics.respawns.load(Ordering::Relaxed);
            m.warm_seeded += svc.metrics.warm_seeded.load(Ordering::Relaxed);
        }
        m
    }

    /// Service-time quantiles aggregated across every service this session
    /// has started: the element-wise **maximum** of each service's own
    /// percentiles (a conservative tail bound — the true pooled quantile
    /// can never exceed the worst per-service one for the p99-style upper
    /// quantiles the daemon exports). Empty sessions report zeros.
    pub fn service_percentiles(&self, qs: &[f64]) -> Vec<Duration> {
        let guard = self.services.lock().unwrap_or_else(|p| p.into_inner());
        let mut out = vec![Duration::ZERO; qs.len()];
        for svc in guard.values() {
            for (slot, d) in out.iter_mut().zip(svc.metrics.service_time_percentiles(qs)) {
                if d > *slot {
                    *slot = d;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::ErrorClass;

    fn quick(net: &str) -> CompileRequest {
        CompileRequest::new().network(net).threads(2)
    }

    #[test]
    fn compile_reports_totals_and_cache() {
        let session = Session::new();
        let r = session.compile(&quick("alexnet")).unwrap();
        assert_eq!(r.total_layers(), 5);
        assert_eq!(r.requests, 5);
        assert_eq!(r.workload, "alexnet");
        assert_eq!(r.mapper, "LOCAL");
        assert!(r.total_energy_uj() > 0.0);
        assert!(r.total_latency_cycles() > 0);
        assert!(r.mean_utilization() > 0.0);
        assert_eq!(
            r.total_macs(),
            crate::workload::zoo::alexnet().iter().map(|l| l.macs()).sum::<u64>()
        );
    }

    #[test]
    fn session_cache_is_warm_across_requests() {
        // The tentpole claim: services (hence caches) outlive requests.
        let session = Session::new();
        let req = quick("alexnet").threads(1);
        let cold = session.compile(&req).unwrap();
        assert_eq!(cold.cache_hits, 0);
        let warm = session.compile(&req).unwrap();
        assert_eq!(warm.cache_hits, 5, "second compile must be fully cached");
        assert!((warm.hit_rate() - 1.0).abs() < 1e-12);
        let m = session.metrics();
        assert_eq!(m.services, 1);
        assert_eq!(m.requests, 10);
        assert_eq!(m.cache_hits, 5);
        // Identical outcomes from cache.
        for (a, b) in cold.networks[0].layers.iter().zip(&warm.networks[0].layers) {
            assert_eq!(a.outcome.mapping, b.outcome.mapping);
        }
    }

    #[test]
    fn graph_modes_report_savings_without_touching_mappings() {
        use crate::graph::GraphMode;
        let session = Session::new();
        let off = session.compile(&quick("mobilenetv2res")).unwrap();
        let fuse =
            session.compile(&quick("mobilenetv2res").graph_mode(GraphMode::Fuse)).unwrap();
        let co =
            session.compile(&quick("mobilenetv2res").graph_mode(GraphMode::CoSelect)).unwrap();
        // Off carries the baseline with zero groups.
        assert_eq!(off.graph.mode, GraphMode::Off);
        assert_eq!(off.graph.groups, 0);
        assert!(off.graph.cross_layer_dram_bytes > 0);
        // The acceptance criterion: fuse forms multi-node groups and
        // reports strictly lower cross-layer DRAM bytes than off.
        assert!(fuse.graph.groups >= 1);
        assert!(fuse.graph.fused_layers >= 2 * fuse.graph.groups);
        assert!(fuse.graph.cross_layer_dram_bytes < off.graph.cross_layer_dram_bytes);
        assert!(co.graph.groups >= 1);
        assert!(co.graph.cross_layer_dram_bytes < off.graph.cross_layer_dram_bytes);
        // Analysis-only: per-layer mappings and scores are identical in
        // every mode, and all three requests share one service/cache.
        assert_eq!(session.metrics().services, 1);
        for (a, b) in off.networks[0].layers.iter().zip(&fuse.networks[0].layers) {
            assert_eq!(a.outcome.mapping, b.outcome.mapping);
            assert_eq!(a.outcome.score, b.outcome.score);
        }
    }

    #[test]
    fn distinct_params_get_distinct_services() {
        let session = Session::new();
        session.compile(&quick("alexnet")).unwrap();
        session.compile(&quick("alexnet").objective(Objective::Delay)).unwrap();
        session.compile(&quick("alexnet").arch_preset("nvdla")).unwrap();
        assert_eq!(session.metrics().services, 3);
    }

    #[test]
    fn same_name_different_configs_get_distinct_services() {
        // The per-service mapping cache keys layers by arch *name*, so the
        // session must never let two different configs that share a name
        // land on one service — that would silently serve results computed
        // for the wrong hardware.
        let session = Session::new();
        let mut a = crate::arch::presets::eyeriss();
        a.name = "custom".into();
        let mut b = crate::arch::presets::nvdla();
        b.name = "custom".into();
        let req = CompileRequest::new().network("alexnet").threads(1);
        let ra = session.compile(&req.clone().accelerator(a)).unwrap();
        let rb = session.compile(&req.accelerator(b)).unwrap();
        assert_eq!(session.metrics().services, 2, "same-name configs shared a service");
        assert_ne!(ra.total_energy_uj(), rb.total_energy_uj());
    }

    #[test]
    fn zoo_compile_matches_batch_counts() {
        let session = Session::new();
        let r = session.compile(&CompileRequest::new().zoo().threads(4)).unwrap();
        assert_eq!(r.networks.len(), 8);
        assert_eq!(r.total_layers(), 13 + 53 + 52 + 26 + 5 + 96 + 18 + 62);
        assert_eq!(r.requests, r.total_layers() as u64);
        assert!(r.cache_hits > 0, "zoo has repeated shapes across networks");
        assert!(r.p50_service <= r.p99_service);
    }

    #[test]
    fn streaming_iter_yields_every_layer_in_order() {
        let session = Session::new();
        let stream = session.compile_iter(&quick("vgg02")).unwrap();
        assert_eq!(stream.len(), 8);
        let reports: Vec<LayerReport> = stream.map(|r| r.unwrap()).collect();
        assert_eq!(reports.len(), 8);
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(r.network, "vgg02");
            assert_eq!(r.layer.name, format!("VGG02_conv{}", i + 1));
            assert!(r.energy_uj() > 0.0);
        }
    }

    #[test]
    fn simulate_requires_single_layer_and_reports_pipeline() {
        let session = Session::new();
        let e = session.simulate(&quick("alexnet"), SimOptions::default()).unwrap_err();
        assert_eq!(e.class(), ErrorClass::Usage);
        let r = session
            .simulate(
                &CompileRequest::new().layer_spec("vgg02:5"),
                SimOptions::default(),
            )
            .unwrap();
        assert!(r.sim.total_cycles >= r.sim.compute_cycles);
        assert!(r.mesh.word_hops > 0);
        assert!(r.mesh_energy_uj() > 0.0);
    }

    #[test]
    fn explore_reports_grid_and_front() {
        let session = Session::new();
        let grid = SweepGrid { pe_dims: vec![(8, 8), (16, 16)], l1_depths: vec![8192] };
        let r = session
            .explore(&CompileRequest::new().network("alexnet"), &grid)
            .unwrap();
        assert_eq!(r.results.len(), 2);
        assert!(!r.front.is_empty());
        assert_eq!(r.network, "alexnet");
    }

    #[test]
    fn recompile_reuses_every_unchanged_layer() {
        // bert through one session: the second pass arrives as a previous
        // api_v1 document and every one of the 96 layers is reused without
        // touching the service queue.
        let session = Session::new();
        let req = quick("bert").threads(1);
        let first = session.compile(&req).unwrap();
        assert_eq!(first.total_layers(), 96);
        assert_eq!(first.incremental_reused, 0);
        let doc = crate::api::json::parse(&crate::api::json::compile_report(&first)).unwrap();
        let second = session.recompile(&doc, &req).unwrap();
        assert_eq!(second.incremental_reused, 96);
        assert_eq!(second.requests, 0, "reused layers must not hit the service");
        assert_eq!(second.total_layers(), 96);
        for (a, b) in first.networks[0].layers.iter().zip(&second.networks[0].layers) {
            assert_eq!(a.outcome.mapping, b.outcome.mapping);
            assert_eq!(a.outcome.score, b.outcome.score);
            assert!(b.cached);
        }
        assert_eq!(session.metrics().incremental_reused, 96);
    }

    #[test]
    fn recompile_remaps_changed_layers_only() {
        // Donate alexnet's document to a vgg02 request: nothing matches,
        // so everything remaps (a degraded-to-full compile, not an error).
        let session = Session::new();
        let donor = session.compile(&quick("alexnet").threads(1)).unwrap();
        let doc = crate::api::json::parse(&crate::api::json::compile_report(&donor)).unwrap();
        let r = session.recompile(&doc, &quick("vgg02").threads(1)).unwrap();
        assert_eq!(r.incremental_reused, 0);
        assert_eq!(r.total_layers(), 8);
        assert_eq!(r.requests, 8);
        // A mismatched objective also disqualifies the donor wholesale.
        let delay = quick("alexnet").threads(1).objective(Objective::Delay);
        let r = session.recompile(&doc, &delay).unwrap();
        assert_eq!(r.incremental_reused, 0);
        assert_eq!(r.total_layers(), 5);
    }

    #[test]
    fn mapping_failures_carry_layer_context() {
        // Budget-1 constrained search on a starved accelerator cannot find
        // a valid candidate, and the accelerator is so small even the
        // LOCAL fallback fails — a *hard* failure. By default it rides in
        // `report.failures` (per-layer isolation); with `fail_fast` the
        // old abort-on-first-error contract returns, naming the layer and
        // classifying as a mapping failure (exit 4).
        let session = Session::new();
        let req = CompileRequest::new()
            .layer_spec("vgg16:9")
            .mapper("rs")
            .budget(1)
            .threads(1)
            .accelerator(tiny_acc());
        let r = session.compile(&req).unwrap();
        assert_eq!(r.total_layers(), 0, "a hard failure must not yield a layer report");
        assert_eq!(r.failures.len(), 1);
        let f = &r.failures[0];
        assert_eq!(f.code, "E_SEARCH");
        assert_eq!(f.layer, "VGG16_conv9");
        assert!(f.error.contains("VGG16_conv9"), "{}", f.error);
        match session.compile(&req.clone().fail_fast(true)) {
            Err(e) => {
                assert_eq!(e.class(), ErrorClass::Failure, "{e}");
                assert_eq!(e.code(), "E_SEARCH");
                assert!(e.to_string().contains("VGG16_conv9"), "{e}");
            }
            Ok(r) => panic!("expected fail-fast abort, got {} layers", r.total_layers()),
        }
    }

    /// An accelerator so starved a budget-1 search cannot fit a tile.
    fn tiny_acc() -> Accelerator {
        use crate::arch::{Noc, PeArray, StorageLevel, Style};
        Accelerator {
            name: "tiny".into(),
            style: Style::EyerissLike,
            datawidth_bits: 16,
            levels: vec![
                StorageLevel::register_file("RF", 2, 16),
                StorageLevel::buffer("GLB", 4, 64),
                StorageLevel::dram(64),
            ],
            pe: PeArray::new(2, 2),
            noc: Noc::default(),
            mac_energy_pj: 1.0,
            clock_mhz: 200.0,
        }
    }
}
