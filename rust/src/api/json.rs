//! Versioned JSON output for the API's report types — and a strict parser
//! for validating it.
//!
//! serde is not in the offline crate set, so this is a hand-rolled
//! serializer with three hard guarantees the CLI tests pin:
//!
//! * **Versioned**: every document opens with `"schema": "api_v1"` and a
//!   `"kind"` discriminator (`compile` / `simulate` / `explore`). Schema
//!   changes bump the tag; consumers reject tags they don't know.
//! * **Byte-stable key order**: keys are emitted in a fixed order, so two
//!   runs over the same inputs differ only in measured wall-clock values —
//!   diffs and golden tests stay meaningful.
//! * **Strict numbers**: floats render via Rust's shortest round-trip
//!   `Display` (re-parsing yields the identical `f64`; the property tests
//!   rely on this), and non-finite values — which valid reports never
//!   produce — degrade to `0` rather than emitting invalid JSON.
//!
//! [`parse`] is the matching strict reader used by the golden CLI tests
//! and the schema-validation tooling; it preserves object key order so
//! tests can assert byte-stable ordering structurally.

use super::session::{CompileReport, ExploreReport, LayerReport, SimulateReport};
use crate::explore::DesignResult;
use crate::mapping::Mapping;
use std::fmt;

/// The schema tag every document carries.
pub const SCHEMA: &str = "api_v1";

/// Render a finite float in shortest round-trip form; non-finite values
/// (which no valid report produces) degrade to `0` so the document stays
/// parseable.
fn jf(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "0".to_string()
    }
}

/// Duration in fractional milliseconds.
fn jms(d: std::time::Duration) -> String {
    jf(d.as_secs_f64() * 1e3)
}

/// JSON string escaping (quotes, backslashes, control characters; UTF-8
/// passes through). Crate-visible so the persistent-cache record encoder
/// and the serve daemon's error frames escape identically.
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One `[u64; 7]` factor array.
fn factors(f: &[u64; 7]) -> String {
    let items: Vec<String> = f.iter().map(u64::to_string).collect();
    format!("[{}]", items.join(", "))
}

/// A mapping as structured JSON: per-level temporal factors
/// ([`crate::workload::Dim`] order N,M,C,R,S,P,Q), per-level permutation
/// strings (innermost dim first), spatial X/Y factors. Crate-visible:
/// the persistent mapping cache embeds exactly this encoding in its log
/// records (one encoder, one decoder — [`parse_mapping`]).
pub(crate) fn mapping(m: &Mapping) -> String {
    let temporal: Vec<String> = m.temporal.iter().map(factors).collect();
    let permutation: Vec<String> = m
        .permutation
        .iter()
        .map(|p| {
            let order: String = p.iter().map(|d| d.name()).collect();
            format!("\"{order}\"")
        })
        .collect();
    format!(
        "{{\"temporal\": [{}], \"permutation\": [{}], \"spatial_x\": {}, \"spatial_y\": {}}}",
        temporal.join(", "),
        permutation.join(", "),
        factors(&m.spatial_x),
        factors(&m.spatial_y)
    )
}

/// One layer report as a single-line object. The `status` object always
/// carries both keys: `kind` (`ok` / `degraded` / `fell_back`) and
/// `reason` (empty for `ok`).
fn layer(l: &LayerReport) -> String {
    let e = &l.outcome.evaluation;
    format!(
        "{{\"name\": \"{}\", \"op\": \"{}\", \"macs\": {}, \"energy_uj\": {}, \"pj_per_mac\": {}, \"latency_cycles\": {}, \"utilization\": {}, \"evaluations\": {}, \"map_time_ms\": {}, \"score\": {}, \"cached\": {}, \"certified\": {}, \"status\": {{\"kind\": \"{}\", \"reason\": \"{}\"}}, \"mapping\": {}}}",
        esc(&l.layer.name),
        l.layer.op.name(),
        e.macs,
        jf(e.energy.total_uj()),
        jf(e.energy.pj_per_mac(e.macs)),
        e.latency_cycles,
        jf(e.utilization),
        l.outcome.evaluations,
        jms(l.outcome.elapsed),
        jf(l.outcome.score),
        l.cached,
        l.outcome.certified,
        l.outcome.status.kind(),
        esc(l.outcome.status.reason()),
        mapping(&l.outcome.mapping)
    )
}

/// Serialize a [`CompileReport`] (the `map`, `compile` and `compile-all`
/// document; they share one schema — `map` is a one-network, one-layer
/// compile).
pub fn compile_report(r: &CompileReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str("  \"kind\": \"compile\",\n");
    s.push_str(&format!("  \"workload\": \"{}\",\n", esc(&r.workload)));
    s.push_str(&format!("  \"arch\": \"{}\",\n", esc(&r.acc.name)));
    s.push_str(&format!("  \"mapper\": \"{}\",\n", esc(&r.mapper)));
    s.push_str(&format!("  \"objective\": \"{}\",\n", r.objective.name()));
    s.push_str("  \"networks\": [\n");
    for (i, net) in r.networks.iter().enumerate() {
        s.push_str("    {\n");
        s.push_str(&format!("      \"name\": \"{}\",\n", esc(&net.name)));
        s.push_str("      \"layers\": [\n");
        for (j, l) in net.layers.iter().enumerate() {
            s.push_str("        ");
            s.push_str(&layer(l));
            s.push_str(if j + 1 < net.layers.len() { ",\n" } else { "\n" });
        }
        s.push_str("      ],\n");
        s.push_str(&format!(
            "      \"totals\": {{\"layers\": {}, \"macs\": {}, \"energy_uj\": {}, \"pj_per_mac\": {}, \"latency_cycles\": {}, \"mean_utilization\": {}, \"cache_hits\": {}}},\n",
            net.layers.len(),
            net.total_macs(),
            jf(net.total_energy_uj()),
            jf(net.pj_per_mac()),
            net.total_latency_cycles(),
            jf(net.mean_utilization()),
            net.cache_hits()
        ));
        s.push_str(&format!("      \"compile_time_ms\": {}\n", jms(net.compile_time)));
        s.push_str(if i + 1 < r.networks.len() { "    },\n" } else { "    }\n" });
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"totals\": {{\"layers\": {}, \"macs\": {}, \"energy_uj\": {}, \"latency_cycles\": {}, \"mean_utilization\": {}}},\n",
        r.total_layers(),
        r.total_macs(),
        jf(r.total_energy_uj()),
        r.total_latency_cycles(),
        jf(r.mean_utilization())
    ));
    s.push_str(&format!(
        "  \"cache\": {{\"requests\": {}, \"hits\": {}, \"hit_rate\": {}, \"p50_service_ms\": {}, \"p99_service_ms\": {}}},\n",
        r.requests,
        r.cache_hits,
        jf(r.hit_rate()),
        jms(r.p50_service),
        jms(r.p99_service)
    ));
    s.push_str(&format!(
        "  \"warm\": {{\"policy\": \"{}\", \"seeded\": {}, \"seed_quality\": {}, \"incremental_reused\": {}}},\n",
        r.seed_policy.name(),
        r.warm_seeded,
        jf(r.seed_quality),
        r.incremental_reused
    ));
    s.push_str(&format!(
        "  \"graph\": {{\"mode\": \"{}\", \"groups\": {}, \"fused_layers\": {}, \"cross_layer_dram_bytes\": {}, \"dram_bytes_saved\": {}}},\n",
        r.graph.mode.name(),
        r.graph.groups,
        r.graph.fused_layers,
        r.graph.cross_layer_dram_bytes,
        r.graph.dram_bytes_saved
    ));
    if r.failures.is_empty() {
        s.push_str("  \"failures\": [],\n");
    } else {
        s.push_str("  \"failures\": [\n");
        for (i, f) in r.failures.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"network\": \"{}\", \"layer\": \"{}\", \"code\": \"{}\", \"error\": \"{}\"}}{}\n",
                esc(&f.network),
                esc(&f.layer),
                f.code,
                esc(&f.error),
                if i + 1 < r.failures.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
    }
    s.push_str(&format!("  \"compile_time_ms\": {}\n", jms(r.compile_time)));
    s.push_str("}\n");
    s
}

/// Serialize a [`SimulateReport`] (the `simulate` document).
pub fn simulate_report(r: &SimulateReport) -> String {
    let e = &r.outcome.evaluation;
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str("  \"kind\": \"simulate\",\n");
    s.push_str(&format!("  \"layer\": \"{}\",\n", esc(&r.layer.name)));
    s.push_str(&format!("  \"op\": \"{}\",\n", r.layer.op.name()));
    s.push_str(&format!("  \"arch\": \"{}\",\n", esc(&r.acc.name)));
    s.push_str(&format!("  \"mapper\": \"{}\",\n", esc(&r.mapper)));
    s.push_str(&format!("  \"objective\": \"{}\",\n", r.outcome.objective.name()));
    s.push_str(&format!(
        "  \"analytical\": {{\"energy_uj\": {}, \"latency_cycles\": {}, \"utilization\": {}}},\n",
        jf(e.energy.total_uj()),
        e.latency_cycles,
        jf(e.utilization)
    ));
    s.push_str(&format!(
        "  \"sim\": {{\"double_buffer\": {}, \"total_cycles\": {}, \"compute_cycles\": {}, \"slowdown\": {}, \"bottleneck_level\": \"{}\", \"levels\": [\n",
        r.options.double_buffer,
        r.sim.total_cycles,
        r.sim.compute_cycles,
        jf(r.sim.slowdown),
        esc(&r.acc.levels[r.sim.bottleneck_level].name)
    ));
    for (i, p) in r.sim.levels.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"rounds\": {}, \"transfer_cycles\": {}, \"stall_cycles\": {}}}{}\n",
            esc(&r.acc.levels[i].name),
            p.rounds,
            p.transfer_cycles,
            p.stall_cycles,
            if i + 1 < r.sim.levels.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]},\n");
    s.push_str(&format!(
        "  \"mesh\": {{\"word_hops\": {}, \"max_link_words\": {}, \"energy_uj\": {}, \"analytical_noc_uj\": {}}}\n",
        r.mesh.word_hops,
        r.mesh.max_link_words,
        jf(r.mesh_energy_uj()),
        jf(r.analytical_noc_uj())
    ));
    s.push_str("}\n");
    s
}

/// One design-sweep aggregate as a single-line object.
fn design(d: &DesignResult) -> String {
    format!(
        "{{\"design\": \"{}\", \"energy_uj\": {}, \"pj_per_mac\": {}, \"latency_cycles\": {}, \"edp\": {}, \"mean_utilization\": {}}}",
        esc(&d.label),
        jf(d.total_energy_uj),
        jf(d.pj_per_mac()),
        d.total_latency_cycles,
        jf(d.edp),
        jf(d.mean_utilization)
    )
}

/// Serialize an [`ExploreReport`] (the `explore` document).
pub fn explore_report(r: &ExploreReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str("  \"kind\": \"explore\",\n");
    s.push_str(&format!("  \"network\": \"{}\",\n", esc(&r.network)));
    s.push_str(&format!("  \"arch\": \"{}\",\n", esc(&r.acc.name)));
    s.push_str(&format!("  \"mapper\": \"{}\",\n", esc(&r.mapper)));
    s.push_str("  \"results\": [\n");
    for (i, d) in r.results.iter().enumerate() {
        s.push_str("    ");
        s.push_str(&design(d));
        s.push_str(if i + 1 < r.results.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ],\n");
    s.push_str("  \"pareto\": [\n");
    for (i, d) in r.front.iter().enumerate() {
        s.push_str("    ");
        s.push_str(&design(d));
        s.push_str(if i + 1 < r.front.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    s
}

/// Parse a layer's `"mapping"` object (as emitted by [`compile_report`])
/// back into a typed [`Mapping`]. Returns `None` on any structural
/// mismatch — wrong arity, unknown dimension letters, non-integer factors
/// — so callers treat unparsable donors as cache misses, not errors.
pub fn parse_mapping(v: &Json) -> Option<Mapping> {
    fn factors7(v: &Json) -> Option<[u64; 7]> {
        let arr = v.as_arr()?;
        if arr.len() != 7 {
            return None;
        }
        let mut out = [0u64; 7];
        for (slot, item) in out.iter_mut().zip(arr) {
            *slot = item.as_u64()?;
        }
        Some(out)
    }
    fn permutation7(v: &Json) -> Option<crate::mapping::Permutation> {
        let s = v.as_str()?;
        if s.chars().count() != 7 {
            return None;
        }
        let mut out = [crate::workload::Dim::N; 7];
        for (slot, c) in out.iter_mut().zip(s.chars()) {
            *slot = crate::workload::Dim::parse(&c.to_string())?;
        }
        Some(out)
    }
    let temporal: Vec<[u64; 7]> = v
        .get("temporal")?
        .as_arr()?
        .iter()
        .map(factors7)
        .collect::<Option<Vec<_>>>()?;
    let permutation: Vec<crate::mapping::Permutation> = v
        .get("permutation")?
        .as_arr()?
        .iter()
        .map(permutation7)
        .collect::<Option<Vec<_>>>()?;
    if temporal.is_empty() || permutation.len() != temporal.len() {
        return None;
    }
    Some(Mapping {
        temporal,
        permutation,
        spatial_x: factors7(v.get("spatial_x")?)?,
        spatial_y: factors7(v.get("spatial_y")?)?,
    })
}

// --------------------------------------------------------------- parsing

/// A parsed JSON value. Object keys keep document order so golden tests
/// can assert the byte-stable key ordering structurally.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as `f64`; the serializer never emits values
    /// outside the exact-integer range).
    Num(f64),
    /// A string (escapes resolved).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, in document key order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object member by key.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The value as a float.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as an exact unsigned integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Object keys in document order.
    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(members) => members.iter().map(|(k, _)| k.as_str()).collect(),
            _ => Vec::new(),
        }
    }
}

/// Parse error with a byte offset into the source.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the error.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Strictly parse one JSON document (trailing whitespace allowed, trailing
/// content rejected).
pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if members.iter().any(|(k, _)| *k == key) {
                return Err(self.err(format!("duplicate key '{key}'")));
            }
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            members.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(members));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad \\u code point"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte sequences pass
                    // through unmodified; the source is a &str, so they
                    // are valid by construction).
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && (self.bytes[self.pos] & 0xc0) == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid UTF-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{CompileRequest, Session};

    #[test]
    fn parser_round_trips_scalars_and_structure() {
        let doc = r#"{"a": 1.5, "b": [true, false, null, "x\n\"y\""], "c": {"d": -3e2}}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.keys(), vec!["a", "b", "c"]);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.5));
        let b = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(b[0].as_bool(), Some(true));
        assert_eq!(b[3].as_str(), Some("x\n\"y\""));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-300.0));
    }

    #[test]
    fn parser_rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\": 1,}",
            "{\"a\": 1} extra",
            "{\"a\": 1, \"a\": 2}",
            "\"unterminated",
            "nul",
        ] {
            assert!(parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn floats_round_trip_exactly_through_shortest_display() {
        for x in [0.1, 1.0 / 3.0, 123456.789, 1e-9, 2.5e17] {
            let doc = format!("{{\"x\": {}}}", jf(x));
            let v = parse(&doc).unwrap();
            assert_eq!(v.get("x").unwrap().as_f64(), Some(x), "{x}");
        }
        assert_eq!(jf(f64::NAN), "0");
        assert_eq!(jf(f64::INFINITY), "0");
    }

    #[test]
    fn escaping_survives_round_trip() {
        let nasty = "a\"b\\c\nd\te\u{1}µ";
        let doc = format!("{{\"s\": \"{}\"}}", esc(nasty));
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some(nasty));
    }

    #[test]
    fn compile_document_has_the_versioned_skeleton() {
        let session = Session::new();
        let r = session
            .compile(&CompileRequest::new().network("alexnet").threads(2))
            .unwrap();
        let doc = compile_report(&r);
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(v.get("kind").unwrap().as_str(), Some("compile"));
        assert_eq!(
            v.keys(),
            vec![
                "schema",
                "kind",
                "workload",
                "arch",
                "mapper",
                "objective",
                "networks",
                "totals",
                "cache",
                "warm",
                "graph",
                "failures",
                "compile_time_ms"
            ]
        );
        let warm = v.get("warm").unwrap();
        assert_eq!(warm.keys(), vec!["policy", "seeded", "seed_quality", "incremental_reused"]);
        assert_eq!(warm.get("policy").unwrap().as_str(), Some("adapt"));
        assert_eq!(warm.get("incremental_reused").unwrap().as_u64(), Some(0));
        let graph = v.get("graph").unwrap();
        assert_eq!(
            graph.keys(),
            vec!["mode", "groups", "fused_layers", "cross_layer_dram_bytes", "dram_bytes_saved"]
        );
        // Default requests run with graph mode off: zero groups, but the
        // baseline cross-layer traffic estimate is still reported.
        assert_eq!(graph.get("mode").unwrap().as_str(), Some("off"));
        assert_eq!(graph.get("groups").unwrap().as_u64(), Some(0));
        assert!(graph.get("cross_layer_dram_bytes").unwrap().as_u64().unwrap() > 0);
        assert!(v.get("failures").unwrap().as_arr().unwrap().is_empty());
        let nets = v.get("networks").unwrap().as_arr().unwrap();
        assert_eq!(nets.len(), 1);
        assert_eq!(nets[0].keys(), vec!["name", "layers", "totals", "compile_time_ms"]);
        let layers = nets[0].get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 5);
        assert_eq!(
            layers[0].keys(),
            vec![
                "name",
                "op",
                "macs",
                "energy_uj",
                "pj_per_mac",
                "latency_cycles",
                "utilization",
                "evaluations",
                "map_time_ms",
                "score",
                "cached",
                "certified",
                "status",
                "mapping"
            ]
        );
        let status = layers[0].get("status").unwrap();
        assert_eq!(status.keys(), vec!["kind", "reason"]);
        assert_eq!(status.get("kind").unwrap().as_str(), Some("ok"));
        assert_eq!(status.get("reason").unwrap().as_str(), Some(""));
        assert_eq!(
            layers[0].get("mapping").unwrap().keys(),
            vec!["temporal", "permutation", "spatial_x", "spatial_y"]
        );
        // Totals in the document equal the typed report exactly (shortest
        // round-trip floats).
        let totals = v.get("totals").unwrap();
        assert_eq!(totals.get("layers").unwrap().as_u64(), Some(5));
        assert_eq!(totals.get("macs").unwrap().as_u64(), Some(r.total_macs()));
        assert_eq!(
            totals.get("energy_uj").unwrap().as_f64(),
            Some(r.total_energy_uj())
        );
        assert_eq!(
            totals.get("latency_cycles").unwrap().as_u64(),
            Some(r.total_latency_cycles())
        );
    }

    #[test]
    fn mappings_round_trip_through_the_document() {
        let session = Session::new();
        let r = session
            .compile(&CompileRequest::new().network("alexnet").threads(1))
            .unwrap();
        let v = parse(&compile_report(&r)).unwrap();
        let layers = v.get("networks").unwrap().as_arr().unwrap()[0]
            .get("layers")
            .unwrap()
            .as_arr()
            .unwrap();
        for (l, typed) in layers.iter().zip(&r.networks[0].layers) {
            let m = parse_mapping(l.get("mapping").unwrap()).expect("mapping parses");
            assert_eq!(m, typed.outcome.mapping);
        }
        // Structural junk degrades to None, never a panic.
        for bad in [
            r#"{"temporal": [], "permutation": [], "spatial_x": [1,1,1,1,1,1,1], "spatial_y": [1,1,1,1,1,1,1]}"#,
            r#"{"temporal": [[1,1,1,1,1,1,1]], "permutation": ["NMCRSPQX"], "spatial_x": [1,1,1,1,1,1,1], "spatial_y": [1,1,1,1,1,1,1]}"#,
            r#"{"temporal": [[1,1,1]], "permutation": ["NMCRSPQ"], "spatial_x": [1,1,1,1,1,1,1], "spatial_y": [1,1,1,1,1,1,1]}"#,
        ] {
            assert_eq!(parse_mapping(&parse(bad).unwrap()), None, "{bad}");
        }
    }

    #[test]
    fn simulate_and_explore_documents_parse() {
        use crate::explore::SweepGrid;
        use crate::sim::SimOptions;
        let session = Session::new();
        let sim = session
            .simulate(&CompileRequest::new().layer_spec("vgg02:5"), SimOptions::default())
            .unwrap();
        let v = parse(&simulate_report(&sim)).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(v.get("kind").unwrap().as_str(), Some("simulate"));
        assert!(v.get("sim").unwrap().get("total_cycles").unwrap().as_u64().is_some());
        assert_eq!(
            v.get("sim").unwrap().get("levels").unwrap().as_arr().unwrap().len(),
            sim.acc.n_levels()
        );

        let grid = SweepGrid { pe_dims: vec![(8, 8)], l1_depths: vec![8192, 16384] };
        let ex = session
            .explore(&CompileRequest::new().network("alexnet"), &grid)
            .unwrap();
        let v = parse(&explore_report(&ex)).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("explore"));
        assert_eq!(v.get("results").unwrap().as_arr().unwrap().len(), 2);
        assert!(!v.get("pareto").unwrap().as_arr().unwrap().is_empty());
    }
}
