//! The compile daemon: [`CompileRequest`]s over a Unix socket.
//!
//! `local-mapper serve` turns one long-lived [`Session`] into a service:
//! clients connect to a Unix domain socket, send length-prefixed JSON
//! request frames, and get back the exact `api_v1` documents the CLI
//! would print. Because every connection shares the one session, the
//! mapping caches, coalescing tables and (with `--cache-dir`) the
//! persistent disk cache are shared across *clients* — the second caller
//! to compile a network pays nothing, even if it is a different process
//! hours later (DESIGN.md §16).
//!
//! # Wire protocol
//!
//! Frames in both directions are a 4-byte big-endian length followed by
//! that many payload bytes. Request payloads are single JSON objects and
//! are capped at [`MAX_FRAME`] bytes; a connection may send any number of
//! frames sequentially. Two verbs:
//!
//! * `{"verb": "compile", ...}` — the remaining keys mirror the CLI
//!   flags: `network`/`layer`/`zoo`, `arch`, `mapper`, `objective`,
//!   `budget`, `seed`, `threads`, `seed_policy`. The reply is the
//!   `api_v1` compile document, or an error document
//!   `{"schema":"api_v1","kind":"error","code":...,"message":...}` with
//!   the same stable codes as CLI stderr.
//! * `{"verb": "metrics"}` — a plain-text, line-oriented scrape of the
//!   session counters (`local_mapper_*` lines): requests, hit rate,
//!   disk hits, coalesced searches, p50/p99 service time, queue depth,
//!   and — when a cache dir is configured — the lifetime totals from the
//!   persistent sidecar.
//!
//! # Backpressure
//!
//! Admission is bounded: at most [`ServeConfig::queue_limit`] compile
//! requests may be in flight at once. Past the high-water mark a request
//! is rejected *before* it touches the session with a typed `E_BUSY`
//! error document carrying the current `queue_depth`, so well-behaved
//! clients can back off instead of piling onto a saturated daemon.
//!
//! # Lifecycle
//!
//! [`run`] is the CLI entry point: it installs `SIGINT`/`SIGTERM`
//! handlers that flip one atomic, serves until a signal arrives, then
//! joins the connection threads and removes the socket file. [`spawn`]
//! is the embeddable/test entry point: same daemon, stopped by dropping
//! (or explicitly stopping) the returned [`ServeHandle`].

use super::json::{self, Json};
use super::request::CompileRequest;
use super::session::Session;
use super::Error;
use crate::coordinator::{PersistentCache, SeedPolicy};
use crate::fault;
use crate::mappers::Objective;
use std::io::{Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Hard cap on a request frame's payload size (1 MiB). Requests are small
/// JSON objects; anything larger is a protocol error and the connection
/// is dropped rather than buffered.
pub const MAX_FRAME: usize = 1 << 20;

/// How the daemon listens and admits work.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Unix-socket path to bind. A stale file from a dead daemon is
    /// removed before binding.
    pub socket: String,
    /// High-water mark for in-flight compile requests; request N+1 is
    /// rejected with `E_BUSY`. `0` rejects everything (useful to test
    /// client backoff).
    pub queue_limit: usize,
    /// Directory for the persistent mapping cache, applied to every
    /// compile served (client requests cannot override it — the daemon
    /// owns its disk state).
    pub cache_dir: Option<String>,
    /// Default worker threads per compile when the client does not send
    /// `threads`.
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            socket: "/tmp/local-mapper.sock".into(),
            queue_limit: 64,
            cache_dir: None,
            threads: 4,
        }
    }
}

/// Signal-to-shutdown latch: `SIGINT`/`SIGTERM` handlers may only flip
/// this atomic (nothing else is async-signal-safe); the accept loop polls
/// it.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

const SIGINT: i32 = 2;
const SIGTERM: i32 = 15;

extern "C" {
    /// libc `signal(2)` — the only libc call in the crate, used instead
    /// of a signal-handling dependency (the build is offline by design).
    fn signal(signum: i32, handler: usize) -> usize;
}

/// The installed handler: one atomic store and nothing else.
extern "C" fn flag_shutdown(_signum: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

fn install_signal_handlers() {
    // SAFETY: `flag_shutdown` is async-signal-safe (a single atomic
    // store) and stays valid for the process lifetime.
    unsafe {
        signal(SIGINT, flag_shutdown as usize);
        signal(SIGTERM, flag_shutdown as usize);
    }
}

/// Everything the connection threads share.
struct ServeState {
    cfg: ServeConfig,
    session: Session,
    /// In-flight admitted compiles (the admission queue depth).
    depth: AtomicU64,
}

/// RAII admission slot: holds one unit of [`ServeState::depth`] from
/// admission until the reply is built, on every exit path.
struct AdmissionSlot<'a> {
    depth: &'a AtomicU64,
}

impl<'a> AdmissionSlot<'a> {
    /// Claim a slot, or `None` past the high-water mark (the failed claim
    /// leaves the depth unchanged).
    fn acquire(depth: &'a AtomicU64, limit: usize) -> Option<Self> {
        let prev = depth.fetch_add(1, Ordering::SeqCst);
        if prev as usize >= limit {
            depth.fetch_sub(1, Ordering::SeqCst);
            return None;
        }
        Some(Self { depth })
    }
}

impl Drop for AdmissionSlot<'_> {
    fn drop(&mut self) {
        self.depth.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A running daemon started by [`spawn`]: stop it explicitly or by
/// dropping the handle (both join the accept loop and every connection
/// thread, then remove the socket file).
pub struct ServeHandle {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    socket: String,
}

impl ServeHandle {
    /// The socket path the daemon is listening on.
    pub fn socket(&self) -> &str {
        &self.socket
    }

    /// Stop the daemon and wait for it to finish in-flight work.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl std::fmt::Debug for ServeHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServeHandle").field("socket", &self.socket).finish()
    }
}

/// Start the daemon on a background thread and return a handle to it.
/// This is the embeddable (and testable) form of [`run`]; it installs no
/// signal handlers.
pub fn spawn(cfg: ServeConfig) -> Result<ServeHandle, Error> {
    // A stale socket file from a crashed daemon would make bind fail with
    // AddrInUse even though nobody is listening.
    let _ = std::fs::remove_file(&cfg.socket);
    let listener =
        UnixListener::bind(&cfg.socket).map_err(|e| Error::io(cfg.socket.clone(), e))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| Error::io(cfg.socket.clone(), e))?;
    let stop = Arc::new(AtomicBool::new(false));
    let socket = cfg.socket.clone();
    let state =
        Arc::new(ServeState { cfg, session: Session::new(), depth: AtomicU64::new(0) });
    let loop_stop = Arc::clone(&stop);
    let thread = std::thread::spawn(move || accept_loop(listener, state, loop_stop));
    Ok(ServeHandle { stop, thread: Some(thread), socket })
}

/// The CLI entry point: serve in the foreground until `SIGINT`/`SIGTERM`,
/// then shut down cleanly (join connections, remove the socket file).
pub fn run(cfg: ServeConfig) -> Result<(), Error> {
    SHUTDOWN.store(false, Ordering::SeqCst);
    install_signal_handlers();
    let handle = spawn(cfg)?;
    while !SHUTDOWN.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(50));
    }
    handle.stop();
    Ok(())
}

/// Accept connections until stopped; each connection gets its own thread
/// (compiles shard internally, so connection threads spend their time
/// blocked on the session, not computing).
fn accept_loop(listener: UnixListener, state: Arc<ServeState>, stop: Arc<AtomicBool>) {
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) && !SHUTDOWN.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                conns.retain(|h| !h.is_finished());
                let state = Arc::clone(&state);
                let stop = Arc::clone(&stop);
                conns.push(std::thread::spawn(move || serve_conn(stream, state, stop)));
            }
            // Nonblocking listener: WouldBlock is the idle case; any other
            // accept error is transient (EMFILE, ECONNABORTED) — back off
            // and keep serving either way.
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    for h in conns {
        let _ = h.join();
    }
    let _ = std::fs::remove_file(&state.cfg.socket);
}

/// One connection: frames in, frames out, until EOF, a protocol error, or
/// shutdown.
fn serve_conn(mut stream: UnixStream, state: Arc<ServeState>, stop: Arc<AtomicBool>) {
    // Short read timeout so a mid-frame read wakes up to observe the stop
    // flag instead of pinning the thread on a silent client.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    loop {
        let payload = match read_frame(&mut stream, &stop) {
            Ok(Some(p)) => p,
            // Clean EOF, shutdown, or a protocol violation: drop the
            // connection either way (errors are per-frame only when the
            // frame itself arrived intact).
            Ok(None) | Err(_) => return,
        };
        let reply = dispatch(&state, &payload);
        if write_frame(&mut stream, reply.as_bytes()).is_err() {
            return;
        }
    }
}

/// Read one length-prefixed frame. `Ok(None)` means clean EOF at a frame
/// boundary or shutdown; torn frames and oversized lengths are errors.
fn read_frame(stream: &mut UnixStream, stop: &AtomicBool) -> std::io::Result<Option<Vec<u8>>> {
    let mut header = [0u8; 4];
    if read_full(stream, &mut header, stop, true)?.is_none() {
        return Ok(None);
    }
    let len = u32::from_be_bytes(header) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut payload = vec![0u8; len];
    if read_full(stream, &mut payload, stop, false)?.is_none() {
        return Ok(None);
    }
    Ok(Some(payload))
}

/// Fill `buf` from the stream, riding out read timeouts (they exist only
/// so the stop flag is observed). `Ok(None)` on shutdown, or on EOF when
/// `eof_ok` and no byte has arrived yet (a client hanging up between
/// frames); EOF mid-buffer is a torn frame and errors.
fn read_full(
    stream: &mut UnixStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    eof_ok: bool,
) -> std::io::Result<Option<()>> {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::SeqCst) || SHUTDOWN.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 && eof_ok {
                    return Ok(None);
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed mid-frame",
                ));
            }
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(Some(()))
}

/// Write one length-prefixed frame.
fn write_frame(stream: &mut UnixStream, payload: &[u8]) -> std::io::Result<()> {
    stream.write_all(&(payload.len() as u32).to_be_bytes())?;
    stream.write_all(payload)?;
    stream.flush()
}

/// Turn one request payload into one reply payload. Every failure becomes
/// an error document — the connection only dies on framing violations.
fn dispatch(state: &ServeState, payload: &[u8]) -> String {
    let Ok(text) = std::str::from_utf8(payload) else {
        return error_doc("E_REQUEST", "request frame is not UTF-8", None);
    };
    let doc = match json::parse(text) {
        Ok(doc) => doc,
        Err(e) => return error_doc("E_JSON", &e.to_string(), None),
    };
    match doc.get("verb").and_then(Json::as_str).unwrap_or("compile") {
        "metrics" => metrics_text(state),
        "compile" => {
            let Some(slot) = AdmissionSlot::acquire(&state.depth, state.cfg.queue_limit)
            else {
                return error_doc(
                    "E_BUSY",
                    &format!(
                        "admission queue full ({} in flight, limit {})",
                        state.depth.load(Ordering::SeqCst),
                        state.cfg.queue_limit
                    ),
                    Some(state.depth.load(Ordering::SeqCst)),
                );
            };
            // Injection point for the robustness tests: `stall:<ms>`
            // holds the admission slot so the queue fills behind it.
            fault::stall_daemon();
            let reply = match request_from(&doc, &state.cfg) {
                Ok(req) => match state.session.compile(&req) {
                    Ok(report) => json::compile_report(&report),
                    Err(e) => error_doc(e.code(), &e.to_string(), None),
                },
                Err(e) => error_doc(e.code(), &e.to_string(), None),
            };
            drop(slot);
            reply
        }
        other => error_doc(
            "E_REQUEST",
            &format!("unknown verb {other:?} (expected compile or metrics)"),
            None,
        ),
    }
}

/// Build a [`CompileRequest`] from a compile verb's JSON fields. The
/// daemon's own cache dir and default thread count apply unless the
/// client overrides threads (it can never override the cache dir).
fn request_from(doc: &Json, cfg: &ServeConfig) -> Result<CompileRequest, Error> {
    let mut req = CompileRequest::new().threads(cfg.threads);
    if doc.get("zoo").and_then(Json::as_bool) == Some(true) {
        req = req.zoo();
    }
    if let Some(n) = doc.get("network").and_then(Json::as_str) {
        req = req.network(n);
    }
    if let Some(s) = doc.get("layer").and_then(Json::as_str) {
        req = req.layer_spec(s);
    }
    if let Some(a) = doc.get("arch").and_then(Json::as_str) {
        req = req.arch_preset(a);
    }
    if let Some(m) = doc.get("mapper").and_then(Json::as_str) {
        req = req.mapper(m);
    }
    if let Some(o) = doc.get("objective").and_then(Json::as_str) {
        let objective = Objective::parse(o).ok_or_else(|| {
            Error::request(format!("unknown objective {o:?} (expected {})", Objective::SPEC))
        })?;
        req = req.objective(objective);
    }
    if let Some(b) = doc.get("budget").and_then(Json::as_u64) {
        req = req.budget(b);
    }
    if let Some(s) = doc.get("seed").and_then(Json::as_u64) {
        req = req.seed(s);
    }
    if let Some(t) = doc.get("threads").and_then(Json::as_u64) {
        req = req.threads(t.max(1) as usize);
    }
    if let Some(p) = doc.get("seed_policy").and_then(Json::as_str) {
        let policy = SeedPolicy::parse(p).ok_or_else(|| {
            Error::request(format!(
                "unknown seed policy {p:?} (expected {})",
                SeedPolicy::SPEC
            ))
        })?;
        req = req.seed_policy(policy);
    }
    if let Some(g) = doc.get("graph_mode").and_then(Json::as_str) {
        let mode = crate::graph::GraphMode::parse(g).ok_or_else(|| {
            Error::request(format!(
                "unknown graph mode {g:?} (expected {})",
                crate::graph::GraphMode::SPEC
            ))
        })?;
        req = req.graph_mode(mode);
    }
    if let Some(dir) = &cfg.cache_dir {
        req = req.cache_dir(dir.clone());
    }
    Ok(req)
}

/// A single-line `api_v1` error document, shape-compatible with the CLI's
/// stderr documents; `queue_depth` rides along on `E_BUSY` only.
fn error_doc(code: &str, message: &str, queue_depth: Option<u64>) -> String {
    let mut doc = format!(
        "{{\"schema\": \"{}\", \"kind\": \"error\", \"code\": \"{}\", \"message\": \"{}\"",
        json::SCHEMA,
        code,
        json::esc(message)
    );
    if let Some(depth) = queue_depth {
        doc.push_str(&format!(", \"queue_depth\": {depth}"));
    }
    doc.push('}');
    doc
}

/// The `metrics` verb's plain-text scrape: one `local_mapper_<counter>
/// <value>` line per counter, session-lifetime live values first, then —
/// when a cache dir is configured — the process-spanning lifetime totals
/// from the persistent sidecar (which include the current session's
/// still-running services only after they flush on drop, so the two
/// sections are reported separately rather than summed).
fn metrics_text(state: &ServeState) -> String {
    use std::fmt::Write as _;
    let m = state.session.metrics();
    let ps = state.session.service_percentiles(&[0.50, 0.99]);
    let mut out = String::new();
    let _ = writeln!(out, "local_mapper_requests_total {}", m.requests);
    let _ = writeln!(out, "local_mapper_cache_hits_total {}", m.cache_hits);
    let _ = writeln!(out, "local_mapper_disk_hits_total {}", m.disk_hits);
    let _ = writeln!(out, "local_mapper_coalesced_total {}", m.coalesced);
    let _ = writeln!(out, "local_mapper_errors_total {}", m.errors);
    let _ = writeln!(out, "local_mapper_fallbacks_total {}", m.fallbacks);
    let _ = writeln!(out, "local_mapper_hit_rate {:.6}", m.hit_rate());
    let _ = writeln!(out, "local_mapper_p50_service_seconds {:.6}", ps[0].as_secs_f64());
    let _ = writeln!(out, "local_mapper_p99_service_seconds {:.6}", ps[1].as_secs_f64());
    let _ = writeln!(
        out,
        "local_mapper_queue_depth {}",
        state.depth.load(Ordering::SeqCst)
    );
    let _ = writeln!(out, "local_mapper_services {}", m.services);
    if let Some(dir) = &state.cfg.cache_dir {
        if let Ok(log) = PersistentCache::open(dir) {
            let t = log.read_totals();
            let _ = writeln!(out, "local_mapper_lifetime_requests_total {}", t.requests);
            let _ = writeln!(
                out,
                "local_mapper_lifetime_cache_hits_total {}",
                t.cache_hits
            );
            let _ = writeln!(out, "local_mapper_lifetime_fallbacks_total {}", t.fallbacks);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_docs_are_valid_json_with_escaped_messages() {
        let doc = error_doc("E_BUSY", "queue \"full\"\n", Some(3));
        let parsed = json::parse(&doc).unwrap();
        assert_eq!(parsed.get("schema").and_then(Json::as_str), Some(json::SCHEMA));
        assert_eq!(parsed.get("kind").and_then(Json::as_str), Some("error"));
        assert_eq!(parsed.get("code").and_then(Json::as_str), Some("E_BUSY"));
        assert_eq!(parsed.get("message").and_then(Json::as_str), Some("queue \"full\"\n"));
        assert_eq!(parsed.get("queue_depth").and_then(Json::as_u64), Some(3));
        let plain = error_doc("E_REQUEST", "nope", None);
        assert!(json::parse(&plain).unwrap().get("queue_depth").is_none());
    }

    #[test]
    fn requests_parse_from_wire_fields() {
        let doc = json::parse(
            "{\"verb\": \"compile\", \"network\": \"alexnet\", \"arch\": \"eyeriss\", \
             \"objective\": \"edp\", \"threads\": 2, \"seed_policy\": \"off\"}",
        )
        .unwrap();
        let cfg = ServeConfig {
            cache_dir: Some("/tmp/never-opened".into()),
            ..ServeConfig::default()
        };
        let req = request_from(&doc, &cfg).unwrap();
        assert_eq!(req.cache_dir.as_deref(), Some("/tmp/never-opened"));
        // The request resolves without touching the cache dir (that only
        // happens at service start).
        let resolved = req.resolve().unwrap();
        assert_eq!(resolved.networks.len(), 1);
        assert_eq!(resolved.threads, 2);
    }

    #[test]
    fn bad_objective_and_policy_are_typed_request_errors() {
        let cfg = ServeConfig::default();
        let bad_obj = json::parse("{\"objective\": \"speed\"}").unwrap();
        let e = request_from(&bad_obj, &cfg).unwrap_err();
        assert_eq!(e.code(), "E_REQUEST");
        let bad_pol = json::parse("{\"seed_policy\": \"always\"}").unwrap();
        let e = request_from(&bad_pol, &cfg).unwrap_err();
        assert_eq!(e.code(), "E_REQUEST");
    }

    #[test]
    fn admission_slots_enforce_the_high_water_mark() {
        let depth = AtomicU64::new(0);
        let a = AdmissionSlot::acquire(&depth, 2).unwrap();
        let b = AdmissionSlot::acquire(&depth, 2).unwrap();
        assert!(AdmissionSlot::acquire(&depth, 2).is_none(), "past high-water mark");
        assert_eq!(depth.load(Ordering::SeqCst), 2, "failed claim must not leak depth");
        drop(a);
        let c = AdmissionSlot::acquire(&depth, 2).unwrap();
        drop(b);
        drop(c);
        assert_eq!(depth.load(Ordering::SeqCst), 0);
        assert!(AdmissionSlot::acquire(&depth, 0).is_none(), "zero limit rejects all");
    }
}
