//! The stable, embeddable compilation API.
//!
//! Everything the CLI can do is reachable programmatically through three
//! pieces, layered so a service or another compiler can embed the mapper
//! without touching `main.rs`:
//!
//! 1. [`CompileRequest`] — a typed, builder-style description of *what* to
//!    compile: a workload ([`WorkloadSpec`]: zoo network, single layer
//!    spec, YAML file, explicit layer list, or the whole batch zoo), an
//!    accelerator ([`ArchSpec`]: preset name, YAML file, or an in-memory
//!    config), a mapper spec plus [`crate::mappers::SearchParams`], and
//!    the worker-thread count.
//! 2. [`Session`] — the facade that owns the
//!    [`crate::coordinator::MappingService`] instances behind the
//!    requests. Services (hence mapping caches and
//!    [`crate::coordinator::ServiceMetrics`]) are keyed by
//!    (arch, mapper, search params, threads) and **live for the whole
//!    session**, so repeated requests share warm caches. [`Session::compile`]
//!    returns a typed [`CompileReport`]; [`Session::compile_iter`] streams
//!    [`LayerReport`]s as the worker pool finishes them.
//! 3. [`json`] — a dependency-free, versioned JSON serializer (schema tag
//!    `"api_v1"`, byte-stable key order) for every report type, plus a
//!    strict parser used by the validation tooling and tests.
//!
//! All failures funnel into one crate-wide [`Error`] with a stable
//! [`Error::code`] per category and an [`ErrorClass`] that fixes the CLI
//! exit code (usage = 2, invalid input = 3, mapping/execution failure
//! = 4).
//!
//! ```
//! use local_mapper::api::{CompileRequest, Session};
//!
//! let session = Session::new();
//! let report = session
//!     .compile(&CompileRequest::new().network("alexnet"))
//!     .unwrap();
//! assert_eq!(report.total_layers(), 5);
//! assert!(report.total_energy_uj() > 0.0);
//! let doc = local_mapper::api::json::compile_report(&report);
//! assert!(doc.starts_with("{\n  \"schema\": \"api_v1\""));
//! ```

pub mod json;
pub mod request;
pub mod serve;
pub mod session;

pub use crate::coordinator::SeedPolicy;
pub use crate::graph::{GraphMode, GraphReport};
pub use request::{ArchSpec, CompileRequest, WorkloadSpec};
pub use serve::{ServeConfig, ServeHandle};
pub use session::{
    CompileReport, ExploreReport, LayerReport, LayerStream, NetworkReport, Session,
    SessionMetrics, SimulateReport,
};

use crate::arch::config::ConfigError;
use crate::mappers::MapError;
use crate::mapping::MappingError;
use crate::runtime::RuntimeError;
use crate::util::yaml::YamlError;
use crate::workload::config::WorkloadError;
use std::fmt;

/// Coarse error class: what kind of failure this is, independent of the
/// module that produced it. Fixes the CLI exit code so scripts can branch
/// on *category* without parsing messages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ErrorClass {
    /// The request itself is malformed: unknown network/mapper/arch/
    /// objective/format name, bad layer spec, empty workload (exit 2).
    Usage,
    /// The request is well-formed but an input failed to load or parse:
    /// YAML syntax/structure errors, I/O failures (exit 3).
    InvalidInput,
    /// Valid inputs, but mapping or execution failed: no valid mapping in
    /// budget, mapping validation failure, runtime error (exit 4).
    Failure,
}

impl ErrorClass {
    /// The process exit code the CLI uses for this class.
    pub fn exit_code(self) -> i32 {
        match self {
            ErrorClass::Usage => 2,
            ErrorClass::InvalidInput => 3,
            ErrorClass::Failure => 4,
        }
    }
}

/// The crate-wide error: one enum wrapping every module error so embedders
/// handle a single type with stable codes, instead of six module enums and
/// ad-hoc `String`s.
#[derive(Debug)]
pub enum Error {
    /// Malformed request (unknown names, bad specs). Produced by the
    /// request resolver and the CLI flag parser.
    Request(String),
    /// Workload YAML loading/validation failed
    /// ([`crate::workload::config`]).
    Workload(WorkloadError),
    /// Accelerator config loading/validation failed
    /// ([`crate::arch::config`]).
    Config(ConfigError),
    /// Raw YAML syntax error outside a workload/config wrapper
    /// ([`crate::util::yaml`]).
    Yaml(YamlError),
    /// A constructed mapping failed validation
    /// ([`crate::mapping::MappingError`]).
    Mapping(MappingError),
    /// A mapper failed to produce a valid mapping
    /// ([`crate::mappers::MapError`]).
    Map(MapError),
    /// PJRT runtime failure ([`crate::runtime::RuntimeError`]).
    Runtime(RuntimeError),
    /// A JSON document named by the request failed to parse (e.g. the
    /// donor report for `--recompile-from`).
    Json(json::JsonError),
    /// Filesystem I/O failure on a path named by the request.
    Io {
        /// The path being read or written.
        path: String,
        /// The underlying I/O error.
        source: std::io::Error,
    },
    /// The serve daemon's admission queue is past its high-water mark;
    /// the request was rejected without being enqueued (backpressure —
    /// DESIGN.md §16). Retry after draining.
    Busy(String),
}

impl Error {
    /// Build a [`Error::Request`] from any displayable message.
    pub fn request(msg: impl Into<String>) -> Self {
        Error::Request(msg.into())
    }

    /// Build a [`Error::Io`] tagged with the offending path.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Stable machine-readable code for the error category. These are part
    /// of the API contract: embedders and scripts may match on them, so a
    /// code is never renamed or reused (pinned by `error_codes_are_stable`).
    pub fn code(&self) -> &'static str {
        match self {
            Error::Request(_) => "E_REQUEST",
            Error::Workload(_) => "E_WORKLOAD",
            Error::Config(_) => "E_CONFIG",
            Error::Yaml(_) => "E_YAML",
            Error::Mapping(_) => "E_MAPPING",
            Error::Map(MapError::Panicked(_)) => "E_PANIC",
            Error::Map(_) => "E_SEARCH",
            Error::Runtime(_) => "E_RUNTIME",
            Error::Json(_) => "E_JSON",
            Error::Io { .. } => "E_IO",
            Error::Busy(_) => "E_BUSY",
        }
    }

    /// The error's [`ErrorClass`] (hence CLI exit code).
    pub fn class(&self) -> ErrorClass {
        match self {
            Error::Request(_) => ErrorClass::Usage,
            Error::Workload(_)
            | Error::Config(_)
            | Error::Yaml(_)
            | Error::Json(_)
            | Error::Io { .. } => ErrorClass::InvalidInput,
            Error::Mapping(_) | Error::Map(_) | Error::Runtime(_) | Error::Busy(_) => {
                ErrorClass::Failure
            }
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Request(msg) => f.write_str(msg),
            Error::Workload(e) => fmt::Display::fmt(e, f),
            Error::Config(e) => fmt::Display::fmt(e, f),
            Error::Yaml(e) => fmt::Display::fmt(e, f),
            Error::Mapping(e) => fmt::Display::fmt(e, f),
            Error::Map(e) => fmt::Display::fmt(e, f),
            Error::Runtime(e) => fmt::Display::fmt(e, f),
            Error::Json(e) => fmt::Display::fmt(e, f),
            Error::Io { path, source } => write!(f, "io: {path}: {source}"),
            Error::Busy(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Request(_) => None,
            Error::Workload(e) => Some(e),
            Error::Config(e) => Some(e),
            Error::Yaml(e) => Some(e),
            Error::Mapping(e) => Some(e),
            Error::Map(e) => Some(e),
            Error::Runtime(e) => Some(e),
            Error::Json(e) => Some(e),
            Error::Io { source, .. } => Some(source),
            Error::Busy(_) => None,
        }
    }
}

impl From<WorkloadError> for Error {
    fn from(e: WorkloadError) -> Self {
        Error::Workload(e)
    }
}

impl From<ConfigError> for Error {
    fn from(e: ConfigError) -> Self {
        Error::Config(e)
    }
}

impl From<YamlError> for Error {
    fn from(e: YamlError) -> Self {
        Error::Yaml(e)
    }
}

impl From<MappingError> for Error {
    fn from(e: MappingError) -> Self {
        Error::Mapping(e)
    }
}

impl From<MapError> for Error {
    fn from(e: MapError) -> Self {
        Error::Map(e)
    }
}

impl From<RuntimeError> for Error {
    fn from(e: RuntimeError) -> Self {
        Error::Runtime(e)
    }
}

impl From<json::JsonError> for Error {
    fn from(e: json::JsonError) -> Self {
        Error::Json(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_are_stable() {
        // Codes and exit codes are API contract: scripts match on them.
        let cases: Vec<(Error, &str, i32)> = vec![
            (Error::request("x"), "E_REQUEST", 2),
            (
                Error::from(WorkloadError::Invalid("x".into())),
                "E_WORKLOAD",
                3,
            ),
            (Error::from(ConfigError::Invalid("x".into())), "E_CONFIG", 3),
            (
                Error::from(YamlError { line: 1, msg: "x".into() }),
                "E_YAML",
                3,
            ),
            (
                Error::from(MappingError::LevelMismatch { found: 2, expected: 3 }),
                "E_MAPPING",
                4,
            ),
            (
                Error::from(MapError::NoValidMapping("x".into())),
                "E_SEARCH",
                4,
            ),
            (
                Error::from(MapError::Panicked("x".into())),
                "E_PANIC",
                4,
            ),
            (Error::from(RuntimeError::msg("x")), "E_RUNTIME", 4),
            (
                Error::from(json::JsonError { pos: 0, msg: "x".into() }),
                "E_JSON",
                3,
            ),
            (
                Error::io("/p", std::io::Error::new(std::io::ErrorKind::NotFound, "x")),
                "E_IO",
                3,
            ),
            (Error::Busy("queue full".into()), "E_BUSY", 4),
        ];
        for (e, code, exit) in cases {
            assert_eq!(e.code(), code);
            assert_eq!(e.class().exit_code(), exit, "{code}");
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn wrapped_sources_are_reachable() {
        use std::error::Error as _;
        let e = Error::from(WorkloadError::Invalid("bad".into()));
        assert!(e.source().is_some());
        assert!(e.to_string().contains("bad"));
        assert!(Error::request("no").source().is_none());
    }
}
