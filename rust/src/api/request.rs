//! Typed compilation requests — the builder side of the API.
//!
//! A [`CompileRequest`] names a workload, an accelerator, a mapper and its
//! search knobs without resolving any of them; [`CompileRequest::resolve`]
//! turns the specs into concrete layers, an [`Accelerator`] and an
//! [`AnyMapper`] with typed [`crate::api::Error`]s for every way that can
//! fail. The CLI's `map`, `compile`, `compile-all`, `simulate` and
//! `explore` subcommands are all thin translations of their flags into one
//! of these.

use super::Error;
use crate::arch::{config, presets, Accelerator};
use crate::coordinator::SeedPolicy;
use crate::graph::GraphMode;
use crate::mappers::{AnyMapper, Objective, SearchParams};
use crate::workload::{config as wconfig, zoo, Layer};

/// What to compile.
#[derive(Debug, Clone)]
pub enum WorkloadSpec {
    /// A zoo network by name ([`zoo::network`] spellings).
    Network(String),
    /// One layer, CLI spelling: `network:index` (1-based) or explicit
    /// `MxCxRxSxPxQ` dims (see [`parse_layer_spec`]).
    LayerSpec(String),
    /// An explicit, already-constructed layer.
    Layer(Layer),
    /// A workload YAML file ([`crate::workload::config`] format).
    File(String),
    /// An explicit named layer list (embedders with their own IR).
    Layers {
        /// Label used in reports.
        name: String,
        /// The layers, in network order.
        layers: Vec<Layer>,
    },
    /// The whole batch zoo ([`zoo::batch_zoo`]) — what `compile-all`
    /// compiles.
    Zoo,
}

/// Which accelerator to target.
#[derive(Debug, Clone)]
pub enum ArchSpec {
    /// A preset by name ([`presets::by_name`]: eyeriss / nvdla /
    /// shidiannao).
    Preset(String),
    /// A Timeloop-style YAML config file ([`crate::arch::config`]).
    File(String),
    /// An explicit, already-constructed accelerator.
    Config(Box<Accelerator>),
}

/// A typed compilation request. Build with the fluent setters, hand to
/// [`crate::api::Session::compile`]; nothing resolves until then.
#[derive(Debug, Clone)]
pub struct CompileRequest {
    /// The workload to compile.
    pub workload: WorkloadSpec,
    /// The accelerator to target.
    pub arch: ArchSpec,
    /// Mapper spec ([`AnyMapper::SPEC`] spellings).
    pub mapper: String,
    /// Search knobs threaded into the mapper (budget, seed, objective,
    /// search threads, pruning).
    pub search: SearchParams,
    /// Worker threads for the mapping service the request runs on.
    pub threads: usize,
    /// Abort the batch on the first hard layer failure instead of
    /// collecting it into [`crate::api::CompileReport::failures`] and
    /// compiling the rest (off by default — per-layer isolation).
    pub fail_fast: bool,
    /// Cross-layer warm-start policy for the mapping service (DESIGN.md
    /// §15). Defaults to [`SeedPolicy::Adapt`]; `Off` restores the
    /// bit-for-bit unseeded service behaviour.
    pub seed_policy: SeedPolicy,
    /// Directory for the disk-backed persistent mapping cache (DESIGN.md
    /// §16): solved mappings are appended to an on-disk log and replayed
    /// on the next request with the same directory, so repeat compiles —
    /// even across processes — cost zero mapper evaluations. `None`
    /// (default) keeps the service memory-only.
    pub cache_dir: Option<String>,
    /// Graph-level compilation mode (DESIGN.md §17; CLI `--graph-mode`):
    /// `Off` (default) keeps the flat per-layer pipeline bit for bit,
    /// `Fuse` runs the DAG fusion pass, `CoSelect` additionally scores
    /// fused groups with the chosen mappings' DRAM traffic. Analysis-only
    /// in every mode — per-layer mappings never change.
    pub graph_mode: GraphMode,
}

impl Default for CompileRequest {
    fn default() -> Self {
        Self {
            workload: WorkloadSpec::Network("vgg16".into()),
            arch: ArchSpec::Preset("eyeriss".into()),
            mapper: "local".into(),
            search: SearchParams::default(),
            threads: 4,
            fail_fast: false,
            seed_policy: SeedPolicy::default(),
            cache_dir: None,
            graph_mode: GraphMode::default(),
        }
    }
}

impl CompileRequest {
    /// A request with the defaults: VGG-16 on Eyeriss, LOCAL mapper,
    /// default [`SearchParams`], 4 service workers.
    pub fn new() -> Self {
        Self::default()
    }

    /// Select a zoo network by name.
    pub fn network(mut self, name: impl Into<String>) -> Self {
        self.workload = WorkloadSpec::Network(name.into());
        self
    }

    /// Select one layer by CLI spec (`network:index` or `MxCxRxSxPxQ`).
    pub fn layer_spec(mut self, spec: impl Into<String>) -> Self {
        self.workload = WorkloadSpec::LayerSpec(spec.into());
        self
    }

    /// Select one explicit layer.
    pub fn layer(mut self, layer: Layer) -> Self {
        self.workload = WorkloadSpec::Layer(layer);
        self
    }

    /// Select a workload YAML file.
    pub fn workload_file(mut self, path: impl Into<String>) -> Self {
        self.workload = WorkloadSpec::File(path.into());
        self
    }

    /// Select an explicit named layer list.
    pub fn layers(mut self, name: impl Into<String>, layers: Vec<Layer>) -> Self {
        self.workload = WorkloadSpec::Layers { name: name.into(), layers };
        self
    }

    /// Select the whole batch zoo (`compile-all`).
    pub fn zoo(mut self) -> Self {
        self.workload = WorkloadSpec::Zoo;
        self
    }

    /// Target an accelerator preset by name.
    pub fn arch_preset(mut self, name: impl Into<String>) -> Self {
        self.arch = ArchSpec::Preset(name.into());
        self
    }

    /// Target an accelerator YAML config file.
    pub fn arch_file(mut self, path: impl Into<String>) -> Self {
        self.arch = ArchSpec::File(path.into());
        self
    }

    /// Target an explicit accelerator config.
    pub fn accelerator(mut self, acc: Accelerator) -> Self {
        self.arch = ArchSpec::Config(Box::new(acc));
        self
    }

    /// Choose the mapper ([`AnyMapper::SPEC`] spellings).
    pub fn mapper(mut self, spec: impl Into<String>) -> Self {
        self.mapper = spec.into();
        self
    }

    /// Replace the whole search-parameter block.
    pub fn search(mut self, params: SearchParams) -> Self {
        self.search = params;
        self
    }

    /// Set the per-layer search budget.
    pub fn budget(mut self, budget: u64) -> Self {
        self.search.budget = budget;
        self
    }

    /// Set the stochastic-mapper seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.search.seed = seed;
        self
    }

    /// Set the search objective.
    pub fn objective(mut self, objective: Objective) -> Self {
        self.search.objective = objective;
        self
    }

    /// Set the per-mapper search-thread count.
    pub fn search_threads(mut self, threads: usize) -> Self {
        self.search.threads = threads.max(1);
        self
    }

    /// Enable/disable bound-based pruning.
    pub fn prune(mut self, prune: bool) -> Self {
        self.search.prune = prune;
        self
    }

    /// Request certified branch-and-bound search (exhaustive mapper):
    /// the report's `certified` flag is `true` when the budget provably
    /// covered the whole candidate space.
    pub fn certify(mut self, certify: bool) -> Self {
        self.search.certify = certify;
        self
    }

    /// Set a per-layer wall-clock search deadline in milliseconds. A
    /// search that overruns it returns its best-so-far (status
    /// `degraded`); one that cannot produce anything in time falls back
    /// to the O(1) LOCAL mapping (status `fell_back`).
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.search.deadline_ms = Some(ms);
        self
    }

    /// Abort on the first hard layer failure instead of isolating it in
    /// the report's `failures` list.
    pub fn fail_fast(mut self, fail_fast: bool) -> Self {
        self.fail_fast = fail_fast;
        self
    }

    /// Set the cross-layer warm-start policy ([`SeedPolicy::Off`] restores
    /// the bit-for-bit unseeded service behaviour).
    pub fn seed_policy(mut self, policy: SeedPolicy) -> Self {
        self.seed_policy = policy;
        self
    }

    /// Attach a disk-backed persistent mapping cache directory (DESIGN.md
    /// §16; CLI `--cache-dir`, env `LOCAL_MAPPER_CACHE_DIR`).
    pub fn cache_dir(mut self, dir: impl Into<String>) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }

    /// Set the mapping-service worker-thread count.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Set the graph-level compilation mode ([`GraphMode::Off`] keeps the
    /// flat per-layer pipeline bit for bit).
    pub fn graph_mode(mut self, mode: GraphMode) -> Self {
        self.graph_mode = mode;
        self
    }

    /// Resolve every spec into concrete values. All the ways a request can
    /// be wrong surface here as typed errors: unknown names are
    /// [`Error::Request`] (usage), unreadable/invalid files are
    /// [`Error::Workload`] / [`Error::Config`] (invalid input).
    pub fn resolve(&self) -> Result<ResolvedRequest, Error> {
        let networks = match &self.workload {
            WorkloadSpec::Network(name) => {
                let layers = zoo::network(name)
                    .ok_or_else(|| Error::request(format!("unknown network '{name}'")))?;
                vec![(name.clone(), layers)]
            }
            WorkloadSpec::LayerSpec(spec) => {
                let layer = parse_layer_spec(spec)?;
                vec![(layer.name.clone(), vec![layer])]
            }
            WorkloadSpec::Layer(layer) => vec![(layer.name.clone(), vec![layer.clone()])],
            WorkloadSpec::File(path) => {
                let layers = wconfig::layers_from_file(path)?;
                vec![(path.clone(), layers)]
            }
            WorkloadSpec::Layers { name, layers } => {
                if layers.is_empty() {
                    return Err(Error::request(format!("workload '{name}' has no layers")));
                }
                vec![(name.clone(), layers.clone())]
            }
            WorkloadSpec::Zoo => zoo::batch_zoo(),
        };
        let acc = match &self.arch {
            ArchSpec::Preset(name) => presets::by_name(name).ok_or_else(|| {
                Error::request(format!("unknown arch '{name}' (eyeriss|nvdla|shidiannao)"))
            })?,
            ArchSpec::File(path) => config::accelerator_from_file(path)?,
            ArchSpec::Config(acc) => (**acc).clone(),
        };
        let params = SearchParams { budget: self.search.budget.max(1), ..self.search };
        let mapper = AnyMapper::parse(&self.mapper, params).ok_or_else(|| {
            Error::request(format!("unknown mapper '{}' ({})", self.mapper, AnyMapper::SPEC))
        })?;
        Ok(ResolvedRequest { networks, acc, mapper, threads: self.threads.max(1) })
    }
}

/// A fully-resolved request: concrete layers, accelerator and mapper.
#[derive(Debug, Clone)]
pub struct ResolvedRequest {
    /// `(network name, layers)` in submission order.
    pub networks: Vec<(String, Vec<Layer>)>,
    /// The accelerator to map onto.
    pub acc: Accelerator,
    /// The resolved mapper.
    pub mapper: AnyMapper,
    /// Service worker threads.
    pub threads: usize,
}

impl ResolvedRequest {
    /// Label for reports: the single network's name, or `zoo(n)` for a
    /// multi-network batch.
    pub fn workload_label(&self) -> String {
        if self.networks.len() == 1 {
            self.networks[0].0.clone()
        } else {
            format!("zoo({})", self.networks.len())
        }
    }
}

/// Parse a CLI layer spec: `network:index` (1-based into the zoo network)
/// or explicit `MxCxRxSxPxQ` dims (a dense conv named `custom`).
pub fn parse_layer_spec(spec: &str) -> Result<Layer, Error> {
    if let Some((net, idx)) = spec.split_once(':') {
        let layers = zoo::network(net)
            .ok_or_else(|| Error::request(format!("unknown network '{net}'")))?;
        let i: usize = idx
            .parse()
            .map_err(|_| Error::request(format!("bad layer index '{idx}' in '{spec}'")))?;
        if i == 0 || i > layers.len() {
            return Err(Error::request(format!("{net} has layers 1..={}", layers.len())));
        }
        Ok(layers[i - 1].clone())
    } else {
        let dims: Vec<u64> = spec
            .split('x')
            .map(|p| {
                p.parse().map_err(|_| Error::request(format!("bad dim '{p}' in '{spec}'")))
            })
            .collect::<Result<_, _>>()?;
        match dims[..] {
            [m, c, r, s, p, q] => Ok(Layer::new("custom", m, c, r, s, p, q)),
            _ => Err(Error::request(format!(
                "layer dims must be MxCxRxSxPxQ (got '{spec}')"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::ErrorClass;

    #[test]
    fn builder_resolves_network_and_preset() {
        let r = CompileRequest::new()
            .network("alexnet")
            .arch_preset("nvdla")
            .mapper("local")
            .resolve()
            .unwrap();
        assert_eq!(r.networks.len(), 1);
        assert_eq!(r.networks[0].1.len(), 5);
        assert_eq!(r.acc.name, "NVDLA");
        assert_eq!(r.workload_label(), "alexnet");
    }

    #[test]
    fn layer_specs_parse_both_spellings() {
        let l = parse_layer_spec("vgg02:5").unwrap();
        assert_eq!(l.name, "VGG02_conv5");
        let l = parse_layer_spec("16x8x3x3x14x14").unwrap();
        assert_eq!(l.name, "custom");
        assert_eq!(l.bounds(), [1, 16, 8, 3, 3, 14, 14]);
        for bad in ["vgg02:0", "vgg02:99", "frob:1", "vgg02:x", "3x3", "axbxcxdxexf"] {
            let e = parse_layer_spec(bad).unwrap_err();
            assert_eq!(e.class(), ErrorClass::Usage, "{bad}");
        }
    }

    #[test]
    fn unknown_names_are_usage_errors() {
        for req in [
            CompileRequest::new().network("frobnet"),
            CompileRequest::new().arch_preset("tpu"),
            CompileRequest::new().mapper("frob"),
            CompileRequest::new().layers("empty", vec![]),
        ] {
            let e = req.resolve().unwrap_err();
            assert_eq!(e.class(), ErrorClass::Usage, "{e}");
            assert_eq!(e.code(), "E_REQUEST");
        }
    }

    #[test]
    fn missing_files_are_invalid_input() {
        let e = CompileRequest::new()
            .workload_file("/nonexistent/layers.yaml")
            .resolve()
            .unwrap_err();
        assert_eq!(e.class(), ErrorClass::InvalidInput);
        assert_eq!(e.code(), "E_WORKLOAD");
        let e = CompileRequest::new()
            .arch_file("/nonexistent/arch.yaml")
            .resolve()
            .unwrap_err();
        assert_eq!(e.code(), "E_CONFIG");
    }

    #[test]
    fn zoo_request_resolves_the_batch_set() {
        let r = CompileRequest::new().zoo().resolve().unwrap();
        assert_eq!(r.networks.len(), 8);
        assert_eq!(r.workload_label(), "zoo(8)");
        assert_eq!(
            r.networks.iter().map(|(_, l)| l.len()).sum::<usize>(),
            13 + 53 + 52 + 26 + 5 + 96 + 18 + 62
        );
    }

    #[test]
    fn search_knobs_thread_through() {
        let r = CompileRequest::new()
            .network("alexnet")
            .mapper("random")
            .budget(40)
            .seed(7)
            .objective(Objective::Edp)
            .search_threads(2)
            .prune(false)
            .resolve()
            .unwrap();
        use crate::mappers::Mapper;
        assert_eq!(r.mapper.objective(), Objective::Edp);
        assert_eq!(r.mapper.name(), "random×40");
    }
}
