//! Experiment harness + emitters for every table and figure of the paper.
//!
//! Each experiment returns structured rows *and* renders the paper-style
//! ASCII table / CSV series, so the CLI (`local-mapper table3 …`), the
//! bench binaries (`cargo bench`) and the integration tests all share one
//! implementation. See DESIGN.md §3 for the experiment index.

use crate::arch::{presets, Accelerator};
use crate::mappers::random::{random_distribution, RandomDistribution};
use crate::mappers::{ConstrainedSearch, LocalMapper, Mapper};
use crate::mapspace::Dataflow;
use crate::model::Evaluation;
use crate::util::table::{fmt_f64, Table};
use crate::workload::zoo::{self, Category, Table2Row};
use std::time::Duration;

/// ---------------------------------------------------------------- Table 2

/// Render Table 2 (workload categories + MAC counts, asserted against the
/// paper's numbers).
pub fn table2() -> (Vec<Table2Row>, Table) {
    let rows = zoo::table2_workloads();
    let mut t = Table::new(vec!["Category", "Workload", "MACs (ours)", "MACs (paper)"]);
    for r in &rows {
        t.row(vec![
            r.category.name().to_string(),
            r.layer.name.clone(),
            r.layer.macs().to_string(),
            r.paper_macs.to_string(),
        ]);
    }
    (rows, t)
}

/// ---------------------------------------------------------------- Table 3

/// One Table-3 cell: a workload on an accelerator, the accelerator's
/// native stationary dataflow search vs LOCAL.
#[derive(Debug, Clone)]
pub struct Table3Cell {
    /// Workload category (Table-2 grouping).
    pub category: Category,
    /// Workload (layer) name.
    pub workload: String,
    /// Accelerator name.
    pub arch: String,
    /// Native dataflow the baseline searched under ("RS"/"WS"/"OS").
    pub dataflow: &'static str,
    /// Wall-clock of the baseline search.
    pub baseline_time: Duration,
    /// Candidate evaluations the baseline performed.
    pub baseline_evals: u64,
    /// Baseline best energy, µJ.
    pub baseline_energy_uj: f64,
    /// Wall-clock of the LOCAL pass.
    pub local_time: Duration,
    /// LOCAL energy, µJ.
    pub local_energy_uj: f64,
    /// Mapping-time speedup: baseline / LOCAL (the paper's 2×–49× claim).
    pub speedup: f64,
}

/// Run the Table-3 experiment: all nine Table-2 workloads × the three
/// accelerators, each compared against its native dataflow search.
/// `budget` caps the baseline search (3000 mirrors the paper's Fig. 3
/// sample count; Timeloop's own victory condition applies on top).
pub fn table3(budget: u64, seed: u64) -> Vec<Table3Cell> {
    let mut out = Vec::new();
    for row in zoo::table2_workloads() {
        for acc in presets::all() {
            let df = Dataflow::native_for(acc.style);
            let search = ConstrainedSearch::new(df, budget, seed);
            let base = search
                .run(&row.layer, &acc)
                .unwrap_or_else(|e| panic!("{} on {}: {e}", row.layer.name, acc.name));
            let local = LocalMapper::new()
                .run(&row.layer, &acc)
                .unwrap_or_else(|e| panic!("LOCAL {} on {}: {e}", row.layer.name, acc.name));
            let speedup = base.elapsed.as_secs_f64() / local.elapsed.as_secs_f64().max(1e-9);
            out.push(Table3Cell {
                category: row.category,
                workload: row.layer.name.clone(),
                arch: acc.name.clone(),
                dataflow: df.name(),
                baseline_time: base.elapsed,
                baseline_evals: base.evaluations,
                baseline_energy_uj: base.evaluation.energy.total_uj(),
                local_time: local.elapsed,
                local_energy_uj: local.evaluation.energy.total_uj(),
                speedup,
            });
        }
    }
    out
}

/// Render Table 3 in the paper's layout (mapping times + our speedup
/// column; the paper reports seconds on Timeloop/C++, we report the
/// measured wall-clock of the equivalent searches — the *ratio* is the
/// reproduced quantity).
pub fn render_table3(cells: &[Table3Cell]) -> Table {
    let mut t = Table::new(vec![
        "Category", "Workload", "Arch", "Mechanism", "Map time", "Evals", "Energy(µJ)", "LOCAL time",
        "LOCAL energy(µJ)", "Speedup",
    ]);
    for c in cells {
        t.row(vec![
            c.category.name().to_string(),
            c.workload.clone(),
            c.arch.clone(),
            c.dataflow.to_string(),
            crate::util::bench::fmt_duration(c.baseline_time),
            c.baseline_evals.to_string(),
            fmt_f64(c.baseline_energy_uj),
            crate::util::bench::fmt_duration(c.local_time),
            fmt_f64(c.local_energy_uj),
            format!("{:.1}x", c.speedup),
        ]);
    }
    t
}

/// ------------------------------------------------------------------ Fig 3

/// Run the Fig.-3 experiment (`n` random mappings of VGG-02 conv5 on
/// Eyeriss, Table-1 configuration) and render the three-bar summary.
pub fn fig3(n: usize, seed: u64) -> (RandomDistribution, Table) {
    let acc = presets::eyeriss();
    let layer = zoo::vgg02()[4].clone();
    let dist = random_distribution(&layer, &acc, n, seed);
    let mut t = Table::new(vec!["case", "energy (µJ)"]);
    t.row(vec!["random_max".to_string(), fmt_f64(dist.max_uj())]);
    t.row(vec!["random_med".to_string(), fmt_f64(dist.med_uj())]);
    t.row(vec!["random_min".to_string(), fmt_f64(dist.min_uj())]);
    (dist, t)
}

/// ------------------------------------------------------------------ Fig 7

/// One Fig.-7 panel: an accelerator × a workload category, energy
/// breakdown of the native stationary dataflow vs LOCAL for each workload
/// in the category.
#[derive(Debug, Clone)]
pub struct Fig7Panel {
    /// Accelerator name.
    pub arch: String,
    /// Native dataflow the baseline searched under.
    pub dataflow: &'static str,
    /// Workload category of the panel.
    pub category: Category,
    /// (workload, baseline eval, LOCAL eval).
    pub entries: Vec<(String, Evaluation, Evaluation)>,
}

/// Run the Fig.-7 experiment: 3 accelerators × 3 categories (the paper's
/// nine panels a–i).
pub fn fig7(budget: u64, seed: u64) -> Vec<Fig7Panel> {
    let mut panels = Vec::new();
    for acc in presets::all() {
        let df = Dataflow::native_for(acc.style);
        for cat in Category::ALL {
            let mut entries = Vec::new();
            for row in zoo::table2_workloads().into_iter().filter(|r| r.category == cat) {
                let base = ConstrainedSearch::new(df, budget, seed)
                    .run(&row.layer, &acc)
                    .unwrap_or_else(|e| panic!("{} on {}: {e}", row.layer.name, acc.name));
                let local = LocalMapper::new().run(&row.layer, &acc).unwrap();
                entries.push((row.layer.name.clone(), base.evaluation, local.evaluation));
            }
            panels.push(Fig7Panel { arch: acc.name.clone(), dataflow: df.name(), category: cat, entries });
        }
    }
    panels
}

/// Render one Fig.-7 panel as stacked-component rows (the figure's bars).
pub fn render_fig7_panel(panel: &Fig7Panel, acc: &Accelerator) -> Table {
    let mut header = vec!["workload".to_string(), "mechanism".to_string()];
    for l in &acc.levels {
        header.push(format!("{} (µJ)", l.name));
    }
    header.push("NoC (µJ)".to_string());
    header.push("MAC (µJ)".to_string());
    header.push("total (µJ)".to_string());
    let mut t = Table::new(header);
    for (name, base, local) in &panel.entries {
        for (mech, e) in [(panel.dataflow, base), ("LOCAL", local)] {
            let mut row = vec![name.clone(), mech.to_string()];
            for &pj in &e.energy.level_pj {
                row.push(fmt_f64(pj / 1e6));
            }
            row.push(fmt_f64(e.energy.noc_pj / 1e6));
            row.push(fmt_f64(e.energy.mac_pj / 1e6));
            row.push(fmt_f64(e.energy.total_uj()));
            t.row(row);
        }
    }
    t
}

/// ------------------------------------------------------------- API reports

/// Per-layer table for one network of an API compile report (what the
/// `map` and `compile` subcommands print in table mode).
pub fn render_layer_reports(net: &crate::api::NetworkReport) -> Table {
    let mut t = Table::new(vec![
        "layer",
        "MACs",
        "energy (µJ)",
        "pJ/MAC",
        "util",
        "latency (cyc)",
        "map time",
        "cached",
        "status",
    ]);
    for l in &net.layers {
        t.row(vec![
            l.layer.name.clone(),
            l.macs().to_string(),
            fmt_f64(l.energy_uj()),
            fmt_f64(l.pj_per_mac()),
            format!("{:.0}%", l.utilization() * 100.0),
            l.latency_cycles().to_string(),
            crate::util::bench::fmt_duration(l.outcome.elapsed),
            if l.cached { "yes" } else { "no" }.into(),
            l.outcome.status.kind().into(),
        ]);
    }
    t
}

/// One-row-per-network summary of an API compile report (what the
/// `compile-all` subcommand prints in table mode).
pub fn render_network_summaries(r: &crate::api::CompileReport) -> Table {
    let mut t = Table::new(vec![
        "network", "layers", "MACs", "energy (µJ)", "pJ/MAC", "latency (cyc)", "mean util",
        "cached", "compile",
    ]);
    for net in &r.networks {
        t.row(vec![
            net.name.clone(),
            net.layers.len().to_string(),
            net.total_macs().to_string(),
            fmt_f64(net.total_energy_uj()),
            fmt_f64(net.pj_per_mac()),
            net.total_latency_cycles().to_string(),
            format!("{:.0}%", net.mean_utilization() * 100.0),
            format!("{}/{}", net.cache_hits(), net.layers.len()),
            crate::util::bench::fmt_duration(net.compile_time),
        ]);
    }
    t
}

/// One-line fusion summary of a compile's graph-level analysis (printed
/// by compile/compile-all in table mode whenever `--graph-mode` is not
/// `off`).
pub fn render_graph_summary(g: &crate::graph::GraphReport) -> String {
    let baseline = g.cross_layer_dram_bytes.saturating_add(g.dram_bytes_saved);
    let pct = if baseline > 0 {
        g.dram_bytes_saved as f64 * 100.0 / baseline as f64
    } else {
        0.0
    };
    format!(
        "graph: mode={} groups={} fused_layers={} cross_layer_dram={} B (saved {} B, {:.1}%)",
        g.mode.name(),
        g.groups,
        g.fused_layers,
        g.cross_layer_dram_bytes,
        g.dram_bytes_saved,
        pct
    )
}

/// ------------------------------------------------------------ Batch compile

/// Render the `compile-all` batch summary: one row per network with
/// energy/latency/utilization aggregates plus the cross-network cache
/// column (the hit rate and service percentiles live on the
/// [`crate::coordinator::BatchPlan`] itself).
pub fn render_batch_summary(batch: &crate::coordinator::BatchPlan) -> Table {
    let mut t = Table::new(vec![
        "network", "layers", "MACs", "energy (µJ)", "pJ/MAC", "latency (cyc)", "mean util",
        "cached", "compile",
    ]);
    for (name, plan) in &batch.networks {
        t.row(vec![
            name.clone(),
            plan.layers.len().to_string(),
            plan.total_macs().to_string(),
            fmt_f64(plan.total_energy_uj()),
            fmt_f64(plan.pj_per_mac()),
            plan.total_latency_cycles().to_string(),
            format!("{:.0}%", plan.mean_utilization() * 100.0),
            format!("{}/{}", plan.cache_hits(), plan.layers.len()),
            crate::util::bench::fmt_duration(plan.compile_time),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_renders_all_nine() {
        let (rows, t) = table2();
        assert_eq!(rows.len(), 9);
        assert_eq!(t.n_rows(), 9);
    }

    #[test]
    fn table3_small_budget_has_27_cells_and_speedup() {
        let cells = table3(60, 42);
        assert_eq!(cells.len(), 27);
        // LOCAL must be faster than search on the vast majority of cells.
        let faster = cells.iter().filter(|c| c.speedup > 1.0).count();
        assert!(faster >= 24, "only {faster}/27 cells show speedup");
        let t = render_table3(&cells);
        assert_eq!(t.n_rows(), 27);
    }

    #[test]
    fn fig3_ordering() {
        let (d, t) = fig3(50, 7);
        assert!(d.min_uj() <= d.med_uj());
        assert_eq!(t.n_rows(), 3);
    }

    #[test]
    fn batch_summary_has_one_row_per_network() {
        let acc = presets::eyeriss();
        let networks = vec![
            ("alexnet".to_string(), zoo::alexnet()),
            ("vgg02".to_string(), zoo::vgg02()),
        ];
        let batch = crate::coordinator::compile_batch(
            &networks,
            &acc,
            &crate::mappers::LocalMapper::new(),
            2,
        )
        .unwrap();
        let t = render_batch_summary(&batch);
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn api_report_tables_cover_layers_and_networks() {
        use crate::api::{CompileRequest, Session};
        let session = Session::new();
        let r = session
            .compile(&CompileRequest::new().network("alexnet").threads(2))
            .unwrap();
        let per_layer = render_layer_reports(&r.networks[0]);
        assert_eq!(per_layer.n_rows(), 5);
        let summary = render_network_summaries(&r);
        assert_eq!(summary.n_rows(), 1);
    }

    #[test]
    fn fig7_panels_cover_grid() {
        let panels = fig7(40, 3);
        assert_eq!(panels.len(), 9);
        for p in &panels {
            assert_eq!(p.entries.len(), 3);
        }
        let acc = presets::eyeriss();
        let t = render_fig7_panel(&panels[0], &acc);
        assert_eq!(t.n_rows(), 6); // 3 workloads × 2 mechanisms
    }
}
