//! Hardware/mapping co-design exploration — the §3 motivation
//! (`O(10^17)` joint space) turned into a usable tool.
//!
//! LOCAL's one-pass cost makes the *mapping* axis of the joint space
//! effectively free, so a designer can sweep hardware configurations
//! directly. [`sweep`] enumerates accelerator variants (PE geometry ×
//! buffer sizes), maps a workload set onto each with any mapper, and
//! returns per-design aggregates; [`pareto`] extracts the energy/latency
//! frontier.
//!
//! Every `(layer, design)` evaluation rides the zero-allocation
//! [`crate::model::EvalContext`] engine through [`Mapper::run`], so sweeps
//! with search mappers (thousands of candidates per design point) stay on
//! the hot path end to end.

use crate::arch::Accelerator;
use crate::mappers::{MapError, Mapper};
use crate::workload::ConvLayer;

/// One hardware design point to evaluate.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    /// Human-readable design label.
    pub label: String,
    /// The accelerator variant.
    pub acc: Accelerator,
}

/// Aggregated result of mapping the workload set on one design.
#[derive(Debug, Clone)]
pub struct DesignResult {
    /// Human-readable design label.
    pub label: String,
    /// Total energy over the workload set, µJ.
    pub total_energy_uj: f64,
    /// Total roofline latency over the workload set, cycles.
    pub total_latency_cycles: u64,
    /// MAC-weighted mean PE utilization.
    pub mean_utilization: f64,
    /// Total MACs over the workload set.
    pub total_macs: u64,
    /// Energy-delay product, µJ · Mcycles.
    pub edp: f64,
}

impl DesignResult {
    /// Energy per MAC, pJ.
    pub fn pj_per_mac(&self) -> f64 {
        self.total_energy_uj * 1e6 / self.total_macs.max(1) as f64
    }
}

/// The sweep grid: PE geometries × level-1 buffer depths applied to a base
/// accelerator.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// PE-array geometries `(rows, cols)` to try.
    pub pe_dims: Vec<(u64, u64)>,
    /// Level-1 buffer depths (words) to try.
    pub l1_depths: Vec<u64>,
}

impl SweepGrid {
    /// A sensible default grid around the paper's machines.
    pub fn default_grid() -> Self {
        Self {
            pe_dims: vec![(8, 8), (12, 14), (16, 16), (8, 32), (32, 8), (24, 24), (32, 32)],
            l1_depths: vec![8192, 16384, 32768, 65536],
        }
    }

    /// Materialize design points from a base machine.
    pub fn points(&self, base: &Accelerator) -> Vec<DesignPoint> {
        let mut out = Vec::new();
        for &(m, n) in &self.pe_dims {
            for &depth in &self.l1_depths {
                let mut acc = base.clone();
                acc.pe = crate::arch::PeArray::new(m, n);
                acc.levels[1].depth = depth;
                let kib = depth * acc.levels[1].width_bits / 8 / 1024;
                acc.name = format!("{}-{m}x{n}-{kib}k", base.name);
                out.push(DesignPoint { label: format!("{m}x{n} / {kib} KiB"), acc });
            }
        }
        out
    }
}

/// Map `layers` on every design point with `mapper`; aggregate per design.
pub fn sweep<M: Mapper>(
    points: &[DesignPoint],
    layers: &[ConvLayer],
    mapper: &M,
) -> Result<Vec<DesignResult>, MapError> {
    let mut out = Vec::with_capacity(points.len());
    for p in points {
        let mut energy = 0.0f64;
        let mut latency = 0u64;
        let mut util_weighted = 0.0f64;
        let mut macs = 0u64;
        for layer in layers {
            let o = mapper.run(layer, &p.acc)?;
            energy += o.evaluation.energy.total_uj();
            latency += o.evaluation.latency_cycles;
            util_weighted += o.evaluation.utilization * o.evaluation.macs as f64;
            macs += o.evaluation.macs;
        }
        out.push(DesignResult {
            label: p.label.clone(),
            total_energy_uj: energy,
            total_latency_cycles: latency,
            mean_utilization: util_weighted / macs.max(1) as f64,
            total_macs: macs,
            edp: energy * latency as f64 / 1e12,
        });
    }
    Ok(out)
}

/// Pareto-optimal subset under (energy, latency) minimization, sorted by
/// energy ascending.
pub fn pareto(results: &[DesignResult]) -> Vec<DesignResult> {
    let mut sorted: Vec<DesignResult> = results.to_vec();
    sorted.sort_by(|a, b| a.total_energy_uj.total_cmp(&b.total_energy_uj));
    let mut front: Vec<DesignResult> = Vec::new();
    let mut best_latency = u64::MAX;
    for r in sorted {
        if r.total_latency_cycles < best_latency {
            best_latency = r.total_latency_cycles;
            front.push(r);
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::LocalMapper;
    use crate::workload::zoo;

    #[test]
    fn sweep_covers_grid() {
        let grid = SweepGrid { pe_dims: vec![(8, 8), (16, 16)], l1_depths: vec![8192, 16384] };
        let points = grid.points(&presets::eyeriss());
        assert_eq!(points.len(), 4);
        let layers = vec![zoo::vgg02()[4].clone()];
        let results = sweep(&points, &layers, &LocalMapper::new()).unwrap();
        assert_eq!(results.len(), 4);
        for r in &results {
            assert!(r.total_energy_uj > 0.0);
            assert!(r.edp > 0.0);
        }
    }

    #[test]
    fn pareto_front_is_monotone() {
        let grid = SweepGrid::default_grid();
        let points = grid.points(&presets::eyeriss());
        let layers = vec![zoo::vgg02()[4].clone()];
        let results = sweep(&points, &layers, &LocalMapper::new()).unwrap();
        let front = pareto(&results);
        assert!(!front.is_empty());
        assert!(front.len() <= results.len());
        // Energy ascending, latency strictly descending along the front.
        for w in front.windows(2) {
            assert!(w[0].total_energy_uj <= w[1].total_energy_uj);
            assert!(w[0].total_latency_cycles > w[1].total_latency_cycles);
        }
        // Every non-front point is dominated by some front point.
        for r in &results {
            let dominated = front.iter().any(|f| {
                f.total_energy_uj <= r.total_energy_uj
                    && f.total_latency_cycles <= r.total_latency_cycles
            });
            assert!(dominated, "{} not dominated and not on front?", r.label);
        }
    }

    #[test]
    fn bigger_buffer_designs_reduce_energy_on_average() {
        let grid = SweepGrid { pe_dims: vec![(12, 14)], l1_depths: vec![4096, 65536] };
        let points = grid.points(&presets::eyeriss());
        let layers = vec![zoo::vgg16()[8].clone()];
        let results = sweep(&points, &layers, &LocalMapper::new()).unwrap();
        // A 16× larger GLB should not increase total energy for this
        // DRAM-bound layer.
        assert!(results[1].total_energy_uj <= results[0].total_energy_uj * 1.05);
    }
}
