//! Spatial DNN accelerator model — the paper's `SPA = {Storage[i,j,k],
//! PE[m,n]}` (§2.2, Eq. 10–16).
//!
//! An [`Accelerator`] is a storage hierarchy (innermost-first: L0 register
//! file at each PE, one or more on-chip buffer levels, DRAM outermost), a 2D
//! PE array, and a NoC. The [`Style`] captures the paper's NVDLA-style vs
//! Eyeriss-style L1↔PE connection distinction (Eq. 14 vs 15–16), which
//! drives both the LOCAL parallelization step and the NoC traffic model.

pub mod config;
pub mod presets;

use crate::workload::Tensor;
use std::fmt;

/// Accelerator connection style (paper Fig. 2).
///
/// * `NvdlaLike` — single L1 buffer broadcasting to the whole PE array
///   (Eq. 14). LOCAL parallelizes C (spatial-X) and M (spatial-Y).
/// * `EyerissLike` — banked L1, one bank per PE column (Eq. 15–16). LOCAL
///   parallelizes Q (spatial-X) and S (spatial-Y).
/// * `ShiDianNaoLike` — output-stationary grid; output pixels are spatial.
///   LOCAL parallelizes Q (spatial-X) and P (spatial-Y). (Interpretation —
///   see DESIGN.md §4.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Style {
    /// Single L1 buffer broadcasting to the whole PE array (Eq. 14).
    NvdlaLike,
    /// Banked L1, one bank per PE column (Eq. 15–16).
    EyerissLike,
    /// Output-stationary grid; output pixels are spatial.
    ShiDianNaoLike,
}

impl Style {
    /// Canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            Style::NvdlaLike => "nvdla",
            Style::EyerissLike => "eyeriss",
            Style::ShiDianNaoLike => "shidiannao",
        }
    }

    /// Parse a (case-insensitive) style name.
    pub fn parse(s: &str) -> Option<Style> {
        match s.to_ascii_lowercase().as_str() {
            "nvdla" | "nvdla-like" | "nvdla_like" => Some(Style::NvdlaLike),
            "eyeriss" | "eyeriss-like" | "eyeriss_like" => Some(Style::EyerissLike),
            "shidiannao" | "shi-diannao" | "shidiannao-like" => Some(Style::ShiDianNaoLike),
            _ => None,
        }
    }
}

impl fmt::Display for Style {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One storage level `s_{i,j,k}` (Eq. 11–12). `|s| = depth × width`.
#[derive(Debug, Clone, PartialEq)]
pub struct StorageLevel {
    /// Human name: "RF", "GLB", "DRAM", ...
    pub name: String,
    /// Words of `width_bits` each. Ignored when `unbounded`.
    pub depth: u64,
    /// Word width in bits.
    pub width_bits: u64,
    /// Number of physical banks at this level (Eyeriss L1 = one per PE
    /// column; single-buffer levels use 1). Banks multiply capacity.
    pub banks: u64,
    /// Level is instanced once per PE (the L0 scratchpad of Fig. 1).
    pub per_pe: bool,
    /// Off-chip / unbounded capacity (DRAM).
    pub unbounded: bool,
    /// Sustained words per cycle into the level below (roofline input).
    pub bandwidth_words_per_cycle: f64,
}

impl StorageLevel {
    /// On-chip buffer constructor.
    pub fn buffer(name: &str, depth: u64, width_bits: u64) -> Self {
        Self {
            name: name.to_string(),
            depth,
            width_bits,
            banks: 1,
            per_pe: false,
            unbounded: false,
            bandwidth_words_per_cycle: 1.0,
        }
    }

    /// Per-PE register-file constructor. RFs are multi-ported (two operand
    /// reads + accumulator read/write per MAC), hence the 4 words/cycle
    /// default per instance.
    pub fn register_file(name: &str, depth: u64, width_bits: u64) -> Self {
        Self {
            per_pe: true,
            bandwidth_words_per_cycle: 4.0,
            ..Self::buffer(name, depth, width_bits)
        }
    }

    /// Unbounded DRAM constructor.
    pub fn dram(width_bits: u64) -> Self {
        Self {
            name: "DRAM".to_string(),
            depth: u64::MAX,
            width_bits,
            banks: 1,
            per_pe: false,
            unbounded: true,
            bandwidth_words_per_cycle: 1.0,
        }
    }

    /// Builder: set the bank count.
    pub fn with_banks(mut self, banks: u64) -> Self {
        self.banks = banks;
        self
    }

    /// Builder: set the sustained bandwidth in words/cycle.
    pub fn with_bandwidth(mut self, words_per_cycle: f64) -> Self {
        self.bandwidth_words_per_cycle = words_per_cycle;
        self
    }

    /// Capacity in bits of one instance (all banks, Eq. 12).
    pub fn capacity_bits(&self) -> u64 {
        if self.unbounded {
            u64::MAX
        } else {
            self.depth.saturating_mul(self.width_bits).saturating_mul(self.banks)
        }
    }

    /// Capacity in data elements of `datawidth` bits.
    pub fn capacity_elements(&self, datawidth: u64) -> u64 {
        if self.unbounded {
            u64::MAX
        } else {
            self.capacity_bits() / datawidth
        }
    }
}

/// The PE array `PE[m,n]` (Eq. 13). `m` rows = spatial X, `n` cols =
/// spatial Y, following the paper's `parallel_for ... in Rang(m) spatial x`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeArray {
    /// Rows (spatial X).
    pub m: u64,
    /// Columns (spatial Y).
    pub n: u64,
}

impl PeArray {
    /// Construct an `m × n` PE array; both dims must be positive.
    pub fn new(m: u64, n: u64) -> Self {
        assert!(m > 0 && n > 0, "PE array dims must be positive");
        Self { m, n }
    }

    /// Total PE count (denominator of Eq. 25).
    pub fn count(&self) -> u64 {
        self.m * self.n
    }
}

/// NoC parameters for the spatial-traffic energy model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Noc {
    /// Energy to move one word one hop, pJ.
    pub hop_energy_pj: f64,
    /// The interconnect supports single-send multicast along a row/column
    /// (Eyeriss's X/Y buses); without it every destination is a unicast.
    pub multicast: bool,
}

impl Default for Noc {
    fn default() -> Self {
        Self { hop_energy_pj: 0.061, multicast: true }
    }
}

/// A complete spatial accelerator (Eq. 10).
#[derive(Debug, Clone, PartialEq)]
pub struct Accelerator {
    /// Machine name ("Eyeriss", "NVDLA", ...).
    pub name: String,
    /// L1↔PE connection style (drives LOCAL's parallelization step).
    pub style: Style,
    /// Data element width in bits (weights/activations).
    pub datawidth_bits: u64,
    /// Storage hierarchy, **innermost first** (levels[0] = per-PE L0; the
    /// last level must be unbounded DRAM).
    pub levels: Vec<StorageLevel>,
    /// The 2D PE array.
    pub pe: PeArray,
    /// NoC parameters.
    pub noc: Noc,
    /// Energy of one MAC, pJ.
    pub mac_energy_pj: f64,
    /// Clock, MHz (latency→seconds conversion only).
    pub clock_mhz: f64,
}

impl Accelerator {
    /// Validate structural invariants; called by presets and the config
    /// loader so downstream code can assume them.
    pub fn validate(&self) -> Result<(), String> {
        if self.levels.len() < 2 {
            return Err("need at least one on-chip level plus DRAM".into());
        }
        if !self.levels.last().unwrap().unbounded {
            return Err("outermost level must be unbounded DRAM".into());
        }
        if self.levels[..self.levels.len() - 1].iter().any(|l| l.unbounded) {
            return Err("only the outermost level may be unbounded".into());
        }
        if !self.levels[0].per_pe {
            return Err("innermost level must be the per-PE register file".into());
        }
        if self.levels.iter().skip(1).any(|l| l.per_pe) {
            return Err("only the innermost level may be per-PE".into());
        }
        if self.datawidth_bits == 0 || self.datawidth_bits > 64 {
            return Err("datawidth must be in 1..=64".into());
        }
        Ok(())
    }

    /// Number of storage levels (the `m` of the map-space `(n!)^m`, §3).
    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    /// Capacity in elements of level `i` **per tile consumer**: per-PE for
    /// L0, whole level otherwise.
    pub fn level_capacity(&self, i: usize) -> u64 {
        self.levels[i].capacity_elements(self.datawidth_bits)
    }

    /// Which tensors a level may hold. All our machines are
    /// "keep-everything" (no bypass), matching the paper's model.
    pub fn stores(&self, _level: usize, _t: Tensor) -> bool {
        true
    }

    /// Per-PE L0 capacity in elements.
    pub fn l0_capacity(&self) -> u64 {
        self.level_capacity(0)
    }
}

impl fmt::Display for Accelerator {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} ({}-style, PE {}x{}, {} levels)", self.name, self.style, self.pe.m, self.pe.n, self.levels.len())
    }
}

#[cfg(test)]
mod tests {
    use super::presets;
    use super::*;

    #[test]
    fn style_parse_roundtrip() {
        for s in [Style::NvdlaLike, Style::EyerissLike, Style::ShiDianNaoLike] {
            assert_eq!(Style::parse(s.name()), Some(s));
        }
        assert_eq!(Style::parse("tpu"), None);
    }

    #[test]
    fn capacity_math() {
        let l = StorageLevel::buffer("GLB", 16384, 64);
        assert_eq!(l.capacity_bits(), 16384 * 64);
        assert_eq!(l.capacity_elements(16), 16384 * 4);
        let rf = StorageLevel::register_file("RF", 16, 16);
        assert_eq!(rf.capacity_elements(16), 16);
        assert!(StorageLevel::dram(64).capacity_elements(16) == u64::MAX);
    }

    #[test]
    fn banked_capacity() {
        let l = StorageLevel::buffer("L1", 512, 16).with_banks(14);
        assert_eq!(l.capacity_elements(16), 512 * 14);
    }

    #[test]
    fn presets_validate() {
        for a in presets::all() {
            a.validate().unwrap_or_else(|e| panic!("{}: {e}", a.name));
        }
    }

    #[test]
    fn validate_rejects_bad_hierarchies() {
        let mut a = presets::eyeriss();
        a.levels.reverse();
        assert!(a.validate().is_err());

        let mut b = presets::eyeriss();
        b.levels[1].unbounded = true;
        assert!(b.validate().is_err());

        let mut c = presets::eyeriss();
        c.levels[0].per_pe = false;
        assert!(c.validate().is_err());
    }

    #[test]
    fn pe_array_count() {
        assert_eq!(PeArray::new(12, 14).count(), 168);
    }

    #[test]
    #[should_panic]
    fn pe_array_rejects_zero() {
        PeArray::new(0, 4);
    }
}
