//! Accelerator presets for the three machines of the paper's evaluation.
//!
//! Geometry follows Table 1 where the paper gives it (Eyeriss) and the cited
//! reference architectures otherwise (NVDLA [4], ShiDianNao [15]); energy
//! per access is derived from these geometries by `energy::Ert`.

use super::{Accelerator, Noc, PeArray, StorageLevel, Style};

/// Eyeriss — Table 1: PE array 12×14, L0 (16,16) per PE, L1 (16384,64)
/// global buffer (128 KiB), 64-bit DRAM interface, 16-bit data.
/// The Eyeriss-style banked L1↔column connection (Eq. 15–16) is carried by
/// `Style::EyerissLike` + `banks = n`, which the NoC model uses for
/// column-bus multicast accounting.
pub fn eyeriss() -> Accelerator {
    let pe = PeArray::new(12, 14);
    Accelerator {
        name: "Eyeriss".to_string(),
        style: Style::EyerissLike,
        datawidth_bits: 16,
        levels: vec![
            StorageLevel::register_file("RF", 16, 16),
            StorageLevel::buffer("GLB", 16384, 64).with_banks(1).with_bandwidth(4.0),
            StorageLevel::dram(64).with_bandwidth(1.0),
        ],
        pe,
        noc: Noc { hop_energy_pj: 0.061, multicast: true },
        mac_energy_pj: 1.0,
        clock_mhz: 200.0,
    }
}

/// NVDLA-style — single GLB (CBUF-like, 256 KiB here) feeding a 16×16 MAC
/// array (Fig. 2a, Eq. 14); weight-stationary lineage.
pub fn nvdla() -> Accelerator {
    Accelerator {
        name: "NVDLA".to_string(),
        style: Style::NvdlaLike,
        datawidth_bits: 16,
        levels: vec![
            StorageLevel::register_file("RF", 16, 16),
            StorageLevel::buffer("CBUF", 32768, 64).with_bandwidth(8.0),
            StorageLevel::dram(64).with_bandwidth(2.0),
        ],
        pe: PeArray::new(16, 16),
        noc: Noc { hop_energy_pj: 0.061, multicast: true },
        mac_energy_pj: 1.0,
        clock_mhz: 1000.0,
    }
}

/// ShiDianNao-style — 8×8 output-stationary PE grid with NBin/NBout/SB
/// buffers modelled as one 64 KiB level.
pub fn shidiannao() -> Accelerator {
    Accelerator {
        name: "ShiDianNao".to_string(),
        style: Style::ShiDianNaoLike,
        datawidth_bits: 16,
        levels: vec![
            StorageLevel::register_file("RF", 16, 16),
            StorageLevel::buffer("SRAM", 8192, 64).with_bandwidth(4.0),
            StorageLevel::dram(64).with_bandwidth(1.0),
        ],
        pe: PeArray::new(8, 8),
        noc: Noc { hop_energy_pj: 0.061, multicast: true },
        mac_energy_pj: 1.0,
        clock_mhz: 1000.0,
    }
}

/// All presets.
pub fn all() -> Vec<Accelerator> {
    vec![eyeriss(), nvdla(), shidiannao()]
}

/// Look up a preset by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Accelerator> {
    match name.to_ascii_lowercase().as_str() {
        "eyeriss" => Some(eyeriss()),
        "nvdla" => Some(nvdla()),
        "shidiannao" | "shi-diannao" => Some(shidiannao()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eyeriss_matches_table1() {
        let a = eyeriss();
        assert_eq!(a.pe.m, 12);
        assert_eq!(a.pe.n, 14);
        assert_eq!(a.levels[0].depth, 16);
        assert_eq!(a.levels[0].width_bits, 16);
        assert_eq!(a.levels[1].depth, 16384);
        assert_eq!(a.levels[1].width_bits, 64);
        assert_eq!(a.levels[2].width_bits, 64);
        assert!(a.levels[2].unbounded);
        // 128 KiB GLB.
        assert_eq!(a.levels[1].capacity_bits() / 8, 128 * 1024);
    }

    #[test]
    fn styles_are_distinct() {
        assert_eq!(eyeriss().style, Style::EyerissLike);
        assert_eq!(nvdla().style, Style::NvdlaLike);
        assert_eq!(shidiannao().style, Style::ShiDianNaoLike);
    }

    #[test]
    fn by_name_lookup() {
        for a in all() {
            assert_eq!(by_name(&a.name).unwrap().name, a.name);
        }
        assert!(by_name("tpu").is_none());
    }
}
