//! Accelerator configuration loading (Timeloop-style YAML).
//!
//! Example (see `configs/eyeriss.yaml`):
//!
//! ```yaml
//! accelerator:
//!   name: eyeriss
//!   style: eyeriss
//!   datawidth: 16
//!   mac_energy_pj: 1.0
//!   clock_mhz: 200
//!   pe_array: [12, 14]
//!   noc:
//!     hop_energy_pj: 0.061
//!     multicast: true
//!   levels:            # innermost (per-PE) first, DRAM last
//!     - name: RF
//!       depth: 16
//!       width: 16
//!       per_pe: true
//!     - name: GLB
//!       depth: 16384
//!       width: 64
//!       bandwidth: 4
//!     - name: DRAM
//!       width: 64
//!       unbounded: true
//! ```

use super::{Accelerator, Noc, PeArray, StorageLevel, Style};
use crate::util::yaml::{self, Value};
use std::fmt;

/// Configuration error.
#[derive(Debug)]
pub enum ConfigError {
    /// YAML syntax error.
    Yaml(yaml::YamlError),
    /// Structurally invalid configuration.
    Invalid(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Yaml(e) => fmt::Display::fmt(e, f),
            ConfigError::Invalid(msg) => write!(f, "config: {msg}"),
            ConfigError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Yaml(e) => Some(e),
            ConfigError::Invalid(_) => None,
            ConfigError::Io(e) => Some(e),
        }
    }
}

impl From<yaml::YamlError> for ConfigError {
    fn from(e: yaml::YamlError) -> Self {
        ConfigError::Yaml(e)
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

fn invalid<T>(msg: impl Into<String>) -> Result<T, ConfigError> {
    Err(ConfigError::Invalid(msg.into()))
}

/// Parse an accelerator from YAML text.
pub fn accelerator_from_str(src: &str) -> Result<Accelerator, ConfigError> {
    let doc = yaml::parse(src)?;
    let a = doc.get("accelerator").unwrap_or(&doc);

    let name = a
        .get("name")
        .and_then(Value::as_str)
        .ok_or_else(|| ConfigError::Invalid("missing accelerator.name".into()))?
        .to_string();

    let style_s = a.get("style").and_then(Value::as_str).unwrap_or("eyeriss");
    let style = Style::parse(style_s)
        .ok_or_else(|| ConfigError::Invalid(format!("unknown style '{style_s}'")))?;

    let datawidth = a.get("datawidth").and_then(Value::as_u64).unwrap_or(16);

    let pe = match a.get("pe_array").and_then(Value::as_list) {
        Some([m, n]) => {
            let m = m.as_u64().ok_or_else(|| ConfigError::Invalid("pe_array[0] not a number".into()))?;
            let n = n.as_u64().ok_or_else(|| ConfigError::Invalid("pe_array[1] not a number".into()))?;
            if m == 0 || n == 0 {
                return invalid("pe_array dims must be positive");
            }
            PeArray::new(m, n)
        }
        _ => return invalid("pe_array must be a 2-element list [m, n]"),
    };

    let mut noc = Noc::default();
    if let Some(n) = a.get("noc") {
        if let Some(h) = n.get("hop_energy_pj").and_then(Value::as_f64) {
            noc.hop_energy_pj = h;
        }
        if let Some(m) = n.get("multicast").and_then(Value::as_bool) {
            noc.multicast = m;
        }
    }

    let levels_v = a
        .get("levels")
        .and_then(Value::as_list)
        .ok_or_else(|| ConfigError::Invalid("missing levels list".into()))?;
    let mut levels = Vec::new();
    for (i, lv) in levels_v.iter().enumerate() {
        let lname = lv
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| ConfigError::Invalid(format!("levels[{i}] missing name")))?;
        let width = lv
            .get("width")
            .and_then(Value::as_u64)
            .ok_or_else(|| ConfigError::Invalid(format!("levels[{i}] missing width")))?;
        let unbounded = lv.get("unbounded").and_then(Value::as_bool).unwrap_or(false);
        let depth = match (unbounded, lv.get("depth").and_then(Value::as_u64)) {
            (true, _) => u64::MAX,
            (false, Some(d)) => d,
            (false, None) => return invalid(format!("levels[{i}] ({lname}) missing depth")),
        };
        let mut level = StorageLevel {
            name: lname.to_string(),
            depth,
            width_bits: width,
            banks: lv.get("banks").and_then(Value::as_u64).unwrap_or(1),
            per_pe: lv.get("per_pe").and_then(Value::as_bool).unwrap_or(false),
            unbounded,
            bandwidth_words_per_cycle: lv.get("bandwidth").and_then(Value::as_f64).unwrap_or(1.0),
        };
        if unbounded {
            level.name = lname.to_string();
        }
        levels.push(level);
    }

    let acc = Accelerator {
        name,
        style,
        datawidth_bits: datawidth,
        levels,
        pe,
        noc,
        mac_energy_pj: a.get("mac_energy_pj").and_then(Value::as_f64).unwrap_or(1.0),
        clock_mhz: a.get("clock_mhz").and_then(Value::as_f64).unwrap_or(200.0),
    };
    acc.validate().map_err(ConfigError::Invalid)?;
    Ok(acc)
}

/// Load an accelerator from a YAML file.
pub fn accelerator_from_file(path: &str) -> Result<Accelerator, ConfigError> {
    let src = std::fs::read_to_string(path)?;
    accelerator_from_str(&src)
}

/// Serialize an accelerator to the YAML format accepted above (used by
/// `local-mapper arch --dump` and in round-trip tests).
pub fn accelerator_to_yaml(a: &Accelerator) -> String {
    let mut s = String::new();
    s.push_str("accelerator:\n");
    s.push_str(&format!("  name: {}\n", a.name));
    s.push_str(&format!("  style: {}\n", a.style.name()));
    s.push_str(&format!("  datawidth: {}\n", a.datawidth_bits));
    s.push_str(&format!("  mac_energy_pj: {}\n", a.mac_energy_pj));
    s.push_str(&format!("  clock_mhz: {}\n", a.clock_mhz));
    s.push_str(&format!("  pe_array: [{}, {}]\n", a.pe.m, a.pe.n));
    s.push_str("  noc:\n");
    s.push_str(&format!("    hop_energy_pj: {}\n", a.noc.hop_energy_pj));
    s.push_str(&format!("    multicast: {}\n", a.noc.multicast));
    s.push_str("  levels:\n");
    for l in &a.levels {
        s.push_str(&format!("    - name: {}\n", l.name));
        if l.unbounded {
            s.push_str("      unbounded: true\n");
        } else {
            s.push_str(&format!("      depth: {}\n", l.depth));
        }
        s.push_str(&format!("      width: {}\n", l.width_bits));
        if l.banks != 1 {
            s.push_str(&format!("      banks: {}\n", l.banks));
        }
        if l.per_pe {
            s.push_str("      per_pe: true\n");
        }
        s.push_str(&format!("      bandwidth: {}\n", l.bandwidth_words_per_cycle));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn roundtrip_presets() {
        for a in presets::all() {
            let y = accelerator_to_yaml(&a);
            let b = accelerator_from_str(&y).unwrap_or_else(|e| panic!("{}: {e}\n{y}", a.name));
            assert_eq!(a, b, "roundtrip mismatch for {}", a.name);
        }
    }

    #[test]
    fn missing_fields_error() {
        assert!(accelerator_from_str("accelerator:\n  name: x\n").is_err());
        let no_depth = "accelerator:\n  name: x\n  pe_array: [2, 2]\n  levels:\n    - name: RF\n      width: 16\n      per_pe: true\n    - name: DRAM\n      width: 64\n      unbounded: true\n";
        assert!(accelerator_from_str(no_depth).is_err());
    }

    #[test]
    fn bad_style_error() {
        let src = "accelerator:\n  name: x\n  style: gpu\n  pe_array: [2, 2]\n  levels:\n    - name: DRAM\n      width: 64\n      unbounded: true\n";
        let e = accelerator_from_str(src).unwrap_err();
        assert!(format!("{e}").contains("style"));
    }

    #[test]
    fn validation_enforced() {
        // DRAM first (not last) must be rejected by Accelerator::validate.
        let src = "accelerator:\n  name: x\n  style: eyeriss\n  pe_array: [2, 2]\n  levels:\n    - name: DRAM\n      width: 64\n      unbounded: true\n    - name: RF\n      depth: 16\n      width: 16\n      per_pe: true\n";
        assert!(accelerator_from_str(src).is_err());
    }
}
