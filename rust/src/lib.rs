//! # local-mapper
//!
//! A compile-time mapping framework for spatial DNN accelerators,
//! reproducing **“LOCAL: Low-Complex Mapping Algorithm for Spatial DNN
//! Accelerators”** (Reshadi & Gregg, NorCAS 2021).
//!
//! The crate provides:
//!
//! * [`workload`] — the operator-generic workload IR
//!   ([`workload::OpKind`] × the Eq.-3 problem dimensions: conv,
//!   depthwise, matmul/FC, pooling, elementwise add) and the network zoo
//!   (VGG-16/VGG-02, ResNet-50, SqueezeNet, MobileNet-V2, AlexNet, plus
//!   a BERT-style matmul stack, pooled VGG and residual MobileNet).
//! * [`arch`] — the spatial-accelerator model (storage hierarchy, PE array,
//!   NoC) with Eyeriss / NVDLA / ShiDianNao presets and YAML configs.
//! * [`mapping`] — the mapping IR (tiling, permutation, spatial partition)
//!   with full validity checking.
//! * [`model`] — the Timeloop-lite analytical engine: loop-nest reuse
//!   analysis, access counts, NoC traffic, PE utilization, latency.
//! * [`energy`] — the Accelergy-lite energy model and Fig.-7 breakdowns.
//! * [`mapspace`] — map-space enumeration, sizes and dataflow constraints.
//! * [`mappers`] — LOCAL (one pass) and the baseline mappers (dataflow-
//!   constrained search, random, exhaustive, genetic, annealing,
//!   LOCAL+refine), all reachable through one resolver
//!   ([`mappers::AnyMapper`]).
//! * [`coordinator`] — the multi-layer compile-time mapping service and the
//!   batch pipeline ([`coordinator::compile_batch`]) that shards whole
//!   model zoos across the worker pool behind one cross-network cache.
//! * [`perf`] — the performance harness behind `BENCH_eval.json`: old-vs-
//!   new evaluator throughput, exhaustive thread scaling, zoo batch wall
//!   time.
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas conv kernels
//!   (behind the `pjrt` feature; a stub otherwise).
//! * [`report`] — emitters for the paper's tables and figures plus the
//!   batch-compile summary.
//!
//! ## Quickstart
//!
//! ```
//! use local_mapper::arch::presets;
//! use local_mapper::mappers::local::LocalMapper;
//! use local_mapper::mappers::Mapper;
//! use local_mapper::model::evaluate;
//! use local_mapper::workload::zoo;
//!
//! let acc = presets::eyeriss();
//! let layer = zoo::vgg16()[8].clone(); // conv9
//! let mapping = LocalMapper::new().map(&layer, &acc).unwrap();
//! let eval = evaluate(&layer, &acc, &mapping).unwrap();
//! assert!(eval.energy.total_pj() > 0.0);
//! ```

#![warn(missing_docs)]

pub mod arch;
pub mod coordinator;
pub mod energy;
pub mod explore;
pub mod mappers;
pub mod mapping;
pub mod mapspace;
pub mod model;
pub mod noc;
pub mod perf;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
