//! # local-mapper
//!
//! A compile-time mapping framework for spatial DNN accelerators,
//! reproducing **“LOCAL: Low-Complex Mapping Algorithm for Spatial DNN
//! Accelerators”** (Reshadi & Gregg, NorCAS 2021).
//!
//! The crate provides:
//!
//! * [`api`] — **the stable, embeddable surface**: typed
//!   [`api::CompileRequest`]s, the [`api::Session`] facade (persistent
//!   mapping services, warm caches and metrics shared across requests,
//!   streaming per-layer results), one crate-wide [`api::Error`] with
//!   stable codes, and the versioned [`api::json`] serializer
//!   (`"api_v1"`). The CLI, the tests and any embedding compiler all sit
//!   on this layer.
//! * [`workload`] — the operator-generic workload IR
//!   ([`workload::OpKind`] × the Eq.-3 problem dimensions: conv,
//!   depthwise, matmul/FC, pooling, elementwise add) and the network zoo
//!   (VGG-16/VGG-02, ResNet-50, SqueezeNet, MobileNet-V2, AlexNet, plus
//!   a BERT-style matmul stack, pooled VGG and residual MobileNet).
//! * [`arch`] — the spatial-accelerator model (storage hierarchy, PE array,
//!   NoC) with Eyeriss / NVDLA / ShiDianNao presets and YAML configs.
//! * [`mapping`] — the mapping IR (tiling, permutation, spatial partition)
//!   with full validity checking.
//! * [`model`] — the Timeloop-lite analytical engine: loop-nest reuse
//!   analysis, access counts, NoC traffic, PE utilization, latency — with
//!   the zero-allocation [`model::EvalContext`] hot path every search
//!   loop rides.
//! * [`energy`] — the Accelergy-lite energy model and Fig.-7 breakdowns.
//! * [`mapspace`] — map-space enumeration, sizes and dataflow constraints.
//! * [`mappers`] — LOCAL (one pass) and the baseline mappers (dataflow-
//!   constrained search, random, exhaustive, genetic, annealing,
//!   LOCAL+refine), all reachable through one resolver
//!   ([`mappers::AnyMapper`]) and all running on the shared
//!   [`mappers::engine`]: candidate sources feeding one `SearchDriver`
//!   that owns budget truncation, pluggable [`mappers::Objective`]s
//!   (energy / delay / EDP), deterministic thread sharding
//!   (`--search-threads`) and bound-based pruning (`--no-prune` to
//!   disable).
//! * [`coordinator`] — the multi-layer compile-time mapping service and the
//!   batch pipeline ([`coordinator::compile_batch`]) that shards whole
//!   model zoos across the worker pool behind one cross-network cache
//!   keyed by [`coordinator::LayerKey`] (shape × op × objective).
//! * [`perf`] — the performance harness behind `BENCH_eval.json`: old-vs-
//!   new evaluator throughput, per-operator throughput, exhaustive thread
//!   scaling, engine pruning/scaling, zoo batch wall time.
//! * [`sim`] — the tile-pipeline latency simulator (single/double
//!   buffering) refining the analytical roofline.
//! * [`explore`] — hardware/mapping co-design sweeps and Pareto fronts.
//! * [`fault`] — deterministic fault injection (`--inject-fault`) driving
//!   the robustness tests and the CI smoke step through the service's
//!   panic-containment, fallback and respawn paths.
//! * [`graph`] — graph-level compilation: the workload DAG
//!   ([`graph::WorkloadGraph`]) recovered from the zoo's layer lists,
//!   pattern-based operator fusion ([`graph::fuse`]) and inter-layer
//!   mapping co-selection ([`graph::schedule`]) behind `--graph-mode`
//!   (`off` keeps the flat pipeline bit for bit).
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas conv kernels
//!   (behind the `pjrt` feature; a stub otherwise).
//! * [`report`] — emitters for the paper's tables and figures plus the
//!   renderers for the API's typed reports.
//!
//! ## Quickstart
//!
//! The embeddable path — a session, a typed request, a typed report:
//!
//! ```
//! use local_mapper::api::{CompileRequest, Session};
//!
//! let session = Session::new();
//! let report = session
//!     .compile(&CompileRequest::new().network("alexnet").arch_preset("eyeriss"))
//!     .unwrap();
//! assert_eq!(report.total_layers(), 5);
//! assert!(report.total_energy_uj() > 0.0);
//!
//! // Same shapes again → served from the session's warm cache.
//! let again = session
//!     .compile(&CompileRequest::new().network("alexnet").arch_preset("eyeriss"))
//!     .unwrap();
//! assert_eq!(again.cache_hits, again.requests);
//!
//! // Versioned machine-readable output (schema "api_v1").
//! let doc = local_mapper::api::json::compile_report(&report);
//! assert!(doc.contains("\"schema\": \"api_v1\""));
//! ```
//!
//! One layer, one mapper, no session — the low-level path is still there:
//!
//! ```
//! use local_mapper::arch::presets;
//! use local_mapper::mappers::{LocalMapper, Mapper};
//! use local_mapper::workload::zoo;
//!
//! let acc = presets::eyeriss();
//! let layer = zoo::vgg16()[8].clone(); // conv9
//! let out = LocalMapper::new().run(&layer, &acc).unwrap();
//! assert!(out.evaluation.energy.total_pj() > 0.0);
//! ```

#![warn(missing_docs)]

pub mod api;
pub mod arch;
pub mod coordinator;
pub mod energy;
pub mod explore;
pub mod fault;
pub mod graph;
pub mod mappers;
pub mod mapping;
pub mod mapspace;
pub mod model;
pub mod noc;
pub mod perf;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
