//! Cross-layer similarity: the warm-start half of ROADMAP open item 5
//! (DESIGN.md §15).
//!
//! The service cache only hits on *exact* [`LayerKey`] matches, yet real
//! networks are full of near-clones — BERT's FFN matmuls differ from its
//! attention matmuls in one dimension, ResNet stages differ in a stride.
//! This module gives [`super::service::MappingService`] a cheap structural
//! index over every key it has already mapped:
//!
//! * [`features`] — a per-key feature vector: operator kind (categorical,
//!   exact match required), the seven dimension bounds on a log2 scale,
//!   and stride/dilation with a heavier weight (a stride change reshapes
//!   the halo far more than a doubled channel count).
//! * [`SimilarityIndex`] — linear nearest-neighbor lookup over the mapped
//!   keys under the weighted-L1 [`distance`]. The zoo tops out at a few
//!   hundred unique keys per service, so a scan beats any tree here.
//! * [`adapt_mapping`] — re-clamp a neighbor's tiling factors to the new
//!   layer's bounds (largest divisor not exceeding the neighbor's factor,
//!   slot by slot, remainder to DRAM), keeping its permutations and
//!   spatial policy. Adapting a mapping onto its own layer reproduces it
//!   exactly; adapting onto a different layer always yields a *valid*
//!   mapping or `None` (pinned by `prop_adapted_seeds_are_always_valid`).
//!
//! The adapted mapping is only ever an engine *seed*: exhaustive/B&B take
//! it as an external incumbent bound (bit-identical final mapping,
//! [`crate::mappers::engine::SearchDriver::search_with_bound`]) and
//! heuristic mappers merge it into their result (never worse than
//! unseeded), so the warm-start path can change compile cost but never
//! mapping quality for the worse.

use super::LayerKey;
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::workload::{Layer, OpKind};

/// When the service's warm-start path may seed engine mappers from
/// similar, already-mapped layers (the `--seed-policy` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SeedPolicy {
    /// Never seed and never maintain the index — bit-for-bit the
    /// pre-warm-start service behavior.
    Off,
    /// Seed from the nearest neighbor within [`SEED_DISTANCE_MAX`],
    /// adapting its mapping to the new layer's bounds (the default).
    #[default]
    Adapt,
    /// Seed only from a zero-distance neighbor. Since the feature vector
    /// is derived from exactly the fields of [`LayerKey`], a cache *miss*
    /// can never have a zero-distance neighbor on the same service — this
    /// policy exists as the debugging floor that exercises the index
    /// without ever adapting a mapping.
    Exact,
}

impl SeedPolicy {
    /// CLI value set for `--seed-policy`.
    pub const SPEC: &'static str = "off|adapt|exact";

    /// Parse a CLI value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(SeedPolicy::Off),
            "adapt" => Some(SeedPolicy::Adapt),
            "exact" => Some(SeedPolicy::Exact),
            _ => None,
        }
    }

    /// Canonical name (stable: feeds the api_v1 `"warm"` block).
    pub fn name(self) -> &'static str {
        match self {
            SeedPolicy::Off => "off",
            SeedPolicy::Adapt => "adapt",
            SeedPolicy::Exact => "exact",
        }
    }

    /// Whether the service should maintain the index and query it at all.
    pub fn enabled(self) -> bool {
        !matches!(self, SeedPolicy::Off)
    }

    /// The neighbor-distance ceiling this policy accepts.
    pub fn max_distance(self) -> f64 {
        match self {
            SeedPolicy::Off => f64::NEG_INFINITY,
            SeedPolicy::Adapt => SEED_DISTANCE_MAX,
            SeedPolicy::Exact => 0.0,
        }
    }
}

impl std::fmt::Display for SeedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Neighbor-distance ceiling for [`SeedPolicy::Adapt`]: roughly "same
/// operator, dims within a combined factor of 2⁸ on the log-L1 scale, same
/// stride and dilation unless very little else differs".
pub const SEED_DISTANCE_MAX: f64 = 8.0;

/// Weight of the stride and dilation coordinates relative to one log2 dim
/// step (a stride change reshapes the input halo and every footprint).
const STRIDE_WEIGHT: f64 = 4.0;

/// Structural feature vector of one [`LayerKey`] (arch and objective are
/// constant within one service's index, so they carry no coordinates).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FeatureVec {
    /// Operator kind — categorical: any mismatch makes the distance
    /// infinite (a pooling window must never seed a conv).
    pub op: OpKind,
    /// log2 of the seven dimension bounds, [`crate::workload::Dim`] order.
    pub dims: [f64; 7],
    /// Stride, linear (strides are tiny integers; the gap 1→2 matters).
    pub stride: f64,
    /// Dilation, linear.
    pub dilation: f64,
}

/// Feature vector of a key (every coordinate is derived from key fields,
/// so equal keys always have distance zero and — the [`SeedPolicy::Exact`]
/// caveat — distinct keys on one service never do).
pub fn features(key: &LayerKey) -> FeatureVec {
    let mut dims = [0.0f64; 7];
    for (i, &v) in key.dims.iter().enumerate() {
        dims[i] = (v.max(1) as f64).log2();
    }
    FeatureVec {
        op: key.op,
        dims,
        stride: key.stride as f64,
        dilation: key.dilation as f64,
    }
}

/// Weighted L1 distance between two feature vectors; infinite across
/// operator kinds.
pub fn distance(a: &FeatureVec, b: &FeatureVec) -> f64 {
    if a.op != b.op {
        return f64::INFINITY;
    }
    let mut d = 0.0;
    for i in 0..7 {
        d += (a.dims[i] - b.dims[i]).abs();
    }
    d += STRIDE_WEIGHT * (a.stride - b.stride).abs();
    d += STRIDE_WEIGHT * (a.dilation - b.dilation).abs();
    d
}

/// Nearest-neighbor index over previously-mapped keys, maintained by the
/// service next to its shard cache. Insertion order is the tie-break, so
/// lookups are deterministic for a fixed insertion history.
#[derive(Debug, Default)]
pub struct SimilarityIndex {
    entries: Vec<(LayerKey, FeatureVec)>,
}

impl SimilarityIndex {
    /// Empty index.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of indexed keys.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the index holds no keys.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Index a freshly-mapped key (duplicates are dropped, matching the
    /// cache's insert-once discipline).
    pub fn insert(&mut self, key: LayerKey) {
        if self.entries.iter().any(|(k, _)| *k == key) {
            return;
        }
        let f = features(&key);
        self.entries.push((key, f));
    }

    /// Nearest indexed neighbor of `key` within `max_dist` (inclusive),
    /// excluding `key` itself. Exact score ties resolve to the earliest
    /// inserted entry.
    pub fn nearest(&self, key: &LayerKey, max_dist: f64) -> Option<(&LayerKey, f64)> {
        let f = features(key);
        let mut best: Option<(&LayerKey, f64)> = None;
        for (k, kf) in &self.entries {
            if k == key {
                continue;
            }
            let d = distance(&f, kf);
            if d <= max_dist && best.map_or(true, |(_, bd)| d < bd) {
                best = Some((k, d));
            }
        }
        best
    }
}

/// Largest divisor of `n` not exceeding `cap` (both ≥ 1; 1 always
/// qualifies). Dim bounds are at most a few thousand, so the descending
/// scan is cheap and runs once per adapted seed, not per candidate.
fn largest_divisor_at_most(n: u64, cap: u64) -> u64 {
    let mut k = cap.min(n).max(1);
    while n % k != 0 {
        k -= 1;
    }
    k
}

/// Adapt a neighbor's mapping to a new layer: per dim, re-clamp the
/// factor of each slot (spatial X, spatial Y, then every temporal level
/// below DRAM, in that order) to the largest divisor of the remaining
/// bound not exceeding the neighbor's factor, and send the remainder to
/// the top (DRAM) temporal level; permutations carry over unchanged.
///
/// Coverage holds by construction and the spatial products can only
/// shrink, so the usual failure mode is a buffer-capacity (`Bounding`)
/// violation on layers with fatter tensors than the neighbor's. Those
/// degrade progressively — hoist each temporal level's tile to DRAM, then
/// drop the spatial unrolling — and if nothing on the ladder validates
/// the adaptation returns `None` and the caller simply searches unseeded.
pub fn adapt_mapping(neighbor: &Mapping, layer: &Layer, acc: &Accelerator) -> Option<Mapping> {
    let n_levels = acc.n_levels();
    if neighbor.n_levels() != n_levels || n_levels == 0 {
        return None;
    }
    let top = n_levels - 1;
    let bounds = layer.bounds();
    let mut m = Mapping {
        temporal: vec![[1u64; 7]; n_levels],
        permutation: neighbor.permutation.clone(),
        spatial_x: [1; 7],
        spatial_y: [1; 7],
    };
    for d in 0..7 {
        let mut rem = bounds[d].max(1);
        let fx = largest_divisor_at_most(rem, neighbor.spatial_x[d]);
        m.spatial_x[d] = fx;
        rem /= fx;
        let fy = largest_divisor_at_most(rem, neighbor.spatial_y[d]);
        m.spatial_y[d] = fy;
        rem /= fy;
        for l in 0..top {
            let ft = largest_divisor_at_most(rem, neighbor.temporal[l][d]);
            m.temporal[l][d] = ft;
            rem /= ft;
        }
        m.temporal[top][d] = rem;
    }
    if m.validate(layer, acc).is_ok() {
        return Some(m);
    }
    // Degradation ladder: hoist one temporal level's tiles to DRAM at a
    // time (shrinking every footprint below it), re-validating each rung.
    for l in 0..top {
        for d in 0..7 {
            m.temporal[top][d] = m.temporal[top][d].saturating_mul(m.temporal[l][d]);
            m.temporal[l][d] = 1;
        }
        if m.validate(layer, acc).is_ok() {
            return Some(m);
        }
    }
    // Last rung: give up the spatial unrolling too.
    for d in 0..7 {
        let s = m.spatial_x[d].saturating_mul(m.spatial_y[d]);
        m.temporal[top][d] = m.temporal[top][d].saturating_mul(s);
        m.spatial_x[d] = 1;
        m.spatial_y[d] = 1;
    }
    if m.validate(layer, acc).is_ok() {
        return Some(m);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::super::layer_key;
    use super::*;
    use crate::arch::presets;
    use crate::mappers::{LocalMapper, Mapper};
    use crate::workload::zoo;

    #[test]
    fn policy_parse_and_name_round_trip() {
        for p in [SeedPolicy::Off, SeedPolicy::Adapt, SeedPolicy::Exact] {
            assert_eq!(SeedPolicy::parse(p.name()), Some(p));
            assert_eq!(p.to_string(), p.name());
        }
        assert_eq!(SeedPolicy::parse("warm"), None);
        assert_eq!(SeedPolicy::default(), SeedPolicy::Adapt);
        assert!(!SeedPolicy::Off.enabled());
        assert!(SeedPolicy::Adapt.enabled());
        assert!(SeedPolicy::Exact.enabled());
        assert_eq!(SeedPolicy::Adapt.max_distance(), SEED_DISTANCE_MAX);
        assert_eq!(SeedPolicy::Exact.max_distance(), 0.0);
    }

    #[test]
    fn distance_is_a_weighted_l1_on_log_dims() {
        let acc = presets::eyeriss();
        let a = layer_key(&Layer::matmul("a", 768, 768, 128), &acc);
        let b = layer_key(&Layer::matmul("b", 3072, 768, 128), &acc);
        let fa = features(&a);
        let fb = features(&b);
        assert_eq!(distance(&fa, &fa), 0.0);
        // One dim quadrupled: |log2 3072 - log2 768| = 2 exactly.
        assert!((distance(&fa, &fb) - 2.0).abs() < 1e-12);
        assert_eq!(distance(&fa, &fb).to_bits(), distance(&fb, &fa).to_bits());
        // Operator kinds never mix.
        let pool = layer_key(&Layer::pooling("p", 64, 2, 112, 112), &acc);
        assert!(distance(&fa, &features(&pool)).is_infinite());
        // Stride weighs heavier than a doubled dim.
        let conv = zoo::vgg16()[0].clone();
        let mut strided = conv.clone();
        strided.stride = 2;
        let d = distance(&features(&layer_key(&conv, &acc)), &features(&layer_key(&strided, &acc)));
        assert!((d - 4.0).abs() < 1e-12);
    }

    #[test]
    fn index_finds_the_nearest_same_op_neighbor() {
        let acc = presets::eyeriss();
        let qkv = layer_key(&Layer::matmul("qkv", 768, 768, 128), &acc);
        let ffn1 = layer_key(&Layer::matmul("ffn1", 3072, 768, 128), &acc);
        let add = layer_key(&Layer::elementwise("add", 768, 128, 1), &acc);
        let mut idx = SimilarityIndex::new();
        assert!(idx.is_empty());
        idx.insert(qkv.clone());
        idx.insert(add.clone());
        idx.insert(qkv.clone()); // duplicate dropped
        assert_eq!(idx.len(), 2);
        // The FFN matmul's nearest neighbor is the attention matmul, never
        // the elementwise add, and never itself once indexed.
        let (k, d) = idx.nearest(&ffn1, SEED_DISTANCE_MAX).unwrap();
        assert_eq!(*k, qkv);
        assert!((d - 2.0).abs() < 1e-12);
        assert!(idx.nearest(&qkv, SEED_DISTANCE_MAX).is_none(), "only itself and another op");
        // A zero ceiling (the `exact` policy) rejects the distance-2 hit.
        assert!(idx.nearest(&ffn1, 0.0).is_none());
        // Threshold is inclusive at the boundary.
        idx.insert(ffn1.clone());
        assert!(idx.nearest(&ffn1, 0.0).is_none(), "self is excluded");
    }

    #[test]
    fn largest_divisor_respects_cap_and_divides() {
        for (n, cap, want) in
            [(12u64, 5u64, 4u64), (12, 12, 12), (12, 1, 1), (7, 6, 1), (3072, 768, 768), (1, 9, 1)]
        {
            assert_eq!(largest_divisor_at_most(n, cap), want, "n={n} cap={cap}");
        }
    }

    #[test]
    fn adapting_onto_the_same_layer_reproduces_the_mapping() {
        let acc = presets::eyeriss();
        for layer in zoo::bert_base().iter().take(6) {
            let out = LocalMapper::new().run(layer, &acc).unwrap();
            let adapted = adapt_mapping(&out.mapping, layer, &acc).unwrap();
            assert_eq!(adapted, out.mapping, "{}", layer.name);
        }
    }

    #[test]
    fn adapted_mappings_validate_on_the_target_layer() {
        let acc = presets::eyeriss();
        let src = Layer::matmul("qkv", 768, 768, 128);
        let out = LocalMapper::new().run(&src, &acc).unwrap();
        for target in [
            Layer::matmul("ffn1", 3072, 768, 128),
            Layer::matmul("ffn2", 768, 3072, 128),
            Layer::matmul("tiny", 48, 48, 16),
            Layer::matmul("odd", 751, 53, 17), // prime-ish bounds: clamps collapse to 1s
        ] {
            let adapted = adapt_mapping(&out.mapping, &target, &acc)
                .unwrap_or_else(|| panic!("{} must adapt", target.name));
            adapted.validate(&target, &acc).unwrap();
        }
    }
}
