//! The compile-time coordinator — the paper's "usability at the compiler
//! level" claim made concrete.
//!
//! [`compile_network`] maps every layer of a network — conv, matmul,
//! pooling or elementwise — onto an accelerator with a chosen mapper, in
//! parallel across worker threads, deduplicating identical layer shapes
//! through a mapping cache (networks repeat shapes constantly — VGG's conv
//! blocks, ResNet's bottlenecks, BERT's twelve identical encoder blocks).
//! [`service::MappingService`] wraps the same machinery as a persistent
//! request loop with metrics, the form a compiler would embed.
//! [`compile_batch`] scales the service to whole model zoos: every layer of
//! every network is sharded across the worker pool behind one
//! **cross-network** mapping cache keyed by [`layer_key`], and the batch
//! reports aggregate [`ServiceMetrics`] (hit rate, p50/p99 service time) —
//! the `compile-all` CLI subcommand in production form.

pub mod persist;
pub mod service;
pub mod similarity;

pub use persist::{CacheStats, CompactReport, LifetimeTotals, LoadReport, PersistentCache};
pub use service::{JobHandle, MapReply, MappingService, ServiceMetrics};
pub use similarity::{adapt_mapping, SeedPolicy, SimilarityIndex, SEED_DISTANCE_MAX};

use crate::arch::Accelerator;
use crate::mappers::{MapError, MapOutcome, Mapper, Objective};
use crate::util::table::{fmt_f64, Table};
use crate::workload::{Layer, OpKind};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// Cache key: everything that determines a mapping for a layer on an arch
/// (the operator kind plus all seven dims, stride and dilation — dilation
/// changes the input halo, hence footprints and every downstream metric —
/// plus the search objective).
///
/// The operator kind is a *correctness* field, not bookkeeping: a matmul,
/// a pooling window and a 1×1 conv can share identical dimension bounds
/// while carrying different relevance sets and tensor volumes, so keys
/// must never collide across ops (pinned by
/// `prop_layer_keys_distinct_across_ops` in `rust/tests/property.rs`).
/// The objective is equally load-bearing: the delay-optimal mapping of a
/// shape is not its energy-optimal mapping, so distinct objectives must
/// never share a cache entry ([`LayerKey::for_objective`]).
///
/// Formerly a formatted `String`; now a plain struct so keys hash without
/// formatting on every request, and [`LayerKey::fnv1a`] gives a stable
/// 64-bit fingerprint for cache sharding ([`service::MappingService`]'s
/// shard pick). The [`std::fmt::Display`] impl renders the canonical
/// string form for logs and reports.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct LayerKey {
    /// Accelerator name (presets are unique by name; YAML configs should
    /// be, too).
    pub arch: String,
    /// Operator kind of the layer (distinct ops with identical dims must
    /// produce distinct keys).
    pub op: OpKind,
    /// The seven problem-dimension bounds, [`crate::workload::Dim::idx`]
    /// order (N, M, C, R, S, P, Q).
    pub dims: [u64; 7],
    /// Stride.
    pub stride: u64,
    /// Filter dilation (changes the input halo).
    pub dilation: u64,
    /// The objective the mapper optimized (distinct objectives must never
    /// share a cache entry).
    pub objective: Objective,
    /// Fused-group fingerprint when this entry belongs to a graph-level
    /// fused group ([`crate::graph::fuse::FusedGroup::member_keys`]);
    /// `None` for plain per-layer entries. Group-scoped entries live in
    /// the same caches without ever colliding with the plain key for the
    /// same shape.
    pub group: Option<u64>,
}

impl LayerKey {
    /// Build the key for a layer on an accelerator (at the default energy
    /// objective; see [`LayerKey::for_objective`]).
    pub fn new(layer: &Layer, acc: &Accelerator) -> Self {
        Self {
            arch: acc.name.clone(),
            op: layer.op,
            dims: [layer.n, layer.m, layer.c, layer.r, layer.s, layer.p, layer.q],
            stride: layer.stride,
            dilation: layer.dilation,
            objective: Objective::Energy,
            group: None,
        }
    }

    /// Builder: rekey for a mapper's objective (the coordinator and the
    /// service always key by `mapper.objective()`).
    pub fn for_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Builder: scope the key to a fused group by its fingerprint
    /// ([`crate::graph::fuse::FusedGroup::fingerprint`]). Group-scoped
    /// keys render, hash and fingerprint differently from the plain key,
    /// so the two kinds of entry never alias in any cache.
    pub fn with_group(mut self, fingerprint: u64) -> Self {
        self.group = Some(fingerprint);
        self
    }

    /// Stable FNV-1a 64-bit fingerprint over the canonical field encoding
    /// (arch bytes, op name bytes, each numeric field little-endian, then
    /// the objective name bytes). Used for cache sharding — stability
    /// across processes matters more than hash quality here, and FNV
    /// mixes the low bits well enough for a power-of-two shard count.
    pub fn fnv1a(&self) -> u64 {
        let mut h = fnv_bytes(0xcbf2_9ce4_8422_2325, self.arch.as_bytes());
        h = fnv_bytes(h, self.op.name().as_bytes());
        for v in self.dims {
            h = fnv_bytes(h, &v.to_le_bytes());
        }
        h = fnv_bytes(h, &self.stride.to_le_bytes());
        h = fnv_bytes(h, &self.dilation.to_le_bytes());
        h = fnv_bytes(h, self.objective.name().as_bytes());
        // Only group-scoped keys hash the fingerprint: plain keys keep the
        // exact pre-graph byte stream, so persisted cache logs stay valid.
        if let Some(g) = self.group {
            h = fnv_bytes(h, &g.to_le_bytes());
        }
        h
    }

    /// Shard index for an `n`-shard cache.
    pub fn shard(&self, n: usize) -> usize {
        (self.fnv1a() % n.max(1) as u64) as usize
    }
}

/// One FNV-1a round over a byte slice.
fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl std::fmt::Display for LayerKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}|{}|n{}m{}c{}r{}s{}p{}q{}st{}di{}|{}",
            self.arch,
            self.op,
            self.dims[0],
            self.dims[1],
            self.dims[2],
            self.dims[3],
            self.dims[4],
            self.dims[5],
            self.dims[6],
            self.stride,
            self.dilation,
            self.objective
        )?;
        // Group-scoped keys carry a suffix; plain keys render exactly the
        // pre-graph canonical form (pinned by `layer_key_display_is_canonical`).
        if let Some(g) = self.group {
            write!(f, "|g{g:016x}")?;
        }
        Ok(())
    }
}

/// Build the cache key for a layer on an accelerator (kept as the
/// call-site-compatible spelling of [`LayerKey::new`]; compose with
/// [`LayerKey::for_objective`] for non-energy mappers).
pub fn layer_key(layer: &Layer, acc: &Accelerator) -> LayerKey {
    LayerKey::new(layer, acc)
}

/// One mapped layer in a network plan.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// The layer that was mapped.
    pub layer: Layer,
    /// The mapping result.
    pub outcome: MapOutcome,
    /// Served from the mapping cache (shape already mapped).
    pub cached: bool,
}

/// A whole-network mapping plan.
#[derive(Debug, Clone)]
pub struct NetworkPlan {
    /// Accelerator name the plan targets.
    pub arch: String,
    /// Mapper that produced the plan.
    pub mapper: String,
    /// Per-layer plans in network order.
    pub layers: Vec<LayerPlan>,
    /// Wall-clock of the whole compile (all layers, parallel).
    pub compile_time: Duration,
}

impl NetworkPlan {
    /// Total energy over all layers, µJ.
    pub fn total_energy_uj(&self) -> f64 {
        self.layers.iter().map(|l| l.outcome.evaluation.energy.total_uj()).sum()
    }

    /// Total roofline latency over all layers (sequential execution).
    pub fn total_latency_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.outcome.evaluation.latency_cycles).sum()
    }

    /// Total MACs.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.outcome.evaluation.macs).sum()
    }

    /// Network-wide energy per MAC, pJ.
    pub fn pj_per_mac(&self) -> f64 {
        self.total_energy_uj() * 1e6 / self.total_macs().max(1) as f64
    }

    /// Sum of per-layer mapping times (the compile-cost metric; cached
    /// layers count ~0).
    pub fn total_mapping_time(&self) -> Duration {
        self.layers.iter().filter(|l| !l.cached).map(|l| l.outcome.elapsed).sum()
    }

    /// Cache hits.
    pub fn cache_hits(&self) -> usize {
        self.layers.iter().filter(|l| l.cached).count()
    }

    /// Mean PE utilization, MAC-weighted.
    pub fn mean_utilization(&self) -> f64 {
        let total = self.total_macs() as f64;
        self.layers
            .iter()
            .map(|l| l.outcome.evaluation.utilization * l.outcome.evaluation.macs as f64)
            .sum::<f64>()
            / total.max(1.0)
    }

    /// Per-layer report table.
    pub fn render(&self) -> Table {
        let mut t = Table::new(vec![
            "layer",
            "MACs",
            "energy (µJ)",
            "pJ/MAC",
            "util",
            "latency (cyc)",
            "map time",
            "cached",
            "status",
        ]);
        for lp in &self.layers {
            let e = &lp.outcome.evaluation;
            t.row(vec![
                lp.layer.name.clone(),
                e.macs.to_string(),
                fmt_f64(e.energy.total_uj()),
                fmt_f64(e.energy.pj_per_mac(e.macs)),
                format!("{:.0}%", e.utilization * 100.0),
                e.latency_cycles.to_string(),
                crate::util::bench::fmt_duration(lp.outcome.elapsed),
                if lp.cached { "yes" } else { "no" }.into(),
                lp.outcome.status.kind().into(),
            ]);
        }
        t
    }
}

/// Map every layer of a network, in parallel over `threads` workers, with
/// shape deduplication. The mapper is cloned per worker before the spawn
/// (search mappers carry interior `Cell` counters, so `Sync` is neither
/// required nor available for every [`crate::mappers::AnyMapper`] variant).
pub fn compile_network<M>(
    layers: &[Layer],
    acc: &Accelerator,
    mapper: &M,
    threads: usize,
) -> Result<NetworkPlan, MapError>
where
    M: Mapper + Clone + Send,
{
    let t0 = std::time::Instant::now();
    let threads = threads.max(1);

    // Deduplicate shapes under the mapper's objective (distinct
    // objectives must never share an entry).
    let objective = mapper.objective();
    let mut unique: Vec<(LayerKey, Layer)> = Vec::new();
    let mut seen: HashMap<LayerKey, usize> = HashMap::new();
    for l in layers {
        let key = layer_key(l, acc).for_objective(objective);
        if !seen.contains_key(&key) {
            seen.insert(key.clone(), unique.len());
            unique.push((key, l.clone()));
        }
    }

    // Parallel map over unique shapes. Errors stay typed end to end
    // ([`MapError`], not rendered strings); they are given layer context
    // at the assembly boundary below.
    let results: Mutex<HashMap<LayerKey, Result<MapOutcome, MapError>>> =
        Mutex::new(HashMap::new());
    let next: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(unique.len().max(1)) {
            let mapper = mapper.clone();
            let unique = &unique;
            let results = &results;
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= unique.len() {
                    break;
                }
                let (key, layer) = &unique[i];
                let out = mapper.run(layer, acc);
                results.lock().unwrap().insert(key.clone(), out);
            });
        }
    });

    // Assemble in network order; duplicate shapes are cache hits.
    let results = results.into_inner().unwrap();
    let mut plans = Vec::with_capacity(layers.len());
    let mut first_use: std::collections::HashSet<LayerKey> = std::collections::HashSet::new();
    for l in layers {
        let key = layer_key(l, acc).for_objective(objective);
        // Invariant: the worker loop above visits every index of `unique`
        // before its scope joins, and every layer's key was inserted into
        // `unique` by the dedup pass — a miss here is a coordinator bug,
        // not a reachable input condition.
        let out = results
            .get(&key)
            .expect("every key mapped")
            .as_ref()
            .map_err(|e| MapError::NoValidMapping(format!("{}: {e}", l.name)))?;
        let cached = !first_use.insert(key);
        plans.push(LayerPlan { layer: l.clone(), outcome: out.clone(), cached });
    }

    Ok(NetworkPlan {
        arch: acc.name.clone(),
        mapper: mapper.name(),
        layers: plans,
        compile_time: t0.elapsed(),
    })
}

/// One layer that failed to map within a batch — even through the
/// service's LOCAL fallback — recorded on [`BatchPlan::failures`] instead
/// of aborting the rest of the batch.
#[derive(Debug, Clone)]
pub struct BatchFailure {
    /// Network the failed layer belongs to.
    pub network: String,
    /// The failed layer's name.
    pub layer: String,
    /// Rendered mapper error.
    pub error: String,
}

/// The result of batch-compiling many networks through one shared
/// [`MappingService`]: per-network plans plus the batch-wide service
/// metrics (cross-network cache hit rate, p50/p99 service time).
#[derive(Debug, Clone)]
pub struct BatchPlan {
    /// Accelerator name the batch targets.
    pub arch: String,
    /// Mapper that produced the batch.
    pub mapper: String,
    /// `(network name, plan)` in submission order.
    pub networks: Vec<(String, NetworkPlan)>,
    /// Layers that failed to map outright, in submission order (the rest
    /// of the batch still compiled).
    pub failures: Vec<BatchFailure>,
    /// Wall-clock of the whole batch (submit → last reply).
    pub batch_time: Duration,
    /// Total layer-mapping requests served.
    pub requests: u64,
    /// Requests served from the cross-network mapping cache.
    pub cache_hits: u64,
    /// Cache hits served from entries replayed off the persistent disk
    /// log (subset of `cache_hits`; 0 without a cache dir).
    pub disk_hits: u64,
    /// Requests that shared another request's in-flight search for the
    /// same key (cross-request coalescing, DESIGN.md §16).
    pub coalesced: u64,
    /// Median in-service time per request (queue + map).
    pub p50_service: Duration,
    /// 99th-percentile in-service time per request.
    pub p99_service: Duration,
    /// Cache misses that ran warm-seeded from a similar shape's adapted
    /// mapping (DESIGN.md §15).
    pub warm_seeded: u64,
    /// Mean seed-hit quality over warm-seeded requests (final score as a
    /// fraction of the seed's; 0 when nothing was seeded).
    pub seed_quality: f64,
}

impl BatchPlan {
    /// Cross-network cache hit rate in `[0, 1]`.
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / self.requests as f64
    }

    /// Layers compiled across all networks.
    pub fn total_layers(&self) -> usize {
        self.networks.iter().map(|(_, p)| p.layers.len()).sum()
    }

    /// Total energy over every network, µJ.
    pub fn total_energy_uj(&self) -> f64 {
        self.networks.iter().map(|(_, p)| p.total_energy_uj()).sum()
    }

    /// Total MACs over every network.
    pub fn total_macs(&self) -> u64 {
        self.networks.iter().map(|(_, p)| p.total_macs()).sum()
    }
}

/// Compile a whole batch of networks on one accelerator: spin up a
/// [`MappingService`] with `threads` workers, submit **every layer of every
/// network up front** (so the queue shards the whole batch across the
/// pool), then collect per-network plans in submission order.
///
/// Unlike [`compile_network`], whose cache is scoped to one network, the
/// service cache here is shared across the batch — a ResNet bottleneck
/// shape already mapped for one network is a hit for every later network
/// on the same accelerator. `LayerPlan::cached` reflects that cross-network
/// cache, and each `NetworkPlan::compile_time` measures that network's
/// reply-collection wall-clock within the batch. Layers that fail to map
/// outright land in [`BatchPlan::failures`] instead of aborting the batch.
pub fn compile_batch<M>(
    networks: &[(String, Vec<Layer>)],
    acc: &Accelerator,
    mapper: &M,
    threads: usize,
) -> Result<BatchPlan, MapError>
where
    M: Mapper + Clone + Send + 'static,
{
    compile_batch_with_policy(networks, acc, mapper, threads, SeedPolicy::default())
}

/// [`compile_batch`] with an explicit cross-layer warm-start policy
/// (DESIGN.md §15) threaded into the underlying service.
pub fn compile_batch_with_policy<M>(
    networks: &[(String, Vec<Layer>)],
    acc: &Accelerator,
    mapper: &M,
    threads: usize,
    policy: SeedPolicy,
) -> Result<BatchPlan, MapError>
where
    M: Mapper + Clone + Send + 'static,
{
    compile_batch_persistent(networks, acc, mapper, threads, policy, None)
}

/// [`compile_batch_with_policy`] with an optional disk-backed persistent
/// cache (DESIGN.md §16): the service replays the log before taking
/// requests and appends every fresh result, so a second batch over the
/// same directory performs zero mapper evaluations.
pub fn compile_batch_persistent<M>(
    networks: &[(String, Vec<Layer>)],
    acc: &Accelerator,
    mapper: &M,
    threads: usize,
    policy: SeedPolicy,
    persist: Option<std::sync::Arc<PersistentCache>>,
) -> Result<BatchPlan, MapError>
where
    M: Mapper + Clone + Send + 'static,
{
    let t0 = std::time::Instant::now();
    let svc = MappingService::start_with_persist(
        acc.clone(),
        mapper.clone(),
        threads.max(1),
        policy,
        persist,
    );

    // Shard: all layers of all networks enter the queue immediately.
    let submitted: Vec<(String, Vec<(Layer, JobHandle)>)> = networks
        .iter()
        .map(|(name, layers)| {
            let handles =
                layers.iter().map(|l| (l.clone(), svc.submit(l.clone()))).collect();
            (name.clone(), handles)
        })
        .collect();

    // Collect per network, preserving network and layer order. A failed
    // layer (the service already tried the LOCAL fallback) is recorded in
    // `failures` and the rest of the batch still lands — one impossible
    // layer must not discard an otherwise-complete zoo compile.
    let mut plans = Vec::with_capacity(submitted.len());
    let mut failures: Vec<BatchFailure> = Vec::new();
    for (name, handles) in submitted {
        let n0 = std::time::Instant::now();
        let mut layer_plans = Vec::with_capacity(handles.len());
        for (layer, handle) in handles {
            match handle.wait() {
                Ok(reply) => layer_plans.push(LayerPlan {
                    layer,
                    outcome: reply.outcome,
                    cached: reply.cached,
                }),
                Err(e) => failures.push(BatchFailure {
                    network: name.clone(),
                    layer: layer.name.clone(),
                    error: e.to_string(),
                }),
            }
        }
        plans.push((
            name,
            NetworkPlan {
                arch: acc.name.clone(),
                mapper: mapper.name(),
                layers: layer_plans,
                compile_time: n0.elapsed(),
            },
        ));
    }

    // Freeze the metrics before tearing the service down.
    let metrics = std::sync::Arc::clone(&svc.metrics);
    svc.shutdown();
    let ordering = std::sync::atomic::Ordering::Relaxed;
    let percentiles = metrics.service_time_percentiles(&[0.50, 0.99]);
    Ok(BatchPlan {
        arch: acc.name.clone(),
        mapper: mapper.name(),
        networks: plans,
        failures,
        batch_time: t0.elapsed(),
        requests: metrics.requests.load(ordering),
        cache_hits: metrics.cache_hits.load(ordering),
        disk_hits: metrics.disk_hits.load(ordering),
        coalesced: metrics.coalesced.load(ordering),
        p50_service: percentiles[0],
        p99_service: percentiles[1],
        warm_seeded: metrics.warm_seeded.load(ordering),
        seed_quality: metrics.seed_quality(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::LocalMapper;
    use crate::workload::zoo;

    #[test]
    fn compiles_vgg16_with_dedup() {
        let acc = presets::eyeriss();
        let layers = zoo::vgg16();
        let plan = compile_network(&layers, &acc, &LocalMapper::new(), 4).unwrap();
        assert_eq!(plan.layers.len(), 13);
        // VGG16 has repeated shapes (conv6/conv7, conv9/conv10, conv12/13).
        assert!(plan.cache_hits() >= 3, "cache hits: {}", plan.cache_hits());
        assert!(plan.total_energy_uj() > 0.0);
        assert_eq!(plan.total_macs(), layers.iter().map(|l| l.macs()).sum::<u64>());
    }

    #[test]
    fn single_thread_equals_parallel() {
        let acc = presets::nvdla();
        let layers = zoo::squeezenet();
        let p1 = compile_network(&layers, &acc, &LocalMapper::new(), 1).unwrap();
        let p8 = compile_network(&layers, &acc, &LocalMapper::new(), 8).unwrap();
        assert_eq!(p1.layers.len(), p8.layers.len());
        for (a, b) in p1.layers.iter().zip(&p8.layers) {
            assert_eq!(a.outcome.mapping, b.outcome.mapping, "layer {}", a.layer.name);
        }
    }

    #[test]
    fn plan_renders() {
        let acc = presets::shidiannao();
        let layers = zoo::alexnet();
        let plan = compile_network(&layers, &acc, &LocalMapper::new(), 2).unwrap();
        let t = plan.render();
        assert_eq!(t.n_rows(), 5);
        assert!(plan.mean_utilization() > 0.0);
    }

    #[test]
    fn batch_compiles_two_networks_with_cross_network_cache() {
        let acc = presets::eyeriss();
        let networks = vec![
            ("alexnet".to_string(), zoo::alexnet()),
            ("alexnet-again".to_string(), zoo::alexnet()),
        ];
        let batch = compile_batch(&networks, &acc, &LocalMapper::new(), 1).unwrap();
        assert_eq!(batch.networks.len(), 2);
        assert!(batch.failures.is_empty());
        assert_eq!(batch.total_layers(), 10);
        assert_eq!(batch.requests, 10);
        // One worker processes requests in submission order, so every layer
        // of the second (identical) network hits the shared cache.
        assert_eq!(batch.cache_hits, 5);
        assert!((batch.hit_rate() - 0.5).abs() < 1e-12);
        assert!(batch.p50_service <= batch.p99_service);
        assert!(batch.total_energy_uj() > 0.0);
        assert_eq!(batch.total_macs(), 2 * zoo::alexnet().iter().map(|l| l.macs()).sum::<u64>());
    }

    #[test]
    fn layer_key_display_is_canonical() {
        let acc = presets::eyeriss();
        let l = zoo::vgg16()[0].clone(); // 64×3×3×3×224×224, stride 1
        let key = layer_key(&l, &acc);
        assert_eq!(key.to_string(), format!("{}|conv|n1m64c3r3s3p224q224st1di1|energy", acc.name));
        let mm = Layer::matmul("mm", 768, 768, 128);
        assert_eq!(
            layer_key(&mm, &acc).for_objective(Objective::Edp).to_string(),
            format!("{}|matmul|n1m768c768r1s1p128q1st1di1|edp", acc.name)
        );
    }

    #[test]
    fn group_scoped_layer_keys_never_alias_plain_keys() {
        // Graph-level fused groups scope their members' cache entries with
        // the group fingerprint; the plain key's rendering, equality and
        // fnv1a stream must stay byte-identical to the pre-graph form.
        let acc = presets::eyeriss();
        let l = zoo::vgg16()[0].clone();
        let plain = layer_key(&l, &acc);
        let grouped = layer_key(&l, &acc).with_group(0xdead_beef);
        assert_ne!(plain, grouped);
        assert_ne!(plain.fnv1a(), grouped.fnv1a());
        assert_eq!(grouped.to_string(), format!("{plain}|g00000000deadbeef"));
        assert_eq!(plain.group, None);
        // Distinct groups, distinct keys.
        assert_ne!(grouped.fnv1a(), layer_key(&l, &acc).with_group(1).fnv1a());
    }

    #[test]
    fn layer_key_distinguishes_objectives() {
        // The delay-optimal mapping of a shape is not its energy-optimal
        // mapping: objectives must never share a cache entry or shard
        // fingerprint.
        let acc = presets::eyeriss();
        let l = zoo::vgg16()[0].clone();
        let keys: Vec<LayerKey> =
            Objective::ALL.iter().map(|&o| layer_key(&l, &acc).for_objective(o)).collect();
        for i in 0..keys.len() {
            for j in i + 1..keys.len() {
                assert_ne!(keys[i], keys[j]);
                assert_ne!(keys[i].fnv1a(), keys[j].fnv1a());
            }
        }
        assert_eq!(layer_key(&l, &acc), layer_key(&l, &acc).for_objective(Objective::Energy));
    }

    #[test]
    fn layer_key_distinguishes_op_kinds_with_identical_dims() {
        // A 1×1 conv, a 1×1 pooling window and an elementwise add can all
        // carry the same seven bounds: the op field must keep their cache
        // entries apart (different relevance → different mappings).
        let acc = presets::eyeriss();
        let conv = Layer::new("c", 64, 1, 1, 1, 14, 14);
        let pool = Layer::pooling("p", 64, 1, 14, 14);
        let add = Layer::elementwise("a", 64, 14, 14);
        assert_eq!(conv.bounds(), pool.bounds());
        assert_eq!(conv.bounds(), add.bounds());
        let keys = [layer_key(&conv, &acc), layer_key(&pool, &acc), layer_key(&add, &acc)];
        assert_ne!(keys[0], keys[1]);
        assert_ne!(keys[0], keys[2]);
        assert_ne!(keys[1], keys[2]);
        assert_ne!(keys[0].fnv1a(), keys[1].fnv1a());
        assert_ne!(keys[0].fnv1a(), keys[2].fnv1a());
        assert_ne!(keys[1].fnv1a(), keys[2].fnv1a());
    }

    #[test]
    fn layer_key_hash_tracks_equality() {
        let a = presets::eyeriss();
        let b = presets::nvdla();
        let l1 = zoo::vgg16()[0].clone();
        let l2 = zoo::vgg16()[1].clone();
        assert_eq!(layer_key(&l1, &a).fnv1a(), layer_key(&l1, &a).fnv1a());
        assert_ne!(layer_key(&l1, &a).fnv1a(), layer_key(&l1, &b).fnv1a());
        assert_ne!(layer_key(&l1, &a).fnv1a(), layer_key(&l2, &a).fnv1a());
        // Shard index is always in range.
        for n in [1usize, 2, 16, 17] {
            assert!(layer_key(&l1, &a).shard(n) < n);
        }
    }

    #[test]
    fn layer_key_distinguishes_arch_and_shape() {
        let a = presets::eyeriss();
        let b = presets::nvdla();
        let l1 = zoo::vgg16()[0].clone();
        let l2 = zoo::vgg16()[1].clone();
        assert_ne!(layer_key(&l1, &a), layer_key(&l1, &b));
        assert_ne!(layer_key(&l1, &a), layer_key(&l2, &a));
        assert_eq!(layer_key(&l1, &a), layer_key(&l1, &a));
        // Dilation changes the input halo and must not collide in the cache.
        let mut dilated = l1.clone();
        dilated.dilation = 2;
        assert_ne!(layer_key(&l1, &a), layer_key(&dilated, &a));
    }
}
