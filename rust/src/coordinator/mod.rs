//! The compile-time coordinator — the paper's "usability at the compiler
//! level" claim made concrete.
//!
//! [`compile_network`] maps every conv layer of a network onto an
//! accelerator with a chosen mapper, in parallel across worker threads,
//! deduplicating identical layer shapes through a mapping cache (networks
//! repeat shapes constantly — VGG's conv blocks, ResNet's bottlenecks).
//! [`service::MappingService`] wraps the same machinery as a persistent
//! request loop with metrics, the form a compiler would embed.

pub mod service;

pub use service::{MappingService, ServiceMetrics};

use crate::arch::Accelerator;
use crate::mappers::{MapError, MapOutcome, Mapper};
use crate::util::table::{fmt_f64, Table};
use crate::workload::ConvLayer;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// Cache key: everything that determines a mapping for a layer on an arch.
pub fn layer_key(layer: &ConvLayer, acc: &Accelerator) -> String {
    format!(
        "{}|n{}m{}c{}r{}s{}p{}q{}st{}dw{}",
        acc.name,
        layer.n,
        layer.m,
        layer.c,
        layer.r,
        layer.s,
        layer.p,
        layer.q,
        layer.stride,
        layer.depthwise
    )
}

/// One mapped layer in a network plan.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub layer: ConvLayer,
    pub outcome: MapOutcome,
    /// Served from the mapping cache (shape already mapped).
    pub cached: bool,
}

/// A whole-network mapping plan.
#[derive(Debug, Clone)]
pub struct NetworkPlan {
    pub arch: String,
    pub mapper: String,
    pub layers: Vec<LayerPlan>,
    /// Wall-clock of the whole compile (all layers, parallel).
    pub compile_time: Duration,
}

impl NetworkPlan {
    /// Total energy over all layers, µJ.
    pub fn total_energy_uj(&self) -> f64 {
        self.layers.iter().map(|l| l.outcome.evaluation.energy.total_uj()).sum()
    }

    /// Total roofline latency over all layers (sequential execution).
    pub fn total_latency_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.outcome.evaluation.latency_cycles).sum()
    }

    /// Total MACs.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.outcome.evaluation.macs).sum()
    }

    /// Sum of per-layer mapping times (the compile-cost metric; cached
    /// layers count ~0).
    pub fn total_mapping_time(&self) -> Duration {
        self.layers.iter().filter(|l| !l.cached).map(|l| l.outcome.elapsed).sum()
    }

    /// Cache hits.
    pub fn cache_hits(&self) -> usize {
        self.layers.iter().filter(|l| l.cached).count()
    }

    /// Mean PE utilization, MAC-weighted.
    pub fn mean_utilization(&self) -> f64 {
        let total = self.total_macs() as f64;
        self.layers
            .iter()
            .map(|l| l.outcome.evaluation.utilization * l.outcome.evaluation.macs as f64)
            .sum::<f64>()
            / total.max(1.0)
    }

    /// Per-layer report table.
    pub fn render(&self) -> Table {
        let mut t = Table::new(vec![
            "layer", "MACs", "energy (µJ)", "pJ/MAC", "util", "latency (cyc)", "map time", "cached",
        ]);
        for lp in &self.layers {
            let e = &lp.outcome.evaluation;
            t.row(vec![
                lp.layer.name.clone(),
                e.macs.to_string(),
                fmt_f64(e.energy.total_uj()),
                fmt_f64(e.energy.pj_per_mac(e.macs)),
                format!("{:.0}%", e.utilization * 100.0),
                e.latency_cycles.to_string(),
                crate::util::bench::fmt_duration(lp.outcome.elapsed),
                if lp.cached { "yes" } else { "no" }.into(),
            ]);
        }
        t
    }
}

/// Map every layer of a network, in parallel over `threads` workers, with
/// shape deduplication. The mapper is cloned per worker (search mappers
/// carry interior counters).
pub fn compile_network<M>(
    layers: &[ConvLayer],
    acc: &Accelerator,
    mapper: &M,
    threads: usize,
) -> Result<NetworkPlan, MapError>
where
    M: Mapper + Clone + Send + Sync,
{
    let t0 = std::time::Instant::now();
    let threads = threads.max(1);

    // Deduplicate shapes.
    let mut unique: Vec<(String, ConvLayer)> = Vec::new();
    let mut seen: HashMap<String, usize> = HashMap::new();
    for l in layers {
        let key = layer_key(l, acc);
        if !seen.contains_key(&key) {
            seen.insert(key.clone(), unique.len());
            unique.push((key, l.clone()));
        }
    }

    // Parallel map over unique shapes.
    let results: Mutex<HashMap<String, Result<MapOutcome, String>>> = Mutex::new(HashMap::new());
    let next: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(unique.len().max(1)) {
            let mapper = mapper.clone();
            let unique = &unique;
            let results = &results;
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= unique.len() {
                    break;
                }
                let (key, layer) = &unique[i];
                let out = mapper.run(layer, acc).map_err(|e| e.to_string());
                results.lock().unwrap().insert(key.clone(), out);
            });
        }
    });

    // Assemble in network order; duplicate shapes are cache hits.
    let results = results.into_inner().unwrap();
    let mut plans = Vec::with_capacity(layers.len());
    let mut first_use: std::collections::HashSet<String> = std::collections::HashSet::new();
    for l in layers {
        let key = layer_key(l, acc);
        let out = results
            .get(&key)
            .expect("every key mapped")
            .as_ref()
            .map_err(|e| MapError::NoValidMapping(format!("{}: {e}", l.name)))?;
        let cached = !first_use.insert(key);
        plans.push(LayerPlan { layer: l.clone(), outcome: out.clone(), cached });
    }

    Ok(NetworkPlan {
        arch: acc.name.clone(),
        mapper: mapper.name(),
        layers: plans,
        compile_time: t0.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::LocalMapper;
    use crate::workload::zoo;

    #[test]
    fn compiles_vgg16_with_dedup() {
        let acc = presets::eyeriss();
        let layers = zoo::vgg16();
        let plan = compile_network(&layers, &acc, &LocalMapper::new(), 4).unwrap();
        assert_eq!(plan.layers.len(), 13);
        // VGG16 has repeated shapes (conv6/conv7, conv9/conv10, conv12/13).
        assert!(plan.cache_hits() >= 3, "cache hits: {}", plan.cache_hits());
        assert!(plan.total_energy_uj() > 0.0);
        assert_eq!(plan.total_macs(), layers.iter().map(|l| l.macs()).sum::<u64>());
    }

    #[test]
    fn single_thread_equals_parallel() {
        let acc = presets::nvdla();
        let layers = zoo::squeezenet();
        let p1 = compile_network(&layers, &acc, &LocalMapper::new(), 1).unwrap();
        let p8 = compile_network(&layers, &acc, &LocalMapper::new(), 8).unwrap();
        assert_eq!(p1.layers.len(), p8.layers.len());
        for (a, b) in p1.layers.iter().zip(&p8.layers) {
            assert_eq!(a.outcome.mapping, b.outcome.mapping, "layer {}", a.layer.name);
        }
    }

    #[test]
    fn plan_renders() {
        let acc = presets::shidiannao();
        let layers = zoo::alexnet();
        let plan = compile_network(&layers, &acc, &LocalMapper::new(), 2).unwrap();
        let t = plan.render();
        assert_eq!(t.n_rows(), 5);
        assert!(plan.mean_utilization() > 0.0);
    }

    #[test]
    fn layer_key_distinguishes_arch_and_shape() {
        let a = presets::eyeriss();
        let b = presets::nvdla();
        let l1 = zoo::vgg16()[0].clone();
        let l2 = zoo::vgg16()[1].clone();
        assert_ne!(layer_key(&l1, &a), layer_key(&l1, &b));
        assert_ne!(layer_key(&l1, &a), layer_key(&l2, &a));
        assert_eq!(layer_key(&l1, &a), layer_key(&l1, &a));
    }
}
