//! The persistent mapping service — a compiler-embeddable request loop.
//!
//! Worker threads pull [`MapRequest`]s from a shared queue, consult the
//! mapping cache, run the mapper on misses, and answer on a per-request
//! channel; failures cross the channel as typed [`MapError`]s so
//! embedders ([`crate::api::Session`], the batch pipeline) never parse
//! error strings. Metrics (requests, cache hits, p50 service time) are
//! exported for the coordinator's own observability — the paper's
//! compile-time claim is only credible if mapping latency is measured in
//! situ.
//!
//! Two hot-path design points: the cache is **sharded** into
//! independently-locked shards keyed by the [`LayerKey`] FNV-1a
//! fingerprint (the old single `Mutex<HashMap>` serialized every worker),
//! and service-time samples land in a **lock-free ring** — recording a
//! request is atomic counter bumps plus one relaxed slot store, so metrics
//! never block the request path.

use super::{layer_key, LayerKey};
use crate::arch::Accelerator;
use crate::mappers::{MapError, MapOutcome, Mapper};
use crate::workload::Layer;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A mapping request: one layer on the service's accelerator.
struct MapRequest {
    layer: Layer,
    reply: mpsc::Sender<Result<MapReply, MapError>>,
    /// Stamped at submission so `service_time` covers queue wait + map.
    submitted: Instant,
}

/// Service answer.
#[derive(Debug, Clone)]
pub struct MapReply {
    /// The mapping result.
    pub outcome: MapOutcome,
    /// Served from the mapping cache (shape already mapped).
    pub cached: bool,
    /// Total in-service time (queue + map).
    pub service_time: Duration,
}

/// Cap on retained service-time samples: percentiles are computed over the
/// most recent window so a long-lived (compiler-embedded) service's memory
/// stays bounded at ~512 KiB however many requests it serves. The ring is
/// allocated up front (lock-free slots cannot grow lazily) — a deliberate
/// trade of one fixed allocation per service for a mutex-free record path.
const MAX_SAMPLES: usize = 1 << 16;

/// Number of independently-locked cache shards. A power of two comfortably
/// above any realistic worker count, so concurrent misses on *different*
/// shapes almost never contend on the same lock.
const CACHE_SHARDS: usize = 16;

/// The mapping cache, split into [`CACHE_SHARDS`] independently-locked
/// shards keyed by [`LayerKey::shard`] (FNV-1a fingerprint). Replaces the
/// old single `Mutex<HashMap>` whose one lock serialized every worker's
/// cache probe and fill.
struct ShardedCache {
    shards: Vec<Mutex<HashMap<LayerKey, MapOutcome>>>,
}

impl ShardedCache {
    fn new() -> Self {
        Self { shards: (0..CACHE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect() }
    }

    fn get(&self, key: &LayerKey) -> Option<MapOutcome> {
        self.shards[key.shard(CACHE_SHARDS)].lock().unwrap().get(key).cloned()
    }

    fn insert(&self, key: LayerKey, outcome: MapOutcome) {
        let shard = key.shard(CACHE_SHARDS);
        self.shards[shard].lock().unwrap().insert(key, outcome);
    }
}

/// Lock-free bounded ring of recent service-time samples, ns.
///
/// Writers claim a slot index with a relaxed `fetch_add` on `claimed`,
/// store the sample, and only then bump `published` — metrics recording
/// never takes a lock on the request critical path, and readers size their
/// snapshot by `published`, so a claimed-but-unwritten slot is (almost
/// never — see below) exposed as a phantom sample. Readers are best-effort
/// telemetry: a slot overwritten concurrently yields a value from either
/// generation, and while writers race, out-of-order completions can
/// transiently expose up to one claimed-but-unwritten slot per in-flight
/// writer; both resolve as soon as the writers finish. Totals are exact at
/// quiescence: once every request
/// has been recorded, `published == claimed` and every counted slot holds
/// a real sample (asserted by `metrics_totals_exact_with_lock_free_samples`).
struct SampleRing {
    slots: Box<[AtomicU64]>,
    /// Slot claims ever issued (monotone; next write position).
    claimed: AtomicUsize,
    /// Completed stores (monotone; readers snapshot up to this).
    published: AtomicUsize,
}

impl Default for SampleRing {
    fn default() -> Self {
        Self {
            slots: (0..MAX_SAMPLES).map(|_| AtomicU64::new(0)).collect(),
            claimed: AtomicUsize::new(0),
            published: AtomicUsize::new(0),
        }
    }
}

impl std::fmt::Debug for SampleRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SampleRing")
            .field("published", &self.published.load(Ordering::Relaxed))
            .finish()
    }
}

impl SampleRing {
    fn push(&self, ns: u64) {
        let i = self.claimed.fetch_add(1, Ordering::Relaxed) % MAX_SAMPLES;
        self.slots[i].store(ns, Ordering::Release);
        self.published.fetch_add(1, Ordering::Release);
    }

    /// Samples retained (exact once all in-flight pushes complete, capped
    /// at the window size).
    fn len(&self) -> usize {
        self.published.load(Ordering::Acquire).min(MAX_SAMPLES)
    }

    fn snapshot(&self) -> Vec<u64> {
        (0..self.len()).map(|i| self.slots[i].load(Ordering::Acquire)).collect()
    }
}

/// Nearest-rank percentile over an ascending-sorted sample slice.
fn percentile_of(sorted: &[u64], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let q = q.clamp(0.0, 1.0);
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    Duration::from_nanos(sorted[idx])
}

/// Counters exported by the service: monotone totals plus a bounded window
/// of service-time samples for percentile queries (the batch pipeline
/// reports p50/p99).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Requests answered (hits + misses + errors).
    pub requests: AtomicU64,
    /// Requests served from the mapping cache.
    pub cache_hits: AtomicU64,
    /// Requests answered with a mapper error.
    pub errors: AtomicU64,
    /// Sum of service times, ns (divide by requests for the mean).
    pub service_ns: AtomicU64,
    /// Most recent service times, ns (percentile source; bounded,
    /// lock-free).
    samples_ns: SampleRing,
}

impl ServiceMetrics {
    /// Record one answered request. Called by the workers; totals only ever
    /// grow, so readers can treat every counter as monotone. The entire
    /// record is atomic counter bumps plus one lock-free ring-slot write —
    /// nothing on the request critical path blocks.
    fn record(&self, service_time: Duration, cached: bool, error: bool) {
        let ns = service_time.as_nanos() as u64;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.service_ns.fetch_add(ns, Ordering::Relaxed);
        if cached {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        if error {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.samples_ns.push(ns);
    }

    /// Sorted snapshot of the retained service-time window.
    fn sorted_samples(&self) -> Vec<u64> {
        let mut samples = self.samples_ns.snapshot();
        samples.sort_unstable();
        samples
    }

    /// Mean service time over all requests so far.
    pub fn mean_service_time(&self) -> Duration {
        let n = self.requests.load(Ordering::Relaxed).max(1);
        Duration::from_nanos(self.service_ns.load(Ordering::Relaxed) / n)
    }

    /// Service-time percentile (`q` in `[0, 1]`, nearest-rank) over the
    /// retained window; zero before any request completes.
    pub fn percentile_service_time(&self, q: f64) -> Duration {
        percentile_of(&self.sorted_samples(), q)
    }

    /// Several percentiles from a single sorted snapshot (one snapshot,
    /// one sort — use this instead of repeated [`percentile_service_time`]
    /// calls when reporting more than one quantile).
    ///
    /// [`percentile_service_time`]: ServiceMetrics::percentile_service_time
    pub fn service_time_percentiles(&self, qs: &[f64]) -> Vec<Duration> {
        let sorted = self.sorted_samples();
        qs.iter().map(|&q| percentile_of(&sorted, q)).collect()
    }

    /// Median (p50) service time.
    pub fn p50_service_time(&self) -> Duration {
        self.percentile_service_time(0.50)
    }

    /// Tail (p99) service time.
    pub fn p99_service_time(&self) -> Duration {
        self.percentile_service_time(0.99)
    }

    /// Cache hit rate in `[0, 1]` (0 before any request completes).
    pub fn hit_rate(&self) -> f64 {
        let requests = self.requests.load(Ordering::Relaxed);
        if requests == 0 {
            return 0.0;
        }
        self.cache_hits.load(Ordering::Relaxed) as f64 / requests as f64
    }
}

/// A running mapping service over one accelerator and one mapper.
pub struct MappingService {
    tx: Option<mpsc::Sender<MapRequest>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Live service counters; clone the `Arc` to keep them past shutdown.
    pub metrics: Arc<ServiceMetrics>,
}

impl MappingService {
    /// Spawn the service with `threads` workers.
    pub fn start<M>(acc: Accelerator, mapper: M, threads: usize) -> Self
    where
        M: Mapper + Clone + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<MapRequest>();
        let rx = Arc::new(Mutex::new(rx));
        let cache: Arc<ShardedCache> = Arc::new(ShardedCache::new());
        let metrics = Arc::new(ServiceMetrics::default());
        let mut workers = Vec::new();
        for _ in 0..threads.max(1) {
            let rx = Arc::clone(&rx);
            let cache = Arc::clone(&cache);
            let metrics = Arc::clone(&metrics);
            let acc = acc.clone();
            let mapper = mapper.clone();
            workers.push(std::thread::spawn(move || {
                // Cache entries are keyed by the mapper's objective, so a
                // (hypothetical) cache shared across services can never
                // serve a delay-optimal mapping to an energy request.
                let objective = mapper.objective();
                loop {
                    // Holding the lock only for recv keeps workers
                    // independent.
                    let req = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(req) = req else { break }; // channel closed → drain
                    let key = layer_key(&req.layer, &acc).for_objective(objective);
                    let hit = cache.get(&key);
                    let (result, cached) = match hit {
                        Some(outcome) => (Ok(outcome), true),
                        None => match mapper.run(&req.layer, &acc) {
                            Ok(outcome) => {
                                cache.insert(key, outcome.clone());
                                (Ok(outcome), false)
                            }
                            Err(e) => (Err(e), false),
                        },
                    };
                    let service_time = req.submitted.elapsed();
                    metrics.record(service_time, cached, result.is_err());
                    // Receiver may have given up; ignore send failures.
                    let _ = req
                        .reply
                        .send(result.map(|outcome| MapReply { outcome, cached, service_time }));
                }
            }));
        }
        Self { tx: Some(tx), workers, metrics }
    }

    /// Submit a layer; returns a handle to await the reply.
    pub fn submit(&self, layer: Layer) -> JobHandle {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("service running")
            .send(MapRequest { layer, reply: reply_tx, submitted: Instant::now() })
            .expect("workers alive");
        JobHandle { rx: reply_rx }
    }

    /// Map a batch and wait for all replies (in request order).
    pub fn map_all(&self, layers: &[Layer]) -> Vec<Result<MapReply, MapError>> {
        let handles: Vec<JobHandle> = layers.iter().map(|l| self.submit(l.clone())).collect();
        handles.into_iter().map(|h| h.wait()).collect()
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // close channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for MappingService {
    fn drop(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Await handle for one submitted request.
pub struct JobHandle {
    rx: mpsc::Receiver<Result<MapReply, MapError>>,
}

impl JobHandle {
    /// Block until the reply arrives. Failures come back as the worker's
    /// typed [`MapError`] (a dropped request — service torn down with the
    /// job still queued — reports as `NoValidMapping`).
    pub fn wait(self) -> Result<MapReply, MapError> {
        self.rx
            .recv()
            .map_err(|_| MapError::NoValidMapping("service dropped request".to_string()))?
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<MapReply, MapError>> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::LocalMapper;
    use crate::workload::zoo;
    use std::sync::atomic::Ordering;

    #[test]
    fn service_maps_a_network_with_cache_hits() {
        let svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 4);
        let layers = zoo::vgg16();
        let replies = svc.map_all(&layers);
        assert_eq!(replies.len(), 13);
        for r in &replies {
            let r = r.as_ref().unwrap();
            assert!(r.outcome.evaluation.energy.total_pj() > 0.0);
        }
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 13);
        // Repeated VGG shapes must hit the cache (exact count depends on
        // request interleaving across workers; at least the later
        // duplicates hit).
        assert!(svc.metrics.cache_hits.load(Ordering::Relaxed) >= 1);
        assert_eq!(svc.metrics.errors.load(Ordering::Relaxed), 0);
        assert!(svc.metrics.mean_service_time() > Duration::ZERO);
        svc.shutdown();
    }

    #[test]
    fn repeated_submission_is_cached() {
        let svc = MappingService::start(presets::nvdla(), LocalMapper::new(), 1);
        let layer = zoo::vgg16()[0].clone();
        let a = svc.submit(layer.clone()).wait().unwrap();
        let b = svc.submit(layer).wait().unwrap();
        assert!(!a.cached);
        assert!(b.cached);
        assert_eq!(a.outcome.mapping, b.outcome.mapping);
    }

    #[test]
    fn service_keys_cache_entries_by_objective() {
        // Two services over the same shapes but different objectives must
        // key their entries apart; each reply carries its own objective.
        use crate::mappers::Objective;
        let layer = zoo::vgg16()[8].clone();
        let energy_svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 1);
        let delay_svc = MappingService::start(
            presets::eyeriss(),
            LocalMapper::new().with_objective(Objective::Delay),
            1,
        );
        let e = energy_svc.submit(layer.clone()).wait().unwrap();
        let d = delay_svc.submit(layer.clone()).wait().unwrap();
        assert_eq!(e.outcome.objective, Objective::Energy);
        assert_eq!(d.outcome.objective, Objective::Delay);
        let acc = presets::eyeriss();
        assert_ne!(
            layer_key(&layer, &acc).for_objective(Objective::Energy),
            layer_key(&layer, &acc).for_objective(Objective::Delay)
        );
        energy_svc.shutdown();
        delay_svc.shutdown();
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let svc = MappingService::start(presets::shidiannao(), LocalMapper::new(), 2);
        let h = svc.submit(zoo::alexnet()[0].clone());
        h.wait().unwrap();
        svc.shutdown(); // must not hang
    }

    #[test]
    fn percentiles_and_hit_rate_track_requests() {
        let svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 2);
        let replies = svc.map_all(&zoo::vgg16());
        assert!(replies.iter().all(|r| r.is_ok()));
        let m = &svc.metrics;
        assert!(m.p50_service_time() > Duration::ZERO);
        assert!(m.p50_service_time() <= m.p99_service_time());
        // The first request of a fresh service is always a miss.
        assert!(m.hit_rate() < 1.0);
        assert!(m.percentile_service_time(0.0) <= m.percentile_service_time(1.0));
        svc.shutdown();
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = ServiceMetrics::default();
        assert_eq!(m.p50_service_time(), Duration::ZERO);
        assert_eq!(m.p99_service_time(), Duration::ZERO);
        assert_eq!(m.hit_rate(), 0.0);
        assert_eq!(m.service_time_percentiles(&[0.5, 0.99]), vec![Duration::ZERO; 2]);
    }

    #[test]
    fn sample_ring_is_bounded() {
        let ring = SampleRing::default();
        for i in 0..(MAX_SAMPLES + 10) as u64 {
            ring.push(i);
        }
        assert_eq!(ring.len(), MAX_SAMPLES);
        // The overflow entries overwrote the oldest slots.
        assert!(ring.snapshot().contains(&(MAX_SAMPLES as u64 + 5)));
    }

    #[test]
    fn metrics_totals_exact_with_lock_free_samples() {
        // Per-request totals must stay exact under concurrent recording:
        // every request bumps the counters and claims exactly one ring
        // slot, with no lock on the request path to drop or batch samples.
        let svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 4);
        let mut layers = Vec::new();
        for _ in 0..3 {
            layers.extend(zoo::vgg16());
        }
        let replies = svc.map_all(&layers);
        assert_eq!(replies.len(), 39);
        assert!(replies.iter().all(|r| r.is_ok()));
        let m = &svc.metrics;
        assert_eq!(m.requests.load(Ordering::Relaxed), 39);
        assert_eq!(m.errors.load(Ordering::Relaxed), 0);
        assert_eq!(m.samples_ns.len(), 39);
        assert!(m.p50_service_time() > Duration::ZERO);
        svc.shutdown();
    }
}
