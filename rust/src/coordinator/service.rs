//! The persistent mapping service — a compiler-embeddable request loop.
//!
//! Worker threads pull [`MapRequest`]s from a shared queue, consult the
//! mapping cache, run the mapper on misses, and answer on a per-request
//! channel. Metrics (requests, cache hits, p50 service time) are exported
//! for the coordinator's own observability — the paper's compile-time
//! claim is only credible if mapping latency is measured in situ.

use super::layer_key;
use crate::arch::Accelerator;
use crate::mappers::{MapOutcome, Mapper};
use crate::workload::ConvLayer;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A mapping request: one layer on the service's accelerator.
struct MapRequest {
    layer: ConvLayer,
    reply: mpsc::Sender<Result<MapReply, String>>,
}

/// Service answer.
#[derive(Debug, Clone)]
pub struct MapReply {
    pub outcome: MapOutcome,
    pub cached: bool,
    /// Total in-service time (queue + map).
    pub service_time: Duration,
}

/// Counters exported by the service.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub requests: AtomicU64,
    pub cache_hits: AtomicU64,
    pub errors: AtomicU64,
    /// Sum of service times, ns (divide by requests for the mean).
    pub service_ns: AtomicU64,
}

impl ServiceMetrics {
    pub fn mean_service_time(&self) -> Duration {
        let n = self.requests.load(Ordering::Relaxed).max(1);
        Duration::from_nanos(self.service_ns.load(Ordering::Relaxed) / n)
    }
}

/// A running mapping service over one accelerator and one mapper.
pub struct MappingService {
    tx: Option<mpsc::Sender<MapRequest>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<ServiceMetrics>,
}

impl MappingService {
    /// Spawn the service with `threads` workers.
    pub fn start<M>(acc: Accelerator, mapper: M, threads: usize) -> Self
    where
        M: Mapper + Clone + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<MapRequest>();
        let rx = Arc::new(Mutex::new(rx));
        let cache: Arc<Mutex<HashMap<String, MapOutcome>>> = Arc::new(Mutex::new(HashMap::new()));
        let metrics = Arc::new(ServiceMetrics::default());
        let mut workers = Vec::new();
        for _ in 0..threads.max(1) {
            let rx = Arc::clone(&rx);
            let cache = Arc::clone(&cache);
            let metrics = Arc::clone(&metrics);
            let acc = acc.clone();
            let mapper = mapper.clone();
            workers.push(std::thread::spawn(move || loop {
                // Holding the lock only for recv keeps workers independent.
                let req = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(req) = req else { break }; // channel closed → drain
                let t0 = Instant::now();
                let key = layer_key(&req.layer, &acc);
                let hit = cache.lock().unwrap().get(&key).cloned();
                let (result, cached) = match hit {
                    Some(outcome) => (Ok(outcome), true),
                    None => match mapper.run(&req.layer, &acc) {
                        Ok(outcome) => {
                            cache.lock().unwrap().insert(key, outcome.clone());
                            (Ok(outcome), false)
                        }
                        Err(e) => (Err(e.to_string()), false),
                    },
                };
                let service_time = t0.elapsed();
                metrics.requests.fetch_add(1, Ordering::Relaxed);
                metrics.service_ns.fetch_add(service_time.as_nanos() as u64, Ordering::Relaxed);
                if cached {
                    metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                }
                if result.is_err() {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                }
                // Receiver may have given up; ignore send failures.
                let _ = req.reply.send(result.map(|outcome| MapReply { outcome, cached, service_time }));
            }));
        }
        Self { tx: Some(tx), workers, metrics }
    }

    /// Submit a layer; returns a handle to await the reply.
    pub fn submit(&self, layer: ConvLayer) -> JobHandle {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("service running")
            .send(MapRequest { layer, reply: reply_tx })
            .expect("workers alive");
        JobHandle { rx: reply_rx }
    }

    /// Map a batch and wait for all replies (in request order).
    pub fn map_all(&self, layers: &[ConvLayer]) -> Vec<Result<MapReply, String>> {
        let handles: Vec<JobHandle> = layers.iter().map(|l| self.submit(l.clone())).collect();
        handles.into_iter().map(|h| h.wait()).collect()
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // close channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for MappingService {
    fn drop(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Await handle for one submitted request.
pub struct JobHandle {
    rx: mpsc::Receiver<Result<MapReply, String>>,
}

impl JobHandle {
    /// Block until the reply arrives.
    pub fn wait(self) -> Result<MapReply, String> {
        self.rx.recv().map_err(|_| "service dropped request".to_string())?
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<MapReply, String>> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::LocalMapper;
    use crate::workload::zoo;
    use std::sync::atomic::Ordering;

    #[test]
    fn service_maps_a_network_with_cache_hits() {
        let svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 4);
        let layers = zoo::vgg16();
        let replies = svc.map_all(&layers);
        assert_eq!(replies.len(), 13);
        for r in &replies {
            let r = r.as_ref().unwrap();
            assert!(r.outcome.evaluation.energy.total_pj() > 0.0);
        }
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 13);
        // Repeated VGG shapes must hit the cache (exact count depends on
        // request interleaving across workers; at least the later
        // duplicates hit).
        assert!(svc.metrics.cache_hits.load(Ordering::Relaxed) >= 1);
        assert_eq!(svc.metrics.errors.load(Ordering::Relaxed), 0);
        assert!(svc.metrics.mean_service_time() > Duration::ZERO);
        svc.shutdown();
    }

    #[test]
    fn repeated_submission_is_cached() {
        let svc = MappingService::start(presets::nvdla(), LocalMapper::new(), 1);
        let layer = zoo::vgg16()[0].clone();
        let a = svc.submit(layer.clone()).wait().unwrap();
        let b = svc.submit(layer).wait().unwrap();
        assert!(!a.cached);
        assert!(b.cached);
        assert_eq!(a.outcome.mapping, b.outcome.mapping);
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let svc = MappingService::start(presets::shidiannao(), LocalMapper::new(), 2);
        let h = svc.submit(zoo::alexnet()[0].clone());
        h.wait().unwrap();
        svc.shutdown(); // must not hang
    }
}
