//! The persistent mapping service — a compiler-embeddable request loop.
//!
//! Worker threads pull [`MapRequest`]s from a shared queue, consult the
//! mapping cache, run the mapper on misses, and answer on a per-request
//! channel; failures cross the channel as typed [`MapError`]s so
//! embedders ([`crate::api::Session`], the batch pipeline) never parse
//! error strings. Metrics (requests, cache hits, p50 service time) are
//! exported for the coordinator's own observability — the paper's
//! compile-time claim is only credible if mapping latency is measured in
//! situ.
//!
//! Two hot-path design points: the cache is **sharded** into
//! independently-locked shards keyed by the [`LayerKey`] FNV-1a
//! fingerprint (the old single `Mutex<HashMap>` serialized every worker),
//! and service-time samples land in a **lock-free ring** — recording a
//! request is atomic counter bumps plus one relaxed slot store, so metrics
//! never block the request path.
//!
//! On a cache miss the service can additionally **warm-start** the mapper
//! from the nearest already-mapped shape (DESIGN.md §15): a
//! [`SimilarityIndex`] over the cached keys finds a same-op neighbour,
//! [`adapt_mapping`] re-clamps its tiling onto the new bounds, and the
//! mapper receives the result as a seed whose contract is result-only /
//! bound-only — seeding can cut evaluations but never change or worsen
//! the selected mapping. Gated by [`SeedPolicy`] and by
//! [`Mapper::accepts_seeds`], so LOCAL services pay nothing.
//!
//! # Service layer (DESIGN.md §16)
//!
//! Two request-path features turn the in-process pool into a durable
//! compilation service. **Cross-request coalescing**: identical in-flight
//! requests (same cache key — layer, arch, objective) share one search
//! via a pending-request table; the first miss claims the search, later
//! twins park their reply sender on it and are answered from the
//! winner's result, so N concurrent identical submissions cost one
//! evaluation budget. **Persistence**: with a
//! [`PersistentCache`](super::persist::PersistentCache) attached
//! ([`MappingService::start_with_persist`]), the disk log is replayed
//! into the sharded cache at startup and every fresh result is appended
//! and flushed — a restarted service never re-maps a layer it has seen
//! (0 mapper evaluations on a warm restart).
//!
//! # Fault isolation (DESIGN.md §14)
//!
//! Each request body runs inside a `catch_unwind` boundary: a panicking
//! mapper is converted into a typed [`MapError::Panicked`] instead of
//! killing the worker, and — like any ordinary mapper error — degrades to
//! the O(1) LOCAL fallback so the layer still gets a valid mapping
//! (flagged [`MapStatus::FellBack`], never cached). Should a worker thread
//! die anyway (a panic outside the boundary), [`MappingService::submit`]
//! supervises the pool and respawns it. Panics, fallbacks and respawns
//! are all counted in [`ServiceMetrics`]. A claimed coalescing entry is
//! resolved on *every* exit path of its search — success, typed error,
//! contained panic → fallback — so parked waiters can never be orphaned.

use super::persist::{LifetimeTotals, PersistentCache};
use super::similarity::{adapt_mapping, SeedPolicy, SimilarityIndex};
use super::{layer_key, LayerKey};
use crate::arch::Accelerator;
use crate::mappers::{LocalMapper, MapError, MapOutcome, MapStatus, Mapper};
use crate::model::EvalContext;
use crate::workload::Layer;
use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A mapping request: one layer on the service's accelerator.
struct MapRequest {
    layer: Layer,
    reply: mpsc::Sender<Result<MapReply, MapError>>,
    /// Stamped at submission so `service_time` covers queue wait + map.
    submitted: Instant,
    /// Process-wide submission ordinal ([`crate::fault::next_ordinal`]);
    /// keys ordinal-targeted fault injection deterministically, whatever
    /// the worker scheduling or cache state.
    ordinal: u64,
}

/// Service answer.
#[derive(Debug, Clone)]
pub struct MapReply {
    /// The mapping result.
    pub outcome: MapOutcome,
    /// Served from the mapping cache (shape already mapped).
    pub cached: bool,
    /// Total in-service time (queue + map).
    pub service_time: Duration,
}

/// Cap on retained service-time samples: percentiles are computed over the
/// most recent window so a long-lived (compiler-embedded) service's memory
/// stays bounded at ~512 KiB however many requests it serves. The ring is
/// allocated up front (lock-free slots cannot grow lazily) — a deliberate
/// trade of one fixed allocation per service for a mutex-free record path.
const MAX_SAMPLES: usize = 1 << 16;

/// Number of independently-locked cache shards. A power of two comfortably
/// above any realistic worker count, so concurrent misses on *different*
/// shapes almost never contend on the same lock.
const CACHE_SHARDS: usize = 16;

/// The mapping cache, split into [`CACHE_SHARDS`] independently-locked
/// shards keyed by [`LayerKey::shard`] (FNV-1a fingerprint). Replaces the
/// old single `Mutex<HashMap>` whose one lock serialized every worker's
/// cache probe and fill.
struct ShardedCache {
    shards: Vec<Mutex<HashMap<LayerKey, MapOutcome>>>,
}

impl ShardedCache {
    fn new() -> Self {
        Self { shards: (0..CACHE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect() }
    }

    // Shard locks tolerate poisoning: a worker that panicked mid-insert
    // leaves the map either without the entry or with a fully-cloned one
    // (`HashMap::insert` doesn't tear values), so the data is safe to keep
    // serving and one crashed request must not wedge the whole cache.
    fn get(&self, key: &LayerKey) -> Option<MapOutcome> {
        self.shards[key.shard(CACHE_SHARDS)]
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .get(key)
            .cloned()
    }

    fn insert(&self, key: LayerKey, outcome: MapOutcome) {
        let shard = key.shard(CACHE_SHARDS);
        self.shards[shard].lock().unwrap_or_else(|p| p.into_inner()).insert(key, outcome);
    }
}

/// Lock-free bounded ring of recent service-time samples, ns.
///
/// Writers claim a slot index with a relaxed `fetch_add` on `claimed`,
/// store the sample, and only then bump `published` — metrics recording
/// never takes a lock on the request critical path, and readers size their
/// snapshot by `published`, so a claimed-but-unwritten slot is (almost
/// never — see below) exposed as a phantom sample. Readers are best-effort
/// telemetry: a slot overwritten concurrently yields a value from either
/// generation, and while writers race, out-of-order completions can
/// transiently expose up to one claimed-but-unwritten slot per in-flight
/// writer; both resolve as soon as the writers finish. Totals are exact at
/// quiescence: once every request
/// has been recorded, `published == claimed` and every counted slot holds
/// a real sample (asserted by `metrics_totals_exact_with_lock_free_samples`).
struct SampleRing {
    slots: Box<[AtomicU64]>,
    /// Slot claims ever issued (monotone; next write position).
    claimed: AtomicUsize,
    /// Completed stores (monotone; readers snapshot up to this).
    published: AtomicUsize,
}

impl Default for SampleRing {
    fn default() -> Self {
        Self {
            slots: (0..MAX_SAMPLES).map(|_| AtomicU64::new(0)).collect(),
            claimed: AtomicUsize::new(0),
            published: AtomicUsize::new(0),
        }
    }
}

impl std::fmt::Debug for SampleRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SampleRing")
            .field("published", &self.published.load(Ordering::Relaxed))
            .finish()
    }
}

impl SampleRing {
    fn push(&self, ns: u64) {
        let i = self.claimed.fetch_add(1, Ordering::Relaxed) % MAX_SAMPLES;
        self.slots[i].store(ns, Ordering::Release);
        self.published.fetch_add(1, Ordering::Release);
    }

    /// Samples retained (exact once all in-flight pushes complete, capped
    /// at the window size).
    fn len(&self) -> usize {
        self.published.load(Ordering::Acquire).min(MAX_SAMPLES)
    }

    fn snapshot(&self) -> Vec<u64> {
        (0..self.len()).map(|i| self.slots[i].load(Ordering::Acquire)).collect()
    }
}

/// Nearest-rank percentile over an ascending-sorted sample slice.
fn percentile_of(sorted: &[u64], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let q = q.clamp(0.0, 1.0);
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    Duration::from_nanos(sorted[idx])
}

/// Counters exported by the service: monotone totals plus a bounded window
/// of service-time samples for percentile queries (the batch pipeline
/// reports p50/p99).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Requests answered (hits + misses + errors).
    pub requests: AtomicU64,
    /// Requests served from the mapping cache.
    pub cache_hits: AtomicU64,
    /// Requests answered with a mapper error.
    pub errors: AtomicU64,
    /// Mapper panics contained at the workers' unwind boundary.
    pub panics: AtomicU64,
    /// Requests answered by the LOCAL fallback rung after the primary
    /// mapper failed or panicked (not counted in `errors`).
    pub fallbacks: AtomicU64,
    /// Worker threads respawned by the supervisor after dying to a panic
    /// outside the containment region.
    pub respawns: AtomicU64,
    /// Cache hits served from entries preloaded off the persistent disk
    /// log (a subset of `cache_hits`; 0 for memory-only services).
    pub disk_hits: AtomicU64,
    /// Requests that parked on another request's in-flight search for
    /// the same key instead of starting their own (DESIGN.md §16).
    /// Counted at registration time, so tests can await coalescing
    /// deterministically before releasing the owning search.
    pub coalesced: AtomicU64,
    /// Cache misses answered by a mapper run that was warm-seeded with a
    /// mapping adapted from the nearest already-mapped neighbour
    /// (DESIGN.md §15).
    pub warm_seeded: AtomicU64,
    /// Sum over warm-seeded requests of `final_score / seed_score × 1000`
    /// (milli-units; see [`ServiceMetrics::seed_quality`] for the mean).
    pub seed_quality_milli: AtomicU64,
    /// Sum of service times, ns (divide by requests for the mean).
    pub service_ns: AtomicU64,
    /// Most recent service times, ns (percentile source; bounded,
    /// lock-free).
    samples_ns: SampleRing,
}

impl ServiceMetrics {
    /// Record one answered request. Called by the workers; totals only ever
    /// grow, so readers can treat every counter as monotone. The entire
    /// record is atomic counter bumps plus one lock-free ring-slot write —
    /// nothing on the request critical path blocks.
    fn record(&self, service_time: Duration, cached: bool, error: bool) {
        let ns = service_time.as_nanos() as u64;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.service_ns.fetch_add(ns, Ordering::Relaxed);
        if cached {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        if error {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.samples_ns.push(ns);
    }

    /// Sorted snapshot of the retained service-time window.
    fn sorted_samples(&self) -> Vec<u64> {
        let mut samples = self.samples_ns.snapshot();
        samples.sort_unstable();
        samples
    }

    /// Mean service time over all requests so far.
    pub fn mean_service_time(&self) -> Duration {
        let n = self.requests.load(Ordering::Relaxed).max(1);
        Duration::from_nanos(self.service_ns.load(Ordering::Relaxed) / n)
    }

    /// Service-time percentile (`q` in `[0, 1]`, nearest-rank) over the
    /// retained window; zero before any request completes.
    pub fn percentile_service_time(&self, q: f64) -> Duration {
        percentile_of(&self.sorted_samples(), q)
    }

    /// Several percentiles from a single sorted snapshot (one snapshot,
    /// one sort — use this instead of repeated [`percentile_service_time`]
    /// calls when reporting more than one quantile).
    ///
    /// [`percentile_service_time`]: ServiceMetrics::percentile_service_time
    pub fn service_time_percentiles(&self, qs: &[f64]) -> Vec<Duration> {
        let sorted = self.sorted_samples();
        qs.iter().map(|&q| percentile_of(&sorted, q)).collect()
    }

    /// Median (p50) service time.
    pub fn p50_service_time(&self) -> Duration {
        self.percentile_service_time(0.50)
    }

    /// Tail (p99) service time.
    pub fn p99_service_time(&self) -> Duration {
        self.percentile_service_time(0.99)
    }

    /// Mean warm-seed quality: the final score as a fraction of the
    /// adapted seed's score, averaged over warm-seeded requests. Values
    /// ≤ 1.0 mean the search ended at or below its seed; 0 before any
    /// seeded request completes.
    pub fn seed_quality(&self) -> f64 {
        let n = self.warm_seeded.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.seed_quality_milli.load(Ordering::Relaxed) as f64 / (n as f64 * 1000.0)
    }

    /// Cache hit rate in `[0, 1]` (0 before any request completes).
    pub fn hit_rate(&self) -> f64 {
        let requests = self.requests.load(Ordering::Relaxed);
        if requests == 0 {
            return 0.0;
        }
        self.cache_hits.load(Ordering::Relaxed) as f64 / requests as f64
    }
}

/// Cap on supervisor respawns over a service's lifetime — a crash-looping
/// mapper must not leak an unbounded stream of threads. Far above anything
/// a real workload hits (fault injection fires once).
const MAX_RESPAWNS: u64 = 64;

/// Extract a human-readable message from a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Reply senders parked on an in-flight search, keyed by the cache key.
/// The first miss on a key claims the search by inserting an empty
/// entry; identical requests arriving before it completes push their
/// reply sender (plus submission stamp, for honest service times) and
/// are answered from the winner's result.
type PendingTable =
    Mutex<HashMap<LayerKey, Vec<(mpsc::Sender<Result<MapReply, MapError>>, Instant)>>>;

/// The shared state one worker runs against, bundled so the respawner
/// clones a single struct and the loop signature stays readable.
#[derive(Clone)]
struct WorkerContext {
    rx: Arc<Mutex<mpsc::Receiver<MapRequest>>>,
    cache: Arc<ShardedCache>,
    index: Arc<Mutex<SimilarityIndex>>,
    policy: SeedPolicy,
    metrics: Arc<ServiceMetrics>,
    acc: Accelerator,
    /// In-flight search registry for cross-request coalescing.
    pending: Arc<PendingTable>,
    /// Disk log fresh results are appended to (`None` → memory-only).
    persist: Option<Arc<PersistentCache>>,
    /// Keys preloaded from the disk log, for `disk_hits` attribution.
    disk_keys: Arc<HashSet<LayerKey>>,
}

/// What one request resolved to inside the containment region.
enum Served {
    /// Answered from the in-memory cache.
    Hit(MapOutcome),
    /// Parked on another request's in-flight search for the same key;
    /// the owning request answers it on completion.
    Coalesced,
    /// Fresh mapper run (outcome, warm-seed quality in milli-units).
    Fresh(MapOutcome, Option<u64>),
}

/// The per-worker request loop. A free function (not a closure in `start`)
/// so the respawner can spawn byte-identical replacements.
fn worker_loop<M: Mapper>(ctx: WorkerContext, mapper: M) {
    let WorkerContext { rx, cache, index, policy, metrics, acc, pending, persist, disk_keys } =
        ctx;
    // Cache entries are keyed by the mapper's objective, so a
    // (hypothetical) cache shared across services can never serve a
    // delay-optimal mapping to an energy request.
    let objective = mapper.objective();
    // Warm starts are gated on the policy AND the mapper opting in, so a
    // LOCAL service (one evaluation per miss — nothing to warm up) pays
    // neither the index maintenance nor the lookups.
    let seeding = policy.enabled() && mapper.accepts_seeds();
    loop {
        // Holding the lock only for recv keeps workers independent. A
        // predecessor that died while holding it poisons the mutex; the
        // queue underneath is intact, so keep draining.
        let req = {
            let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
            guard.recv()
        };
        let Ok(req) = req else { break }; // channel closed → drain
        // Injected worker death fires OUTSIDE the containment region so
        // the whole thread dies (exercising the supervisor's respawn
        // path); the dropped reply sender surfaces upstream as a typed
        // "service dropped request" error.
        if crate::fault::should_kill_worker(req.ordinal) {
            panic!("injected worker death at request ordinal {}", req.ordinal);
        }
        let key = layer_key(&req.layer, &acc).for_objective(objective);
        // Containment region: the fault hook, the cache probe and the
        // mapper all run under `catch_unwind`, so one buggy (or injected)
        // panic degrades this request instead of killing the worker. The
        // mapper resets its interior state on entry, so observing it after
        // an unwind is safe (hence `AssertUnwindSafe`).
        // Set inside the containment closure when this request claims the
        // in-flight search for `key`; read afterwards on every exit path
        // (panic included) to resolve the pending entry.
        let claimed = std::cell::Cell::new(false);
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            crate::fault::inject(req.ordinal)?;
            if let Some(outcome) = cache.get(&key) {
                return Ok(Served::Hit(outcome));
            }
            // Cross-request coalescing (DESIGN.md §16): under the pending
            // lock, re-probe the cache (the owner may have finished
            // between the two probes), then either park this request on
            // an in-flight search for the same key or claim the search.
            {
                let mut table = pending.lock().unwrap_or_else(|p| p.into_inner());
                if let Some(outcome) = cache.get(&key) {
                    return Ok(Served::Hit(outcome));
                }
                if let Some(waiters) = table.get_mut(&key) {
                    waiters.push((req.reply.clone(), req.submitted));
                    metrics.coalesced.fetch_add(1, Ordering::Relaxed);
                    return Ok(Served::Coalesced);
                }
                table.insert(key.clone(), Vec::new());
                claimed.set(true);
            }
            // Warm start (DESIGN.md §15): adapt the nearest already-mapped
            // neighbour's mapping into a seed for this miss. The adapted
            // seed only ever tightens the search (every mapper's seeding
            // contract is result-only / bound-only), so correctness never
            // depends on the neighbour actually being similar.
            let seed = if seeding {
                let neighbor = {
                    let idx = index.lock().unwrap_or_else(|p| p.into_inner());
                    idx.nearest(&key, policy.max_distance()).map(|(k, _)| k.clone())
                };
                neighbor
                    .and_then(|nk| cache.get(&nk))
                    .and_then(|n| adapt_mapping(&n.mapping, &req.layer, &acc))
            } else {
                None
            };
            match seed {
                Some(seed) => {
                    let mut ctx = EvalContext::new(&req.layer, &acc);
                    let seed_score = objective.score(ctx.evaluate_into(&seed));
                    let out =
                        mapper.run_seeded(&req.layer, &acc, std::slice::from_ref(&seed))?;
                    // Seed-hit quality: how close the seed already was to
                    // where the search ended (1000 = the seed itself won).
                    let ratio_milli = if seed_score > 0.0 {
                        (objective.score(&out.evaluation) / seed_score * 1000.0) as u64
                    } else {
                        1000
                    };
                    Ok(Served::Fresh(out, Some(ratio_milli)))
                }
                None => mapper.run(&req.layer, &acc).map(|outcome| Served::Fresh(outcome, None)),
            }
        }));
        let primary = match attempt {
            Ok(r) => r,
            Err(payload) => {
                metrics.panics.fetch_add(1, Ordering::Relaxed);
                Err(MapError::Panicked(panic_message(payload.as_ref())))
            }
        };
        let (result, cached) = match primary {
            // Parked: the owning request answers it, metrics included.
            Ok(Served::Coalesced) => continue,
            Ok(Served::Hit(outcome)) => {
                if disk_keys.contains(&key) {
                    metrics.disk_hits.fetch_add(1, Ordering::Relaxed);
                }
                (Ok(outcome), true)
            }
            Ok(Served::Fresh(outcome, warm)) => {
                cache.insert(key.clone(), outcome.clone());
                if let Some(log) = &persist {
                    // Best-effort: an unwritable cache dir degrades
                    // persistence, never the reply.
                    let _ = log.append(&req.layer, &outcome, &acc);
                }
                if seeding {
                    index.lock().unwrap_or_else(|p| p.into_inner()).insert(key.clone());
                }
                if let Some(ratio_milli) = warm {
                    metrics.warm_seeded.fetch_add(1, Ordering::Relaxed);
                    metrics.seed_quality_milli.fetch_add(ratio_milli, Ordering::Relaxed);
                }
                (Ok(outcome), false)
            }
            // Degradation ladder (DESIGN.md §14): any failure — panic or
            // typed error — falls back to the O(1) LOCAL pass so the
            // layer still gets a valid mapping. The stop-gap outcome is
            // deliberately NOT cached (a transient failure must not
            // poison the cache); if even LOCAL cannot map the layer, the
            // ORIGINAL error propagates.
            Err(e) => {
                match LocalMapper::new().with_objective(objective).run(&req.layer, &acc) {
                    Ok(mut outcome) => {
                        outcome.status = MapStatus::FellBack { reason: e.to_string() };
                        metrics.fallbacks.fetch_add(1, Ordering::Relaxed);
                        (Ok(outcome), false)
                    }
                    Err(_) => (Err(e), false),
                }
            }
        };
        let service_time = req.submitted.elapsed();
        metrics.record(service_time, cached, result.is_err());
        // Resolve the coalescing entry: answer every parked waiter with
        // this result before answering our own caller. This runs on every
        // exit path of a claimed search — success, typed error, contained
        // panic → fallback — so waiters can never be orphaned.
        if claimed.get() {
            let waiters = pending
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .remove(&key)
                .unwrap_or_default();
            for (reply, submitted) in waiters {
                let waited = submitted.elapsed();
                metrics.record(waited, false, result.is_err());
                let _ = reply.send(
                    result
                        .clone()
                        .map(|outcome| MapReply { outcome, cached: false, service_time: waited }),
                );
            }
        }
        // Receiver may have given up; ignore send failures.
        let _ = req.reply.send(result.map(|outcome| MapReply { outcome, cached, service_time }));
    }
}

/// A running mapping service over one accelerator and one mapper.
pub struct MappingService {
    tx: Option<mpsc::Sender<MapRequest>>,
    /// Live worker handles, behind a lock so [`MappingService::submit`]
    /// can supervise (join the dead, install replacements) through
    /// `&self`.
    workers: Mutex<Vec<JoinHandle<()>>>,
    /// Spawns one fresh worker on the service's queue/cache/metrics; used
    /// at start and by the supervisor for respawns.
    spawn_worker: Box<dyn Fn() -> JoinHandle<()> + Send + Sync>,
    /// Live service counters; clone the `Arc` to keep them past shutdown.
    pub metrics: Arc<ServiceMetrics>,
    /// Attached disk cache; `Drop` folds this service's totals into its
    /// lifetime sidecar after the workers have quiesced.
    persist: Option<Arc<PersistentCache>>,
}

impl MappingService {
    /// Spawn the service with `threads` workers and the default seed
    /// policy ([`SeedPolicy::Adapt`] — a no-op for mappers that don't
    /// accept seeds, LOCAL included).
    pub fn start<M>(acc: Accelerator, mapper: M, threads: usize) -> Self
    where
        M: Mapper + Clone + Send + 'static,
    {
        Self::start_with_policy(acc, mapper, threads, SeedPolicy::default())
    }

    /// Spawn the service with `threads` workers and an explicit
    /// cross-layer warm-start policy (DESIGN.md §15).
    pub fn start_with_policy<M>(
        acc: Accelerator,
        mapper: M,
        threads: usize,
        policy: SeedPolicy,
    ) -> Self
    where
        M: Mapper + Clone + Send + 'static,
    {
        Self::start_with_persist(acc, mapper, threads, policy, None)
    }

    /// Spawn the service with an attached disk-backed persistent cache
    /// (DESIGN.md §16): the log is replayed into the in-memory cache up
    /// front — so a warm restart costs zero mapper evaluations — and
    /// every fresh clean result is appended and flushed. `None` behaves
    /// exactly like [`MappingService::start_with_policy`].
    pub fn start_with_persist<M>(
        acc: Accelerator,
        mapper: M,
        threads: usize,
        policy: SeedPolicy,
        persist: Option<Arc<PersistentCache>>,
    ) -> Self
    where
        M: Mapper + Clone + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<MapRequest>();
        let rx = Arc::new(Mutex::new(rx));
        let cache: Arc<ShardedCache> = Arc::new(ShardedCache::new());
        let index: Arc<Mutex<SimilarityIndex>> = Arc::new(Mutex::new(SimilarityIndex::new()));
        let metrics = Arc::new(ServiceMetrics::default());
        // Warm restart: replay the disk log into the sharded cache (and,
        // for seed-accepting mappers, the similarity index — yesterday's
        // mappings warm-start today's new shapes too). Keys replayed
        // from disk feed `disk_hits` attribution.
        let mut disk_keys = HashSet::new();
        if let Some(log) = &persist {
            let seeding = policy.enabled() && mapper.accepts_seeds();
            let loaded = log.load(&acc);
            let mut idx = index.lock().unwrap_or_else(|p| p.into_inner());
            for (key, outcome) in loaded.entries {
                if seeding {
                    idx.insert(key.clone());
                }
                cache.insert(key.clone(), outcome);
                disk_keys.insert(key);
            }
        }
        let ctx = WorkerContext {
            rx,
            cache,
            index,
            policy,
            metrics: Arc::clone(&metrics),
            acc,
            pending: Arc::new(PendingTable::default()),
            persist: persist.clone(),
            disk_keys: Arc::new(disk_keys),
        };
        // The prototype mapper sits behind a mutex so the respawner stays
        // `Sync` even for mappers with interior (`Cell`) state.
        let mapper = Mutex::new(mapper);
        let spawn_worker: Box<dyn Fn() -> JoinHandle<()> + Send + Sync> = Box::new(move || {
            let ctx = ctx.clone();
            let mapper = mapper.lock().unwrap_or_else(|p| p.into_inner()).clone();
            std::thread::spawn(move || worker_loop(ctx, mapper))
        });
        let workers = (0..threads.max(1)).map(|_| spawn_worker()).collect();
        Self { tx: Some(tx), workers: Mutex::new(workers), spawn_worker, metrics, persist }
    }

    /// Join workers that died to a panic outside the containment region
    /// (e.g. an injected worker death) and install replacements, up to
    /// [`MAX_RESPAWNS`]. Cleanly-exited workers are reaped without
    /// respawn.
    fn supervise(&self) {
        let mut workers = self.workers.lock().unwrap_or_else(|p| p.into_inner());
        if workers.iter().all(|w| !w.is_finished()) {
            return; // common case: everyone alive, nothing to reap
        }
        let handles = std::mem::take(&mut *workers);
        for handle in handles {
            if !handle.is_finished() {
                workers.push(handle);
                continue;
            }
            match handle.join() {
                Ok(()) => {} // clean exit: queue closed, no respawn
                Err(_) => {
                    if self.metrics.respawns.load(Ordering::Relaxed) < MAX_RESPAWNS {
                        self.metrics.respawns.fetch_add(1, Ordering::Relaxed);
                        workers.push((self.spawn_worker)());
                    }
                }
            }
        }
    }

    /// Submit a layer; returns a handle to await the reply. Dead workers
    /// are respawned first, so the pool self-heals request by request.
    pub fn submit(&self, layer: Layer) -> JobHandle {
        self.supervise();
        let ordinal = crate::fault::next_ordinal();
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            // Invariant: `tx` is only taken by shutdown/drop, which
            // consume/end the service — no submit can race them.
            .expect("service running")
            .send(MapRequest { layer, reply: reply_tx, submitted: Instant::now(), ordinal })
            // Send fails only when every receiver is gone; the respawner
            // closure holds the receiver `Arc` for the service's lifetime,
            // so the queue outlives any worker crash.
            .expect("request queue alive");
        JobHandle { rx: reply_rx }
    }

    /// Map a batch and wait for all replies (in request order).
    pub fn map_all(&self, layers: &[Layer]) -> Vec<Result<MapReply, MapError>> {
        let handles: Vec<JobHandle> = layers.iter().map(|l| self.submit(l.clone())).collect();
        handles.into_iter().map(|h| h.wait()).collect()
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // close channel; Drop joins the workers
    }
}

impl Drop for MappingService {
    fn drop(&mut self) {
        self.tx.take();
        // `get_mut` needs no lock (exclusive access); a poisoned mutex
        // only means a worker died mid-supervision — the handles are
        // still sound to join.
        let workers = match self.workers.get_mut() {
            Ok(w) => w,
            Err(p) => p.into_inner(),
        };
        for w in workers.drain(..) {
            let _ = w.join();
        }
        // Workers have quiesced, so the counters are final: fold this
        // service's lifetime into the cache-dir sidecar exactly once
        // (`shutdown()` also lands here). Best-effort, like appends.
        if let Some(log) = &self.persist {
            let o = Ordering::Relaxed;
            let _ = log.accumulate_totals(LifetimeTotals {
                requests: self.metrics.requests.load(o),
                cache_hits: self.metrics.cache_hits.load(o),
                fallbacks: self.metrics.fallbacks.load(o),
            });
        }
    }
}

/// Await handle for one submitted request.
pub struct JobHandle {
    rx: mpsc::Receiver<Result<MapReply, MapError>>,
}

impl JobHandle {
    /// Block until the reply arrives. Failures come back as the worker's
    /// typed [`MapError`] (a dropped request — service torn down with the
    /// job still queued — reports as `NoValidMapping`).
    pub fn wait(self) -> Result<MapReply, MapError> {
        self.rx
            .recv()
            .map_err(|_| MapError::NoValidMapping("service dropped request".to_string()))?
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<MapReply, MapError>> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::LocalMapper;
    use crate::workload::zoo;
    use std::sync::atomic::Ordering;

    #[test]
    fn service_maps_a_network_with_cache_hits() {
        let svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 4);
        let layers = zoo::vgg16();
        let replies = svc.map_all(&layers);
        assert_eq!(replies.len(), 13);
        for r in &replies {
            let r = r.as_ref().unwrap();
            assert!(r.outcome.evaluation.energy.total_pj() > 0.0);
        }
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 13);
        // Repeated VGG shapes must be deduplicated — as a cache hit when
        // the twin already finished, or coalesced onto it when it is
        // still in flight (the split depends on worker interleaving).
        let deduped = svc.metrics.cache_hits.load(Ordering::Relaxed)
            + svc.metrics.coalesced.load(Ordering::Relaxed);
        assert!(deduped >= 1);
        assert_eq!(svc.metrics.errors.load(Ordering::Relaxed), 0);
        assert!(svc.metrics.mean_service_time() > Duration::ZERO);
        svc.shutdown();
    }

    #[test]
    fn repeated_submission_is_cached() {
        let svc = MappingService::start(presets::nvdla(), LocalMapper::new(), 1);
        let layer = zoo::vgg16()[0].clone();
        let a = svc.submit(layer.clone()).wait().unwrap();
        let b = svc.submit(layer).wait().unwrap();
        assert!(!a.cached);
        assert!(b.cached);
        assert_eq!(a.outcome.mapping, b.outcome.mapping);
    }

    #[test]
    fn service_keys_cache_entries_by_objective() {
        // Two services over the same shapes but different objectives must
        // key their entries apart; each reply carries its own objective.
        use crate::mappers::Objective;
        let layer = zoo::vgg16()[8].clone();
        let energy_svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 1);
        let delay_svc = MappingService::start(
            presets::eyeriss(),
            LocalMapper::new().with_objective(Objective::Delay),
            1,
        );
        let e = energy_svc.submit(layer.clone()).wait().unwrap();
        let d = delay_svc.submit(layer.clone()).wait().unwrap();
        assert_eq!(e.outcome.objective, Objective::Energy);
        assert_eq!(d.outcome.objective, Objective::Delay);
        let acc = presets::eyeriss();
        assert_ne!(
            layer_key(&layer, &acc).for_objective(Objective::Energy),
            layer_key(&layer, &acc).for_objective(Objective::Delay)
        );
        energy_svc.shutdown();
        delay_svc.shutdown();
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let svc = MappingService::start(presets::shidiannao(), LocalMapper::new(), 2);
        let h = svc.submit(zoo::alexnet()[0].clone());
        h.wait().unwrap();
        svc.shutdown(); // must not hang
    }

    #[test]
    fn percentiles_and_hit_rate_track_requests() {
        let svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 2);
        let replies = svc.map_all(&zoo::vgg16());
        assert!(replies.iter().all(|r| r.is_ok()));
        let m = &svc.metrics;
        assert!(m.p50_service_time() > Duration::ZERO);
        assert!(m.p50_service_time() <= m.p99_service_time());
        // The first request of a fresh service is always a miss.
        assert!(m.hit_rate() < 1.0);
        assert!(m.percentile_service_time(0.0) <= m.percentile_service_time(1.0));
        svc.shutdown();
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = ServiceMetrics::default();
        assert_eq!(m.p50_service_time(), Duration::ZERO);
        assert_eq!(m.p99_service_time(), Duration::ZERO);
        assert_eq!(m.hit_rate(), 0.0);
        assert_eq!(m.service_time_percentiles(&[0.5, 0.99]), vec![Duration::ZERO; 2]);
    }

    #[test]
    fn sample_ring_is_bounded() {
        let ring = SampleRing::default();
        for i in 0..(MAX_SAMPLES + 10) as u64 {
            ring.push(i);
        }
        assert_eq!(ring.len(), MAX_SAMPLES);
        // The overflow entries overwrote the oldest slots.
        assert!(ring.snapshot().contains(&(MAX_SAMPLES as u64 + 5)));
    }

    #[test]
    fn warm_starts_seed_cache_misses_from_neighbours() {
        use crate::coordinator::SeedPolicy;
        use crate::mappers::RandomMapper;
        // One worker makes the miss order deterministic: bert_base has 4
        // unique shapes — 3 matmuls and 1 elementwise. The first matmul
        // miss has no neighbour, the other two adapt it (distance ≤ 8);
        // the elementwise add has no same-op neighbour.
        let svc = MappingService::start_with_policy(
            presets::eyeriss(),
            RandomMapper::new(64, 42),
            1,
            SeedPolicy::Adapt,
        );
        let replies = svc.map_all(&zoo::bert_base());
        assert!(replies.iter().all(|r| r.is_ok()));
        assert_eq!(svc.metrics.warm_seeded.load(Ordering::Relaxed), 2);
        let q = svc.metrics.seed_quality();
        assert!(q > 0.0 && q <= 1.0 + 1e-9, "seed quality out of range: {q}");
        svc.shutdown();
    }

    #[test]
    fn seed_policy_off_disables_warm_starts_and_never_changes_results() {
        use crate::coordinator::SeedPolicy;
        use crate::mappers::RandomMapper;
        let seeded = MappingService::start_with_policy(
            presets::eyeriss(),
            RandomMapper::new(64, 42),
            1,
            SeedPolicy::Adapt,
        );
        let cold = MappingService::start_with_policy(
            presets::eyeriss(),
            RandomMapper::new(64, 42),
            1,
            SeedPolicy::Off,
        );
        let warm_replies = seeded.map_all(&zoo::bert_base());
        let cold_replies = cold.map_all(&zoo::bert_base());
        assert_eq!(cold.metrics.warm_seeded.load(Ordering::Relaxed), 0);
        assert_eq!(cold.metrics.seed_quality(), 0.0);
        // Seeding is result-only: every layer ends at an equal-or-better
        // objective score than the unseeded service.
        for (w, c) in warm_replies.iter().zip(&cold_replies) {
            let (w, c) = (w.as_ref().unwrap(), c.as_ref().unwrap());
            assert!(
                w.outcome.evaluation.energy.total_pj()
                    <= c.outcome.evaluation.energy.total_pj() + 1e-9
            );
        }
        seeded.shutdown();
        cold.shutdown();
    }

    #[test]
    fn local_services_never_pay_for_seeding() {
        // LOCAL doesn't opt into seeds, so even an Adapt-policy service
        // keeps warm_seeded at zero (the gate is mapper-side).
        let svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 2);
        let replies = svc.map_all(&zoo::bert_base());
        assert!(replies.iter().all(|r| r.is_ok()));
        assert_eq!(svc.metrics.warm_seeded.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn concurrent_identical_requests_coalesce_into_one_search() {
        use crate::mapping::Mapping;
        use std::sync::atomic::AtomicBool;
        // A mapper whose search blocks until the test opens the gate, so
        // "identical requests while a search is in flight" is a scripted
        // state, not a race we hope to win.
        #[derive(Clone)]
        struct GatedMapper {
            gate: Arc<AtomicBool>,
            runs: Arc<AtomicU64>,
        }
        impl Mapper for GatedMapper {
            fn name(&self) -> String {
                "gated".to_string()
            }
            fn map(&self, layer: &Layer, acc: &Accelerator) -> Result<Mapping, MapError> {
                self.runs.fetch_add(1, Ordering::SeqCst);
                while !self.gate.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                LocalMapper::new().map(layer, acc)
            }
        }
        let gate = Arc::new(AtomicBool::new(false));
        let runs = Arc::new(AtomicU64::new(0));
        let svc = MappingService::start(
            presets::eyeriss(),
            GatedMapper { gate: Arc::clone(&gate), runs: Arc::clone(&runs) },
            4,
        );
        let layer = zoo::alexnet()[0].clone();
        let handles: Vec<JobHandle> = (0..4).map(|_| svc.submit(layer.clone())).collect();
        // One submission claims the (gated) search; with four workers the
        // other three must park on it. `coalesced` is bumped at
        // registration, so this wait is deterministic.
        let t0 = Instant::now();
        while svc.metrics.coalesced.load(Ordering::SeqCst) < 3 {
            assert!(t0.elapsed() < Duration::from_secs(30), "requests never coalesced");
            std::thread::sleep(Duration::from_millis(1));
        }
        gate.store(true, Ordering::SeqCst);
        let replies: Vec<MapReply> =
            handles.into_iter().map(|h| h.wait().unwrap()).collect();
        // N identical concurrent submissions → exactly one search, N
        // identical typed replies.
        assert_eq!(runs.load(Ordering::SeqCst), 1, "coalesced twins must share one search");
        assert_eq!(svc.metrics.requests.load(Ordering::SeqCst), 4);
        assert_eq!(svc.metrics.coalesced.load(Ordering::SeqCst), 3);
        for r in &replies {
            assert_eq!(r.outcome.mapping, replies[0].outcome.mapping);
            assert_eq!(r.outcome.score.to_bits(), replies[0].outcome.score.to_bits());
        }
        svc.shutdown();
    }

    #[test]
    fn warm_restart_serves_every_layer_from_the_persistent_cache() {
        let dir = std::env::temp_dir()
            .join(format!("local-mapper-svc-restart-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let layers = zoo::alexnet();
        let open = || Arc::new(PersistentCache::open(&dir).unwrap().with_namespace("LOCAL"));
        let cold_replies = {
            let svc = MappingService::start_with_persist(
                presets::eyeriss(),
                LocalMapper::new(),
                2,
                SeedPolicy::default(),
                Some(open()),
            );
            let replies: Vec<MapReply> =
                svc.map_all(&layers).into_iter().map(|r| r.unwrap()).collect();
            assert_eq!(svc.metrics.disk_hits.load(Ordering::Relaxed), 0);
            svc.shutdown();
            replies
        };
        // "Restart": a fresh service over the same directory must answer
        // every layer from the replayed log — bit-identically, with zero
        // mapper evaluations.
        let svc = MappingService::start_with_persist(
            presets::eyeriss(),
            LocalMapper::new(),
            2,
            SeedPolicy::default(),
            Some(open()),
        );
        let warm_replies: Vec<MapReply> =
            svc.map_all(&layers).into_iter().map(|r| r.unwrap()).collect();
        for (w, c) in warm_replies.iter().zip(&cold_replies) {
            assert!(w.cached, "warm restart must serve from the disk cache");
            assert_eq!(w.outcome.mapping, c.outcome.mapping);
            assert_eq!(w.outcome.score.to_bits(), c.outcome.score.to_bits());
        }
        assert_eq!(svc.metrics.cache_hits.load(Ordering::Relaxed), 5);
        assert_eq!(svc.metrics.disk_hits.load(Ordering::Relaxed), 5);
        svc.shutdown();
        // Both services folded their totals into the lifetime sidecar.
        let totals = open().read_totals();
        assert_eq!(totals.requests, 10);
        assert_eq!(totals.cache_hits, 5);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn metrics_totals_exact_with_lock_free_samples() {
        // Per-request totals must stay exact under concurrent recording:
        // every request bumps the counters and claims exactly one ring
        // slot, with no lock on the request path to drop or batch samples.
        let svc = MappingService::start(presets::eyeriss(), LocalMapper::new(), 4);
        let mut layers = Vec::new();
        for _ in 0..3 {
            layers.extend(zoo::vgg16());
        }
        let replies = svc.map_all(&layers);
        assert_eq!(replies.len(), 39);
        assert!(replies.iter().all(|r| r.is_ok()));
        let m = &svc.metrics;
        assert_eq!(m.requests.load(Ordering::Relaxed), 39);
        assert_eq!(m.errors.load(Ordering::Relaxed), 0);
        assert_eq!(m.samples_ns.len(), 39);
        assert!(m.p50_service_time() > Duration::ZERO);
        svc.shutdown();
    }
}
