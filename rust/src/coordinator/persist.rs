//! Disk-backed persistent mapping cache — the restart-survival layer of
//! the compilation service (DESIGN.md §16).
//!
//! [`PersistentCache`] keeps an append-only, checksummed log of solved
//! mapping records under a cache directory (`--cache-dir`). Each record
//! is a single line:
//!
//! ```text
//! LMC1 <fnv1a(payload), 16 hex digits> <single-line JSON payload>
//! ```
//!
//! The payload reuses the `api_v1` mapping encoder for the mapping body
//! and carries enough context to *re-derive* everything else on load:
//! the layer's dimensions, the objective, the accelerator fingerprint,
//! the producing service's namespace, and the recorded score bits.
//! [`PersistentCache::load`] replays every record through
//! [`Mapping::validate`] and the analytical model; a record whose
//! recomputed score no longer matches its recorded bits (cost-model
//! drift since the record was written) is skipped rather than trusted,
//! so the cache can never serve a stale score. Torn or corrupt tails are
//! handled like a write-ahead log: the file is truncated at the first
//! unreadable line and everything before it survives. Well-formed
//! records that merely don't apply — another accelerator, another
//! service namespace, an unknown record version — are skipped without
//! truncation, so one log can serve many configurations.
//!
//! Version evolution rule: the `LMC1` tag is bumped when the payload
//! layout changes. Loaders skip checksummed lines whose tag digit they
//! do not recognize, so old servers ignore new records and new servers
//! ignore obsolete ones — no migration step, the cache just re-warms.
//!
//! A small sidecar (`totals.v1`) accumulates lifetime service totals
//! (requests, cache hits, fallbacks) across every process that used the
//! directory; `cache-stats` and the serve `metrics` verb report these
//! alongside the current process's live counters.

use super::{layer_key, LayerKey};
use crate::api::json::{self, Json};
use crate::arch::{config, Accelerator};
use crate::mappers::{MapOutcome, MapStatus, Objective};
use crate::model::EvalContext;
use crate::workload::{Layer, OpKind};
use std::collections::HashSet;
use std::fs::{self, File, OpenOptions};
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Duration;

/// Tag opening every mapping record line (see the module docs for the
/// version-evolution rule).
const RECORD_TAG: &str = "LMC1";
/// Tag opening the lifetime-totals sidecar line.
const TOTALS_TAG: &str = "LMT1";
/// Mapping log file name inside the cache directory.
const LOG_FILE: &str = "mappings.log";
/// Lifetime-totals sidecar file name inside the cache directory.
const TOTALS_FILE: &str = "totals.v1";

/// FNV-1a over a byte string — the same dependency-free hash the
/// coordinator uses for [`LayerKey`] fingerprints.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Structural fingerprint of an accelerator: FNV-1a over its canonical
/// YAML serialization, so records are only replayed onto the exact
/// hardware they were computed for.
pub fn arch_fingerprint(acc: &Accelerator) -> u64 {
    fnv1a(config::accelerator_to_yaml(acc).as_bytes())
}

/// Cumulative service totals across every process that has used a cache
/// directory, persisted in the `totals.v1` sidecar.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LifetimeTotals {
    /// Mapping requests served.
    pub requests: u64,
    /// Requests answered from the in-memory cache.
    pub cache_hits: u64,
    /// Requests that degraded to the LOCAL fallback.
    pub fallbacks: u64,
}

/// What [`PersistentCache::load`] reconstructed from the log.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Unique `(key, outcome)` pairs ready for the in-memory cache
    /// (first record wins on duplicate keys).
    pub entries: Vec<(LayerKey, MapOutcome)>,
    /// Well-formed records replayed, duplicates included.
    pub records: usize,
    /// Well-formed records that did not apply (other accelerator, other
    /// namespace, unknown version, or stale score bits).
    pub skipped: usize,
    /// Bytes truncated off the tail after a torn or corrupt record.
    pub truncated_bytes: u64,
}

/// Summary of the on-disk log for the `cache-stats` subcommand.
#[derive(Debug, Default, Clone, Copy)]
pub struct CacheStats {
    /// Checksummed, well-formed records in the log (all namespaces).
    pub records: usize,
    /// Log file size in bytes.
    pub log_bytes: u64,
    /// Lifetime totals from the sidecar.
    pub totals: LifetimeTotals,
}

/// What [`PersistentCache::compact`] did to the log, for the
/// `cache-compact` subcommand's before/after report.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CompactReport {
    /// Well-formed records in the log before compaction (all versions
    /// and namespaces, duplicates included).
    pub before: usize,
    /// Records surviving compaction.
    pub after: usize,
    /// Later duplicates of an already-kept `(namespace, arch, key)`
    /// triple — the same records `load` would have ignored under its
    /// first-record-wins rule.
    pub dropped_duplicates: usize,
    /// Checksummed records under superseded record versions, which no
    /// current loader will ever replay.
    pub dropped_stale: usize,
}

/// An append-only, checksummed mapping log under a cache directory. One
/// instance per [`MappingService`](super::MappingService); several
/// instances (even across processes) may share a directory — appends go
/// through `O_APPEND` whole-line writes and loads filter by namespace
/// and accelerator fingerprint.
#[derive(Debug)]
pub struct PersistentCache {
    dir: PathBuf,
    log: PathBuf,
    /// Record-producer identity (mapper name, search seed, seed policy).
    /// Records only replay into a service with the same namespace, so a
    /// `random×300` search result can never warm an `exhaustive` service.
    namespace: String,
    /// Append handle behind a lock so concurrent workers emit whole
    /// records (one `write_all` per line under the lock).
    file: Mutex<File>,
}

impl PersistentCache {
    /// Open (creating if needed) the cache directory and its log with an
    /// empty namespace. Callers that mix mappers in one directory should
    /// chain [`Self::with_namespace`].
    pub fn open(dir: impl AsRef<Path>) -> io::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)?;
        let log = dir.join(LOG_FILE);
        let file = OpenOptions::new().create(true).append(true).open(&log)?;
        Ok(Self { dir, log, namespace: String::new(), file: Mutex::new(file) })
    }

    /// Set the record-producer namespace (see the `namespace` field).
    pub fn with_namespace(mut self, ns: impl Into<String>) -> Self {
        self.namespace = ns.into();
        self
    }

    /// The cache directory this instance writes under.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Append one solved mapping. Only clean (`MapStatus::Ok`) outcomes
    /// are persisted: degraded and fell-back mappings are circumstantial
    /// (a deadline fired, a fault was injected) and must not pin a worse
    /// mapping across restarts. The line is flushed before returning.
    pub fn append(&self, layer: &Layer, outcome: &MapOutcome, acc: &Accelerator) -> io::Result<()> {
        if !matches!(outcome.status, MapStatus::Ok) {
            return Ok(());
        }
        let key = layer_key(layer, acc).for_objective(outcome.objective);
        let payload = encode_payload(arch_fingerprint(acc), &self.namespace, &key, layer, outcome);
        let line = format!("{RECORD_TAG} {:016x} {payload}\n", fnv1a(payload.as_bytes()));
        let mut file = self.file.lock().unwrap_or_else(|p| p.into_inner());
        file.write_all(line.as_bytes())?;
        file.flush()
    }

    /// Replay the log into cache entries for `acc` and this namespace.
    /// Corruption truncates (see the module docs); inapplicable records
    /// are skipped and counted.
    pub fn load(&self, acc: &Accelerator) -> LoadReport {
        let bytes = match fs::read(&self.log) {
            Ok(b) => b,
            Err(_) => return LoadReport::default(),
        };
        let arch_fp = arch_fingerprint(acc);
        let mut report = LoadReport::default();
        let mut seen: HashSet<LayerKey> = HashSet::new();
        let mut pos = 0usize;
        while pos < bytes.len() {
            // A line without a terminating newline is a torn tail.
            let Some(nl) = bytes[pos..].iter().position(|&b| b == b'\n') else {
                break;
            };
            let line = &bytes[pos..pos + nl];
            match decode_line(line) {
                Decoded::Corrupt => break,
                Decoded::Skip => report.skipped += 1,
                Decoded::Payload(doc) => {
                    match decode_payload(&doc, acc, arch_fp, &self.namespace) {
                        None => report.skipped += 1,
                        Some((key, outcome)) => {
                            report.records += 1;
                            if seen.insert(key.clone()) {
                                report.entries.push((key, outcome));
                            }
                        }
                    }
                }
            }
            pos += nl + 1;
        }
        if pos < bytes.len() {
            // WAL recovery: drop the unreadable tail so the next append
            // starts from a clean record boundary.
            report.truncated_bytes = (bytes.len() - pos) as u64;
            let _ = OpenOptions::new()
                .write(true)
                .open(&self.log)
                .and_then(|f| f.set_len(pos as u64));
        }
        report
    }

    /// Log summary for `cache-stats`: checksum-validates every line but
    /// does not replay mappings (and never truncates).
    pub fn stats(&self) -> CacheStats {
        let log_bytes = fs::metadata(&self.log).map(|m| m.len()).unwrap_or(0);
        let mut records = 0usize;
        for line in self.well_formed_payloads() {
            let _ = line;
            records += 1;
        }
        CacheStats { records, log_bytes, totals: self.read_totals() }
    }

    /// The set of [`LayerKey`] fingerprints recorded for `arch_fp`, in
    /// any namespace — `cache-stats` intersects this with a network's
    /// key fingerprints to report per-network coverage.
    pub fn key_fingerprints(&self, arch_fp: u64) -> HashSet<u64> {
        let mut keys = HashSet::new();
        for doc in self.well_formed_payloads() {
            let rec_arch = doc.get("arch_fp").and_then(Json::as_str).and_then(hex64);
            if rec_arch != Some(arch_fp) {
                continue;
            }
            if let Some(fp) = doc.get("key_fp").and_then(Json::as_str).and_then(hex64) {
                keys.insert(fp);
            }
        }
        keys
    }

    /// Checksummed current-version payloads, stopping at the first
    /// corrupt line (read-only scan).
    fn well_formed_payloads(&self) -> Vec<Json> {
        let bytes = fs::read(&self.log).unwrap_or_default();
        let mut docs = Vec::new();
        let mut pos = 0usize;
        while pos < bytes.len() {
            let Some(nl) = bytes[pos..].iter().position(|&b| b == b'\n') else {
                break;
            };
            match decode_line(&bytes[pos..pos + nl]) {
                Decoded::Corrupt => break,
                Decoded::Skip => {}
                Decoded::Payload(doc) => docs.push(doc),
            }
            pos += nl + 1;
        }
        docs
    }

    /// Read the lifetime-totals sidecar; zeros when missing or corrupt
    /// (totals are best-effort operational data, never load-bearing).
    pub fn read_totals(&self) -> LifetimeTotals {
        let Ok(text) = fs::read_to_string(self.dir.join(TOTALS_FILE)) else {
            return LifetimeTotals::default();
        };
        let Some(rest) = text.trim_end().strip_prefix(TOTALS_TAG) else {
            return LifetimeTotals::default();
        };
        let Some((sum, payload)) = rest.trim_start().split_once(' ') else {
            return LifetimeTotals::default();
        };
        if hex64(sum) != Some(fnv1a(payload.as_bytes())) {
            return LifetimeTotals::default();
        }
        let Ok(doc) = json::parse(payload) else {
            return LifetimeTotals::default();
        };
        let field = |k: &str| doc.get(k).and_then(Json::as_u64).unwrap_or(0);
        LifetimeTotals {
            requests: field("requests"),
            cache_hits: field("cache_hits"),
            fallbacks: field("fallbacks"),
        }
    }

    /// Rewrite the log in place, keeping only the first well-formed
    /// record per `(namespace, arch, key)` triple — exactly the records
    /// [`Self::load`] would replay under its first-record-wins rule —
    /// and dropping duplicates, superseded record versions, and any
    /// corrupt tail. The rewrite is atomic (temp file + rename), and the
    /// append handle is reopened afterwards so later appends from this
    /// instance land in the compacted log rather than the old inode.
    pub fn compact(&self) -> io::Result<CompactReport> {
        let mut file = self.file.lock().unwrap_or_else(|p| p.into_inner());
        let bytes = fs::read(&self.log)?;
        let mut report = CompactReport::default();
        let mut kept: Vec<&[u8]> = Vec::new();
        let mut seen: HashSet<(String, String, String)> = HashSet::new();
        let mut pos = 0usize;
        while pos < bytes.len() {
            // A line without a newline is a torn tail: dropped, like the
            // WAL truncation in `load`.
            let Some(nl) = bytes[pos..].iter().position(|&b| b == b'\n') else {
                break;
            };
            match decode_line(&bytes[pos..pos + nl]) {
                Decoded::Corrupt => break,
                Decoded::Skip => {
                    report.before += 1;
                    report.dropped_stale += 1;
                }
                Decoded::Payload(doc) => {
                    report.before += 1;
                    let field =
                        |k: &str| doc.get(k).and_then(Json::as_str).unwrap_or("").to_string();
                    if seen.insert((field("ns"), field("arch_fp"), field("key_fp"))) {
                        kept.push(&bytes[pos..pos + nl + 1]);
                    } else {
                        report.dropped_duplicates += 1;
                    }
                }
            }
            pos += nl + 1;
        }
        report.after = kept.len();
        let tmp = self.dir.join(format!("{LOG_FILE}.tmp.{}", std::process::id()));
        {
            let mut out = File::create(&tmp)?;
            for line in &kept {
                out.write_all(line)?;
            }
            out.flush()?;
        }
        fs::rename(&tmp, &self.log)?;
        *file = OpenOptions::new().create(true).append(true).open(&self.log)?;
        Ok(report)
    }

    /// Fold a finished service's totals into the sidecar. The write is
    /// atomic (temp file + rename) so a crash mid-update leaves the old
    /// totals intact rather than a torn line.
    pub fn accumulate_totals(&self, delta: LifetimeTotals) -> io::Result<()> {
        let cur = self.read_totals();
        let payload = format!(
            "{{\"requests\": {}, \"cache_hits\": {}, \"fallbacks\": {}}}",
            cur.requests.saturating_add(delta.requests),
            cur.cache_hits.saturating_add(delta.cache_hits),
            cur.fallbacks.saturating_add(delta.fallbacks),
        );
        let line = format!("{TOTALS_TAG} {:016x} {payload}\n", fnv1a(payload.as_bytes()));
        let tmp = self.dir.join(format!("{TOTALS_FILE}.tmp.{}", std::process::id()));
        fs::write(&tmp, line)?;
        fs::rename(&tmp, self.dir.join(TOTALS_FILE))
    }
}

/// One line of the log, classified.
enum Decoded {
    /// Checksummed payload under the current record tag.
    Payload(Json),
    /// Checksummed line under a different record version — not ours.
    Skip,
    /// Unreadable: bad tag shape, bad checksum, or bad JSON.
    Corrupt,
}

/// Split and checksum-verify one log line.
fn decode_line(line: &[u8]) -> Decoded {
    let Ok(text) = std::str::from_utf8(line) else {
        return Decoded::Corrupt;
    };
    let mut parts = text.splitn(3, ' ');
    let (Some(tag), Some(sum), Some(payload)) = (parts.next(), parts.next(), parts.next()) else {
        return Decoded::Corrupt;
    };
    if hex64(sum) != Some(fnv1a(payload.as_bytes())) {
        return Decoded::Corrupt;
    }
    if tag != RECORD_TAG {
        // A checksummed line from another record version: skip, per the
        // evolution rule. Anything else is corruption.
        return if tag.len() == RECORD_TAG.len() && tag.starts_with("LMC") {
            Decoded::Skip
        } else {
            Decoded::Corrupt
        };
    }
    match json::parse(payload) {
        Ok(doc) => Decoded::Payload(doc),
        Err(_) => Decoded::Corrupt,
    }
}

/// Serialize one record payload (single line, stable key order).
fn encode_payload(
    arch_fp: u64,
    ns: &str,
    key: &LayerKey,
    layer: &Layer,
    outcome: &MapOutcome,
) -> String {
    // u64 fingerprints and f64 score bits travel as hex strings: the
    // hand-rolled JSON number is an f64 and would round them past 2^53.
    format!(
        "{{\"v\": 1, \"arch_fp\": \"{arch_fp:016x}\", \"ns\": \"{}\", \"key_fp\": \"{:016x}\", \
         \"name\": \"{}\", \"op\": \"{}\", \"dims\": [{}, {}, {}, {}, {}, {}, {}], \
         \"stride\": {}, \"dilation\": {}, \"objective\": \"{}\", \"score_bits\": \"{:016x}\", \
         \"evaluations\": {}, \"elapsed_us\": {}, \"certified\": {}, \"mapping\": {}}}",
        json::esc(ns),
        key.fnv1a(),
        json::esc(&layer.name),
        layer.op.name(),
        layer.n,
        layer.m,
        layer.c,
        layer.r,
        layer.s,
        layer.p,
        layer.q,
        layer.stride,
        layer.dilation,
        outcome.objective.name(),
        outcome.score.to_bits(),
        outcome.evaluations,
        outcome.elapsed.as_micros().min(u128::from(u64::MAX)) as u64,
        outcome.certified,
        json::mapping(&outcome.mapping),
    )
}

/// Parse a 16-digit hex fingerprint.
fn hex64(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

/// Rebuild `(key, outcome)` from a well-formed payload, or `None` when
/// the record does not apply here (see [`LoadReport::skipped`]).
fn decode_payload(
    doc: &Json,
    acc: &Accelerator,
    arch_fp: u64,
    ns: &str,
) -> Option<(LayerKey, MapOutcome)> {
    if doc.get("v")?.as_u64()? != 1 {
        return None;
    }
    if doc.get("arch_fp").and_then(Json::as_str).and_then(hex64)? != arch_fp {
        return None;
    }
    if doc.get("ns")?.as_str()? != ns {
        return None;
    }
    let dims = doc.get("dims")?.as_arr()?;
    if dims.len() != 7 {
        return None;
    }
    let d: Vec<u64> = dims.iter().map(Json::as_u64).collect::<Option<_>>()?;
    let layer = Layer {
        name: doc.get("name")?.as_str()?.to_string(),
        op: OpKind::parse(doc.get("op")?.as_str()?)?,
        n: d[0],
        m: d[1],
        c: d[2],
        r: d[3],
        s: d[4],
        p: d[5],
        q: d[6],
        stride: doc.get("stride")?.as_u64()?,
        dilation: doc.get("dilation")?.as_u64()?,
    };
    let objective = Objective::parse(doc.get("objective")?.as_str()?)?;
    let score_bits = doc.get("score_bits").and_then(Json::as_str).and_then(hex64)?;
    let mapping = json::parse_mapping(doc.get("mapping")?)?;
    mapping.validate(&layer, acc).ok()?;
    // Replay through the live model: the recorded score must reproduce
    // bit for bit, otherwise the cost model has moved since the record
    // was written and a fresh search is the only honest answer.
    let mut ctx = EvalContext::new(&layer, acc);
    let evaluation = ctx.evaluate_into(&mapping).clone();
    let score = objective.score(&evaluation);
    if score.to_bits() != score_bits {
        return None;
    }
    let key = layer_key(&layer, acc).for_objective(objective);
    if key.fnv1a() != doc.get("key_fp").and_then(Json::as_str).and_then(hex64)? {
        return None;
    }
    let outcome = MapOutcome {
        mapping,
        evaluation,
        evaluations: doc.get("evaluations")?.as_u64()?,
        elapsed: Duration::from_micros(doc.get("elapsed_us")?.as_u64()?),
        objective,
        score,
        certified: doc.get("certified")?.as_bool()?,
        status: MapStatus::Ok,
    };
    Some((key, outcome))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::{LocalMapper, Mapper};
    use crate::workload::zoo;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("local-mapper-persist-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn solved(layers: &[Layer], acc: &Accelerator) -> Vec<(Layer, MapOutcome)> {
        layers
            .iter()
            .map(|l| (l.clone(), LocalMapper::new().run(l, acc).unwrap()))
            .collect()
    }

    #[test]
    fn round_trip_replays_alexnet_bit_identically() {
        let dir = temp_dir("roundtrip");
        let acc = presets::eyeriss();
        let cache = PersistentCache::open(&dir).unwrap();
        let outcomes = solved(&zoo::alexnet(), &acc);
        for (layer, outcome) in &outcomes {
            cache.append(layer, outcome, &acc).unwrap();
        }
        let report = cache.load(&acc);
        assert_eq!(report.records, outcomes.len());
        assert_eq!(report.entries.len(), outcomes.len());
        assert_eq!(report.skipped, 0);
        assert_eq!(report.truncated_bytes, 0);
        for ((layer, outcome), (key, loaded)) in outcomes.iter().zip(&report.entries) {
            assert_eq!(*key, layer_key(layer, &acc).for_objective(outcome.objective));
            assert_eq!(loaded.mapping, outcome.mapping, "{}: mapping drifted", layer.name);
            assert_eq!(
                loaded.score.to_bits(),
                outcome.score.to_bits(),
                "{}: score bits drifted",
                layer.name
            );
            assert_eq!(loaded.evaluations, outcome.evaluations);
            assert_eq!(loaded.certified, outcome.certified);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_appends_dedupe_first_wins_on_load() {
        let dir = temp_dir("dedupe");
        let acc = presets::eyeriss();
        let cache = PersistentCache::open(&dir).unwrap();
        let (layer, outcome) = solved(&zoo::alexnet()[..1], &acc).remove(0);
        cache.append(&layer, &outcome, &acc).unwrap();
        cache.append(&layer, &outcome, &acc).unwrap();
        let report = cache.load(&acc);
        assert_eq!(report.records, 2);
        assert_eq!(report.entries.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_truncated_and_the_prefix_survives() {
        let dir = temp_dir("torn");
        let acc = presets::eyeriss();
        let cache = PersistentCache::open(&dir).unwrap();
        let outcomes = solved(&zoo::alexnet()[..3], &acc);
        for (layer, outcome) in &outcomes {
            cache.append(layer, outcome, &acc).unwrap();
        }
        let log = dir.join(LOG_FILE);
        let clean_len = fs::metadata(&log).unwrap().len();
        // Simulate a crash mid-append: a record prefix with no newline.
        let mut f = OpenOptions::new().append(true).open(&log).unwrap();
        f.write_all(b"LMC1 00ffee11 {\"v\": 1, \"arch").unwrap();
        drop(f);
        let report = cache.load(&acc);
        assert_eq!(report.entries.len(), 3, "prefix records must survive");
        assert!(report.truncated_bytes > 0);
        assert_eq!(fs::metadata(&log).unwrap().len(), clean_len, "tail not truncated");
        // The log is clean again: appends and reloads keep working.
        let (layer, outcome) = solved(&zoo::alexnet()[3..4], &acc).remove(0);
        cache.append(&layer, &outcome, &acc).unwrap();
        assert_eq!(cache.load(&acc).entries.len(), 4);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_checksum_truncates_from_the_bad_record() {
        let dir = temp_dir("checksum");
        let acc = presets::eyeriss();
        let cache = PersistentCache::open(&dir).unwrap();
        let outcomes = solved(&zoo::alexnet()[..3], &acc);
        for (layer, outcome) in &outcomes {
            cache.append(layer, outcome, &acc).unwrap();
        }
        let log = dir.join(LOG_FILE);
        let mut bytes = fs::read(&log).unwrap();
        // Flip one payload byte of the second record: its checksum no
        // longer matches, so recovery truncates there (WAL semantics).
        let first_nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        bytes[first_nl + 30] ^= 0x01;
        fs::write(&log, &bytes).unwrap();
        let report = cache.load(&acc);
        assert_eq!(report.entries.len(), 1, "records before the corruption survive");
        assert!(report.truncated_bytes > 0);
        assert_eq!(fs::metadata(&log).unwrap().len() as usize, first_nl + 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_arch_records_are_skipped_without_truncation() {
        let dir = temp_dir("arch");
        let eyeriss = presets::eyeriss();
        let nvdla = presets::by_name("nvdla").unwrap();
        let cache = PersistentCache::open(&dir).unwrap();
        for (layer, outcome) in solved(&zoo::alexnet(), &eyeriss) {
            cache.append(&layer, &outcome, &eyeriss).unwrap();
        }
        let report = cache.load(&nvdla);
        assert_eq!(report.entries.len(), 0);
        assert_eq!(report.skipped, 5);
        assert_eq!(report.truncated_bytes, 0, "foreign records must not be destroyed");
        assert_eq!(cache.load(&eyeriss).entries.len(), 5, "still replay on their own arch");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn namespaces_partition_the_log() {
        let dir = temp_dir("ns");
        let acc = presets::eyeriss();
        let writer = PersistentCache::open(&dir).unwrap().with_namespace("LOCAL|s42");
        for (layer, outcome) in solved(&zoo::alexnet()[..2], &acc) {
            writer.append(&layer, &outcome, &acc).unwrap();
        }
        let other = PersistentCache::open(&dir).unwrap().with_namespace("random×300|s7");
        assert_eq!(other.load(&acc).entries.len(), 0);
        assert_eq!(writer.load(&acc).entries.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_record_versions_are_skipped_not_truncated() {
        let dir = temp_dir("version");
        let acc = presets::eyeriss();
        let cache = PersistentCache::open(&dir).unwrap();
        let payload = "{\"v\": 9}";
        let line = format!("LMC9 {:016x} {payload}\n", fnv1a(payload.as_bytes()));
        fs::write(dir.join(LOG_FILE), line).unwrap();
        let (layer, outcome) = solved(&zoo::alexnet()[..1], &acc).remove(0);
        cache.append(&layer, &outcome, &acc).unwrap();
        let report = cache.load(&acc);
        assert_eq!(report.entries.len(), 1, "records after the foreign version still load");
        assert_eq!(report.skipped, 1);
        assert_eq!(report.truncated_bytes, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_score_bits_are_skipped() {
        let dir = temp_dir("drift");
        let acc = presets::eyeriss();
        let cache = PersistentCache::open(&dir).unwrap();
        let (layer, outcome) = solved(&zoo::alexnet()[..1], &acc).remove(0);
        cache.append(&layer, &outcome, &acc).unwrap();
        // Simulate cost-model drift: rewrite the record with different
        // score bits and a *valid* checksum.
        let log = dir.join(LOG_FILE);
        let text = fs::read_to_string(&log).unwrap();
        let old = format!("\"score_bits\": \"{:016x}\"", outcome.score.to_bits());
        let new = format!("\"score_bits\": \"{:016x}\"", outcome.score.to_bits() ^ 1);
        let payload = text.trim_end().splitn(3, ' ').nth(2).unwrap().replace(&old, &new);
        fs::write(&log, format!("{RECORD_TAG} {:016x} {payload}\n", fnv1a(payload.as_bytes())))
            .unwrap();
        let report = cache.load(&acc);
        assert_eq!(report.entries.len(), 0, "a drifted score must not be trusted");
        assert_eq!(report.skipped, 1);
        assert_eq!(report.truncated_bytes, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lifetime_totals_accumulate_across_openings() {
        let dir = temp_dir("totals");
        let delta = LifetimeTotals { requests: 325, cache_hits: 133, fallbacks: 1 };
        {
            let cache = PersistentCache::open(&dir).unwrap();
            assert_eq!(cache.read_totals(), LifetimeTotals::default());
            cache.accumulate_totals(delta).unwrap();
        }
        {
            // A "restarted" process folds its own totals on top.
            let cache = PersistentCache::open(&dir).unwrap();
            assert_eq!(cache.read_totals(), delta);
            cache.accumulate_totals(delta).unwrap();
            assert_eq!(
                cache.read_totals(),
                LifetimeTotals { requests: 650, cache_hits: 266, fallbacks: 2 }
            );
        }
        // Corrupt sidecars read as zeros, never as garbage.
        fs::write(dir.join(TOTALS_FILE), "LMT1 0000000000000000 {}\n").unwrap();
        let cache = PersistentCache::open(&dir).unwrap();
        assert_eq!(cache.read_totals(), LifetimeTotals::default());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_drops_duplicates_and_stale_versions() {
        let dir = temp_dir("compact");
        let acc = presets::eyeriss();
        let cache = PersistentCache::open(&dir).unwrap();
        let outcomes = solved(&zoo::alexnet()[..2], &acc);
        for (layer, outcome) in &outcomes {
            cache.append(layer, outcome, &acc).unwrap();
        }
        // A duplicate of the first record and a checksummed line under a
        // superseded record version, both of which load() would ignore.
        let (layer, outcome) = outcomes[0].clone();
        cache.append(&layer, &outcome, &acc).unwrap();
        let stale = "{\"v\": 9}";
        let line = format!("LMC9 {:016x} {stale}\n", fnv1a(stale.as_bytes()));
        let mut f = OpenOptions::new().append(true).open(dir.join(LOG_FILE)).unwrap();
        f.write_all(line.as_bytes()).unwrap();
        // And a torn tail, which compaction drops like WAL recovery.
        f.write_all(b"LMC1 00ffee11 {\"v\": 1, \"arch").unwrap();
        drop(f);
        let report = cache.compact().unwrap();
        assert_eq!(report.before, 4);
        assert_eq!(report.after, 2);
        assert_eq!(report.dropped_duplicates, 1);
        assert_eq!(report.dropped_stale, 1);
        let loaded = cache.load(&acc);
        assert_eq!(loaded.entries.len(), 2, "survivors still replay");
        assert_eq!(loaded.skipped, 0);
        assert_eq!(loaded.truncated_bytes, 0, "compaction already cleaned the tail");
        assert_eq!(cache.stats().records, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn appends_after_compact_land_in_the_compacted_log() {
        let dir = temp_dir("compact-append");
        let acc = presets::eyeriss();
        let cache = PersistentCache::open(&dir).unwrap();
        let outcomes = solved(&zoo::alexnet()[..2], &acc);
        for (layer, outcome) in &outcomes {
            cache.append(layer, outcome, &acc).unwrap();
        }
        let (layer, outcome) = outcomes[0].clone();
        cache.append(&layer, &outcome, &acc).unwrap();
        assert_eq!(cache.compact().unwrap().after, 2);
        // The append handle was reopened on the new inode: this record
        // must be visible through the compacted log, not a ghost file.
        let (layer, outcome) = solved(&zoo::alexnet()[2..3], &acc).remove(0);
        cache.append(&layer, &outcome, &acc).unwrap();
        assert_eq!(cache.stats().records, 3);
        assert_eq!(cache.load(&acc).entries.len(), 3);
        // Idempotent: nothing left to drop.
        let again = cache.compact().unwrap();
        assert_eq!(again, CompactReport { before: 3, after: 3, ..CompactReport::default() });
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_summarize_without_replaying() {
        let dir = temp_dir("stats");
        let acc = presets::eyeriss();
        let cache = PersistentCache::open(&dir).unwrap();
        for (layer, outcome) in solved(&zoo::alexnet()[..2], &acc) {
            cache.append(&layer, &outcome, &acc).unwrap();
        }
        let stats = cache.stats();
        assert_eq!(stats.records, 2);
        assert!(stats.log_bytes > 0);
        let fps = cache.key_fingerprints(arch_fingerprint(&acc));
        assert_eq!(fps.len(), 2);
        assert!(cache.key_fingerprints(0xdead_beef).is_empty());
        let _ = fs::remove_dir_all(&dir);
    }
}
