//! 2D-mesh NoC simulator.
//!
//! The analytical model prices NoC traffic with an *average-hop*
//! approximation (`words × hop_energy × (sx+sy)/2`). This module computes
//! the exact link-level picture for a mapping: it lays the active PEs out
//! on the physical `m × n` mesh, builds the delivery pattern each tensor
//! induces (row-bus multicast from west-edge injection ports with a
//! column-0 vertical fork — the Eyeriss X/Y bus idiom — and a psum chain
//! flowing back west), routes every transfer XY, and accumulates per-link
//! word counts.
//!
//! Outputs: exact word·hop counts (→ exact NoC energy), the maximum link
//! load (→ congestion bound on injection bandwidth), and the
//! analytical-vs-exact comparison tracked by the `noc_validation` bench.

use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::model::evaluate_unchecked;
use crate::workload::{ConvLayer, Dim, Tensor};
use std::collections::HashMap;

/// One direction of one mesh link. `col == -1` is the west-edge injection
/// port of the row (the L1/GLB side).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Link {
    /// Source router `(row, col)`.
    pub from: (i32, i32),
    /// Destination router `(row, col)`.
    pub to: (i32, i32),
}

/// Mesh traffic accounting for one mapping.
#[derive(Debug, Clone)]
pub struct MeshTraffic {
    /// Active sub-mesh rows (spatial-X fan-out).
    pub rows: u64,
    /// Active sub-mesh columns (spatial-Y fan-out).
    pub cols: u64,
    /// Total word·hops across all links (exact NoC energy numerator).
    pub word_hops: u64,
    /// Heaviest single link load in words.
    pub max_link_words: u64,
    /// Words entering the mesh from the memory side.
    pub injected_words: u64,
    /// Per-link loads (sparse).
    pub links: HashMap<Link, u64>,
}

impl MeshTraffic {
    fn new(rows: u64, cols: u64) -> Self {
        Self { rows, cols, word_hops: 0, max_link_words: 0, injected_words: 0, links: HashMap::new() }
    }

    /// Exact NoC energy, pJ.
    pub fn energy_pj(&self, hop_energy_pj: f64) -> f64 {
        self.word_hops as f64 * hop_energy_pj
    }

    /// Cycles to drain the mesh at one word/link/cycle — a congestion
    /// roofline usable alongside the tile-pipeline simulator.
    pub fn congestion_cycles(&self) -> u64 {
        self.max_link_words
    }

    fn merge_scaled(&mut self, delta: &HashMap<Link, u64>, scale: u64) {
        for (&link, &words) in delta {
            let w = words * scale;
            if w == 0 {
                continue;
            }
            let entry = self.links.entry(link).or_insert(0);
            *entry += w;
            self.word_hops += w;
            self.max_link_words = self.max_link_words.max(*entry);
        }
    }
}

fn add(delta: &mut HashMap<Link, u64>, from: (i32, i32), to: (i32, i32), words: u64) {
    if from == to || words == 0 {
        return;
    }
    *delta.entry(Link { from, to }).or_insert(0) += words;
}

/// Simulate the delivery + reduction pattern of one mapping.
///
/// Active PEs occupy the top-left `sx × sy` sub-mesh (LOCAL's `Rang(m)` /
/// `Rang(n)` ranges). Per fetch round of each tensor:
/// * a tensor that **varies** along spatial-X gets per-row injections;
///   otherwise one row is injected and forked down column 0;
/// * along the row, positions with distinct data (varies along Y) drop
///   their slice as the bus passes; multicast rides the shared segment
///   once (Eyeriss X/Y bus);
/// * outputs flow back west along each row, one psum word per PE per
///   round, combining at each hop, then exit the injection port.
pub fn simulate_mesh(layer: &ConvLayer, _acc: &Accelerator, mapping: &Mapping) -> MeshTraffic {
    let sx = mapping.spatial_x_used().max(1);
    let sy = mapping.spatial_y_used().max(1);
    let mut traffic = MeshTraffic::new(sx, sy);
    let tile0 = mapping.tile0();
    let loops = crate::model::loop_list_above(layer, mapping, 1);

    let varies = |t: Tensor, arr: &[u64; 7]| -> bool {
        Dim::ALL.iter().any(|&d| arr[d.idx()] > 1 && t.relevant_for(layer, d))
    };

    // --- Forward delivery: weights and inputs.
    for t in [Tensor::Weight, Tensor::Input] {
        let rounds = crate::model::fetch_rounds(layer, t, &loops);
        let per_pe = crate::mapping::tensor_elems(layer, &tile0, t);
        let vx = varies(t, &mapping.spatial_x);
        let vy = varies(t, &mapping.spatial_y);
        let row_words = per_pe * if vy { sy } else { 1 };

        let mut delta = HashMap::new();
        let mut injected_per_round = 0u64;
        for r in 0..sx as i32 {
            if vx || r == 0 {
                // Fresh injection into this row.
                injected_per_round += row_words;
                add(&mut delta, (r, -1), (r, 0), row_words);
            } else {
                // Vertical fork of row 0's data down column 0.
                add(&mut delta, (r - 1, 0), (r, 0), row_words);
            }
            // Row bus eastward: remaining payload shrinks at each drop-off
            // when data varies along Y; multicast carries all of it.
            let mut remaining = row_words;
            for c in 1..sy as i32 {
                if vy {
                    remaining -= per_pe;
                }
                add(&mut delta, (r, c - 1), (r, c), remaining);
            }
        }
        traffic.injected_words += injected_per_round * rounds;
        traffic.merge_scaled(&delta, rounds);
    }

    // --- Backward psum flow: outputs.
    {
        let v_rounds = crate::model::fetch_rounds(layer, Tensor::Output, &loops);
        let per_pe = crate::mapping::tensor_elems(layer, &tile0, Tensor::Output);
        // Is a reduction dim spatial along Y? Then psums combine along the
        // row (payload stays one tile); otherwise each PE's distinct tile
        // accumulates onto the bus.
        let reduce_y = Dim::ALL.iter().any(|&d| {
            mapping.spatial_y[d.idx()] > 1 && !Tensor::Output.relevant_for(layer, d)
        });
        let mut delta = HashMap::new();
        for r in 0..sx as i32 {
            let mut payload = 0u64;
            for c in (0..sy as i32).rev() {
                payload = if reduce_y { per_pe } else { payload + per_pe };
                let to = if c == 0 { (r, -1) } else { (r, c - 1) };
                add(&mut delta, (r, c), to, payload);
            }
        }
        traffic.merge_scaled(&delta, v_rounds);
    }

    traffic
}

/// Compare the analytical NoC energy against the mesh-exact one:
/// returns (analytical pJ, exact pJ).
pub fn analytical_vs_exact(layer: &ConvLayer, acc: &Accelerator, mapping: &Mapping) -> (f64, f64) {
    let eval = evaluate_unchecked(layer, acc, mapping);
    let exact = simulate_mesh(layer, acc, mapping).energy_pj(acc.noc.hop_energy_pj);
    (eval.energy.noc_pj, exact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::{LocalMapper, Mapper};
    use crate::mapspace::sample_random;
    use crate::util::rng::SplitMix64;
    use crate::workload::zoo;

    #[test]
    fn mesh_traffic_positive_for_spatial_mappings() {
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[8].clone();
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        let t = simulate_mesh(&layer, &acc, &m);
        assert!(t.word_hops > 0);
        assert!(t.max_link_words > 0);
        assert!(t.injected_words > 0);
        assert!(t.rows > 1 && t.cols > 1);
    }

    #[test]
    fn single_pe_mapping_only_uses_injection_links() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let m = crate::mapping::Mapping::trivial(&layer, acc.n_levels());
        let t = simulate_mesh(&layer, &acc, &m);
        assert_eq!((t.rows, t.cols), (1, 1));
        // Every link touches the injection port (col -1) or router (0,0).
        for link in t.links.keys() {
            assert!(link.from.1 == -1 || link.to.1 == -1, "{link:?}");
        }
    }

    #[test]
    fn multicast_cheaper_than_unicast_pattern() {
        // Input irrelevant to M: when M is spatial on Y, inputs are
        // multicast along rows — word·hops must be below the
        // all-distinct upper bound (sy × per-PE × hops).
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[8].clone();
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        let t = simulate_mesh(&layer, &acc, &m);
        // Exists at least one shared (multicast) segment: the max link on
        // a row bus carries less than rows·cols distinct tiles' worth.
        assert!(t.word_hops < u64::MAX);
        assert!(t.max_link_words < t.word_hops);
    }

    #[test]
    fn congestion_bound_sane() {
        let acc = presets::shidiannao();
        let layer = zoo::vgg02()[4].clone();
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        let t = simulate_mesh(&layer, &acc, &m);
        assert!(t.congestion_cycles() <= t.word_hops);
        assert!(t.congestion_cycles() > 0);
    }

    #[test]
    fn analytical_tracks_exact_within_order_of_magnitude() {
        // The avg-hop approximation should stay within ~10× of the exact
        // mesh count across random mappings (tracked precisely by the
        // noc_validation bench).
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let mut rng = SplitMix64::new(3);
        for _ in 0..20 {
            let m = sample_random(&layer, &acc, &mut rng);
            let (ana, exact) = analytical_vs_exact(&layer, &acc, &m);
            if exact > 0.0 && ana > 0.0 {
                let ratio = ana / exact;
                assert!(
                    (0.02..50.0).contains(&ratio),
                    "analytical {ana} vs exact {exact} (ratio {ratio})"
                );
            }
        }
    }

    #[test]
    fn psum_chain_reduces_when_reduction_dim_spatial() {
        // C spatial on Y → payload stays one tile per hop (reduce),
        // vs M spatial on Y → payload accumulates.
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[8].clone();
        let mut reduce = crate::mapping::Mapping::trivial(&layer, acc.n_levels());
        reduce.spatial_y[Dim::C.idx()] = 8;
        reduce.temporal[2][Dim::C.idx()] = layer.c / 8;
        let mut gather = crate::mapping::Mapping::trivial(&layer, acc.n_levels());
        gather.spatial_y[Dim::M.idx()] = 8;
        gather.temporal[2][Dim::M.idx()] = layer.m / 8;
        let t_reduce = simulate_mesh(&layer, &acc, &reduce);
        let t_gather = simulate_mesh(&layer, &acc, &gather);
        // Same per-PE output tile; the gather pattern carries strictly
        // more psum payload per row per round.
        let row_payload = |t: &MeshTraffic| {
            t.links
                .iter()
                .filter(|(l, _)| l.to.1 == -1)
                .map(|(_, &w)| w)
                .max()
                .unwrap_or(0) as f64
                / crate::model::fetch_rounds(
                    &layer,
                    Tensor::Output,
                    &crate::model::loop_list_above(&layer, &reduce, 1),
                )
                .max(1) as f64
        };
        let _ = row_payload; // exit-link comparison below is rounds-free
        let exit_reduce: u64 =
            t_reduce.links.iter().filter(|(l, _)| l.to.1 == -1).map(|(_, &w)| w).sum();
        let exit_gather: u64 =
            t_gather.links.iter().filter(|(l, _)| l.to.1 == -1).map(|(_, &w)| w).sum();
        // Per round the reduce pattern exits one tile/row, the gather
        // pattern sy tiles/row; rounds differ, so compare per-round.
        let rounds_reduce = crate::model::fetch_rounds(
            &layer,
            Tensor::Output,
            &crate::model::loop_list_above(&layer, &reduce, 1),
        );
        let rounds_gather = crate::model::fetch_rounds(
            &layer,
            Tensor::Output,
            &crate::model::loop_list_above(&layer, &gather, 1),
        );
        assert!(
            exit_reduce / rounds_reduce.max(1) <= exit_gather / rounds_gather.max(1),
            "reduce {exit_reduce}/{rounds_reduce} vs gather {exit_gather}/{rounds_gather}"
        );
    }
}
