//! Map-space definition, sampling and size accounting (paper §3).
//!
//! A point in the map-space chooses, for every problem dimension, an
//! ordered factorization across the temporal levels plus the two spatial
//! slots, together with a loop permutation per level. The §3 motivation
//! sizes — `(n!)^m ≈ O(10^8)` for six swappable loops over three storage
//! levels, and the `O(10^17)` full co-design space — are reproduced by
//! [`permutation_space`] and [`design_space`] (exercised by the
//! `motivation_mapspace` bench).

pub mod constraints;

pub use constraints::{Constraints, Dataflow};

use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::util::factor::count_factorizations;
use crate::util::rng::SplitMix64;
use crate::workload::{Dim, Layer};

/// `(n!)^m` — the §3 permutation-space size for `n` swappable loop-nests
/// over `m` storage levels.
pub fn permutation_space(n_loops: u64, m_levels: u32) -> f64 {
    let fact: f64 = (1..=n_loops).map(|i| i as f64).product();
    fact.powi(m_levels as i32)
}

/// Factorization-space size: ordered splits of every dim across
/// `slots` positions (temporal levels + spatial slots).
pub fn factorization_space(layer: &Layer, slots: usize) -> f64 {
    Dim::ALL
        .iter()
        .map(|&d| count_factorizations(layer.bound(d), slots) as f64)
        .product()
}

/// Total mapping-space size for a layer on an accelerator:
/// factorizations × per-level permutations (the paper counts the six
/// non-degenerate loops of a conv layer; we count exactly the
/// non-degenerate dims of this layer).
pub fn map_space(layer: &Layer, acc: &Accelerator) -> f64 {
    let n_loops = Dim::ALL.iter().filter(|&&d| layer.bound(d) > 1).count() as u64;
    let slots = acc.n_levels() + 2; // temporal levels + spatial X/Y
    factorization_space(layer, slots) * permutation_space(n_loops, acc.n_levels() as u32)
}

/// The §3 co-design space: PE-count choices × mapping permutations for the
/// paper's VGG16 layer-2 example (`64² × 224² × 3² × (6!)³ ≈ O(10^17)`).
pub fn design_space(k: u64, c: u64, y: u64, x: u64, r: u64, s: u64, m_levels: u32) -> f64 {
    (k * c) as f64 * (y * x) as f64 * (r * s) as f64 * permutation_space(6, m_levels)
}

/// Draw one uniformly-ish random **valid** mapping (the Fig. 3 generator).
///
/// Strategy: per dim, draw a random ordered factorization across
/// `levels + 2` slots (spatial X, spatial Y, then temporal innermost →
/// outermost); draw a random permutation per level; then repair capacity
/// violations by migrating factors outward (toward DRAM), which always
/// terminates because the DRAM level is unbounded. Spatial overflows are
/// repaired by folding the excess back into the outermost temporal level.
pub fn sample_random(layer: &Layer, acc: &Accelerator, rng: &mut SplitMix64) -> Mapping {
    let n_levels = acc.n_levels();
    let mut m = Mapping {
        temporal: vec![[1u64; 7]; n_levels],
        permutation: vec![Dim::ALL; n_levels],
        spatial_x: [1; 7],
        spatial_y: [1; 7],
    };

    for d in Dim::ALL {
        let mut rest = layer.bound(d);
        // Spatial slots first.
        for spatial in [true, false] {
            let cap = if spatial { acc.pe.m } else { acc.pe.n };
            let f = crate::util::factor::with_divisors(rest, |divs| {
                // Divisors are ascending: those ≤ cap form a prefix.
                let n_ok = divs.partition_point(|&x| x <= cap);
                divs[rng.index(n_ok.max(1))]
            });
            if spatial {
                m.spatial_x[d.idx()] = f;
            } else {
                m.spatial_y[d.idx()] = f;
            }
            rest /= f;
        }
        // Temporal slots, innermost first; the last level takes the rest.
        for l in 0..n_levels - 1 {
            let f = crate::util::factor::with_divisors(rest, |divs| *rng.choose(divs));
            m.temporal[l][d.idx()] = f;
            rest /= f;
        }
        m.temporal[n_levels - 1][d.idx()] = rest;
    }

    // Random permutation per level.
    for l in 0..n_levels {
        rng.shuffle(&mut m.permutation[l]);
    }

    repair(layer, acc, &mut m);
    debug_assert!(m.validate(layer, acc).is_ok(), "repair failed: {m}");
    m
}

/// Repair a candidate in place: clamp spatial fan-out to the PE array and
/// migrate tile factors outward until every bounded level fits.
pub fn repair(layer: &Layer, acc: &Accelerator, m: &mut Mapping) {
    let n_levels = acc.n_levels();
    let top = n_levels - 1;

    // Spatial clamping: pull factors out of the spatial slots (largest dim
    // first) into the outermost temporal level until the fan-out fits.
    for (slot, cap) in [(0usize, acc.pe.m), (1usize, acc.pe.n)] {
        loop {
            let arr = if slot == 0 { &m.spatial_x } else { &m.spatial_y };
            let used: u64 = arr.iter().product();
            if used <= cap {
                break;
            }
            // Move the smallest prime factor of the largest spatial entry.
            let d = (0..7).max_by_key(|&i| arr[i]).unwrap();
            let f = smallest_prime_factor(arr[d]);
            if slot == 0 {
                m.spatial_x[d] /= f;
            } else {
                m.spatial_y[d] /= f;
            }
            m.temporal[top][d] *= f;
        }
    }

    // Capacity repair, innermost outward. Level 0 bounds the per-PE tile;
    // levels 1..top bound the cumulative tile.
    for l in 0..top {
        loop {
            let footprint = if l == 0 {
                crate::mapping::tensor_footprint(layer, &m.tile0())
            } else {
                m.footprint(layer, l)
            };
            if footprint <= acc.level_capacity(l) {
                break;
            }
            // Shrink the largest temporal factor at this level.
            let d = (0..7).max_by_key(|&i| m.temporal[l][i]).unwrap();
            if m.temporal[l][d] == 1 {
                // Nothing left to shrink at this level (footprint is
                // irreducible); the validate() debug assert will flag the
                // impossible hierarchy.
                break;
            }
            let f = smallest_prime_factor(m.temporal[l][d]);
            m.temporal[l][d] /= f;
            m.temporal[l + 1][d] *= f;
        }
    }
}

/// Branch-and-bound lattice assignment order: problem dims in descending
/// odometer significance (`Q` is the outermost digit, `N` the least
/// significant). Fixing the first `k` lattice dims therefore pins one
/// **contiguous** range of odometer block indices — the invariant that
/// lets [`crate::mappers::engine::BoundedLattice`] prune a whole subtree
/// as a single index span (and count its skipped candidates exactly)
/// while enumerating candidates in the very same global order as
/// [`crate::mappers::engine::OdometerSource`], so tie-breaks on equal
/// scores resolve identically.
pub fn lattice_order() -> [Dim; 7] {
    let mut order = Dim::ALL;
    order.reverse();
    order
}

/// Number of odometer blocks that share one fixed assignment of the first
/// `depth` dims of [`lattice_order`]: the product of the remaining dims'
/// ordered-split counts across `n_levels + 2` slots (`depth == 0` is the
/// whole factorization space, `depth == 7` a single tiling). Saturates at
/// `u64::MAX` like the sources' block accounting.
pub fn lattice_subtree_blocks(layer: &Layer, acc: &Accelerator, depth: usize) -> u64 {
    let slots = acc.n_levels() + 2;
    lattice_order()[depth.min(7)..]
        .iter()
        .fold(1u64, |n, &d| n.saturating_mul(count_factorizations(layer.bound(d), slots)))
}

fn smallest_prime_factor(n: u64) -> u64 {
    debug_assert!(n > 1);
    let mut i = 2;
    while i * i <= n {
        if n % i == 0 {
            return i;
        }
        i += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::zoo;

    #[test]
    fn paper_motivation_sizes() {
        // (6!)³ = 373 248 000 ≈ O(10^8).
        let p = permutation_space(6, 3);
        assert_eq!(p, 373_248_000.0);
        assert!(p >= 1e8 && p < 1e9);
        // 64²·224²·3²·(6!)³ ≈ O(10^17).
        let d = design_space(64, 64, 224, 224, 3, 3, 3);
        assert!(d > 1e17 && d < 1e18, "{d}");
    }

    #[test]
    fn map_space_is_huge_for_real_layers() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        assert!(map_space(&layer, &acc) > 1e12);
    }

    #[test]
    fn random_samples_are_valid() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let mut rng = SplitMix64::new(1);
        for _ in 0..200 {
            let m = sample_random(&layer, &acc, &mut rng);
            m.validate(&layer, &acc).unwrap();
        }
    }

    #[test]
    fn random_samples_are_valid_on_all_presets() {
        let mut rng = SplitMix64::new(7);
        for acc in presets::all() {
            for layer in zoo::table2_workloads() {
                for _ in 0..20 {
                    let m = sample_random(&layer.layer, &acc, &mut rng);
                    m.validate(&layer.layer, &acc)
                        .unwrap_or_else(|e| panic!("{} on {}: {e}", layer.layer.name, acc.name));
                }
            }
        }
    }

    #[test]
    fn random_samples_differ() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let mut rng = SplitMix64::new(3);
        let a = sample_random(&layer, &acc, &mut rng);
        let b = sample_random(&layer, &acc, &mut rng);
        assert_ne!(a, b);
    }

    #[test]
    fn lattice_order_is_descending_significance() {
        let o = lattice_order();
        assert_eq!(o[0], Dim::Q);
        assert_eq!(o[6], Dim::N);
        for (k, d) in o.iter().enumerate() {
            assert_eq!(d.idx(), 6 - k);
        }
    }

    #[test]
    fn lattice_subtree_blocks_telescopes() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let slots = acc.n_levels() + 2;
        // depth 0 is the full factorization space; each extra fixed dim
        // divides out exactly that dim's split count.
        assert_eq!(
            lattice_subtree_blocks(&layer, &acc, 0) as f64,
            factorization_space(&layer, slots)
        );
        for depth in 0..7 {
            let d = lattice_order()[depth];
            assert_eq!(
                lattice_subtree_blocks(&layer, &acc, depth),
                lattice_subtree_blocks(&layer, &acc, depth + 1)
                    * count_factorizations(layer.bound(d), slots)
            );
        }
        assert_eq!(lattice_subtree_blocks(&layer, &acc, 7), 1);
    }

    #[test]
    fn repair_is_idempotent_on_valid() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let mut rng = SplitMix64::new(9);
        let m = sample_random(&layer, &acc, &mut rng);
        let mut m2 = m.clone();
        repair(&layer, &acc, &mut m2);
        assert_eq!(m, m2);
    }
}
