//! Dataflow constraint sets — how the paper's baselines are expressed.
//!
//! The paper extracts its row/weight/output-stationary baselines from
//! Timeloop "by defining data-reuse constraints" (§6.1): the stationarity
//! of a dataflow becomes a restriction of the map-space, and a search runs
//! inside the restricted space. [`Dataflow`] encodes the three baselines'
//! constraints; [`Constraints::admit`] filters candidates and
//! [`Constraints::imprint`] steers the sampler so constrained search does
//! not reject-sample forever.

use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::util::factor::factor_splits;
use crate::util::rng::SplitMix64;
use crate::workload::{ConvLayer, Dim};

/// The three stationary dataflows the paper compares against (§6.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Eyeriss row stationary [2]: one filter row stays in each PE; filter
    /// rows spread over PE rows, output rows over PE columns.
    RowStationary,
    /// NVDLA weight stationary [4]: the filter tile stays in the PE; input
    /// channels spread over PE rows, output channels over columns; P/Q
    /// iterate innermost above the RF so weights never move.
    WeightStationary,
    /// ShiDianNao output stationary [15]: each PE owns output pixels;
    /// Q over PE rows, P over columns; reduction (C,R,S) iterates
    /// innermost above the RF so psums never move.
    OutputStationary,
}

impl Dataflow {
    /// Canonical short name ("RS"/"WS"/"OS").
    pub fn name(self) -> &'static str {
        match self {
            Dataflow::RowStationary => "RS",
            Dataflow::WeightStationary => "WS",
            Dataflow::OutputStationary => "OS",
        }
    }

    /// Parse a (case-insensitive) dataflow name.
    pub fn parse(s: &str) -> Option<Dataflow> {
        match s.to_ascii_uppercase().as_str() {
            "RS" | "ROW" | "ROW-STATIONARY" => Some(Dataflow::RowStationary),
            "WS" | "WEIGHT" | "WEIGHT-STATIONARY" => Some(Dataflow::WeightStationary),
            "OS" | "OUTPUT" | "OUTPUT-STATIONARY" => Some(Dataflow::OutputStationary),
            _ => None,
        }
    }

    /// The dataflow each accelerator natively runs in the paper's Table 3.
    pub fn native_for(style: crate::arch::Style) -> Dataflow {
        match style {
            crate::arch::Style::EyerissLike => Dataflow::RowStationary,
            crate::arch::Style::NvdlaLike => Dataflow::WeightStationary,
            crate::arch::Style::ShiDianNaoLike => Dataflow::OutputStationary,
        }
    }

    /// Constraint set for this dataflow.
    pub fn constraints(self) -> Constraints {
        match self {
            Dataflow::RowStationary => Constraints {
                name: "RS",
                spatial_x: Some(Dim::R),
                spatial_y: Some(Dim::P),
                stationary_dims_l0: vec![Dim::S],
                inner_above_rf: vec![Dim::S, Dim::Q],
            },
            Dataflow::WeightStationary => Constraints {
                name: "WS",
                spatial_x: Some(Dim::C),
                spatial_y: Some(Dim::M),
                stationary_dims_l0: vec![Dim::R, Dim::S],
                inner_above_rf: vec![Dim::P, Dim::Q],
            },
            Dataflow::OutputStationary => Constraints {
                name: "OS",
                spatial_x: Some(Dim::Q),
                spatial_y: Some(Dim::P),
                stationary_dims_l0: vec![],
                inner_above_rf: vec![Dim::C, Dim::R, Dim::S],
            },
        }
    }
}

/// A restriction of the map-space expressing one dataflow's stationarity.
#[derive(Debug, Clone)]
pub struct Constraints {
    /// Constraint-set name (matches the dataflow short name).
    pub name: &'static str,
    /// Dim that must occupy the spatial-X slot (as much of it as fits).
    pub spatial_x: Option<Dim>,
    /// Dim that must occupy the spatial-Y slot.
    pub spatial_y: Option<Dim>,
    /// Dims whose full (residual) extent must sit in the per-PE L0 tile —
    /// the "stationary" tensor's footprint.
    pub stationary_dims_l0: Vec<Dim>,
    /// Dims that must be the innermost non-degenerate temporal loops at
    /// level 1 (just above the RF), in the given inner→outer order — this
    /// is what keeps the stationary tensor resident.
    pub inner_above_rf: Vec<Dim>,
}

impl Constraints {
    /// Does a mapping satisfy this constraint set?
    pub fn admit(&self, layer: &ConvLayer, acc: &Accelerator, m: &Mapping) -> bool {
        // Spatial slots: the designated dim must own the slot exclusively
        // (other dims' factors there must be 1) and be maximal for the
        // array dimension (largest divisor of the dim bound that fits).
        for (want, arr, cap) in [
            (self.spatial_x, &m.spatial_x, acc.pe.m),
            (self.spatial_y, &m.spatial_y, acc.pe.n),
        ] {
            if let Some(d) = want {
                let (expect, _) = factor_splits(layer.bound(d), cap);
                if arr[d.idx()] != expect {
                    return false;
                }
                if (0..7).any(|i| i != d.idx() && arr[i] != 1) {
                    return false;
                }
            }
        }
        // Innermost order at level 1: the first non-degenerate loops must
        // be exactly `inner_above_rf` (those with extent > 1), in order.
        let non_degenerate: Vec<Dim> = m
            .loops(1)
            .filter(|&(_, f)| f > 1)
            .map(|(d, _)| d)
            .collect();
        let expected: Vec<Dim> = self
            .inner_above_rf
            .iter()
            .copied()
            .filter(|&d| m.temporal[1][d.idx()] > 1)
            .collect();
        if non_degenerate.len() < expected.len() {
            return false;
        }
        non_degenerate[..expected.len()] == expected[..]
    }

    /// Force a candidate into the constrained subspace (the sampler calls
    /// this after [`crate::mapspace::sample_random`]): claims the spatial
    /// slots, pins stationary dims at L0, orders the level-1 permutation,
    /// then re-repairs capacities.
    pub fn imprint(&self, layer: &ConvLayer, acc: &Accelerator, m: &mut Mapping, rng: &mut SplitMix64) {
        let top = m.n_levels() - 1;
        // Clear spatial slots and re-assign the constrained dims.
        for i in 0..7 {
            m.temporal[top][i] *= m.spatial_x[i] * m.spatial_y[i];
            m.spatial_x[i] = 1;
            m.spatial_y[i] = 1;
        }
        for (want, cap, is_x) in [(self.spatial_x, acc.pe.m, true), (self.spatial_y, acc.pe.n, false)] {
            if let Some(d) = want {
                let i = d.idx();
                // Gather d's full residual from the temporal slots, then
                // split it spatially as large as fits.
                let total: u64 =
                    m.temporal.iter().map(|f| f[i]).product::<u64>();
                let (sp, rest) = factor_splits(layer.bound(d).min(total), cap);
                // Reset d's temporal split: everything to DRAM, then spatial.
                for f in m.temporal.iter_mut() {
                    f[i] = 1;
                }
                m.temporal[top][i] = rest;
                if is_x {
                    m.spatial_x[i] = sp;
                } else {
                    m.spatial_y[i] = sp;
                }
            }
        }
        // Stationary dims: as much of the residual into L0 as the RF can
        // hold (best-effort — a 16-element keep-everything RF cannot always
        // hold a full 3×3 filter plus operands).
        for &d in &self.stationary_dims_l0 {
            let i = d.idx();
            let spatial = m.spatial_x[i] * m.spatial_y[i];
            for f in m.temporal.iter_mut() {
                f[i] = 1;
            }
            let residual = layer.bound(d) / spatial;
            m.temporal[top][i] = residual;
            for f in crate::util::factor::divisors(residual).into_iter().rev() {
                m.temporal[0][i] = f;
                m.temporal[top][i] = residual / f;
                if crate::mapping::tensor_footprint(layer, &m.tile0()) <= acc.level_capacity(0) {
                    break;
                }
            }
        }
        // Level-1 permutation: constrained dims innermost (in order), the
        // rest shuffled behind them.
        let mut rest: Vec<Dim> = Dim::ALL
            .iter()
            .copied()
            .filter(|d| !self.inner_above_rf.contains(d))
            .collect();
        rng.shuffle(&mut rest);
        let mut perm = self.inner_above_rf.clone();
        perm.extend(rest);
        for (i, d) in perm.into_iter().enumerate() {
            m.permutation[1][i] = d;
        }
        crate::mapspace::repair(layer, acc, m);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mapspace::sample_random;
    use crate::workload::zoo;

    #[test]
    fn dataflow_parse_and_names() {
        assert_eq!(Dataflow::parse("ws"), Some(Dataflow::WeightStationary));
        assert_eq!(Dataflow::parse("row"), Some(Dataflow::RowStationary));
        assert_eq!(Dataflow::parse("OS"), Some(Dataflow::OutputStationary));
        assert_eq!(Dataflow::parse("xx"), None);
        assert_eq!(Dataflow::RowStationary.name(), "RS");
    }

    #[test]
    fn native_dataflows() {
        use crate::arch::Style;
        assert_eq!(Dataflow::native_for(Style::EyerissLike), Dataflow::RowStationary);
        assert_eq!(Dataflow::native_for(Style::NvdlaLike), Dataflow::WeightStationary);
        assert_eq!(Dataflow::native_for(Style::ShiDianNaoLike), Dataflow::OutputStationary);
    }

    #[test]
    fn imprint_then_admit_all_dataflows() {
        let mut rng = SplitMix64::new(11);
        for df in [Dataflow::RowStationary, Dataflow::WeightStationary, Dataflow::OutputStationary] {
            let cons = df.constraints();
            for acc in presets::all() {
                let layer = zoo::vgg16()[8].clone();
                for _ in 0..10 {
                    let mut m = sample_random(&layer, &acc, &mut rng);
                    cons.imprint(&layer, &acc, &mut m, &mut rng);
                    m.validate(&layer, &acc)
                        .unwrap_or_else(|e| panic!("{} on {}: {e}\n{m}", cons.name, acc.name));
                    assert!(
                        cons.admit(&layer, &acc, &m),
                        "{} imprint not admitted on {}:\n{m}",
                        cons.name,
                        acc.name
                    );
                }
            }
        }
    }

    #[test]
    fn unconstrained_random_rarely_admitted() {
        // Sanity: the constraint actually constrains.
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let cons = Dataflow::WeightStationary.constraints();
        let mut rng = SplitMix64::new(5);
        let admitted = (0..100)
            .filter(|_| {
                let m = sample_random(&layer, &acc, &mut rng);
                cons.admit(&layer, &acc, &m)
            })
            .count();
        assert!(admitted < 10, "{admitted} of 100 random maps admitted");
    }

    #[test]
    fn ws_keeps_weights_stationary() {
        // After WS imprint, as much of R/S as fits sits in L0 and P/Q are
        // innermost at level 1 → the weight tile survives P/Q iteration.
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[8].clone();
        let mut rng = SplitMix64::new(13);
        let mut m = sample_random(&layer, &acc, &mut rng);
        Dataflow::WeightStationary.constraints().imprint(&layer, &acc, &mut m, &mut rng);
        // At least one filter dim pinned at L0 (capacity-limited).
        let pinned = m.temporal[0][Dim::R.idx()] * m.temporal[0][Dim::S.idx()];
        assert!(pinned >= 3, "filter not resident: {m}");
        // C spatial on X, M spatial on Y (maximal divisors ≤ 16).
        assert_eq!(m.spatial_x[Dim::C.idx()], 16);
        assert_eq!(m.spatial_y[Dim::M.idx()], 16);
        // P and Q are the innermost non-degenerate level-1 loops.
        let inner: Vec<Dim> = m.loops(1).filter(|&(_, f)| f > 1).map(|(d, _)| d).collect();
        if !inner.is_empty() {
            assert!(inner[0] == Dim::P || inner[0] == Dim::Q);
        }
    }
}
