//! A minimal YAML-subset parser for accelerator/workload config files.
//!
//! serde/serde_yaml are not in the offline crate set; this covers the subset
//! Timeloop-style configs need: nested maps by 2-space indentation, block
//! lists (`- item` / `- key: value`), inline lists (`[a, b]`), scalar
//! strings/numbers/bools, `#` comments and blank lines.
//!
//! It is deliberately strict: tabs are rejected, duplicate keys are errors,
//! and indentation must be consistent — config typos should fail loudly at
//! compile time (of the network), not silently mis-map a layer.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed YAML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Scalar (numbers/bools stay strings until a typed accessor runs).
    Str(String),
    /// Block or inline list.
    List(Vec<Value>),
    /// Nested map.
    Map(BTreeMap<String, Value>),
}

/// Parse error with 1-based line information.
#[derive(Debug, Clone, PartialEq)]
pub struct YamlError {
    /// 1-based source line of the error.
    pub line: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for YamlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "yaml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for YamlError {}

impl Value {
    /// Scalar as a string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Scalar parsed as u64 (underscore separators allowed).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_str().and_then(|s| s.replace('_', "").parse().ok())
    }

    /// Scalar parsed as f64.
    pub fn as_f64(&self) -> Option<f64> {
        self.as_str().and_then(|s| s.parse().ok())
    }

    /// Scalar parsed as a bool (`true`/`yes`/`false`/`no`).
    pub fn as_bool(&self) -> Option<bool> {
        match self.as_str()? {
            "true" | "yes" => Some(true),
            "false" | "no" => Some(false),
            _ => None,
        }
    }

    /// List contents.
    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(v) => Some(v),
            _ => None,
        }
    }

    /// Map contents.
    pub fn as_map(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Map(m) => Some(m),
            _ => None,
        }
    }

    /// Map lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_map()?.get(key)
    }
}

struct Line {
    no: usize,
    indent: usize,
    text: String,
}

/// Parse a YAML-subset document into a [`Value`].
pub fn parse(src: &str) -> Result<Value, YamlError> {
    let mut lines = Vec::new();
    for (i, raw) in src.lines().enumerate() {
        let no = i + 1;
        if raw.contains('\t') {
            return Err(YamlError { line: no, msg: "tabs are not allowed".into() });
        }
        // Strip comments (naive: we never quote '#' in our configs).
        let stripped = match raw.find('#') {
            Some(p) => &raw[..p],
            None => raw,
        };
        if stripped.trim().is_empty() {
            continue;
        }
        let indent = stripped.len() - stripped.trim_start().len();
        lines.push(Line { no, indent, text: stripped.trim().to_string() });
    }
    if lines.is_empty() {
        return Ok(Value::Map(BTreeMap::new()));
    }
    let (v, consumed) = parse_block(&lines, 0, lines[0].indent)?;
    if consumed != lines.len() {
        return Err(YamlError {
            line: lines[consumed].no,
            msg: format!("unexpected dedent/content (indent {})", lines[consumed].indent),
        });
    }
    Ok(v)
}

/// Parse a block starting at `pos` whose items share `indent`.
fn parse_block(lines: &[Line], pos: usize, indent: usize) -> Result<(Value, usize), YamlError> {
    if lines[pos].text.starts_with("- ") || lines[pos].text == "-" {
        parse_list(lines, pos, indent)
    } else {
        parse_map(lines, pos, indent)
    }
}

fn parse_map(lines: &[Line], mut pos: usize, indent: usize) -> Result<(Value, usize), YamlError> {
    let mut map = BTreeMap::new();
    while pos < lines.len() && lines[pos].indent == indent && !lines[pos].text.starts_with("- ") {
        let line = &lines[pos];
        let (key, rest) = line.text.split_once(':').ok_or(YamlError {
            line: line.no,
            msg: format!("expected 'key: value', got '{}'", line.text),
        })?;
        let key = key.trim().to_string();
        if map.contains_key(&key) {
            return Err(YamlError { line: line.no, msg: format!("duplicate key '{key}'") });
        }
        let rest = rest.trim();
        if rest.is_empty() {
            // Nested block follows at deeper indent.
            pos += 1;
            if pos < lines.len() && lines[pos].indent > indent {
                let (v, next) = parse_block(lines, pos, lines[pos].indent)?;
                map.insert(key, v);
                pos = next;
            } else {
                map.insert(key, Value::Str(String::new()));
            }
        } else {
            map.insert(key, parse_scalar(rest));
            pos += 1;
        }
        if pos < lines.len() && lines[pos].indent > indent {
            return Err(YamlError {
                line: lines[pos].no,
                msg: "unexpected indent (value already given on parent line?)".into(),
            });
        }
    }
    Ok((Value::Map(map), pos))
}

fn parse_list(lines: &[Line], mut pos: usize, indent: usize) -> Result<(Value, usize), YamlError> {
    let mut items = Vec::new();
    while pos < lines.len() && lines[pos].indent == indent && lines[pos].text.starts_with('-') {
        let line = &lines[pos];
        let body = line.text[1..].trim().to_string();
        if body.is_empty() {
            return Err(YamlError { line: line.no, msg: "empty list item".into() });
        }
        if body.contains(':') && !body.starts_with('[') {
            // `- key: value` opens an inline map item that may continue at
            // indent+2 on following lines.
            let item_indent = indent + 2;
            let synthetic = Line { no: line.no, indent: item_indent, text: body };
            // Collect following lines that belong to this item.
            let mut sub: Vec<&Line> = vec![&synthetic];
            let mut next = pos + 1;
            while next < lines.len() && lines[next].indent >= item_indent && !(lines[next].indent == indent) {
                sub.push(&lines[next]);
                next += 1;
            }
            let owned: Vec<Line> = sub
                .iter()
                .map(|l| Line { no: l.no, indent: l.indent, text: l.text.clone() })
                .collect();
            let (v, used) = parse_map(&owned, 0, item_indent)?;
            if used != owned.len() {
                return Err(YamlError { line: owned[used].no, msg: "bad indentation in list item".into() });
            }
            items.push(v);
            pos = next;
        } else {
            items.push(parse_scalar(&body));
            pos += 1;
        }
    }
    Ok((Value::List(items), pos))
}

/// Scalars: inline lists `[a, b, c]` or plain strings (numbers stay strings
/// until a typed accessor is called).
fn parse_scalar(s: &str) -> Value {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let items = inner
            .split(',')
            .map(|p| Value::Str(p.trim().to_string()))
            .filter(|v| v.as_str().map(|s| !s.is_empty()).unwrap_or(true))
            .collect();
        return Value::List(items);
    }
    Value::Str(s.trim_matches('"').to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_map() {
        let v = parse("a: 1\nb: hello\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("hello"));
    }

    #[test]
    fn nested_map_and_inline_list() {
        let src = "arch:\n  pe_array: [12, 14]\n  noc:\n    hop_energy_pj: 0.05\n";
        let v = parse(src).unwrap();
        let arch = v.get("arch").unwrap();
        let pe = arch.get("pe_array").unwrap().as_list().unwrap();
        assert_eq!(pe[0].as_u64(), Some(12));
        assert_eq!(pe[1].as_u64(), Some(14));
        assert_eq!(arch.get("noc").unwrap().get("hop_energy_pj").unwrap().as_f64(), Some(0.05));
    }

    #[test]
    fn block_list_of_maps() {
        let src = "levels:\n  - name: RF\n    depth: 16\n  - name: GLB\n    depth: 16384\n";
        let v = parse(src).unwrap();
        let levels = v.get("levels").unwrap().as_list().unwrap();
        assert_eq!(levels.len(), 2);
        assert_eq!(levels[0].get("name").unwrap().as_str(), Some("RF"));
        assert_eq!(levels[1].get("depth").unwrap().as_u64(), Some(16384));
    }

    #[test]
    fn comments_and_blanks() {
        let v = parse("# top\na: 1\n\n  # indented comment\nb: 2\n").unwrap();
        assert_eq!(v.get("b").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn bools_and_underscore_numbers() {
        let v = parse("x: true\ny: 16_384\n").unwrap();
        assert_eq!(v.get("x").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("y").unwrap().as_u64(), Some(16384));
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a: 1\na: 2\n").is_err());
    }

    #[test]
    fn tabs_rejected() {
        let e = parse("a:\n\tb: 1\n").unwrap_err();
        assert!(e.msg.contains("tab"));
    }

    #[test]
    fn empty_doc() {
        assert_eq!(parse("").unwrap(), Value::Map(BTreeMap::new()));
    }
}
