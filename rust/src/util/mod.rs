//! Shared utilities: deterministic PRNG, integer factorization, CLI arg
//! parsing, ASCII table rendering and micro-bench timing.
//!
//! The offline crate set has no `rand`, `clap` or `criterion`; these small
//! hand-rolled equivalents keep the rest of the crate dependency-free.

pub mod bench;
pub mod cli;
pub mod factor;
pub mod rng;
pub mod table;
pub mod yaml;

pub use bench::{median_time, Timed};
pub use factor::{divisors, factor_splits, factorizations};
pub use rng::SplitMix64;
pub use table::Table;
