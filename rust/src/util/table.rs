//! ASCII table rendering for the report module and bench harnesses.
//!
//! Emits both aligned ASCII (human output, mirrors the paper's tables) and
//! CSV (for plotting Fig. 3 / Fig. 7 series downstream).

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the arity does not match the header.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// True when the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Number of data rows (excluding the header).
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as aligned ASCII with a separator under the header.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(c);
                for _ in c.len()..width[i] {
                    s.push(' ');
                }
            }
            while s.ends_with(' ') {
                s.pop();
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.header, &width));
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }

    /// Render as CSV (no quoting needed for our numeric/identifier cells;
    /// commas in cells are replaced by `;`).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| c.replace(',', ";");
        out.push_str(&self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a f64 with engineering-style precision suitable for energy (pJ/µJ)
/// and time values: 3 significant-ish decimals, no scientific notation for
/// the common ranges.
pub fn fmt_f64(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else if x.abs() >= 0.01 {
        format!("{x:.3}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new(vec!["a", "bb"]);
        t.row(vec!["xxx", "y"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "a    bb");
        assert_eq!(lines[2], "xxx  y");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["x", "y"]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new(vec!["w", "e"]);
        t.row(vec!["conv1", "12.5"]);
        assert_eq!(t.to_csv(), "w,e\nconv1,12.5\n");
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_f64(0.0), "0");
        assert_eq!(fmt_f64(12345.6), "12346");
        assert_eq!(fmt_f64(42.42), "42.4");
        assert_eq!(fmt_f64(1.23456), "1.235");
        assert!(fmt_f64(0.0001).contains('e'));
    }
}
