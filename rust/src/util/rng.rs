//! SplitMix64 — a tiny, high-quality, deterministic PRNG.
//!
//! Used by the random mapper (Fig. 3 experiment), the genetic mapper and the
//! property-test drivers. Determinism matters: every experiment in
//! EXPERIMENTS.md is reproducible from its seed.

/// SplitMix64 PRNG (Steele, Lea & Flood 2014). Passes BigCrush when used as
/// a 64-bit generator; more than good enough for mapping sampling.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    /// Uses Lemire's multiply-shift rejection-free reduction (the tiny bias
    /// for bounds near 2^64 is irrelevant at mapping-space sizes).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "next_below(0)");
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize index in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn next_below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.next_below(13) < 13);
        }
    }

    #[test]
    fn next_below_covers_all_residues() {
        let mut r = SplitMix64::new(11);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.next_below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
