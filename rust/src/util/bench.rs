//! Micro-bench timing (criterion is not in the offline crate set).
//!
//! `cargo bench` targets use [`Timed`] for warmup + median-of-N timing and
//! print paper-style tables; mapping-time measurements in the Table-3 bench
//! use wall-clock [`std::time::Instant`] directly since the measured unit is
//! an entire search, not a micro-op.

use std::time::{Duration, Instant};

/// Result of a timed run: median, min, max over the measured iterations.
#[derive(Debug, Clone, Copy)]
pub struct Timed {
    /// Median of the measured iterations.
    pub median: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Slowest iteration.
    pub max: Duration,
    /// Number of measured iterations.
    pub iters: usize,
}

impl Timed {
    /// Median nanoseconds as f64 (for rate computations).
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

/// Run `f` for `warmup` unmeasured iterations then `iters` measured ones and
/// report median/min/max. `f` should return something observable to keep the
/// optimizer honest; the return value is black-boxed here.
pub fn median_time<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Timed {
    assert!(iters > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    Timed {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: samples[samples.len() - 1],
        iters,
    }
}

/// Optimizer barrier (std::hint::black_box is stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Human-format a duration: ns/µs/ms/s with 3 significant digits.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_time_reports_all_fields() {
        let t = median_time(2, 5, || (0..100u64).sum::<u64>());
        assert_eq!(t.iters, 5);
        assert!(t.min <= t.median && t.median <= t.max);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
    }
}
