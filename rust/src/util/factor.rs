//! Integer factorization helpers for map-space construction.
//!
//! A mapping splits each problem dimension `d` into per-level tile factors
//! whose product covers `d`. Enumerating those splits is the core of the
//! map-space (`mapspace` module); the arithmetic lives here.

/// All divisors of `n`, ascending. `divisors(12) == [1,2,3,4,6,12]`.
pub fn divisors(n: u64) -> Vec<u64> {
    assert!(n > 0, "divisors(0)");
    let mut lo = Vec::new();
    let mut hi = Vec::new();
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            lo.push(i);
            if i != n / i {
                hi.push(n / i);
            }
        }
        i += 1;
    }
    hi.reverse();
    lo.extend(hi);
    lo
}

/// All ordered splits of `n` into exactly `k` factors (each ≥ 1) whose
/// product is exactly `n`. `factorizations(4, 2) == [[1,4],[2,2],[4,1]]`.
///
/// The count grows as the number of ordered factorizations — fine for DNN
/// layer dims (≤ a few hundred) and small `k` (≤ 4 levels).
pub fn factorizations(n: u64, k: usize) -> Vec<Vec<u64>> {
    assert!(k >= 1);
    if k == 1 {
        return vec![vec![n]];
    }
    let mut out = Vec::new();
    for d in divisors(n) {
        for mut rest in factorizations(n / d, k - 1) {
            let mut v = Vec::with_capacity(k);
            v.push(d);
            v.append(&mut rest);
            out.push(v);
        }
    }
    out
}

/// Number of ordered splits of `n` into `k` factors without materializing
/// them (used for map-space size accounting, paper §3).
pub fn count_factorizations(n: u64, k: usize) -> u64 {
    if k == 1 {
        return 1;
    }
    divisors(n)
        .into_iter()
        .map(|d| count_factorizations(n / d, k - 1))
        .sum()
}

thread_local! {
    static DIVISOR_CACHE: std::cell::RefCell<std::collections::HashMap<u64, Vec<u64>>> =
        std::cell::RefCell::new(std::collections::HashMap::new());
}

/// Memoized [`divisors`]: runs `f` over the cached divisor list of `n`.
/// Layer dims repeat millions of times across search candidates, so the
/// samplers use this (perf pass iteration 2 — EXPERIMENTS.md §Perf).
pub fn with_divisors<R>(n: u64, f: impl FnOnce(&[u64]) -> R) -> R {
    DIVISOR_CACHE.with(|cache| {
        let mut cache = cache.borrow_mut();
        let d = cache.entry(n).or_insert_with(|| divisors(n));
        f(d)
    })
}

/// Greedy split of `n` into `(inner, outer)` with `inner` the largest
/// divisor of `n` that is ≤ `cap`, and `outer = n / inner`. Used by the
/// LOCAL assignment phase: give the lower level the biggest range that fits.
pub fn factor_splits(n: u64, cap: u64) -> (u64, u64) {
    assert!(n > 0);
    if cap == 0 {
        return (1, n);
    }
    let mut best = 1;
    for d in divisors(n) {
        if d <= cap && d > best {
            best = d;
        }
    }
    (best, n / best)
}

/// Ceiling division.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(13), vec![1, 13]);
        assert_eq!(divisors(36), vec![1, 2, 3, 4, 6, 9, 12, 18, 36]);
    }

    #[test]
    fn factorizations_product_invariant() {
        for n in [1u64, 2, 6, 12, 56, 128] {
            for k in 1..=3 {
                let fs = factorizations(n, k);
                assert!(!fs.is_empty());
                for f in &fs {
                    assert_eq!(f.len(), k);
                    assert_eq!(f.iter().product::<u64>(), n, "n={n} k={k} f={f:?}");
                }
                // No duplicates.
                let mut sorted = fs.clone();
                sorted.sort();
                sorted.dedup();
                assert_eq!(sorted.len(), fs.len());
            }
        }
    }

    #[test]
    fn factorizations_counts_match() {
        for n in [1u64, 4, 12, 56, 224] {
            for k in 1..=4 {
                assert_eq!(
                    count_factorizations(n, k),
                    factorizations(n, k).len() as u64,
                    "n={n} k={k}"
                );
            }
        }
    }

    #[test]
    fn factorizations_k2_example() {
        assert_eq!(factorizations(4, 2), vec![vec![1, 4], vec![2, 2], vec![4, 1]]);
    }

    #[test]
    fn factor_splits_greedy() {
        assert_eq!(factor_splits(56, 8), (8, 7));
        assert_eq!(factor_splits(56, 9), (8, 7)); // largest divisor ≤ 9 is 8
        assert_eq!(factor_splits(56, 56), (56, 1));
        assert_eq!(factor_splits(13, 4), (1, 13)); // prime, nothing fits
        assert_eq!(factor_splits(12, 0), (1, 12));
    }

    #[test]
    fn with_divisors_matches_direct() {
        for n in [1u64, 12, 56, 224, 512] {
            with_divisors(n, |d| assert_eq!(d, divisors(n).as_slice()));
            // Second call hits the cache and must agree.
            with_divisors(n, |d| assert_eq!(d, divisors(n).as_slice()));
        }
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 5), 1);
    }
}
