//! Minimal CLI argument parsing (clap is not in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args —
//! enough for the `local-mapper` subcommands and the example binaries.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand-style positionals plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments in order (first is the subcommand).
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `argv[0]` must already be
    /// stripped.
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    /// First positional (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// String option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parsed numeric option with default; exits with a message on a
    /// malformed value (CLI surface, not library surface).
    pub fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{key} expects a number, got '{v}'");
                std::process::exit(2);
            }),
        }
    }

    /// Boolean flag (`--verbose`).
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["table3", "--arch", "eyeriss", "--trials=5", "--verbose"]);
        assert_eq!(a.subcommand(), Some("table3"));
        assert_eq!(a.get("arch"), Some("eyeriss"));
        assert_eq!(a.get_num::<u32>("trials", 0), 5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.subcommand(), None);
        assert_eq!(a.get_or("arch", "eyeriss"), "eyeriss");
        assert_eq!(a.get_num::<u64>("seed", 42), 42);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "x"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("x"));
    }
}
