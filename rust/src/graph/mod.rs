//! Graph-level compilation: workload DAG, operator fusion and
//! inter-layer mapping co-selection (DESIGN.md §17).
//!
//! The per-layer pipeline maps every layer of a network independently
//! from a flat `Vec<Layer>`; real compilers for spatial accelerators map
//! the *graph*, because inter-layer DRAM traffic — writing each layer's
//! output only for the next layer to read it straight back — dominates
//! total off-chip movement. This subsystem recovers that structure:
//!
//! * [`ir`] — [`WorkloadGraph`]: the flat layer list promoted to a DAG
//!   with shape-checked producer/consumer [`Edge`]s. Residual networks
//!   (mobilenetv2res, bert) get real multi-predecessor structure; plain
//!   chains (alexnet, vgg16) degrade to the existing linear order.
//! * [`fuse`] — the pattern-based fusion pass (`conv→add`, `conv→pool`,
//!   `matmul→add`, `conv→add→pool`) forming [`FusedGroup`]s whose
//!   intermediate tensors stay on chip, gated by the per-op relevance
//!   tables and the shared level's capacity.
//! * [`schedule`] — inter-layer co-selection: scoring fused groups by the
//!   DRAM traffic they actually remove under the chosen mappings, rolled
//!   up into the [`GraphReport`] carried by every
//!   [`crate::api::CompileReport`].
//!
//! The whole subsystem is **analysis-only**: per-layer mapping work is
//! identical in every mode, so `--graph-mode off` (the default) is
//! bit-identical to the flat pipeline by construction, and the property
//! suite pins it.

pub mod fuse;
pub mod ir;
pub mod schedule;

pub use fuse::{fusable, fuse_network, FusedGroup};
pub use ir::{Edge, WorkloadGraph};
pub use schedule::{analyze, GraphReport, MappingIndex};

/// How much graph structure a compile request exploits
/// (`--graph-mode`, [`crate::api::CompileRequest::graph_mode`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum GraphMode {
    /// No graph analysis beyond the baseline traffic estimate; the flat
    /// per-layer pipeline, bit for bit. The default.
    #[default]
    Off,
    /// Run the fusion pass and report fused groups with static
    /// (tensor-volume) DRAM savings.
    Fuse,
    /// Fusion plus mapping-aware co-selection: groups are scored with the
    /// member layers' actual DRAM traffic and kept only when fusing wins.
    CoSelect,
}

impl GraphMode {
    /// Accepted `--graph-mode` values, for usage messages.
    pub const SPEC: &'static str = "off|fuse|co_select";

    /// Parse a CLI/serve value (`off`, `fuse`, `co_select`/`co-select`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(GraphMode::Off),
            "fuse" => Some(GraphMode::Fuse),
            "co_select" | "co-select" => Some(GraphMode::CoSelect),
            _ => None,
        }
    }

    /// Canonical name, as printed in reports and api_v1 documents.
    pub fn name(self) -> &'static str {
        match self {
            GraphMode::Off => "off",
            GraphMode::Fuse => "fuse",
            GraphMode::CoSelect => "co_select",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_mode_parses_its_own_names() {
        for mode in [GraphMode::Off, GraphMode::Fuse, GraphMode::CoSelect] {
            assert_eq!(GraphMode::parse(mode.name()), Some(mode));
        }
        assert_eq!(GraphMode::parse("co-select"), Some(GraphMode::CoSelect));
        assert_eq!(GraphMode::parse("on"), None);
        assert_eq!(GraphMode::default(), GraphMode::Off);
    }
}
