//! The workload DAG: producer/consumer structure over the operator IR.
//!
//! The rest of the pipeline consumes workloads as a flat `Vec<Layer>` in
//! execution order. That order is a valid topological sort of the real
//! dataflow graph, but it erases *which* earlier layer each layer actually
//! reads — and graph-level optimization (fusion, inter-layer co-selection,
//! DESIGN.md §17) needs exactly that structure. [`WorkloadGraph`] recovers
//! it: every node is a [`Layer`], every [`Edge`] is a tensor-shape-checked
//! producer→consumer relation, and construction from a layer list infers
//! the edges from the shapes alone:
//!
//! * each consumer is wired to its **nearest** shape-compatible producers,
//!   one per input operand ([`crate::workload::OpKind::input_operands`]) —
//!   so a plain chain (alexnet, vgg16) degrades to exactly the linear
//!   order the pipeline already uses, while a residual add
//!   (mobilenetv2res) or an attention/FFN block add (bert) picks up its
//!   second, skip-level predecessor;
//! * an edge exists only when the producer's output tensor can actually
//!   feed the consumer's input ([`compatible`]): batch and channel counts
//!   agree and the producer's output spatial extent lies between the
//!   consumer's strictly-needed core and its padded halo extent.
//!
//! Edges always point forward (`from < to`), so the node order itself is a
//! topological order; [`WorkloadGraph::topo_order`] recomputes one from
//! the edges (Kahn's algorithm) and is pinned equal to `0..n` in tests.

use crate::workload::Layer;

/// One producer→consumer edge: node `from`'s output tensor is (one of)
/// node `to`'s input operand(s). Always forward: `from < to`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    /// Producer node index into [`WorkloadGraph::nodes`].
    pub from: usize,
    /// Consumer node index into [`WorkloadGraph::nodes`].
    pub to: usize,
}

/// A workload as a DAG of layers with shape-checked producer/consumer
/// edges. Built from a flat layer list by [`WorkloadGraph::from_layers`]
/// (or [`WorkloadGraph::zoo`] for a zoo network by name).
#[derive(Debug, Clone)]
pub struct WorkloadGraph {
    /// Workload name (network name for zoo graphs).
    pub name: String,
    /// The layers, in execution order (a topological order of `edges`).
    pub nodes: Vec<Layer>,
    /// Shape-checked producer→consumer edges, sorted by `(from, to)`.
    pub edges: Vec<Edge>,
}

/// True when `producer`'s output tensor can feed one of `consumer`'s
/// input operands: batches agree, the producer's output channels (always
/// on `M`) match the consumer's input channel count, and on each spatial
/// axis the producer's output extent covers at least the consumer's
/// strictly-needed core (`(p-1)·stride + 1` rows) without exceeding its
/// padded halo extent ([`Layer::h`]/[`Layer::w`]) — i.e. the two tensors
/// differ by at most the convolution padding.
pub fn compatible(producer: &Layer, consumer: &Layer) -> bool {
    if producer.n != consumer.n || producer.m != consumer.input_channels() {
        return false;
    }
    let rows_core = (consumer.p - 1) * consumer.stride + 1;
    let cols_core = (consumer.q - 1) * consumer.stride + 1;
    rows_core <= producer.p
        && producer.p <= consumer.h()
        && cols_core <= producer.q
        && producer.q <= consumer.w()
}

impl WorkloadGraph {
    /// Build the DAG for a flat layer list by shape inference: each
    /// consumer is wired to its nearest compatible producers, one per
    /// input operand (see the [module docs](self) for the rules). Chains
    /// degrade to the linear order; residual adds get two predecessors.
    pub fn from_layers(name: &str, layers: &[Layer]) -> Self {
        let nodes: Vec<Layer> = layers.to_vec();
        let mut edges = Vec::new();
        for (i, consumer) in nodes.iter().enumerate().skip(1) {
            let wanted = consumer.op.input_operands() as usize;
            let mut found = 0usize;
            for j in (0..i).rev() {
                if compatible(&nodes[j], consumer) {
                    edges.push(Edge { from: j, to: i });
                    found += 1;
                    if found == wanted {
                        break;
                    }
                }
            }
        }
        edges.sort_by_key(|e| (e.from, e.to));
        Self { name: name.to_string(), nodes, edges }
    }

    /// The DAG of a zoo network by name ([`crate::workload::zoo::network`]
    /// spellings). `None` for unknown names.
    pub fn zoo(name: &str) -> Option<Self> {
        crate::workload::zoo::network(name).map(|layers| Self::from_layers(name, &layers))
    }

    /// Node count.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Indices of the nodes whose output node `i` consumes.
    pub fn predecessors(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.edges.iter().filter(move |e| e.to == i).map(|e| e.from)
    }

    /// Indices of the nodes that consume node `i`'s output.
    pub fn successors(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.edges.iter().filter(move |e| e.from == i).map(|e| e.to)
    }

    /// Number of consumers of node `i`'s output.
    pub fn out_degree(&self, i: usize) -> usize {
        self.successors(i).count()
    }

    /// A topological order of the nodes (Kahn's algorithm, smallest ready
    /// index first, so the result is deterministic). Because construction
    /// only creates forward edges, this is always exactly `0..n` — the
    /// execution order the flat pipeline already uses — but it is computed
    /// from the edges, so a hand-built graph with reordered nodes still
    /// iterates producers-first.
    pub fn topo_order(&self) -> Vec<usize> {
        let n = self.nodes.len();
        let mut indegree = vec![0usize; n];
        for e in &self.edges {
            indegree[e.to] += 1;
        }
        let mut order = Vec::with_capacity(n);
        let mut done = vec![false; n];
        while order.len() < n {
            // Smallest unprocessed node with no unprocessed predecessor.
            let Some(i) = (0..n).find(|&i| !done[i] && indegree[i] == 0) else {
                break; // cycle: unreachable for shape-inferred graphs
            };
            done[i] = true;
            order.push(i);
            for j in self.successors(i).collect::<Vec<_>>() {
                indegree[j] -= 1;
            }
        }
        order
    }

    /// True when the graph is a plain chain: edges are exactly
    /// `{i → i+1}` for every consecutive pair — the shape a linear network
    /// (alexnet, vgg16) must degrade to.
    pub fn is_linear_chain(&self) -> bool {
        self.edges.len() + 1 == self.nodes.len().max(1)
            && self.edges.iter().enumerate().all(|(i, e)| e.from == i && e.to == i + 1)
    }

    /// Check every structural invariant: edge indices in range, edges
    /// strictly forward (`from < to`, hence acyclic), no duplicate edges,
    /// every edge shape-[`compatible`], and no consumer wired to more
    /// predecessors than its operand count.
    pub fn check(&self) -> Result<(), String> {
        let n = self.nodes.len();
        for (k, e) in self.edges.iter().enumerate() {
            if e.from >= n || e.to >= n {
                return Err(format!("edge {}→{} out of range (n={n})", e.from, e.to));
            }
            if e.from >= e.to {
                return Err(format!("edge {}→{} is not forward", e.from, e.to));
            }
            if self.edges[..k].contains(e) {
                return Err(format!("duplicate edge {}→{}", e.from, e.to));
            }
            if !compatible(&self.nodes[e.from], &self.nodes[e.to]) {
                return Err(format!(
                    "edge {}→{} fails the shape check ({} → {})",
                    e.from, e.to, self.nodes[e.from].name, self.nodes[e.to].name
                ));
            }
        }
        for (i, node) in self.nodes.iter().enumerate() {
            let preds = self.predecessors(i).count() as u64;
            if preds > node.op.input_operands() {
                return Err(format!(
                    "node {i} ({}) has {preds} predecessors but {} input operand(s)",
                    node.name,
                    node.op.input_operands()
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;
    use crate::workload::OpKind;

    #[test]
    fn plain_chains_degrade_to_the_linear_order() {
        for name in ["alexnet", "vgg16", "vgg02"] {
            let g = WorkloadGraph::zoo(name).unwrap();
            g.check().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(g.is_linear_chain(), "{name} must be a linear chain");
            assert_eq!(g.topo_order(), (0..g.n_nodes()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn mobilenetv2res_adds_have_residual_predecessors() {
        let g = WorkloadGraph::zoo("mobilenetv2res").unwrap();
        g.check().unwrap();
        assert!(!g.is_linear_chain());
        let adds: Vec<usize> = (0..g.n_nodes())
            .filter(|&i| g.nodes[i].op == OpKind::Elementwise)
            .collect();
        assert_eq!(adds.len(), 10, "mobilenetv2res carries 10 residual adds");
        for &i in &adds {
            let preds: Vec<usize> = g.predecessors(i).collect();
            assert_eq!(preds.len(), 2, "{} needs a skip edge", g.nodes[i].name);
            // The nearest predecessor is the project conv directly before
            // the add; the other is an earlier, skip-level producer.
            assert!(preds.contains(&(i - 1)));
            assert!(preds.iter().any(|&p| p < i - 1));
        }
    }

    #[test]
    fn bert_adds_have_two_predecessors() {
        let g = WorkloadGraph::zoo("bert").unwrap();
        g.check().unwrap();
        assert!(!g.is_linear_chain());
        let adds: Vec<usize> = (0..g.n_nodes())
            .filter(|&i| g.nodes[i].op == OpKind::Elementwise)
            .collect();
        assert_eq!(adds.len(), 24, "12 blocks × 2 residual adds");
        for &i in &adds {
            assert_eq!(g.predecessors(i).count(), 2, "{}", g.nodes[i].name);
        }
    }

    #[test]
    fn vgg16pooled_pools_follow_their_convs() {
        let g = WorkloadGraph::zoo("vgg16pool").unwrap();
        g.check().unwrap();
        for i in 0..g.n_nodes() {
            if g.nodes[i].op == OpKind::Pooling {
                assert_eq!(g.predecessors(i).collect::<Vec<_>>(), vec![i - 1]);
            }
        }
    }

    #[test]
    fn every_zoo_network_builds_a_valid_graph() {
        for (name, layers) in zoo::batch_zoo() {
            let g = WorkloadGraph::from_layers(&name, &layers);
            g.check().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(g.n_nodes(), layers.len());
            assert_eq!(g.topo_order(), (0..layers.len()).collect::<Vec<_>>(), "{name}");
        }
    }

    #[test]
    fn compatibility_checks_channels_and_spatial_extent() {
        let a = Layer::new("a", 64, 3, 3, 3, 224, 224);
        let b = Layer::new("b", 64, 64, 3, 3, 224, 224);
        assert!(compatible(&a, &b), "64-channel output feeds 64-channel input");
        let wrong_c = Layer::new("c", 64, 32, 3, 3, 224, 224);
        assert!(!compatible(&a, &wrong_c), "channel mismatch");
        let wrong_p = Layer::new("d", 64, 64, 3, 3, 32, 32);
        assert!(!compatible(&a, &wrong_p), "spatial mismatch");
        // Stride-2 downsampling consumes the full extent: still an edge.
        let down = Layer::new("e", 128, 64, 3, 3, 112, 112).with_stride(2);
        assert!(compatible(&a, &down));
        // Pooling: input channels ride on M.
        let pool = Layer::pooling("p", 64, 2, 112, 112).with_stride(2);
        assert!(compatible(&a, &pool));
        // Elementwise add: exact spatial match required (no halo).
        let add = Layer::elementwise("add", 64, 224, 224);
        assert!(compatible(&a, &add));
        let add_off = Layer::elementwise("add2", 64, 112, 112);
        assert!(!compatible(&a, &add_off));
    }

    #[test]
    fn check_rejects_malformed_graphs() {
        let layers = zoo::alexnet();
        let mut g = WorkloadGraph::from_layers("alexnet", &layers);
        g.edges.push(Edge { from: 3, to: 1 });
        assert!(g.check().unwrap_err().contains("not forward"));
        let mut g = WorkloadGraph::from_layers("alexnet", &layers);
        g.edges.push(Edge { from: 0, to: 99 });
        assert!(g.check().unwrap_err().contains("out of range"));
        let mut g = WorkloadGraph::from_layers("alexnet", &layers);
        g.edges.push(Edge { from: 0, to: 3 });
        assert!(g.check().is_err(), "incompatible or over-subscribed edge must fail");
    }
}
