//! Inter-layer co-selection and the graph report.
//!
//! [`analyze`] is the graph-compilation entry point the [`crate::api`]
//! session calls once per compile: it builds the [`super::ir::WorkloadGraph`]
//! for every network in the request, runs the [`super::fuse`] pass when the
//! mode asks for it, and rolls the result up into one [`GraphReport`] —
//! groups formed, fused layer count, and the estimated cross-layer DRAM
//! traffic with and without fusion.
//!
//! Three accounting levels, one per [`super::GraphMode`]:
//!
//! * **off** — every producer/consumer edge crosses DRAM: the producer
//!   writes its output once, each consumer reads it once. The report
//!   carries that baseline and zero groups; per-layer mapping is
//!   untouched (bit-identity is property-pinned).
//! * **fuse** — pattern-fused edges keep the intermediate on chip; the
//!   saving per fused edge is the static tensor volume, once for the
//!   avoided DRAM write and once for the avoided read.
//! * **co_select** — the fused pairs are *scored* with the mapped
//!   layers' actual DRAM traffic: the producer's `Output` DRAM words plus
//!   the consumer's per-operand `Input` DRAM words under their chosen
//!   mappings ([`EvalContext::dram_tensor_words`] — the cross-layer
//!   DRAM-traffic term). A group is kept only when its score is a real
//!   saving, and identical shape chains share one scoring pass via
//!   [`super::fuse::FusedGroup::fingerprint`] (bert's 24 residual groups
//!   collapse to 2 evaluations).
//!
//! Co-selection never mutates mappings either: layers are still mapped
//! one at a time through the [`crate::coordinator::MappingService`]
//! (coalescing, persistent cache, warm seeds and fault fallback all keep
//! working); the graph pass decides which inter-layer tensors *stay on
//! chip* given those mappings.

use super::fuse::fuse_network;
use super::ir::WorkloadGraph;
use super::GraphMode;
use crate::arch::Accelerator;
use crate::mappers::Objective;
use crate::mapping::Mapping;
use crate::model::EvalContext;
use crate::workload::{Layer, Tensor};
use std::collections::HashMap;

/// Per-layer mappings for co-selection scoring, keyed by
/// `(network name, layer name)`. Pass an empty map for `off`/`fuse` (or
/// when mappings are unavailable — scoring then falls back to static
/// volumes).
pub type MappingIndex = HashMap<(String, String), Mapping>;

/// The graph-compilation summary of one compile request, reported in the
/// `graph` block of the api_v1 document and the table output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphReport {
    /// The mode the request ran under.
    pub mode: GraphMode,
    /// Fused groups formed (0 under `off`).
    pub groups: usize,
    /// Layers that are members of a fused group.
    pub fused_layers: usize,
    /// Estimated cross-layer DRAM bytes under this mode: the off-mode
    /// baseline minus [`GraphReport::dram_bytes_saved`].
    pub cross_layer_dram_bytes: u64,
    /// Estimated DRAM bytes the fused schedule keeps on chip.
    pub dram_bytes_saved: u64,
}

impl GraphReport {
    /// The zero report (no graph structure analyzed yet).
    pub fn empty(mode: GraphMode) -> Self {
        Self {
            mode,
            groups: 0,
            fused_layers: 0,
            cross_layer_dram_bytes: 0,
            dram_bytes_saved: 0,
        }
    }
}

/// Bytes of one `elems`-element tensor at the accelerator's datawidth.
fn tensor_bytes(elems: u64, acc: &Accelerator) -> u64 {
    elems.saturating_mul((acc.datawidth_bits + 7) / 8)
}

/// Off-mode baseline: every edge's tensor crosses DRAM — one write per
/// producer with at least one consumer, one read per consumer.
fn baseline_bytes(g: &WorkloadGraph, acc: &Accelerator) -> u64 {
    let mut total = 0u64;
    for (i, node) in g.nodes.iter().enumerate() {
        if g.out_degree(i) > 0 {
            total = total.saturating_add(tensor_bytes(node.tensor_volume(Tensor::Output), acc));
        }
    }
    for e in &g.edges {
        total = total
            .saturating_add(tensor_bytes(g.nodes[e.from].tensor_volume(Tensor::Output), acc));
    }
    total
}

/// Static (fuse-mode) saving of one fused producer→consumer edge: the
/// intermediate's volume, once for the avoided DRAM write and once for
/// the avoided read.
fn static_edge_saving(producer: &Layer, acc: &Accelerator) -> u64 {
    tensor_bytes(producer.tensor_volume(Tensor::Output), acc).saturating_mul(2)
}

/// Co-selection score of one fused edge: the DRAM traffic the fusion
/// actually removes under the chosen mappings — the producer's `Output`
/// DRAM words plus the consumer's `Input` DRAM words divided by its
/// operand count (the access table does not split operands; a residual
/// add reads two inputs of equal volume, of which fusion keeps one on
/// chip). Falls back to the static volume estimate when either mapping
/// is missing (e.g. the layer failed to map).
fn co_edge_saving(
    network: &str,
    producer: &Layer,
    consumer: &Layer,
    acc: &Accelerator,
    mappings: &MappingIndex,
) -> u64 {
    let mp = mappings.get(&(network.to_string(), producer.name.clone()));
    let mc = mappings.get(&(network.to_string(), consumer.name.clone()));
    let (Some(mp), Some(mc)) = (mp, mc) else {
        return static_edge_saving(producer, acc);
    };
    let out_words = EvalContext::new(producer, acc).dram_tensor_words(mp, Tensor::Output);
    let in_words = EvalContext::new(consumer, acc).dram_tensor_words(mc, Tensor::Input)
        / consumer.op.input_operands().max(1);
    tensor_bytes(out_words.saturating_add(in_words), acc)
}

/// Analyze the graph structure of every network in a compile request and
/// report the fused groups and estimated cross-layer DRAM traffic for
/// `mode`. `objective` keys the group fingerprints (and must match the
/// mapper's objective); `mappings` feeds co-selection scoring and may be
/// empty otherwise. Pure analysis: never changes what gets mapped.
pub fn analyze(
    networks: &[(String, Vec<Layer>)],
    acc: &Accelerator,
    mode: GraphMode,
    objective: Objective,
    mappings: &MappingIndex,
) -> GraphReport {
    let mut report = GraphReport::empty(mode);
    let mut baseline = 0u64;
    // Shape-keyed score cache: identical groups (same member LayerKeys)
    // save the same traffic, so bert's repeated blocks score once.
    let mut scores: HashMap<u64, u64> = HashMap::new();
    for (name, layers) in networks {
        let g = WorkloadGraph::from_layers(name, layers);
        baseline = baseline.saturating_add(baseline_bytes(&g, acc));
        if mode == GraphMode::Off {
            continue;
        }
        for grp in fuse_network(&g, acc) {
            let saved: u64 = match mode {
                GraphMode::Fuse => grp
                    .members
                    .windows(2)
                    .map(|pair| static_edge_saving(&g.nodes[pair[0]], acc))
                    .sum(),
                GraphMode::CoSelect => {
                    let fp = grp.fingerprint(&g, acc, objective);
                    *scores.entry(fp).or_insert_with(|| {
                        grp.members
                            .windows(2)
                            .map(|pair| {
                                co_edge_saving(
                                    name,
                                    &g.nodes[pair[0]],
                                    &g.nodes[pair[1]],
                                    acc,
                                    mappings,
                                )
                            })
                            .sum()
                    })
                }
                GraphMode::Off => unreachable!("handled above"),
            };
            if saved == 0 {
                continue; // co-selection: fusing must actually win
            }
            report.groups += 1;
            report.fused_layers += grp.members.len();
            report.dram_bytes_saved = report.dram_bytes_saved.saturating_add(saved);
        }
    }
    report.cross_layer_dram_bytes = baseline.saturating_sub(report.dram_bytes_saved);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::{LocalMapper, Mapper};
    use crate::workload::zoo;

    fn net(name: &str) -> Vec<(String, Vec<Layer>)> {
        vec![(name.to_string(), zoo::network(name).unwrap())]
    }

    #[test]
    fn off_mode_reports_the_baseline_and_no_groups() {
        let acc = presets::eyeriss();
        let r = analyze(
            &net("mobilenetv2res"),
            &acc,
            GraphMode::Off,
            Objective::Energy,
            &MappingIndex::new(),
        );
        assert_eq!(r.mode, GraphMode::Off);
        assert_eq!(r.groups, 0);
        assert_eq!(r.fused_layers, 0);
        assert_eq!(r.dram_bytes_saved, 0);
        assert!(r.cross_layer_dram_bytes > 0, "residual net has inter-layer traffic");
    }

    #[test]
    fn fuse_mode_saves_strictly_against_off() {
        let acc = presets::eyeriss();
        let networks = net("mobilenetv2res");
        let off =
            analyze(&networks, &acc, GraphMode::Off, Objective::Energy, &MappingIndex::new());
        let fuse =
            analyze(&networks, &acc, GraphMode::Fuse, Objective::Energy, &MappingIndex::new());
        assert!(fuse.groups >= 1, "mobilenetv2res must form fused groups");
        assert_eq!(fuse.fused_layers, 2 * fuse.groups, "conv+add pairs");
        assert!(fuse.dram_bytes_saved > 0);
        assert!(
            fuse.cross_layer_dram_bytes < off.cross_layer_dram_bytes,
            "fusion must report strictly lower cross-layer DRAM bytes"
        );
        assert_eq!(
            fuse.cross_layer_dram_bytes + fuse.dram_bytes_saved,
            off.cross_layer_dram_bytes
        );
    }

    #[test]
    fn co_select_scores_with_real_mappings() {
        let acc = presets::eyeriss();
        let networks = net("bert");
        let mapper = LocalMapper::new();
        let mut mappings = MappingIndex::new();
        for (name, layers) in &networks {
            for l in layers {
                let out = mapper.run(l, &acc).unwrap();
                mappings.insert((name.clone(), l.name.clone()), out.mapping);
            }
        }
        let fuse = analyze(&networks, &acc, GraphMode::Fuse, Objective::Energy, &mappings);
        let co = analyze(&networks, &acc, GraphMode::CoSelect, Objective::Energy, &mappings);
        assert_eq!(co.groups, fuse.groups, "every bert group is a real win");
        // Mapped DRAM traffic is at least the compulsory tensor volume, so
        // the mapping-aware score can only grow past the static estimate.
        assert!(co.dram_bytes_saved >= fuse.dram_bytes_saved);
        assert!(co.cross_layer_dram_bytes <= fuse.cross_layer_dram_bytes);
    }

    #[test]
    fn plain_chains_fuse_to_nothing() {
        let acc = presets::eyeriss();
        let off = analyze(&net("vgg16"), &acc, GraphMode::Off, Objective::Energy, &MappingIndex::new());
        let fuse =
            analyze(&net("vgg16"), &acc, GraphMode::Fuse, Objective::Energy, &MappingIndex::new());
        assert_eq!(fuse.groups, 0);
        assert_eq!(fuse.cross_layer_dram_bytes, off.cross_layer_dram_bytes);
    }
}
