//! Pattern-based operator fusion over the workload DAG.
//!
//! Fusion merges a producer and its sole consumer into one
//! [`FusedGroup`] so the inter-layer tensor is produced and drained
//! **on chip** instead of round-tripping through DRAM. The pass is
//! deliberately conservative — a group forms only when every legality
//! test passes (DESIGN.md §17):
//!
//! 1. **Pattern**: the pair is one of `conv→add`, `conv→pool`,
//!    `matmul→add`, extended to `conv→add→pool` when a pooling layer
//!    drains the add. Producers are the weight-carrying ops (conv,
//!    depthwise conv, matmul); consumers are the weight-less ops whose
//!    input is exactly the producer's output.
//! 2. **Sole consumer**: the producer's output may have no other reader
//!    in the graph — fusing would otherwise still force the DRAM write
//!    for the second consumer, saving nothing.
//! 3. **Shape**: the edge passes [`super::ir::compatible`] (also enforced
//!    at graph construction).
//! 4. **Relevance**: the per-op relevance tables
//!    ([`crate::workload::OpKind::relevant_dims`], PR 3) must carry the
//!    fused intermediate — the producer's `Output` must be indexed by
//!    `M` and `P` and the consumer's `Input` by its channel dimension and
//!    `P`, so a tile of the intermediate means the same coordinates on
//!    both sides.
//! 5. **Capacity**: one output row tile of the producer
//!    (`n × m × q` elements — the line-buffer granularity at which a
//!    `P`-ordered producer hands tiles to its consumer) must fit the
//!    shared on-chip level (the outermost bounded level, directly below
//!    DRAM).
//!
//! Fusion never changes any per-layer mapping: groups are a schedule
//! annotation consumed by [`super::schedule`], and `--graph-mode off`
//! (or `--no-fuse`) reproduces the flat pipeline bit for bit.

use super::ir::{compatible, WorkloadGraph};
use crate::arch::Accelerator;
use crate::coordinator::LayerKey;
use crate::mappers::Objective;
use crate::workload::{Dim, Layer, OpKind, Tensor};

/// A maximal fused chain of node indices (topological order) with the
/// pattern that formed it. Members are consecutive producer→consumer
/// pairs; every inner edge's tensor stays on chip.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusedGroup {
    /// Member node indices into the graph, producers first.
    pub members: Vec<usize>,
    /// Human-readable pattern: `conv+add`, `conv+pool`, `matmul+add` or
    /// `conv+add+pool`.
    pub pattern: &'static str,
}

impl FusedGroup {
    /// The member layers, producers first.
    pub fn layers<'a>(&self, g: &'a WorkloadGraph) -> impl Iterator<Item = &'a Layer> + '_ {
        self.members.iter().map(move |&i| &g.nodes[i])
    }

    /// Stable group fingerprint: FNV-1a fold of the members'
    /// [`LayerKey::fnv1a`] fingerprints under `objective`. Identical
    /// shape chains (bert's twelve encoder blocks) share a fingerprint,
    /// so group-level work (co-selection scoring, group-scoped cache
    /// entries) deduplicates across repeats.
    pub fn fingerprint(&self, g: &WorkloadGraph, acc: &Accelerator, objective: Objective) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for layer in self.layers(g) {
            let fp = LayerKey::new(layer, acc).for_objective(objective).fnv1a();
            for b in fp.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }

    /// Group-scoped cache keys for every member: the member's ordinary
    /// [`LayerKey`] extended with this group's fingerprint
    /// ([`LayerKey::with_group`]), so a mapping chosen *for the group
    /// context* can live in the same caches as the plain per-layer entry
    /// without ever colliding with it.
    pub fn member_keys(
        &self,
        g: &WorkloadGraph,
        acc: &Accelerator,
        objective: Objective,
    ) -> Vec<LayerKey> {
        let fp = self.fingerprint(g, acc, objective);
        self.layers(g)
            .map(|l| LayerKey::new(l, acc).for_objective(objective).with_group(fp))
            .collect()
    }
}

/// Index of the shared on-chip level: the outermost **bounded** level,
/// directly below DRAM (the accelerator validator guarantees exactly the
/// last level is unbounded). This is where a fused intermediate lives.
pub fn shared_level(acc: &Accelerator) -> usize {
    acc.n_levels() - 2
}

/// Relevance-table legality (rule 4 of the [module docs](self)): the
/// fused intermediate must be addressable by the same `(channel, P)`
/// coordinates on both sides of the edge.
fn relevance_legal(producer: &Layer, consumer: &Layer) -> bool {
    let chan = if consumer.op.channels_on_m() { Dim::M } else { Dim::C };
    producer.op.relevant(Tensor::Output, Dim::M)
        && producer.op.relevant(Tensor::Output, Dim::P)
        && consumer.op.relevant(Tensor::Input, chan)
        && consumer.op.relevant(Tensor::Input, Dim::P)
}

/// Capacity legality (rule 5): one output row tile of the producer —
/// `n × m × q` elements, the line-buffer granularity of a `P`-ordered
/// producer — must fit the shared on-chip level.
fn tile_fits(producer: &Layer, acc: &Accelerator) -> bool {
    producer
        .n
        .saturating_mul(producer.m)
        .saturating_mul(producer.q)
        <= acc.level_capacity(shared_level(acc))
}

/// All legality rules for fusing one producer→consumer edge (shape,
/// relevance tables, on-chip capacity). Public so the property tests can
/// assert every formed group satisfies it edge by edge.
pub fn fusable(producer: &Layer, consumer: &Layer, acc: &Accelerator) -> bool {
    compatible(producer, consumer)
        && relevance_legal(producer, consumer)
        && tile_fits(producer, acc)
}

/// Run the fusion pass over one graph: walk the nodes in topological
/// order and greedily form the longest legal group starting at each
/// unclaimed weight-carrying producer. Every returned group has ≥ 2
/// members; unfused nodes simply keep their flat-pipeline schedule.
pub fn fuse_network(g: &WorkloadGraph, acc: &Accelerator) -> Vec<FusedGroup> {
    let mut in_group = vec![false; g.n_nodes()];
    let mut groups = Vec::new();
    for i in g.topo_order() {
        if in_group[i] {
            continue;
        }
        let producer = &g.nodes[i];
        if !matches!(producer.op, OpKind::Conv | OpKind::DepthwiseConv | OpKind::MatMul) {
            continue;
        }
        let succs: Vec<usize> = g.successors(i).collect();
        let &[j] = &succs[..] else { continue }; // sole-consumer rule
        if in_group[j] {
            continue;
        }
        let mid = &g.nodes[j];
        if !matches!(mid.op, OpKind::Elementwise | OpKind::Pooling)
            || !fusable(producer, mid, acc)
        {
            continue;
        }
        let mut members = vec![i, j];
        let mut pattern = match (producer.op, mid.op) {
            (OpKind::MatMul, OpKind::Elementwise) => "matmul+add",
            (_, OpKind::Elementwise) => "conv+add",
            _ => "conv+pool",
        };
        // conv→add extends to conv→add→pool when a pooling layer is the
        // add's sole consumer and the add→pool edge is itself fusable.
        if mid.op == OpKind::Elementwise && producer.op != OpKind::MatMul {
            let tails: Vec<usize> = g.successors(j).collect();
            if let &[k] = &tails[..] {
                if !in_group[k]
                    && g.nodes[k].op == OpKind::Pooling
                    && fusable(mid, &g.nodes[k], acc)
                {
                    members.push(k);
                    pattern = "conv+add+pool";
                }
            }
        }
        for &m in &members {
            in_group[m] = true;
        }
        groups.push(FusedGroup { members, pattern });
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn mobilenetv2res_fuses_project_conv_into_each_residual_add() {
        let g = WorkloadGraph::zoo("mobilenetv2res").unwrap();
        let acc = presets::eyeriss();
        let groups = fuse_network(&g, &acc);
        assert!(!groups.is_empty(), "mobilenetv2res must form fused groups");
        for grp in &groups {
            assert_eq!(grp.pattern, "conv+add");
            assert_eq!(grp.members.len(), 2);
            assert_eq!(g.nodes[grp.members[0]].op, OpKind::Conv);
            assert_eq!(g.nodes[grp.members[1]].op, OpKind::Elementwise);
            for pair in grp.members.windows(2) {
                assert!(fusable(&g.nodes[pair[0]], &g.nodes[pair[1]], &acc));
            }
        }
    }

    #[test]
    fn bert_fuses_matmul_into_residual_adds() {
        let g = WorkloadGraph::zoo("bert").unwrap();
        let acc = presets::eyeriss();
        let groups = fuse_network(&g, &acc);
        assert_eq!(groups.len(), 24, "one matmul+add per residual add");
        assert!(groups.iter().all(|grp| grp.pattern == "matmul+add"));
    }

    #[test]
    fn vgg16pool_fuses_conv_into_pool() {
        let g = WorkloadGraph::zoo("vgg16pool").unwrap();
        let acc = presets::eyeriss();
        let groups = fuse_network(&g, &acc);
        assert_eq!(groups.len(), 5, "one conv+pool per pooling layer");
        assert!(groups.iter().all(|grp| grp.pattern == "conv+pool"));
    }

    #[test]
    fn plain_chains_form_no_groups() {
        let acc = presets::eyeriss();
        for name in ["alexnet", "vgg16"] {
            let g = WorkloadGraph::zoo(name).unwrap();
            assert!(fuse_network(&g, &acc).is_empty(), "{name}");
        }
    }

    #[test]
    fn capacity_rule_blocks_fusion_on_a_starved_accelerator() {
        let mut acc = presets::eyeriss();
        // Shrink the GLB below one output row tile of any producer.
        acc.levels[1].depth = 4;
        let g = WorkloadGraph::zoo("mobilenetv2res").unwrap();
        assert!(fuse_network(&g, &acc).is_empty());
    }

    #[test]
    fn group_fingerprints_dedupe_identical_chains() {
        let g = WorkloadGraph::zoo("bert").unwrap();
        let acc = presets::eyeriss();
        let groups = fuse_network(&g, &acc);
        let fps: std::collections::HashSet<u64> =
            groups.iter().map(|grp| grp.fingerprint(&g, &acc, Objective::Energy)).collect();
        // bert's encoder blocks repeat two shapes of residual-add chain
        // (attention 768×768 and FFN 3072→768), so 24 groups collapse to 2
        // distinct fingerprints.
        assert_eq!(fps.len(), 2);
        // Group-scoped member keys never collide with the plain keys.
        let keys = groups[0].member_keys(&g, &acc, Objective::Energy);
        for (k, layer) in keys.iter().zip(groups[0].layers(&g)) {
            let plain = LayerKey::new(layer, &acc);
            assert_ne!(k, &plain);
            assert_ne!(k.fnv1a(), plain.fnv1a());
        }
    }
}
