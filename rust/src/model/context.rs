//! The reusable, zero-allocation evaluation engine.
//!
//! [`evaluate_unchecked`] is the inner loop of every search mapper, but it
//! heap-allocates on every call: the access table, the bandwidth vector,
//! the [`Ert`] (rebuilt from the accelerator geometry each time) and the
//! returned [`Evaluation`] all hit the allocator per candidate. Search
//! mappers evaluate the *same* (layer, accelerator) pair thousands to
//! millions of times, so everything that depends only on that pair can be
//! hoisted out of the loop.
//!
//! [`EvalContext`] does exactly that: it precomputes the energy reference
//! table, the per-tensor dimension-relevance masks (operator-aware — built
//! from the layer's [`crate::workload::OpKind`] projection, e.g. depthwise
//! Input follows `M`, matmul drops `R`/`S`), and owns a scratch [`Evaluation`]
//! whose vectors are sized once at construction. The hot path,
//! [`EvalContext::evaluate_into`], overwrites the scratch in place and
//! returns a borrow — **zero heap allocations per candidate** (the loop
//! list is a fixed-capacity stack array, tile math is `[u64; 7]` arrays).
//!
//! Results are bit-identical to the legacy [`evaluate_unchecked`] path:
//! the floating-point operations run in the same order on the same
//! precomputed values (pinned by `prop_eval_context_bit_identical_to_legacy`
//! in `rust/tests/property.rs`).
//!
//! [`evaluate_unchecked`]: super::evaluate_unchecked

use super::nest::{loop_list_above, LoopIter};
use super::{Access, Evaluation, TensorIdx};
use crate::arch::Accelerator;
use crate::energy::{EnergyBreakdown, Ert};
use crate::mapping::{tensor_elems, Mapping, MappingError};
use crate::workload::{Dim, Layer, Tensor};

/// Precomputed per-(layer, accelerator) evaluation state with reusable
/// scratch buffers. Construct once per search, call
/// [`EvalContext::evaluate_into`] per candidate.
#[derive(Debug, Clone)]
pub struct EvalContext {
    layer: Layer,
    acc: Accelerator,
    ert: Ert,
    /// `relevance[tensor_idx][dim_idx]` — layer-aware tensor/dim relevance.
    relevance: [[bool; 7]; 3],
    scratch: Evaluation,
}

impl EvalContext {
    /// Precompute the ERT, relevance masks and scratch buffers for one
    /// (layer, accelerator) pair. This is the only allocating step; every
    /// subsequent [`EvalContext::evaluate_into`] call is allocation-free.
    pub fn new(layer: &Layer, acc: &Accelerator) -> Self {
        let n_levels = acc.n_levels();
        let mut relevance = [[false; 7]; 3];
        for t in Tensor::ALL {
            for d in Dim::ALL {
                relevance[t.t_idx()][d.idx()] = t.relevant_for(layer, d);
            }
        }
        let scratch = Evaluation {
            access: vec![[Access::default(); 3]; n_levels],
            noc_words: 0,
            noc_avg_hops: 0.0,
            macs: 0,
            active_pes: 0,
            utilization: 0.0,
            compute_cycles: 0,
            bandwidth_cycles: vec![0; n_levels],
            latency_cycles: 0,
            energy: EnergyBreakdown::zero(n_levels),
        };
        Self {
            layer: layer.clone(),
            acc: acc.clone(),
            ert: Ert::for_accelerator(acc),
            relevance,
            scratch,
        }
    }

    /// The layer this context evaluates against.
    pub fn layer(&self) -> &Layer {
        &self.layer
    }

    /// The accelerator this context evaluates against.
    pub fn acc(&self) -> &Accelerator {
        &self.acc
    }

    /// Validate-then-evaluate convenience (mirrors [`super::evaluate`]).
    pub fn evaluate(&mut self, mapping: &Mapping) -> Result<&Evaluation, MappingError> {
        mapping.validate(&self.layer, &self.acc)?;
        Ok(self.evaluate_into(mapping))
    }

    /// Hot-path accessor: total energy (pJ) of one candidate. What the
    /// search mappers rank by.
    pub fn energy_pj(&mut self, mapping: &Mapping) -> f64 {
        self.evaluate_into(mapping).energy.total_pj()
    }

    /// Evaluate one candidate into the scratch buffers and return a borrow.
    /// Performs **no heap allocation**: the access table, bandwidth vector
    /// and energy breakdown are overwritten in place, the loop list above
    /// each boundary is a fixed-capacity stack array, and all tile math is
    /// `[u64; 7]` stack arrays. Clone the returned `Evaluation` only when a
    /// candidate is kept (once per improvement, not once per candidate).
    ///
    /// The mapping must be valid for this context's (layer, accelerator)
    /// pair (debug builds assert); the arithmetic is identical to
    /// [`super::evaluate_unchecked`], operation for operation.
    pub fn evaluate_into(&mut self, mapping: &Mapping) -> &Evaluation {
        let EvalContext { layer, acc, ert, relevance, scratch } = self;
        debug_assert!(mapping.validate(layer, acc).is_ok());
        let n_levels = acc.n_levels();
        debug_assert_eq!(mapping.n_levels(), n_levels);

        for row in scratch.access.iter_mut() {
            *row = [Access::default(); 3];
        }

        let fanout = mapping.spatial_x_used() * mapping.spatial_y_used();

        // Spatial tile: per-PE tile ⊗ spatial factors (unique data across
        // the whole PE array).
        let tile0 = mapping.tile0();
        let mut spatial_tile = tile0;
        for d in 0..7 {
            spatial_tile[d] *= mapping.spatial_x[d] * mapping.spatial_y[d];
        }

        // --- Level-0 (RF) datapath traffic (weight-less ops skip W;
        // elementwise adds read both summands).
        let macs = layer.macs();
        if layer.op.uses_weights() {
            scratch.access[0][Tensor::Weight.t_idx()].reads += macs;
        }
        scratch.access[0][Tensor::Input.t_idx()].reads += macs * layer.op.input_operands();
        if !layer.op.reduction_dims().is_empty() {
            // Accumulation: each op read-modify-writes a partial sum. Ops
            // with no reduction dims (elementwise add) write each output
            // exactly once and never read it back.
            scratch.access[0][Tensor::Output.t_idx()].reads += macs; // accumulator read
        }
        scratch.access[0][Tensor::Output.t_idx()].writes += macs; // accumulator write

        let mut noc_words: u64 = 0;

        // --- Boundaries (see `super::evaluate_unchecked` for the model).
        for l in 1..n_levels {
            let loops = loop_list_above(layer, mapping, l);
            for t in Tensor::ALL {
                if t == Tensor::Weight && !layer.op.uses_weights() {
                    continue; // no weight tensor: zero elements at every level
                }
                let ti = t.t_idx();
                let mask = &relevance[ti];
                let (unique_child, aggregate_child) = if l == 1 {
                    let unique = tensor_elems(layer, &spatial_tile, t);
                    let aggregate = fanout * tensor_elems(layer, &tile0, t);
                    (unique, aggregate)
                } else {
                    let e = mapping.tensor_tile_elems(layer, l - 1, t);
                    (e, e)
                };
                match t {
                    Tensor::Weight | Tensor::Input => {
                        let rounds = fetch_rounds_masked(mask, &loops);
                        let served = if l == 1 && !acc.noc.multicast {
                            aggregate_child
                        } else {
                            unique_child
                        };
                        scratch.access[l][ti].reads += rounds * served;
                        scratch.access[l - 1][ti].writes += rounds * aggregate_child;
                        if l == 1 {
                            noc_words += rounds * served;
                        }
                    }
                    Tensor::Output => {
                        let v = fetch_rounds_masked(mask, &loops);
                        let u = distinct_tiles_masked(mask, &loops);
                        debug_assert!(v >= u);
                        scratch.access[l][ti].writes += v * unique_child;
                        scratch.access[l][ti].reads += (v - u) * unique_child;
                        scratch.access[l - 1][ti].reads += v * aggregate_child;
                        scratch.access[l - 1][ti].writes += (v - u) * aggregate_child;
                        if l == 1 {
                            noc_words += v * unique_child + (v - u) * unique_child;
                            noc_words += v * (aggregate_child - unique_child);
                        }
                    }
                }
            }
        }

        // --- Latency roofline (same instance/bandwidth model as legacy).
        let compute_cycles: u64 = mapping.temporal.iter().flatten().product();
        for l in 0..n_levels {
            let words: u64 = (0..3).map(|ti| scratch.access[l][ti].total()).sum();
            let instances = if acc.levels[l].per_pe { fanout.max(1) } else { 1 };
            let bw = acc.levels[l].bandwidth_words_per_cycle.max(f64::MIN_POSITIVE)
                * instances as f64;
            scratch.bandwidth_cycles[l] = (words as f64 / bw).ceil() as u64;
        }
        let latency_cycles =
            compute_cycles.max(scratch.bandwidth_cycles.iter().copied().max().unwrap_or(0));

        // --- Energy roll-up from the precomputed ERT.
        for l in 0..n_levels {
            let words: u64 = (0..3).map(|ti| scratch.access[l][ti].total()).sum();
            scratch.energy.level_pj[l] = words as f64 * ert.level(l);
        }
        let noc_avg_hops = (mapping.spatial_x_used() + mapping.spatial_y_used()) as f64 / 2.0;
        scratch.energy.noc_pj = noc_words as f64 * ert.noc_hop_pj * noc_avg_hops;
        scratch.energy.mac_pj = macs as f64 * ert.mac_pj;

        scratch.noc_words = noc_words;
        scratch.noc_avg_hops = noc_avg_hops;
        scratch.macs = macs;
        scratch.active_pes = fanout;
        scratch.utilization = mapping.pe_utilization(acc);
        scratch.compute_cycles = compute_cycles;
        scratch.latency_cycles = latency_cycles;
        scratch
    }
}

/// Most storage levels any supported accelerator carries (bound scratch is
/// stack-allocated at this size).
const MAX_BOUND_LEVELS: usize = 8;

impl EvalContext {
    /// Permutation-independent **lower bound** on `(total energy pJ,
    /// roofline latency cycles)` over every per-level loop permutation of
    /// `mapping`'s tiling — the bound-based pruner's primitive
    /// ([`crate::mappers::engine::SearchDriver`]).
    ///
    /// The bound replaces each tensor's fetch rounds at each boundary with
    /// their minimum over all permutations: the stationarity gate cannot
    /// open below the lowest level `L*` holding a relevant non-degenerate
    /// loop, at `L*` only the relevant trips are forced (irrelevant loops
    /// can sit innermost), and above `L*` every trip is forced (it sits
    /// above the first relevant loop whatever the order). Everything else —
    /// per-tensor footprints from the precomputed relevance masks, the
    /// spatial boundary, multicast, the compulsory datapath traffic and
    /// compute cycles — is already permutation-independent and computed
    /// exactly. Word counts are composed with saturating arithmetic and
    /// rolled up in the same order as [`EvalContext::evaluate_into`]
    /// (IEEE rounding is monotone), so the returned pair never exceeds the
    /// real evaluation of **any** member of the tiling's permutation block:
    /// skipping a block whose bound already exceeds the incumbent can
    /// never change a search's argmin (pinned by
    /// `prop_objective_bound_is_a_true_lower_bound` and the pruned-vs-
    /// unpruned sweeps in `rust/tests/property.rs`).
    ///
    /// The mapping need not be valid (invalid candidates may be bounded
    /// before validation); only its level count must match.
    pub fn objective_bound(&self, mapping: &Mapping) -> (f64, u64) {
        let EvalContext { layer, acc, ert, relevance, .. } = self;
        let n_levels = acc.n_levels();
        debug_assert_eq!(mapping.n_levels(), n_levels);
        if n_levels > MAX_BOUND_LEVELS {
            // Deeper hierarchies than the stack scratch covers: return the
            // trivially-valid bound (prunes nothing, stays correct).
            return (0.0, 0);
        }
        let mut words = [0u64; MAX_BOUND_LEVELS];

        let fanout = mapping.spatial_x_used() * mapping.spatial_y_used();
        let tile0 = mapping.tile0();
        let mut spatial_tile = tile0;
        for d in 0..7 {
            spatial_tile[d] *= mapping.spatial_x[d] * mapping.spatial_y[d];
        }

        // Level-0 datapath traffic: exact and mapping-order-free.
        let macs = layer.macs();
        if layer.op.uses_weights() {
            words[0] += macs;
        }
        words[0] += macs * layer.op.input_operands();
        if !layer.op.reduction_dims().is_empty() {
            words[0] += macs; // accumulator read-back
        }
        words[0] += macs; // accumulator write

        // Per-level trip products: `rel[l][t]` over the t-relevant dims,
        // `all[l]` over every dim.
        let mut rel = [[1u64; 3]; MAX_BOUND_LEVELS];
        let mut all = [1u64; MAX_BOUND_LEVELS];
        for l in 0..n_levels {
            for d in 0..7 {
                let f = mapping.temporal[l][d];
                all[l] = all[l].saturating_mul(f);
                for (t, mask) in relevance.iter().enumerate() {
                    if mask[d] {
                        rel[l][t] = rel[l][t].saturating_mul(f);
                    }
                }
            }
        }
        // Minimum fetch rounds of tensor `t` above boundary `l`.
        let rounds_min = |t: usize, l: usize| -> u64 {
            let Some(lstar) = (l..n_levels).find(|&lev| rel[lev][t] > 1) else {
                return 1;
            };
            let mut r = rel[lstar][t];
            for lev in lstar + 1..n_levels {
                r = r.saturating_mul(all[lev]);
            }
            r
        };
        // Distinct child tiles of `t` above boundary `l` (exact).
        let distinct = |t: usize, l: usize| -> u64 {
            (l..n_levels).fold(1u64, |u, lev| u.saturating_mul(rel[lev][t]))
        };

        let mut noc_words: u64 = 0;
        for l in 1..n_levels {
            for t in Tensor::ALL {
                if t == Tensor::Weight && !layer.op.uses_weights() {
                    continue;
                }
                let ti = t.t_idx();
                let (unique_child, aggregate_child) = if l == 1 {
                    let unique = tensor_elems(layer, &spatial_tile, t);
                    let aggregate = fanout * tensor_elems(layer, &tile0, t);
                    (unique, aggregate)
                } else {
                    let e = mapping.tensor_tile_elems(layer, l - 1, t);
                    (e, e)
                };
                match t {
                    Tensor::Weight | Tensor::Input => {
                        let rounds = rounds_min(ti, l);
                        let served = if l == 1 && !acc.noc.multicast {
                            aggregate_child
                        } else {
                            unique_child
                        };
                        words[l] = words[l].saturating_add(rounds.saturating_mul(served));
                        words[l - 1] =
                            words[l - 1].saturating_add(rounds.saturating_mul(aggregate_child));
                        if l == 1 {
                            noc_words = noc_words.saturating_add(rounds.saturating_mul(served));
                        }
                    }
                    Tensor::Output => {
                        let v = rounds_min(ti, l);
                        let u = distinct(ti, l);
                        debug_assert!(v >= u);
                        let extra = v - u;
                        words[l] = words[l]
                            .saturating_add(v.saturating_mul(unique_child))
                            .saturating_add(extra.saturating_mul(unique_child));
                        words[l - 1] = words[l - 1]
                            .saturating_add(v.saturating_mul(aggregate_child))
                            .saturating_add(extra.saturating_mul(aggregate_child));
                        if l == 1 {
                            noc_words = noc_words
                                .saturating_add(v.saturating_mul(unique_child))
                                .saturating_add(extra.saturating_mul(unique_child))
                                .saturating_add(
                                    v.saturating_mul(aggregate_child - unique_child),
                                );
                        }
                    }
                }
            }
        }

        // Latency lower bound: exact compute roofline vs bandwidth over
        // the lower-bound word counts (same instance model as the
        // evaluator).
        let compute_cycles: u64 = mapping.temporal.iter().flatten().product();
        let mut latency = compute_cycles;
        for l in 0..n_levels {
            let instances = if acc.levels[l].per_pe { fanout.max(1) } else { 1 };
            let bw = acc.levels[l].bandwidth_words_per_cycle.max(f64::MIN_POSITIVE)
                * instances as f64;
            latency = latency.max((words[l] as f64 / bw).ceil() as u64);
        }

        // Energy roll-up in the evaluator's summation order (levels
        // ascending, then NoC, then MAC) so float monotonicity carries
        // over to the total.
        let mut energy = 0.0f64;
        for (l, &w) in words.iter().enumerate().take(n_levels) {
            energy += w as f64 * ert.level(l);
        }
        let noc_avg_hops = (mapping.spatial_x_used() + mapping.spatial_y_used()) as f64 / 2.0;
        energy += noc_words as f64 * ert.noc_hop_pj * noc_avg_hops;
        energy += macs as f64 * ert.mac_pj;
        (energy, latency)
    }
}

/// Mask-based [`super::nest::fetch_rounds`]: identical integer arithmetic,
/// with the per-loop relevance test replaced by a precomputed table lookup.
fn fetch_rounds_masked(mask: &[bool; 7], loops: &[LoopIter]) -> u64 {
    let mut rounds = 1u64;
    let mut seen_relevant = false;
    for &(d, trip) in loops {
        if !seen_relevant {
            if mask[d.idx()] {
                seen_relevant = true;
            } else {
                continue; // stationary across this loop
            }
        }
        rounds = rounds.saturating_mul(trip);
    }
    rounds
}

/// Mask-based [`super::nest::distinct_tiles`].
fn distinct_tiles_masked(mask: &[bool; 7], loops: &[LoopIter]) -> u64 {
    loops
        .iter()
        .filter(|&&(d, _)| mask[d.idx()])
        .map(|&(_, trip)| trip)
        .product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mapspace::sample_random;
    use crate::model::evaluate_unchecked;
    use crate::util::rng::SplitMix64;
    use crate::workload::zoo;

    #[test]
    fn context_matches_legacy_on_zoo_layer() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let mut ctx = EvalContext::new(&layer, &acc);
        let mut rng = SplitMix64::new(11);
        for _ in 0..25 {
            let m = sample_random(&layer, &acc, &mut rng);
            let legacy = evaluate_unchecked(&layer, &acc, &m);
            let fast = ctx.evaluate_into(&m);
            assert_eq!(&legacy, fast);
        }
    }

    #[test]
    fn context_matches_legacy_on_depthwise() {
        // Depthwise relevance (Input follows M) must be baked into the mask.
        let acc = presets::eyeriss();
        let layer = zoo::mobilenet_v2().into_iter().find(|l| l.is_depthwise()).unwrap();
        let mut ctx = EvalContext::new(&layer, &acc);
        let mut rng = SplitMix64::new(13);
        for _ in 0..25 {
            let m = sample_random(&layer, &acc, &mut rng);
            assert_eq!(&evaluate_unchecked(&layer, &acc, &m), ctx.evaluate_into(&m));
        }
    }

    #[test]
    fn context_matches_legacy_on_every_op_kind() {
        // The op-aware masks and weight gating must agree with the legacy
        // evaluator on every operator projection, not just conv.
        let acc = presets::eyeriss();
        let mut rng = SplitMix64::new(19);
        for layer in [
            Layer::matmul("mm", 96, 64, 56),
            Layer::pooling("pool", 64, 2, 28, 28).with_stride(2),
            Layer::elementwise("add", 96, 28, 28),
        ] {
            let mut ctx = EvalContext::new(&layer, &acc);
            for _ in 0..15 {
                let m = sample_random(&layer, &acc, &mut rng);
                assert_eq!(
                    &evaluate_unchecked(&layer, &acc, &m),
                    ctx.evaluate_into(&m),
                    "{}",
                    layer.name
                );
            }
        }
    }

    #[test]
    fn context_is_reusable_across_candidates() {
        // Stale scratch state from one candidate must not leak into the next:
        // evaluate A, then B, then A again — the two A evaluations agree.
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[0].clone();
        let mut rng = SplitMix64::new(17);
        let a = sample_random(&layer, &acc, &mut rng);
        let b = sample_random(&layer, &acc, &mut rng);
        let mut ctx = EvalContext::new(&layer, &acc);
        let first = ctx.evaluate_into(&a).clone();
        let _ = ctx.evaluate_into(&b);
        assert_eq!(first, *ctx.evaluate_into(&a));
    }

    #[test]
    fn evaluate_rejects_invalid() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let mut m = Mapping::trivial(&layer, acc.n_levels());
        m.temporal[2][0] = 999;
        let mut ctx = EvalContext::new(&layer, &acc);
        assert!(ctx.evaluate(&m).is_err());
    }

    #[test]
    fn accessors_expose_the_pair() {
        let acc = presets::shidiannao();
        let layer = zoo::alexnet()[0].clone();
        let ctx = EvalContext::new(&layer, &acc);
        assert_eq!(ctx.layer().name, layer.name);
        assert_eq!(ctx.acc().name, acc.name);
    }
}
