//! The reusable, zero-allocation evaluation engine.
//!
//! [`evaluate_unchecked`] is the inner loop of every search mapper, but it
//! heap-allocates on every call: the access table, the bandwidth vector,
//! the [`Ert`] (rebuilt from the accelerator geometry each time) and the
//! returned [`Evaluation`] all hit the allocator per candidate. Search
//! mappers evaluate the *same* (layer, accelerator) pair thousands to
//! millions of times, so everything that depends only on that pair can be
//! hoisted out of the loop.
//!
//! [`EvalContext`] does exactly that: it precomputes the energy reference
//! table, the per-tensor dimension-relevance masks (operator-aware — built
//! from the layer's [`crate::workload::OpKind`] projection, e.g. depthwise
//! Input follows `M`, matmul drops `R`/`S`), and owns a scratch [`Evaluation`]
//! whose vectors are sized once at construction. The hot path,
//! [`EvalContext::evaluate_into`], overwrites the scratch in place and
//! returns a borrow — **zero heap allocations per candidate** (the loop
//! list is a fixed-capacity stack array, tile math is `[u64; 7]` arrays).
//!
//! Results are bit-identical to the legacy [`evaluate_unchecked`] path:
//! the floating-point operations run in the same order on the same
//! precomputed values (pinned by `prop_eval_context_bit_identical_to_legacy`
//! in `rust/tests/property.rs`).
//!
//! [`evaluate_unchecked`]: super::evaluate_unchecked

use super::nest::{loop_list_above, LoopIter};
use super::{Access, Evaluation, TensorIdx};
use crate::arch::Accelerator;
use crate::energy::{EnergyBreakdown, Ert};
use crate::mapping::{tensor_elems, Mapping, MappingError};
use crate::workload::{Dim, Layer, Tensor};

/// Precomputed per-(layer, accelerator) evaluation state with reusable
/// scratch buffers. Construct once per search, call
/// [`EvalContext::evaluate_into`] per candidate.
#[derive(Debug, Clone)]
pub struct EvalContext {
    layer: Layer,
    acc: Accelerator,
    ert: Ert,
    /// `relevance[tensor_idx][dim_idx]` — layer-aware tensor/dim relevance.
    relevance: [[bool; 7]; 3],
    scratch: Evaluation,
}

impl EvalContext {
    /// Precompute the ERT, relevance masks and scratch buffers for one
    /// (layer, accelerator) pair. This is the only allocating step; every
    /// subsequent [`EvalContext::evaluate_into`] call is allocation-free.
    pub fn new(layer: &Layer, acc: &Accelerator) -> Self {
        let n_levels = acc.n_levels();
        let mut relevance = [[false; 7]; 3];
        for t in Tensor::ALL {
            for d in Dim::ALL {
                relevance[t.t_idx()][d.idx()] = t.relevant_for(layer, d);
            }
        }
        let scratch = Evaluation {
            access: vec![[Access::default(); 3]; n_levels],
            noc_words: 0,
            noc_avg_hops: 0.0,
            macs: 0,
            active_pes: 0,
            utilization: 0.0,
            compute_cycles: 0,
            bandwidth_cycles: vec![0; n_levels],
            latency_cycles: 0,
            energy: EnergyBreakdown::zero(n_levels),
        };
        Self {
            layer: layer.clone(),
            acc: acc.clone(),
            ert: Ert::for_accelerator(acc),
            relevance,
            scratch,
        }
    }

    /// The layer this context evaluates against.
    pub fn layer(&self) -> &Layer {
        &self.layer
    }

    /// The accelerator this context evaluates against.
    pub fn acc(&self) -> &Accelerator {
        &self.acc
    }

    /// Validate-then-evaluate convenience (mirrors [`super::evaluate`]).
    pub fn evaluate(&mut self, mapping: &Mapping) -> Result<&Evaluation, MappingError> {
        mapping.validate(&self.layer, &self.acc)?;
        Ok(self.evaluate_into(mapping))
    }

    /// Hot-path accessor: total energy (pJ) of one candidate. What the
    /// search mappers rank by.
    pub fn energy_pj(&mut self, mapping: &Mapping) -> f64 {
        self.evaluate_into(mapping).energy.total_pj()
    }

    /// DRAM traffic (reads + writes, in words) of one tensor under a
    /// mapping — the cross-layer DRAM-traffic term graph-level
    /// co-selection scores fused groups with
    /// ([`crate::graph::schedule`]): a fused producer→consumer edge
    /// removes the producer's `Output` DRAM words and the consumer's
    /// share of `Input` DRAM words. Plain accessor over
    /// [`EvalContext::evaluate_into`]'s access table; arithmetic
    /// untouched.
    pub fn dram_tensor_words(&mut self, mapping: &Mapping, t: Tensor) -> u64 {
        let dram = self.acc.n_levels() - 1;
        let a = &self.evaluate_into(mapping).access[dram][t.t_idx()];
        a.reads + a.writes
    }

    /// Evaluate one candidate into the scratch buffers and return a borrow.
    /// Performs **no heap allocation**: the access table, bandwidth vector
    /// and energy breakdown are overwritten in place, the loop list above
    /// each boundary is a fixed-capacity stack array, and all tile math is
    /// `[u64; 7]` stack arrays. Clone the returned `Evaluation` only when a
    /// candidate is kept (once per improvement, not once per candidate).
    ///
    /// The mapping must be valid for this context's (layer, accelerator)
    /// pair (debug builds assert); the arithmetic is identical to
    /// [`super::evaluate_unchecked`], operation for operation.
    pub fn evaluate_into(&mut self, mapping: &Mapping) -> &Evaluation {
        let EvalContext { layer, acc, ert, relevance, scratch } = self;
        debug_assert!(mapping.validate(layer, acc).is_ok());
        let n_levels = acc.n_levels();
        debug_assert_eq!(mapping.n_levels(), n_levels);

        for row in scratch.access.iter_mut() {
            *row = [Access::default(); 3];
        }

        let fanout = mapping.spatial_x_used() * mapping.spatial_y_used();

        // Spatial tile: per-PE tile ⊗ spatial factors (unique data across
        // the whole PE array).
        let tile0 = mapping.tile0();
        let mut spatial_tile = tile0;
        for d in 0..7 {
            spatial_tile[d] *= mapping.spatial_x[d] * mapping.spatial_y[d];
        }

        // --- Level-0 (RF) datapath traffic (weight-less ops skip W;
        // elementwise adds read both summands).
        let macs = layer.macs();
        if layer.op.uses_weights() {
            scratch.access[0][Tensor::Weight.t_idx()].reads += macs;
        }
        scratch.access[0][Tensor::Input.t_idx()].reads += macs * layer.op.input_operands();
        if !layer.op.reduction_dims().is_empty() {
            // Accumulation: each op read-modify-writes a partial sum. Ops
            // with no reduction dims (elementwise add) write each output
            // exactly once and never read it back.
            scratch.access[0][Tensor::Output.t_idx()].reads += macs; // accumulator read
        }
        scratch.access[0][Tensor::Output.t_idx()].writes += macs; // accumulator write

        let mut noc_words: u64 = 0;

        // --- Boundaries (see `super::evaluate_unchecked` for the model).
        for l in 1..n_levels {
            let loops = loop_list_above(layer, mapping, l);
            for t in Tensor::ALL {
                if t == Tensor::Weight && !layer.op.uses_weights() {
                    continue; // no weight tensor: zero elements at every level
                }
                let ti = t.t_idx();
                let mask = &relevance[ti];
                let (unique_child, aggregate_child) = if l == 1 {
                    let unique = tensor_elems(layer, &spatial_tile, t);
                    let aggregate = fanout * tensor_elems(layer, &tile0, t);
                    (unique, aggregate)
                } else {
                    let e = mapping.tensor_tile_elems(layer, l - 1, t);
                    (e, e)
                };
                match t {
                    Tensor::Weight | Tensor::Input => {
                        let rounds = fetch_rounds_masked(mask, &loops);
                        let served = if l == 1 && !acc.noc.multicast {
                            aggregate_child
                        } else {
                            unique_child
                        };
                        scratch.access[l][ti].reads += rounds * served;
                        scratch.access[l - 1][ti].writes += rounds * aggregate_child;
                        if l == 1 {
                            noc_words += rounds * served;
                        }
                    }
                    Tensor::Output => {
                        let v = fetch_rounds_masked(mask, &loops);
                        let u = distinct_tiles_masked(mask, &loops);
                        debug_assert!(v >= u);
                        scratch.access[l][ti].writes += v * unique_child;
                        scratch.access[l][ti].reads += (v - u) * unique_child;
                        scratch.access[l - 1][ti].reads += v * aggregate_child;
                        scratch.access[l - 1][ti].writes += (v - u) * aggregate_child;
                        if l == 1 {
                            noc_words += v * unique_child + (v - u) * unique_child;
                            noc_words += v * (aggregate_child - unique_child);
                        }
                    }
                }
            }
        }

        // --- Latency roofline (same instance/bandwidth model as legacy).
        let compute_cycles: u64 = mapping.temporal.iter().flatten().product();
        for l in 0..n_levels {
            let words: u64 = (0..3).map(|ti| scratch.access[l][ti].total()).sum();
            let instances = if acc.levels[l].per_pe { fanout.max(1) } else { 1 };
            let bw = acc.levels[l].bandwidth_words_per_cycle.max(f64::MIN_POSITIVE)
                * instances as f64;
            scratch.bandwidth_cycles[l] = (words as f64 / bw).ceil() as u64;
        }
        let latency_cycles =
            compute_cycles.max(scratch.bandwidth_cycles.iter().copied().max().unwrap_or(0));

        // --- Energy roll-up from the precomputed ERT.
        for l in 0..n_levels {
            let words: u64 = (0..3).map(|ti| scratch.access[l][ti].total()).sum();
            scratch.energy.level_pj[l] = words as f64 * ert.level(l);
        }
        let noc_avg_hops = (mapping.spatial_x_used() + mapping.spatial_y_used()) as f64 / 2.0;
        scratch.energy.noc_pj = noc_words as f64 * ert.noc_hop_pj * noc_avg_hops;
        scratch.energy.mac_pj = macs as f64 * ert.mac_pj;

        scratch.noc_words = noc_words;
        scratch.noc_avg_hops = noc_avg_hops;
        scratch.macs = macs;
        scratch.active_pes = fanout;
        scratch.utilization = mapping.pe_utilization(acc);
        scratch.compute_cycles = compute_cycles;
        scratch.latency_cycles = latency_cycles;
        scratch
    }

    /// Score every member of one permutation block in a single pass
    /// (structure-of-arrays batch evaluation).
    ///
    /// All `members` must share one tiling — identical temporal and
    /// spatial factors, only the per-level loop permutations differing,
    /// which is exactly what a [`crate::mappers::engine::CandidateSource`]
    /// block yields (debug builds assert). Everything permutation-
    /// independent — per-tensor footprints, per-boundary child tile sizes,
    /// the compulsory datapath traffic, compute cycles, the NoC hop
    /// model — is computed once per block; per member only the fetch
    /// rounds (the sole permutation-dependent quantity) are recomputed.
    ///
    /// Pushes one `(total energy pJ, latency cycles)` pair per member into
    /// `out` (cleared first), bit-identical to
    /// `(evaluate_into(m).energy.total_pj(), evaluate_into(m).latency_cycles)`:
    /// the per-level word sums are associative integer additions and the
    /// float roll-up runs in [`EvalContext::evaluate_into`]'s exact order
    /// (pinned by `prop_evaluate_many_bit_identical_to_evaluate_into`).
    pub fn evaluate_many(&mut self, members: &[Mapping], out: &mut Vec<(f64, u64)>) {
        out.clear();
        if members.is_empty() {
            return;
        }
        if self.acc.n_levels() > MAX_BOUND_LEVELS {
            // Deeper hierarchies than the stack scratch covers: fall back
            // to the one-at-a-time path (identical results, no batch win).
            for m in members {
                let e = self.evaluate_into(m);
                out.push((e.energy.total_pj(), e.latency_cycles));
            }
            return;
        }
        let EvalContext { layer, acc, ert, relevance, .. } = self;
        let n_levels = acc.n_levels();
        let first = &members[0];
        debug_assert_eq!(first.n_levels(), n_levels);

        let fanout = first.spatial_x_used() * first.spatial_y_used();
        let tile0 = first.tile0();
        let mut spatial_tile = tile0;
        for d in 0..7 {
            spatial_tile[d] *= first.spatial_x[d] * first.spatial_y[d];
        }

        // Level-0 datapath traffic — identical for every member.
        let macs = layer.macs();
        let mut words0: u64 = 0;
        if layer.op.uses_weights() {
            words0 += macs;
        }
        words0 += macs * layer.op.input_operands();
        if !layer.op.reduction_dims().is_empty() {
            words0 += macs; // accumulator read-back
        }
        words0 += macs; // accumulator write

        // Per-(boundary, tensor) child tile sizes and NoC serving size —
        // tiling-only quantities, hoisted out of the member loop.
        let mut unique = [[0u64; 3]; MAX_BOUND_LEVELS];
        let mut aggregate = [[0u64; 3]; MAX_BOUND_LEVELS];
        let mut served = [[0u64; 3]; MAX_BOUND_LEVELS];
        for l in 1..n_levels {
            for t in Tensor::ALL {
                if t == Tensor::Weight && !layer.op.uses_weights() {
                    continue;
                }
                let ti = t.t_idx();
                let (uc, ac) = if l == 1 {
                    let u = tensor_elems(layer, &spatial_tile, t);
                    let a = fanout * tensor_elems(layer, &tile0, t);
                    (u, a)
                } else {
                    let e = first.tensor_tile_elems(layer, l - 1, t);
                    (e, e)
                };
                unique[l][ti] = uc;
                aggregate[l][ti] = ac;
                served[l][ti] = if l == 1 && !acc.noc.multicast { ac } else { uc };
            }
        }

        let compute_cycles: u64 = first.temporal.iter().flatten().product();
        let noc_avg_hops = (first.spatial_x_used() + first.spatial_y_used()) as f64 / 2.0;

        for m in members {
            debug_assert!(m.validate(layer, acc).is_ok());
            debug_assert_eq!(m.temporal, first.temporal);
            debug_assert_eq!(m.spatial_x, first.spatial_x);
            debug_assert_eq!(m.spatial_y, first.spatial_y);

            let mut words = [0u64; MAX_BOUND_LEVELS];
            words[0] = words0;
            let mut noc_words: u64 = 0;
            for l in 1..n_levels {
                let loops = loop_list_above(layer, m, l);
                for t in Tensor::ALL {
                    if t == Tensor::Weight && !layer.op.uses_weights() {
                        continue;
                    }
                    let ti = t.t_idx();
                    let mask = &relevance[ti];
                    match t {
                        Tensor::Weight | Tensor::Input => {
                            let rounds = fetch_rounds_masked(mask, &loops);
                            words[l] += rounds * served[l][ti];
                            words[l - 1] += rounds * aggregate[l][ti];
                            if l == 1 {
                                noc_words += rounds * served[l][ti];
                            }
                        }
                        Tensor::Output => {
                            let v = fetch_rounds_masked(mask, &loops);
                            let u = distinct_tiles_masked(mask, &loops);
                            debug_assert!(v >= u);
                            words[l] += v * unique[l][ti] + (v - u) * unique[l][ti];
                            words[l - 1] +=
                                v * aggregate[l][ti] + (v - u) * aggregate[l][ti];
                            if l == 1 {
                                noc_words += v * unique[l][ti] + (v - u) * unique[l][ti];
                                noc_words += v * (aggregate[l][ti] - unique[l][ti]);
                            }
                        }
                    }
                }
            }

            let mut latency = compute_cycles;
            for (l, &w) in words.iter().enumerate().take(n_levels) {
                let instances = if acc.levels[l].per_pe { fanout.max(1) } else { 1 };
                let bw = acc.levels[l].bandwidth_words_per_cycle.max(f64::MIN_POSITIVE)
                    * instances as f64;
                latency = latency.max((w as f64 / bw).ceil() as u64);
            }

            let mut energy = 0.0f64;
            for (l, &w) in words.iter().enumerate().take(n_levels) {
                energy += w as f64 * ert.level(l);
            }
            energy += noc_words as f64 * ert.noc_hop_pj * noc_avg_hops;
            energy += macs as f64 * ert.mac_pj;
            out.push((energy, latency));
        }
    }
}

/// Most storage levels any supported accelerator carries (bound scratch is
/// stack-allocated at this size).
const MAX_BOUND_LEVELS: usize = 8;

impl EvalContext {
    /// Permutation-independent **lower bound** on `(total energy pJ,
    /// roofline latency cycles)` over every per-level loop permutation of
    /// `mapping`'s tiling — the bound the pruner falls back to for sources
    /// whose block members carry arbitrary permutations
    /// ([`crate::mappers::engine::CandidateSource::rotation_members`] =
    /// `false`; rotation-member blocks get the far tighter
    /// [`EvalContext::block_bound`]).
    ///
    /// The bound replaces each tensor's fetch rounds at each boundary with
    /// their minimum over all permutations: the stationarity gate cannot
    /// open below the lowest level `L*` holding a relevant non-degenerate
    /// loop, at `L*` only the relevant trips are forced (irrelevant loops
    /// can sit innermost), and above `L*` every trip is forced (it sits
    /// above the first relevant loop whatever the order). Everything else —
    /// per-tensor footprints from the precomputed relevance masks, the
    /// spatial boundary, multicast, the compulsory datapath traffic and
    /// compute cycles — is already permutation-independent and computed
    /// exactly. Word counts are composed with saturating arithmetic and
    /// rolled up in the same order as [`EvalContext::evaluate_into`]
    /// (IEEE rounding is monotone), so the returned pair never exceeds the
    /// real evaluation of **any** member of the tiling's permutation block:
    /// skipping a block whose bound already exceeds the incumbent can
    /// never change a search's argmin (pinned by
    /// `prop_objective_bound_is_a_true_lower_bound` and the pruned-vs-
    /// unpruned sweeps in `rust/tests/property.rs`).
    ///
    /// The mapping need not be valid (invalid candidates may be bounded
    /// before validation); only its level count must match.
    pub fn objective_bound(&self, mapping: &Mapping) -> (f64, u64) {
        let fanout = mapping.spatial_x_used() * mapping.spatial_y_used();
        self.bound_impl(mapping, fanout)
    }

    /// **Tight** lower bound on `(total energy pJ, latency cycles)` over
    /// the members of `mapping`'s tiling's **rotation block** — the 7
    /// per-level-rotated permutations that [`crate::mappers::engine`]
    /// sources with rotation members actually emit.
    ///
    /// Where [`EvalContext::objective_bound`] must hold for *every* loop
    /// permutation (and therefore collapses each tensor's fetch rounds to
    /// their all-permutation minimum, a bound loose enough that it rarely
    /// exceeds an incumbent in practice), this bound only has to hold for
    /// the 7 rotations a block contains, so it can run the evaluator's
    /// exact word assembly once per rotation and take the element-wise
    /// minimum. On a full assignment the energy leg is *exact*: it equals
    /// the block's cheapest member bit-for-bit (pinned by
    /// `partial_bound_fully_assigned_is_the_rotation_minimum`), which is
    /// what makes bound-based pruning actually engage (see DESIGN.md §13).
    ///
    /// Unsound for arbitrary permutations: a shuffled member interleaving
    /// irrelevant loops differently can score below every rotation, so
    /// sources whose members are not rotations
    /// ([`crate::mappers::engine::CandidateSource::rotation_members`] =
    /// `false`) must keep using [`EvalContext::objective_bound`].
    pub fn block_bound(&self, mapping: &Mapping) -> (f64, u64) {
        let fanout = mapping.spatial_x_used() * mapping.spatial_y_used();
        self.rotation_bound_impl(mapping, fanout)
    }

    /// [`EvalContext::block_bound`] generalized to a **partial** tiling
    /// assignment — the branch-and-bound primitive
    /// ([`crate::mappers::engine::BoundedLattice`]).
    ///
    /// `assigned[d]` marks problem dims whose factor split is already
    /// fixed; every unassigned dim must carry factor 1 in all of
    /// `mapping`'s slots (spatial and temporal — debug builds assert). The
    /// returned pair lower-bounds, per rotation and hence for the
    /// element-wise minimum, every member of the **rotation block** of
    /// every completion of the prefix: completing the assignment only
    /// multiplies extra factors ≥ 1 into trip products and tile extents,
    /// and with the rotation fixed every word-count term of the exact
    /// assembly is monotone non-decreasing under that — a new trip either
    /// joins a fetch-rounds product directly or, by becoming the first
    /// relevant loop of a tensor, additionally un-skips the irrelevant
    /// trips that previously led the nest; for the Output
    /// `2·rounds − distinct` term because the trip scales `rounds` by ≥
    /// its factor and `distinct` by exactly it, with `rounds ≥ distinct`.
    /// The latency leg divides by the level bandwidth × instance count,
    /// which *grows* with fan-out — so for per-PE levels the unknown
    /// completed fan-out is replaced by its upper bound (assigned fan-out
    /// × the full bound of every unassigned dim, capped at the PE count,
    /// which no *valid* completion exceeds). With all dims assigned the
    /// pair equals [`EvalContext::block_bound`] bit-for-bit on valid
    /// mappings — the element-wise minimum over the block's 7 member
    /// evaluations (pinned by `prop_partial_bound_*` in
    /// `rust/tests/property.rs`).
    pub fn partial_bound(&self, mapping: &Mapping, assigned: &[bool; 7]) -> (f64, u64) {
        #[cfg(debug_assertions)]
        for (d, &fixed) in assigned.iter().enumerate() {
            if !fixed {
                debug_assert_eq!(mapping.spatial_x[d], 1);
                debug_assert_eq!(mapping.spatial_y[d], 1);
                debug_assert!(mapping.temporal.iter().all(|t| t[d] == 1));
            }
        }
        let mut fanout_ub = mapping.spatial_x_used() * mapping.spatial_y_used();
        for (d, &fixed) in assigned.iter().enumerate() {
            if !fixed {
                fanout_ub = fanout_ub.saturating_mul(self.layer.bound(Dim::ALL[d]));
            }
        }
        let fanout_ub = fanout_ub.min(self.acc.pe.count()).max(1);
        self.rotation_bound_impl(mapping, fanout_ub)
    }

    /// Shared body of [`EvalContext::block_bound`] and
    /// [`EvalContext::partial_bound`]: the evaluator's exact word assembly
    /// run once per rotation of the canonical dim order (the 7 members a
    /// rotation block contains), reduced to the element-wise minimum.
    /// `latency_fanout` is the per-PE instance count used by the latency
    /// leg (the mapping's own fan-out for the full bound, its completion
    /// upper bound for the partial one). Word counts saturate; the float
    /// roll-up runs in [`EvalContext::evaluate_into`]'s exact order, so on
    /// a full assignment each rotation's energy matches that member's
    /// evaluation bit-for-bit.
    fn rotation_bound_impl(&self, mapping: &Mapping, latency_fanout: u64) -> (f64, u64) {
        let EvalContext { layer, acc, ert, relevance, .. } = self;
        let n_levels = acc.n_levels();
        debug_assert_eq!(mapping.n_levels(), n_levels);
        if n_levels > MAX_BOUND_LEVELS {
            // Deeper hierarchies than the stack scratch covers: return the
            // trivially-valid bound (prunes nothing, stays correct).
            return (0.0, 0);
        }

        let fanout = mapping.spatial_x_used() * mapping.spatial_y_used();
        let tile0 = mapping.tile0();
        let mut spatial_tile = tile0;
        for d in 0..7 {
            spatial_tile[d] *= mapping.spatial_x[d] * mapping.spatial_y[d];
        }

        // Level-0 datapath traffic: exact and permutation-free.
        let macs = layer.macs();
        let mut words0: u64 = 0;
        if layer.op.uses_weights() {
            words0 += macs;
        }
        words0 += macs * layer.op.input_operands();
        if !layer.op.reduction_dims().is_empty() {
            words0 += macs; // accumulator read-back
        }
        words0 += macs; // accumulator write

        // Per-(boundary, tensor) child tile sizes and NoC serving size —
        // tiling-only, hoisted out of the rotation loop (the same
        // quantities `evaluate_many` hoists out of its member loop).
        let mut unique = [[0u64; 3]; MAX_BOUND_LEVELS];
        let mut aggregate = [[0u64; 3]; MAX_BOUND_LEVELS];
        let mut served = [[0u64; 3]; MAX_BOUND_LEVELS];
        for l in 1..n_levels {
            for t in Tensor::ALL {
                if t == Tensor::Weight && !layer.op.uses_weights() {
                    continue;
                }
                let ti = t.t_idx();
                let (uc, ac) = if l == 1 {
                    let u = tensor_elems(layer, &spatial_tile, t);
                    let a = fanout * tensor_elems(layer, &tile0, t);
                    (u, a)
                } else {
                    let e = mapping.tensor_tile_elems(layer, l - 1, t);
                    (e, e)
                };
                unique[l][ti] = uc;
                aggregate[l][ti] = ac;
                served[l][ti] = if l == 1 && !acc.noc.multicast { ac } else { uc };
            }
        }

        let compute_cycles: u64 = mapping.temporal.iter().flatten().product();
        let noc_avg_hops = (mapping.spatial_x_used() + mapping.spatial_y_used()) as f64 / 2.0;

        let mut e_min = f64::INFINITY;
        let mut l_min = u64::MAX;
        for rot in 0..7usize {
            // The rotated nest, levels ascending, non-degenerate trips
            // only — exactly `loop_list_above(_, member_rot, l)` as slices
            // of one flat array.
            let mut flat = [(Dim::N, 1u64); 7 * MAX_BOUND_LEVELS];
            let mut offset = [0usize; MAX_BOUND_LEVELS + 1];
            let mut len = 0usize;
            for l in 0..n_levels {
                offset[l] = len;
                for k in 0..7 {
                    let d = Dim::ALL[(k + rot) % 7];
                    let trip = mapping.temporal[l][d.idx()];
                    if trip > 1 {
                        flat[len] = (d, trip);
                        len += 1;
                    }
                }
            }
            offset[n_levels] = len;

            let mut words = [0u64; MAX_BOUND_LEVELS];
            words[0] = words0;
            let mut noc_words: u64 = 0;
            for l in 1..n_levels {
                let loops = &flat[offset[l]..len];
                for t in Tensor::ALL {
                    if t == Tensor::Weight && !layer.op.uses_weights() {
                        continue;
                    }
                    let ti = t.t_idx();
                    let mask = &relevance[ti];
                    match t {
                        Tensor::Weight | Tensor::Input => {
                            let rounds = fetch_rounds_masked(mask, loops);
                            words[l] =
                                words[l].saturating_add(rounds.saturating_mul(served[l][ti]));
                            words[l - 1] = words[l - 1]
                                .saturating_add(rounds.saturating_mul(aggregate[l][ti]));
                            if l == 1 {
                                noc_words = noc_words
                                    .saturating_add(rounds.saturating_mul(served[l][ti]));
                            }
                        }
                        Tensor::Output => {
                            let v = fetch_rounds_masked(mask, loops);
                            let u = distinct_tiles_masked(mask, loops);
                            debug_assert!(v >= u);
                            let extra = v - u;
                            words[l] = words[l]
                                .saturating_add(v.saturating_mul(unique[l][ti]))
                                .saturating_add(extra.saturating_mul(unique[l][ti]));
                            words[l - 1] = words[l - 1]
                                .saturating_add(v.saturating_mul(aggregate[l][ti]))
                                .saturating_add(extra.saturating_mul(aggregate[l][ti]));
                            if l == 1 {
                                noc_words = noc_words
                                    .saturating_add(v.saturating_mul(unique[l][ti]))
                                    .saturating_add(extra.saturating_mul(unique[l][ti]))
                                    .saturating_add(
                                        v.saturating_mul(aggregate[l][ti] - unique[l][ti]),
                                    );
                            }
                        }
                    }
                }
            }

            let mut latency = compute_cycles;
            for (l, &w) in words.iter().enumerate().take(n_levels) {
                let instances = if acc.levels[l].per_pe { latency_fanout.max(1) } else { 1 };
                let bw = acc.levels[l].bandwidth_words_per_cycle.max(f64::MIN_POSITIVE)
                    * instances as f64;
                latency = latency.max((w as f64 / bw).ceil() as u64);
            }

            let mut energy = 0.0f64;
            for (l, &w) in words.iter().enumerate().take(n_levels) {
                energy += w as f64 * ert.level(l);
            }
            energy += noc_words as f64 * ert.noc_hop_pj * noc_avg_hops;
            energy += macs as f64 * ert.mac_pj;

            e_min = e_min.min(energy);
            l_min = l_min.min(latency);
        }
        (e_min, l_min)
    }

    /// Body of [`EvalContext::objective_bound`] — the all-permutation
    /// relaxation. `latency_fanout` is the per-PE instance count used by
    /// the latency leg; every other quantity is read from `mapping`
    /// directly.
    fn bound_impl(&self, mapping: &Mapping, latency_fanout: u64) -> (f64, u64) {
        let EvalContext { layer, acc, ert, relevance, .. } = self;
        let n_levels = acc.n_levels();
        debug_assert_eq!(mapping.n_levels(), n_levels);
        if n_levels > MAX_BOUND_LEVELS {
            // Deeper hierarchies than the stack scratch covers: return the
            // trivially-valid bound (prunes nothing, stays correct).
            return (0.0, 0);
        }
        let mut words = [0u64; MAX_BOUND_LEVELS];

        let fanout = mapping.spatial_x_used() * mapping.spatial_y_used();
        let tile0 = mapping.tile0();
        let mut spatial_tile = tile0;
        for d in 0..7 {
            spatial_tile[d] *= mapping.spatial_x[d] * mapping.spatial_y[d];
        }

        // Level-0 datapath traffic: exact and mapping-order-free.
        let macs = layer.macs();
        if layer.op.uses_weights() {
            words[0] += macs;
        }
        words[0] += macs * layer.op.input_operands();
        if !layer.op.reduction_dims().is_empty() {
            words[0] += macs; // accumulator read-back
        }
        words[0] += macs; // accumulator write

        // Per-level trip products: `rel[l][t]` over the t-relevant dims,
        // `all[l]` over every dim.
        let mut rel = [[1u64; 3]; MAX_BOUND_LEVELS];
        let mut all = [1u64; MAX_BOUND_LEVELS];
        for l in 0..n_levels {
            for d in 0..7 {
                let f = mapping.temporal[l][d];
                all[l] = all[l].saturating_mul(f);
                for (t, mask) in relevance.iter().enumerate() {
                    if mask[d] {
                        rel[l][t] = rel[l][t].saturating_mul(f);
                    }
                }
            }
        }
        // Minimum fetch rounds of tensor `t` above boundary `l`.
        let rounds_min = |t: usize, l: usize| -> u64 {
            let Some(lstar) = (l..n_levels).find(|&lev| rel[lev][t] > 1) else {
                return 1;
            };
            let mut r = rel[lstar][t];
            for lev in lstar + 1..n_levels {
                r = r.saturating_mul(all[lev]);
            }
            r
        };
        // Distinct child tiles of `t` above boundary `l` (exact).
        let distinct = |t: usize, l: usize| -> u64 {
            (l..n_levels).fold(1u64, |u, lev| u.saturating_mul(rel[lev][t]))
        };

        let mut noc_words: u64 = 0;
        for l in 1..n_levels {
            for t in Tensor::ALL {
                if t == Tensor::Weight && !layer.op.uses_weights() {
                    continue;
                }
                let ti = t.t_idx();
                let (unique_child, aggregate_child) = if l == 1 {
                    let unique = tensor_elems(layer, &spatial_tile, t);
                    let aggregate = fanout * tensor_elems(layer, &tile0, t);
                    (unique, aggregate)
                } else {
                    let e = mapping.tensor_tile_elems(layer, l - 1, t);
                    (e, e)
                };
                match t {
                    Tensor::Weight | Tensor::Input => {
                        let rounds = rounds_min(ti, l);
                        let served = if l == 1 && !acc.noc.multicast {
                            aggregate_child
                        } else {
                            unique_child
                        };
                        words[l] = words[l].saturating_add(rounds.saturating_mul(served));
                        words[l - 1] =
                            words[l - 1].saturating_add(rounds.saturating_mul(aggregate_child));
                        if l == 1 {
                            noc_words = noc_words.saturating_add(rounds.saturating_mul(served));
                        }
                    }
                    Tensor::Output => {
                        let v = rounds_min(ti, l);
                        let u = distinct(ti, l);
                        debug_assert!(v >= u);
                        let extra = v - u;
                        words[l] = words[l]
                            .saturating_add(v.saturating_mul(unique_child))
                            .saturating_add(extra.saturating_mul(unique_child));
                        words[l - 1] = words[l - 1]
                            .saturating_add(v.saturating_mul(aggregate_child))
                            .saturating_add(extra.saturating_mul(aggregate_child));
                        if l == 1 {
                            noc_words = noc_words
                                .saturating_add(v.saturating_mul(unique_child))
                                .saturating_add(extra.saturating_mul(unique_child))
                                .saturating_add(
                                    v.saturating_mul(aggregate_child - unique_child),
                                );
                        }
                    }
                }
            }
        }

        // Latency lower bound: exact compute roofline vs bandwidth over
        // the lower-bound word counts (same instance model as the
        // evaluator).
        let compute_cycles: u64 = mapping.temporal.iter().flatten().product();
        let mut latency = compute_cycles;
        for l in 0..n_levels {
            let instances = if acc.levels[l].per_pe { latency_fanout.max(1) } else { 1 };
            let bw = acc.levels[l].bandwidth_words_per_cycle.max(f64::MIN_POSITIVE)
                * instances as f64;
            latency = latency.max((words[l] as f64 / bw).ceil() as u64);
        }

        // Energy roll-up in the evaluator's summation order (levels
        // ascending, then NoC, then MAC) so float monotonicity carries
        // over to the total.
        let mut energy = 0.0f64;
        for (l, &w) in words.iter().enumerate().take(n_levels) {
            energy += w as f64 * ert.level(l);
        }
        let noc_avg_hops = (mapping.spatial_x_used() + mapping.spatial_y_used()) as f64 / 2.0;
        energy += noc_words as f64 * ert.noc_hop_pj * noc_avg_hops;
        energy += macs as f64 * ert.mac_pj;
        (energy, latency)
    }
}

/// Mask-based [`super::nest::fetch_rounds`]: identical integer arithmetic,
/// with the per-loop relevance test replaced by a precomputed table lookup.
fn fetch_rounds_masked(mask: &[bool; 7], loops: &[LoopIter]) -> u64 {
    let mut rounds = 1u64;
    let mut seen_relevant = false;
    for &(d, trip) in loops {
        if !seen_relevant {
            if mask[d.idx()] {
                seen_relevant = true;
            } else {
                continue; // stationary across this loop
            }
        }
        rounds = rounds.saturating_mul(trip);
    }
    rounds
}

/// Mask-based [`super::nest::distinct_tiles`].
fn distinct_tiles_masked(mask: &[bool; 7], loops: &[LoopIter]) -> u64 {
    loops
        .iter()
        .filter(|&&(d, _)| mask[d.idx()])
        .map(|&(_, trip)| trip)
        .product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mapspace::sample_random;
    use crate::model::evaluate_unchecked;
    use crate::util::rng::SplitMix64;
    use crate::workload::zoo;

    #[test]
    fn context_matches_legacy_on_zoo_layer() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let mut ctx = EvalContext::new(&layer, &acc);
        let mut rng = SplitMix64::new(11);
        for _ in 0..25 {
            let m = sample_random(&layer, &acc, &mut rng);
            let legacy = evaluate_unchecked(&layer, &acc, &m);
            let fast = ctx.evaluate_into(&m);
            assert_eq!(&legacy, fast);
        }
    }

    #[test]
    fn context_matches_legacy_on_depthwise() {
        // Depthwise relevance (Input follows M) must be baked into the mask.
        let acc = presets::eyeriss();
        let layer = zoo::mobilenet_v2().into_iter().find(|l| l.is_depthwise()).unwrap();
        let mut ctx = EvalContext::new(&layer, &acc);
        let mut rng = SplitMix64::new(13);
        for _ in 0..25 {
            let m = sample_random(&layer, &acc, &mut rng);
            assert_eq!(&evaluate_unchecked(&layer, &acc, &m), ctx.evaluate_into(&m));
        }
    }

    #[test]
    fn context_matches_legacy_on_every_op_kind() {
        // The op-aware masks and weight gating must agree with the legacy
        // evaluator on every operator projection, not just conv.
        let acc = presets::eyeriss();
        let mut rng = SplitMix64::new(19);
        for layer in [
            Layer::matmul("mm", 96, 64, 56),
            Layer::pooling("pool", 64, 2, 28, 28).with_stride(2),
            Layer::elementwise("add", 96, 28, 28),
        ] {
            let mut ctx = EvalContext::new(&layer, &acc);
            for _ in 0..15 {
                let m = sample_random(&layer, &acc, &mut rng);
                assert_eq!(
                    &evaluate_unchecked(&layer, &acc, &m),
                    ctx.evaluate_into(&m),
                    "{}",
                    layer.name
                );
            }
        }
    }

    #[test]
    fn dram_tensor_words_reads_the_last_level_row() {
        // The accessor is pure bookkeeping over the existing access table:
        // it must equal the DRAM row of a full evaluation, and every
        // tensor's DRAM traffic is at least its compulsory volume.
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let mut ctx = EvalContext::new(&layer, &acc);
        let mut rng = SplitMix64::new(23);
        let m = sample_random(&layer, &acc, &mut rng);
        let dram = acc.n_levels() - 1;
        for t in Tensor::ALL {
            let a = ctx.evaluate_into(&m).access[dram][t.t_idx()];
            assert_eq!(ctx.dram_tensor_words(&m, t), a.reads + a.writes);
        }
        assert!(ctx.dram_tensor_words(&m, Tensor::Output) >= layer.tensor_volume(Tensor::Output));
    }

    #[test]
    fn context_is_reusable_across_candidates() {
        // Stale scratch state from one candidate must not leak into the next:
        // evaluate A, then B, then A again — the two A evaluations agree.
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[0].clone();
        let mut rng = SplitMix64::new(17);
        let a = sample_random(&layer, &acc, &mut rng);
        let b = sample_random(&layer, &acc, &mut rng);
        let mut ctx = EvalContext::new(&layer, &acc);
        let first = ctx.evaluate_into(&a).clone();
        let _ = ctx.evaluate_into(&b);
        assert_eq!(first, *ctx.evaluate_into(&a));
    }

    #[test]
    fn evaluate_rejects_invalid() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let mut m = Mapping::trivial(&layer, acc.n_levels());
        m.temporal[2][0] = 999;
        let mut ctx = EvalContext::new(&layer, &acc);
        assert!(ctx.evaluate(&m).is_err());
    }

    #[test]
    fn evaluate_many_matches_per_member_path() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[2].clone();
        let mut ctx = EvalContext::new(&layer, &acc);
        let mut rng = SplitMix64::new(23);
        let base = sample_random(&layer, &acc, &mut rng);
        // One permutation block: the tiling of `base` under the odometer
        // member rotations (rotations are valid permutations, so all pass).
        let mut members = Vec::new();
        for i in 0..7usize {
            let mut m = base.clone();
            let mut p = Dim::ALL;
            p.rotate_left(i);
            for perm in m.permutation.iter_mut() {
                *perm = p;
            }
            members.push(m);
        }
        let mut out = Vec::new();
        ctx.evaluate_many(&members, &mut out);
        assert_eq!(out.len(), members.len());
        for (m, &(e, lat)) in members.iter().zip(&out) {
            let ev = ctx.evaluate_into(m);
            assert_eq!(e.to_bits(), ev.energy.total_pj().to_bits());
            assert_eq!(lat, ev.latency_cycles);
        }
    }

    #[test]
    fn partial_bound_fully_assigned_is_the_rotation_minimum() {
        // On a full assignment the tight bound is exact: it equals the
        // element-wise minimum over the tiling's 7 rotation members'
        // evaluations bit-for-bit, agrees with `block_bound`, and never
        // drops below the conservative all-permutation bound.
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[4].clone();
        let mut ctx = EvalContext::new(&layer, &acc);
        let mut rng = SplitMix64::new(29);
        for _ in 0..20 {
            let m = sample_random(&layer, &acc, &mut rng);
            let (pe, pl) = ctx.partial_bound(&m, &[true; 7]);
            let (ke, kl) = ctx.block_bound(&m);
            assert_eq!(ke.to_bits(), pe.to_bits());
            assert_eq!(kl, pl);
            let mut e_min = f64::INFINITY;
            let mut l_min = u64::MAX;
            for rot in 0..7usize {
                let mut member = m.clone();
                let mut p = Dim::ALL;
                p.rotate_left(rot);
                for perm in member.permutation.iter_mut() {
                    *perm = p;
                }
                let e = ctx.evaluate_into(&member);
                e_min = e_min.min(e.energy.total_pj());
                l_min = l_min.min(e.latency_cycles);
            }
            assert_eq!(pe.to_bits(), e_min.to_bits());
            assert_eq!(pl, l_min);
            let (oe, ol) = ctx.objective_bound(&m);
            assert!(oe <= pe, "all-permutation bound above the rotation minimum");
            assert!(ol <= pl);
        }
    }

    #[test]
    fn accessors_expose_the_pair() {
        let acc = presets::shidiannao();
        let layer = zoo::alexnet()[0].clone();
        let ctx = EvalContext::new(&layer, &acc);
        assert_eq!(ctx.layer().name, layer.name);
        assert_eq!(ctx.acc().name, acc.name);
    }
}
