//! The reusable, zero-allocation evaluation engine.
//!
//! [`evaluate_unchecked`] is the inner loop of every search mapper, but it
//! heap-allocates on every call: the access table, the bandwidth vector,
//! the [`Ert`] (rebuilt from the accelerator geometry each time) and the
//! returned [`Evaluation`] all hit the allocator per candidate. Search
//! mappers evaluate the *same* (layer, accelerator) pair thousands to
//! millions of times, so everything that depends only on that pair can be
//! hoisted out of the loop.
//!
//! [`EvalContext`] does exactly that: it precomputes the energy reference
//! table, the per-tensor dimension-relevance masks (operator-aware — built
//! from the layer's [`crate::workload::OpKind`] projection, e.g. depthwise
//! Input follows `M`, matmul drops `R`/`S`), and owns a scratch [`Evaluation`]
//! whose vectors are sized once at construction. The hot path,
//! [`EvalContext::evaluate_into`], overwrites the scratch in place and
//! returns a borrow — **zero heap allocations per candidate** (the loop
//! list is a fixed-capacity stack array, tile math is `[u64; 7]` arrays).
//!
//! Results are bit-identical to the legacy [`evaluate_unchecked`] path:
//! the floating-point operations run in the same order on the same
//! precomputed values (pinned by `prop_eval_context_bit_identical_to_legacy`
//! in `rust/tests/property.rs`).
//!
//! [`evaluate_unchecked`]: super::evaluate_unchecked

use super::nest::{loop_list_above, LoopIter};
use super::{Access, Evaluation, TensorIdx};
use crate::arch::Accelerator;
use crate::energy::{EnergyBreakdown, Ert};
use crate::mapping::{tensor_elems, Mapping, MappingError};
use crate::workload::{ConvLayer, Dim, Tensor};

/// Precomputed per-(layer, accelerator) evaluation state with reusable
/// scratch buffers. Construct once per search, call
/// [`EvalContext::evaluate_into`] per candidate.
#[derive(Debug, Clone)]
pub struct EvalContext {
    layer: ConvLayer,
    acc: Accelerator,
    ert: Ert,
    /// `relevance[tensor_idx][dim_idx]` — layer-aware tensor/dim relevance.
    relevance: [[bool; 7]; 3],
    scratch: Evaluation,
}

impl EvalContext {
    /// Precompute the ERT, relevance masks and scratch buffers for one
    /// (layer, accelerator) pair. This is the only allocating step; every
    /// subsequent [`EvalContext::evaluate_into`] call is allocation-free.
    pub fn new(layer: &ConvLayer, acc: &Accelerator) -> Self {
        let n_levels = acc.n_levels();
        let mut relevance = [[false; 7]; 3];
        for t in Tensor::ALL {
            for d in Dim::ALL {
                relevance[t.t_idx()][d.idx()] = t.relevant_for(layer, d);
            }
        }
        let scratch = Evaluation {
            access: vec![[Access::default(); 3]; n_levels],
            noc_words: 0,
            noc_avg_hops: 0.0,
            macs: 0,
            active_pes: 0,
            utilization: 0.0,
            compute_cycles: 0,
            bandwidth_cycles: vec![0; n_levels],
            latency_cycles: 0,
            energy: EnergyBreakdown::zero(n_levels),
        };
        Self {
            layer: layer.clone(),
            acc: acc.clone(),
            ert: Ert::for_accelerator(acc),
            relevance,
            scratch,
        }
    }

    /// The layer this context evaluates against.
    pub fn layer(&self) -> &ConvLayer {
        &self.layer
    }

    /// The accelerator this context evaluates against.
    pub fn acc(&self) -> &Accelerator {
        &self.acc
    }

    /// Validate-then-evaluate convenience (mirrors [`super::evaluate`]).
    pub fn evaluate(&mut self, mapping: &Mapping) -> Result<&Evaluation, MappingError> {
        mapping.validate(&self.layer, &self.acc)?;
        Ok(self.evaluate_into(mapping))
    }

    /// Hot-path accessor: total energy (pJ) of one candidate. What the
    /// search mappers rank by.
    pub fn energy_pj(&mut self, mapping: &Mapping) -> f64 {
        self.evaluate_into(mapping).energy.total_pj()
    }

    /// Evaluate one candidate into the scratch buffers and return a borrow.
    /// Performs **no heap allocation**: the access table, bandwidth vector
    /// and energy breakdown are overwritten in place, the loop list above
    /// each boundary is a fixed-capacity stack array, and all tile math is
    /// `[u64; 7]` stack arrays. Clone the returned `Evaluation` only when a
    /// candidate is kept (once per improvement, not once per candidate).
    ///
    /// The mapping must be valid for this context's (layer, accelerator)
    /// pair (debug builds assert); the arithmetic is identical to
    /// [`super::evaluate_unchecked`], operation for operation.
    pub fn evaluate_into(&mut self, mapping: &Mapping) -> &Evaluation {
        let EvalContext { layer, acc, ert, relevance, scratch } = self;
        debug_assert!(mapping.validate(layer, acc).is_ok());
        let n_levels = acc.n_levels();
        debug_assert_eq!(mapping.n_levels(), n_levels);

        for row in scratch.access.iter_mut() {
            *row = [Access::default(); 3];
        }

        let fanout = mapping.spatial_x_used() * mapping.spatial_y_used();

        // Spatial tile: per-PE tile ⊗ spatial factors (unique data across
        // the whole PE array).
        let tile0 = mapping.tile0();
        let mut spatial_tile = tile0;
        for d in 0..7 {
            spatial_tile[d] *= mapping.spatial_x[d] * mapping.spatial_y[d];
        }

        // --- Level-0 (RF) datapath traffic (weight-less ops skip W;
        // elementwise adds read both summands).
        let macs = layer.macs();
        if layer.op.uses_weights() {
            scratch.access[0][Tensor::Weight.t_idx()].reads += macs;
        }
        scratch.access[0][Tensor::Input.t_idx()].reads += macs * layer.op.input_operands();
        if !layer.op.reduction_dims().is_empty() {
            // Accumulation: each op read-modify-writes a partial sum. Ops
            // with no reduction dims (elementwise add) write each output
            // exactly once and never read it back.
            scratch.access[0][Tensor::Output.t_idx()].reads += macs; // accumulator read
        }
        scratch.access[0][Tensor::Output.t_idx()].writes += macs; // accumulator write

        let mut noc_words: u64 = 0;

        // --- Boundaries (see `super::evaluate_unchecked` for the model).
        for l in 1..n_levels {
            let loops = loop_list_above(layer, mapping, l);
            for t in Tensor::ALL {
                if t == Tensor::Weight && !layer.op.uses_weights() {
                    continue; // no weight tensor: zero elements at every level
                }
                let ti = t.t_idx();
                let mask = &relevance[ti];
                let (unique_child, aggregate_child) = if l == 1 {
                    let unique = tensor_elems(layer, &spatial_tile, t);
                    let aggregate = fanout * tensor_elems(layer, &tile0, t);
                    (unique, aggregate)
                } else {
                    let e = mapping.tensor_tile_elems(layer, l - 1, t);
                    (e, e)
                };
                match t {
                    Tensor::Weight | Tensor::Input => {
                        let rounds = fetch_rounds_masked(mask, &loops);
                        let served = if l == 1 && !acc.noc.multicast {
                            aggregate_child
                        } else {
                            unique_child
                        };
                        scratch.access[l][ti].reads += rounds * served;
                        scratch.access[l - 1][ti].writes += rounds * aggregate_child;
                        if l == 1 {
                            noc_words += rounds * served;
                        }
                    }
                    Tensor::Output => {
                        let v = fetch_rounds_masked(mask, &loops);
                        let u = distinct_tiles_masked(mask, &loops);
                        debug_assert!(v >= u);
                        scratch.access[l][ti].writes += v * unique_child;
                        scratch.access[l][ti].reads += (v - u) * unique_child;
                        scratch.access[l - 1][ti].reads += v * aggregate_child;
                        scratch.access[l - 1][ti].writes += (v - u) * aggregate_child;
                        if l == 1 {
                            noc_words += v * unique_child + (v - u) * unique_child;
                            noc_words += v * (aggregate_child - unique_child);
                        }
                    }
                }
            }
        }

        // --- Latency roofline (same instance/bandwidth model as legacy).
        let compute_cycles: u64 = mapping.temporal.iter().flatten().product();
        for l in 0..n_levels {
            let words: u64 = (0..3).map(|ti| scratch.access[l][ti].total()).sum();
            let instances = if acc.levels[l].per_pe { fanout.max(1) } else { 1 };
            let bw = acc.levels[l].bandwidth_words_per_cycle.max(f64::MIN_POSITIVE)
                * instances as f64;
            scratch.bandwidth_cycles[l] = (words as f64 / bw).ceil() as u64;
        }
        let latency_cycles =
            compute_cycles.max(scratch.bandwidth_cycles.iter().copied().max().unwrap_or(0));

        // --- Energy roll-up from the precomputed ERT.
        for l in 0..n_levels {
            let words: u64 = (0..3).map(|ti| scratch.access[l][ti].total()).sum();
            scratch.energy.level_pj[l] = words as f64 * ert.level(l);
        }
        let noc_avg_hops = (mapping.spatial_x_used() + mapping.spatial_y_used()) as f64 / 2.0;
        scratch.energy.noc_pj = noc_words as f64 * ert.noc_hop_pj * noc_avg_hops;
        scratch.energy.mac_pj = macs as f64 * ert.mac_pj;

        scratch.noc_words = noc_words;
        scratch.noc_avg_hops = noc_avg_hops;
        scratch.macs = macs;
        scratch.active_pes = fanout;
        scratch.utilization = mapping.pe_utilization(acc);
        scratch.compute_cycles = compute_cycles;
        scratch.latency_cycles = latency_cycles;
        scratch
    }
}

/// Mask-based [`super::nest::fetch_rounds`]: identical integer arithmetic,
/// with the per-loop relevance test replaced by a precomputed table lookup.
fn fetch_rounds_masked(mask: &[bool; 7], loops: &[LoopIter]) -> u64 {
    let mut rounds = 1u64;
    let mut seen_relevant = false;
    for &(d, trip) in loops {
        if !seen_relevant {
            if mask[d.idx()] {
                seen_relevant = true;
            } else {
                continue; // stationary across this loop
            }
        }
        rounds = rounds.saturating_mul(trip);
    }
    rounds
}

/// Mask-based [`super::nest::distinct_tiles`].
fn distinct_tiles_masked(mask: &[bool; 7], loops: &[LoopIter]) -> u64 {
    loops
        .iter()
        .filter(|&&(d, _)| mask[d.idx()])
        .map(|&(_, trip)| trip)
        .product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mapspace::sample_random;
    use crate::model::evaluate_unchecked;
    use crate::util::rng::SplitMix64;
    use crate::workload::zoo;

    #[test]
    fn context_matches_legacy_on_zoo_layer() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let mut ctx = EvalContext::new(&layer, &acc);
        let mut rng = SplitMix64::new(11);
        for _ in 0..25 {
            let m = sample_random(&layer, &acc, &mut rng);
            let legacy = evaluate_unchecked(&layer, &acc, &m);
            let fast = ctx.evaluate_into(&m);
            assert_eq!(&legacy, fast);
        }
    }

    #[test]
    fn context_matches_legacy_on_depthwise() {
        // Depthwise relevance (Input follows M) must be baked into the mask.
        let acc = presets::eyeriss();
        let layer = zoo::mobilenet_v2().into_iter().find(|l| l.is_depthwise()).unwrap();
        let mut ctx = EvalContext::new(&layer, &acc);
        let mut rng = SplitMix64::new(13);
        for _ in 0..25 {
            let m = sample_random(&layer, &acc, &mut rng);
            assert_eq!(&evaluate_unchecked(&layer, &acc, &m), ctx.evaluate_into(&m));
        }
    }

    #[test]
    fn context_matches_legacy_on_every_op_kind() {
        // The op-aware masks and weight gating must agree with the legacy
        // evaluator on every operator projection, not just conv.
        let acc = presets::eyeriss();
        let mut rng = SplitMix64::new(19);
        for layer in [
            ConvLayer::matmul("mm", 96, 64, 56),
            ConvLayer::pooling("pool", 64, 2, 28, 28).with_stride(2),
            ConvLayer::elementwise("add", 96, 28, 28),
        ] {
            let mut ctx = EvalContext::new(&layer, &acc);
            for _ in 0..15 {
                let m = sample_random(&layer, &acc, &mut rng);
                assert_eq!(
                    &evaluate_unchecked(&layer, &acc, &m),
                    ctx.evaluate_into(&m),
                    "{}",
                    layer.name
                );
            }
        }
    }

    #[test]
    fn context_is_reusable_across_candidates() {
        // Stale scratch state from one candidate must not leak into the next:
        // evaluate A, then B, then A again — the two A evaluations agree.
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[0].clone();
        let mut rng = SplitMix64::new(17);
        let a = sample_random(&layer, &acc, &mut rng);
        let b = sample_random(&layer, &acc, &mut rng);
        let mut ctx = EvalContext::new(&layer, &acc);
        let first = ctx.evaluate_into(&a).clone();
        let _ = ctx.evaluate_into(&b);
        assert_eq!(first, *ctx.evaluate_into(&a));
    }

    #[test]
    fn evaluate_rejects_invalid() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let mut m = Mapping::trivial(&layer, acc.n_levels());
        m.temporal[2][0] = 999;
        let mut ctx = EvalContext::new(&layer, &acc);
        assert!(ctx.evaluate(&m).is_err());
    }

    #[test]
    fn accessors_expose_the_pair() {
        let acc = presets::shidiannao();
        let layer = zoo::alexnet()[0].clone();
        let ctx = EvalContext::new(&layer, &acc);
        assert_eq!(ctx.layer().name, layer.name);
        assert_eq!(ctx.acc().name, acc.name);
    }
}
