//! The Timeloop-lite analytical engine.
//!
//! Given a validated [`Mapping`] of a [`Layer`] onto an
//! [`Accelerator`], this module computes per-level per-tensor access
//! counts, NoC traffic, PE utilization (paper Eq. 25), a roofline latency,
//! and — through [`crate::energy`] — the per-component energy breakdown the
//! paper's Fig. 3/7 report. The model is operator-generic: tensor/dim
//! relevance and tile element counts come from the layer's
//! [`crate::workload::OpKind`] projection, so matmul, pooling and
//! elementwise layers ride the same engine (weight-less ops simply carry
//! zero weight traffic; elementwise adds read two operands per result).
//!
//! # Reuse model
//!
//! We use the classic permutation-aware stationarity model (Timeloop's
//! default read model without bypass):
//!
//! The tile of tensor `T` held at level `l-1` is refetched from level `l`
//! each time any loop *relevant to `T`* above it iterates. The contiguous
//! run of `T`-irrelevant loops immediately above the tile keeps it
//! **stationary** (no refetch); every loop above the first relevant loop —
//! relevant or not — multiplies the fetch count (degenerate trip-1 loops
//! are transparent).
//!
//! Outputs are read-modify-write: with `V` total tile visits (counted by
//! the same rule, relevance = {N,M,P,Q}) and `U` distinct output tiles
//! (product of relevant trips only), the level receives `V` tile-writes and
//! serves `V − U` partial-sum read-backs (the first visit of each distinct
//! tile initializes instead of reading).
//!
//! # Spatial boundary
//!
//! Spatial (PE-array) loops sit between L1 and the per-PE L0. With a
//! multicast NoC, L1 reads only the *unique* words across the array
//! (`tensor_elems` over tile0 ⊗ spatial factors — halo sharing included);
//! each PE still fills its own L0 copy. Spatially-reduced outputs
//! (reduction dim mapped spatially) contribute `aggregate − unique` extra
//! NoC words for the inter-PE psum tree.

pub mod context;
pub mod nest;

use crate::arch::Accelerator;
use crate::energy::{EnergyBreakdown, Ert};
use crate::mapping::{tensor_elems, Mapping, MappingError};
use crate::workload::{Layer, Tensor};

pub use context::EvalContext;
pub use nest::{distinct_tiles, fetch_rounds, loop_list_above, LoopIter, LoopList};

/// Per-level access counts for one tensor, in words (data elements).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Access {
    /// Words read out of this level (serving the level below / datapath).
    pub reads: u64,
    /// Words written into this level (fills and partial-sum updates).
    pub writes: u64,
}

impl Access {
    /// Reads + writes.
    pub fn total(&self) -> u64 {
        self.reads + self.writes
    }
}

/// Full analytical evaluation of one mapping.
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluation {
    /// `access[level][tensor_idx]` — words, aligned with
    /// `Accelerator::levels` and `Tensor::ALL` ordering (W, I, O).
    pub access: Vec<[Access; 3]>,
    /// Words crossing the NoC (L1→PE delivery + psum reduction).
    pub noc_words: u64,
    /// Average hop distance used for NoC energy.
    pub noc_avg_hops: f64,
    /// Total MAC operations (== layer.macs()).
    pub macs: u64,
    /// Active PEs (spatial fan-out).
    pub active_pes: u64,
    /// PE utilization, Eq. 25.
    pub utilization: f64,
    /// Per-PE compute cycles (1 MAC/cycle/PE).
    pub compute_cycles: u64,
    /// Bandwidth-bound cycles per level boundary.
    pub bandwidth_cycles: Vec<u64>,
    /// Roofline latency = max(compute, all bandwidth bounds).
    pub latency_cycles: u64,
    /// Energy breakdown (Fig. 7 components).
    pub energy: EnergyBreakdown,
}

impl Evaluation {
    /// Total energy in µJ (Fig. 3 / Fig. 7 axis).
    pub fn energy_uj(&self) -> f64 {
        self.energy.total_uj()
    }

    /// Throughput in MACs/cycle implied by the roofline latency.
    pub fn macs_per_cycle(&self) -> f64 {
        self.macs as f64 / self.latency_cycles.max(1) as f64
    }

    /// Energy-delay product (pJ·cycles) — used by the ablation benches.
    pub fn edp(&self) -> f64 {
        self.energy.total_pj() * self.latency_cycles as f64
    }
}

/// Evaluate a mapping. Validates first; returns the mapping error if the
/// mapping does not fit (callers in search loops rely on this being cheap).
pub fn evaluate(
    layer: &Layer,
    acc: &Accelerator,
    mapping: &Mapping,
) -> Result<Evaluation, MappingError> {
    mapping.validate(layer, acc)?;
    Ok(evaluate_unchecked(layer, acc, mapping))
}

/// Evaluate without re-validating (debug builds still assert validity).
///
/// This is the **legacy, allocating** path: it rebuilds the [`Ert`] and
/// allocates the access/bandwidth/energy vectors on every call. Search
/// loops should use [`EvalContext::evaluate_into`] instead, which hoists
/// the per-(layer, accelerator) work out of the loop and reuses scratch
/// buffers — bit-identical results, zero allocations per candidate. This
/// function is kept as the API-stable one-shot entry point and as the
/// reference implementation the context path is property-tested against.
pub fn evaluate_unchecked(layer: &Layer, acc: &Accelerator, mapping: &Mapping) -> Evaluation {
    debug_assert!(mapping.validate(layer, acc).is_ok());
    let n_levels = acc.n_levels();
    let mut access = vec![[Access::default(); 3]; n_levels];

    let fanout = mapping.spatial_x_used() * mapping.spatial_y_used();

    // Spatial tile: per-PE tile ⊗ spatial factors (unique data across the
    // whole PE array).
    let tile0 = mapping.tile0();
    let mut spatial_tile = tile0;
    for d in 0..7 {
        spatial_tile[d] *= mapping.spatial_x[d] * mapping.spatial_y[d];
    }

    // --- Level-0 (RF) datapath traffic: every op reads its operands
    // (weight-less ops skip W; elementwise adds read both summands) and
    // read-modify-writes the accumulator.
    let macs = layer.macs();
    if layer.op.uses_weights() {
        access[0][Tensor::Weight.t_idx()].reads += macs;
    }
    access[0][Tensor::Input.t_idx()].reads += macs * layer.op.input_operands();
    if !layer.op.reduction_dims().is_empty() {
        // Accumulation: each op read-modify-writes a partial sum. Ops with
        // no reduction dims (elementwise add) write each output exactly
        // once and never read it back.
        access[0][Tensor::Output.t_idx()].reads += macs; // accumulator read
    }
    access[0][Tensor::Output.t_idx()].writes += macs; // accumulator write

    let mut noc_words: u64 = 0;

    // --- Boundaries: parent level l serves child tiles of level l-1,
    // for l in 1..n_levels. Loop list above the child = loops at levels
    // l..top (inner→outer).
    for l in 1..n_levels {
        let loops = loop_list_above(layer, mapping, l);
        for t in Tensor::ALL {
            if t == Tensor::Weight && !layer.op.uses_weights() {
                continue; // no weight tensor: zero elements at every level
            }
            let ti = t.t_idx();
            // Child tile uniqueness at this boundary.
            let (unique_child, aggregate_child) = if l == 1 {
                let unique = tensor_elems(layer, &spatial_tile, t);
                let aggregate = fanout * tensor_elems(layer, &tile0, t);
                (unique, aggregate)
            } else {
                let e = mapping.tensor_tile_elems(layer, l - 1, t);
                (e, e)
            };
            match t {
                Tensor::Weight | Tensor::Input => {
                    let rounds = fetch_rounds(layer, t, &loops);
                    let served = if l == 1 && !acc.noc.multicast {
                        aggregate_child
                    } else {
                        unique_child
                    };
                    // Parent reads what it serves downward.
                    access[l][ti].reads += rounds * served;
                    // Children write their fills (each PE fills its copy at
                    // the spatial boundary).
                    access[l - 1][ti].writes += rounds * aggregate_child;
                    if l == 1 {
                        noc_words += rounds * served;
                    }
                }
                Tensor::Output => {
                    let v = fetch_rounds(layer, t, &loops);
                    let u = distinct_tiles(layer, t, &loops);
                    debug_assert!(v >= u);
                    // Updates flowing up into level l...
                    access[l][ti].writes += v * unique_child;
                    // ...and psum read-backs served to the child.
                    access[l][ti].reads += (v - u) * unique_child;
                    // Child-side reads of the psums it sends up, and fills
                    // of psums it gets back, are the child's own level
                    // traffic:
                    access[l - 1][ti].reads += v * aggregate_child;
                    access[l - 1][ti].writes += (v - u) * aggregate_child;
                    if l == 1 {
                        // Upward psum words + read-backs cross the NoC;
                        // spatial reduction adds the (aggregate − unique)
                        // inter-PE combining traffic.
                        noc_words += v * unique_child + (v - u) * unique_child;
                        noc_words += v * (aggregate_child - unique_child);
                    }
                }
            }
        }
    }

    // --- Latency: compute roofline vs per-boundary bandwidth. Per-PE
    // levels (the RF) are parallel instances: their aggregate traffic is
    // served by `active_pes` multi-ported register files, so the per-level
    // bandwidth scales with the spatial fan-out.
    let compute_cycles: u64 = mapping.temporal.iter().flatten().product();
    let mut bandwidth_cycles = Vec::with_capacity(n_levels);
    for l in 0..n_levels {
        let words: u64 = (0..3).map(|ti| access[l][ti].total()).sum();
        let instances = if acc.levels[l].per_pe { fanout.max(1) } else { 1 };
        let bw = acc.levels[l].bandwidth_words_per_cycle.max(f64::MIN_POSITIVE)
            * instances as f64;
        bandwidth_cycles.push((words as f64 / bw).ceil() as u64);
    }
    let latency_cycles = compute_cycles.max(bandwidth_cycles.iter().copied().max().unwrap_or(0));

    // --- Energy roll-up.
    let ert = Ert::for_accelerator(acc);
    let mut energy = EnergyBreakdown::zero(n_levels);
    for l in 0..n_levels {
        let words: u64 = (0..3).map(|ti| access[l][ti].total()).sum();
        energy.level_pj[l] = words as f64 * ert.level(l);
    }
    // Average Manhattan distance across the active sub-array.
    let noc_avg_hops = (mapping.spatial_x_used() + mapping.spatial_y_used()) as f64 / 2.0;
    energy.noc_pj = noc_words as f64 * ert.noc_hop_pj * noc_avg_hops;
    energy.mac_pj = macs as f64 * ert.mac_pj;

    Evaluation {
        access,
        noc_words,
        noc_avg_hops,
        macs,
        active_pes: fanout,
        utilization: mapping.pe_utilization(acc),
        compute_cycles,
        bandwidth_cycles,
        latency_cycles,
        energy,
    }
}

/// Tensor index into `Evaluation::access` rows.
pub trait TensorIdx {
    /// Dense row index in `Tensor::ALL` (W, I, O) order.
    fn t_idx(self) -> usize;
}

impl TensorIdx for Tensor {
    fn t_idx(self) -> usize {
        match self {
            Tensor::Weight => 0,
            Tensor::Input => 1,
            Tensor::Output => 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::arch::{Accelerator, Noc, PeArray, StorageLevel, Style};
    use crate::mapping::Mapping;
    use crate::workload::{zoo, Dim};

    /// 2-level machine (per-PE RF + DRAM) for hand-checked counts.
    fn tiny_acc() -> Accelerator {
        Accelerator {
            name: "tiny".into(),
            style: Style::EyerissLike,
            datawidth_bits: 16,
            levels: vec![
                StorageLevel::register_file("RF", 64, 16),
                StorageLevel::dram(64),
            ],
            pe: PeArray::new(2, 2),
            noc: Noc::default(),
            mac_energy_pj: 1.0,
            clock_mhz: 200.0,
        }
    }

    /// M=2, C=2, P=2, everything else 1. 8 MACs.
    fn tiny_layer() -> Layer {
        Layer::new("tiny", 2, 2, 1, 1, 2, 1)
    }

    #[test]
    fn hand_computed_counts_two_level() {
        let acc = tiny_acc();
        let layer = tiny_layer();
        // All loops temporal at DRAM, canonical order (N,M,C,R,S,P,Q
        // innermost→outermost) → non-degenerate inner→outer: M2, C2, P2.
        let m = Mapping::trivial(&layer, 2);
        let e = evaluate(&layer, &acc, &m).unwrap();
        assert_eq!(e.macs, 8);
        // Weights (rel M,C): innermost loop M is relevant → 2·2·2 rounds.
        assert_eq!(e.access[1][0].reads, 8);
        // Input (rel C,P): M skipped as leading-irrelevant → C·P = 4.
        assert_eq!(e.access[1][1].reads, 4);
        // Output: V = 8 (M relevant immediately), U = M·P = 4.
        assert_eq!(e.access[1][2].writes, 8);
        assert_eq!(e.access[1][2].reads, 4);
        // RF datapath traffic; Output adds the V = 8 psum hand-ups on top
        // of the 8 accumulator reads.
        assert_eq!(e.access[0][0].reads, 8);
        assert_eq!(e.access[0][1].reads, 8);
        assert_eq!(e.access[0][2].reads, 8 + 8);
        // RF fills = parent reads (fanout 1) + psum writebacks.
        assert_eq!(e.access[0][0].writes, 8);
        assert_eq!(e.access[0][1].writes, 4);
        // Output child-side: reads of psums sent up = 8, fills of
        // read-backs = 4, plus 8 accumulator writes from the datapath.
        assert_eq!(e.access[0][2].writes, 8 + 4);
        assert_eq!(e.compute_cycles, 8);
        assert!(e.latency_cycles >= 8);
    }

    #[test]
    fn permutation_changes_reuse() {
        let acc = tiny_acc();
        let layer = tiny_layer();
        let mut m = Mapping::trivial(&layer, 2);
        // Put P innermost instead: order P, C, M (inner→outer).
        m.permutation[1] = [Dim::P, Dim::C, Dim::M, Dim::N, Dim::R, Dim::S, Dim::Q];
        let e = evaluate(&layer, &acc, &m).unwrap();
        // Weights: leading P irrelevant → skipped; C·M = 4 rounds.
        assert_eq!(e.access[1][0].reads, 4);
        // Input: P relevant immediately → 8 rounds.
        assert_eq!(e.access[1][1].reads, 8);
        // Output: V = P·C·M = 8 (P relevant), U = 4.
        assert_eq!(e.access[1][2].writes, 8);
    }

    #[test]
    fn spatial_multicast_reduces_parent_reads() {
        let acc = tiny_acc();
        let layer = tiny_layer();
        // Parallelize M over X (2 PEs): weights split, inputs multicast.
        let mut m = Mapping::trivial(&layer, 2);
        m.spatial_x[Dim::M.idx()] = 2;
        m.temporal[1][Dim::M.idx()] = 1;
        let e = evaluate(&layer, &acc, &m).unwrap();
        assert_eq!(e.active_pes, 2);
        // Loops above boundary: C2, P2 (M now spatial).
        // Weights unique across PEs = M2·C1(tile)… per round W unique =
        // tensor_elems(spatial_tile W) with M=2,C=1 → 2; rounds: C
        // relevant immediately → C·P = 4 → reads = 8.
        assert_eq!(e.access[1][0].reads, 8);
        // Input: unique across PEs = 1 (M irrelevant to I) → multicast.
        // rounds = C·P = 4 → parent reads 4, but both PEs fill: child
        // fills = rounds · fanout · tile0 = 8.
        assert_eq!(e.access[1][1].reads, 4);
        assert_eq!(e.access[0][1].writes, 8);
        // Utilization = 2 active of 4 PEs.
        assert!((e.utilization - 0.5).abs() < 1e-12);
    }

    #[test]
    fn spatial_reduction_traffic_counted() {
        let acc = tiny_acc();
        let layer = tiny_layer();
        // Parallelize C (a reduction dim) over X.
        let mut m = Mapping::trivial(&layer, 2);
        m.spatial_x[Dim::C.idx()] = 2;
        m.temporal[1][Dim::C.idx()] = 1;
        let e0 = {
            // Baseline without spatial C for NoC comparison.
            let m0 = Mapping::trivial(&layer, 2);
            evaluate(&layer, &acc, &m0).unwrap()
        };
        let e = evaluate(&layer, &acc, &m).unwrap();
        // Output unique across PEs < aggregate → reduction words appear.
        assert!(e.noc_words > 0);
        // DRAM psum writes shrink vs baseline (C no longer revisits above).
        assert!(e.access[1][2].writes <= e0.access[1][2].writes);
    }

    #[test]
    fn mac_conservation_across_mappings() {
        // MAC count is mapping-invariant (property also swept in
        // rust/tests/property.rs with random mappings).
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let m1 = Mapping::trivial(&layer, acc.n_levels());
        let e1 = evaluate(&layer, &acc, &m1).unwrap();
        assert_eq!(e1.macs, layer.macs());
        assert_eq!(e1.energy.mac_pj, layer.macs() as f64);
    }

    #[test]
    fn energy_positive_and_dram_dominant_for_trivial() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let m = Mapping::trivial(&layer, acc.n_levels());
        let e = evaluate(&layer, &acc, &m).unwrap();
        assert!(e.energy.total_pj() > 0.0);
        // Everything streams from DRAM: DRAM must dominate storage energy.
        assert!(e.energy.dram_pj() > e.energy.level_pj[1]);
    }

    #[test]
    fn weightless_ops_carry_no_weight_traffic() {
        let acc = presets::eyeriss();
        for layer in [
            Layer::pooling("pool", 64, 2, 28, 28).with_stride(2),
            Layer::elementwise("add", 64, 28, 28),
        ] {
            let m = Mapping::trivial(&layer, acc.n_levels());
            let e = evaluate(&layer, &acc, &m).unwrap();
            for l in 0..acc.n_levels() {
                assert_eq!(
                    e.access[l][Tensor::Weight.t_idx()].total(),
                    0,
                    "{} level {l}",
                    layer.name
                );
            }
            assert!(e.energy.total_pj() > 0.0);
        }
    }

    #[test]
    fn elementwise_reads_two_operands_per_add() {
        let acc = presets::eyeriss();
        let layer = Layer::elementwise("add", 8, 4, 4);
        let m = Mapping::trivial(&layer, acc.n_levels());
        let e = evaluate(&layer, &acc, &m).unwrap();
        assert_eq!(e.access[0][Tensor::Input.t_idx()].reads, 2 * e.macs);
        // No reduction → no accumulator read-back: L0 output reads are the
        // value hand-ups alone (one per result for this trivial mapping).
        assert_eq!(e.access[0][Tensor::Output.t_idx()].reads, e.macs);
        // Both operands stream from DRAM at least once.
        let top = acc.n_levels() - 1;
        assert!(e.access[top][Tensor::Input.t_idx()].reads >= 2 * layer.m * layer.p * layer.q);
    }

    #[test]
    fn matmul_matches_equivalent_1x1_conv() {
        // A matmul is numerically the 1×1-conv projection with rows on P:
        // identical traffic, latency and energy under the same mapping.
        let acc = presets::eyeriss();
        let mm = Layer::matmul("mm", 64, 32, 16);
        let conv = Layer::new("conv", 64, 32, 1, 1, 16, 1);
        let m = Mapping::trivial(&mm, acc.n_levels());
        assert_eq!(evaluate(&mm, &acc, &m).unwrap(), evaluate(&conv, &acc, &m).unwrap());
    }

    #[test]
    fn invalid_mapping_rejected() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let mut m = Mapping::trivial(&layer, acc.n_levels());
        m.temporal[2][0] = 999; // breaks coverage
        assert!(evaluate(&layer, &acc, &m).is_err());
    }

    #[test]
    fn bandwidth_can_bound_latency() {
        let mut acc = tiny_acc();
        acc.levels[1].bandwidth_words_per_cycle = 0.001;
        let layer = tiny_layer();
        let m = Mapping::trivial(&layer, 2);
        let e = evaluate(&layer, &acc, &m).unwrap();
        assert!(e.latency_cycles > e.compute_cycles);
    }
}
