//! Loop-nest machinery for the reuse model: building the ordered loop list
//! above a boundary and counting fetch rounds / distinct tiles under the
//! stationarity rule (see the module docs of [`crate::model`]).

use crate::mapping::Mapping;
use crate::workload::{ConvLayer, Dim, Tensor};

/// One non-degenerate loop: dimension and trip count (> 1).
pub type LoopIter = (Dim, u64);

/// Maximum loops a boundary can see: 7 dims × up to 6 levels. Fixed-size
/// storage keeps the evaluator allocation-free (perf pass iteration 1 —
/// see EXPERIMENTS.md §Perf).
const MAX_LOOPS: usize = 42;

/// A fixed-capacity, stack-allocated loop list (inner→outer order).
#[derive(Debug, Clone, Copy)]
pub struct LoopList {
    items: [LoopIter; MAX_LOOPS],
    len: usize,
}

impl LoopList {
    fn new() -> Self {
        Self { items: [(Dim::N, 1); MAX_LOOPS], len: 0 }
    }

    fn push(&mut self, item: LoopIter) {
        assert!(self.len < MAX_LOOPS, "loop list overflow");
        self.items[self.len] = item;
        self.len += 1;
    }

    /// Number of non-degenerate loops in the list.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no non-degenerate loop is present.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Iterate the loops inner→outer.
    pub fn iter(&self) -> std::slice::Iter<'_, LoopIter> {
        self.items[..self.len].iter()
    }
}

impl std::ops::Deref for LoopList {
    type Target = [LoopIter];

    fn deref(&self) -> &[LoopIter] {
        &self.items[..self.len]
    }
}

/// The ordered list of non-degenerate temporal loops **above** the child
/// tiles of boundary `l` (i.e. loops at levels `l..top`), innermost first.
/// Within each level the mapping's permutation gives the order; levels
/// stack inner→outer. Trip-1 loops are transparent and dropped.
pub fn loop_list_above(_layer: &ConvLayer, mapping: &Mapping, l: usize) -> LoopList {
    let mut out = LoopList::new();
    for level in l..mapping.n_levels() {
        for (d, f) in mapping.loops(level) {
            if f > 1 {
                out.push((d, f));
            }
        }
    }
    out
}

/// Number of times a child tile of tensor `t` is (re)fetched given the
/// loops above it: skip the leading (innermost) contiguous run of
/// `t`-irrelevant loops — the tile is stationary across those — then
/// multiply every remaining trip count, relevant or not.
pub fn fetch_rounds(layer: &ConvLayer, t: Tensor, loops: &[LoopIter]) -> u64 {
    let mut rounds = 1u64;
    let mut seen_relevant = false;
    for &(d, trip) in loops {
        if !seen_relevant {
            if t.relevant_for(layer, d) {
                seen_relevant = true;
            } else {
                continue; // stationary across this loop
            }
        }
        rounds = rounds.saturating_mul(trip);
    }
    rounds
}

/// Number of *distinct* child tiles of tensor `t` enumerated by the loops
/// above it: product of the `t`-relevant trip counts only. For outputs this
/// is the `U` of the `V − U` psum read-back rule.
pub fn distinct_tiles(layer: &ConvLayer, t: Tensor, loops: &[LoopIter]) -> u64 {
    loops
        .iter()
        .filter(|&&(d, _)| t.relevant_for(layer, d))
        .map(|&(_, trip)| trip)
        .product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::Mapping;
    use crate::workload::ConvLayer;

    fn layer() -> ConvLayer {
        ConvLayer::new("t", 4, 4, 3, 3, 8, 8)
    }

    #[test]
    fn loop_list_drops_degenerate_and_orders_inner_first() {
        let l = layer();
        let mut m = Mapping::trivial(&l, 3);
        // Move C to level 1, keep the rest at level 2.
        m.temporal[2][Dim::C.idx()] = 1;
        m.temporal[1][Dim::C.idx()] = 4;
        let loops = loop_list_above(&l, &m, 1);
        // Level-1 loops come first (C), then level-2 loops in canonical
        // order (M, R, S, P, Q — N is degenerate).
        assert_eq!(loops[0], (Dim::C, 4));
        assert_eq!(loops[1], (Dim::M, 4));
        assert_eq!(loops.len(), 6);
    }

    #[test]
    fn stationarity_skips_leading_irrelevant_only() {
        let l = layer();
        // Loops inner→outer: P(8) then M(4). Weights: P irrelevant →
        // stationary across it; M relevant → 4 rounds.
        let loops = vec![(Dim::P, 8), (Dim::M, 4)];
        assert_eq!(fetch_rounds(&l, Tensor::Weight, &loops), 4);
        // Flip the order: M inner → no stationarity, 32 rounds.
        let loops = vec![(Dim::M, 4), (Dim::P, 8)];
        assert_eq!(fetch_rounds(&l, Tensor::Weight, &loops), 32);
    }

    #[test]
    fn irrelevant_above_relevant_counts() {
        let l = layer();
        // Q(inner, irrelevant to W) M C P(outer, irrelevant): skip Q only.
        let loops = vec![(Dim::Q, 2), (Dim::M, 4), (Dim::C, 4), (Dim::P, 8)];
        assert_eq!(fetch_rounds(&l, Tensor::Weight, &loops), 4 * 4 * 8);
        assert_eq!(distinct_tiles(&l, Tensor::Weight, &loops), 16);
    }

    #[test]
    fn empty_list_means_one_round() {
        let l = layer();
        assert_eq!(fetch_rounds(&l, Tensor::Weight, &[]), 1);
        assert_eq!(distinct_tiles(&l, Tensor::Output, &[]), 1);
    }

    #[test]
    fn input_sliding_window_relevance() {
        let l = layer();
        // R is relevant to Input via the halo.
        let loops = vec![(Dim::R, 3)];
        assert_eq!(fetch_rounds(&l, Tensor::Input, &loops), 3);
        // M is not.
        let loops = vec![(Dim::M, 4)];
        assert_eq!(fetch_rounds(&l, Tensor::Input, &loops), 1);
    }

    #[test]
    fn depthwise_m_relevant_to_input() {
        let dl = ConvLayer::new("dw", 8, 8, 3, 3, 8, 8).depthwise();
        let loops = vec![(Dim::M, 8)];
        assert_eq!(fetch_rounds(&dl, Tensor::Input, &loops), 8);
    }

    #[test]
    fn v_geq_u_invariant() {
        let l = layer();
        let loops = vec![(Dim::C, 4), (Dim::M, 4), (Dim::R, 3), (Dim::P, 8)];
        let v = fetch_rounds(&l, Tensor::Output, &loops);
        let u = distinct_tiles(&l, Tensor::Output, &loops);
        assert!(v >= u);
        assert_eq!(u, 4 * 8); // M·P
        // C (innermost) is irrelevant to Output → stationary; then M·R·P.
        assert_eq!(v, 4 * 3 * 8);
    }
}
