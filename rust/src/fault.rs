//! Deterministic fault injection for the compilation pipeline.
//!
//! The robustness tests and the CI smoke step need to provoke the failure
//! paths — a mapper panic, a stalled search, a simulated allocation
//! failure, a dead worker thread — on demand and *deterministically*. This
//! module is the single arming point: a process-global plan set from the
//! `--inject-fault <spec>` CLI flag or the [`ENV_VAR`] environment
//! variable, consulted by the mapping-service workers through two hooks:
//!
//! * [`inject`] runs **inside** the worker's panic-containment region, so
//!   an injected panic is caught, counted and degraded to the LOCAL
//!   fallback exactly like a real mapper bug would be.
//! * [`should_kill_worker`] runs **outside** that region, so the worker
//!   thread genuinely dies and the service supervisor's respawn path is
//!   exercised.
//!
//! Faults that target a specific request are keyed by the **submission
//! ordinal** — the 0-based position of the request in process-wide
//! submission order, stamped by [`next_ordinal`] at submit time. Ordinals
//! are independent of worker scheduling and cache state, so `panic:3`
//! deterministically hits the fourth submitted layer on every run.
//!
//! When nothing is armed every hook is a single relaxed atomic load — the
//! module is compiled unconditionally and costs nothing in production.

use crate::mappers::MapError;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Environment variable consulted by [`arm_from_env`]: holds the same
/// `panic:<idx>` / `stall:<ms>` / `oom-sim` / `worker-death:<idx>` spec as
/// the `--inject-fault` CLI flag.
pub const ENV_VAR: &str = "LOCAL_MAPPER_INJECT_FAULT";

/// The fault to inject, parsed from an `--inject-fault` spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic inside the worker's containment region when the request with
    /// this submission ordinal is served (fires once).
    Panic {
        /// 0-based submission ordinal of the request to hit.
        layer_idx: u64,
    },
    /// Sleep inside every request — simulates a stalled search so deadline
    /// and degradation paths can be driven from the CLI.
    Stall {
        /// Milliseconds slept per request.
        ms: u64,
    },
    /// Fail every request with a simulated allocation error (typed
    /// [`MapError`], not a panic — exercises the ordinary-error fallback).
    OomSim,
    /// Kill the worker thread *outside* the containment region when the
    /// request with this submission ordinal arrives (fires once) —
    /// exercises the supervisor's respawn path.
    WorkerDeath {
        /// 0-based submission ordinal of the request to hit.
        layer_idx: u64,
    },
}

/// Hot-path gate: every hook bails on one relaxed load when disarmed.
static ARMED: AtomicBool = AtomicBool::new(false);
/// One-shot latch for the fire-once kinds (`panic`, `worker-death`).
static FIRED: AtomicBool = AtomicBool::new(false);
/// Process-wide submission counter; reset by [`arm`].
static ORDINAL: AtomicU64 = AtomicU64::new(0);
/// The armed plan (`None` while disarmed).
static PLAN: Mutex<Option<FaultKind>> = Mutex::new(None);

/// Parse an injection spec: `panic:<idx>`, `stall:<ms>`, `oom-sim` or
/// `worker-death:<idx>`.
pub fn parse(spec: &str) -> Result<FaultKind, String> {
    if spec == "oom-sim" {
        return Ok(FaultKind::OomSim);
    }
    let (kind, arg) = spec.split_once(':').ok_or_else(|| {
        format!(
            "bad fault spec {spec:?} (expected panic:<idx>, stall:<ms>, \
             oom-sim or worker-death:<idx>)"
        )
    })?;
    let n: u64 = arg
        .parse()
        .map_err(|_| format!("bad fault spec {spec:?}: {arg:?} is not a number"))?;
    match kind {
        "panic" => Ok(FaultKind::Panic { layer_idx: n }),
        "stall" => Ok(FaultKind::Stall { ms: n }),
        "worker-death" => Ok(FaultKind::WorkerDeath { layer_idx: n }),
        _ => Err(format!("unknown fault kind {kind:?} in {spec:?}")),
    }
}

/// Arm `kind` process-wide. Resets the submission-ordinal counter and the
/// fire-once latch, so ordinal-keyed faults are deterministic relative to
/// the submissions that follow.
pub fn arm(kind: FaultKind) {
    let mut plan = PLAN.lock().unwrap_or_else(|p| p.into_inner());
    *plan = Some(kind);
    ORDINAL.store(0, Ordering::Relaxed);
    FIRED.store(false, Ordering::Relaxed);
    ARMED.store(true, Ordering::Relaxed);
}

/// Disarm: every hook returns to its no-op fast path.
pub fn disarm() {
    ARMED.store(false, Ordering::Relaxed);
    let mut plan = PLAN.lock().unwrap_or_else(|p| p.into_inner());
    *plan = None;
}

/// Whether a fault is currently armed.
pub fn is_armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Arm from [`ENV_VAR`] if it is set and non-empty. Returns `Ok(true)` if
/// a fault was armed, `Ok(false)` if the variable is unset/empty, and the
/// parse error for a malformed spec.
pub fn arm_from_env() -> Result<bool, String> {
    match std::env::var(ENV_VAR) {
        Ok(spec) if !spec.is_empty() => {
            arm(parse(&spec)?);
            Ok(true)
        }
        _ => Ok(false),
    }
}

/// RAII disarm guard for in-process tests: the fault stays armed exactly
/// for the guard's lifetime.
pub struct Armed(());

/// Arm `kind` for the returned guard's lifetime.
pub fn arm_guard(kind: FaultKind) -> Armed {
    arm(kind);
    Armed(())
}

impl Drop for Armed {
    fn drop(&mut self) {
        disarm();
    }
}

/// Claim the next submission ordinal. Called by the service at submit
/// time; a constant 0 while disarmed so unrelated submissions never
/// advance the counter between [`arm`] and the faulted run.
pub fn next_ordinal() -> u64 {
    if !is_armed() {
        return 0;
    }
    ORDINAL.fetch_add(1, Ordering::Relaxed)
}

fn plan() -> Option<FaultKind> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    *PLAN.lock().unwrap_or_else(|p| p.into_inner())
}

/// The in-containment hook: called by a service worker at the top of the
/// guarded region for the request with `ordinal`. May panic (caught by the
/// worker), sleep, or return a typed error, per the armed plan.
pub fn inject(ordinal: u64) -> Result<(), MapError> {
    match plan() {
        None | Some(FaultKind::WorkerDeath { .. }) => Ok(()),
        Some(FaultKind::Panic { layer_idx }) => {
            if ordinal == layer_idx && !FIRED.swap(true, Ordering::Relaxed) {
                panic!("injected panic at request ordinal {ordinal}");
            }
            Ok(())
        }
        Some(FaultKind::Stall { ms }) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Some(FaultKind::OomSim) => {
            Err(MapError::NoValidMapping("injected oom-sim allocation failure".into()))
        }
    }
}

/// The daemon admission hook: called by the serve loop while a request
/// holds its admission slot, *before* the compile starts. Sleeps only
/// when a `stall:<ms>` plan is armed — that holds the slot long enough
/// for the backpressure tests to fill the queue deterministically — and
/// is a single relaxed load otherwise. Other fault kinds are ignored
/// here; they belong to the mapping-service hooks above.
pub fn stall_daemon() {
    if let Some(FaultKind::Stall { ms }) = plan() {
        std::thread::sleep(Duration::from_millis(ms));
    }
}

/// The out-of-containment hook: `true` exactly once for the
/// `worker-death:<idx>` request, telling the worker to panic *outside* its
/// unwind boundary so the thread dies and the supervisor must respawn it.
pub fn should_kill_worker(ordinal: u64) -> bool {
    matches!(plan(), Some(FaultKind::WorkerDeath { layer_idx }) if ordinal == layer_idx)
        && !FIRED.swap(true, Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    // Only the pure parser is unit-tested here: arming mutates process
    // globals and the lib's unit tests run concurrently, so everything
    // that fires a fault lives in `tests/failure_injection.rs` (its own
    // process, serialized there).
    use super::*;

    #[test]
    fn specs_parse() {
        assert_eq!(parse("panic:3"), Ok(FaultKind::Panic { layer_idx: 3 }));
        assert_eq!(parse("stall:250"), Ok(FaultKind::Stall { ms: 250 }));
        assert_eq!(parse("oom-sim"), Ok(FaultKind::OomSim));
        assert_eq!(parse("worker-death:0"), Ok(FaultKind::WorkerDeath { layer_idx: 0 }));
    }

    #[test]
    fn bad_specs_are_rejected_with_context() {
        for bad in ["", "panic", "panic:x", "melt:1", "stall:", "oom-sim:1"] {
            let err = parse(bad).unwrap_err();
            assert!(err.contains("fault") || err.contains("unknown"), "{bad}: {err}");
        }
    }
}
