//! Tile-pipeline latency simulator.
//!
//! The analytical model's latency is a pure roofline
//! (`max(compute, per-level bandwidth)`); this module refines it by walking
//! the mapped loop nest level by level and simulating the **tile
//! pipeline**: at every storage boundary, child-tile fetches either
//! serialize with the child's own execution (single-buffered) or overlap
//! with it (double-buffered, the ping-pong buffers every real accelerator
//! uses — Eyeriss's GLB, NVDLA's CBUF banks).
//!
//! The recursion: a level-`l` tile is executed by `n` child-tile rounds;
//! each round needs `fetch` cycles of transfer from level `l` and `child`
//! cycles of execution below.
//!
//! * single-buffered: `n · (fetch + child)`
//! * double-buffered: `fetch + n·max(fetch, child)` (first fill exposed,
//!   then steady-state overlap)
//!
//! The simulator reports per-level busy/stall cycles and the bottleneck
//! level — the profile the §Perf pass reads. Used by the `latency_sim`
//! ablation bench to quantify what double buffering buys each mapping
//! (and to check the analytical roofline is a lower bound).

use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::model::{evaluate_unchecked, Evaluation};
use crate::workload::{ConvLayer, Tensor};

/// Simulation options.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Ping-pong (double) buffering at every bounded level.
    pub double_buffer: bool,
    /// Spatial PEs compute in lockstep (true) or ideally overlapped.
    pub lockstep_pes: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self { double_buffer: true, lockstep_pes: true }
    }
}

/// Per-level simulation profile.
#[derive(Debug, Clone, Default)]
pub struct LevelProfile {
    /// Cycles this level spent transferring data downward.
    pub transfer_cycles: u64,
    /// Cycles the level's consumers were stalled waiting on it.
    pub stall_cycles: u64,
    /// Child rounds executed.
    pub rounds: u64,
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// End-to-end cycles for the full layer.
    pub total_cycles: u64,
    /// Pure compute cycles (all PEs busy, no stalls).
    pub compute_cycles: u64,
    /// Per-level profiles, aligned with `Accelerator::levels`
    /// (level 0 entry describes the RF→datapath boundary).
    pub levels: Vec<LevelProfile>,
    /// Index of the level whose transfers dominate stalls.
    pub bottleneck_level: usize,
    /// total / compute — 1.0 means perfectly compute-bound.
    pub slowdown: f64,
}

impl SimResult {
    /// Effective MACs/cycle across the array.
    pub fn macs_per_cycle(&self, macs: u64) -> f64 {
        macs as f64 / self.total_cycles.max(1) as f64
    }
}

/// Simulate the tile pipeline of a validated mapping.
///
/// Transfer volumes come from the same access-count analysis the energy
/// model uses (so the two views are consistent by construction); timing
/// composes them through the buffered-pipeline recursion above.
pub fn simulate(
    layer: &ConvLayer,
    acc: &Accelerator,
    mapping: &Mapping,
    opts: SimOptions,
) -> SimResult {
    let eval = evaluate_unchecked(layer, acc, mapping);
    simulate_from_eval(layer, acc, mapping, &eval, opts)
}

/// Simulate re-using an existing evaluation (hot path for ablations).
pub fn simulate_from_eval(
    layer: &ConvLayer,
    _acc: &Accelerator,
    mapping: &Mapping,
    eval: &Evaluation,
    opts: SimOptions,
) -> SimResult {
    let n_levels = mapping.n_levels();
    let mut profiles = vec![LevelProfile::default(); n_levels];

    // Per-PE compute cycles for one L0 tile residency: the innermost
    // temporal loops (level 0 factors) all run per fetch round.
    let tile0_iters: u64 = mapping.temporal[0].iter().product();
    let active = eval.active_pes.max(1);
    // Total per-PE iterations = all temporal loops.
    let per_pe_total: u64 = mapping.temporal.iter().flatten().product();
    let compute_cycles = if opts.lockstep_pes {
        per_pe_total
    } else {
        // Ideal overlap: aggregate MACs over all PEs.
        (eval.macs + active - 1) / active
    };

    // Rounds at each boundary: how many times level l delivers a full
    // child working set. Derive from the max fetch rounds across tensors
    // (the binding transfer schedule).
    let mut rounds = vec![1u64; n_levels];
    for l in 1..n_levels {
        let loops = crate::model::loop_list_above(layer, mapping, l);
        rounds[l] = Tensor::ALL
            .iter()
            .map(|&t| crate::model::fetch_rounds(layer, t, &loops))
            .max()
            .unwrap_or(1);
    }

    // Words level l moves per round (reads it serves + writes it accepts
    // from below).
    let mut words_per_round = vec![0u64; n_levels];
    for l in 1..n_levels {
        let total: u64 = (0..3)
            .map(|ti| eval.access[l][ti].reads + eval.access[l][ti].writes)
            .sum::<u64>()
            // Datapath RF traffic is not a boundary transfer.
            .saturating_sub(if l == 0 { eval.macs * 4 } else { 0 });
        words_per_round[l] = total / rounds[l].max(1);
    }

    // Bottom-up pipeline composition.
    // child_time = cycles to execute everything below boundary l, per
    // level-(l-1) residency.
    let mut child_time = if tile0_iters == 0 { 1 } else { tile0_iters };
    let mut total = child_time;
    for l in 1..n_levels {
        let bw = _acc.levels[l].bandwidth_words_per_cycle.max(f64::MIN_POSITIVE);
        let fetch = (words_per_round[l] as f64 / bw).ceil() as u64;
        let n = (rounds[l].max(1)) / rounds.get(l + 1).copied().unwrap_or(1).max(1);
        let n = n.max(1);
        let level_total = if opts.double_buffer {
            fetch + n * child_time.max(fetch)
        } else {
            n * (fetch + child_time)
        };
        profiles[l].transfer_cycles = fetch * n;
        profiles[l].stall_cycles = level_total.saturating_sub(n * child_time);
        profiles[l].rounds = n;
        child_time = level_total;
        total = level_total;
    }

    let bottleneck_level = (0..n_levels)
        .max_by_key(|&l| profiles[l].stall_cycles)
        .unwrap_or(0);
    let slowdown = total as f64 / compute_cycles.max(1) as f64;
    SimResult {
        total_cycles: total.max(compute_cycles),
        compute_cycles,
        levels: profiles,
        bottleneck_level,
        slowdown: slowdown.max(1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::{LocalMapper, Mapper};
    use crate::workload::zoo;

    fn setup() -> (ConvLayer, Accelerator, Mapping) {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        (layer, acc, m)
    }

    #[test]
    fn double_buffering_never_slower() {
        let (layer, acc, m) = setup();
        let db = simulate(&layer, &acc, &m, SimOptions { double_buffer: true, lockstep_pes: true });
        let sb = simulate(&layer, &acc, &m, SimOptions { double_buffer: false, lockstep_pes: true });
        assert!(db.total_cycles <= sb.total_cycles, "{} > {}", db.total_cycles, sb.total_cycles);
    }

    #[test]
    fn simulated_latency_at_least_compute_bound() {
        let (layer, acc, m) = setup();
        let r = simulate(&layer, &acc, &m, SimOptions::default());
        assert!(r.total_cycles >= r.compute_cycles);
        assert!(r.slowdown >= 1.0);
    }

    #[test]
    fn profiles_cover_all_levels() {
        let (layer, acc, m) = setup();
        let r = simulate(&layer, &acc, &m, SimOptions::default());
        assert_eq!(r.levels.len(), acc.n_levels());
        assert!(r.bottleneck_level < acc.n_levels());
        // Boundary levels performed transfers.
        assert!(r.levels[1].transfer_cycles > 0);
        assert!(r.levels[2].transfer_cycles > 0);
    }

    #[test]
    fn starved_bandwidth_shows_up_as_stalls() {
        let (layer, mut acc, m) = setup();
        acc.levels[2].bandwidth_words_per_cycle = 0.01;
        let r = simulate(&layer, &acc, &m, SimOptions::default());
        assert_eq!(r.bottleneck_level, 2);
        assert!(r.slowdown > 2.0, "slowdown {}", r.slowdown);
    }

    #[test]
    fn works_on_all_presets_and_categories() {
        for acc in presets::all() {
            for row in zoo::table2_workloads() {
                let m = LocalMapper::new().map(&row.layer, &acc).unwrap();
                let r = simulate(&row.layer, &acc, &m, SimOptions::default());
                assert!(r.total_cycles > 0, "{} on {}", row.layer.name, acc.name);
            }
        }
    }
}
