//! Per-component energy breakdown — the stacked bars of the paper's Fig. 7
//! (DRAM / GLB / NoC / RF-spad / MAC).

use crate::arch::Accelerator;

/// Energy totals per architectural component, in pJ.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyBreakdown {
    /// Storage-level energies aligned with `Accelerator::levels`
    /// (index 0 = per-PE RF, last = DRAM).
    pub level_pj: Vec<f64>,
    /// NoC (L1↔PE delivery + spatial psum reduction).
    pub noc_pj: f64,
    /// Datapath MACs.
    pub mac_pj: f64,
}

impl EnergyBreakdown {
    /// All-zero breakdown for a machine with `n_levels` storage levels.
    pub fn zero(n_levels: usize) -> Self {
        Self { level_pj: vec![0.0; n_levels], noc_pj: 0.0, mac_pj: 0.0 }
    }

    /// Total energy, pJ.
    pub fn total_pj(&self) -> f64 {
        self.level_pj.iter().sum::<f64>() + self.noc_pj + self.mac_pj
    }

    /// Total energy, µJ (the unit of Fig. 3 / Fig. 7 axes).
    pub fn total_uj(&self) -> f64 {
        self.total_pj() / 1e6
    }

    /// DRAM (outermost level) share — the dominant Fig. 7 component.
    pub fn dram_pj(&self) -> f64 {
        *self.level_pj.last().unwrap_or(&0.0)
    }

    /// Energy per MAC (pJ) given an op count — the paper's efficiency lens.
    pub fn pj_per_mac(&self, macs: u64) -> f64 {
        self.total_pj() / macs.max(1) as f64
    }

    /// Labelled components for report/CSV emission: (name, pJ),
    /// storage levels first (innermost→outermost), then NoC, then MAC.
    pub fn components<'a>(&'a self, acc: &'a Accelerator) -> Vec<(&'a str, f64)> {
        let mut out: Vec<(&str, f64)> = acc
            .levels
            .iter()
            .zip(&self.level_pj)
            .map(|(l, &e)| (l.name.as_str(), e))
            .collect();
        out.push(("NoC", self.noc_pj));
        out.push(("MAC", self.mac_pj));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn totals_add_up() {
        let mut b = EnergyBreakdown::zero(3);
        b.level_pj = vec![1.0, 2.0, 3.0];
        b.noc_pj = 0.5;
        b.mac_pj = 4.0;
        assert!((b.total_pj() - 10.5).abs() < 1e-12);
        assert!((b.total_uj() - 10.5e-6).abs() < 1e-18);
        assert_eq!(b.dram_pj(), 3.0);
    }

    #[test]
    fn components_are_labelled() {
        let acc = presets::eyeriss();
        let mut b = EnergyBreakdown::zero(acc.levels.len());
        b.level_pj = vec![1.0, 2.0, 3.0];
        let c = b.components(&acc);
        assert_eq!(c[0].0, "RF");
        assert_eq!(c[1].0, "GLB");
        assert_eq!(c[2].0, "DRAM");
        assert_eq!(c[3].0, "NoC");
        assert_eq!(c[4].0, "MAC");
    }

    #[test]
    fn pj_per_mac_guards_zero() {
        let b = EnergyBreakdown::zero(2);
        assert_eq!(b.pj_per_mac(0), 0.0);
    }
}
