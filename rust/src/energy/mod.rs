//! Accelergy-lite energy model.
//!
//! The paper evaluates energy through Accelergy's energy reference tables
//! (ERTs) [24]. We generate an ERT from the accelerator geometry with the
//! standard SRAM scaling heuristic: access energy grows ~√capacity
//! (wordline/bitline length), anchored to the widely used Eyeriss relative
//! costs (MAC ≈ 1, RF ≈ 1, GLB(128 KiB) ≈ 6, DRAM ≈ 200 — Chen et al.,
//! ISCA'16 Table).  Absolute pJ values are a technology constant times the
//! relative number; comparisons between mappers (Fig. 7, Table 3) only need
//! the relative table, exactly as in the paper.

pub mod breakdown;

pub use breakdown::EnergyBreakdown;

use crate::arch::Accelerator;

/// Relative-cost anchors (Eyeriss ISCA'16, normalized to one MAC).
const DRAM_REL: f64 = 200.0;
/// GLB anchor: 128 KiB ↔ 6× MAC.
const GLB_ANCHOR_BITS: f64 = (128 * 1024 * 8) as f64;
const GLB_ANCHOR_REL: f64 = 6.0;
/// Floor for tiny register files (≈ one MAC).
const RF_FLOOR_REL: f64 = 0.8;

/// Energy reference table: pJ per access for every storage level of one
/// accelerator, plus MAC and NoC-hop energies.
#[derive(Debug, Clone, PartialEq)]
pub struct Ert {
    /// pJ per word access, aligned with `Accelerator::levels`.
    pub level_pj: Vec<f64>,
    /// pJ per MAC.
    pub mac_pj: f64,
    /// pJ per word per NoC hop.
    pub noc_hop_pj: f64,
}

impl Ert {
    /// Build the ERT for an accelerator from its geometry.
    pub fn for_accelerator(acc: &Accelerator) -> Ert {
        let unit = acc.mac_energy_pj; // technology scale: 1 MAC in pJ
        let level_pj = acc
            .levels
            .iter()
            .map(|l| {
                if l.unbounded {
                    DRAM_REL * unit
                } else {
                    let bits = l.capacity_bits() as f64;
                    let rel = GLB_ANCHOR_REL * (bits / GLB_ANCHOR_BITS).sqrt();
                    rel.max(RF_FLOOR_REL) * unit
                }
            })
            .collect();
        Ert {
            level_pj,
            mac_pj: unit,
            noc_hop_pj: acc.noc.hop_energy_pj,
        }
    }

    /// pJ per access at storage level `l`.
    pub fn level(&self, l: usize) -> f64 {
        self.level_pj[l]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn eyeriss_ert_matches_anchors() {
        let acc = presets::eyeriss();
        let ert = Ert::for_accelerator(&acc);
        // RF (256 bit) hits the floor.
        assert!((ert.level(0) - 0.8).abs() < 1e-9, "{}", ert.level(0));
        // GLB is exactly the 128 KiB anchor.
        assert!((ert.level(1) - 6.0).abs() < 1e-9, "{}", ert.level(1));
        // DRAM anchor.
        assert!((ert.level(2) - 200.0).abs() < 1e-9);
        assert_eq!(ert.mac_pj, 1.0);
    }

    #[test]
    fn energy_monotone_in_capacity() {
        // Bigger buffers cost more per access.
        let mut a = presets::eyeriss();
        let e_small = Ert::for_accelerator(&a).level(1);
        a.levels[1].depth *= 4;
        let e_big = Ert::for_accelerator(&a).level(1);
        assert!(e_big > e_small);
        // √ scaling: 4× capacity → 2× energy.
        assert!((e_big / e_small - 2.0).abs() < 1e-9);
    }

    #[test]
    fn hierarchy_is_ordered() {
        // Every preset: deeper levels cost strictly more per access.
        for acc in presets::all() {
            let ert = Ert::for_accelerator(&acc);
            for l in 1..acc.levels.len() {
                assert!(
                    ert.level(l) > ert.level(l - 1),
                    "{}: level {l} ({}) not costlier than level {}",
                    acc.name,
                    ert.level(l),
                    l - 1
                );
            }
        }
    }

    #[test]
    fn technology_scale_is_linear() {
        let mut a = presets::eyeriss();
        a.mac_energy_pj = 2.0;
        let ert = Ert::for_accelerator(&a);
        assert!((ert.level(1) - 12.0).abs() < 1e-9);
        assert!((ert.level(2) - 400.0).abs() < 1e-9);
    }
}
