//! Random mapping — the paper's §3 motivation experiment (Fig. 3) and the
//! best-of-N random baseline.
//!
//! The best-of-N mapper rides the engine's [`RandomStream`]: candidate `i`
//! is a pure function of `(seed, i)`, so the [`SearchDriver`] shards the
//! stream across worker threads with bit-identical outcomes at every
//! thread count, and a larger budget only appends candidates (more budget
//! never hurts). Pruning is off by default here so `evaluations` keeps the
//! exact best-of-N accounting; [`RandomMapper::with_pruning`] opts in.

use super::engine::{deadline_instant, Objective, RandomStream, SearchDriver};
use super::{MapError, MapStatus, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::mapspace::sample_random;
use crate::model::{EvalContext, Evaluation};
use crate::util::rng::SplitMix64;
use crate::workload::Layer;
use std::cell::Cell;

/// Best-objective-of-N random mapper.
#[derive(Debug, Clone)]
pub struct RandomMapper {
    /// Number of random candidates to draw.
    pub samples: u64,
    /// PRNG seed (deterministic across runs).
    pub seed: u64,
    /// The objective being minimized.
    pub objective: Objective,
    /// Worker threads (identical results at every value).
    pub threads: usize,
    /// Bound-based pruning (off by default: best-of-N keeps exact
    /// evaluation accounting).
    pub prune: bool,
    /// Per-layer wall-clock deadline, ms (`None` = unbounded).
    pub deadline_ms: Option<u64>,
    evaluated: Cell<u64>,
    degraded: Cell<bool>,
}

impl RandomMapper {
    /// Best-of-`samples` random mapper with the given seed.
    pub fn new(samples: u64, seed: u64) -> Self {
        assert!(samples > 0);
        Self {
            samples,
            seed,
            objective: Objective::Energy,
            threads: 1,
            prune: false,
            deadline_ms: None,
            evaluated: Cell::new(0),
            degraded: Cell::new(false),
        }
    }

    /// Mapper configured from shared engine params (`budget` = samples;
    /// pruning stays off — see the type docs).
    pub fn from_params(params: &super::SearchParams) -> Self {
        let mut m = Self::new(params.budget, params.seed);
        m.objective = params.objective;
        m.threads = params.threads.max(1);
        m.deadline_ms = params.deadline_ms;
        m
    }

    /// Builder: minimize `objective` instead of energy.
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Builder: shard the stream across `threads` workers.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Builder: enable bound-based pruning (never changes the selected
    /// mapping; `evaluations` then reports only the unpruned candidates).
    pub fn with_pruning(mut self) -> Self {
        self.prune = true;
        self
    }
}

impl Mapper for RandomMapper {
    fn name(&self) -> String {
        format!("random×{}", self.samples)
    }

    fn objective(&self) -> Objective {
        self.objective
    }

    fn evaluations(&self) -> u64 {
        // `samples` until a map runs; afterwards the engine's examined
        // count (identical unless pruning was opted in).
        if self.evaluated.get() > 0 {
            self.evaluated.get()
        } else {
            self.samples
        }
    }

    fn status(&self) -> MapStatus {
        if self.degraded.get() {
            MapStatus::Degraded { reason: "deadline expired mid-search".into() }
        } else {
            MapStatus::Ok
        }
    }

    fn map(&self, layer: &Layer, acc: &Accelerator) -> Result<Mapping, MapError> {
        self.map_seeded(layer, acc, &[])
    }

    fn accepts_seeds(&self) -> bool {
        true
    }

    /// Cross-layer seeds ride the engine's existing warm-start slot: they
    /// are scored at post-stream indices (one examined tick apiece, exact
    /// ties to the stream), so the result is `min(unseeded best, seeds)` —
    /// never worse than unseeded (DESIGN.md §15).
    fn map_seeded(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        seeds: &[Mapping],
    ) -> Result<Mapping, MapError> {
        self.degraded.set(false);
        let source = RandomStream::new(layer, acc, self.seed, self.samples);
        let driver = SearchDriver {
            objective: self.objective,
            budget: self.samples,
            threads: self.threads,
            prune: self.prune,
            deadline: deadline_instant(self.deadline_ms),
        };
        match driver.search(layer, acc, &source, seeds) {
            Some(b) => {
                self.evaluated.set(b.examined);
                self.degraded.set(b.degraded);
                Ok(b.mapping)
            }
            None => {
                Err(MapError::NoValidMapping("random stream produced no candidate".into()))
            }
        }
    }
}

/// Fig. 3 distribution: energy of `n` random mappings, classified into the
/// paper's `random_max` / `random_med` / `random_min` cases.
#[derive(Debug, Clone)]
pub struct RandomDistribution {
    /// Sorted ascending, µJ.
    pub energies_uj: Vec<f64>,
    /// The evaluation behind the minimum-energy mapping.
    pub min: Evaluation,
    /// The evaluation behind the median-energy mapping.
    pub med: Evaluation,
    /// The evaluation behind the maximum-energy mapping.
    pub max: Evaluation,
}

impl RandomDistribution {
    /// Minimum energy, µJ (`random_min`).
    pub fn min_uj(&self) -> f64 {
        self.energies_uj[0]
    }

    /// Median energy, µJ (`random_med`).
    pub fn med_uj(&self) -> f64 {
        self.energies_uj[self.energies_uj.len() / 2]
    }

    /// Maximum energy, µJ (`random_max`).
    pub fn max_uj(&self) -> f64 {
        *self.energies_uj.last().unwrap()
    }

    /// The paper's headline deltas: (max−med)/max and (med−min)/med.
    pub fn spread(&self) -> (f64, f64) {
        let (max, med, min) = (self.max_uj(), self.med_uj(), self.min_uj());
        ((max - med) / max, (med - min) / med)
    }
}

/// Run the Fig. 3 experiment: `n` random mappings of `layer` on `acc`.
pub fn random_distribution(
    layer: &Layer,
    acc: &Accelerator,
    n: usize,
    seed: u64,
) -> RandomDistribution {
    assert!(n >= 3);
    let mut rng = SplitMix64::new(seed);
    let mut ctx = EvalContext::new(layer, acc);
    // Keep only (energy, mapping) per draw — the three representative
    // evaluations are recomputed after sorting (deterministic model), so
    // the sweep itself stays on the zero-allocation context path.
    let mut evals: Vec<(f64, Mapping)> = (0..n)
        .map(|_| {
            let m = sample_random(layer, acc, &mut rng);
            let uj = ctx.evaluate_into(&m).energy.total_uj();
            (uj, m)
        })
        .collect();
    evals.sort_by(|a, b| a.0.total_cmp(&b.0));
    let energies_uj: Vec<f64> = evals.iter().map(|(uj, _)| *uj).collect();
    let min = ctx.evaluate_into(&evals.first().unwrap().1).clone();
    let med = ctx.evaluate_into(&evals[evals.len() / 2].1).clone();
    let max = ctx.evaluate_into(&evals.last().unwrap().1).clone();
    RandomDistribution { energies_uj, min, med, max }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::zoo;

    #[test]
    fn best_of_n_improves_with_n() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let e1 = RandomMapper::new(1, 42).run(&layer, &acc).unwrap();
        let e64 = RandomMapper::new(64, 42).run(&layer, &acc).unwrap();
        assert!(e64.evaluation.energy.total_pj() <= e1.evaluation.energy.total_pj());
        assert_eq!(e64.evaluations, 64);
    }

    #[test]
    fn parallel_and_pruned_runs_match_the_serial_mapping() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let base = RandomMapper::new(200, 9).run(&layer, &acc).unwrap();
        for threads in [2usize, 4, 8] {
            let out = RandomMapper::new(200, 9).with_threads(threads).run(&layer, &acc).unwrap();
            assert_eq!(out.mapping, base.mapping, "threads={threads}");
        }
        let pruned = RandomMapper::new(200, 9).with_pruning().run(&layer, &acc).unwrap();
        assert_eq!(pruned.mapping, base.mapping);
    }

    #[test]
    fn distribution_is_ordered_and_wide() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let d = random_distribution(&layer, &acc, 200, 7);
        assert!(d.min_uj() <= d.med_uj() && d.med_uj() <= d.max_uj());
        // The paper's Fig. 3 point: the spread is large (77% / 90% there).
        let (hi, lo) = d.spread();
        assert!(hi > 0.2, "max→med spread too small: {hi}");
        assert!(lo > 0.2, "med→min spread too small: {lo}");
    }

    #[test]
    fn distribution_deterministic_by_seed() {
        let acc = presets::shidiannao();
        let layer = zoo::vgg16()[0].clone();
        let a = random_distribution(&layer, &acc, 50, 9);
        let b = random_distribution(&layer, &acc, 50, 9);
        assert_eq!(a.energies_uj, b.energies_uj);
    }
}
