//! Random mapping — the paper's §3 motivation experiment (Fig. 3) and the
//! best-of-N random baseline.

use super::{MapError, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::mapspace::sample_random;
use crate::model::{EvalContext, Evaluation};
use crate::util::rng::SplitMix64;
use crate::workload::ConvLayer;

/// Best-energy-of-N random mapper.
#[derive(Debug, Clone)]
pub struct RandomMapper {
    /// Number of random candidates to draw.
    pub samples: u64,
    /// PRNG seed (deterministic across runs).
    pub seed: u64,
}

impl RandomMapper {
    /// Best-of-`samples` random mapper with the given seed.
    pub fn new(samples: u64, seed: u64) -> Self {
        assert!(samples > 0);
        Self { samples, seed }
    }
}

impl Mapper for RandomMapper {
    fn name(&self) -> String {
        format!("random×{}", self.samples)
    }

    fn evaluations(&self) -> u64 {
        self.samples
    }

    fn map(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<Mapping, MapError> {
        let mut rng = SplitMix64::new(self.seed);
        let mut ctx = EvalContext::new(layer, acc);
        let mut best: Option<(f64, Mapping)> = None;
        for _ in 0..self.samples {
            let m = sample_random(layer, acc, &mut rng);
            let pj = ctx.energy_pj(&m);
            if best.as_ref().map(|(b, _)| pj < *b).unwrap_or(true) {
                best = Some((pj, m));
            }
        }
        Ok(best.expect("samples > 0").1)
    }
}

/// Fig. 3 distribution: energy of `n` random mappings, classified into the
/// paper's `random_max` / `random_med` / `random_min` cases.
#[derive(Debug, Clone)]
pub struct RandomDistribution {
    /// Sorted ascending, µJ.
    pub energies_uj: Vec<f64>,
    /// The evaluation behind the minimum-energy mapping.
    pub min: Evaluation,
    /// The evaluation behind the median-energy mapping.
    pub med: Evaluation,
    /// The evaluation behind the maximum-energy mapping.
    pub max: Evaluation,
}

impl RandomDistribution {
    /// Minimum energy, µJ (`random_min`).
    pub fn min_uj(&self) -> f64 {
        self.energies_uj[0]
    }

    /// Median energy, µJ (`random_med`).
    pub fn med_uj(&self) -> f64 {
        self.energies_uj[self.energies_uj.len() / 2]
    }

    /// Maximum energy, µJ (`random_max`).
    pub fn max_uj(&self) -> f64 {
        *self.energies_uj.last().unwrap()
    }

    /// The paper's headline deltas: (max−med)/max and (med−min)/med.
    pub fn spread(&self) -> (f64, f64) {
        let (max, med, min) = (self.max_uj(), self.med_uj(), self.min_uj());
        ((max - med) / max, (med - min) / med)
    }
}

/// Run the Fig. 3 experiment: `n` random mappings of `layer` on `acc`.
pub fn random_distribution(
    layer: &ConvLayer,
    acc: &Accelerator,
    n: usize,
    seed: u64,
) -> RandomDistribution {
    assert!(n >= 3);
    let mut rng = SplitMix64::new(seed);
    let mut ctx = EvalContext::new(layer, acc);
    // Keep only (energy, mapping) per draw — the three representative
    // evaluations are recomputed after sorting (deterministic model), so
    // the sweep itself stays on the zero-allocation context path.
    let mut evals: Vec<(f64, Mapping)> = (0..n)
        .map(|_| {
            let m = sample_random(layer, acc, &mut rng);
            let uj = ctx.evaluate_into(&m).energy.total_uj();
            (uj, m)
        })
        .collect();
    evals.sort_by(|a, b| a.0.total_cmp(&b.0));
    let energies_uj: Vec<f64> = evals.iter().map(|(uj, _)| *uj).collect();
    let min = ctx.evaluate_into(&evals.first().unwrap().1).clone();
    let med = ctx.evaluate_into(&evals[evals.len() / 2].1).clone();
    let max = ctx.evaluate_into(&evals.last().unwrap().1).clone();
    RandomDistribution { energies_uj, min, med, max }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::zoo;

    #[test]
    fn best_of_n_improves_with_n() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let e1 = RandomMapper::new(1, 42).run(&layer, &acc).unwrap();
        let e64 = RandomMapper::new(64, 42).run(&layer, &acc).unwrap();
        assert!(e64.evaluation.energy.total_pj() <= e1.evaluation.energy.total_pj());
        assert_eq!(e64.evaluations, 64);
    }

    #[test]
    fn distribution_is_ordered_and_wide() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let d = random_distribution(&layer, &acc, 200, 7);
        assert!(d.min_uj() <= d.med_uj() && d.med_uj() <= d.max_uj());
        // The paper's Fig. 3 point: the spread is large (77% / 90% there).
        let (hi, lo) = d.spread();
        assert!(hi > 0.2, "max→med spread too small: {hi}");
        assert!(lo > 0.2, "med→min spread too small: {lo}");
    }

    #[test]
    fn distribution_deterministic_by_seed() {
        let acc = presets::shidiannao();
        let layer = zoo::vgg16()[0].clone();
        let a = random_distribution(&layer, &acc, 50, 9);
        let b = random_distribution(&layer, &acc, 50, 9);
        assert_eq!(a.energies_uj, b.energies_uj);
    }
}
