//! The LOCAL mapping algorithm — the paper's contribution (§5, Fig. 4).
//!
//! One pass, three phases, no search:
//!
//! 1. **Parallelization** (Fig. 4 lines 1–9): the two "effective" dims of
//!    the accelerator style are mapped spatially — NVDLA-style: `C →
//!    spatial-X (Rang m)`, `M → spatial-Y (Rang n)`; Eyeriss-style: `Q → X`,
//!    `S → Y`; ShiDianNao-style (output-stationary grid, Fig. 5): `Q → X`,
//!    `P → Y`. Spatial factors are the largest divisors of the dim bounds
//!    that fit the array (the divisor-exact reading of `Rang(m)` — see
//!    DESIGN.md §4).
//! 2. **Assignment** (lines 10–16): the remaining (temporal) ranges are
//!    assigned to storage levels with priority from the lowest level up,
//!    each level greedily taking the largest ranges that satisfy the
//!    bounding constraint Eq. (18).
//! 3. **Scheduling** (lines 17–22): per level, loops are permuted so
//!    higher-range loops sit innermost (toward the cheaper memory);
//!    reduction dims (C, R, S) win ties to keep partial sums local. The
//!    constant two-policy comparison runs through the shared
//!    [`SearchDriver`] as a two-candidate [`CandidateSource`], so it ranks
//!    by the configured [`Objective`] like every other mapper.
//!
//! Complexity: O(dims × levels × divisors) — a few microseconds; the
//! whole point of the paper (Table 3).

use super::engine::{CandidateSource, Objective, SearchDriver};
use super::{MapError, Mapper};
use crate::arch::{Accelerator, Style};
use crate::mapping::{tensor_footprint, Mapping};
use crate::util::factor::{divisors, factor_splits};
use crate::workload::{Dim, Layer, OpKind};

/// The LOCAL one-pass mapper.
#[derive(Debug, Clone, Copy, Default)]
pub struct LocalMapper {
    /// The objective ranking the two schedule candidates.
    pub objective: Objective,
}

/// The constant two-candidate schedule comparison, expressed as an engine
/// source: one tiling, two per-level permutation policies (range-descending
/// and reduction-first — DESIGN.md §4).
#[derive(Debug)]
struct ScheduleSource {
    base: Mapping,
    reduction_dims: &'static [Dim],
}

impl ScheduleSource {
    fn policy(&self, reduction_first: bool, m: &mut Mapping) {
        m.clone_from(&self.base);
        for l in 0..m.n_levels() {
            let mut dims = Dim::ALL;
            let t = m.temporal[l];
            dims.sort_by_key(|d| {
                let f = t[d.idx()];
                let reduction = self.reduction_dims.contains(d);
                if reduction_first {
                    (!reduction, std::cmp::Reverse(f), false)
                } else {
                    // Descending factor; reduction wins ties.
                    (false, std::cmp::Reverse(f), !reduction)
                }
            });
            m.permutation[l] = dims;
        }
    }
}

impl CandidateSource for ScheduleSource {
    fn n_blocks(&self) -> u64 {
        2
    }

    fn emit_block(&self, b: u64, m: &mut Mapping) -> bool {
        self.policy(b == 1, m);
        true
    }
}

impl LocalMapper {
    /// Construct the (stateless) LOCAL mapper at the default objective.
    pub fn new() -> Self {
        LocalMapper::default()
    }

    /// Builder: rank the schedule comparison by `objective`.
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// The style-dependent spatial dims (paper Fig. 5 / Fig. 4 lines 3–8):
    /// returns (X dim, Y dim). This is the conv assignment; for the
    /// operator-aware variant see [`LocalMapper::spatial_dims_for`].
    pub fn spatial_dims(style: Style) -> (Dim, Dim) {
        match style {
            Style::NvdlaLike => (Dim::C, Dim::M),
            Style::EyerissLike => (Dim::Q, Dim::S),
            Style::ShiDianNaoLike => (Dim::Q, Dim::P),
        }
    }

    /// Operator-aware spatial dims. Conv and depthwise layers keep the
    /// paper's Fig. 5 assignment verbatim (conv-path mappings are
    /// bit-identical to the Conv-only pipeline); other ops walk the
    /// style's preference order and pick the first two *live* dims of the
    /// projection (a dead dim — bound pinned to 1 — would waste the whole
    /// array axis; e.g. matmul on an Eyeriss grid gets rows on X and the
    /// `C` reduction on Y instead of the degenerate `Q`/`S` pair).
    pub fn spatial_dims_for(layer: &Layer, style: Style) -> (Dim, Dim) {
        let (dx, dy) = Self::spatial_dims(style);
        if matches!(layer.op, OpKind::Conv | OpKind::DepthwiseConv) {
            return (dx, dy);
        }
        let prefs_x: &[Dim] = match style {
            Style::NvdlaLike => &[Dim::C, Dim::Q, Dim::P, Dim::M],
            Style::EyerissLike => &[Dim::Q, Dim::P, Dim::C, Dim::M],
            Style::ShiDianNaoLike => &[Dim::Q, Dim::P, Dim::M],
        };
        let prefs_y: &[Dim] = match style {
            Style::NvdlaLike => &[Dim::M, Dim::P, Dim::Q],
            Style::EyerissLike => &[Dim::S, Dim::R, Dim::C, Dim::M, Dim::P],
            Style::ShiDianNaoLike => &[Dim::P, Dim::Q, Dim::M],
        };
        let live = |d: &Dim| layer.bound(*d) > 1;
        let x = prefs_x.iter().copied().find(live).unwrap_or(dx);
        let y = prefs_y
            .iter()
            .copied()
            .find(|d| live(d) && *d != x)
            .unwrap_or(if dy == x { dx } else { dy });
        (x, y)
    }
}

impl Mapper for LocalMapper {
    fn name(&self) -> String {
        "LOCAL".to_string()
    }

    fn objective(&self) -> Objective {
        self.objective
    }

    /// One construction pass + the constant two-candidate schedule
    /// comparison (DESIGN.md §4).
    fn evaluations(&self) -> u64 {
        2
    }

    fn map(&self, layer: &Layer, acc: &Accelerator) -> Result<Mapping, MapError> {
        let n_levels = acc.n_levels();
        let top = n_levels - 1;
        let mut m = Mapping {
            temporal: vec![[1u64; 7]; n_levels],
            permutation: vec![Dim::ALL; n_levels],
            spatial_x: [1; 7],
            spatial_y: [1; 7],
        };

        // ---- Phase 1: parallelization (operator-aware, Fig. 5 for conv).
        let (dx, dy) = Self::spatial_dims_for(layer, acc.style);
        debug_assert_ne!(dx, dy);
        let (sx, _) = factor_splits(layer.bound(dx), acc.pe.m);
        m.spatial_x[dx.idx()] = sx;
        let (sy, _) = factor_splits(layer.bound(dy), acc.pe.n);
        m.spatial_y[dy.idx()] = sy;

        // Residual (temporal) ranges per dim.
        let mut residual = layer.bounds();
        residual[dx.idx()] /= sx;
        residual[dy.idx()] /= sy;

        // ---- Phase 2: assignment, lowest level first (lines 11–16).
        // Walk dims in descending residual so large ranges land low.
        for l in 0..top {
            let capacity = acc.level_capacity(l);
            let mut order: Vec<usize> = (0..7).collect();
            order.sort_by_key(|&i| std::cmp::Reverse(residual[i]));
            for i in order {
                if residual[i] == 1 {
                    continue;
                }
                // Largest divisor of the residual whose tile still fits.
                for f in divisors(residual[i]).into_iter().rev() {
                    m.temporal[l][i] = f;
                    let footprint = if l == 0 {
                        tensor_footprint(layer, &m.tile0())
                    } else {
                        m.footprint(layer, l)
                    };
                    if footprint <= capacity {
                        residual[i] /= f;
                        break;
                    }
                    m.temporal[l][i] = 1;
                }
            }
        }

        // Leftovers go to DRAM (unbounded).
        for i in 0..7 {
            m.temporal[top][i] = residual[i];
        }

        // ---- Phase 3: scheduling (lines 18–22). The paper fixes the
        // level assignment ("higher range tensor to lower s_i") but leaves
        // the within-level loop order under-specified; we resolve it with
        // a constant-size comparison of the two natural policies (still
        // O(1) — 2 model evaluations through the shared engine):
        //   A. range-descending innermost (big loops near cheap memory);
        //   B. the op's reduction dims innermost (partial sums stationary;
        //      C,R,S for conv, C for matmul, R,S for pooling).
        let source = ScheduleSource { base: m, reduction_dims: layer.op.reduction_dims() };
        // LOCAL deliberately never takes a deadline: its O(1) two-candidate
        // pass is the guaranteed bottom rung of the degradation ladder
        // (DESIGN.md §14), so it must stay unconditionally runnable.
        let driver = SearchDriver {
            objective: self.objective,
            budget: 2,
            threads: 1,
            prune: false,
            deadline: None,
        };
        let best = driver.search(layer, acc, &source, &[]).ok_or_else(|| {
            MapError::NoValidMapping(format!(
                "LOCAL construction does not fit {} on {}",
                layer.name, acc.name
            ))
        })?;
        Ok(best.mapping)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::model::evaluate;
    use crate::workload::zoo;

    #[test]
    fn fig5_spatial_assignments() {
        assert_eq!(LocalMapper::spatial_dims(Style::NvdlaLike), (Dim::C, Dim::M));
        assert_eq!(LocalMapper::spatial_dims(Style::EyerissLike), (Dim::Q, Dim::S));
        assert_eq!(LocalMapper::spatial_dims(Style::ShiDianNaoLike), (Dim::Q, Dim::P));
    }

    #[test]
    fn maps_all_presets_and_workloads() {
        for acc in presets::all() {
            for row in zoo::table2_workloads() {
                let m = LocalMapper::new().map(&row.layer, &acc).unwrap_or_else(|e| {
                    panic!("LOCAL failed on {} × {}: {e}", row.layer.name, acc.name)
                });
                m.validate(&row.layer, &acc).unwrap();
            }
        }
    }

    #[test]
    fn nvdla_parallelizes_c_and_m_fully() {
        let acc = presets::nvdla(); // 16×16
        let layer = zoo::vgg16()[8].clone(); // C=M=512
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        assert_eq!(m.spatial_x[Dim::C.idx()], 16);
        assert_eq!(m.spatial_y[Dim::M.idx()], 16);
        assert!((m.pe_utilization(&acc) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eyeriss_parallelizes_q_and_s() {
        let acc = presets::eyeriss(); // 12×14
        let layer = zoo::vgg02()[4].clone(); // Q=56, S=3
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        assert_eq!(m.spatial_x[Dim::Q.idx()], 8); // largest divisor of 56 ≤ 12
        assert_eq!(m.spatial_y[Dim::S.idx()], 3);
    }

    #[test]
    fn shidiannao_parallelizes_output_pixels() {
        let acc = presets::shidiannao(); // 8×8
        let layer = zoo::vgg02()[4].clone(); // P=Q=56
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        assert_eq!(m.spatial_x[Dim::Q.idx()], 8);
        assert_eq!(m.spatial_y[Dim::P.idx()], 8);
        assert!((m.pe_utilization(&acc) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn assignment_fills_low_levels_first() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        // L0 is used (tile > 1 element in at least one dim).
        assert!(m.tile0().iter().product::<u64>() > 1, "{m}");
        // L1 (GLB) holds a substantially bigger tile than L0.
        let f0 = tensor_footprint(&layer, &m.tile0());
        let f1 = m.footprint(&layer, 1);
        assert!(f1 > f0);
        // Bounding honored (Eq. 18).
        assert!(f1 <= acc.level_capacity(1));
    }

    #[test]
    fn scheduling_follows_one_of_the_two_policies() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let m = LocalMapper::new().map(&layer, &acc).unwrap();
        for l in 0..m.n_levels() {
            let loops: Vec<(Dim, u64)> = m.loops(l).collect();
            // Policy A: factors descend monotonically.
            let desc = loops.windows(2).all(|w| w[0].1 >= w[1].1);
            // Policy B: all reduction dims precede all non-reduction dims,
            // descending within each class.
            let is_red = |d: Dim| matches!(d, Dim::C | Dim::R | Dim::S);
            let split = loops.iter().position(|&(d, _)| !is_red(d)).unwrap_or(loops.len());
            let red_first = loops[..split].iter().all(|&(d, _)| is_red(d))
                && loops[split..].iter().all(|&(d, _)| !is_red(d))
                && loops[..split].windows(2).all(|w| w[0].1 >= w[1].1)
                && loops[split..].windows(2).all(|w| w[0].1 >= w[1].1);
            assert!(desc || red_first, "level {l} follows neither policy: {m}");
        }
    }

    #[test]
    fn objective_changes_only_the_schedule_pick() {
        // Every objective yields a valid mapping with the same tiling
        // (phases 1–2 are objective-free; only the two-policy pick moves).
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let energy = LocalMapper::new().map(&layer, &acc).unwrap();
        for o in Objective::ALL {
            let m = LocalMapper::new().with_objective(o).map(&layer, &acc).unwrap();
            m.validate(&layer, &acc).unwrap();
            assert_eq!(m.temporal, energy.temporal, "{o}");
            assert_eq!(m.spatial_x, energy.spatial_x, "{o}");
            assert_eq!(m.spatial_y, energy.spatial_y, "{o}");
        }
    }

    #[test]
    fn one_pass_beats_trivial_mapping_on_energy() {
        for acc in presets::all() {
            let layer = zoo::vgg16()[8].clone();
            let local = LocalMapper::new().map(&layer, &acc).unwrap();
            let e_local = evaluate(&layer, &acc, &local).unwrap();
            let trivial = Mapping::trivial(&layer, acc.n_levels());
            let e_trivial = evaluate(&layer, &acc, &trivial).unwrap();
            assert!(
                e_local.energy.total_pj() < e_trivial.energy.total_pj(),
                "{}: LOCAL {} !< trivial {}",
                acc.name,
                e_local.energy.total_pj(),
                e_trivial.energy.total_pj()
            );
        }
    }

    #[test]
    fn deterministic() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let a = LocalMapper::new().map(&layer, &acc).unwrap();
        let b = LocalMapper::new().map(&layer, &acc).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn works_on_depthwise_layers() {
        let acc = presets::eyeriss();
        let dw = zoo::mobilenet_v2().into_iter().find(|l| l.is_depthwise()).unwrap();
        let m = LocalMapper::new().map(&dw, &acc).unwrap();
        m.validate(&dw, &acc).unwrap();
    }

    #[test]
    fn conv_spatial_dims_unchanged_by_op_awareness() {
        // The conv path must keep the Fig. 5 assignment verbatim — even
        // for 1×1 convs whose S bound is dead (bit-identity requirement).
        let one_by_one = Layer::new("c1x1", 64, 32, 1, 1, 14, 14);
        for style in [Style::NvdlaLike, Style::EyerissLike, Style::ShiDianNaoLike] {
            assert_eq!(
                LocalMapper::spatial_dims_for(&one_by_one, style),
                LocalMapper::spatial_dims(style)
            );
        }
    }

    #[test]
    fn matmul_spatial_dims_use_live_subset() {
        let mm = Layer::matmul("mm", 768, 768, 128);
        // NVDLA keeps (C, M) — both live for matmul.
        assert_eq!(LocalMapper::spatial_dims_for(&mm, Style::NvdlaLike), (Dim::C, Dim::M));
        // Eyeriss substitutes the dead Q/S pair with rows × reduction.
        assert_eq!(LocalMapper::spatial_dims_for(&mm, Style::EyerissLike), (Dim::P, Dim::C));
        // ShiDianNao: rows on X, output features on Y.
        assert_eq!(LocalMapper::spatial_dims_for(&mm, Style::ShiDianNaoLike), (Dim::P, Dim::M));
        // The chosen pair never collides.
        for l in [
            Layer::matmul("mm1", 64, 1, 7),
            Layer::pooling("p", 64, 2, 14, 14),
            Layer::elementwise("e", 64, 14, 14),
            Layer::elementwise("tiny", 1, 1, 1),
        ] {
            for style in [Style::NvdlaLike, Style::EyerissLike, Style::ShiDianNaoLike] {
                let (x, y) = LocalMapper::spatial_dims_for(&l, style);
                assert_ne!(x, y, "{} on {style:?}", l.name);
            }
        }
    }

    #[test]
    fn maps_every_op_kind_on_every_preset() {
        let layers = [
            Layer::matmul("mm", 768, 768, 128),
            Layer::matmul("ffn", 3072, 768, 128),
            Layer::pooling("pool", 64, 2, 112, 112).with_stride(2),
            Layer::elementwise("add", 256, 28, 28),
        ];
        for acc in presets::all() {
            for layer in &layers {
                let m = LocalMapper::new().map(layer, &acc).unwrap_or_else(|e| {
                    panic!("LOCAL failed on {} × {}: {e}", layer.name, acc.name)
                });
                m.validate(layer, &acc).unwrap();
                // Live-subset parallelization engages at least one axis for
                // these amply-sized layers.
                assert!(m.spatial_x_used() * m.spatial_y_used() > 1, "{}", layer.name);
            }
        }
    }
}
