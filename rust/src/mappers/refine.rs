//! LOCAL + refinement — the natural extension the paper's conclusion
//! gestures at: keep LOCAL's one-pass construction as the seed, then spend
//! a *small, bounded* budget hill-climbing around it. Quantifies how much
//! energy the single pass leaves on the table (ablation bench
//! `mapper_quality`).

use super::local::LocalMapper;
use super::{MapError, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::mapspace::repair;
use crate::model::EvalContext;
use crate::util::rng::SplitMix64;
use crate::workload::ConvLayer;
use std::cell::Cell;

/// Greedy hill-climbing around the LOCAL seed: try factor migrations and
/// permutation swaps, keep strict improvements, stop after `budget` trials
/// or `patience` consecutive rejections.
#[derive(Debug, Clone)]
pub struct LocalRefined {
    /// Hard cap on candidate evaluations (including the LOCAL seed).
    pub budget: u64,
    /// Consecutive rejections before stopping early.
    pub patience: u64,
    /// PRNG seed (deterministic across runs).
    pub seed: u64,
    evaluated: Cell<u64>,
}

impl LocalRefined {
    /// Refiner around the LOCAL seed with the given budget and seed.
    pub fn new(budget: u64, seed: u64) -> Self {
        assert!(budget > 0);
        Self { budget, patience: budget / 3 + 1, seed, evaluated: Cell::new(0) }
    }
}

impl Mapper for LocalRefined {
    fn name(&self) -> String {
        format!("LOCAL+refine({})", self.budget)
    }

    fn evaluations(&self) -> u64 {
        self.evaluated.get()
    }

    fn map(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<Mapping, MapError> {
        let seed_mapping = LocalMapper::new().map(layer, acc)?;
        let mut ctx = EvalContext::new(layer, acc);
        let mut best = seed_mapping;
        let mut best_e = ctx.energy_pj(&best);
        let mut evaluated = 1u64 + 2; // LOCAL's own schedule comparison
        let mut rng = SplitMix64::new(self.seed);
        let mut rejected = 0u64;
        let n_levels = best.n_levels();
        while evaluated < self.budget && rejected < self.patience {
            let mut cand = best.clone();
            match rng.next_below(3) {
                0 => {
                    // Migrate a prime factor one level outward/inward.
                    let d = rng.index(7);
                    let l = rng.index(n_levels - 1);
                    let (a, b) = if rng.next_below(2) == 0 { (l, l + 1) } else { (l + 1, l) };
                    if cand.temporal[a][d] > 1 {
                        let f = smallest_prime(cand.temporal[a][d]);
                        cand.temporal[a][d] /= f;
                        cand.temporal[b][d] *= f;
                    }
                }
                1 => {
                    // Swap adjacent loops at one level.
                    let l = rng.index(n_levels);
                    let i = rng.index(6);
                    cand.permutation[l].swap(i, i + 1);
                }
                _ => {
                    // Grow a spatial slot from the top temporal level.
                    let d = rng.index(7);
                    let top = n_levels - 1;
                    if cand.temporal[top][d] > 1 {
                        let f = smallest_prime(cand.temporal[top][d]);
                        cand.temporal[top][d] /= f;
                        if rng.next_below(2) == 0 {
                            cand.spatial_x[d] *= f;
                        } else {
                            cand.spatial_y[d] *= f;
                        }
                    }
                }
            }
            repair(layer, acc, &mut cand);
            if cand.validate(layer, acc).is_err() {
                rejected += 1;
                continue;
            }
            let e = ctx.energy_pj(&cand);
            evaluated += 1;
            if e < best_e {
                best = cand;
                best_e = e;
                rejected = 0;
            } else {
                rejected += 1;
            }
        }
        self.evaluated.set(evaluated);
        Ok(best)
    }
}

fn smallest_prime(n: u64) -> u64 {
    let mut i = 2;
    while i * i <= n {
        if n % i == 0 {
            return i;
        }
        i += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::zoo;

    #[test]
    fn refine_never_worse_than_local() {
        for acc in presets::all() {
            for row in zoo::table2_workloads() {
                let local = LocalMapper::new().run(&row.layer, &acc).unwrap();
                let refined = LocalRefined::new(150, 42).run(&row.layer, &acc).unwrap();
                assert!(
                    refined.evaluation.energy.total_pj() <= local.evaluation.energy.total_pj() + 1e-9,
                    "{} on {}: refine {} > local {}",
                    row.layer.name,
                    acc.name,
                    refined.evaluation.energy.total_pj(),
                    local.evaluation.energy.total_pj()
                );
            }
        }
    }

    #[test]
    fn refine_respects_budget() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let r = LocalRefined::new(50, 1);
        r.run(&layer, &acc).unwrap();
        assert!(r.evaluations() <= 50 + 3);
    }

    #[test]
    fn refined_mapping_valid() {
        let acc = presets::shidiannao();
        let layer = zoo::squeezenet()[0].clone();
        let m = LocalRefined::new(200, 7).map(&layer, &acc).unwrap();
        m.validate(&layer, &acc).unwrap();
    }
}
