//! LOCAL + refinement — the natural extension the paper's conclusion
//! gestures at: keep LOCAL's one-pass construction as the seed, then spend
//! a *small, bounded* budget hill-climbing around it. Quantifies how much
//! of the objective the single pass leaves on the table (ablation bench
//! `mapper_quality`).
//!
//! The climb is an engine [`BatchSource`]: the LOCAL seed is candidate 0,
//! each later proposal mutates the incumbent, and the shared
//! [`SearchDriver`] owns the budget, validity filtering, scoring and best
//! tracking (greedy: only strict improvements move the incumbent).

use super::engine::{deadline_instant, BatchSource, Objective, SearchDriver};
use super::local::LocalMapper;
use super::{MapError, MapStatus, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::mapspace::repair;
use crate::util::rng::SplitMix64;
use crate::workload::Layer;
use std::cell::Cell;

/// Greedy hill-climbing around the LOCAL seed: try factor migrations and
/// permutation swaps, keep strict improvements, stop after `budget` trials
/// or `patience` consecutive rejections.
#[derive(Debug, Clone)]
pub struct LocalRefined {
    /// Hard cap on candidate evaluations (including the LOCAL seed).
    pub budget: u64,
    /// Consecutive rejections before stopping early.
    pub patience: u64,
    /// PRNG seed (deterministic across runs).
    pub seed: u64,
    /// The objective being climbed.
    pub objective: Objective,
    /// Per-layer wall-clock deadline, ms (`None` = unbounded).
    pub deadline_ms: Option<u64>,
    evaluated: Cell<u64>,
    degraded: Cell<bool>,
}

impl LocalRefined {
    /// Refiner around the LOCAL seed with the given budget and seed.
    pub fn new(budget: u64, seed: u64) -> Self {
        assert!(budget > 0);
        Self {
            budget,
            patience: budget / 3 + 1,
            seed,
            objective: Objective::Energy,
            deadline_ms: None,
            evaluated: Cell::new(0),
            degraded: Cell::new(false),
        }
    }

    /// Refiner configured from shared engine params.
    pub fn from_params(params: &super::SearchParams) -> Self {
        let mut m = Self::new(params.budget, params.seed);
        m.objective = params.objective;
        m.deadline_ms = params.deadline_ms;
        m
    }

    /// Builder: minimize `objective` instead of energy.
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }
}

/// The greedy climb as an engine source: tracks the incumbent from the
/// driver's feedback and proposes one mutated neighbour per batch. The
/// budget counts **scored** candidates (invalid proposals only burn
/// patience, like the pre-engine loop), so the source owns the stop
/// condition and the driver's proposal cap stays open.
struct Climb<'a> {
    layer: &'a Layer,
    acc: &'a Accelerator,
    rng: SplitMix64,
    budget: u64,
    scored: u64,
    patience: u64,
    rejected: u64,
    seed_mapping: Option<Mapping>,
    /// Incumbent `(mapping, score)` rebuilt from feedback.
    best: Option<(Mapping, f64)>,
    /// Proposal awaiting feedback.
    proposed: Option<Mapping>,
}

impl BatchSource for Climb<'_> {
    fn next_batch(&mut self, feedback: &[Option<f64>], out: &mut Vec<Mapping>) {
        if let Some(prev) = self.proposed.take() {
            let fb = feedback.first().copied().flatten();
            if fb.is_some() {
                self.scored += 1;
            }
            let improved = match fb {
                Some(score) => self.best.as_ref().map(|(_, b)| score < *b).unwrap_or(true),
                None => false,
            };
            if improved {
                self.best = Some((prev, fb.expect("improvement implies a score")));
                self.rejected = 0;
            } else {
                self.rejected += 1;
                if self.rejected >= self.patience {
                    return;
                }
            }
        }
        if self.scored >= self.budget {
            return;
        }
        if let Some(seed) = self.seed_mapping.take() {
            // Candidate 0 is the LOCAL seed itself.
            self.proposed = Some(seed.clone());
            out.push(seed);
            return;
        }
        let Some((best, _)) = &self.best else {
            return; // seed never scored — give up
        };
        let mut cand = best.clone();
        self.mutate(&mut cand);
        self.proposed = Some(cand.clone());
        out.push(cand);
    }
}

impl Climb<'_> {
    fn mutate(&mut self, cand: &mut Mapping) {
        let n_levels = cand.n_levels();
        let rng = &mut self.rng;
        match rng.next_below(3) {
            0 => {
                // Migrate a prime factor one level outward/inward.
                let d = rng.index(7);
                let l = rng.index(n_levels - 1);
                let (a, b) = if rng.next_below(2) == 0 { (l, l + 1) } else { (l + 1, l) };
                if cand.temporal[a][d] > 1 {
                    let f = smallest_prime(cand.temporal[a][d]);
                    cand.temporal[a][d] /= f;
                    cand.temporal[b][d] *= f;
                }
            }
            1 => {
                // Swap adjacent loops at one level.
                let l = rng.index(n_levels);
                let i = rng.index(6);
                cand.permutation[l].swap(i, i + 1);
            }
            _ => {
                // Grow a spatial slot from the top temporal level.
                let d = rng.index(7);
                let top = n_levels - 1;
                if cand.temporal[top][d] > 1 {
                    let f = smallest_prime(cand.temporal[top][d]);
                    cand.temporal[top][d] /= f;
                    if rng.next_below(2) == 0 {
                        cand.spatial_x[d] *= f;
                    } else {
                        cand.spatial_y[d] *= f;
                    }
                }
            }
        }
        repair(self.layer, self.acc, cand);
    }
}

fn smallest_prime(n: u64) -> u64 {
    let mut i = 2;
    while i * i <= n {
        if n % i == 0 {
            return i;
        }
        i += 1;
    }
    n
}

impl Mapper for LocalRefined {
    fn name(&self) -> String {
        format!("LOCAL+refine({})", self.budget)
    }

    fn objective(&self) -> Objective {
        self.objective
    }

    fn evaluations(&self) -> u64 {
        self.evaluated.get()
    }

    fn status(&self) -> MapStatus {
        if self.degraded.get() {
            MapStatus::Degraded { reason: "deadline expired mid-search".into() }
        } else {
            MapStatus::Ok
        }
    }

    fn map(&self, layer: &Layer, acc: &Accelerator) -> Result<Mapping, MapError> {
        self.map_seeded(layer, acc, &[])
    }

    fn accepts_seeds(&self) -> bool {
        true
    }

    /// Cross-layer seeds are merged into the *result only* — the climb
    /// still starts from LOCAL's mapping and walks exactly as unseeded, so
    /// the returned mapping is `min(climb best, seeds)` and never worse
    /// than the unseeded run (DESIGN.md §15).
    fn map_seeded(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        seeds: &[Mapping],
    ) -> Result<Mapping, MapError> {
        self.degraded.set(false);
        let seed_mapping =
            LocalMapper::new().with_objective(self.objective).map(layer, acc)?;
        let mut climb = Climb {
            layer,
            acc,
            rng: SplitMix64::new(self.seed),
            budget: self.budget,
            scored: 0,
            patience: self.patience,
            rejected: 0,
            seed_mapping: Some(seed_mapping),
            best: None,
            proposed: None,
        };
        // The climb self-limits on *scored* candidates (see `Climb`), so
        // the driver's proposal cap stays above any realistic
        // invalid-proposal overhead.
        let driver = SearchDriver {
            objective: self.objective,
            budget: self.budget.saturating_mul(4).saturating_add(8),
            threads: 1,
            prune: false,
            deadline: deadline_instant(self.deadline_ms),
        };
        match driver.search_batched_seeded(layer, acc, &mut climb, seeds) {
            Some(b) => {
                // + LOCAL's own two-candidate schedule comparison.
                self.evaluated.set(b.scored + 2);
                self.degraded.set(b.degraded);
                Ok(b.mapping)
            }
            None => Err(MapError::NoValidMapping("refinement seed failed validation".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::zoo;

    #[test]
    fn refine_never_worse_than_local() {
        for acc in presets::all() {
            for row in zoo::table2_workloads() {
                let local = LocalMapper::new().run(&row.layer, &acc).unwrap();
                let refined = LocalRefined::new(150, 42).run(&row.layer, &acc).unwrap();
                assert!(
                    refined.evaluation.energy.total_pj() <= local.evaluation.energy.total_pj() + 1e-9,
                    "{} on {}: refine {} > local {}",
                    row.layer.name,
                    acc.name,
                    refined.evaluation.energy.total_pj(),
                    local.evaluation.energy.total_pj()
                );
            }
        }
    }

    #[test]
    fn refine_respects_budget() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let r = LocalRefined::new(50, 1);
        r.run(&layer, &acc).unwrap();
        assert!(r.evaluations() <= 50 + 3);
    }

    #[test]
    fn refine_climbs_the_configured_objective() {
        // A delay-objective climb never ends slower than the LOCAL seed.
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let local = LocalMapper::new().with_objective(Objective::Delay).run(&layer, &acc).unwrap();
        let refined = LocalRefined::new(200, 3)
            .with_objective(Objective::Delay)
            .run(&layer, &acc)
            .unwrap();
        assert!(refined.evaluation.latency_cycles <= local.evaluation.latency_cycles);
    }

    #[test]
    fn refined_mapping_valid() {
        let acc = presets::shidiannao();
        let layer = zoo::squeezenet()[0].clone();
        let m = LocalRefined::new(200, 7).map(&layer, &acc).unwrap();
        m.validate(&layer, &acc).unwrap();
    }
}
