//! Exhaustive (brute-force) mapper — the §3 "48 hours for one layer"
//! straw man, usable here only on small layers / truncated budgets.
//! Serves as the test oracle: on layers where full enumeration is
//! feasible, no other mapper may beat it.

use super::{MapError, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::model::evaluate_unchecked;
use crate::util::factor::factorizations;
use crate::workload::{ConvLayer, Dim};
use std::cell::Cell;

/// Deterministic enumeration of the factorization space (canonical
/// permutations; optionally a rotation set) with best-energy selection.
#[derive(Debug, Clone)]
pub struct ExhaustiveMapper {
    /// Stop after this many candidates (the space explodes quickly).
    pub max_candidates: u64,
    /// Also try rotated per-level permutations (×7 candidates).
    pub permute: bool,
    evaluated: Cell<u64>,
}

impl ExhaustiveMapper {
    /// Enumerator truncated at `max_candidates` evaluations.
    pub fn new(max_candidates: u64) -> Self {
        Self { max_candidates, permute: false, evaluated: Cell::new(0) }
    }

    /// Builder: also enumerate the rotation set of per-level permutations.
    pub fn with_permutations(mut self) -> Self {
        self.permute = true;
        self
    }

    /// Size of the factorization space this would enumerate.
    pub fn space_size(layer: &ConvLayer, acc: &Accelerator) -> u64 {
        Dim::ALL
            .iter()
            .map(|&d| {
                crate::util::factor::count_factorizations(layer.bound(d), acc.n_levels() + 2)
            })
            .product()
    }
}

impl Mapper for ExhaustiveMapper {
    fn name(&self) -> String {
        "exhaustive".to_string()
    }

    fn evaluations(&self) -> u64 {
        self.evaluated.get()
    }

    fn map(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<Mapping, MapError> {
        let n_levels = acc.n_levels();
        let slots = n_levels + 2; // spatial X, spatial Y, temporal levels
        // Per-dim ordered factorizations across slots:
        // [sx, sy, t0, t1, ..., t_top].
        let per_dim: Vec<Vec<Vec<u64>>> =
            Dim::ALL.iter().map(|&d| factorizations(layer.bound(d), slots)).collect();

        // Odometer over the per-dim choices.
        let mut idx = [0usize; 7];
        let mut evaluated = 0u64;
        let mut best: Option<(f64, Mapping)> = None;
        'outer: loop {
            // Assemble the candidate.
            let mut m = Mapping {
                temporal: vec![[1u64; 7]; n_levels],
                permutation: vec![Dim::ALL; n_levels],
                spatial_x: [1; 7],
                spatial_y: [1; 7],
            };
            for d in 0..7 {
                let split = &per_dim[d][idx[d]];
                m.spatial_x[d] = split[0];
                m.spatial_y[d] = split[1];
                for l in 0..n_levels {
                    m.temporal[l][d] = split[2 + l];
                }
            }
            let perms: u64 = if self.permute { 7 } else { 1 };
            for rot in 0..perms {
                let mut cand = m.clone();
                for l in 0..n_levels {
                    cand.permutation[l].rotate_left(rot as usize);
                }
                if cand.validate(layer, acc).is_ok() {
                    let e = evaluate_unchecked(layer, acc, &cand);
                    let pj = e.energy.total_pj();
                    if best.as_ref().map(|(b, _)| pj < *b).unwrap_or(true) {
                        best = Some((pj, cand));
                    }
                }
                evaluated += 1;
                if evaluated >= self.max_candidates {
                    break 'outer;
                }
            }
            // Advance the odometer.
            let mut d = 0;
            loop {
                idx[d] += 1;
                if idx[d] < per_dim[d].len() {
                    break;
                }
                idx[d] = 0;
                d += 1;
                if d == 7 {
                    break 'outer;
                }
            }
        }
        self.evaluated.set(evaluated);
        best.map(|(_, m)| m)
            .ok_or_else(|| MapError::NoValidMapping("exhaustive found no valid mapping".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::arch::{Accelerator, Noc, PeArray, StorageLevel, Style};
    use crate::mappers::LocalMapper;

    fn small_acc() -> Accelerator {
        Accelerator {
            name: "small".into(),
            style: Style::NvdlaLike,
            datawidth_bits: 16,
            levels: vec![
                StorageLevel::register_file("RF", 64, 16),
                StorageLevel::buffer("GLB", 1024, 64),
                StorageLevel::dram(64),
            ],
            pe: PeArray::new(4, 4),
            noc: Noc::default(),
            mac_energy_pj: 1.0,
            clock_mhz: 200.0,
        }
    }

    fn small_layer() -> ConvLayer {
        ConvLayer::new("small", 8, 4, 3, 3, 8, 8)
    }

    #[test]
    fn enumerates_and_finds_valid_best() {
        let acc = small_acc();
        let layer = small_layer();
        let ex = ExhaustiveMapper::new(200_000);
        let out = ex.run(&layer, &acc).unwrap();
        out.mapping.validate(&layer, &acc).unwrap();
        assert!(out.evaluations > 1000);
    }

    #[test]
    fn oracle_no_mapper_beats_full_enumeration() {
        let acc = small_acc();
        let layer = ConvLayer::new("tiny", 4, 2, 1, 1, 4, 4);
        let size = ExhaustiveMapper::space_size(&layer, &acc);
        assert!(size < 2_000_000, "space too big for oracle test: {size}");
        let ex = ExhaustiveMapper::new(size).with_permutations();
        let best = ex.run(&layer, &acc).unwrap();
        let local = LocalMapper::new().run(&layer, &acc).unwrap();
        assert!(
            local.evaluation.energy.total_pj() >= best.evaluation.energy.total_pj() * 0.999,
            "LOCAL ({}) beat the exhaustive oracle ({})",
            local.evaluation.energy.total_pj(),
            best.evaluation.energy.total_pj()
        );
    }

    #[test]
    fn space_size_matches_paper_scale() {
        // The §3 example: mapping spaces are astronomically large even
        // before permutations.
        let acc = presets::eyeriss();
        let layer = crate::workload::zoo::vgg02()[4].clone();
        assert!(ExhaustiveMapper::space_size(&layer, &acc) > 1_000_000_000);
    }
}
