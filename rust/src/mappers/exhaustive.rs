//! Exhaustive (brute-force) mapper — the §3 "48 hours for one layer"
//! straw man, usable here only on small layers / truncated budgets.
//! Serves as the test oracle: on layers where full enumeration is
//! feasible, no other mapper may beat it.
//!
//! The enumeration itself is the engine's [`OdometerSource`]: per-dim
//! ordered splits, optionally fanned out into 7 rotated per-level
//! permutations per slot, every candidate carrying a stable global index.
//! The shared [`SearchDriver`] shards the (budget-truncated) block range
//! across scoped worker threads with a deterministic best-merge — lowest
//! objective score, exact tie broken by the lowest global index — so the
//! result is identical for every thread count (pinned by
//! `prop_parallel_exhaustive_matches_single_thread`).
//!
//! # Pruning
//!
//! By default the search **warm-starts** from the LOCAL mapping (scored
//! with a post-stream index, so exact ties still go to the enumerated
//! candidate) and lets the driver's bound-based pruner skip whole
//! permutation blocks whose [`crate::model::EvalContext::objective_bound`]
//! already exceeds the incumbent. Pruning never changes the selected
//! mapping, its evaluation or its tie-break index — it only cuts
//! evaluations (pinned by `prop_pruned_exhaustive_is_bit_identical` in
//! `rust/tests/property.rs`). [`ExhaustiveMapper::without_pruning`] and
//! [`ExhaustiveMapper::without_warm_start`] restore the raw enumeration
//! (the perf harness uses it to measure fixed-work thread scaling).

use super::engine::{
    deadline_instant, BoundedLattice, Objective, OdometerSource, SearchBest, SearchDriver,
};
use super::{LocalMapper, MapError, MapStatus, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::model::EvalContext;
use crate::util::factor::count_factorizations;
use crate::workload::{Dim, Layer};
use std::cell::Cell;

/// Deterministic enumeration of the factorization space (canonical
/// permutations; optionally a rotation set) with best-objective selection,
/// sharded across worker threads and bound-pruned by default.
#[derive(Debug, Clone)]
pub struct ExhaustiveMapper {
    /// Stop after this many candidates (the space explodes quickly).
    pub max_candidates: u64,
    /// Also try rotated per-level permutations (×7 candidates).
    pub permute: bool,
    /// Worker threads the odometer space is sharded across (≥ 1). The
    /// result — and every evaluation count — is identical for every value.
    pub threads: usize,
    /// The objective being minimized.
    pub objective: Objective,
    /// Bound-based block pruning (on by default; never changes the
    /// selected mapping).
    pub prune: bool,
    /// Warm-start the incumbent with the LOCAL mapping (on by default;
    /// candidate set = LOCAL seed ∪ truncated enumeration either way, so
    /// pruned and unpruned runs agree).
    pub warm_start: bool,
    /// Search via branch-and-bound over the factorization lattice
    /// ([`BoundedLattice`]) instead of the flat odometer, reporting
    /// certification when the budget admits the whole space (the
    /// `--certify` CLI flag). Same candidate space, same argmin and
    /// tie-break as the flat search.
    pub certify: bool,
    /// Per-layer wall-clock deadline, ms (`None` = unbounded).
    pub deadline_ms: Option<u64>,
    evaluated: Cell<u64>,
    pruned: Cell<u64>,
    certified: Cell<bool>,
    degraded: Cell<bool>,
}

impl ExhaustiveMapper {
    /// Enumerator truncated at `max_candidates` evaluations.
    pub fn new(max_candidates: u64) -> Self {
        Self {
            max_candidates,
            permute: false,
            threads: 1,
            objective: Objective::Energy,
            prune: true,
            warm_start: true,
            certify: false,
            deadline_ms: None,
            evaluated: Cell::new(0),
            pruned: Cell::new(0),
            certified: Cell::new(false),
            degraded: Cell::new(false),
        }
    }

    /// Enumerator configured from shared engine params.
    pub fn from_params(params: &super::SearchParams) -> Self {
        let mut e = Self::new(params.budget);
        e.threads = params.threads.max(1);
        e.objective = params.objective;
        e.prune = params.prune;
        e.certify = params.certify;
        e.deadline_ms = params.deadline_ms;
        e
    }

    /// Builder: search via branch-and-bound and report certification.
    pub fn with_certification(mut self) -> Self {
        self.certify = true;
        self
    }

    /// Builder: also enumerate the rotation set of per-level permutations.
    pub fn with_permutations(mut self) -> Self {
        self.permute = true;
        self
    }

    /// Builder: shard the enumeration across `threads` workers.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Builder: minimize `objective` instead of energy.
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Builder: disable bound-based pruning (every in-budget candidate is
    /// materialized and checked — the historical accounting).
    pub fn without_pruning(mut self) -> Self {
        self.prune = false;
        self
    }

    /// Builder: drop the LOCAL warm-start seed (pure enumeration; pruning
    /// then only engages once the enumerated incumbent exists).
    pub fn without_warm_start(mut self) -> Self {
        self.warm_start = false;
        self
    }

    /// Candidates skipped by the pruner on the last `map` call.
    pub fn pruned(&self) -> u64 {
        self.pruned.get()
    }

    /// Run the configured search (flat odometer or branch-and-bound) with
    /// an optional external incumbent bound (DESIGN.md §15).
    fn run_search(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        bound: Option<f64>,
    ) -> (Option<SearchBest>, bool) {
        let driver = SearchDriver {
            objective: self.objective,
            budget: self.max_candidates,
            threads: self.threads,
            prune: self.prune,
            deadline: deadline_instant(self.deadline_ms),
        };
        let seeds: Vec<Mapping> = if self.warm_start {
            LocalMapper::new().map(layer, acc).into_iter().collect()
        } else {
            Vec::new()
        };
        if self.certify {
            let source = BoundedLattice::new(layer, acc, self.permute);
            driver.branch_and_bound_with_bound(layer, acc, &source, &seeds, bound)
        } else {
            let source = OdometerSource::new(layer, acc, self.permute);
            (driver.search_with_bound(layer, acc, &source, &seeds, bound), false)
        }
    }

    /// Record a finished search in the interior counters and unwrap it.
    fn finish(&self, best: Option<SearchBest>, certified: bool) -> Result<Mapping, MapError> {
        match best {
            Some(b) => {
                self.evaluated.set(b.examined);
                self.pruned.set(b.pruned);
                self.certified.set(certified);
                self.degraded.set(b.degraded);
                Ok(b.mapping)
            }
            None => {
                self.evaluated.set(0);
                self.pruned.set(0);
                self.certified.set(false);
                Err(MapError::NoValidMapping("exhaustive found no valid mapping".into()))
            }
        }
    }

    /// Size of the factorization space this would enumerate.
    pub fn space_size(layer: &Layer, acc: &Accelerator) -> u64 {
        Dim::ALL
            .iter()
            .map(|&d| count_factorizations(layer.bound(d), acc.n_levels() + 2))
            .product()
    }
}

impl Mapper for ExhaustiveMapper {
    fn name(&self) -> String {
        "exhaustive".to_string()
    }

    fn objective(&self) -> Objective {
        self.objective
    }

    fn evaluations(&self) -> u64 {
        self.evaluated.get()
    }

    fn certified(&self) -> bool {
        self.certified.get()
    }

    fn status(&self) -> MapStatus {
        if self.degraded.get() {
            MapStatus::Degraded { reason: "deadline expired mid-search".into() }
        } else {
            MapStatus::Ok
        }
    }

    fn map(&self, layer: &Layer, acc: &Accelerator) -> Result<Mapping, MapError> {
        self.degraded.set(false);
        let (best, certified) = self.run_search(layer, acc, None);
        self.finish(best, certified)
    }

    fn accepts_seeds(&self) -> bool {
        true
    }

    /// Cross-layer seeds tighten the incumbent as external *bounds only*
    /// — they never enter the candidate stream, so an accepted result is
    /// bit-identical to the unseeded run's argmin (DESIGN.md §15). When
    /// the bounded run's best scores above the bound (the adapted seed
    /// was better than anything in budget — the bound may have masked the
    /// true argmin), the search reruns unbounded and both runs' examined
    /// counts are summed for honest accounting.
    fn map_seeded(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        seeds: &[Mapping],
    ) -> Result<Mapping, MapError> {
        self.degraded.set(false);
        let mut ctx = EvalContext::new(layer, acc);
        let mut bound: Option<f64> = None;
        for s in seeds {
            if s.validate(layer, acc).is_ok() {
                let score = self.objective.score(ctx.evaluate_into(s));
                bound = Some(bound.map_or(score, |b: f64| b.min(score)));
            }
        }
        let Some(bd) = bound else {
            // No valid seed: identical to the unseeded path.
            let (best, certified) = self.run_search(layer, acc, None);
            return self.finish(best, certified);
        };
        let (best, certified) = self.run_search(layer, acc, bound);
        if best.as_ref().is_some_and(|b| b.score <= bd) {
            return self.finish(best, certified);
        }
        let (spent, spent_pruned) =
            best.as_ref().map_or((0, 0), |b| (b.examined, b.pruned));
        let (rerun, certified2) = self.run_search(layer, acc, None);
        match rerun {
            Some(mut b) => {
                b.examined += spent;
                b.pruned += spent_pruned;
                self.finish(Some(b), certified2)
            }
            // The unbounded rerun found nothing (e.g. a deadline expired
            // between the runs): keep the bounded incumbent rather than
            // discarding a valid mapping.
            None => self.finish(best, false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::arch::{Accelerator, Noc, PeArray, StorageLevel, Style};

    fn small_acc() -> Accelerator {
        Accelerator {
            name: "small".into(),
            style: Style::NvdlaLike,
            datawidth_bits: 16,
            levels: vec![
                StorageLevel::register_file("RF", 64, 16),
                StorageLevel::buffer("GLB", 1024, 64),
                StorageLevel::dram(64),
            ],
            pe: PeArray::new(4, 4),
            noc: Noc::default(),
            mac_energy_pj: 1.0,
            clock_mhz: 200.0,
        }
    }

    fn small_layer() -> Layer {
        Layer::new("small", 8, 4, 3, 3, 8, 8)
    }

    #[test]
    fn enumerates_and_finds_valid_best() {
        let acc = small_acc();
        let layer = small_layer();
        let ex = ExhaustiveMapper::new(200_000).without_pruning().without_warm_start();
        let out = ex.run(&layer, &acc).unwrap();
        out.mapping.validate(&layer, &acc).unwrap();
        assert!(out.evaluations > 1000);
    }

    #[test]
    fn oracle_no_mapper_beats_full_enumeration() {
        let acc = small_acc();
        let layer = Layer::new("tiny", 4, 2, 1, 1, 4, 4);
        let size = ExhaustiveMapper::space_size(&layer, &acc);
        assert!(size < 2_000_000, "space too big for oracle test: {size}");
        let ex = ExhaustiveMapper::new(size).with_permutations();
        let best = ex.run(&layer, &acc).unwrap();
        let local = LocalMapper::new().run(&layer, &acc).unwrap();
        assert!(
            local.evaluation.energy.total_pj() >= best.evaluation.energy.total_pj() * 0.999,
            "LOCAL ({}) beat the exhaustive oracle ({})",
            local.evaluation.energy.total_pj(),
            best.evaluation.energy.total_pj()
        );
    }

    #[test]
    fn sharded_enumeration_matches_single_thread() {
        // Same best mapping, same best score bits, same evaluation and
        // prune counts at every thread count — the deterministic-merge
        // contract, with pruning and warm-start at their defaults.
        let acc = small_acc();
        let layer = Layer::new("tiny", 4, 2, 1, 1, 4, 4);
        let serial = ExhaustiveMapper::new(40_000).with_permutations();
        let base = serial.run(&layer, &acc).unwrap();
        let base_pruned = serial.pruned();
        for threads in [2usize, 4, 8] {
            let par = ExhaustiveMapper::new(40_000).with_permutations().with_threads(threads);
            let out = par.run(&layer, &acc).unwrap();
            assert_eq!(out.mapping, base.mapping, "threads={threads}");
            assert_eq!(
                out.evaluation.energy.total_pj().to_bits(),
                base.evaluation.energy.total_pj().to_bits(),
                "threads={threads}"
            );
            assert_eq!(out.evaluations, base.evaluations, "threads={threads}");
            assert_eq!(par.pruned(), base_pruned, "threads={threads}");
        }
    }

    #[test]
    fn budget_truncation_is_thread_invariant() {
        // Without pruning, a budget that cuts mid-rotation evaluates
        // exactly the budgeted candidate set (plus the warm-start seed);
        // with pruning, the pruned + examined split is thread-invariant
        // and accounts for every in-budget candidate.
        let acc = small_acc();
        let layer = small_layer();
        let raw = ExhaustiveMapper::new(999).with_permutations().without_pruning();
        let base = raw.run(&layer, &acc).unwrap();
        assert_eq!(base.evaluations, 999 + 1); // + LOCAL warm-start seed
        let sharded =
            ExhaustiveMapper::new(999).with_permutations().without_pruning().with_threads(3);
        let out = sharded.run(&layer, &acc).unwrap();
        assert_eq!(out.evaluations, base.evaluations);
        assert_eq!(out.mapping, base.mapping);
        let pruned = ExhaustiveMapper::new(999).with_permutations().with_threads(3);
        let pout = pruned.run(&layer, &acc).unwrap();
        assert_eq!(pout.mapping, base.mapping);
        assert_eq!(pout.evaluations + pruned.pruned(), base.evaluations);
    }

    #[test]
    fn pruning_preserves_the_argmin_and_cuts_work() {
        let acc = small_acc();
        let layer = small_layer();
        let full = ExhaustiveMapper::new(50_000).with_permutations().without_pruning();
        let base = full.run(&layer, &acc).unwrap();
        let fast = ExhaustiveMapper::new(50_000).with_permutations();
        let out = fast.run(&layer, &acc).unwrap();
        assert_eq!(out.mapping, base.mapping);
        assert_eq!(
            out.evaluation.energy.total_pj().to_bits(),
            base.evaluation.energy.total_pj().to_bits()
        );
        assert!(out.evaluations <= base.evaluations);
        assert_eq!(out.evaluations + fast.pruned(), base.evaluations);
    }

    #[test]
    fn certified_search_matches_flat_enumeration() {
        let acc = small_acc();
        let layer = Layer::new("tiny", 4, 2, 1, 1, 4, 2);
        let budget = ExhaustiveMapper::space_size(&layer, &acc) * 7;
        let flat = ExhaustiveMapper::new(budget).with_permutations().without_pruning();
        let base = flat.run(&layer, &acc).unwrap();
        assert!(!base.certified, "flat enumeration never claims certification");
        let bnb = ExhaustiveMapper::new(budget).with_permutations().with_certification();
        let out = bnb.run(&layer, &acc).unwrap();
        assert!(out.certified, "full-space branch-and-bound run must certify");
        assert_eq!(out.mapping, base.mapping);
        assert_eq!(out.score.to_bits(), base.score.to_bits());
        // Same candidate account: examined + pruned covers the space (and
        // the LOCAL warm-start seed is in both runs' examined counts).
        assert_eq!(out.evaluations + bnb.pruned(), base.evaluations);
        assert!(bnb.pruned() > 0, "warm-started branch-and-bound must prune");
    }

    #[test]
    fn cross_layer_bound_seeds_keep_the_argmin_bit_identical() {
        let acc = small_acc();
        let layer = small_layer();
        for certify in [false, true] {
            let mk = || {
                let m = ExhaustiveMapper::new(5_000).with_permutations();
                if certify {
                    m.with_certification()
                } else {
                    m
                }
            };
            let base = mk().run(&layer, &acc).unwrap();
            // An oracle seed (the argmin itself) acts as a pure bound:
            // bit-identical result at no more evaluations.
            let fast = mk();
            let out = fast.run_seeded(&layer, &acc, &[base.mapping.clone()]).unwrap();
            assert_eq!(out.mapping, base.mapping, "certify={certify}");
            assert_eq!(out.score.to_bits(), base.score.to_bits());
            assert_eq!(out.certified, base.certified);
            assert!(out.evaluations <= base.evaluations, "certify={certify}");
            // A weak (but valid) seed bounds nothing out: same argmin.
            let trivial = Mapping::trivial(&layer, acc.n_levels());
            let out2 = mk().run_seeded(&layer, &acc, &[trivial]).unwrap();
            assert_eq!(out2.mapping, base.mapping, "certify={certify}");
            assert_eq!(out2.score.to_bits(), base.score.to_bits());
            // An invalid seed is ignored: exact unseeded behavior.
            let mut broken = base.mapping.clone();
            broken.temporal[0][0] *= 7;
            let out3 = mk().run_seeded(&layer, &acc, &[broken]).unwrap();
            assert_eq!(out3.mapping, base.mapping, "certify={certify}");
            assert_eq!(out3.evaluations, base.evaluations);
        }
    }

    #[test]
    fn a_seed_below_the_truncated_argmin_forces_the_honest_rerun() {
        // Budget 1 without warm-start examines only odometer candidate 0;
        // seeding with a wide search's argmin puts the bound below it, so
        // the bounded run cannot accept and the mapper reruns unbounded —
        // the final mapping still equals the unseeded budget-1 result.
        let acc = small_acc();
        let layer = small_layer();
        let mk = || ExhaustiveMapper::new(1).without_warm_start();
        let base = mk().run(&layer, &acc).unwrap();
        let wide = ExhaustiveMapper::new(50_000).with_permutations().run(&layer, &acc).unwrap();
        let out = mk().run_seeded(&layer, &acc, &[wide.mapping.clone()]).unwrap();
        assert_eq!(out.mapping, base.mapping);
        assert_eq!(out.score.to_bits(), base.score.to_bits());
        if wide.score < base.score {
            // Case b: both runs' examined counts are summed.
            assert!(out.evaluations >= base.evaluations);
        }
    }

    #[test]
    fn dead_dims_shrink_the_enumeration_space() {
        // An op's pinned dims carry exactly one divisor, so the odometer
        // space of a matmul is a strict subset of the same-size conv's.
        let acc = small_acc();
        let mm = Layer::matmul("mm", 8, 4, 8);
        let conv = Layer::new("c", 8, 4, 3, 3, 8, 8);
        let mm_size = ExhaustiveMapper::space_size(&mm, &acc);
        assert!(mm_size < ExhaustiveMapper::space_size(&conv, &acc));
        // Exhaustive enumeration of the projected space stays feasible and
        // returns a valid mapping.
        let out = ExhaustiveMapper::new(mm_size.min(50_000)).run(&mm, &acc).unwrap();
        out.mapping.validate(&mm, &acc).unwrap();
    }

    #[test]
    fn space_size_matches_paper_scale() {
        // The §3 example: mapping spaces are astronomically large even
        // before permutations.
        let acc = presets::eyeriss();
        let layer = crate::workload::zoo::vgg02()[4].clone();
        assert!(ExhaustiveMapper::space_size(&layer, &acc) > 1_000_000_000);
    }
}
