//! Exhaustive (brute-force) mapper — the §3 "48 hours for one layer"
//! straw man, usable here only on small layers / truncated budgets.
//! Serves as the test oracle: on layers where full enumeration is
//! feasible, no other mapper may beat it.
//!
//! # Parallel enumeration
//!
//! The factorization space is an odometer over per-dim ordered splits;
//! each odometer slot optionally fans out into 7 rotated per-level
//! permutations. Every candidate therefore has a stable **global index**
//! `slot × perms + rot`, independent of how the work is divided. The
//! mapper partitions the (budget-truncated) slot range into contiguous
//! shards, one per worker thread ([`std::thread::scope`]); each worker
//! enumerates its shard with a reusable candidate `Mapping` (rotations
//! applied in place and reset per slot — no per-candidate clone) and a
//! per-worker [`EvalContext`], tracking its best `(energy, global index,
//! mapping)`.
//!
//! The merge is deterministic: lowest energy wins, exact-tie broken by the
//! lowest global candidate index. That is precisely the order in which the
//! single-threaded loop would have kept candidates (strict `<` keeps the
//! earliest minimum), so the result is identical for every thread count —
//! pinned by `prop_parallel_exhaustive_matches_single_thread` in
//! `rust/tests/property.rs`.

use super::{MapError, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::model::EvalContext;
use crate::util::factor::factorizations;
use crate::workload::{ConvLayer, Dim};
use std::cell::Cell;

/// Deterministic enumeration of the factorization space (canonical
/// permutations; optionally a rotation set) with best-energy selection,
/// sharded across worker threads.
#[derive(Debug, Clone)]
pub struct ExhaustiveMapper {
    /// Stop after this many candidates (the space explodes quickly).
    pub max_candidates: u64,
    /// Also try rotated per-level permutations (×7 candidates).
    pub permute: bool,
    /// Worker threads the odometer space is sharded across (≥ 1). The
    /// result is identical for every value (deterministic merge).
    pub threads: usize,
    evaluated: Cell<u64>,
}

impl ExhaustiveMapper {
    /// Enumerator truncated at `max_candidates` evaluations.
    pub fn new(max_candidates: u64) -> Self {
        Self { max_candidates, permute: false, threads: 1, evaluated: Cell::new(0) }
    }

    /// Builder: also enumerate the rotation set of per-level permutations.
    pub fn with_permutations(mut self) -> Self {
        self.permute = true;
        self
    }

    /// Builder: shard the enumeration across `threads` workers.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Size of the factorization space this would enumerate.
    pub fn space_size(layer: &ConvLayer, acc: &Accelerator) -> u64 {
        Dim::ALL
            .iter()
            .map(|&d| {
                crate::util::factor::count_factorizations(layer.bound(d), acc.n_levels() + 2)
            })
            .product()
    }
}

/// Decode a linear odometer position into per-dim indices. Dim 0 is the
/// least-significant digit, matching the serial odometer's carry order.
fn odometer_at(mut linear: u64, per_dim: &[Vec<Vec<u64>>]) -> [usize; 7] {
    let mut idx = [0usize; 7];
    for d in 0..7 {
        let len = per_dim[d].len() as u64;
        idx[d] = (linear % len) as usize;
        linear /= len;
    }
    idx
}

/// Start of shard `w` when `total` slots are split across `workers`
/// contiguous shards (shard `w` covers `[start(w), start(w + 1))`).
fn shard_start(total: u64, workers: u64, w: u64) -> u64 {
    let base = total / workers;
    let rem = total % workers;
    w * base + w.min(rem)
}

impl Mapper for ExhaustiveMapper {
    fn name(&self) -> String {
        "exhaustive".to_string()
    }

    fn evaluations(&self) -> u64 {
        self.evaluated.get()
    }

    fn map(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<Mapping, MapError> {
        let n_levels = acc.n_levels();
        let slots = n_levels + 2; // spatial X, spatial Y, temporal levels
        // Per-dim ordered factorizations across slots:
        // [sx, sy, t0, t1, ..., t_top].
        let per_dim: Vec<Vec<Vec<u64>>> =
            Dim::ALL.iter().map(|&d| factorizations(layer.bound(d), slots)).collect();

        let perms: u64 = if self.permute { 7 } else { 1 };
        // Budget-truncated slot range: candidate `slot × perms + rot` is
        // evaluated iff its global index is below the budget, so only the
        // first ceil(budget / perms) odometer slots can contribute. (A zero
        // budget still evaluates one candidate, like the serial loop did.)
        let budget = self.max_candidates.max(1);
        let total_slots: u128 = per_dim.iter().map(|v| v.len() as u128).product();
        let slots_needed = budget.div_ceil(perms);
        let visit_slots: u64 =
            if total_slots < slots_needed as u128 { total_slots as u64 } else { slots_needed };

        let n_workers = self.threads.max(1).min(visit_slots.max(1) as usize) as u64;
        let mut evaluated_total = 0u64;
        let mut best: Option<(f64, u64, Mapping)> = None;
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n_workers as usize);
            for w in 0..n_workers {
                let per_dim = &per_dim;
                let start = shard_start(visit_slots, n_workers, w);
                let end = shard_start(visit_slots, n_workers, w + 1);
                handles.push(scope.spawn(move || {
                    let mut ctx = EvalContext::new(layer, acc);
                    // One reusable candidate per worker; rotations mutate it
                    // in place (no per-rotation clone — the old inner loop
                    // cloned two Vecs per candidate).
                    let mut m = Mapping {
                        temporal: vec![[1u64; 7]; n_levels],
                        permutation: vec![Dim::ALL; n_levels],
                        spatial_x: [1; 7],
                        spatial_y: [1; 7],
                    };
                    let mut shard_best: Option<(f64, u64, Mapping)> = None;
                    let mut evaluated = 0u64;
                    for slot in start..end {
                        let idx = odometer_at(slot, per_dim);
                        for d in 0..7 {
                            let split = &per_dim[d][idx[d]];
                            m.spatial_x[d] = split[0];
                            m.spatial_y[d] = split[1];
                            for l in 0..n_levels {
                                m.temporal[l][d] = split[2 + l];
                            }
                        }
                        for p in m.permutation.iter_mut() {
                            *p = Dim::ALL;
                        }
                        for rot in 0..perms {
                            let cand_index = slot * perms + rot;
                            if cand_index >= budget {
                                break;
                            }
                            if rot > 0 {
                                for p in m.permutation.iter_mut() {
                                    p.rotate_left(1);
                                }
                            }
                            if m.validate(layer, acc).is_ok() {
                                let pj = ctx.energy_pj(&m);
                                let improves =
                                    shard_best.as_ref().map(|(b, _, _)| pj < *b).unwrap_or(true);
                                if improves {
                                    shard_best = Some((pj, cand_index, m.clone()));
                                }
                            }
                            evaluated += 1;
                        }
                    }
                    (evaluated, shard_best)
                }));
            }
            for h in handles {
                let (ev, shard_best) = h.join().expect("exhaustive shard worker panicked");
                evaluated_total += ev;
                if let Some((pj, ci, m)) = shard_best {
                    let better = match &best {
                        None => true,
                        // Deterministic merge: lowest energy; exact tie →
                        // lowest global candidate index (serial order).
                        Some((bpj, bci, _)) => pj < *bpj || (pj == *bpj && ci < *bci),
                    };
                    if better {
                        best = Some((pj, ci, m));
                    }
                }
            }
        });
        self.evaluated.set(evaluated_total);
        best.map(|(_, _, m)| m)
            .ok_or_else(|| MapError::NoValidMapping("exhaustive found no valid mapping".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::arch::{Accelerator, Noc, PeArray, StorageLevel, Style};
    use crate::mappers::LocalMapper;

    fn small_acc() -> Accelerator {
        Accelerator {
            name: "small".into(),
            style: Style::NvdlaLike,
            datawidth_bits: 16,
            levels: vec![
                StorageLevel::register_file("RF", 64, 16),
                StorageLevel::buffer("GLB", 1024, 64),
                StorageLevel::dram(64),
            ],
            pe: PeArray::new(4, 4),
            noc: Noc::default(),
            mac_energy_pj: 1.0,
            clock_mhz: 200.0,
        }
    }

    fn small_layer() -> ConvLayer {
        ConvLayer::new("small", 8, 4, 3, 3, 8, 8)
    }

    #[test]
    fn enumerates_and_finds_valid_best() {
        let acc = small_acc();
        let layer = small_layer();
        let ex = ExhaustiveMapper::new(200_000);
        let out = ex.run(&layer, &acc).unwrap();
        out.mapping.validate(&layer, &acc).unwrap();
        assert!(out.evaluations > 1000);
    }

    #[test]
    fn oracle_no_mapper_beats_full_enumeration() {
        let acc = small_acc();
        let layer = ConvLayer::new("tiny", 4, 2, 1, 1, 4, 4);
        let size = ExhaustiveMapper::space_size(&layer, &acc);
        assert!(size < 2_000_000, "space too big for oracle test: {size}");
        let ex = ExhaustiveMapper::new(size).with_permutations();
        let best = ex.run(&layer, &acc).unwrap();
        let local = LocalMapper::new().run(&layer, &acc).unwrap();
        assert!(
            local.evaluation.energy.total_pj() >= best.evaluation.energy.total_pj() * 0.999,
            "LOCAL ({}) beat the exhaustive oracle ({})",
            local.evaluation.energy.total_pj(),
            best.evaluation.energy.total_pj()
        );
    }

    #[test]
    fn sharded_enumeration_matches_single_thread() {
        // Same best mapping, same best energy bits, same evaluation count
        // at every thread count — the deterministic-merge contract.
        let acc = small_acc();
        let layer = ConvLayer::new("tiny", 4, 2, 1, 1, 4, 4);
        let serial = ExhaustiveMapper::new(40_000).with_permutations();
        let base = serial.run(&layer, &acc).unwrap();
        for threads in [2usize, 4, 8] {
            let par = ExhaustiveMapper::new(40_000).with_permutations().with_threads(threads);
            let out = par.run(&layer, &acc).unwrap();
            assert_eq!(out.mapping, base.mapping, "threads={threads}");
            assert_eq!(
                out.evaluation.energy.total_pj().to_bits(),
                base.evaluation.energy.total_pj().to_bits(),
                "threads={threads}"
            );
            assert_eq!(out.evaluations, base.evaluations, "threads={threads}");
        }
    }

    #[test]
    fn budget_truncation_is_thread_invariant() {
        // A budget that cuts mid-rotation must still evaluate exactly the
        // same candidate set (global indices below the budget).
        let acc = small_acc();
        let layer = small_layer();
        let a = ExhaustiveMapper::new(999).with_permutations();
        let base = a.run(&layer, &acc).unwrap();
        assert_eq!(base.evaluations, 999);
        let b = ExhaustiveMapper::new(999).with_permutations().with_threads(3);
        let out = b.run(&layer, &acc).unwrap();
        assert_eq!(out.evaluations, 999);
        assert_eq!(out.mapping, base.mapping);
    }

    #[test]
    fn dead_dims_shrink_the_enumeration_space() {
        // An op's pinned dims carry exactly one divisor, so the odometer
        // space of a matmul is a strict subset of the same-size conv's.
        let acc = small_acc();
        let mm = ConvLayer::matmul("mm", 8, 4, 8);
        let conv = ConvLayer::new("c", 8, 4, 3, 3, 8, 8);
        let mm_size = ExhaustiveMapper::space_size(&mm, &acc);
        assert!(mm_size < ExhaustiveMapper::space_size(&conv, &acc));
        // Exhaustive enumeration of the projected space stays feasible and
        // returns a valid mapping.
        let out = ExhaustiveMapper::new(mm_size.min(50_000)).run(&mm, &acc).unwrap();
        out.mapping.validate(&mm, &acc).unwrap();
    }

    #[test]
    fn space_size_matches_paper_scale() {
        // The §3 example: mapping spaces are astronomically large even
        // before permutations.
        let acc = presets::eyeriss();
        let layer = crate::workload::zoo::vgg02()[4].clone();
        assert!(ExhaustiveMapper::space_size(&layer, &acc) > 1_000_000_000);
    }
}
