//! GAMMA-style genetic mapper [19] — the iterative heuristic family the
//! paper positions LOCAL against (§1, §7): good energy, but many
//! evaluations and long mapping time. Used by the ablation bench to place
//! LOCAL on the quality-vs-time curve.

use super::{MapError, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::mapspace::{repair, sample_random};
use crate::model::EvalContext;
use crate::util::rng::SplitMix64;
use crate::workload::ConvLayer;
use std::cell::Cell;

/// Genetic-algorithm mapper: population of mappings, tournament selection,
/// factor-migration mutation, per-dim crossover, elitism.
#[derive(Debug, Clone)]
pub struct GeneticMapper {
    /// Population size (≥ 4; a quarter survives as elite).
    pub population: usize,
    /// Number of generations.
    pub generations: usize,
    /// Per-child mutation probability.
    pub mutation_rate: f64,
    /// PRNG seed (deterministic across runs).
    pub seed: u64,
    evaluated: Cell<u64>,
}

impl GeneticMapper {
    /// GA mapper with the given population, generations and seed.
    pub fn new(population: usize, generations: usize, seed: u64) -> Self {
        assert!(population >= 4);
        Self { population, generations, mutation_rate: 0.3, seed, evaluated: Cell::new(0) }
    }
}

fn fitness(ctx: &mut EvalContext, m: &Mapping) -> f64 {
    ctx.energy_pj(m)
}

/// Mutation: move one prime factor of one dim between two random slots
/// (levels / spatial), or swap two permutation entries at one level.
fn mutate(layer: &ConvLayer, acc: &Accelerator, m: &mut Mapping, rng: &mut SplitMix64) {
    let n_levels = m.n_levels();
    match rng.next_below(3) {
        0 => {
            // Migrate a prime factor of dim d from slot a to slot b.
            let d = rng.index(7);
            // Slots: 0..n_levels temporal, n_levels = sx, n_levels+1 = sy.
            let a = rng.index(n_levels + 2);
            let b = rng.index(n_levels + 2);
            if a == b {
                return;
            }
            let get = |m: &Mapping, s: usize| -> u64 {
                if s < n_levels {
                    m.temporal[s][d]
                } else if s == n_levels {
                    m.spatial_x[d]
                } else {
                    m.spatial_y[d]
                }
            };
            let v = get(m, a);
            if v <= 1 {
                return;
            }
            let f = smallest_prime(v);
            let setv = |m: &mut Mapping, s: usize, v: u64| {
                if s < n_levels {
                    m.temporal[s][d] = v;
                } else if s == n_levels {
                    m.spatial_x[d] = v;
                } else {
                    m.spatial_y[d] = v;
                }
            };
            setv(m, a, v / f);
            let w = get(m, b);
            setv(m, b, w * f);
        }
        1 => {
            // Swap two permutation entries at one level.
            let l = rng.index(n_levels);
            let i = rng.index(7);
            let j = rng.index(7);
            m.permutation[l].swap(i, j);
        }
        _ => {
            // Re-draw one dim's split entirely from a fresh sample.
            let fresh = sample_random(layer, acc, rng);
            let d = rng.index(7);
            for l in 0..n_levels {
                m.temporal[l][d] = fresh.temporal[l][d];
            }
            m.spatial_x[d] = fresh.spatial_x[d];
            m.spatial_y[d] = fresh.spatial_y[d];
        }
    }
    repair(layer, acc, m);
}

/// Crossover: child takes each dim's split from one parent, permutations
/// level-wise from either parent.
fn crossover(a: &Mapping, b: &Mapping, rng: &mut SplitMix64) -> Mapping {
    let mut child = a.clone();
    for d in 0..7 {
        if rng.next_below(2) == 1 {
            for l in 0..child.n_levels() {
                child.temporal[l][d] = b.temporal[l][d];
            }
            child.spatial_x[d] = b.spatial_x[d];
            child.spatial_y[d] = b.spatial_y[d];
        }
    }
    for l in 0..child.n_levels() {
        if rng.next_below(2) == 1 {
            child.permutation[l] = b.permutation[l];
        }
    }
    child
}

fn smallest_prime(n: u64) -> u64 {
    let mut i = 2;
    while i * i <= n {
        if n % i == 0 {
            return i;
        }
        i += 1;
    }
    n
}

impl Mapper for GeneticMapper {
    fn name(&self) -> String {
        format!("GA(p{}g{})", self.population, self.generations)
    }

    fn evaluations(&self) -> u64 {
        self.evaluated.get()
    }

    fn map(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<Mapping, MapError> {
        let mut rng = SplitMix64::new(self.seed);
        let mut ctx = EvalContext::new(layer, acc);
        let mut evaluated = 0u64;
        // Initial population.
        let mut pop: Vec<(f64, Mapping)> = (0..self.population)
            .map(|_| {
                let m = sample_random(layer, acc, &mut rng);
                evaluated += 1;
                (fitness(&mut ctx, &m), m)
            })
            .collect();
        pop.sort_by(|a, b| a.0.total_cmp(&b.0));

        for _gen in 0..self.generations {
            let elite = self.population / 4;
            let mut next: Vec<(f64, Mapping)> = pop[..elite].to_vec();
            while next.len() < self.population {
                // Tournament selection from the current population.
                let pick = |rng: &mut SplitMix64| {
                    let i = rng.index(pop.len());
                    let j = rng.index(pop.len());
                    if pop[i].0 < pop[j].0 { i } else { j }
                };
                let pa = pick(&mut rng);
                let pb = pick(&mut rng);
                let mut child = crossover(&pop[pa].1, &pop[pb].1, &mut rng);
                if rng.next_f64() < self.mutation_rate {
                    mutate(layer, acc, &mut child, &mut rng);
                }
                repair(layer, acc, &mut child);
                if child.validate(layer, acc).is_ok() {
                    evaluated += 1;
                    next.push((fitness(&mut ctx, &child), child));
                }
            }
            next.sort_by(|a, b| a.0.total_cmp(&b.0));
            pop = next;
        }
        self.evaluated.set(evaluated);
        Ok(pop.remove(0).1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::RandomMapper;
    use crate::workload::zoo;

    #[test]
    fn ga_produces_valid_mapping() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let ga = GeneticMapper::new(16, 5, 42);
        let out = ga.run(&layer, &acc).unwrap();
        out.mapping.validate(&layer, &acc).unwrap();
        assert!(out.evaluations >= 16);
    }

    #[test]
    fn ga_beats_single_random_draw() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let ga = GeneticMapper::new(16, 10, 1).run(&layer, &acc).unwrap();
        let rnd = RandomMapper::new(1, 1).run(&layer, &acc).unwrap();
        assert!(ga.evaluation.energy.total_pj() <= rnd.evaluation.energy.total_pj());
    }

    #[test]
    fn crossover_preserves_validity_after_repair() {
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[8].clone();
        let mut rng = SplitMix64::new(5);
        for _ in 0..50 {
            let a = sample_random(&layer, &acc, &mut rng);
            let b = sample_random(&layer, &acc, &mut rng);
            let mut c = crossover(&a, &b, &mut rng);
            repair(&layer, &acc, &mut c);
            c.validate(&layer, &acc).unwrap();
        }
    }

    #[test]
    fn mutation_preserves_coverage() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let mut rng = SplitMix64::new(77);
        let mut m = sample_random(&layer, &acc, &mut rng);
        for _ in 0..100 {
            mutate(&layer, &acc, &mut m, &mut rng);
            m.validate(&layer, &acc).unwrap();
        }
    }
}
