//! GAMMA-style genetic mapper [19] — the iterative heuristic family the
//! paper positions LOCAL against (§1, §7): good energy, but many
//! evaluations and long mapping time. Used by the ablation bench to place
//! LOCAL on the quality-vs-time curve.
//!
//! The population step is an engine [`BatchSource`]: each generation's
//! children are bred sequentially (selection needs the previous
//! generation's scores), handed to the shared [`SearchDriver`] as one
//! batch, and scored through the zero-allocation context — in parallel
//! across the driver's worker threads when configured, with identical
//! results at every thread count (each candidate is scored
//! independently).

use super::engine::source::candidate_seed;
use super::engine::{deadline_instant, BatchSource, Objective, SearchDriver};
use super::{MapError, MapStatus, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::mapspace::{repair, sample_random};
use crate::util::rng::SplitMix64;
use crate::workload::Layer;
use std::cell::Cell;

/// Genetic-algorithm mapper: population of mappings, tournament selection,
/// factor-migration mutation, per-dim crossover, elitism.
#[derive(Debug, Clone)]
pub struct GeneticMapper {
    /// Population size (≥ 4; a quarter survives as elite).
    pub population: usize,
    /// Number of generations.
    pub generations: usize,
    /// Per-child mutation probability.
    pub mutation_rate: f64,
    /// PRNG seed (deterministic across runs).
    pub seed: u64,
    /// The objective used as fitness.
    pub objective: Objective,
    /// Worker threads for scoring each generation (identical results at
    /// every value).
    pub threads: usize,
    /// Per-layer wall-clock deadline, ms (`None` = unbounded).
    pub deadline_ms: Option<u64>,
    evaluated: Cell<u64>,
    degraded: Cell<bool>,
}

impl GeneticMapper {
    /// GA mapper with the given population, generations and seed.
    pub fn new(population: usize, generations: usize, seed: u64) -> Self {
        assert!(population >= 4);
        Self {
            population,
            generations,
            mutation_rate: 0.3,
            seed,
            objective: Objective::Energy,
            threads: 1,
            deadline_ms: None,
            evaluated: Cell::new(0),
            degraded: Cell::new(false),
        }
    }

    /// Builder: apply the shared engine params (objective + threads +
    /// deadline; the population/generation shape stays as constructed).
    pub fn with_params(mut self, params: &super::SearchParams) -> Self {
        self.objective = params.objective;
        self.threads = params.threads.max(1);
        self.deadline_ms = params.deadline_ms;
        self
    }

    /// Builder: minimize `objective` instead of energy.
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }
}

/// Mutation: move one prime factor of one dim between two random slots
/// (levels / spatial), or swap two permutation entries at one level.
fn mutate(layer: &Layer, acc: &Accelerator, m: &mut Mapping, rng: &mut SplitMix64) {
    let n_levels = m.n_levels();
    match rng.next_below(3) {
        0 => {
            // Migrate a prime factor of dim d from slot a to slot b.
            let d = rng.index(7);
            // Slots: 0..n_levels temporal, n_levels = sx, n_levels+1 = sy.
            let a = rng.index(n_levels + 2);
            let b = rng.index(n_levels + 2);
            if a == b {
                return;
            }
            let get = |m: &Mapping, s: usize| -> u64 {
                if s < n_levels {
                    m.temporal[s][d]
                } else if s == n_levels {
                    m.spatial_x[d]
                } else {
                    m.spatial_y[d]
                }
            };
            let v = get(m, a);
            if v <= 1 {
                return;
            }
            let f = smallest_prime(v);
            let setv = |m: &mut Mapping, s: usize, v: u64| {
                if s < n_levels {
                    m.temporal[s][d] = v;
                } else if s == n_levels {
                    m.spatial_x[d] = v;
                } else {
                    m.spatial_y[d] = v;
                }
            };
            setv(m, a, v / f);
            let w = get(m, b);
            setv(m, b, w * f);
        }
        1 => {
            // Swap two permutation entries at one level.
            let l = rng.index(n_levels);
            let i = rng.index(7);
            let j = rng.index(7);
            m.permutation[l].swap(i, j);
        }
        _ => {
            // Re-draw one dim's split entirely from a fresh sample.
            let fresh = sample_random(layer, acc, rng);
            let d = rng.index(7);
            for l in 0..n_levels {
                m.temporal[l][d] = fresh.temporal[l][d];
            }
            m.spatial_x[d] = fresh.spatial_x[d];
            m.spatial_y[d] = fresh.spatial_y[d];
        }
    }
    repair(layer, acc, m);
}

/// Crossover: child takes each dim's split from one parent, permutations
/// level-wise from either parent.
fn crossover(a: &Mapping, b: &Mapping, rng: &mut SplitMix64) -> Mapping {
    let mut child = a.clone();
    for d in 0..7 {
        if rng.next_below(2) == 1 {
            for l in 0..child.n_levels() {
                child.temporal[l][d] = b.temporal[l][d];
            }
            child.spatial_x[d] = b.spatial_x[d];
            child.spatial_y[d] = b.spatial_y[d];
        }
    }
    for l in 0..child.n_levels() {
        if rng.next_below(2) == 1 {
            child.permutation[l] = b.permutation[l];
        }
    }
    child
}

fn smallest_prime(n: u64) -> u64 {
    let mut i = 2;
    while i * i <= n {
        if n % i == 0 {
            return i;
        }
        i += 1;
    }
    n
}

/// The GA population step as an engine source: batch `0` is the seed
/// population (each member drawn like the random stream's same-index
/// candidate, so the GA provably contains the single-draw baseline);
/// every later batch is one generation of pre-validated children.
struct GaPopulation<'a> {
    layer: &'a Layer,
    acc: &'a Accelerator,
    rng: SplitMix64,
    seed: u64,
    population: usize,
    generations: usize,
    mutation_rate: f64,
    /// Scored survivors: elite carried over + last batch, sorted by score.
    pop: Vec<(f64, Mapping)>,
    /// Elite carried across the pending batch (already scored).
    elite: Vec<(f64, Mapping)>,
    /// The batch awaiting feedback.
    pending: Vec<Mapping>,
    generations_done: usize,
}

impl GaPopulation<'_> {
    fn fold_feedback(&mut self, feedback: &[Option<f64>]) {
        let mut next = std::mem::take(&mut self.elite);
        for (m, s) in self.pending.drain(..).zip(feedback) {
            if let Some(score) = s {
                next.push((*score, m));
            }
        }
        next.sort_by(|a, b| a.0.total_cmp(&b.0));
        self.pop = next;
    }
}

impl BatchSource for GaPopulation<'_> {
    fn next_batch(&mut self, feedback: &[Option<f64>], out: &mut Vec<Mapping>) {
        if self.pop.is_empty() && self.pending.is_empty() {
            // Seed population.
            for i in 0..self.population {
                let mut rng = SplitMix64::new(candidate_seed(self.seed, i as u64));
                out.push(sample_random(self.layer, self.acc, &mut rng));
            }
            self.pending = out.clone();
            return;
        }
        self.fold_feedback(feedback);
        if self.generations_done >= self.generations || self.pop.is_empty() {
            return;
        }
        self.generations_done += 1;
        let elite_n = self.population / 4;
        self.elite = self.pop[..elite_n.min(self.pop.len())].to_vec();
        while out.len() < self.population - self.elite.len() {
            // Tournament selection from the current population.
            let pick = |rng: &mut SplitMix64, pop: &[(f64, Mapping)]| {
                let i = rng.index(pop.len());
                let j = rng.index(pop.len());
                if pop[i].0 < pop[j].0 {
                    i
                } else {
                    j
                }
            };
            let pa = pick(&mut self.rng, &self.pop);
            let pb = pick(&mut self.rng, &self.pop);
            let mut child = crossover(&self.pop[pa].1, &self.pop[pb].1, &mut self.rng);
            if self.rng.next_f64() < self.mutation_rate {
                mutate(self.layer, self.acc, &mut child, &mut self.rng);
            }
            repair(self.layer, self.acc, &mut child);
            if child.validate(self.layer, self.acc).is_ok() {
                out.push(child);
            }
        }
        self.pending = out.clone();
    }
}

impl Mapper for GeneticMapper {
    fn name(&self) -> String {
        format!("GA(p{}g{})", self.population, self.generations)
    }

    fn objective(&self) -> Objective {
        self.objective
    }

    fn evaluations(&self) -> u64 {
        self.evaluated.get()
    }

    fn status(&self) -> MapStatus {
        if self.degraded.get() {
            MapStatus::Degraded { reason: "deadline expired mid-search".into() }
        } else {
            MapStatus::Ok
        }
    }

    fn map(&self, layer: &Layer, acc: &Accelerator) -> Result<Mapping, MapError> {
        self.map_seeded(layer, acc, &[])
    }

    fn accepts_seeds(&self) -> bool {
        true
    }

    /// Cross-layer seeds are merged into the *result only* — the
    /// population breeds exactly as unseeded (seeds never join the gene
    /// pool), so the returned mapping is `min(GA best, seeds)` and never
    /// worse than the unseeded run (DESIGN.md §15).
    fn map_seeded(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        seeds: &[Mapping],
    ) -> Result<Mapping, MapError> {
        self.degraded.set(false);
        let mut source = GaPopulation {
            layer,
            acc,
            rng: SplitMix64::new(self.seed),
            seed: self.seed,
            population: self.population,
            generations: self.generations,
            mutation_rate: self.mutation_rate,
            pop: Vec::new(),
            elite: Vec::new(),
            pending: Vec::new(),
            generations_done: 0,
        };
        // The GA's budget is its population × generation shape; the driver
        // still owns validity filtering, scoring and best tracking.
        let driver = SearchDriver {
            objective: self.objective,
            budget: u64::MAX,
            threads: self.threads,
            prune: false,
            deadline: deadline_instant(self.deadline_ms),
        };
        match driver.search_batched_seeded(layer, acc, &mut source, seeds) {
            Some(b) => {
                self.evaluated.set(b.scored);
                self.degraded.set(b.degraded);
                Ok(b.mapping)
            }
            None => Err(MapError::NoValidMapping("GA produced no valid candidate".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::RandomMapper;
    use crate::workload::zoo;

    #[test]
    fn ga_produces_valid_mapping() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let ga = GeneticMapper::new(16, 5, 42);
        let out = ga.run(&layer, &acc).unwrap();
        out.mapping.validate(&layer, &acc).unwrap();
        assert!(out.evaluations >= 16);
    }

    #[test]
    fn ga_beats_single_random_draw() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let ga = GeneticMapper::new(16, 10, 1).run(&layer, &acc).unwrap();
        let rnd = RandomMapper::new(1, 1).run(&layer, &acc).unwrap();
        assert!(ga.evaluation.energy.total_pj() <= rnd.evaluation.energy.total_pj());
    }

    #[test]
    fn ga_is_thread_invariant() {
        // Children are bred sequentially and scored independently, so the
        // parallel-scored GA returns the identical mapping.
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let base = GeneticMapper::new(16, 4, 7).map(&layer, &acc).unwrap();
        for threads in [2usize, 8] {
            let mut ga = GeneticMapper::new(16, 4, 7);
            ga.threads = threads;
            assert_eq!(ga.map(&layer, &acc).unwrap(), base, "threads={threads}");
        }
    }

    #[test]
    fn crossover_preserves_validity_after_repair() {
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[8].clone();
        let mut rng = SplitMix64::new(5);
        for _ in 0..50 {
            let a = sample_random(&layer, &acc, &mut rng);
            let b = sample_random(&layer, &acc, &mut rng);
            let mut c = crossover(&a, &b, &mut rng);
            repair(&layer, &acc, &mut c);
            c.validate(&layer, &acc).unwrap();
        }
    }

    #[test]
    fn mutation_preserves_coverage() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let mut rng = SplitMix64::new(77);
        let mut m = sample_random(&layer, &acc, &mut rng);
        for _ in 0..100 {
            mutate(&layer, &acc, &mut m, &mut rng);
            m.validate(&layer, &acc).unwrap();
        }
    }
}
