//! Mappers: the LOCAL one-pass algorithm (the paper's contribution) and the
//! baselines it is evaluated against — dataflow-constrained search (the
//! Table-3 RS/WS/OS columns), pure random sampling (Fig. 3), exhaustive
//! enumeration (test oracle on small layers) and a GAMMA-style genetic
//! search (related-work ablation, §7).

pub mod annealing;
pub mod exhaustive;
pub mod genetic;
pub mod local;
pub mod random;
pub mod refine;
pub mod search;

pub use annealing::AnnealingMapper;
pub use exhaustive::ExhaustiveMapper;
pub use local::LocalMapper;
pub use random::RandomMapper;
pub use refine::LocalRefined;
pub use search::ConstrainedSearch;

use crate::arch::Accelerator;
use crate::mapping::{Mapping, MappingError};
use crate::model::{EvalContext, Evaluation};
use crate::workload::ConvLayer;
use std::fmt;
use std::time::{Duration, Instant};

/// Mapper failure.
#[derive(Debug)]
pub enum MapError {
    /// The mapper exhausted its budget/space without a valid mapping.
    NoValidMapping(String),
    /// A constructed mapping failed validation.
    Invalid(MappingError),
}

impl fmt::Display for MapError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MapError::NoValidMapping(msg) => write!(f, "no valid mapping found: {msg}"),
            MapError::Invalid(e) => fmt::Display::fmt(e, f),
        }
    }
}

impl std::error::Error for MapError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MapError::NoValidMapping(_) => None,
            MapError::Invalid(e) => Some(e),
        }
    }
}

impl From<MappingError> for MapError {
    fn from(e: MappingError) -> Self {
        MapError::Invalid(e)
    }
}

/// Result of running a mapper: the chosen mapping, its evaluation, and the
/// search cost (the paper's *mapping time*, Table 3).
#[derive(Debug, Clone)]
pub struct MapOutcome {
    /// The chosen mapping.
    pub mapping: Mapping,
    /// Analytical evaluation of the chosen mapping.
    pub evaluation: Evaluation,
    /// Number of candidate evaluations performed (2 for LOCAL — its
    /// constant-size schedule comparison; hundreds–thousands for search).
    pub evaluations: u64,
    /// Wall-clock search time.
    pub elapsed: Duration,
}

/// A mapping algorithm: layer × accelerator → mapping.
pub trait Mapper {
    /// Short display name ("LOCAL", "RS-search", ...).
    fn name(&self) -> String;

    /// Construct the mapping only (no timing bookkeeping).
    fn map(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<Mapping, MapError>;

    /// Number of candidate evaluations `map` performs (reported in
    /// Table 3 next to wall-clock).
    fn evaluations(&self) -> u64 {
        1
    }

    /// Run with timing: the measured quantity of the paper's Table 3.
    /// The final evaluation goes through the same [`EvalContext`] engine
    /// the search loops use (bit-identical to the legacy evaluator), so
    /// every caller — coordinator workers, `explore::sweep`, the CLI —
    /// exercises one evaluation path. For this single evaluation the
    /// context is built fresh (a one-time cost dwarfed by the `map()`
    /// search it follows); the zero-allocation payoff is inside the
    /// mappers' candidate loops.
    fn run(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<MapOutcome, MapError> {
        let t0 = Instant::now();
        let mapping = self.map(layer, acc)?;
        let elapsed = t0.elapsed();
        mapping.validate(layer, acc)?;
        let mut ctx = EvalContext::new(layer, acc);
        let evaluation = ctx.evaluate_into(&mapping).clone();
        Ok(MapOutcome { mapping, evaluation, evaluations: self.evaluations(), elapsed })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::zoo;

    #[test]
    fn run_reports_timing_and_validates() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let out = LocalMapper::new().run(&layer, &acc).unwrap();
        assert_eq!(out.evaluations, 2);
        assert!(out.evaluation.energy.total_pj() > 0.0);
    }
}
