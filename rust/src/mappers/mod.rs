//! Mappers: the LOCAL one-pass algorithm (the paper's contribution) and the
//! baselines it is evaluated against — dataflow-constrained search (the
//! Table-3 RS/WS/OS columns), pure random sampling (Fig. 3), exhaustive
//! enumeration (test oracle on small layers) and a GAMMA-style genetic
//! search (related-work ablation, §7).

pub mod annealing;
pub mod exhaustive;
pub mod genetic;
pub mod local;
pub mod random;
pub mod refine;
pub mod search;

pub use annealing::AnnealingMapper;
pub use exhaustive::ExhaustiveMapper;
pub use genetic::GeneticMapper;
pub use local::LocalMapper;
pub use random::RandomMapper;
pub use refine::LocalRefined;
pub use search::ConstrainedSearch;

use crate::arch::Accelerator;
use crate::mapping::{Mapping, MappingError};
use crate::model::{EvalContext, Evaluation};
use crate::workload::ConvLayer;
use std::fmt;
use std::time::{Duration, Instant};

/// Mapper failure.
#[derive(Debug)]
pub enum MapError {
    /// The mapper exhausted its budget/space without a valid mapping.
    NoValidMapping(String),
    /// A constructed mapping failed validation.
    Invalid(MappingError),
}

impl fmt::Display for MapError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MapError::NoValidMapping(msg) => write!(f, "no valid mapping found: {msg}"),
            MapError::Invalid(e) => fmt::Display::fmt(e, f),
        }
    }
}

impl std::error::Error for MapError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MapError::NoValidMapping(_) => None,
            MapError::Invalid(e) => Some(e),
        }
    }
}

impl From<MappingError> for MapError {
    fn from(e: MappingError) -> Self {
        MapError::Invalid(e)
    }
}

/// Result of running a mapper: the chosen mapping, its evaluation, and the
/// search cost (the paper's *mapping time*, Table 3).
#[derive(Debug, Clone)]
pub struct MapOutcome {
    /// The chosen mapping.
    pub mapping: Mapping,
    /// Analytical evaluation of the chosen mapping.
    pub evaluation: Evaluation,
    /// Number of candidate evaluations performed (2 for LOCAL — its
    /// constant-size schedule comparison; hundreds–thousands for search).
    pub evaluations: u64,
    /// Wall-clock search time.
    pub elapsed: Duration,
}

/// A mapping algorithm: layer × accelerator → mapping.
pub trait Mapper {
    /// Short display name ("LOCAL", "RS-search", ...).
    fn name(&self) -> String;

    /// Construct the mapping only (no timing bookkeeping).
    fn map(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<Mapping, MapError>;

    /// Number of candidate evaluations `map` performs (reported in
    /// Table 3 next to wall-clock).
    fn evaluations(&self) -> u64 {
        1
    }

    /// Run with timing: the measured quantity of the paper's Table 3.
    /// The final evaluation goes through the same [`EvalContext`] engine
    /// the search loops use (bit-identical to the legacy evaluator), so
    /// every caller — coordinator workers, `explore::sweep`, the CLI —
    /// exercises one evaluation path. For this single evaluation the
    /// context is built fresh (a one-time cost dwarfed by the `map()`
    /// search it follows); the zero-allocation payoff is inside the
    /// mappers' candidate loops.
    fn run(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<MapOutcome, MapError> {
        let t0 = Instant::now();
        let mapping = self.map(layer, acc)?;
        let elapsed = t0.elapsed();
        mapping.validate(layer, acc)?;
        let mut ctx = EvalContext::new(layer, acc);
        let evaluation = ctx.evaluate_into(&mapping).clone();
        Ok(MapOutcome { mapping, evaluation, evaluations: self.evaluations(), elapsed })
    }
}

/// Every mapper in the framework behind one cloneable, sendable dispatch
/// type — the single resolver the CLI's `map`, `compile`, `compile-all`
/// and `explore` subcommands all share ([`AnyMapper::parse`]), so the
/// full mapper set is exposed consistently everywhere a `--mapper` flag
/// is accepted.
#[derive(Debug, Clone)]
pub enum AnyMapper {
    /// The LOCAL one-pass mapper (the paper's contribution).
    Local(LocalMapper),
    /// Best-of-N random sampling (Fig. 3 baseline).
    Random(RandomMapper),
    /// GAMMA-style genetic search.
    Genetic(GeneticMapper),
    /// Simulated annealing.
    Annealing(AnnealingMapper),
    /// LOCAL seed + bounded hill-climbing refinement.
    Refine(LocalRefined),
    /// Sharded-parallel exhaustive enumeration (budget-truncated).
    Exhaustive(ExhaustiveMapper),
    /// Dataflow-constrained search (the RS/WS/OS Table-3 baselines).
    Search(ConstrainedSearch),
}

impl AnyMapper {
    /// The mapper spec strings [`AnyMapper::parse`] accepts (shown in CLI
    /// help and error messages).
    pub const SPEC: &str = "local|rs|ws|os|random|ga|annealing|refine|exhaustive";

    /// Resolve a mapper spec. `budget` caps search mappers (candidate
    /// evaluations / annealing steps; the GA scales its generation count
    /// as `budget / 150`, so the historical 3000 default yields the
    /// classic p32/g20 configuration); `seed` makes stochastic mappers
    /// deterministic. Returns `None` for an unknown spec.
    pub fn parse(spec: &str, budget: u64, seed: u64) -> Option<AnyMapper> {
        let budget = budget.max(1);
        Some(match spec.to_ascii_lowercase().as_str() {
            "local" => AnyMapper::Local(LocalMapper::new()),
            "random" => AnyMapper::Random(RandomMapper::new(budget, seed)),
            "ga" | "genetic" => {
                let generations = (budget / 150).max(1) as usize;
                AnyMapper::Genetic(GeneticMapper::new(32, generations, seed))
            }
            "annealing" | "sa" => AnyMapper::Annealing(AnnealingMapper::new(budget, seed)),
            "refine" | "local+refine" => AnyMapper::Refine(LocalRefined::new(budget, seed)),
            "exhaustive" => {
                AnyMapper::Exhaustive(ExhaustiveMapper::new(budget).with_permutations())
            }
            df => AnyMapper::Search(ConstrainedSearch::new(
                crate::mapspace::Dataflow::parse(df)?,
                budget,
                seed,
            )),
        })
    }

    fn inner(&self) -> &dyn Mapper {
        match self {
            AnyMapper::Local(m) => m,
            AnyMapper::Random(m) => m,
            AnyMapper::Genetic(m) => m,
            AnyMapper::Annealing(m) => m,
            AnyMapper::Refine(m) => m,
            AnyMapper::Exhaustive(m) => m,
            AnyMapper::Search(m) => m,
        }
    }
}

impl Mapper for AnyMapper {
    fn name(&self) -> String {
        self.inner().name()
    }

    fn evaluations(&self) -> u64 {
        self.inner().evaluations()
    }

    fn map(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<Mapping, MapError> {
        self.inner().map(layer, acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::zoo;

    #[test]
    fn run_reports_timing_and_validates() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let out = LocalMapper::new().run(&layer, &acc).unwrap();
        assert_eq!(out.evaluations, 2);
        assert!(out.evaluation.energy.total_pj() > 0.0);
    }

    #[test]
    fn any_mapper_resolves_all_seven_mechanisms() {
        let acc = presets::eyeriss();
        let layer = zoo::alexnet()[2].clone();
        for spec in ["local", "rs", "ws", "os", "random", "ga", "annealing", "refine", "exhaustive"]
        {
            let m = AnyMapper::parse(spec, 40, 1)
                .unwrap_or_else(|| panic!("spec '{spec}' did not resolve"));
            let out =
                m.run(&layer, &acc).unwrap_or_else(|e| panic!("{spec} failed to map: {e}"));
            out.mapping.validate(&layer, &acc).unwrap();
        }
        assert!(AnyMapper::parse("frob", 40, 1).is_none());
        // Aliases resolve to the same mechanisms.
        assert_eq!(AnyMapper::parse("sa", 10, 1).unwrap().name(), "SA(10)");
        assert_eq!(AnyMapper::parse("ROW", 10, 1).unwrap().name(), "RS-search");
        // The GA honours the budget: the historical 3000 default resolves
        // to the classic p32/g20; small budgets shrink the generations.
        assert_eq!(AnyMapper::parse("ga", 3000, 1).unwrap().name(), "GA(p32g20)");
        assert_eq!(AnyMapper::parse("ga", 40, 1).unwrap().name(), "GA(p32g1)");
    }

    #[test]
    fn any_mapper_is_usable_by_the_batch_pipeline() {
        // AnyMapper must satisfy the coordinator bounds (Clone + Send) so
        // one resolver serves map, compile, compile-all and explore.
        let acc = presets::eyeriss();
        let m = AnyMapper::parse("local", 40, 1).unwrap();
        let plan =
            crate::coordinator::compile_network(&zoo::alexnet(), &acc, &m, 2).unwrap();
        assert_eq!(plan.layers.len(), 5);
    }
}
