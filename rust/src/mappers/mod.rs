//! Mappers: the LOCAL one-pass algorithm (the paper's contribution) and the
//! baselines it is evaluated against — dataflow-constrained search (the
//! Table-3 RS/WS/OS columns), pure random sampling (Fig. 3), exhaustive
//! enumeration (test oracle on small layers) and a GAMMA-style genetic
//! search (related-work ablation, §7).
//!
//! All seven run on the shared [`engine`]: candidate generation is a
//! [`engine::CandidateSource`] (indexed streams) or
//! [`engine::BatchSource`] (adaptive proposals), and the
//! [`engine::SearchDriver`] owns budget truncation, validity filtering,
//! objective scoring, deterministic best-merge, thread sharding and
//! bound-based pruning (DESIGN.md §11).

pub mod annealing;
pub mod engine;
pub mod exhaustive;
pub mod genetic;
pub mod local;
pub mod random;
pub mod refine;
pub mod search;

pub use annealing::AnnealingMapper;
pub use engine::{Objective, SearchDriver, SearchParams};
pub use exhaustive::ExhaustiveMapper;
pub use genetic::GeneticMapper;
pub use local::LocalMapper;
pub use random::RandomMapper;
pub use refine::LocalRefined;
pub use search::ConstrainedSearch;

use crate::arch::Accelerator;
use crate::mapping::{Mapping, MappingError};
use crate::model::{EvalContext, Evaluation};
use crate::workload::Layer;
use std::fmt;
use std::time::{Duration, Instant};

/// Mapper failure. `Clone` so the mapping service can broadcast one
/// search's failure to every request coalesced onto it.
#[derive(Debug, Clone)]
pub enum MapError {
    /// The mapper exhausted its budget/space without a valid mapping.
    NoValidMapping(String),
    /// A constructed mapping failed validation.
    Invalid(MappingError),
    /// The mapper panicked; the payload is the panic message. Produced by
    /// the [`crate::coordinator::MappingService`] worker's `catch_unwind`
    /// containment — a mapper bug surfaces as a typed, per-layer error
    /// (stable code `E_PANIC`) instead of tearing down the process.
    Panicked(String),
}

impl fmt::Display for MapError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MapError::NoValidMapping(msg) => write!(f, "no valid mapping found: {msg}"),
            MapError::Invalid(e) => fmt::Display::fmt(e, f),
            MapError::Panicked(msg) => write!(f, "mapper panicked: {msg}"),
        }
    }
}

impl std::error::Error for MapError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MapError::NoValidMapping(_) => None,
            MapError::Invalid(e) => Some(e),
            MapError::Panicked(_) => None,
        }
    }
}

impl From<MappingError> for MapError {
    fn from(e: MappingError) -> Self {
        MapError::Invalid(e)
    }
}

/// How a mapping was obtained — the degradation ladder's per-layer
/// verdict (DESIGN.md §14).
///
/// `Ok` is the normal case. `Degraded` means a deadline cut the search
/// short and the outcome is the best incumbent found so far — still a
/// valid mapping, just not the one an uncut search would have returned.
/// `FellBack` means the configured mapper failed outright (error or
/// panic) and the service substituted the O(1) LOCAL schedule, so the
/// layer still carries a valid mapping. Neither non-`Ok` state is a
/// failure: the CLI exits 0 for both.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MapStatus {
    /// The configured mapper completed normally.
    Ok,
    /// A deadline truncated the search; the outcome is the best-so-far
    /// incumbent rather than the full search's answer.
    Degraded {
        /// Human-readable cause (e.g. "deadline expired mid-search").
        reason: String,
    },
    /// The configured mapper failed and the LOCAL fallback produced the
    /// mapping instead.
    FellBack {
        /// The original failure that triggered the fallback.
        reason: String,
    },
}

impl MapStatus {
    /// `true` for the normal, non-degraded case.
    pub fn is_ok(&self) -> bool {
        matches!(self, MapStatus::Ok)
    }

    /// Stable machine-readable discriminator: `ok` / `degraded` /
    /// `fell_back` (the `status.kind` value in `api_v1` documents).
    pub fn kind(&self) -> &'static str {
        match self {
            MapStatus::Ok => "ok",
            MapStatus::Degraded { .. } => "degraded",
            MapStatus::FellBack { .. } => "fell_back",
        }
    }

    /// The degradation reason, empty for `Ok` (the `status.reason` value
    /// in `api_v1` documents — both keys are always present).
    pub fn reason(&self) -> &str {
        match self {
            MapStatus::Ok => "",
            MapStatus::Degraded { reason } | MapStatus::FellBack { reason } => reason,
        }
    }
}

impl fmt::Display for MapStatus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MapStatus::Ok => write!(f, "ok"),
            MapStatus::Degraded { reason } => write!(f, "degraded: {reason}"),
            MapStatus::FellBack { reason } => write!(f, "fell back: {reason}"),
        }
    }
}

/// Result of running a mapper: the chosen mapping, its evaluation, and the
/// search cost (the paper's *mapping time*, Table 3).
#[derive(Debug, Clone)]
pub struct MapOutcome {
    /// The chosen mapping.
    pub mapping: Mapping,
    /// Analytical evaluation of the chosen mapping.
    pub evaluation: Evaluation,
    /// Number of candidate evaluations performed (2 for LOCAL — its
    /// constant-size schedule comparison; hundreds–thousands for search).
    pub evaluations: u64,
    /// Wall-clock search time.
    pub elapsed: Duration,
    /// The objective the mapper minimized.
    pub objective: Objective,
    /// The chosen mapping's objective score (lower is better).
    pub score: f64,
    /// Whether the search provably covered its whole candidate space, so
    /// `mapping` is a certified optimum over it (branch-and-bound under
    /// `--certify` with a budget admitting the full space; always `false`
    /// for heuristic and budget-truncated searches).
    pub certified: bool,
    /// How the mapping was obtained: normally, deadline-truncated, or via
    /// the LOCAL fallback (DESIGN.md §14).
    pub status: MapStatus,
}

/// A mapping algorithm: layer × accelerator → mapping.
pub trait Mapper {
    /// Short display name ("LOCAL", "RS-search", ...).
    fn name(&self) -> String;

    /// The objective this mapper instance minimizes (engine mappers carry
    /// it as configuration; the default is the historical energy metric).
    fn objective(&self) -> Objective {
        Objective::Energy
    }

    /// Construct the mapping only (no timing bookkeeping).
    fn map(&self, layer: &Layer, acc: &Accelerator) -> Result<Mapping, MapError>;

    /// Whether this mapper makes use of cross-layer warm-start seeds in
    /// [`Mapper::map_seeded`]. The service gates all similarity-index
    /// work on this, so mappers that ignore seeds — LOCAL above all, whose
    /// one-pass construction is already O(1) — pay nothing for the
    /// warm-start machinery (DESIGN.md §15).
    fn accepts_seeds(&self) -> bool {
        false
    }

    /// Construct the mapping with cross-layer warm-start seeds (valid
    /// mappings adapted from similar, already-mapped layers). The default
    /// ignores the seeds. Implementations must keep the warm-start
    /// contract: exhaustive/B&B searches use seeds as external incumbent
    /// bounds only (bit-identical final mapping), heuristic searches merge
    /// them into the result only (final score never worse than unseeded).
    fn map_seeded(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        _seeds: &[Mapping],
    ) -> Result<Mapping, MapError> {
        self.map(layer, acc)
    }

    /// Number of candidate evaluations `map` performs (reported in
    /// Table 3 next to wall-clock).
    fn evaluations(&self) -> u64 {
        1
    }

    /// Whether the last `map` call provably covered its whole candidate
    /// space (branch-and-bound certification,
    /// [`crate::mappers::engine::SearchDriver::branch_and_bound`]).
    /// Mappers without a certification notion report `false`.
    fn certified(&self) -> bool {
        false
    }

    /// Status of the last `map` call: [`MapStatus::Degraded`] when a
    /// deadline truncated the search ([`SearchParams::deadline_ms`]).
    /// Mappers without a deadline notion — LOCAL above all, whose O(1)
    /// pass is the guaranteed bottom of the degradation ladder — report
    /// [`MapStatus::Ok`]. (The [`MapStatus::FellBack`] state is assigned
    /// by the service worker, never by a mapper itself.)
    fn status(&self) -> MapStatus {
        MapStatus::Ok
    }

    /// Run with timing: the measured quantity of the paper's Table 3.
    /// The final evaluation goes through the same [`EvalContext`] engine
    /// the search loops use (bit-identical to the legacy evaluator), so
    /// every caller — coordinator workers, `explore::sweep`, the CLI —
    /// exercises one evaluation path. For this single evaluation the
    /// context is built fresh (a one-time cost dwarfed by the `map()`
    /// search it follows); the zero-allocation payoff is inside the
    /// engine's candidate loops.
    fn run(&self, layer: &Layer, acc: &Accelerator) -> Result<MapOutcome, MapError> {
        self.run_seeded(layer, acc, &[])
    }

    /// [`Mapper::run`] with cross-layer warm-start seeds threaded through
    /// to [`Mapper::map_seeded`] — the entry point the service worker uses
    /// when the similarity index supplies a neighbor's adapted mapping.
    fn run_seeded(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        seeds: &[Mapping],
    ) -> Result<MapOutcome, MapError> {
        let t0 = Instant::now();
        let mapping = self.map_seeded(layer, acc, seeds)?;
        let elapsed = t0.elapsed();
        mapping.validate(layer, acc)?;
        let mut ctx = EvalContext::new(layer, acc);
        let evaluation = ctx.evaluate_into(&mapping).clone();
        let objective = self.objective();
        let score = objective.score(&evaluation);
        Ok(MapOutcome {
            mapping,
            evaluation,
            evaluations: self.evaluations(),
            elapsed,
            objective,
            score,
            certified: self.certified(),
            status: self.status(),
        })
    }
}

/// Every mapper in the framework behind one cloneable, sendable dispatch
/// type — the single resolver the CLI's `map`, `compile`, `compile-all`
/// and `explore` subcommands all share ([`AnyMapper::parse`]), so the
/// full mapper set is exposed consistently everywhere a `--mapper` flag
/// is accepted.
#[derive(Debug, Clone)]
pub enum AnyMapper {
    /// The LOCAL one-pass mapper (the paper's contribution).
    Local(LocalMapper),
    /// Best-of-N random sampling (Fig. 3 baseline).
    Random(RandomMapper),
    /// GAMMA-style genetic search.
    Genetic(GeneticMapper),
    /// Simulated annealing.
    Annealing(AnnealingMapper),
    /// LOCAL seed + bounded hill-climbing refinement.
    Refine(LocalRefined),
    /// Sharded-parallel exhaustive enumeration (budget-truncated, pruned).
    Exhaustive(ExhaustiveMapper),
    /// Dataflow-constrained search (the RS/WS/OS Table-3 baselines).
    Search(ConstrainedSearch),
}

impl AnyMapper {
    /// The mapper spec strings [`AnyMapper::parse`] accepts (shown in CLI
    /// help and error messages).
    pub const SPEC: &str = "local|rs|ws|os|random|ga|annealing|refine|exhaustive";

    /// Resolve a mapper spec under shared [`SearchParams`]. The budget
    /// caps search mappers (candidate evaluations / annealing steps; the
    /// GA scales its generation count as `budget / 150`, so the
    /// historical 3000 default yields the classic p32/g20 configuration);
    /// the seed makes stochastic mappers deterministic; the objective,
    /// thread count and pruning switch are threaded into every engine
    /// mapper. Returns `None` for an unknown spec.
    pub fn parse(spec: &str, params: SearchParams) -> Option<AnyMapper> {
        let params = SearchParams { budget: params.budget.max(1), ..params };
        Some(match spec.to_ascii_lowercase().as_str() {
            "local" => AnyMapper::Local(LocalMapper::new().with_objective(params.objective)),
            "random" => AnyMapper::Random(RandomMapper::from_params(&params)),
            "ga" | "genetic" => {
                let generations = (params.budget / 150).max(1) as usize;
                let ga = GeneticMapper::new(32, generations, params.seed).with_params(&params);
                AnyMapper::Genetic(ga)
            }
            "annealing" | "sa" => AnyMapper::Annealing(AnnealingMapper::from_params(&params)),
            "refine" | "local+refine" => AnyMapper::Refine(LocalRefined::from_params(&params)),
            "exhaustive" => {
                AnyMapper::Exhaustive(ExhaustiveMapper::from_params(&params).with_permutations())
            }
            df => AnyMapper::Search(ConstrainedSearch::from_params(
                crate::mapspace::Dataflow::parse(df)?,
                &params,
            )),
        })
    }

    fn inner(&self) -> &dyn Mapper {
        match self {
            AnyMapper::Local(m) => m,
            AnyMapper::Random(m) => m,
            AnyMapper::Genetic(m) => m,
            AnyMapper::Annealing(m) => m,
            AnyMapper::Refine(m) => m,
            AnyMapper::Exhaustive(m) => m,
            AnyMapper::Search(m) => m,
        }
    }
}

impl Mapper for AnyMapper {
    fn name(&self) -> String {
        self.inner().name()
    }

    fn objective(&self) -> Objective {
        self.inner().objective()
    }

    fn evaluations(&self) -> u64 {
        self.inner().evaluations()
    }

    fn certified(&self) -> bool {
        self.inner().certified()
    }

    fn status(&self) -> MapStatus {
        self.inner().status()
    }

    fn map(&self, layer: &Layer, acc: &Accelerator) -> Result<Mapping, MapError> {
        self.inner().map(layer, acc)
    }

    fn accepts_seeds(&self) -> bool {
        self.inner().accepts_seeds()
    }

    fn map_seeded(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        seeds: &[Mapping],
    ) -> Result<Mapping, MapError> {
        self.inner().map_seeded(layer, acc, seeds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::zoo;

    #[test]
    fn run_reports_timing_and_validates() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let out = LocalMapper::new().run(&layer, &acc).unwrap();
        assert_eq!(out.evaluations, 2);
        assert!(out.evaluation.energy.total_pj() > 0.0);
        // The outcome carries the objective and its score.
        assert_eq!(out.objective, Objective::Energy);
        assert_eq!(out.score, out.evaluation.energy.total_pj());
    }

    #[test]
    fn run_scores_the_configured_objective() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let out =
            LocalMapper::new().with_objective(Objective::Edp).run(&layer, &acc).unwrap();
        assert_eq!(out.objective, Objective::Edp);
        assert_eq!(out.score, out.evaluation.edp());
    }

    #[test]
    fn any_mapper_resolves_all_seven_mechanisms() {
        let acc = presets::eyeriss();
        let layer = zoo::alexnet()[2].clone();
        for spec in ["local", "rs", "ws", "os", "random", "ga", "annealing", "refine", "exhaustive"]
        {
            let m = AnyMapper::parse(spec, SearchParams::new(40, 1))
                .unwrap_or_else(|| panic!("spec '{spec}' did not resolve"));
            let out =
                m.run(&layer, &acc).unwrap_or_else(|e| panic!("{spec} failed to map: {e}"));
            out.mapping.validate(&layer, &acc).unwrap();
        }
        assert!(AnyMapper::parse("frob", SearchParams::new(40, 1)).is_none());
        // Aliases resolve to the same mechanisms.
        assert_eq!(AnyMapper::parse("sa", SearchParams::new(10, 1)).unwrap().name(), "SA(10)");
        assert_eq!(AnyMapper::parse("ROW", SearchParams::new(10, 1)).unwrap().name(), "RS-search");
        // The GA honours the budget: the historical 3000 default resolves
        // to the classic p32/g20; small budgets shrink the generations.
        let ga = AnyMapper::parse("ga", SearchParams::new(3000, 1)).unwrap();
        assert_eq!(ga.name(), "GA(p32g20)");
        assert_eq!(AnyMapper::parse("ga", SearchParams::new(40, 1)).unwrap().name(), "GA(p32g1)");
    }

    #[test]
    fn any_mapper_threads_the_objective_through_parse() {
        let params = SearchParams::new(40, 1).with_objective(Objective::Delay);
        for spec in ["local", "rs", "random", "ga", "annealing", "refine", "exhaustive"] {
            let m = AnyMapper::parse(spec, params).unwrap();
            assert_eq!(m.objective(), Objective::Delay, "{spec}");
        }
        // An objective-aware mapper minimizes what it was asked to: on a
        // searched layer the delay-optimal pick is never slower than the
        // energy-optimal pick.
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let p = SearchParams::new(200, 7);
        let energy = AnyMapper::parse("random", p).unwrap().run(&layer, &acc).unwrap();
        let delay = AnyMapper::parse("random", p.with_objective(Objective::Delay))
            .unwrap()
            .run(&layer, &acc)
            .unwrap();
        assert!(delay.evaluation.latency_cycles <= energy.evaluation.latency_cycles);
    }

    #[test]
    fn any_mapper_is_usable_by_the_batch_pipeline() {
        // AnyMapper must satisfy the coordinator bounds (Clone + Send) so
        // one resolver serves map, compile, compile-all and explore.
        let acc = presets::eyeriss();
        let m = AnyMapper::parse("local", SearchParams::new(40, 1)).unwrap();
        let plan =
            crate::coordinator::compile_network(&zoo::alexnet(), &acc, &m, 2).unwrap();
        assert_eq!(plan.layers.len(), 5);
    }
}
