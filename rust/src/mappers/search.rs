//! Dataflow-constrained search — the paper's RS/WS/OS baselines.
//!
//! §6.2: "the calculation time of row, weight, and output stationary are
//! extracted from the Timeloop-Accelergy framework by defining data-reuse
//! constraints … we still need many comparisons to select the appropriate
//! case". We reproduce that experiment design: the dataflow becomes a
//! [`Constraints`] restriction of the map-space, and a sampling search with
//! a Timeloop-style victory condition (stop after `patience` consecutive
//! non-improving candidates, or at `budget`) picks the best-energy mapping.
//! Mapping time = wall-clock of the whole search; LOCAL does one pass.

use super::{MapError, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::mapspace::{sample_random, Dataflow};
use crate::model::EvalContext;
use crate::util::rng::SplitMix64;
use crate::workload::ConvLayer;
use std::cell::Cell;

/// Search within a dataflow-constrained map-space.
#[derive(Debug, Clone)]
pub struct ConstrainedSearch {
    /// The stationary dataflow restricting the map-space.
    pub dataflow: Dataflow,
    /// Hard cap on candidate evaluations.
    pub budget: u64,
    /// Victory condition: consecutive non-improving candidates before
    /// declaring convergence (Timeloop's `victory-condition`).
    pub patience: u64,
    /// PRNG seed (deterministic across runs).
    pub seed: u64,
    evaluated: Cell<u64>,
}

impl ConstrainedSearch {
    /// Search inside `dataflow`'s subspace with the given budget and seed.
    pub fn new(dataflow: Dataflow, budget: u64, seed: u64) -> Self {
        assert!(budget > 0);
        Self { dataflow, budget, patience: budget / 4 + 1, seed, evaluated: Cell::new(0) }
    }

    /// Timeloop-ish defaults used by the Table-3 bench.
    pub fn table3(dataflow: Dataflow, seed: u64) -> Self {
        Self::new(dataflow, 3000, seed)
    }
}

impl Mapper for ConstrainedSearch {
    fn name(&self) -> String {
        format!("{}-search", self.dataflow.name())
    }

    fn evaluations(&self) -> u64 {
        self.evaluated.get()
    }

    fn map(&self, layer: &ConvLayer, acc: &Accelerator) -> Result<Mapping, MapError> {
        let cons = self.dataflow.constraints();
        let mut rng = SplitMix64::new(self.seed);
        let mut ctx = EvalContext::new(layer, acc);
        let mut best: Option<(f64, Mapping)> = None;
        let mut since_improved = 0u64;
        let mut evaluated = 0u64;
        while evaluated < self.budget {
            let mut m = sample_random(layer, acc, &mut rng);
            cons.imprint(layer, acc, &mut m, &mut rng);
            if m.validate(layer, acc).is_err() {
                // Imprint could not satisfy both constraints and capacity
                // for this draw; count it (Timeloop counts invalids too).
                evaluated += 1;
                continue;
            }
            let pj = ctx.energy_pj(&m);
            evaluated += 1;
            if best.as_ref().map(|(b, _)| pj < *b).unwrap_or(true) {
                best = Some((pj, m));
                since_improved = 0;
            } else {
                since_improved += 1;
                if since_improved >= self.patience {
                    break;
                }
            }
        }
        self.evaluated.set(evaluated);
        best.map(|(_, m)| m).ok_or_else(|| {
            MapError::NoValidMapping(format!(
                "{} found no valid candidate in {} draws on {} × {}",
                self.name(),
                self.budget,
                layer.name,
                acc.name
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::LocalMapper;
    use crate::workload::zoo;

    #[test]
    fn all_dataflows_find_valid_mappings() {
        for df in [Dataflow::RowStationary, Dataflow::WeightStationary, Dataflow::OutputStationary] {
            for acc in presets::all() {
                let layer = zoo::vgg16()[8].clone();
                let s = ConstrainedSearch::new(df, 300, 42);
                let out = s.run(&layer, &acc).unwrap();
                out.mapping.validate(&layer, &acc).unwrap();
                assert!(out.evaluations > 1, "{} did not search", s.name());
            }
        }
    }

    #[test]
    fn search_result_admitted_by_constraints() {
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[8].clone();
        let s = ConstrainedSearch::new(Dataflow::WeightStationary, 200, 1);
        let m = s.map(&layer, &acc).unwrap();
        assert!(Dataflow::WeightStationary.constraints().admit(&layer, &acc, &m));
    }

    #[test]
    fn more_budget_never_hurts() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let small = ConstrainedSearch::new(Dataflow::RowStationary, 50, 3).run(&layer, &acc).unwrap();
        let big = ConstrainedSearch::new(Dataflow::RowStationary, 500, 3).run(&layer, &acc).unwrap();
        assert!(big.evaluation.energy.total_pj() <= small.evaluation.energy.total_pj());
    }

    #[test]
    fn local_is_much_cheaper_than_search() {
        // The Table-3 shape: LOCAL evaluates once; search evaluates many.
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let local = LocalMapper::new().run(&layer, &acc).unwrap();
        let search = ConstrainedSearch::table3(Dataflow::RowStationary, 42).run(&layer, &acc).unwrap();
        assert_eq!(local.evaluations, 2);
        assert!(search.evaluations >= 100, "search too short: {}", search.evaluations);
    }
}
