//! Dataflow-constrained search — the paper's RS/WS/OS baselines.
//!
//! §6.2: "the calculation time of row, weight, and output stationary are
//! extracted from the Timeloop-Accelergy framework by defining data-reuse
//! constraints … we still need many comparisons to select the appropriate
//! case". We reproduce that experiment design: the dataflow becomes a
//! [`crate::mapspace::Constraints`] restriction of the map-space imprinted on the engine's
//! [`RandomStream`], and the shared [`SearchDriver`] picks the
//! best-objective mapping under the evaluation budget. Mapping time =
//! wall-clock of the whole search; LOCAL does one pass.
//!
//! Because the stream is indexed, the search is **parallel** (identical
//! outcomes at every thread count) and **pruned** by default: candidates
//! whose [`crate::model::EvalContext::objective_bound`] already exceeds
//! the incumbent are skipped without a model evaluation — the
//! Turbo-Charged-Mapper move — which never changes the selected mapping
//! (`prop_pruned_constrained_search_is_bit_identical`).

use super::engine::{deadline_instant, Objective, RandomStream, SearchDriver};
use super::{MapError, MapStatus, Mapper};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::mapspace::Dataflow;
use crate::workload::Layer;
use std::cell::Cell;

/// Search within a dataflow-constrained map-space.
#[derive(Debug, Clone)]
pub struct ConstrainedSearch {
    /// The stationary dataflow restricting the map-space.
    pub dataflow: Dataflow,
    /// Hard cap on candidate evaluations.
    pub budget: u64,
    /// PRNG seed (deterministic across runs).
    pub seed: u64,
    /// The objective being minimized.
    pub objective: Objective,
    /// Worker threads (identical results at every value).
    pub threads: usize,
    /// Bound-based pruning (on by default; never changes the selected
    /// mapping, only cuts evaluations).
    pub prune: bool,
    /// Per-layer wall-clock deadline, ms (`None` = unbounded).
    pub deadline_ms: Option<u64>,
    evaluated: Cell<u64>,
    pruned: Cell<u64>,
    degraded: Cell<bool>,
}

impl ConstrainedSearch {
    /// Search inside `dataflow`'s subspace with the given budget and seed.
    pub fn new(dataflow: Dataflow, budget: u64, seed: u64) -> Self {
        assert!(budget > 0);
        Self {
            dataflow,
            budget,
            seed,
            objective: Objective::Energy,
            threads: 1,
            prune: true,
            deadline_ms: None,
            evaluated: Cell::new(0),
            pruned: Cell::new(0),
            degraded: Cell::new(false),
        }
    }

    /// Search configured from shared engine params.
    pub fn from_params(dataflow: Dataflow, params: &super::SearchParams) -> Self {
        let mut s = Self::new(dataflow, params.budget, params.seed);
        s.objective = params.objective;
        s.threads = params.threads.max(1);
        s.prune = params.prune;
        s.deadline_ms = params.deadline_ms;
        s
    }

    /// Timeloop-ish defaults used by the Table-3 bench.
    pub fn table3(dataflow: Dataflow, seed: u64) -> Self {
        Self::new(dataflow, 3000, seed)
    }

    /// Builder: minimize `objective` instead of energy.
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Builder: shard the stream across `threads` workers.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Builder: disable bound-based pruning (every in-budget draw is
    /// materialized and checked — the historical accounting).
    pub fn without_pruning(mut self) -> Self {
        self.prune = false;
        self
    }

    /// Candidates skipped by the pruner on the last `map` call.
    pub fn pruned(&self) -> u64 {
        self.pruned.get()
    }
}

impl Mapper for ConstrainedSearch {
    fn name(&self) -> String {
        format!("{}-search", self.dataflow.name())
    }

    fn objective(&self) -> Objective {
        self.objective
    }

    fn evaluations(&self) -> u64 {
        self.evaluated.get()
    }

    fn status(&self) -> MapStatus {
        if self.degraded.get() {
            MapStatus::Degraded { reason: "deadline expired mid-search".into() }
        } else {
            MapStatus::Ok
        }
    }

    fn map(&self, layer: &Layer, acc: &Accelerator) -> Result<Mapping, MapError> {
        self.map_seeded(layer, acc, &[])
    }

    fn accepts_seeds(&self) -> bool {
        true
    }

    /// Cross-layer seeds ride the engine's warm-start slot, but only the
    /// ones the dataflow's constraints admit — the candidate set (and any
    /// returned mapping) must stay inside the constrained subspace. An
    /// admitted seed is scored at a post-stream index (exact ties to the
    /// stream), so the result is never worse than unseeded.
    fn map_seeded(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        seeds: &[Mapping],
    ) -> Result<Mapping, MapError> {
        self.degraded.set(false);
        let constraints = self.dataflow.constraints();
        let admitted: Vec<Mapping> = seeds
            .iter()
            .filter(|s| constraints.admit(layer, acc, s))
            .cloned()
            .collect();
        let source = RandomStream::new(layer, acc, self.seed, self.budget)
            .constrained(self.dataflow.constraints());
        let driver = SearchDriver {
            objective: self.objective,
            budget: self.budget,
            threads: self.threads,
            prune: self.prune,
            deadline: deadline_instant(self.deadline_ms),
        };
        // The imprinted draws can still fail validation; the driver counts
        // them like Timeloop counts invalids.
        match driver.search(layer, acc, &source, &admitted) {
            Some(b) => {
                self.evaluated.set(b.examined);
                self.pruned.set(b.pruned);
                self.degraded.set(b.degraded);
                Ok(b.mapping)
            }
            None => {
                self.evaluated.set(self.budget);
                self.pruned.set(0);
                Err(MapError::NoValidMapping(format!(
                    "{} found no valid candidate in {} draws on {} × {}",
                    self.name(),
                    self.budget,
                    layer.name,
                    acc.name
                )))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::LocalMapper;
    use crate::workload::zoo;

    #[test]
    fn all_dataflows_find_valid_mappings() {
        for df in [Dataflow::RowStationary, Dataflow::WeightStationary, Dataflow::OutputStationary] {
            for acc in presets::all() {
                let layer = zoo::vgg16()[8].clone();
                let s = ConstrainedSearch::new(df, 300, 42);
                let out = s.run(&layer, &acc).unwrap();
                out.mapping.validate(&layer, &acc).unwrap();
                assert!(out.evaluations > 1, "{} did not search", s.name());
            }
        }
    }

    #[test]
    fn search_result_admitted_by_constraints() {
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[8].clone();
        let s = ConstrainedSearch::new(Dataflow::WeightStationary, 200, 1);
        let m = s.map(&layer, &acc).unwrap();
        assert!(Dataflow::WeightStationary.constraints().admit(&layer, &acc, &m));
    }

    #[test]
    fn more_budget_never_hurts() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let small = ConstrainedSearch::new(Dataflow::RowStationary, 50, 3).run(&layer, &acc).unwrap();
        let big = ConstrainedSearch::new(Dataflow::RowStationary, 500, 3).run(&layer, &acc).unwrap();
        assert!(big.evaluation.energy.total_pj() <= small.evaluation.energy.total_pj());
    }

    #[test]
    fn parallel_search_is_thread_invariant() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let serial = ConstrainedSearch::new(Dataflow::RowStationary, 400, 7);
        let base = serial.run(&layer, &acc).unwrap();
        for threads in [2usize, 4, 8] {
            let s = ConstrainedSearch::new(Dataflow::RowStationary, 400, 7).with_threads(threads);
            let out = s.run(&layer, &acc).unwrap();
            assert_eq!(out.mapping, base.mapping, "threads={threads}");
            assert_eq!(out.evaluations, base.evaluations, "threads={threads}");
        }
    }

    #[test]
    fn pruning_only_cuts_evaluations() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let full = ConstrainedSearch::new(Dataflow::RowStationary, 600, 11).without_pruning();
        let base = full.run(&layer, &acc).unwrap();
        let fast = ConstrainedSearch::new(Dataflow::RowStationary, 600, 11);
        let out = fast.run(&layer, &acc).unwrap();
        assert_eq!(out.mapping, base.mapping);
        assert!(out.evaluations <= base.evaluations);
        assert_eq!(out.evaluations + fast.pruned(), base.evaluations);
    }

    #[test]
    fn local_is_much_cheaper_than_search() {
        // The Table-3 shape: LOCAL evaluates once; search evaluates many.
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[8].clone();
        let local = LocalMapper::new().run(&layer, &acc).unwrap();
        let search = ConstrainedSearch::table3(Dataflow::RowStationary, 42).run(&layer, &acc).unwrap();
        assert_eq!(local.evaluations, 2);
        assert!(search.evaluations >= 100, "search too short: {}", search.evaluations);
    }
}
