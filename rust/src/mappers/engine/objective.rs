//! The search objective: which scalar the engine minimizes.
//!
//! Historically every mapper hardcoded total energy. The engine threads a
//! user-chosen [`Objective`] through candidate scoring, the best-merge
//! tie-break, [`crate::mappers::MapOutcome`], the coordinator's cache key
//! ([`crate::coordinator::LayerKey`]) and the `--objective` CLI flag, so
//! distinct objectives are first-class and never share cached mappings.

use crate::model::Evaluation;
use std::fmt;

/// The scalar a search minimizes over candidate mappings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Objective {
    /// Total energy, pJ (the paper's Fig. 3 / Fig. 7 axis; the historical
    /// hardcoded metric).
    #[default]
    Energy,
    /// Roofline latency, cycles.
    Delay,
    /// Energy–delay product, pJ·cycles.
    Edp,
}

impl Objective {
    /// Spec strings [`Objective::parse`] accepts (CLI help text).
    pub const SPEC: &str = "energy|delay|edp";

    /// Every objective (report/bench sweeps).
    pub const ALL: [Objective; 3] = [Objective::Energy, Objective::Delay, Objective::Edp];

    /// Parse a CLI spec (case-insensitive; `latency` aliases `delay`).
    pub fn parse(s: &str) -> Option<Objective> {
        match s.to_ascii_lowercase().as_str() {
            "energy" => Some(Objective::Energy),
            "delay" | "latency" => Some(Objective::Delay),
            "edp" => Some(Objective::Edp),
            _ => None,
        }
    }

    /// Canonical lowercase name (cache keys, JSON, CLI echo).
    pub fn name(self) -> &'static str {
        match self {
            Objective::Energy => "energy",
            Objective::Delay => "delay",
            Objective::Edp => "edp",
        }
    }

    /// Compose the objective scalar from the two primitive metrics. Shared
    /// by real scores ([`Objective::score`]) and the pruner's lower bounds:
    /// composing component-wise lower bounds yields a lower bound of the
    /// composed score because every composition is monotone in both
    /// arguments (and IEEE rounding is monotone).
    pub fn compose(self, energy_pj: f64, latency_cycles: u64) -> f64 {
        match self {
            Objective::Energy => energy_pj,
            Objective::Delay => latency_cycles as f64,
            Objective::Edp => energy_pj * latency_cycles as f64,
        }
    }

    /// Score one evaluated candidate (lower is better).
    pub fn score(self, e: &Evaluation) -> f64 {
        self.compose(e.energy.total_pj(), e.latency_cycles)
    }
}

impl fmt::Display for Objective {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mappers::{LocalMapper, Mapper};
    use crate::workload::zoo;

    #[test]
    fn parse_round_trips_and_aliases() {
        for o in Objective::ALL {
            assert_eq!(Objective::parse(o.name()), Some(o));
            assert_eq!(o.to_string(), o.name());
        }
        assert_eq!(Objective::parse("LATENCY"), Some(Objective::Delay));
        assert_eq!(Objective::parse("frob"), None);
        assert_eq!(Objective::default(), Objective::Energy);
    }

    #[test]
    fn scores_match_the_evaluation_fields() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg16()[0].clone();
        let out = LocalMapper::new().run(&layer, &acc).unwrap();
        let e = &out.evaluation;
        assert_eq!(Objective::Energy.score(e), e.energy.total_pj());
        assert_eq!(Objective::Delay.score(e), e.latency_cycles as f64);
        assert_eq!(Objective::Edp.score(e), e.edp());
    }
}
