//! True branch-and-bound over the tiling factorization lattice
//! (DESIGN.md §13).
//!
//! [`super::SearchDriver::search`] over an [`super::OdometerSource`] prunes
//! one permutation block at a time: it must still *materialize* every
//! tiling before bounding it. [`BoundedLattice`] exposes the same candidate
//! space as a lattice of partial factor assignments — dims are fixed one at
//! a time in [`crate::mapspace::lattice_order`] (descending odometer
//! significance), so every partial assignment owns one **contiguous** range
//! of global block indices. [`SearchDriver::branch_and_bound`] walks that
//! lattice depth-first, bounds each subtree with
//! [`EvalContext::partial_bound`] and skips it wholesale when the bound
//! already exceeds the incumbent — same argmin, same tie-break index, a
//! fraction of the bound computations and none of the materialization for
//! pruned subtrees.
//!
//! # Certification
//!
//! The walk covers exactly the driver's budget-truncated index range, and
//! a skipped subtree provably contains no candidate better than the
//! incumbent ([`EvalContext::partial_bound`]'s lower-bound contract). When
//! the budget admits the *entire* space, the returned best is therefore a
//! certified optimum over every enumerated tiling × rotation — reported as
//! the `certified` flag, and surfaced all the way up through
//! [`crate::mappers::MapOutcome`] and the `api_v1` JSON.
//!
//! # Determinism
//!
//! Identical machinery to [`super::SearchDriver::search`]: synchronized
//! pruning rounds with the incumbent frozen at each round boundary,
//! contiguous per-worker shards, and the lowest-score/lowest-index merge.
//! A node's prune decision depends only on its bound and the frozen
//! incumbent — never on which worker visits it — so the evaluated set,
//! every count and the argmin are bit-identical at every thread count
//! (pinned by `prop_branch_and_bound_matches_unpruned_exhaustive`).

use super::{merge_best, min_opt, shard_start, CandidateSource, SearchBest, SearchDriver, ShardResult};
use super::{Objective, MIN_ROUND_BLOCKS, PRUNE_ROUNDS};
use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::mapspace::lattice_order;
use crate::model::EvalContext;
use crate::util::factor::factorizations;
use crate::workload::{Dim, Layer};

/// The exhaustive tiling space exposed as a branch-and-bound lattice.
///
/// Enumerates exactly the candidates of [`super::OdometerSource`] under
/// exactly the same global indices (`block × perms + rotation`, dim 0 the
/// least-significant odometer digit), so an exact score tie between two
/// distinct tilings resolves to the same winner whichever engine ran —
/// the precondition for the bit-identity guarantees of
/// [`SearchDriver::branch_and_bound`].
#[derive(Debug)]
pub struct BoundedLattice {
    /// `per_dim[d]` = ordered splits of dim `d`'s bound across
    /// `[sx, sy, t0, .., t_top]` (identical to the odometer's tables).
    per_dim: Vec<Vec<Vec<u64>>>,
    /// `weight[d]` = blocks per index step of dim `d` (`Π_{d' < d} len`),
    /// `weight[7]` = the whole space; saturating.
    weight: [u64; 8],
    /// Lattice assignment order (descending significance); depth `k` of
    /// the DFS fixes `order[k]`.
    order: [Dim; 7],
    n_levels: usize,
    perms: u64,
}

impl BoundedLattice {
    /// Build the lattice for one (layer, accelerator) pair. `permute` adds
    /// the 7-rotation permutation fan-out per tiling, as the odometer does.
    pub fn new(layer: &Layer, acc: &Accelerator, permute: bool) -> Self {
        let n_levels = acc.n_levels();
        let slots = n_levels + 2;
        let per_dim: Vec<Vec<Vec<u64>>> =
            Dim::ALL.iter().map(|&d| factorizations(layer.bound(d), slots)).collect();
        let mut weight = [1u64; 8];
        for d in 0..7 {
            weight[d + 1] = weight[d].saturating_mul(per_dim[d].len() as u64);
        }
        #[cfg(debug_assertions)]
        for depth in 0..=7usize {
            // The subtree spans must agree with the mapspace accounting.
            debug_assert_eq!(
                weight[7 - depth],
                crate::mapspace::lattice_subtree_blocks(layer, acc, depth),
                "lattice span mismatch at depth {depth}"
            );
        }
        Self { per_dim, weight, order: lattice_order(), n_levels, perms: if permute { 7 } else { 1 } }
    }

    /// Exact space size (blocks), before any u64 clamping.
    fn blocks_u128(&self) -> u128 {
        self.per_dim.iter().map(|v| v.len() as u128).product()
    }

    /// Write split `i` of dim `d` into `m`'s spatial/temporal slots.
    fn assign(&self, d: usize, i: usize, m: &mut Mapping) {
        let split = &self.per_dim[d][i];
        m.spatial_x[d] = split[0];
        m.spatial_y[d] = split[1];
        for l in 0..self.n_levels {
            m.temporal[l][d] = split[2 + l];
        }
    }

    /// Reset dim `d` to the all-ones (unassigned) split.
    fn clear(&self, d: usize, m: &mut Mapping) {
        m.spatial_x[d] = 1;
        m.spatial_y[d] = 1;
        for l in 0..self.n_levels {
            m.temporal[l][d] = 1;
        }
    }
}

impl CandidateSource for BoundedLattice {
    fn n_blocks(&self) -> u64 {
        self.blocks_u128().min(u64::MAX as u128) as u64
    }

    fn block_len(&self) -> u64 {
        self.perms
    }

    fn emit_block(&self, b: u64, m: &mut Mapping) -> bool {
        let mut linear = b;
        for (d, splits) in self.per_dim.iter().enumerate() {
            let len = splits.len() as u64;
            let idx = (linear % len) as usize;
            linear /= len;
            let split = &splits[idx];
            m.spatial_x[d] = split[0];
            m.spatial_y[d] = split[1];
            for l in 0..self.n_levels {
                m.temporal[l][d] = split[2 + l];
            }
        }
        for p in m.permutation.iter_mut() {
            *p = Dim::ALL;
        }
        true
    }

    fn emit_member(&self, _b: u64, i: u64, m: &mut Mapping) {
        let mut p = Dim::ALL;
        p.rotate_left((i % 7) as usize);
        for perm in m.permutation.iter_mut() {
            *perm = p;
        }
    }

    fn rotation_members(&self) -> bool {
        true
    }
}

/// One worker's depth-first walk over its contiguous block range.
struct Dfs<'a> {
    src: &'a BoundedLattice,
    layer: &'a Layer,
    acc: &'a Accelerator,
    objective: Objective,
    prune: bool,
    ctx: &'a mut EvalContext,
    /// Scratch mapping: unassigned dims carry 1 everywhere (the
    /// [`EvalContext::partial_bound`] precondition).
    m: &'a mut Mapping,
    assigned: [bool; 7],
    /// Incumbent frozen at the round boundary.
    incumbent: Option<f64>,
    /// This worker's block range within the round.
    lo: u64,
    hi: u64,
    budget: u64,
    visit_blocks: u64,
    /// Candidates of the final visited block that fall past the budget.
    overhang: u64,
    out: ShardResult,
    members_buf: Vec<Mapping>,
    member_ids: Vec<u64>,
    scores: Vec<(f64, u64)>,
}

impl Dfs<'_> {
    /// In-budget candidate count of the block range `[a, b)`.
    fn members_in(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < b && b <= self.visit_blocks);
        let mut n = (b - a) * self.src.perms;
        if b == self.visit_blocks {
            n -= self.overhang;
        }
        n
    }

    /// Visit the lattice node whose first `depth` dims are assigned and
    /// whose block range starts at `base`, clipped to `[lo, hi)`.
    fn node(&mut self, depth: usize, base: u64) {
        if depth == 7 {
            self.leaf(base);
            return;
        }
        let d = self.src.order[depth].idx();
        let w = self.src.weight[d];
        let len = self.src.per_dim[d].len() as u64;
        for i in 0..len {
            let child = base.saturating_add(i.saturating_mul(w));
            if child >= self.hi {
                break; // choices are index-ascending: nothing further overlaps
            }
            let child_end = child.saturating_add(w);
            if child_end <= self.lo {
                continue;
            }
            self.src.assign(d, i as usize, self.m);
            self.assigned[d] = true;
            let mut cut = false;
            if self.prune {
                if let Some(inc) = self.incumbent {
                    let (e_lb, l_lb) = self.ctx.partial_bound(self.m, &self.assigned);
                    if self.objective.compose(e_lb, l_lb) > inc {
                        // No valid completion in this subtree can beat the
                        // incumbent: skip it wholesale, counting only the
                        // in-range, in-budget candidates.
                        self.out.pruned +=
                            self.members_in(child.max(self.lo), child_end.min(self.hi));
                        cut = true;
                    }
                }
            }
            if !cut {
                self.node(depth + 1, child);
            }
        }
        self.src.clear(d, self.m);
        self.assigned[d] = false;
    }

    /// Fully-assigned tiling: materialize and batch-score its rotations.
    fn leaf(&mut self, b: u64) {
        debug_assert!(b >= self.lo && b < self.hi);
        let perms = self.src.perms;
        let first = b * perms;
        let members = perms.min(self.budget - first);
        for p in self.m.permutation.iter_mut() {
            *p = Dim::ALL;
        }
        self.member_ids.clear();
        let mut n_valid = 0usize;
        for i in 0..members {
            if i > 0 {
                self.src.emit_member(b, i, self.m);
            }
            self.out.examined += 1;
            if self.m.validate(self.layer, self.acc).is_ok() {
                if n_valid == self.members_buf.len() {
                    self.members_buf.push(self.m.clone());
                } else {
                    super::copy_mapping_into(&mut self.members_buf[n_valid], self.m);
                }
                self.member_ids.push(first + i);
                n_valid += 1;
            }
        }
        if n_valid > 0 {
            self.ctx.evaluate_many(&self.members_buf[..n_valid], &mut self.scores);
            self.out.scored += n_valid as u64;
            for (k, &(e_pj, lat)) in self.scores.iter().enumerate() {
                let score = self.objective.compose(e_pj, lat);
                merge_best(&mut self.out.best, score, self.member_ids[k], &self.members_buf[k]);
            }
        }
    }
}

impl SearchDriver {
    /// Branch-and-bound over the factorization lattice. Same candidate
    /// space, budget semantics, seed handling and tie-breaks as
    /// [`SearchDriver::search`] over the equivalent odometer — but whole
    /// subtrees of tilings are pruned against the incumbent via
    /// [`EvalContext::partial_bound`] before any of their blocks is
    /// materialized. Returns the best (or `None` when nothing validated)
    /// plus `certified`: `true` iff the budget admitted the entire space,
    /// i.e. every candidate was either scored or provably bounded out and
    /// the argmin is the space-wide optimum.
    pub fn branch_and_bound(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        source: &BoundedLattice,
        seeds: &[Mapping],
    ) -> (Option<SearchBest>, bool) {
        self.branch_and_bound_with_bound(layer, acc, source, seeds, None)
    }

    /// [`SearchDriver::branch_and_bound`] with an extra *external*
    /// incumbent bound, mirroring [`SearchDriver::search_with_bound`]: the
    /// bound tightens every round's frozen incumbent without ever entering
    /// the candidate stream. Whenever the unbounded argmin scores
    /// `<= bound` the result — including the coverage certificate — is
    /// bit-identical to the unbounded run at no more examined candidates;
    /// when it scores `> bound` the walk may bound it out, so callers must
    /// treat `best.score > bound` (or `None`) as "rerun unbounded"
    /// (DESIGN.md §15).
    pub fn branch_and_bound_with_bound(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        source: &BoundedLattice,
        seeds: &[Mapping],
        bound: Option<f64>,
    ) -> (Option<SearchBest>, bool) {
        // An already-expired deadline covers nothing: no result, and
        // certainly no certificate.
        if self.expired() {
            return (None, false);
        }
        let budget = self.budget.max(1);
        let perms = source.block_len().max(1);
        let visit_blocks = source.n_blocks().min(budget.div_ceil(perms));
        let mut certified = source.blocks_u128() * perms as u128 <= budget as u128;
        let overhang = visit_blocks.saturating_mul(perms).saturating_sub(budget);

        let mut best: Option<(f64, u64, Mapping)> = None;
        let (mut examined, mut scored, mut pruned) = (0u64, 0u64, 0u64);

        if !seeds.is_empty() {
            let mut ctx = EvalContext::new(layer, acc);
            for (i, s) in seeds.iter().enumerate() {
                if s.validate(layer, acc).is_err() {
                    continue;
                }
                examined += 1;
                scored += 1;
                let score = self.objective.score(ctx.evaluate_into(s));
                merge_best(&mut best, score, budget.saturating_add(i as u64), s);
            }
        }

        let n_workers = (self.threads.max(1) as u64).min(visit_blocks.max(1));
        let round_blocks = if self.prune {
            visit_blocks.div_ceil(PRUNE_ROUNDS).max(MIN_ROUND_BLOCKS)
        } else {
            visit_blocks.max(1)
        };
        let n_levels = acc.n_levels();
        let mut workers: Vec<(EvalContext, Mapping)> = (0..n_workers)
            .map(|_| (EvalContext::new(layer, acc), all_ones_mapping(n_levels)))
            .collect();

        let mut degraded = false;
        let mut r0 = 0u64;
        while r0 < visit_blocks {
            if self.expired() {
                // Deadline hit mid-search: the remaining subtrees were
                // neither examined nor bounded out, so the coverage
                // certificate is forfeit along with them.
                degraded = true;
                certified = false;
                break;
            }
            let r1 = (r0 + round_blocks).min(visit_blocks);
            let round_n = r1 - r0;
            let w_n = n_workers.min(round_n);
            let incumbent = min_opt(best.as_ref().map(|(s, _, _)| *s), bound);
            let objective = self.objective;
            let prune = self.prune;
            let results: Vec<ShardResult> = std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(w_n as usize);
                for (w, slot) in workers.iter_mut().take(w_n as usize).enumerate() {
                    let start = r0 + shard_start(round_n, w_n, w as u64);
                    let end = r0 + shard_start(round_n, w_n, w as u64 + 1);
                    handles.push(scope.spawn(move || {
                        let (ctx, scratch) = slot;
                        let mut dfs = Dfs {
                            src: source,
                            layer,
                            acc,
                            objective,
                            prune,
                            ctx,
                            m: scratch,
                            assigned: [false; 7],
                            incumbent,
                            lo: start,
                            hi: end,
                            budget,
                            visit_blocks,
                            overhang,
                            out: ShardResult::default(),
                            members_buf: Vec::new(),
                            member_ids: Vec::new(),
                            scores: Vec::new(),
                        };
                        dfs.node(0, 0);
                        dfs.out
                    }));
                }
                handles.into_iter().map(|h| h.join().expect("bnb worker panicked")).collect()
            });
            for r in results {
                examined += r.examined;
                scored += r.scored;
                pruned += r.pruned;
                if let Some((s, i, m)) = r.best {
                    merge_best(&mut best, s, i, &m);
                }
            }
            r0 = r1;
        }

        let best = best.map(|(score, index, mapping)| SearchBest {
            mapping,
            score,
            index,
            examined,
            scored,
            pruned,
            degraded,
        });
        (best, certified)
    }
}

/// A mapping with factor 1 in every slot — the DFS scratch's rest state
/// (every dim unassigned, the [`EvalContext::partial_bound`] precondition).
fn all_ones_mapping(n_levels: usize) -> Mapping {
    Mapping {
        temporal: vec![[1u64; 7]; n_levels],
        permutation: vec![Dim::ALL; n_levels],
        spatial_x: [1; 7],
        spatial_y: [1; 7],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::zoo;

    #[test]
    fn lattice_indices_match_the_odometer() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let odo = super::super::OdometerSource::new(&layer, &acc, true);
        let lat = BoundedLattice::new(&layer, &acc, true);
        assert_eq!(lat.n_blocks(), odo.n_blocks());
        assert_eq!(lat.block_len(), odo.block_len());
        let mut a = all_ones_mapping(acc.n_levels());
        let mut b = all_ones_mapping(acc.n_levels());
        for blk in [0u64, 1, 7, 715, 9999, 123_456] {
            assert!(lat.emit_block(blk, &mut a));
            assert!(odo.emit_block(blk, &mut b));
            assert_eq!(a, b, "block {blk}");
            lat.emit_member(blk, 3, &mut a);
            odo.emit_member(blk, 3, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn branch_and_bound_matches_plain_search_counts() {
        // On a budget-truncated slice of a real layer: identical argmin and
        // a complete examined/pruned account of every in-budget candidate.
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let lat = BoundedLattice::new(&layer, &acc, true);
        let driver = SearchDriver {
            objective: Objective::Energy,
            budget: 700,
            threads: 1,
            prune: false,
            deadline: None,
        };
        let base = driver.search(&layer, &acc, &lat, &[]).unwrap();
        let bnb_driver = SearchDriver { prune: true, ..driver };
        let (bnb, certified) =
            bnb_driver.branch_and_bound(&layer, &acc, &lat, &[base.mapping.clone()]);
        let bnb = bnb.unwrap();
        assert!(!certified, "vgg02 conv5 space cannot fit a 700 budget");
        assert_eq!(bnb.mapping, base.mapping);
        assert_eq!(bnb.score.to_bits(), base.score.to_bits());
        assert_eq!(bnb.index, base.index);
        // Seed adds one examined candidate; every in-budget candidate is
        // either examined or provably pruned.
        assert_eq!(bnb.examined + bnb.pruned, base.examined + 1);
        assert!(bnb.pruned > 0, "perfect incumbent must prune something");
    }

    #[test]
    fn certified_when_the_budget_covers_the_space() {
        // A tiny layer whose whole tiling × rotation space fits the budget.
        let layer = crate::workload::Layer::new("tiny", 4, 2, 1, 1, 4, 2);
        let acc = presets::eyeriss();
        let lat = BoundedLattice::new(&layer, &acc, true);
        let space = lat.blocks_u128() * lat.block_len() as u128;
        let driver = SearchDriver {
            objective: Objective::Energy,
            budget: space as u64,
            threads: 1,
            prune: true,
            deadline: None,
        };
        let (best, certified) = driver.branch_and_bound(&layer, &acc, &lat, &[]);
        let best = best.unwrap();
        assert!(certified);
        // Certified = every candidate examined or pruned.
        assert_eq!(best.examined + best.pruned, space as u64);
        // And the argmin equals the unpruned space-wide optimum.
        let full = SearchDriver { prune: false, ..driver }.search(&layer, &acc, &lat, &[]).unwrap();
        assert_eq!(best.mapping, full.mapping);
        assert_eq!(best.score.to_bits(), full.score.to_bits());
        assert_eq!(best.index, full.index);
    }
}
