//! Candidate sources: where the engine's candidates come from.
//!
//! Two shapes cover all seven mappers:
//!
//! * [`CandidateSource`] — **indexed** generation: every candidate has a
//!   stable global index `block × block_len + member`, computable
//!   independently of every other candidate. Odometer enumeration
//!   (exhaustive), the seeded random stream (random) and the
//!   dataflow-constrained stream (RS/WS/OS search) are indexed, which is
//!   what lets [`super::SearchDriver::search`] shard them across threads
//!   with bit-identical results at any thread count, and lets the pruner
//!   skip whole blocks.
//! * [`BatchSource`] — **adaptive** generation: the next batch depends on
//!   the scores of the previous one (SA neighbourhoods, GA population
//!   steps, hill-climbing). [`super::SearchDriver::search_batched`] owns
//!   budget truncation, validity filtering and best tracking; the source
//!   owns only the proposal logic.

use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::mapspace::{sample_random, Constraints};
use crate::util::factor::factorizations;
use crate::util::rng::SplitMix64;
use crate::workload::{Dim, Layer};

/// An indexed candidate stream: candidate `block × block_len + member` is
/// generated from its index alone (no sequential state), so the driver can
/// shard blocks across worker threads deterministically.
pub trait CandidateSource: Sync {
    /// Blocks in the space (before the driver's budget truncation).
    fn n_blocks(&self) -> u64;

    /// Candidates per block. All members of one block must share the
    /// block's **tiling** (only per-level permutations may differ) — the
    /// contract that lets the pruner bound a whole block at once.
    fn block_len(&self) -> u64 {
        1
    }

    /// Materialize block `b`'s member 0 into `m`, overwriting it entirely.
    /// Returns `false` when the block yields no candidate.
    fn emit_block(&self, b: u64, m: &mut Mapping) -> bool;

    /// Rewrite `m` (currently holding some member of block `b`) into
    /// member `i ≥ 1`. Must not change the tiling.
    fn emit_member(&self, b: u64, i: u64, m: &mut Mapping) {
        let _ = (b, i, m);
    }

    /// `true` when every member of every block carries a **rotation** of
    /// the canonical dim order as its per-level permutation (member `i` =
    /// canonical order rotated left `i` at every level). The driver then
    /// prunes blocks with the tight
    /// [`crate::model::EvalContext::block_bound`] instead of the
    /// conservative all-permutation
    /// [`crate::model::EvalContext::objective_bound`] — sound only under
    /// this contract, so leave the default `false` for anything that emits
    /// shuffled or policy-sorted permutations.
    fn rotation_members(&self) -> bool {
        false
    }
}

/// An adaptive candidate stream: proposals depend on earlier scores.
pub trait BatchSource {
    /// Fill `out` with the next proposals given `feedback[i]` = the
    /// objective score of the previous batch's candidate `i` (`None` when
    /// it failed validation). Leave `out` empty to end the search. The
    /// first call receives empty feedback.
    fn next_batch(&mut self, feedback: &[Option<f64>], out: &mut Vec<Mapping>);
}

/// Mix a stream seed with a candidate index into an independent PRNG seed
/// (SplitMix64 is explicitly designed for this kind of seed splitting).
pub fn candidate_seed(seed: u64, index: u64) -> u64 {
    seed ^ index.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// The exhaustive odometer over per-dim ordered factorizations, optionally
/// fanned out into the 7 rotated per-level permutations per slot (the
/// enumeration previously private to `ExhaustiveMapper`).
#[derive(Debug)]
pub struct OdometerSource {
    /// `per_dim[d]` = ordered splits of dim `d`'s bound across
    /// `[sx, sy, t0, .., t_top]`.
    per_dim: Vec<Vec<Vec<u64>>>,
    n_levels: usize,
    perms: u64,
}

impl OdometerSource {
    /// Build the odometer for one (layer, accelerator) pair. `permute`
    /// adds the 7-rotation permutation fan-out per slot.
    pub fn new(layer: &Layer, acc: &Accelerator, permute: bool) -> Self {
        let n_levels = acc.n_levels();
        let slots = n_levels + 2;
        let per_dim: Vec<Vec<Vec<u64>>> =
            Dim::ALL.iter().map(|&d| factorizations(layer.bound(d), slots)).collect();
        Self { per_dim, n_levels, perms: if permute { 7 } else { 1 } }
    }

    /// Decode a linear odometer position into per-dim split indices. Dim 0
    /// is the least-significant digit (the serial odometer's carry order).
    fn odometer_at(&self, mut linear: u64) -> [usize; 7] {
        let mut idx = [0usize; 7];
        for (d, splits) in self.per_dim.iter().enumerate() {
            let len = splits.len() as u64;
            idx[d] = (linear % len) as usize;
            linear /= len;
        }
        idx
    }
}

impl CandidateSource for OdometerSource {
    fn n_blocks(&self) -> u64 {
        let total: u128 = self.per_dim.iter().map(|v| v.len() as u128).product();
        total.min(u64::MAX as u128) as u64
    }

    fn block_len(&self) -> u64 {
        self.perms
    }

    fn emit_block(&self, b: u64, m: &mut Mapping) -> bool {
        let idx = self.odometer_at(b);
        for d in 0..7 {
            let split = &self.per_dim[d][idx[d]];
            m.spatial_x[d] = split[0];
            m.spatial_y[d] = split[1];
            for l in 0..self.n_levels {
                m.temporal[l][d] = split[2 + l];
            }
        }
        for p in m.permutation.iter_mut() {
            *p = Dim::ALL;
        }
        true
    }

    fn emit_member(&self, _b: u64, i: u64, m: &mut Mapping) {
        // Member `i` is the canonical permutation rotated left `i` times at
        // every level — written from scratch so members need not be emitted
        // in order.
        let mut p = Dim::ALL;
        p.rotate_left((i % 7) as usize);
        for perm in m.permutation.iter_mut() {
            *perm = p;
        }
    }

    fn rotation_members(&self) -> bool {
        // Member 0 is the canonical order (rotation 0); with `permute` the
        // fan-out is exactly the 7 rotations. Either way the tight block
        // bound's contract holds.
        true
    }
}

/// The seeded random stream (best-of-N sampling), optionally imprinted
/// with dataflow [`Constraints`] (the RS/WS/OS searches). Candidate `i`
/// draws from its own [`candidate_seed`]-derived PRNG, so the stream is a
/// pure function of `(seed, i)` — shardable, and a budget extension only
/// appends candidates (prefix property).
#[derive(Debug)]
pub struct RandomStream<'a> {
    layer: &'a Layer,
    acc: &'a Accelerator,
    seed: u64,
    samples: u64,
    constraints: Option<Constraints>,
}

impl<'a> RandomStream<'a> {
    /// Unconstrained stream of `samples` random candidates.
    pub fn new(layer: &'a Layer, acc: &'a Accelerator, seed: u64, samples: u64) -> Self {
        Self { layer, acc, seed, samples, constraints: None }
    }

    /// Builder: imprint every draw with dataflow constraints.
    pub fn constrained(mut self, constraints: Constraints) -> Self {
        self.constraints = Some(constraints);
        self
    }
}

impl CandidateSource for RandomStream<'_> {
    fn n_blocks(&self) -> u64 {
        self.samples
    }

    fn emit_block(&self, b: u64, m: &mut Mapping) -> bool {
        let mut rng = SplitMix64::new(candidate_seed(self.seed, b));
        *m = sample_random(self.layer, self.acc, &mut rng);
        if let Some(cons) = &self.constraints {
            cons.imprint(self.layer, self.acc, m, &mut rng);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mapspace::Dataflow;
    use crate::workload::zoo;

    #[test]
    fn odometer_blocks_cover_tilings_and_rotations() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let src = OdometerSource::new(&layer, &acc, true);
        assert_eq!(src.block_len(), 7);
        assert!(src.n_blocks() > 1_000_000);
        let mut m = Mapping::trivial(&layer, acc.n_levels());
        assert!(src.emit_block(0, &mut m));
        // Block 0 is the all-at-DRAM split with canonical permutations.
        assert_eq!(m.temporal[acc.n_levels() - 1], layer.bounds());
        assert_eq!(m.permutation[0], Dim::ALL);
        // Member emission only rotates permutations, never the tiling.
        let tiling = (m.temporal.clone(), m.spatial_x, m.spatial_y);
        src.emit_member(0, 3, &mut m);
        assert_eq!((m.temporal.clone(), m.spatial_x, m.spatial_y), tiling);
        let mut expect = Dim::ALL;
        expect.rotate_left(3);
        assert_eq!(m.permutation[1], expect);
    }

    #[test]
    fn random_stream_is_a_pure_function_of_the_index() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let src = RandomStream::new(&layer, &acc, 42, 16);
        let mut a = Mapping::trivial(&layer, acc.n_levels());
        let mut b = Mapping::trivial(&layer, acc.n_levels());
        // Same index twice → identical candidate, regardless of call order.
        assert!(src.emit_block(7, &mut a));
        assert!(src.emit_block(3, &mut b));
        assert!(src.emit_block(7, &mut b));
        assert_eq!(a, b);
        // Different indices → (virtually always) different candidates.
        src.emit_block(8, &mut b);
        assert_ne!(a, b);
        a.validate(&layer, &acc).unwrap();
    }

    #[test]
    fn constrained_stream_imprints_the_dataflow() {
        let acc = presets::nvdla();
        let layer = zoo::vgg16()[8].clone();
        let df = Dataflow::WeightStationary;
        let src = RandomStream::new(&layer, &acc, 1, 64).constrained(df.constraints());
        let mut m = Mapping::trivial(&layer, acc.n_levels());
        let mut admitted = 0;
        for b in 0..64 {
            src.emit_block(b, &mut m);
            if m.validate(&layer, &acc).is_ok() && df.constraints().admit(&layer, &acc, &m) {
                admitted += 1;
            }
        }
        assert!(admitted > 32, "only {admitted}/64 draws admitted");
    }
}
