//! The shared search engine behind every mapper.
//!
//! Each of the seven mappers used to own a private candidate loop with its
//! own budget accounting, validity filtering and best tracking — and only
//! `ExhaustiveMapper` knew how to shard work across threads. The engine
//! centralizes all of that (DESIGN.md §11):
//!
//! * [`Objective`] — the scalar being minimized (energy / delay / EDP),
//!   threaded through scoring, [`crate::mappers::MapOutcome`] and the
//!   coordinator cache key.
//! * [`CandidateSource`] / [`BatchSource`] — where candidates come from:
//!   indexed streams (odometer enumeration, seeded random, constrained
//!   random) and adaptive proposals (SA, GA, hill-climbing).
//! * [`SearchDriver`] — budget truncation, validity filtering, objective
//!   scoring through the zero-allocation [`EvalContext`], deterministic
//!   best-merge, scoped-thread sharding for indexed sources, and the
//!   bound-based pruner.
//!
//! # Determinism
//!
//! Indexed searches are **bit-identical at every thread count**. Every
//! candidate has a stable global index `block × block_len + member`; each
//! worker keeps its best `(score, index)` pair and the merge takes the
//! lowest score, exact ties broken by the lowest index — precisely the
//! order a single-threaded loop keeps candidates (strict `<` keeps the
//! earliest minimum). Pruning decisions compare each block's lower bound
//! against the incumbent **frozen at the start of the round**, never a
//! worker-local running best, so the set of evaluated candidates (and
//! hence every count) is also thread-count-invariant.
//!
//! # Pruning
//!
//! With [`SearchParams::prune`] on, the driver asks for a cheap lower
//! bound of each block's objective before materializing its members —
//! [`EvalContext::block_bound`] (exact per-rotation word assembly, min
//! over the 7 rotations) when the source's members are rotations of the
//! canonical order ([`CandidateSource::rotation_members`]), else the
//! conservative all-permutation [`EvalContext::objective_bound`].
//! A block is skipped only when its bound **strictly exceeds** the
//! incumbent score; any skipped candidate therefore scores strictly worse
//! than the final best and can affect neither the argmin nor its
//! tie-break index. Warm-starting the incumbent (e.g. exhaustive search
//! seeding with the LOCAL mapping) makes the pruner effective from the
//! first block; seed candidates carry indices **after** the whole stream,
//! so an exact tie is still resolved in favour of the enumerated
//! candidate.

pub mod lattice;
pub mod objective;
pub mod source;

pub use lattice::BoundedLattice;
pub use objective::Objective;
pub use source::{BatchSource, CandidateSource, OdometerSource, RandomStream};

use crate::arch::Accelerator;
use crate::mapping::Mapping;
use crate::model::EvalContext;
use crate::workload::Layer;

/// Engine-wide knobs shared by every search mapper; the `--budget`,
/// `--seed`, `--objective`, `--search-threads` and `--no-prune` CLI flags
/// resolve into one of these ([`crate::mappers::AnyMapper::parse`]).
#[derive(Debug, Clone, Copy)]
pub struct SearchParams {
    /// Hard cap on candidate evaluations per layer mapping.
    pub budget: u64,
    /// PRNG seed for stochastic sources (deterministic across runs).
    pub seed: u64,
    /// The scalar every mapper minimizes.
    pub objective: Objective,
    /// Worker threads for indexed sources (results are identical at every
    /// value).
    pub threads: usize,
    /// Bound-based block pruning for the mappers that support it
    /// (exhaustive and dataflow-constrained search have it on by default).
    pub prune: bool,
    /// Run the exhaustive mapper as branch-and-bound over the
    /// factorization lattice ([`BoundedLattice`]) and report whether the
    /// search provably covered the whole space (the `--certify` CLI flag;
    /// surfaced as [`crate::mappers::MapOutcome::certified`]).
    pub certify: bool,
    /// Wall-clock deadline per layer mapping, milliseconds (the
    /// `--deadline-ms` CLI flag). `None` means unbounded. Engine mappers
    /// check it at round boundaries and return the best-so-far incumbent
    /// flagged [`crate::mappers::MapStatus::Degraded`]; an expired
    /// deadline with no incumbent yields the LOCAL fallback
    /// ([`crate::mappers::MapStatus::FellBack`], DESIGN.md §14).
    pub deadline_ms: Option<u64>,
}

impl SearchParams {
    /// Params with the given budget and seed at the default objective,
    /// single-threaded, pruning on.
    pub fn new(budget: u64, seed: u64) -> Self {
        Self { budget, seed, ..Self::default() }
    }

    /// Builder: set the objective.
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Builder: set the worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Builder: disable bound-based pruning.
    pub fn without_pruning(mut self) -> Self {
        self.prune = false;
        self
    }

    /// Builder: request certified branch-and-bound search.
    pub fn with_certify(mut self, certify: bool) -> Self {
        self.certify = certify;
        self
    }

    /// Builder: set the per-layer wall-clock deadline in milliseconds.
    pub fn with_deadline_ms(mut self, deadline_ms: u64) -> Self {
        self.deadline_ms = Some(deadline_ms);
        self
    }
}

impl Default for SearchParams {
    fn default() -> Self {
        Self {
            budget: 3000,
            seed: 42,
            objective: Objective::Energy,
            threads: 1,
            prune: true,
            certify: false,
            deadline_ms: None,
        }
    }
}

/// What a driver run found.
#[derive(Debug, Clone)]
pub struct SearchBest {
    /// The winning mapping (lowest objective score; exact ties go to the
    /// lowest global candidate index).
    pub mapping: Mapping,
    /// Its objective score.
    pub score: f64,
    /// Its global candidate index (the tie-break witness; seed candidates
    /// sit after the whole stream).
    pub index: u64,
    /// Candidates materialized and validity-checked (valid or not); the
    /// historical "evaluations" accounting of the enumerative mappers.
    pub examined: u64,
    /// Candidates that passed validation and were fully scored.
    pub scored: u64,
    /// Candidates skipped by the bound-based pruner without being
    /// materialized.
    pub pruned: u64,
    /// `true` when the wall-clock deadline expired mid-search and this is
    /// the best-so-far incumbent rather than the full run's answer
    /// (surfaced as [`crate::mappers::MapStatus::Degraded`]).
    pub degraded: bool,
}

/// Incumbent refreshes per pruned search: the block range is processed in
/// this many synchronized rounds so later rounds prune against the best of
/// all earlier ones.
const PRUNE_ROUNDS: u64 = 32;

/// Floor on blocks per round: bounds the sharding/merge overhead and
/// guarantees a pruned search still examines a meaningful unpruned prefix
/// when it has no warm-start seed.
const MIN_ROUND_BLOCKS: u64 = 128;

/// Resolve a relative per-layer deadline into an absolute instant
/// anchored at "now" — called once at the start of each `map` so every
/// driver round within that mapping shares one wall-clock budget.
/// Absurdly large values that would overflow the clock saturate to
/// unbounded (`None`).
pub fn deadline_instant(deadline_ms: Option<u64>) -> Option<std::time::Instant> {
    deadline_ms.and_then(|ms| {
        std::time::Instant::now().checked_add(std::time::Duration::from_millis(ms))
    })
}

/// Start of shard `w` when `total` items are split across `workers`
/// contiguous shards (shard `w` covers `[start(w), start(w + 1))`).
fn shard_start(total: u64, workers: u64, w: u64) -> u64 {
    let base = total / workers;
    let rem = total % workers;
    w * base + w.min(rem)
}

/// Fold one scored candidate into the running best: lowest score wins,
/// exact ties go to the lowest global index.
fn merge_best(best: &mut Option<(f64, u64, Mapping)>, score: f64, index: u64, m: &Mapping) {
    let better = match best {
        None => true,
        Some((bs, bi, _)) => score < *bs || (score == *bs && index < *bi),
    };
    if better {
        *best = Some((score, index, m.clone()));
    }
}

/// Minimum of two optional scores (`None` = unbounded): the round
/// incumbent under an external warm-start bound.
fn min_opt(a: Option<f64>, b: Option<f64>) -> Option<f64> {
    match (a, b) {
        (Some(x), Some(y)) => Some(x.min(y)),
        (Some(x), None) => Some(x),
        (None, y) => y,
    }
}

/// Allocation-reusing mapping copy (`Vec::clone_from` keeps the level
/// vectors' buffers), for the batch-evaluation member staging buffers.
fn copy_mapping_into(dst: &mut Mapping, src: &Mapping) {
    dst.temporal.clone_from(&src.temporal);
    dst.permutation.clone_from(&src.permutation);
    dst.spatial_x = src.spatial_x;
    dst.spatial_y = src.spatial_y;
}

/// Per-worker tallies and best for one round shard.
#[derive(Debug, Default)]
struct ShardResult {
    examined: u64,
    scored: u64,
    pruned: u64,
    best: Option<(f64, u64, Mapping)>,
}

/// The shared search driver (see the module docs).
#[derive(Debug, Clone, Copy)]
pub struct SearchDriver {
    /// The scalar being minimized.
    pub objective: Objective,
    /// Hard cap on candidate evaluations (global candidate indices at or
    /// above the budget are never materialized; a zero budget still
    /// admits one candidate).
    pub budget: u64,
    /// Worker threads for indexed sources.
    pub threads: usize,
    /// Bound-based block pruning.
    pub prune: bool,
    /// Wall-clock deadline: checked at round boundaries only (never
    /// inside a shard), so a truncated search still keeps the engine's
    /// deterministic merge within every completed round. `None` means
    /// unbounded.
    pub deadline: Option<std::time::Instant>,
}

impl SearchDriver {
    /// `true` once the wall-clock deadline (if any) has passed.
    fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| std::time::Instant::now() >= d)
    }

    /// Deterministic (thread-count-invariant) search over an indexed
    /// source. `seeds` warm-start the incumbent: they are scored first,
    /// carry post-stream indices (an exact tie prefers the enumerated
    /// candidate), and make the pruner effective from the first block.
    /// Returns `None` when no candidate passed validation.
    pub fn search<S: CandidateSource>(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        source: &S,
        seeds: &[Mapping],
    ) -> Option<SearchBest> {
        self.search_with_bound(layer, acc, source, seeds, None)
    }

    /// [`SearchDriver::search`] with an extra *external* incumbent bound.
    ///
    /// The bound tightens every round's frozen incumbent
    /// (`min(best-so-far, bound)`) without entering the candidate stream:
    /// it is never examined, scored or merged, so it can only *remove*
    /// work, never add a candidate. A block is pruned only when its lower
    /// bound strictly exceeds the incumbent, so whenever the unbounded
    /// argmin scores `<= bound` it is never pruned and the bounded run
    /// returns the bit-identical `(mapping, score, index)` with
    /// `examined <= ` the unbounded run's — the cross-layer warm-start
    /// contract (DESIGN.md §15). When the argmin scores `> bound` the
    /// bounded run may return a worse candidate or `None`; callers detect
    /// that (`best.score > bound`) and rerun unbounded.
    pub fn search_with_bound<S: CandidateSource>(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        source: &S,
        seeds: &[Mapping],
        bound: Option<f64>,
    ) -> Option<SearchBest> {
        // An already-expired deadline admits no search at all: return
        // `None` (not a zero-candidate incumbent) so the service worker
        // drops to the LOCAL fallback rung of the degradation ladder.
        if self.expired() {
            return None;
        }
        let budget = self.budget.max(1);
        let block_len = source.block_len().max(1);
        let visit_blocks = source.n_blocks().min(budget.div_ceil(block_len));
        // Rotation-member sources get the tight per-rotation block bound;
        // everything else keeps the conservative all-permutation bound.
        let rotation_block = source.rotation_members();

        let mut best: Option<(f64, u64, Mapping)> = None;
        let (mut examined, mut scored, mut pruned) = (0u64, 0u64, 0u64);

        if !seeds.is_empty() {
            let mut ctx = EvalContext::new(layer, acc);
            for (i, s) in seeds.iter().enumerate() {
                if s.validate(layer, acc).is_err() {
                    continue;
                }
                examined += 1;
                scored += 1;
                let score = self.objective.score(ctx.evaluate_into(s));
                merge_best(&mut best, score, budget.saturating_add(i as u64), s);
            }
        }

        let n_workers = (self.threads.max(1) as u64).min(visit_blocks.max(1));
        let round_blocks = if self.prune {
            visit_blocks.div_ceil(PRUNE_ROUNDS).max(MIN_ROUND_BLOCKS)
        } else {
            visit_blocks.max(1)
        };
        let mut workers: Vec<(EvalContext, Mapping)> = (0..n_workers)
            .map(|_| (EvalContext::new(layer, acc), Mapping::trivial(layer, acc.n_levels())))
            .collect();

        let mut degraded = false;
        let mut r0 = 0u64;
        while r0 < visit_blocks {
            if self.expired() {
                degraded = true;
                break;
            }
            let r1 = (r0 + round_blocks).min(visit_blocks);
            let round_n = r1 - r0;
            let w_n = n_workers.min(round_n);
            // Frozen at the round boundary: every worker prunes against the
            // same incumbent whatever the thread count. An external bound
            // only tightens it (see `search_with_bound`).
            let incumbent = min_opt(best.as_ref().map(|(s, _, _)| *s), bound);
            let results: Vec<ShardResult> = std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(w_n as usize);
                for (w, slot) in workers.iter_mut().take(w_n as usize).enumerate() {
                    let start = r0 + shard_start(round_n, w_n, w as u64);
                    let end = r0 + shard_start(round_n, w_n, w as u64 + 1);
                    handles.push(scope.spawn(move || {
                        let (ctx, scratch) = slot;
                        let mut out = ShardResult::default();
                        // Member staging for batch scoring: reused across
                        // blocks so a multi-member block costs no steady-
                        // state allocation.
                        let mut members_buf: Vec<Mapping> = Vec::new();
                        let mut member_ids: Vec<u64> = Vec::new();
                        let mut scores: Vec<(f64, u64)> = Vec::new();
                        for b in start..end {
                            if !source.emit_block(b, scratch) {
                                continue;
                            }
                            let first = b * block_len;
                            let members = block_len.min(budget - first);
                            if self.prune {
                                if let Some(inc) = incumbent {
                                    let (e_lb, l_lb) = if rotation_block {
                                        ctx.block_bound(scratch)
                                    } else {
                                        ctx.objective_bound(scratch)
                                    };
                                    if self.objective.compose(e_lb, l_lb) > inc {
                                        out.pruned += members;
                                        continue;
                                    }
                                }
                            }
                            if members == 1 {
                                out.examined += 1;
                                if scratch.validate(layer, acc).is_ok() {
                                    out.scored += 1;
                                    let score =
                                        self.objective.score(ctx.evaluate_into(scratch));
                                    merge_best(&mut out.best, score, first, scratch);
                                }
                                continue;
                            }
                            // Permutation block: stage the valid members and
                            // score them in one `evaluate_many` pass (bit-
                            // identical to the per-member path).
                            member_ids.clear();
                            let mut n_valid = 0usize;
                            for i in 0..members {
                                if i > 0 {
                                    source.emit_member(b, i, scratch);
                                }
                                out.examined += 1;
                                if scratch.validate(layer, acc).is_ok() {
                                    if n_valid == members_buf.len() {
                                        members_buf.push(scratch.clone());
                                    } else {
                                        copy_mapping_into(&mut members_buf[n_valid], scratch);
                                    }
                                    member_ids.push(first + i);
                                    n_valid += 1;
                                }
                            }
                            if n_valid > 0 {
                                ctx.evaluate_many(&members_buf[..n_valid], &mut scores);
                                out.scored += n_valid as u64;
                                for (k, &(e_pj, lat)) in scores.iter().enumerate() {
                                    let score = self.objective.compose(e_pj, lat);
                                    merge_best(
                                        &mut out.best,
                                        score,
                                        member_ids[k],
                                        &members_buf[k],
                                    );
                                }
                            }
                        }
                        out
                    }));
                }
                handles.into_iter().map(|h| h.join().expect("search worker panicked")).collect()
            });
            for r in results {
                examined += r.examined;
                scored += r.scored;
                pruned += r.pruned;
                if let Some((s, i, m)) = r.best {
                    merge_best(&mut best, s, i, &m);
                }
            }
            r0 = r1;
        }

        best.map(|(score, index, mapping)| SearchBest {
            mapping,
            score,
            index,
            examined,
            scored,
            pruned,
            degraded,
        })
    }

    /// Adaptive search: pull proposal batches from the source, score them
    /// (in parallel when a batch is large enough), feed the scores back,
    /// repeat until the source dries up or the budget is reached. Proposal
    /// order defines the global candidate index, so results are
    /// deterministic at every thread count here too.
    pub fn search_batched<S: BatchSource>(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        source: &mut S,
    ) -> Option<SearchBest> {
        // Same entry rule as `search`: an already-expired deadline means
        // no proposals at all, and `None` routes to the LOCAL fallback.
        if self.expired() {
            return None;
        }
        let budget = self.budget.max(1);
        let n_workers = self.threads.max(1);
        let mut ctxs: Vec<EvalContext> =
            (0..n_workers).map(|_| EvalContext::new(layer, acc)).collect();
        let mut best: Option<(f64, u64, Mapping)> = None;
        let (mut examined, mut scored) = (0u64, 0u64);
        let mut feedback: Vec<Option<f64>> = Vec::new();
        let mut batch: Vec<Mapping> = Vec::new();
        let mut index = 0u64;
        let mut degraded = false;
        while index < budget {
            if self.expired() {
                degraded = true;
                break;
            }
            batch.clear();
            source.next_batch(&feedback, &mut batch);
            if batch.is_empty() {
                break;
            }
            batch.truncate((budget - index) as usize);
            feedback = self.score_candidates(layer, acc, &mut ctxs, &batch);
            for (m, s) in batch.iter().zip(&feedback) {
                examined += 1;
                if let Some(score) = s {
                    scored += 1;
                    merge_best(&mut best, *score, index, m);
                }
                index += 1;
            }
        }
        best.map(|(score, index, mapping)| SearchBest {
            mapping,
            score,
            index,
            examined,
            scored,
            pruned: 0,
            degraded,
        })
    }

    /// [`SearchDriver::search_batched`] plus cross-layer warm-start seeds
    /// merged into the *result only*. The adaptive run proceeds exactly as
    /// unseeded — seeds are never fed into the proposal chain or
    /// population, so the proposal stream stays deterministic — and each
    /// valid seed is then scored (one examined/scored tick apiece) at a
    /// post-stream index, so the returned best is `min(unseeded best,
    /// seeds)` with exact ties resolved to the proposal stream. The final
    /// score is therefore never worse than the unseeded run's.
    pub fn search_batched_seeded<S: BatchSource>(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        source: &mut S,
        seeds: &[Mapping],
    ) -> Option<SearchBest> {
        if self.expired() {
            return None;
        }
        let base = self.search_batched(layer, acc, source);
        if seeds.is_empty() {
            return base;
        }
        let budget = self.budget.max(1);
        let mut best: Option<(f64, u64, Mapping)> = None;
        let (mut examined, mut scored, mut degraded) = (0u64, 0u64, false);
        if let Some(b) = base {
            examined = b.examined;
            scored = b.scored;
            degraded = b.degraded;
            best = Some((b.score, b.index, b.mapping));
        }
        let mut ctx = EvalContext::new(layer, acc);
        for (i, s) in seeds.iter().enumerate() {
            if s.validate(layer, acc).is_err() {
                continue;
            }
            examined += 1;
            scored += 1;
            let score = self.objective.score(ctx.evaluate_into(s));
            merge_best(&mut best, score, budget.saturating_add(i as u64), s);
        }
        best.map(|(score, index, mapping)| SearchBest {
            mapping,
            score,
            index,
            examined,
            scored,
            pruned: 0,
            degraded,
        })
    }

    /// Validity-filter and score a fixed candidate batch; `None` marks an
    /// invalid candidate. Sharded across the context pool when the batch
    /// amortizes the spawn cost (every candidate is scored independently,
    /// so the result is identical at any thread count).
    fn score_candidates(
        &self,
        layer: &Layer,
        acc: &Accelerator,
        ctxs: &mut [EvalContext],
        batch: &[Mapping],
    ) -> Vec<Option<f64>> {
        let score_one = |ctx: &mut EvalContext, m: &Mapping| {
            if m.validate(layer, acc).is_ok() {
                Some(self.objective.score(ctx.evaluate_into(m)))
            } else {
                None
            }
        };
        let w_n = ctxs.len().min(batch.len()).max(1);
        if w_n <= 1 || batch.len() < 8 {
            let ctx = &mut ctxs[0];
            return batch.iter().map(|m| score_one(ctx, m)).collect();
        }
        let mut out = vec![None; batch.len()];
        std::thread::scope(|scope| {
            let mut rest = &mut out[..];
            let mut batch_rest = batch;
            for (w, ctx) in ctxs.iter_mut().take(w_n).enumerate() {
                let start = shard_start(batch.len() as u64, w_n as u64, w as u64) as usize;
                let end = shard_start(batch.len() as u64, w_n as u64, w as u64 + 1) as usize;
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
                rest = tail;
                let (bchunk, btail) = batch_rest.split_at(end - start);
                batch_rest = btail;
                scope.spawn(move || {
                    for (slot, m) in chunk.iter_mut().zip(bchunk) {
                        *slot = score_one(ctx, m);
                    }
                });
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::zoo;

    #[test]
    fn shard_bounds_partition_the_range() {
        for total in [0u64, 1, 7, 100, 999] {
            for workers in [1u64, 2, 3, 8] {
                assert_eq!(shard_start(total, workers, 0), 0);
                assert_eq!(shard_start(total, workers, workers), total);
                for w in 0..workers {
                    assert!(shard_start(total, workers, w) <= shard_start(total, workers, w + 1));
                }
            }
        }
    }

    #[test]
    fn indexed_search_is_thread_invariant_with_and_without_pruning() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        for prune in [false, true] {
            let src = RandomStream::new(&layer, &acc, 11, 400);
            let base = SearchDriver {
                objective: Objective::Energy,
                budget: 400,
                threads: 1,
                prune,
                deadline: None,
            }
            .search(&layer, &acc, &src, &[])
            .unwrap();
            for threads in [2usize, 4, 8] {
                let par = SearchDriver {
                    objective: Objective::Energy,
                    budget: 400,
                    threads,
                    prune,
                    deadline: None,
                }
                .search(&layer, &acc, &src, &[])
                .unwrap();
                assert_eq!(par.mapping, base.mapping, "prune={prune} threads={threads}");
                assert_eq!(par.score.to_bits(), base.score.to_bits());
                assert_eq!(par.index, base.index);
                assert_eq!(par.examined, base.examined);
                assert_eq!(par.pruned, base.pruned);
            }
        }
    }

    #[test]
    fn pruning_never_changes_the_selected_candidate() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        for objective in Objective::ALL {
            let src = RandomStream::new(&layer, &acc, 5, 300);
            let full =
                SearchDriver { objective, budget: 300, threads: 1, prune: false, deadline: None }
                    .search(&layer, &acc, &src, &[])
                    .unwrap();
            let pruned =
                SearchDriver { objective, budget: 300, threads: 1, prune: true, deadline: None }
                    .search(&layer, &acc, &src, &[])
                    .unwrap();
            assert_eq!(pruned.mapping, full.mapping, "{objective}");
            assert_eq!(pruned.score.to_bits(), full.score.to_bits());
            assert_eq!(pruned.index, full.index);
            assert!(pruned.examined <= full.examined);
            assert_eq!(pruned.examined + pruned.pruned, full.examined);
        }
    }

    #[test]
    fn deadlines_degrade_instead_of_failing() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let src = RandomStream::new(&layer, &acc, 11, 400);
        let unbounded = SearchDriver {
            objective: Objective::Energy,
            budget: 400,
            threads: 1,
            prune: false,
            deadline: None,
        };
        let base = unbounded.search(&layer, &acc, &src, &[]).unwrap();
        assert!(!base.degraded);
        // A generous deadline changes nothing — the run completes and is
        // bit-identical to the unbounded one.
        let roomy = SearchDriver {
            deadline: Some(std::time::Instant::now() + std::time::Duration::from_secs(3600)),
            ..unbounded
        };
        let out = roomy.search(&layer, &acc, &src, &[]).unwrap();
        assert!(!out.degraded);
        assert_eq!(out.mapping, base.mapping);
        assert_eq!(out.score.to_bits(), base.score.to_bits());
        // An already-expired deadline admits no candidates at all: `None`
        // routes the caller to the LOCAL fallback.
        let expired = SearchDriver { deadline: Some(std::time::Instant::now()), ..unbounded };
        assert!(expired.search(&layer, &acc, &src, &[]).is_none());
    }

    #[test]
    fn seeds_warm_start_but_lose_exact_ties_to_the_stream() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let src = RandomStream::new(&layer, &acc, 11, 64);
        let driver = SearchDriver {
            objective: Objective::Energy,
            budget: 64,
            threads: 1,
            prune: false,
            deadline: None,
        };
        let plain = driver.search(&layer, &acc, &src, &[]).unwrap();
        // Seeding with the stream's own winner cannot change the result —
        // the tie resolves to the enumerated (lower-index) copy.
        let seeded = driver.search(&layer, &acc, &src, &[plain.mapping.clone()]).unwrap();
        assert_eq!(seeded.mapping, plain.mapping);
        assert_eq!(seeded.index, plain.index);
        assert_eq!(seeded.examined, plain.examined + 1);
        // An invalid seed is ignored.
        let mut broken = plain.mapping.clone();
        broken.temporal[0][0] *= 7;
        let s2 = driver.search(&layer, &acc, &src, &[broken]).unwrap();
        assert_eq!(s2.examined, plain.examined);
    }

    #[test]
    fn external_bounds_never_change_an_in_bound_argmin() {
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let src = RandomStream::new(&layer, &acc, 11, 400);
        let driver = SearchDriver {
            objective: Objective::Energy,
            budget: 400,
            threads: 1,
            prune: true,
            deadline: None,
        };
        let base = driver.search(&layer, &acc, &src, &[]).unwrap();
        // Any bound at or above the argmin: bit-identical result, and the
        // tightened incumbent can only remove work.
        for slack in [1.0, 1.25, 100.0] {
            let b = driver
                .search_with_bound(&layer, &acc, &src, &[], Some(base.score * slack))
                .unwrap();
            assert_eq!(b.mapping, base.mapping, "slack {slack}");
            assert_eq!(b.score.to_bits(), base.score.to_bits());
            assert_eq!(b.index, base.index);
            assert!(b.examined <= base.examined);
            assert!(b.pruned >= base.pruned);
        }
        // A bound below the argmin may lose it — callers detect the
        // `score > bound` (or `None`) outcome and rerun unbounded.
        let tight = driver.search_with_bound(&layer, &acc, &src, &[], Some(base.score * 0.5));
        if let Some(t) = tight {
            assert!(t.score >= base.score);
        }
        // `None` delegates to the plain search.
        let none = driver.search_with_bound(&layer, &acc, &src, &[], None).unwrap();
        assert_eq!(none.examined, base.examined);
        assert_eq!(none.mapping, base.mapping);
    }

    #[test]
    fn batched_search_tracks_best_and_budget() {
        struct Fixed(Vec<Mapping>, usize);
        impl BatchSource for Fixed {
            fn next_batch(&mut self, _f: &[Option<f64>], out: &mut Vec<Mapping>) {
                if self.1 == 0 {
                    out.extend(self.0.iter().cloned());
                    self.1 = 1;
                }
            }
        }
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let src = RandomStream::new(&layer, &acc, 3, 12);
        let mut pool = Vec::new();
        for b in 0..12 {
            let mut m = Mapping::trivial(&layer, acc.n_levels());
            src.emit_block(b, &mut m);
            pool.push(m);
        }
        let driver = SearchDriver {
            objective: Objective::Energy,
            budget: 3000,
            threads: 1,
            prune: false,
            deadline: None,
        };
        let out = driver.search_batched(&layer, &acc, &mut Fixed(pool.clone(), 0)).unwrap();
        assert_eq!(out.examined, 12);
        assert_eq!(out.scored, 12);
        // Identical to the indexed search over the same candidates.
        let indexed = driver.search(&layer, &acc, &src, &[]).unwrap();
        assert_eq!(out.mapping, indexed.mapping);
        assert_eq!(out.index, indexed.index);
        // Budget truncation applies to proposals.
        let tiny = SearchDriver { budget: 5, ..driver };
        let cut = tiny.search_batched(&layer, &acc, &mut Fixed(pool, 0)).unwrap();
        assert_eq!(cut.examined, 5);
        // Parallel scoring matches (batch large enough to shard).
        let par = SearchDriver { threads: 4, ..driver };
        let mut big = Vec::new();
        for b in 0..12 {
            let mut m = Mapping::trivial(&layer, acc.n_levels());
            src.emit_block(b, &mut m);
            big.push(m);
        }
        let pout = par.search_batched(&layer, &acc, &mut Fixed(big, 0)).unwrap();
        assert_eq!(pout.mapping, out.mapping);
        assert_eq!(pout.score.to_bits(), out.score.to_bits());
    }

    #[test]
    fn batched_seeds_merge_into_the_result_only() {
        struct Fixed(Vec<Mapping>, usize);
        impl BatchSource for Fixed {
            fn next_batch(&mut self, _f: &[Option<f64>], out: &mut Vec<Mapping>) {
                if self.1 == 0 {
                    out.extend(self.0.iter().cloned());
                    self.1 = 1;
                }
            }
        }
        let acc = presets::eyeriss();
        let layer = zoo::vgg02()[4].clone();
        let src = RandomStream::new(&layer, &acc, 3, 12);
        let mut pool = Vec::new();
        for b in 0..12 {
            let mut m = Mapping::trivial(&layer, acc.n_levels());
            src.emit_block(b, &mut m);
            pool.push(m);
        }
        let driver = SearchDriver {
            objective: Objective::Energy,
            budget: 3000,
            threads: 1,
            prune: false,
            deadline: None,
        };
        let plain = driver.search_batched(&layer, &acc, &mut Fixed(pool.clone(), 0)).unwrap();
        // Seeding with the stream's own winner: the exact tie resolves to
        // the proposal-stream copy, at one extra examined candidate.
        let seeded = driver
            .search_batched_seeded(
                &layer,
                &acc,
                &mut Fixed(pool.clone(), 0),
                &[plain.mapping.clone()],
            )
            .unwrap();
        assert_eq!(seeded.mapping, plain.mapping);
        assert_eq!(seeded.index, plain.index);
        assert_eq!(seeded.examined, plain.examined + 1);
        // A seed from a much larger search never worsens the result.
        let wide = driver
            .search(&layer, &acc, &RandomStream::new(&layer, &acc, 11, 400), &[])
            .unwrap();
        let boosted = driver
            .search_batched_seeded(&layer, &acc, &mut Fixed(pool.clone(), 0), &[wide.mapping])
            .unwrap();
        assert!(boosted.score <= plain.score);
        // An invalid seed is ignored entirely.
        let mut broken = plain.mapping.clone();
        broken.temporal[0][0] *= 7;
        let s2 =
            driver.search_batched_seeded(&layer, &acc, &mut Fixed(pool, 0), &[broken]).unwrap();
        assert_eq!(s2.examined, plain.examined);
        assert_eq!(s2.mapping, plain.mapping);
    }
}
